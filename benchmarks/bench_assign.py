"""Tile-assignment benchmark: dense top-K sweep vs sort-based scatter.

The ROADMAP "assignment-dominated" item: tiered rasterization won the
render phase ~2.5x but end-to-end training time is dominated by
``assign_tiles``'s dense O(T*N) per-tile sweep.  The sorted path
(``assign_tiles_sorted``) expands each splat into its overlapped tiles
under a static per-splat budget B and pays O(N*B log(N*B)) — independent
of the tile count — which is the production-trainer scaling (Grendel /
RetinaGS duplicate-and-sort).  This benchmark measures the crossover:

  assignment phase   jitted assign-only closures over a precomputed
      projection, dense vs sorted, swept over N (table size), sparsity
      (splat radius -> per-splat tile overlap), and tile count T.  Parity
      is asserted bit-identically (with overflow 0) before timing — a fast
      wrong assignment is not a speedup.

  end-to-end train step   ``make_train_step`` wall-clock with
      cfg.assign_impl = "dense" vs "sorted" on the sparse scene — the
      number the ROADMAP item asks for (recorded into the JSON the CI
      bench gate tracks).

Acceptance: the sorted path beats the dense sweep on the sparse high-N
config (largest N at the largest T in the sweep); exits 1 below
``--gate-floor``.  Saves JSON under experiments/benchmarks/assign.json.

    PYTHONPATH=src python -m benchmarks.bench_assign [--smoke] [--reps 3]
        [--gate-floor 1.0]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.core.cameras import orbital_rig, select
from repro.core.gaussians import from_points
from repro.core.projection import project
from repro.core.tiling import TileGrid, assign_tiles, assign_tiles_sorted
from repro.core.train import GSTrainCfg, make_train_step, init_opt


def _steady(fn, *, reps: int) -> float:
    jax.block_until_ready(fn())            # warmup: compile + first run
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _scene(n_points: int, *, res: int, scale: float, seed: int = 0):
    """Uniform point cloud over the frame; ``scale`` is the splat radius in
    units of the mean point spacing (0.4 = sparse isosurface-like overlap,
    3.0 = heavy-overlap worst case)."""
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0.0, 1.0, (n_points, 3))
    cols = rng.uniform(0.0, 1.0, (n_points, 3))
    spacing = 1.0 / max(n_points, 1) ** (1.0 / 3.0)
    g = from_points(jnp.asarray(pts, jnp.float32), jnp.asarray(cols),
                    init_scale=scale * spacing, opacity=0.9)
    cams = orbital_rig(2, (0.5, 0.5, 0.5), 2.6, width=res, height=res)
    return g, select(cams, 0)


def _bench_config(name, *, n_points, res, scale, budget, K, reps):
    """Time dense vs sorted assignment on one (N, T, sparsity) config."""
    grid = TileGrid(res, res, 8, 16)
    g, cam = _scene(n_points, res=res, scale=scale)
    splats = project(g, cam)

    fn_dense = jax.jit(lambda s: assign_tiles(s, grid, K=K))
    fn_sorted = jax.jit(lambda s: assign_tiles_sorted(s, grid, K=K,
                                                      tile_budget=budget))
    # parity first, bit-identically (overflow must be 0 for the comparison
    # to be apples-to-apples — grow the config's budget otherwise)
    i_d, s_d = fn_dense(splats)
    i_s, s_s, ov = assign_tiles_sorted(splats, grid, K=K, tile_budget=budget,
                                       return_overflow=True)
    assert int(ov) == 0, f"{name}: budget {budget} overflowed ({int(ov)})"
    np.testing.assert_array_equal(np.asarray(s_d), np.asarray(s_s))
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_s))

    t_d = _steady(lambda: fn_dense(splats), reps=reps)
    t_s = _steady(lambda: fn_sorted(splats), reps=reps)
    occ = np.asarray((np.asarray(s_d) > -1e29).sum(-1))
    print(f"  {name:18s} N={n_points:6d} T={grid.n_tiles:5d} B={budget:3d} "
          f"dense {t_d*1e3:8.2f} ms  sorted {t_s*1e3:8.2f} ms  "
          f"({t_d/t_s:5.2f}x)  med-occ "
          f"{int(np.median(occ[occ > 0])) if (occ > 0).any() else 0}")
    return {"n_points": n_points, "res": res, "n_tiles": grid.n_tiles,
            "scale": scale, "tile_budget": budget, "K": K,
            "t_dense_s": t_d, "t_sorted_s": t_s, "speedup": t_d / t_s}


def _bench_train_step(*, n_points, res, steps, reps, K):
    """End-to-end train-step wall-clock, dense vs sorted assignment (the
    tiered rasterizer default in both; only the assignment impl differs).
    The sorted cfg pins an explicit budget VERIFIED to cover the scene
    (overflow 0) — a fast wrong assignment is not a speedup here either."""
    grid = TileGrid(res, res, 8, 16)
    g, cam = _scene(n_points, res=res, scale=0.4)
    splats = project(g, cam)
    from repro.core.tiling import splat_tile_counts
    budget = int(np.asarray(splat_tile_counts(splats, grid)).max())
    _, _, ov = assign_tiles_sorted(splats, grid, K=K, tile_budget=budget,
                                   return_overflow=True)
    assert int(ov) == 0, f"train-step budget {budget} overflowed ({int(ov)})"
    gt = jnp.zeros((res, res, 3), jnp.float32)
    out = {}
    for impl in ("dense", "sorted"):
        cfg = GSTrainCfg(K=K, assign_impl=impl, assign_budget=budget)
        step = jax.jit(make_train_step(cfg, grid, extent=1.0))
        opt = init_opt(g)

        def run(g=g, opt=opt, step=step):
            gg, oo = g, opt
            for _ in range(steps):
                gg, oo, loss = step(gg, oo, cam, gt)
            return loss

        out[impl] = _steady(run, reps=reps)
    print(f"  train-step ({steps} steps) N={n_points} T={grid.n_tiles}: "
          f"dense {out['dense']*1e3:8.1f} ms  sorted "
          f"{out['sorted']*1e3:8.1f} ms  "
          f"({out['dense']/out['sorted']:.2f}x)")
    return {"n_points": n_points, "res": res, "n_tiles": grid.n_tiles,
            "steps": steps, "K": K, "tile_budget": budget,
            "t_dense_s": out["dense"], "t_sorted_s": out["sorted"],
            "speedup": out["dense"] / out["sorted"]}


def run(*, reps: int = 3, quick: bool = False, gate_floor: float = 1.0):
    K = 32
    if quick:
        # CI smoke tier: small sweep, the largest config still shows the
        # scaling (T=512 tiles x 24k splats)
        configs = [
            ("sparse-small", dict(n_points=6000, res=128, scale=0.4,
                                  budget=16)),
            ("sparse-high-N", dict(n_points=24000, res=256, scale=0.4,
                                   budget=16)),
            ("dense-overlap", dict(n_points=6000, res=128, scale=3.0,
                                   budget=64)),
        ]
        train_cfg = dict(n_points=6000, res=128, steps=2)
    else:
        configs = [
            ("sparse-small", dict(n_points=20000, res=128, scale=0.4,
                                  budget=16)),
            ("sparse-mid-T", dict(n_points=20000, res=256, scale=0.4,
                                  budget=16)),
            ("sparse-high-N", dict(n_points=80000, res=512, scale=0.4,
                                   budget=16)),
            ("dense-overlap", dict(n_points=20000, res=256, scale=3.0,
                                   budget=144)),
        ]
        train_cfg = dict(n_points=48000, res=512, steps=2)

    print("\n[assign] dense O(T*N) sweep vs sorted O(N*B log) scatter, "
          f"K={K}, reps={reps}")
    results = {"K": K, "reps": reps, "configs": {}}
    for name, c in configs:
        results["configs"][name] = _bench_config(name, K=K, reps=reps, **c)
    results["train_step"] = _bench_train_step(K=K, reps=reps, **train_cfg)

    headline = results["configs"]["sparse-high-N"]["speedup"]
    ok = headline >= gate_floor
    print(f"  acceptance: sorted >= {gate_floor:.2f}x dense on "
          f"sparse-high-N: {headline:.2f}x {'PASS' if ok else 'FAIL'}")
    results.update({"gate_floor": gate_floor, "gate_pass": ok,
                    "headline_speedup": headline})
    save_result("assign", results)
    if not ok:
        raise SystemExit(
            f"assign acceptance FAILED: sorted {headline:.2f}x < "
            f"{gate_floor}x dense on the sparse high-N config")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny settings for CI smoke runs")
    ap.add_argument("--gate-floor", type=float, default=1.0,
                    help="min sorted/dense speedup on the sparse high-N "
                         "config before exiting 1")
    args = ap.parse_args()
    run(reps=args.reps, quick=args.smoke, gate_floor=args.gate_floor)


if __name__ == "__main__":
    main()
