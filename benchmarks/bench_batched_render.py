"""Batched multi-view rendering benchmark (tentpole acceptance gate).

Two measurements of rendering V views on the cpu tier:

  render-phase (headline, acceptance):  the pipeline's real usage — R
      successive render_views calls over R different gaussian sets (GT,
      per-partition GT, merged, ...), cold start.  The seed's per-view
      Python loop rebuilt its jit closure per call, so every round paid a
      full recompile plus V dispatches + V host syncs; the batched path
      compiles once (cached jit) and issues one fused dispatch per chunk.

  steady-state:  per-call wall-clock with compilation excluded on both
      sides — the honest lower bound on the win (dispatch amortization +
      cross-view vectorization only).

Acceptance: render-phase speedup >= 2x for V >= 8.  Saves JSON under
experiments/benchmarks/batched_render.json.

    PYTHONPATH=src python -m benchmarks.bench_batched_render [--views 8]
        [--res 64] [--points 4000] [--smoke]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.core import pipeline as pl
from repro.core.cameras import select
from repro.core.cameras import orbital_rig
from repro.core.pipeline import gt_gaussians, render_views
from repro.core.render import render, render_batch
from repro.core.tiling import TileGrid
from repro.data.isosurface import point_cloud_for


def seed_render_views(g, cams, grid, *, K, impl="ref", bg=1.0):
    """The seed's pipeline.render_views, verbatim shape: a fresh jit closure
    (recompiles per call), one dispatch + host sync per view."""
    rfn = jax.jit(lambda gg, cam: render(gg, cam, grid, K=K, impl=impl, bg=bg))
    rgbs, covs = [], []
    for v in range(cams.view.shape[0]):
        out = rfn(g, select(cams, v))
        rgbs.append(np.asarray(out.rgb))
        covs.append(np.asarray(out.coverage))
    return np.stack(rgbs), np.stack(covs)


def _steady(fn, *, reps: int) -> float:
    fn()                                   # warmup: compile + first run
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def run(*, views: int = 8, res: int = 64, n_points: int = 4000, K: int = 32,
        rounds: int = 5, reps: int = 3, quick: bool = False,
        gate_floor: float = 2.0):
    if quick:
        views, res, n_points, reps = max(4, views // 2), min(res, 48), 1500, 2
    pts, cols = point_cloud_for("sphere_shell", n_points)
    center = 0.5 * (pts.max(0) + pts.min(0))
    extent = float(np.linalg.norm(pts.max(0) - pts.min(0)))
    cams = orbital_rig(views, center, 1.5 * extent, width=res, height=res)
    grid = TileGrid(res, res, 8, 16)
    # R distinct same-shaped gaussian sets — run_pipeline(n_parts=2) makes
    # exactly 5 such render_views calls per run (global GT, 2x partition GT,
    # merged eval, boundary coverage)
    gs = [gt_gaussians(pts + 0.001 * r, cols) for r in range(rounds)]

    # parity first — a fast wrong renderer is not a speedup
    rgb_l, _ = seed_render_views(gs[0], cams, grid, K=K)
    rgb_b, _ = render_views(gs[0], cams, grid, K=K, impl="ref", batch=views)
    np.testing.assert_allclose(rgb_l, rgb_b, rtol=1e-5, atol=1e-5)

    # ---- render phase, cold start on both sides ----
    pl._render_batch_jit.cache_clear()
    jax.clear_caches()
    t0 = time.perf_counter()
    for g in gs:
        seed_render_views(g, cams, grid, K=K)
    t_loop_phase = time.perf_counter() - t0

    jax.clear_caches()
    t0 = time.perf_counter()
    for g in gs:
        render_views(g, cams, grid, K=K, impl="ref", batch=views)
    t_batch_phase = time.perf_counter() - t0
    phase_speedup = t_loop_phase / t_batch_phase

    # ---- steady state (compile excluded on both sides) ----
    rfn = jax.jit(lambda gg, cam: render(gg, cam, grid, K=K, impl="ref"))
    rb = jax.jit(lambda gg, cc: render_batch(gg, cc, grid, K=K, impl="ref"))
    g = gs[0]
    vi = jnp.arange(views)

    def loop_steady():
        outs = []
        for v in range(views):
            out = rfn(g, select(cams, v))
            outs.append((np.asarray(out.rgb), np.asarray(out.coverage)))
        return outs

    def batch_steady():
        out = rb(g, select(cams, vi))
        return np.asarray(out.rgb), np.asarray(out.coverage)

    t_loop_ss = _steady(loop_steady, reps=reps)
    t_batch_ss = _steady(batch_steady, reps=reps)
    ss_speedup = t_loop_ss / t_batch_ss

    print(f"\n[batched_render] V={views} res={res} N={n_points} K={K} "
          f"rounds={rounds}")
    print(f"  render phase: loop {t_loop_phase*1e3:8.1f} ms   "
          f"batch {t_batch_phase*1e3:8.1f} ms   ({phase_speedup:.2f}x)")
    print(f"  steady state: loop {t_loop_ss*1e3:8.1f} ms   "
          f"batch {t_batch_ss*1e3:8.1f} ms   ({ss_speedup:.2f}x)")
    gated = views >= 8            # the speedup gate only binds at V >= 8
    ok = phase_speedup >= gate_floor or not gated
    print(f"  acceptance (render phase >={gate_floor}x for V>=8): "
          f"{'PASS' if ok else 'FAIL'}" + ("" if gated else " (ungated: V<8)"))

    save_result("batched_render", {
        "views": views, "res": res, "n_points": n_points, "K": K,
        "rounds": rounds,
        "t_loop_phase_s": t_loop_phase, "t_batch_phase_s": t_batch_phase,
        "phase_speedup": phase_speedup,
        "t_loop_steady_s": t_loop_ss, "t_batch_steady_s": t_batch_ss,
        "steady_speedup": ss_speedup,
        # what was actually tested: the floor used and whether V bound it —
        # "pass" at floor 1.3 or ungated (V<8) is NOT the 2x criterion
        "gate_floor": gate_floor, "gated": gated, "gate_pass": ok,
        "meets_2x_criterion": bool(gated and phase_speedup >= 2.0),
    })
    if not ok:
        # fail the build, not just the log line.  Local/default runs gate
        # at the 2x acceptance criterion; CI passes --gate-floor 1.3 so a
        # noisy shared runner can't flake the build while a true regression
        # (e.g. reintroducing per-chunk recompiles, ~1.0x) still fails.
        raise SystemExit(
            f"batched_render acceptance FAILED: {phase_speedup:.2f}x < "
            f"{gate_floor}x at V={views}")
    return phase_speedup


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--views", type=int, default=8)
    ap.add_argument("--res", type=int, default=64)
    ap.add_argument("--points", type=int, default=4000)
    ap.add_argument("--K", type=int, default=32)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny settings for CI smoke runs")
    ap.add_argument("--gate-floor", type=float, default=2.0,
                    help="min render-phase speedup at V>=8 before exiting 1 "
                         "(CI uses a lower floor to absorb runner noise)")
    args = ap.parse_args()
    run(views=args.views, res=args.res, n_points=args.points, K=args.K,
        quick=args.smoke, gate_floor=args.gate_floor)


if __name__ == "__main__":
    main()
