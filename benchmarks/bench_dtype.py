"""Mixed-precision dtype policy: payload + wall-clock at f32 vs bf16, and
the int8 cold-attribute checkpoint size (PR 8 tentpole gate).

Under ``dtype_policy="bf16"`` (core.dtypes) the gathered/exchanged splat
tables move over the collectives in bf16 — every lane of every row halves,
so the per-device communicated payload is EXACTLY half the f32 policy's
(asserted, not just reported).  Compositing still accumulates f32, so the
policy is a storage/wire dtype, not a math change — which is why parity
can be asserted before anything is timed:

  * WITHIN the bf16 policy the sparse exchange must still equal the
    all-gather at 1e-6 (both move identically rounded rows);
  * ACROSS policies the loss gap is bf16 input rounding through the
    compositor, bounded at 5e-2 relative (the distributed test suite pins
    the same band).

Wall-clock is reported for context only: on forced HOST devices the
collectives are memcpy-emulated, so payload bytes — not step time — is the
headline number (same caveat as bench_exchange).

The int8 checkpoint leg quantizes the scene's cold attributes (SH color +
opacity logit, runtime.checkpoint.quantize_cold) and measures real bytes
on disk vs the f32 checkpoint — the size must actually shrink.

Runs its measurement in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the flag must be
set before jax initializes), mesh ("part",) x 4.

    PYTHONPATH=src python -m benchmarks.bench_dtype [--smoke]
        [--res 128] [--points-per-part 512] [--reps 3]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from benchmarks.common import save_result

N_DEV = 4


def _inner(*, res: int, n_local: int, views: int, reps: int):
    """Runs inside the forced-host-device subprocess; prints one RESULT
    line of JSON as its last stdout line."""
    import tempfile
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.cameras import orbital_rig, select
    from repro.core.distributed import (ExchangeSchedule, gs_shardings,
                                        make_gs_exchange_probe,
                                        make_gs_forward, make_gs_train_step)
    from repro.core.gaussians import from_points
    from repro.core.projection import project
    from repro.core.tiling import TileGrid, splat_features
    from repro.core.train import GSOptState, GSTrainCfg
    from repro.data.isosurface import point_cloud_for
    from repro.runtime.checkpoint import CheckpointManager, quantize_cold

    K = 16
    n_total = N_DEV * n_local
    grid = TileGrid(res, res, 8, 16)
    pts, cols = point_cloud_for("kingsnake", int(n_total * 1.5))
    pts, cols = pts[:n_total], cols[:n_total]
    cams = orbital_rig(views, (0.5, 0.5, 0.5), 0.8, width=res, height=res)
    cam_b = select(cams, jnp.arange(views))
    g_all = from_points(jnp.asarray(pts), jnp.asarray(cols),
                        init_scale=0.008 if res >= 128 else 0.01,
                        opacity=0.8)
    g_b = jax.tree.map(lambda x: x[None], g_all)       # (P=1, N, ...)

    mesh = jax.make_mesh((N_DEV,), ("part",))
    g_sh, opt_sh, b_sh = gs_shardings(mesh, views=views)
    g_dev = jax.device_put(g_b, g_sh)
    cam_dev = jax.device_put(cam_b, b_sh["cam"])

    # ---- payload accounting: the gathered table is rows x (F + 3) lanes;
    # the wire dtype is the whole story, so bf16 is EXACTLY half ----
    F = splat_features(project(g_all, select(cams, 0))).shape[-1]
    rows = N_DEV * views * n_local
    payload_f32 = rows * (F + 3) * 4
    payload_bf16 = rows * (F + 3) * 2
    assert payload_bf16 * 2 == payload_f32

    gt = jnp.zeros((views, grid.n_tiles, 3, grid.tile_h, grid.tile_w))
    mask = jnp.ones((views, grid.n_tiles, grid.tile_h, grid.tile_w), bool)
    gt_dev = jax.device_put(gt, b_sh["gt_tiles"])
    mask_dev = jax.device_put(mask, b_sh["mask_tiles"])
    batch = {"gt_tiles": gt_dev, "mask_tiles": mask_dev, "cam": cam_dev}

    # ---- parity BEFORE timing #1: within the bf16 policy the exchange
    # forward equals the all-gather forward at 1e-6 ----
    max_edge = int(jax.jit(make_gs_exchange_probe(mesh, grid, views=views))(
        g_dev, cam_dev))
    E = ExchangeSchedule().probe_budget(max_edge, n_local)
    l_pair = []
    for exch in (False, True):
        f = make_gs_forward(mesh, grid, K=K, impl="ref", views=views,
                            dtype_policy="bf16", exchange=exch,
                            exchange_budget=E if exch else None)
        l_pair.append(float(jax.jit(f)(g_dev, cam_dev, gt_dev, mask_dev)))
    np.testing.assert_allclose(l_pair[1], l_pair[0], rtol=1e-6, atol=1e-7)

    def fresh_state():
        g = jax.tree.map(jnp.array, g_b)
        tr = {k: getattr(g, k) for k in
              ("means", "log_scales", "quats", "opacity_logit", "colors")}
        o = GSOptState(
            m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
            v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
            step=jnp.int32(0),
            grad_accum=jnp.zeros((1, n_total)),
            grad_count=jnp.zeros((1, n_total)))
        return jax.device_put(g, g_sh), jax.device_put(o, opt_sh)

    def timed(cfg):
        step = make_gs_train_step(mesh, cfg, grid, extent=1.0, impl="ref",
                                  views=views)
        g, o = fresh_state()
        g, o, loss = step(g, o, batch)                 # warmup: compile
        loss = float(jax.block_until_ready(loss))
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            g, o, l = step(g, o, batch)
            jax.block_until_ready(l)
            best = min(best, time.perf_counter() - t0)
        return best, loss

    t32, l32 = timed(GSTrainCfg(K=K))
    tbf, lbf = timed(GSTrainCfg(K=K, dtype_policy="bf16"))
    # parity BEFORE reporting #2: the cross-policy loss gap stays in the
    # documented bf16 rounding band
    assert abs(lbf - l32) <= 5e-2 * abs(l32) + 1e-6, (lbf, l32)

    # ---- int8 cold-attribute checkpoint: real bytes on disk ----
    def ckpt_bytes(tree, extra=None):
        with tempfile.TemporaryDirectory() as td:
            d = CheckpointManager(td).save(1, tree, extra=extra)
            return sum(os.path.getsize(os.path.join(d, f))
                       for f in os.listdir(d) if f.endswith(".npy"))

    q, meta = quantize_cold(g_all)
    ck32 = ckpt_bytes(g_all)
    ck8 = ckpt_bytes(q, extra={"quant": meta})
    assert ck8 < ck32

    print("RESULT " + json.dumps({
        "n_devices": N_DEV, "n_local": n_local, "views": views, "res": res,
        "feature_lanes": F + 3, "exchange_budget": E,
        "payload_bytes_f32": payload_f32,
        "payload_bytes_bf16": payload_bf16,
        "payload_ratio": payload_f32 / payload_bf16,
        "t_step_f32_s": t32, "t_step_bf16_s": tbf,
        "loss_f32": l32, "loss_bf16": lbf,
        "loss_rel_gap": abs(lbf - l32) / max(abs(l32), 1e-12),
        "ckpt_bytes_f32": ck32, "ckpt_bytes_int8": ck8,
        "ckpt_reduction": ck32 / ck8}))


def run(*, res: int = 128, n_local: int = 512, views: int = 4,
        reps: int = 3, quick: bool = False):
    if quick:
        res, n_local, views, reps = 64, 256, 2, 2
    cmd = [sys.executable, "-m", "benchmarks.bench_dtype", "--inner",
           "--res", str(res), "--points-per-part", str(n_local),
           "--views", str(views), "--reps", str(reps)]
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={N_DEV}",
               JAX_PLATFORMS="cpu")
    env.setdefault("PYTHONPATH", "src")
    print(f"\n[dtype] res={res} n_local={n_local} x{N_DEV} parts "
          f"V={views} (subprocess, {N_DEV} forced host devices)")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=1800)
    sys.stdout.write(proc.stdout[: proc.stdout.rfind("RESULT ")])
    sys.stderr.write(proc.stderr[-2000:] if proc.returncode else "")
    if proc.returncode:
        raise SystemExit(f"bench_dtype inner failed ({proc.returncode})")
    r = json.loads(proc.stdout.rstrip().rsplit("RESULT ", 1)[1])

    mb = 1.0 / (1024 * 1024)
    print("  gathered-table payload: f32 "
          f"{r['payload_bytes_f32'] * mb:7.2f} MiB  bf16 "
          f"{r['payload_bytes_bf16'] * mb:7.2f} MiB  "
          f"({r['payload_ratio']:.0f}x smaller — every wire lane halves)")
    print(f"  train step: f32 {r['t_step_f32_s'] * 1e3:8.2f} ms  bf16 "
          f"{r['t_step_bf16_s'] * 1e3:8.2f} ms  (host-device collectives "
          "are memcpy-emulated — payload is the headline)")
    print(f"  loss gap f32 vs bf16: {r['loss_rel_gap']:.2e} relative "
          "(parity asserted in-process before timing)")
    print(f"  merged checkpoint: f32 {r['ckpt_bytes_f32'] * mb:6.2f} MiB  "
          f"int8-cold {r['ckpt_bytes_int8'] * mb:6.2f} MiB  "
          f"({r['ckpt_reduction']:.2f}x smaller)")
    save_result("dtype", r)
    return r


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--res", type=int, default=128)
    ap.add_argument("--points-per-part", type=int, default=512)
    ap.add_argument("--views", type=int, default=4)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--inner", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.inner:
        _inner(res=args.res, n_local=args.points_per_part,
               views=args.views, reps=args.reps)
        return
    run(res=args.res, n_local=args.points_per_part, views=args.views,
        reps=args.reps, quick=args.smoke)


if __name__ == "__main__":
    main()
