"""Sparse-overlap splat exchange vs the full-table all-gather (tentpole
gate for the exchange path in core/distributed.py).

The all-gather moves EVERY partition's projected table to every device even
though a device's tile sub-window only needs the splats whose bboxes
overlap it.  The exchange probes a PER-EDGE (src, dst) budget matrix and
moves only the overlapping rows via a ragged ppermute ladder — so the
per-device communicated payload drops proportionally to the probed edge
overlap, not the single worst edge.  With overlap-aware (Morton-ordered)
partitioning each shard is a compact brick whose overlap concentrates on a
few screen bands, and the overlap-aware window assignment
(``window_assignment``) parks each brick's dominant band on the free local
shift — together the per-device payload DECREASES with n_part at paper
scale, the strong-scaling property this benchmark measures and (in sweep
mode) gates.  Exchange == gather loss parity at 1e-6 is asserted before
any timing, so the measured configs are known-equal.

Each measurement runs in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=<n_part>`` (the flag
must be set before jax initializes, and the orchestrator has long since
imported jax), mesh ("part",) x n_part.  The TOTAL splat count is held
fixed across a sweep — scaling n_part splits the same scene finer, the
paper's strong-scaling axis.

    PYTHONPATH=src python -m benchmarks.bench_exchange [--smoke]
        [--n-part 4,8,16] [--res 256] [--points-per-part 4096] [--reps 3]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from benchmarks.common import save_result


def _inner(*, res: int, n_total: int, n_dev: int, views: int, reps: int,
           spatial_sort: bool):
    """Runs inside the forced-host-device subprocess; prints one RESULT
    line of JSON as its last stdout line."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.cameras import orbital_rig, select
    from repro.core.distributed import (ExchangeSchedule, gs_shardings,
                                        make_gs_exchange_probe,
                                        make_gs_train_step,
                                        window_assignment)
    from repro.core.gaussians import from_points
    from repro.core.partition import spatial_order
    from repro.core.projection import project
    from repro.core.tiling import TileGrid, splat_features
    from repro.core.train import GSOptState, GSTrainCfg

    K = 16
    n_local = n_total // n_dev
    grid = TileGrid(res, res, 8, 16)
    # kingsnake close-up: the surface fills the frame and spreads across
    # the horizontal tile bands, so each device's sub-window genuinely sees
    # only a fraction of each peer's splats — the regime the exchange
    # exists for.  point_cloud_for returns ~n points, so over-request and
    # slice.
    from repro.data.isosurface import point_cloud_for
    pts, cols = point_cloud_for("kingsnake", int(n_total * 1.5))
    assert pts.shape[0] >= n_total, pts.shape
    pts, cols = pts[:n_total], cols[:n_total]
    if spatial_sort:
        # overlap-aware layout: Morton-order the rows so each contiguous
        # "part" shard is a compact spatial brick (core.partition) — the
        # condition under which per-edge overlap shrinks with n_part
        order = spatial_order(pts)
        pts, cols = pts[order], cols[order]
    cams = orbital_rig(views, (0.5, 0.5, 0.5), 0.8, width=res, height=res)
    cam_b = select(cams, jnp.arange(views))
    g_all = from_points(jnp.asarray(pts), jnp.asarray(cols),
                        init_scale=0.004 if res >= 256
                        else 0.008 if res >= 128 else 0.01,
                        opacity=0.8)
    g_b = jax.tree.map(lambda x: x[None], g_all)       # (P=1, N, ...)

    mesh = jax.make_mesh((n_dev,), ("part",))
    g_sh, opt_sh, b_sh = gs_shardings(mesh, views=views)
    g_dev = jax.device_put(g_b, g_sh)
    cam_dev = jax.device_put(cam_b, b_sh["cam"])

    # ---- probe the per-edge demand matrix; payload is rows * row_bytes.
    # The bench sizes budgets at EXACT demand (slack=1, round_to=1): the
    # wire payload then measures the true probed overlap, not the
    # schedule's safety margin (production keeps the slack; parity below
    # holds either way because the probe covers the timed views).
    probe = jax.jit(make_gs_exchange_probe(mesh, grid, views=views,
                                           per_edge=True))
    demand = np.asarray(probe(g_dev, cam_dev))
    es = ExchangeSchedule(slack=1.0, round_to=1)
    B = np.asarray(es.probe_budget(demand, n_local))
    # the transport's slab heights: ring shift k moves every
    # (s -> (s+k) % n) edge in one slab sized by that shift's worst edge
    # (core.distributed ppermute ladder), with the overlap-aware window
    # assignment tau pulling each brick's dominant band onto the free
    # local shift — the same tau the forward derives from this budget
    ring = (np.arange(n_dev) + np.arange(n_dev)[:, None]) % n_dev
    tau = window_assignment(np.minimum(B, n_local))
    e_shift = np.array([B[np.arange(n_dev), tau[ring[k]]].max()
                        for k in range(n_dev)], np.int64)
    rows_wire = int(e_shift[1:].sum())           # communicated rows/device
    rows_all = int(e_shift.sum())                # incl. the local slab
    F = splat_features(project(g_all, select(cams, 0))).shape[-1]
    # per-dtype row accounting: the wire dtype follows cfg.dtype_policy
    # (core.dtypes) — f32 rows are (F + 3) * 4 bytes (feat + aux), bf16
    # halves every lane (bench_dtype times the policies; here the bf16
    # payload rides along so the exchange table reports both)
    row_bytes = (F + 3) * 4
    row_bytes_bf16 = (F + 3) * 2
    bytes_gather = (n_dev - 1) * views * n_local * row_bytes
    bytes_exchange = rows_wire * views * row_bytes

    # ---- one train step, gather vs exchange (parity gates the timing) ----
    gt = jnp.zeros((views, grid.n_tiles, 3, grid.tile_h, grid.tile_w))
    mask = jnp.ones((views, grid.n_tiles, grid.tile_h, grid.tile_w), bool)
    batch = {"gt_tiles": jax.device_put(gt, b_sh["gt_tiles"]),
             "mask_tiles": jax.device_put(mask, b_sh["mask_tiles"]),
             "cam": cam_dev}

    def fresh_state():
        # fresh buffers each config: the step DONATES g/opt, and device_put
        # aliases (doesn't copy) leaves whose sharding already matches, so
        # reusing one host tree across configs would hand the second run
        # deleted buffers
        g = jax.tree.map(jnp.array, g_b)
        tr = {k: getattr(g, k) for k in
              ("means", "log_scales", "quats", "opacity_logit", "colors")}
        o = GSOptState(
            m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
            v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
            step=jnp.int32(0),
            grad_accum=jnp.zeros((1, n_total)),
            grad_count=jnp.zeros((1, n_total)))
        return jax.device_put(g, g_sh), jax.device_put(o, opt_sh)

    def timed(cfg, budget):
        step = make_gs_train_step(mesh, cfg, grid, extent=1.0, impl="ref",
                                  views=views, exchange_budget=budget)
        # the step donates g/opt, so thread the returned state through
        g, o = fresh_state()
        g, o, loss = step(g, o, batch)                 # warmup: compile
        loss = float(jax.block_until_ready(loss))
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            g, o, l = step(g, o, batch)
            jax.block_until_ready(l)
            best = min(best, time.perf_counter() - t0)
        return best, loss

    t_g, l_g = timed(GSTrainCfg(K=K), None)
    t_e, l_e = timed(GSTrainCfg(K=K, exchange=True), B)
    np.testing.assert_allclose(l_e, l_g, rtol=1e-6, atol=1e-7)

    print("RESULT " + json.dumps({
        "n_devices": n_dev, "n_local": n_local, "n_total": n_total,
        "views": views, "res": res, "n_tiles": grid.n_tiles,
        "spatial_sort": spatial_sort,
        "max_edge_overlap": int(demand.max()),
        "mean_edge_overlap": float(demand.mean()),
        "budget": int(B.max()), "budget_matrix_rows_wire": rows_wire,
        "budget_matrix_rows_all": rows_all,
        "overlap_frac": int(demand.max()) / n_local,
        "payload_bytes_gather": bytes_gather,
        "payload_bytes_exchange": bytes_exchange,
        "payload_bytes_gather_bf16":
            (n_dev - 1) * views * n_local * row_bytes_bf16,
        "payload_bytes_exchange_bf16": rows_wire * views * row_bytes_bf16,
        "payload_reduction": bytes_gather / max(bytes_exchange, 1),
        "t_step_gather_s": t_g, "t_step_exchange_s": t_e,
        "step_speedup": t_g / t_e, "loss": l_g}))


def _run_one(*, res: int, n_total: int, n_dev: int, views: int, reps: int,
             spatial_sort: bool) -> dict:
    cmd = [sys.executable, "-m", "benchmarks.bench_exchange", "--inner",
           "--res", str(res), "--n-total", str(n_total),
           "--n-part", str(n_dev), "--views", str(views),
           "--reps", str(reps)]
    if not spatial_sort:
        cmd.append("--no-spatial-sort")
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={n_dev}",
               JAX_PLATFORMS="cpu")
    env.setdefault("PYTHONPATH", "src")
    print(f"\n[exchange] res={res} n_total={n_total} x{n_dev} parts "
          f"V={views} sort={spatial_sort} "
          f"(subprocess, {n_dev} forced host devices)")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=1800)
    sys.stdout.write(proc.stdout[: proc.stdout.rfind("RESULT ")])
    sys.stderr.write(proc.stderr[-2000:] if proc.returncode else "")
    if proc.returncode:
        raise SystemExit(f"bench_exchange inner failed ({proc.returncode})")
    r = json.loads(proc.stdout.rstrip().rsplit("RESULT ", 1)[1])

    mb = 1.0 / (1024 * 1024)
    print(f"  probed edge overlap: worst {r['max_edge_overlap']}"
          f"/{r['n_local']} ({r['overlap_frac']:.1%}), "
          f"mean {r['mean_edge_overlap']:.1f}")
    print("  per-device payload: all-gather "
          f"{r['payload_bytes_gather'] * mb:7.2f} MiB  exchange "
          f"{r['payload_bytes_exchange'] * mb:7.2f} MiB  "
          f"({r['payload_reduction']:.2f}x smaller, proportional to the "
          "probed per-edge overlap)")
    print(f"  train step: gather {r['t_step_gather_s'] * 1e3:8.2f} ms  "
          f"exchange {r['t_step_exchange_s'] * 1e3:8.2f} ms  "
          f"({r['step_speedup']:.2f}x; host-device collectives are "
          "memcpy-emulated — payload is the headline)")
    return r


def run(*, res: int = 256, n_local: int = 4096, views: int = 4,
        reps: int = 3, quick: bool = False,
        gate_floor: float | None = None,
        n_parts: tuple = (4,), spatial_sort: bool = True):
    """Sweep the exchange over ``n_parts`` partition counts at a FIXED
    total splat count (``n_local`` is the per-part count at the first
    entry).  With more than one entry the sweep GATES on the per-device
    exchange payload strictly decreasing as n_part grows — the scaling
    property per-edge budgets + overlap-aware partitioning exist for.
    ``gate_floor`` additionally requires the first entry's payload
    reduction over the all-gather to meet the floor.  Returns the first
    entry's result dict (the orchestrator's wall-clock entry), with the
    full sweep under ``"sweep"``."""
    if quick:
        res, n_local, views, reps = 64, 256, 2, 2
    n_parts = tuple(int(n) for n in n_parts)
    n_total = n_local * n_parts[0]
    results = []
    for n_dev in n_parts:
        if n_total % n_dev:
            raise SystemExit(f"--n-part {n_dev} must divide the total "
                             f"splat count {n_total}")
        results.append(_run_one(res=res, n_total=n_total, n_dev=n_dev,
                                views=views, reps=reps,
                                spatial_sort=spatial_sort))

    r = dict(results[0])
    r["sweep"] = [
        {k: x[k] for k in ("n_devices", "n_local", "payload_bytes_exchange",
                           "payload_bytes_gather", "payload_reduction",
                           "max_edge_overlap", "mean_edge_overlap",
                           "budget_matrix_rows_wire", "t_step_exchange_s",
                           "t_step_gather_s")}
        for x in results]
    save_result("exchange", r)
    if len(results) > 1:
        pay = [x["payload_bytes_exchange"] for x in results]
        print(f"\n[exchange] payload sweep over n_part={list(n_parts)}: "
              + " -> ".join(f"{p / (1 << 20):.2f} MiB" for p in pay))
        for a, b, na, nb in zip(pay, pay[1:], n_parts, n_parts[1:]):
            if b >= a:
                raise SystemExit(
                    "exchange scale gate FAILED: per-device payload did "
                    f"not decrease from n_part={na} ({a}B) to n_part={nb} "
                    f"({b}B) — per-edge budgets + spatial partitioning "
                    "are not delivering overlap that shrinks with scale")
    if gate_floor is not None and r["payload_reduction"] < gate_floor:
        raise SystemExit(
            f"exchange payload gate FAILED: {r['payload_reduction']:.2f}x "
            f"reduction below floor {gate_floor:.2f}x — the probed budget "
            "no longer undercuts the full table")
    return r


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--res", type=int, default=256)
    ap.add_argument("--points-per-part", type=int, default=4096,
                    help="per-part splats at the FIRST --n-part entry; the "
                         "total count stays fixed across the sweep")
    ap.add_argument("--views", type=int, default=4)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--n-part", default="4",
                    help="comma-separated partition counts to sweep, e.g. "
                         "4,8,16 (each runs a subprocess with that many "
                         "forced host devices)")
    ap.add_argument("--no-spatial-sort", action="store_true",
                    help="skip the Morton row sort (shows the scrambled-"
                         "layout overlap the sweep gate would fail on)")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--gate-floor", type=float, default=None,
                    help="fail unless the exchange payload is at least this "
                         "factor smaller than the all-gather's")
    ap.add_argument("--n-total", type=int, default=None,
                    help=argparse.SUPPRESS)      # inner-only
    ap.add_argument("--inner", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.inner:
        _inner(res=args.res, n_total=args.n_total,
               n_dev=int(args.n_part), views=args.views, reps=args.reps,
               spatial_sort=not args.no_spatial_sort)
        return
    run(res=args.res, n_local=args.points_per_part, views=args.views,
        reps=args.reps, quick=args.smoke, gate_floor=args.gate_floor,
        n_parts=tuple(int(x) for x in args.n_part.split(",")),
        spatial_sort=not args.no_spatial_sort)


if __name__ == "__main__":
    main()
