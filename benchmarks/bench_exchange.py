"""Sparse-overlap splat exchange vs the full-table all-gather (tentpole
gate for the exchange path in core/distributed.py).

The all-gather moves EVERY partition's projected table to every device even
though a device's tile sub-window only needs the splats whose bboxes
overlap it.  The exchange probes a per-(src, dst) edge budget E and moves
exactly ``n_data * E`` rows per table tensor via one ``lax.all_to_all`` —
so the per-device communicated payload drops from ``n_data * n_local`` rows
to ``n_data * E`` rows, i.e. proportionally to the probed strip overlap.
This benchmark measures that proportionality on a real scene (plus the
train-step wall-clocks for context — on forced HOST devices the collective
is memcpy-emulated, so payload, not wall-clock, is the headline number) and
asserts exchange/gather loss parity so the timed configs are known-equal.

Runs its measurement in a SUBPROCESS with
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the flag must be
set before jax initializes, and the orchestrator has long since imported
jax), mesh ("part",) x 4.

    PYTHONPATH=src python -m benchmarks.bench_exchange [--smoke]
        [--res 128] [--points-per-part 1024] [--reps 3]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

from benchmarks.common import save_result

N_DEV = 4


def _inner(*, res: int, n_local: int, views: int, reps: int):
    """Runs inside the forced-host-device subprocess; prints one RESULT
    line of JSON as its last stdout line."""
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.cameras import orbital_rig, select
    from repro.core.distributed import (ExchangeSchedule, gs_shardings,
                                        make_gs_exchange_probe,
                                        make_gs_train_step)
    from repro.core.gaussians import from_points
    from repro.core.projection import project
    from repro.core.tiling import TileGrid, splat_features
    from repro.core.train import GSOptState, GSTrainCfg
    from repro.data.isosurface import point_cloud_for

    K = 16
    n_total = N_DEV * n_local
    grid = TileGrid(res, res, 8, 16)
    # kingsnake close-up: the surface fills the frame and spreads across
    # the horizontal tile bands, so each device's sub-window genuinely sees
    # only a fraction of each peer's splats (~28% probed overlap) — the
    # regime the exchange exists for.  point_cloud_for returns ~n points,
    # so over-request and slice.
    pts, cols = point_cloud_for("kingsnake", int(n_total * 1.5))
    assert pts.shape[0] >= n_total, pts.shape
    pts, cols = pts[:n_total], cols[:n_total]
    cams = orbital_rig(views, (0.5, 0.5, 0.5), 0.8, width=res, height=res)
    cam_b = select(cams, jnp.arange(views))
    g_all = from_points(jnp.asarray(pts), jnp.asarray(cols),
                        init_scale=0.008 if res >= 128 else 0.01,
                        opacity=0.8)
    g_b = jax.tree.map(lambda x: x[None], g_all)       # (P=1, N, ...)

    mesh = jax.make_mesh((N_DEV,), ("part",))
    g_sh, opt_sh, b_sh = gs_shardings(mesh, views=views)
    g_dev = jax.device_put(g_b, g_sh)
    cam_dev = jax.device_put(cam_b, b_sh["cam"])

    # ---- probe the edge budget; payload is rows * row_bytes ----
    probe = jax.jit(make_gs_exchange_probe(mesh, grid, views=views))
    max_edge = int(probe(g_dev, cam_dev))
    es = ExchangeSchedule()
    E = es.probe_budget(max_edge, n_local)
    F = splat_features(project(g_all, select(cams, 0))).shape[-1]
    # per-dtype row accounting: the wire dtype follows cfg.dtype_policy
    # (core.dtypes) — f32 rows are (F + 3) * 4 bytes (feat + aux), bf16
    # halves every lane (bench_dtype times the policies; here the bf16
    # payload rides along so the exchange table reports both)
    row_bytes = (F + 3) * 4
    row_bytes_bf16 = (F + 3) * 2
    bytes_gather = N_DEV * views * n_local * row_bytes
    bytes_exchange = N_DEV * views * E * row_bytes

    # ---- one train step, gather vs exchange ----
    gt = jnp.zeros((views, grid.n_tiles, 3, grid.tile_h, grid.tile_w))
    mask = jnp.ones((views, grid.n_tiles, grid.tile_h, grid.tile_w), bool)
    batch = {"gt_tiles": jax.device_put(gt, b_sh["gt_tiles"]),
             "mask_tiles": jax.device_put(mask, b_sh["mask_tiles"]),
             "cam": cam_dev}
    def fresh_state():
        # fresh buffers each config: the step DONATES g/opt, and device_put
        # aliases (doesn't copy) leaves whose sharding already matches, so
        # reusing one host tree across configs would hand the second run
        # deleted buffers
        g = jax.tree.map(jnp.array, g_b)
        tr = {k: getattr(g, k) for k in
              ("means", "log_scales", "quats", "opacity_logit", "colors")}
        o = GSOptState(
            m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
            v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
            step=jnp.int32(0),
            grad_accum=jnp.zeros((1, n_total)),
            grad_count=jnp.zeros((1, n_total)))
        return jax.device_put(g, g_sh), jax.device_put(o, opt_sh)

    def timed(cfg):
        step = make_gs_train_step(mesh, cfg, grid, extent=1.0, impl="ref",
                                  views=views)
        # the step donates g/opt, so thread the returned state through
        g, o = fresh_state()
        g, o, loss = step(g, o, batch)                 # warmup: compile
        loss = float(jax.block_until_ready(loss))
        best = float("inf")
        for _ in range(reps):
            t0 = time.perf_counter()
            g, o, l = step(g, o, batch)
            jax.block_until_ready(l)
            best = min(best, time.perf_counter() - t0)
        return best, loss

    t_g, l_g = timed(GSTrainCfg(K=K))
    t_e, l_e = timed(GSTrainCfg(K=K, exchange=True, exchange_budget=E))
    np.testing.assert_allclose(l_e, l_g, rtol=1e-6, atol=1e-7)

    print("RESULT " + json.dumps({
        "n_devices": N_DEV, "n_local": n_local, "views": views, "res": res,
        "n_tiles": grid.n_tiles, "max_edge_overlap": max_edge, "budget": E,
        "overlap_frac": max_edge / n_local, "budget_frac": E / n_local,
        "payload_bytes_gather": bytes_gather,
        "payload_bytes_exchange": bytes_exchange,
        "payload_bytes_gather_bf16": N_DEV * views * n_local * row_bytes_bf16,
        "payload_bytes_exchange_bf16": N_DEV * views * E * row_bytes_bf16,
        "payload_reduction": bytes_gather / bytes_exchange,
        "t_step_gather_s": t_g, "t_step_exchange_s": t_e,
        "step_speedup": t_g / t_e, "loss": l_g}))


def run(*, res: int = 128, n_local: int = 512, views: int = 4,
        reps: int = 3, quick: bool = False, gate_floor: float | None = None):
    if quick:
        res, n_local, views, reps = 64, 256, 2, 2
    cmd = [sys.executable, "-m", "benchmarks.bench_exchange", "--inner",
           "--res", str(res), "--points-per-part", str(n_local),
           "--views", str(views), "--reps", str(reps)]
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={N_DEV}",
               JAX_PLATFORMS="cpu")
    env.setdefault("PYTHONPATH", "src")
    print(f"\n[exchange] res={res} n_local={n_local} x{N_DEV} parts "
          f"V={views} (subprocess, {N_DEV} forced host devices)")
    proc = subprocess.run(cmd, env=env, capture_output=True, text=True,
                          timeout=1800)
    sys.stdout.write(proc.stdout[: proc.stdout.rfind("RESULT ")])
    sys.stderr.write(proc.stderr[-2000:] if proc.returncode else "")
    if proc.returncode:
        raise SystemExit(f"bench_exchange inner failed ({proc.returncode})")
    r = json.loads(proc.stdout.rstrip().rsplit("RESULT ", 1)[1])

    mb = 1.0 / (1024 * 1024)
    print(f"  probed edge overlap {r['max_edge_overlap']}/{r['n_local']} "
          f"({r['overlap_frac']:.1%}) -> budget {r['budget']} "
          f"({r['budget_frac']:.1%})")
    print(f"  per-device payload: all-gather "
          f"{r['payload_bytes_gather'] * mb:7.2f} MiB  exchange "
          f"{r['payload_bytes_exchange'] * mb:7.2f} MiB  "
          f"({r['payload_reduction']:.2f}x smaller, proportional to the "
          f"probed overlap)")
    print(f"  train step: gather {r['t_step_gather_s'] * 1e3:8.2f} ms  "
          f"exchange {r['t_step_exchange_s'] * 1e3:8.2f} ms  "
          f"({r['step_speedup']:.2f}x; host-device collectives are "
          f"memcpy-emulated — payload is the headline)")
    save_result("exchange", r)
    if gate_floor is not None and r["payload_reduction"] < gate_floor:
        raise SystemExit(
            f"exchange payload gate FAILED: {r['payload_reduction']:.2f}x "
            f"reduction below floor {gate_floor:.2f}x — the probed budget "
            f"no longer undercuts the full table")
    return r


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--res", type=int, default=128)
    ap.add_argument("--points-per-part", type=int, default=512)
    ap.add_argument("--views", type=int, default=4)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--gate-floor", type=float, default=None,
                    help="fail unless the exchange payload is at least this "
                         "factor smaller than the all-gather's")
    ap.add_argument("--inner", action="store_true", help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.inner:
        _inner(res=args.res, n_local=args.points_per_part,
               views=args.views, reps=args.reps)
        return
    run(res=args.res, n_local=args.points_per_part, views=args.views,
        reps=args.reps, quick=args.smoke, gate_floor=args.gate_floor)


if __name__ == "__main__":
    main()
