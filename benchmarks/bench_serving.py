"""Serving-path benchmark: pose-bucket cache + batched dispatch (PR 7).

Measures requests/second through core/serving.GSRenderServer at request
batch sizes V in {1, 4, 16}, steady-state best-of-reps with compilation
excluded (a disjoint warmup rig compiles every jit before timing):

  cold    fresh cache every rep — each request pays projection +
          tile assignment + render (the miss path);
  warm    the same rig re-served — every request hits the pose-bucket
          cache and skips assignment entirely (the hit path);
  shed    warm requests under forced load shedding — cached Kmax tables
          sliced to the low serving K (the degraded-but-served path).

The headline is warm/cold at V=16: the cache exists to delete the
assignment phase from repeat views, so warm must clear ``--gate-floor``
(default 1.5x) or the bench exits nonzero.  Saves JSON under
experiments/benchmarks/serving.json; rides into BENCH_*.json via
benchmarks/run.py (smoke tier).

    PYTHONPATH=src python -m benchmarks.bench_serving [--smoke]
        [--res 128] [--points 12000] [--reps 3] [--gate-floor 1.5]
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.core.cameras import orbital_rig
from repro.core.gaussians import from_points
from repro.core.serving import GSRenderServer, ServeCfg
from repro.core.tiling import TileGrid


def _scene(n_points: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    pts = rng.uniform(0.0, 1.0, (n_points, 3))
    cols = rng.uniform(0.0, 1.0, (n_points, 3))
    spacing = 1.0 / max(n_points, 1) ** (1.0 / 3.0)
    return from_points(jnp.asarray(pts, jnp.float32), jnp.asarray(cols),
                       init_scale=0.6 * spacing, opacity=0.9)


def _rig(n: int, res: int, *, radius: float = 2.2, seed_phase: float = 0.0):
    # a tiny phase offset keeps warmup poses in DIFFERENT buckets from the
    # timed poses, so warmup compiles jits without pre-filling the cache
    return orbital_rig(n, (0.5 + seed_phase, 0.5, 0.5), radius,
                       width=res, height=res)


def _serve_rps(server: GSRenderServer, rig, *, reps: int,
               cold: bool) -> float:
    """Best-of-reps requests/s for one pass over ``rig``; ``cold`` drops
    the cache before every rep so each request pays the miss path."""
    V = int(rig.view.shape[0])
    best = float("inf")
    for _ in range(reps):
        if cold:
            server.clear_cache()
        t0 = time.perf_counter()
        results = server.serve(rig)
        dt = time.perf_counter() - t0
        assert len(results) == V
        best = min(best, dt)
    return V / best


def run(*, res: int = 128, n_points: int = 12000, K: int = 64,
        reps: int = 3, batches=(1, 4, 16), gate_floor: float = 1.5,
        quick: bool = False):
    if quick:
        n_points, reps = 8000, 2
    grid = TileGrid(res, res, 8, 16)
    g = _scene(n_points)
    results = {"res": res, "n_points": n_points, "K": K,
               "n_tiles": grid.n_tiles, "batches": {}}
    print(f"\n[serving] res={res} N={n_points} K={K} T={grid.n_tiles}")

    ratio_at_gate = None
    for V in batches:
        cfg = ServeCfg(K=K, impl="ref", max_batch=V, lod_fracs=(1.0,))
        server = GSRenderServer(g, grid, cfg, center=(0.5, 0.5, 0.5))
        shed_cfg = ServeCfg(K=K, impl="ref", max_batch=V, lod_fracs=(1.0,),
                            shed_at=0)
        shed_server = GSRenderServer(g, grid, shed_cfg,
                                     center=(0.5, 0.5, 0.5))
        warmup = _rig(V, res, seed_phase=0.021)
        rig = _rig(V, res)

        server.serve(warmup)            # compile miss path
        server.serve(warmup)            # compile hit path
        cold = _serve_rps(server, rig, reps=reps, cold=True)
        warm = _serve_rps(server, rig, reps=reps, cold=False)
        shed_server.serve(warmup)
        shed_server.serve(warmup)
        shed = _serve_rps(shed_server, rig, reps=reps, cold=False)
        assert shed_server.telemetry()["shed"] > 0    # shedding engaged
        ratio = warm / cold
        if V == max(batches):
            ratio_at_gate = ratio
        results["batches"][str(V)] = {
            "cold_rps": cold, "warm_rps": warm, "shed_rps": shed,
            "warm_over_cold": ratio,
        }
        print(f"  V={V:3d}  cold {cold:8.1f} req/s   warm {warm:8.1f} "
              f"req/s   shed(warm) {shed:8.1f} req/s   warm/cold "
              f"{ratio:.2f}x")

    results["warm_over_cold_at_max_batch"] = ratio_at_gate
    results["gate_floor"] = gate_floor
    save_result("serving", results)
    if ratio_at_gate is not None and ratio_at_gate < gate_floor:
        raise SystemExit(
            f"[serving] GATE: warm/cold {ratio_at_gate:.2f}x at "
            f"V={max(batches)} under the {gate_floor:.2f}x floor — the "
            "cache stopped deleting the assignment phase")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--res", type=int, default=128)
    ap.add_argument("--points", type=int, default=12000)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--gate-floor", type=float, default=1.5)
    args = ap.parse_args()
    run(res=args.res, n_points=args.points, reps=args.reps,
        gate_floor=args.gate_floor, quick=args.smoke)


if __name__ == "__main__":
    main()
