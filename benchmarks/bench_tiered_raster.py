"""Occupancy-tiered rasterization benchmark (variable-K tentpole gate).

Measures the RENDER PHASE — feature gather + rasterize kernel + (tiered
only) binning/compaction/scatter — on precomputed tile assignments, plus
the end-to-end render for context.  impl="ref", steady-state best-of-reps,
compilation excluded on both sides.

  sparse scene   a thin low-occupancy field covers the frame with a small
      heavy cluster — the paper's isosurface-over-background regime: most
      tiles hold a handful of splats, a few hold hundreds.  Tiered dispatch
      (k_tiers) runs the light tiles at the small K and skips empty tiles
      entirely instead of paying the dense Kmax everywhere; the headline
      number is the dense/tiered render-phase ratio (> 1 == speedup).

  dense scene    every tile sits in the top tier — the worst case for
      tiering.  The gate: tiered must not regress past ``--dense-slack``
      (binning + scatter overhead only).

  truncation     a heavy-overlap scene rendered (a) dense at the legacy
      static K, (b) tiered with a large top tier, both against a
      high-K dense reference.  Tiering lets heavy tiles keep the large K
      without paying it everywhere, so its truncation error collapses;
      recorded as the max-abs-error reduction.

Saves JSON under experiments/benchmarks/tiered_raster.json.

    PYTHONPATH=src python -m benchmarks.bench_tiered_raster [--smoke]
        [--res 256] [--points 20000] [--reps 3]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.core.cameras import orbital_rig, select
from repro.core.gaussians import from_points
from repro.core.projection import project
from repro.core.render import _tiered_tiles, render
from repro.core.tiling import (TileGrid, assign_tiles, auto_tier_caps,
                               gather_features_at, splat_features,
                               tile_occupancy, tile_origins)
from repro.kernels import rasterize_tiles


def _steady(fn, *, reps: int) -> float:
    jax.block_until_ready(fn())            # warmup: compile + first run
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        best = min(best, time.perf_counter() - t0)
    return best


def _scene(n_points: int, *, res: int, heavy_frac: float, scale: float,
           seed: int = 0):
    """Synthetic occupancy-controlled scene: (1-heavy_frac) of the splats
    scatter uniformly over the frame (low per-tile occupancy), heavy_frac
    concentrate in a small ball (top-tier tiles).  ``scale`` is the splat
    radius in units of the mean point spacing."""
    rng = np.random.default_rng(seed)
    n_bg = n_points - int(n_points * heavy_frac)
    pts = rng.uniform(0.0, 1.0, (n_bg, 3))
    if n_points - n_bg:
        ball = 0.5 + 0.08 * rng.standard_normal((n_points - n_bg, 3))
        pts = np.concatenate([pts, ball])
    cols = rng.uniform(0.0, 1.0, (n_points, 3))
    spacing = 1.0 / max(n_points, 1) ** (1.0 / 3.0)
    g = from_points(jnp.asarray(pts, jnp.float32), jnp.asarray(cols),
                    init_scale=scale * spacing, opacity=0.9)
    cams = orbital_rig(2, (0.5, 0.5, 0.5), 2.6, width=res, height=res)
    return g, select(cams, 0)


def _phase_fns(g, cam, grid: TileGrid, Kmax: int, k_tiers, caps, impl="ref"):
    """Jitted render-phase closures over a precomputed assignment: dense =
    full-K gather + one launch; tiered = binning + per-tier gather/launch +
    scatter.  Both take the (N, F) feature table so the timed region is
    exactly the part the tentpole changes."""
    splats = project(g, cam)
    idx, score = assign_tiles(splats, grid, K=Kmax)
    feat = splat_features(splats)
    occ = np.asarray(tile_occupancy(score))
    origins = tile_origins(grid)

    dense = jax.jit(lambda f: rasterize_tiles(
        gather_features_at(f, idx, score), origins,
        tile_h=grid.tile_h, tile_w=grid.tile_w, impl=impl))
    tiered = jax.jit(lambda f: _tiered_tiles(
        f, idx, score, grid, k_tiers=k_tiers, tier_caps=caps, impl=impl)[0])
    return dense, tiered, feat, occ


def run(*, res: int = 256, n_points: int = 20000, reps: int = 3,
        k_tiers=(16, 64, 128), dense_slack: float = 1.25,
        quick: bool = False):
    if quick:
        res, n_points, reps, k_tiers = 128, 6000, 2, (8, 32, 64)
    k_tiers = tuple(k_tiers)
    Kmax = k_tiers[-1]
    grid = TileGrid(res, res, 8, 16)
    results = {"res": res, "n_points": n_points, "k_tiers": list(k_tiers),
               "n_tiles": grid.n_tiles}

    print(f"\n[tiered_raster] res={res} N={n_points} k_tiers={k_tiers} "
          f"T={grid.n_tiles}")
    # sparse: a ~6-splat/tile background field + a heavy cluster holding the
    # rest of the budget — most tiles land in the low tiers, a few in the top
    n_bg = min(n_points // 2, 6 * grid.n_tiles)
    scenes = {
        "sparse": _scene(n_points, res=res,
                         heavy_frac=1.0 - n_bg / n_points, scale=0.4),
        # big splats everywhere: every tile saturates the top tier
        "dense": _scene(n_points, res=res, heavy_frac=0.0, scale=3.0),
    }
    for name, (g, cam) in scenes.items():
        occ_probe = np.asarray(tile_occupancy(
            assign_tiles(project(g, cam), grid, K=Kmax)[1]))
        caps = auto_tier_caps(occ_probe[None], k_tiers)
        fn_d, fn_t, feat, occ = _phase_fns(g, cam, grid, Kmax, k_tiers, caps)
        np.testing.assert_allclose(np.asarray(fn_t(feat)),
                                   np.asarray(fn_d(feat)),
                                   rtol=1e-5, atol=1e-5)
        t_d = _steady(lambda: fn_d(feat), reps=reps)
        t_t = _steady(lambda: fn_t(feat), reps=reps)
        ratio = t_d / t_t
        # end-to-end (projection + assignment included) for context
        rfn_d = jax.jit(lambda gg, c=cam: render(gg, c, grid, K=Kmax,
                                                 impl="ref").rgb)
        rfn_t = jax.jit(lambda gg, c=cam, tc=caps: render(
            gg, c, grid, k_tiers=k_tiers, tier_caps=tc, impl="ref").rgb)
        e_d = _steady(lambda: rfn_d(g), reps=reps)
        e_t = _steady(lambda: rfn_t(g), reps=reps)
        frac_bg = float((occ == 0).mean())
        print(f"  {name:7s} bg-tiles {frac_bg:5.1%}  med-occ "
              f"{int(np.median(occ[occ > 0])) if (occ > 0).any() else 0:4d}"
              f"  caps {caps}")
        print(f"          render-phase dense {t_d*1e3:8.2f} ms  tiered "
              f"{t_t*1e3:8.2f} ms  ({ratio:.2f}x)   end-to-end "
              f"{e_d*1e3:8.2f} -> {e_t*1e3:8.2f} ms ({e_d/e_t:.2f}x)")
        results[name] = {"t_dense_s": t_d, "t_tiered_s": t_t,
                         "speedup": ratio, "bg_tile_frac": frac_bg,
                         "t_e2e_dense_s": e_d, "t_e2e_tiered_s": e_t,
                         "e2e_speedup": e_d / e_t, "tier_caps": list(caps)}

    # ---- truncation-error reduction on a heavy-overlap scene ----
    k_old = k_tiers[1]                     # the legacy single static K
    k_ref = max(4 * Kmax, 256)
    g, cam = _scene(n_points, res=res, heavy_frac=0.5, scale=1.5, seed=1)
    ref = np.asarray(render(g, cam, grid, K=k_ref, impl="ref").rgb)
    img_dense = np.asarray(render(g, cam, grid, K=k_old, impl="ref").rgb)
    trunc_tiers = tuple(list(k_tiers[:-1]) + [k_ref])
    img_tier = np.asarray(render(g, cam, grid, k_tiers=trunc_tiers,
                                 impl="ref").rgb)
    e_dense = float(np.abs(img_dense - ref).max())
    e_tier = float(np.abs(img_tier - ref).max())
    print(f"  truncation vs K={k_ref} ref: static K={k_old} err {e_dense:.2e}"
          f"  tiered{trunc_tiers} err {e_tier:.2e}")
    results["truncation"] = {"k_static": k_old, "k_ref": k_ref,
                             "err_static": e_dense, "err_tiered": e_tier}

    sparse_up = results["sparse"]["speedup"]
    dense_ok = results["dense"]["speedup"] >= 1.0 / dense_slack
    trunc_ok = e_tier <= e_dense
    ok = dense_ok and trunc_ok
    print(f"  acceptance: sparse render-phase {sparse_up:.2f}x recorded; "
          f"dense within {dense_slack:.2f}x slack: "
          f"{'PASS' if dense_ok else 'FAIL'}; truncation not worse: "
          f"{'PASS' if trunc_ok else 'FAIL'}")
    results.update({"dense_slack": dense_slack, "gate_pass": ok})
    save_result("tiered_raster", results)
    if not ok:
        raise SystemExit(
            "tiered_raster acceptance FAILED: dense ratio "
            f"{results['dense']['speedup']:.2f}x (floor "
            f"{1.0/dense_slack:.2f}x), truncation {e_tier:.2e} vs "
            f"{e_dense:.2e}")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--res", type=int, default=256)
    ap.add_argument("--points", type=int, default=20000)
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--dense-slack", type=float, default=1.25,
                    help="max tolerated tiered/dense slowdown on the dense "
                         "scene before exiting 1 (CPU binning overhead)")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny settings for CI smoke runs")
    args = ap.parse_args()
    run(res=args.res, n_points=args.points, reps=args.reps,
        dense_slack=args.dense_slack, quick=args.smoke)


if __name__ == "__main__":
    main()
