"""Time-series warm-start benchmark (PR 9): convergence + bounded memory.

Two claims of the ``--timeseries`` driver, measured on a small evolving
sphere_shell scene through the real distributed driver
(``core/distributed.fit_partitions``):

  convergence   timestep t=1 warm-started from t=0's trained state must
                reach the COLD run's final loss (fresh init on the same
                t=1 scene, ``steps_cold`` steps) in at most
                ``gate_frac`` (default 0.6) of its steps — the
                per-timestep retraining saving that makes in-situ use
                plausible (PAPERS.md: arXiv 2509.05216 frames this cost
                as the obstacle);
  boundedness   a multi-timestep run with densification ON and
                ``densify_cap`` set holds the live-splat count exactly
                flat at the cap across timesteps (GeoGaussian-style
                num_max) while the UNCAPPED twin keeps growing — the
                memory wild card of distributed 3D-GS training
                (arXiv 2406.18533) stays bounded.

Exits nonzero when warm-start needs more than ``gate_frac`` of the cold
steps or the cap is exceeded; ``benchmarks/run.py`` (smoke tier)
downgrades that to a warning and the committed-baseline comparison
(tools/check_bench.py) gates CI.  Saves JSON under
experiments/benchmarks/timeseries.json.

    PYTHONPATH=src python -m benchmarks.bench_timeseries [--smoke]
        [--steps 24] [--gate-frac 0.6]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import save_result
from repro.configs.gs_datasets import get_gs_dataset
from repro.core.cameras import orbital_rig
from repro.core.distributed import fit_partitions
from repro.core.pipeline import build_scene, prepare_timestep
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg


def _fit(td, cams, grid, cfg, mesh, *, steps, key, warm=None,
         densify_every=0, densify_from=0, densify_cap=None):
    return fit_partitions(
        td.g0, cams, jnp.asarray(td.gts),
        None if td.masks is None else jnp.asarray(td.masks), cfg,
        mesh=mesh, steps=steps, extent=td.extent, key=key, grid=grid,
        schedule=cfg.tier_schedule(), warm_start=warm,
        densify_every=densify_every, densify_from=densify_from,
        densify_cap=densify_cap)


def run(*, steps: int = 24, res: int = 32, n_views: int = 4,
        dt: float = 0.02, gate_frac: float = 0.6, quick: bool = False):
    if quick:
        steps = min(steps, 16)
    S = steps
    ds = get_gs_dataset("sphere_shell", "cpu")
    # series-fixed frame from the t=0 scene, exactly like the driver
    points, _, extent = build_scene(ds, 0, t=0.0)
    center = 0.5 * (points.max(0) + points.min(0))
    cams = orbital_rig(n_views, center, 1.6 * extent / 2 + 1e-3,
                       width=res, height=res)
    grid = TileGrid(res, res, 8, 16)
    cfg = GSTrainCfg(K=16, lambda_dssim=0.0, bg=0.0, view_batch=2,
                     lr_colors=5e-2)
    mesh = jax.make_mesh((len(jax.devices()), 1), ("part", "view"))
    cap0 = -(-int(ds.n_points * ds.capacity_factor) // len(jax.devices())) \
        * len(jax.devices())
    key = jax.random.PRNGKey(0)

    def prep(t_idx):
        return prepare_timestep(ds, cams, grid, t=t_idx * dt, n_parts=1,
                                capacity=cap0, K=cfg.K)

    print(f"\n[timeseries] sphere_shell res={res} steps/timestep={S} "
          f"dt={dt} capacity={cap0}")

    # ---- convergence: cold vs warm on the SAME t=1 scene.  The warm seed
    # gets 2S steps at t=0 — a running series has accumulated training,
    # which is exactly the asset warm-starting carries forward; the cold
    # baseline re-inits from the t=1 extraction (our analytic extraction
    # is a STRONG init — exact positions and colors — so this gate is
    # conservative vs real in-situ data).  Each run gets a fresh
    # prepare_timestep: the donating step consumes the init buffers.
    t0 = time.perf_counter()
    _, _, cold = _fit(prep(1), cams, grid, cfg, mesh, steps=S, key=key)
    target = cold[-1]
    g_t0, opt_t0, _ = _fit(prep(0), cams, grid, cfg, mesh, steps=2 * S,
                           key=key)
    warm_tree = jax.tree.map(jax.device_get, (g_t0, opt_t0))
    extra = {"dtype_policy": cfg.dtype_policy,
             "grad_compress": cfg.grad_compress}
    _, _, warm = _fit(prep(1), cams, grid, cfg, mesh, steps=3 * S, key=key,
                      warm=(warm_tree, extra, 2 * S))
    hit = [i + 1 for i, l in enumerate(warm) if l <= target]
    steps_warm = hit[0] if hit else len(warm) + 1
    ratio = steps_warm / S
    print(f"  cold: {S} steps -> final loss {target:.4f}")
    print(f"  warm: reaches it in {steps_warm} steps "
          f"({ratio:.2f}x of cold, gate <= {gate_frac:.2f}x)")

    # ---- boundedness: capped vs uncapped densify across 3 timesteps ----
    dcfg = GSTrainCfg(K=16, lambda_dssim=0.0, bg=0.0, view_batch=2,
                      lr_colors=5e-2, max_new=256, densify_grad_thresh=1e-9)
    Sd = max(4, S // 4)
    live_capped, live_free = [], []
    cap = None
    for capped in (True, False):
        warm_t, lives = None, []
        for t in range(3):
            td = prep(t)
            if cap is None:
                cap = int(np.asarray(td.g0.active).sum())
            g1, o1, _ = _fit(td, cams, grid, dcfg, mesh,
                             steps=(t + 1) * Sd, key=key, warm=warm_t,
                             densify_every=2, densify_from=0,
                             densify_cap=cap if capped else None)
            lives.append(int(np.asarray(g1.active).sum()))
            warm_t = (jax.tree.map(jax.device_get, (g1, o1)),
                      {"dtype_policy": dcfg.dtype_policy,
                       "grad_compress": dcfg.grad_compress}, (t + 1) * Sd)
        (live_capped if capped else live_free).extend(lives)
    print(f"  densify_cap={cap}: live {live_capped} (capped)  "
          f"vs {live_free} (uncapped)")

    results = {
        "steps_cold": S, "target_loss": float(target),
        "steps_to_target_warm": int(steps_warm),
        "warm_over_cold_steps": float(ratio), "gate_frac": gate_frac,
        "densify_cap": int(cap), "live_capped": live_capped,
        "live_uncapped": live_free,
        "wall_clock_s": time.perf_counter() - t0,
    }
    save_result("timeseries", results)
    if ratio > gate_frac:
        raise SystemExit(
            f"[timeseries] GATE: warm-start needed {steps_warm}/{S} steps "
            f"({ratio:.2f}x) to reach the cold final loss — over the "
            f"{gate_frac:.2f}x floor; warm-starting stopped paying")
    if max(live_capped) > cap:
        raise SystemExit(
            f"[timeseries] GATE: live splats {max(live_capped)} exceeded "
            f"densify_cap={cap} — the cap no longer bounds memory")
    if len(set(live_capped)) != 1:
        raise SystemExit(
            "[timeseries] GATE: capped live count drifted across "
            f"timesteps ({live_capped}) — expected flat at the cap")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--gate-frac", type=float, default=0.6)
    args = ap.parse_args()
    run(steps=args.steps, gate_frac=args.gate_frac, quick=args.smoke)


if __name__ == "__main__":
    main()
