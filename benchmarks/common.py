"""Shared helpers for the paper-table benchmarks (CPU-tier protocol).

The paper's tables are reproduced at CPU-tractable scale with the SAME
pipeline code; 'nodes' map to spatial partitions trained independently
(wall-clock of a multi-node run = max over partitions, since partitions are
embarrassingly parallel — we train them sequentially and report the max).
Paper-scale numbers are extrapolated with a calibrated work model and
clearly labelled as such.
"""

from __future__ import annotations

import json
import os

import numpy as np

RESULT_DIR = "experiments/benchmarks"


def save_result(name: str, payload: dict):
    os.makedirs(RESULT_DIR, exist_ok=True)
    with open(os.path.join(RESULT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=float)


def parallel_time(per_partition_seconds):
    """Wall-clock of independent partitions running concurrently."""
    return float(np.max(per_partition_seconds))


def fmt_minutes(s: float) -> str:
    return f"{s/60:.2f}m" if s >= 60 else f"{s:.1f}s"
