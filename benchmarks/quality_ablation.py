"""Ghost-cell + background-mask ablation (paper Fig. 2 / Fig. 4).

Four pipeline variants on the same scene/partitioning:
    full      ghosts + masks  (the paper's method)
    no_ghost  masks only
    no_mask   ghosts only
    none      neither         (Fig. 2b: gaps + streaks)

Reports merged-render PSNR/SSIM/grad_sim vs. the point-cloud ground truth.
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import save_result
from repro.core.pipeline import PipelineCfg, run_pipeline
from repro.core.train import GSTrainCfg

VARIANTS = {
    "full": dict(use_ghost=True, use_mask=True),
    "no_ghost": dict(use_ghost=False, use_mask=True),
    "no_mask": dict(use_ghost=True, use_mask=False),
    "none": dict(use_ghost=False, use_mask=False),
}


def run(dataset="kingsnake", parts=4, steps=150, resolution=64, views=12,
        quick=False):
    if quick:
        steps, views, parts = 100, 10, 4
    rows = {}
    for name, flags in VARIANTS.items():
        t0 = time.perf_counter()
        res = run_pipeline(PipelineCfg(
            dataset=dataset, n_parts=parts, resolution=resolution,
            steps=steps, n_views=views, train=GSTrainCfg(), **flags))
        rows[name] = dict(psnr=res.psnr, ssim=res.ssim,
                          grad_sim=res.grad_sim,
                          boundary_psnr=res.boundary_psnr,
                          boundary_ssim=res.boundary_ssim,
                          boundary_frac=res.boundary_frac,
                          seconds=time.perf_counter() - t0)
    print(f"\n[quality_ablation] {dataset}, {parts} partitions, "
          f"{steps} steps @ {resolution}^2  (paper Fig. 2/4)")
    print(f"{'variant':10s} {'PSNR':>7s} {'SSIM':>7s} {'grad_sim':>9s} "
          f"{'bnd-PSNR':>9s} {'bnd-SSIM':>9s}")
    for name, r in rows.items():
        print(f"{name:10s} {r['psnr']:7.2f} {r['ssim']:7.4f} "
              f"{r['grad_sim']:9.4f} {r['boundary_psnr']:9.2f} "
              f"{r['boundary_ssim']:9.4f}")
    d = rows["full"]["psnr"] - rows["none"]["psnr"]
    db = rows["full"]["boundary_psnr"] - rows["none"]["boundary_psnr"]
    print(f"-> ghosts+masks vs neither: {d:+.2f} dB global, {db:+.2f} dB on "
          f"boundary pixels ({100*rows['full']['boundary_frac']:.1f}% of "
          "image — where Fig. 2's gaps/streaks live)")
    save_result("quality_ablation", dict(dataset=dataset, parts=parts,
                                         steps=steps, resolution=resolution,
                                         rows=rows))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="kingsnake")
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--resolution", type=int, default=64)
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(a.dataset, a.parts, a.steps, a.resolution, quick=a.quick)
