"""Roofline analysis over the dry-run records (deliverable g).

Reads experiments/dryrun/single/*.json (the roofline table is single-pod by
assignment; multi-pod records prove the pod axis shards) and emits the
per-cell three-term roofline:

    compute_s    HLO_FLOPs / (chip peak 197 TF bf16)
    memory_s     HLO_bytes / (819 GB/s HBM)
    collective_s wire_bytes / (50 GB/s ICI link)

plus the dominant term, MODEL_FLOPS/HLO_FLOPs (useful-compute ratio), and a
one-line "what would move the bottleneck" note.  Everything is per-device.

    PYTHONPATH=src python -m benchmarks.roofline [--dir experiments/dryrun]
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Optional

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

NOTES = {
    ("compute_s", "moe"): "activate fewer experts per token (EP all-to-all "
                          "dispatch instead of dense all-expert einsum)",
    ("compute_s", None): "already compute-bound: raise MXU utilisation "
                         "(larger matmul tiles, bf16 everywhere)",
    ("memory_s", "attn"): "flash-attention custom-vjp (drop the per-chunk "
                          "probability stash), smaller kv blocks",
    ("memory_s", "moe"): "dense all-expert einsum reads every expert's "
                         "weights: EP dispatch reads only routed experts",
    ("memory_s", None): "cut activation stashes (custom-vjp flash attn, "
                        "remat policy) / fuse loss (lse without full logits)",
    ("collective_s", "gs"): "hierarchical top-K merge: exchange per-shard "
                            "candidate lists instead of the full splat table",
    ("collective_s", None): "overlap TP all-reduces with next-layer matmuls "
                            "(reduce-scatter + all-gather decomposition), "
                            "gradient compression on the DP axis",
}


def load_cells(dir: str, mesh: str = "single"):
    cells = []
    root = os.path.join(dir, mesh)
    if not os.path.isdir(root):
        return cells
    for fn in sorted(os.listdir(root)):
        if fn.endswith(".json"):
            with open(os.path.join(root, fn)) as f:
                cells.append(json.load(f))
    return cells


def note_for(cell) -> str:
    dom = cell.get("bottleneck", "?")
    arch = cell["arch"]
    family = None
    if arch.startswith("gs-"):
        family = "gs"
    elif "mixtral" in arch or "llama4" in arch or "jamba" in arch:
        family = "moe"
    elif cell["shape"].startswith(("train", "prefill")):
        family = "attn" if dom == "memory_s" else None
    return NOTES.get((dom, family)) or NOTES.get((dom, None), "")


def fmt_table(cells, *, full_notes: bool = False) -> str:
    rows = []
    head = (f"{'cell':42s} {'status':7s} {'compute':>9s} {'memory':>9s} "
            f"{'collect':>9s} {'bound':>10s} {'useful':>7s}")
    rows.append(head)
    rows.append("-" * len(head))
    for c in cells:
        name = f"{c['arch']}__{c['shape']}"
        if c["status"] == "skip":
            rows.append(f"{name:42s} {'skip':7s} {'':>9s} {'':>9s} {'':>9s} "
                        f"{'':>10s} {'':>7s}")
            continue
        if c["status"] != "ok":
            rows.append(f"{name:42s} {'ERROR':7s}")
            continue
        r = c["roofline"]
        rows.append(
            f"{name:42s} {'ok':7s} "
            f"{r['compute_s']*1e3:8.1f}ms {r['memory_s']*1e3:8.1f}ms "
            f"{r['collective_s']*1e3:8.1f}ms "
            f"{c['bottleneck'].replace('_s',''):>10s} "
            f"{c['useful_flops_ratio']:7.3f}")
        if full_notes:
            rows.append(f"    -> {note_for(c)}")
    return "\n".join(rows)


def summarize(dir: str = "experiments/dryrun", *, full_notes=True,
              out_json: Optional[str] = None) -> str:
    single = load_cells(dir, "single")
    multi = load_cells(dir, "multi")
    lines = []
    lines.append("ROOFLINE (single-pod 16x16 = 256 chips, per-device terms)")
    lines.append(fmt_table(single, full_notes=full_notes))
    ok = [c for c in single if c["status"] == "ok"]
    if ok:
        worst = min(ok, key=lambda c: c["useful_flops_ratio"])
        coll = max(ok, key=lambda c: c["roofline"]["collective_s"]
                   / max(sum(c["roofline"].values()), 1e-12))
        lines.append("")
        lines.append(f"worst useful-compute ratio: {worst['arch']}__"
                     f"{worst['shape']} ({worst['useful_flops_ratio']:.3f})")
        lines.append(f"most collective-bound:      {coll['arch']}__"
                     f"{coll['shape']}")
    lines.append("")
    n_ok = sum(c["status"] == "ok" for c in multi)
    n_skip = sum(c["status"] == "skip" for c in multi)
    n_err = len(multi) - n_ok - n_skip
    lines.append(f"MULTI-POD (2x16x16 = 512 chips): {n_ok} ok, {n_skip} "
                 f"skip, {n_err} error")
    gs_multi = [c for c in multi if c["arch"].startswith("gs-")
                and c["status"] == "ok"]
    for c in gs_multi:
        lines.append(f"  {c['arch']}: pod-spanning collective bytes = "
                     f"{c['hlo']['pod_spanning_bytes']:.0f} "
                     "(paper independence: scalar loss metric only)")
    if out_json:
        with open(out_json, "w") as f:
            json.dump({"single": single, "multi": multi}, f, indent=1)
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--notes", action="store_true")
    args = ap.parse_args()
    print(summarize(args.dir, full_notes=args.notes))


if __name__ == "__main__":
    main()
