"""Benchmark orchestrator: one entry per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--full] [--smoke]
        [--json PATH]

Default mode balances coverage vs CPU time (~10-20 min); --full runs the
longer protocols; --smoke is the CI tier (batched-render + tiered-raster +
assignment + exchange microbenches, a few minutes on CPU).  Results are printed AND
saved under experiments/benchmarks/*.json; ``--json PATH`` additionally
writes one machine-readable summary — per-benchmark name, config, and
wall-clock — the format the CI regression gate (tools/check_bench.py vs
benchmarks/baseline.json) and the BENCH_*.json trajectory share.  The
roofline section reads the dry-run records under experiments/dryrun (run
`python -m repro.launch.dryrun` first for fresh ones).
"""

from __future__ import annotations

import argparse
import json
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke tier: batched-render, tiered-raster, "
                         "assignment, exchange, dtype and serving "
                         "microbenches only (a few min on CPU)")
    ap.add_argument("--skip-roofline", action="store_true")
    ap.add_argument("--json", metavar="PATH", default=None,
                    help="write a machine-readable summary (name, config, "
                         "wall_clock_s per benchmark) for the CI "
                         "regression gate / BENCH_*.json trajectory")
    args = ap.parse_args()
    quick = not args.full
    mode = "smoke" if args.smoke else ("full" if args.full else "default")
    t0 = time.time()
    entries = []

    def bench(name, fn):
        """Run one benchmark, recording wall-clock (and, when the bench
        returns a dict, its full result payload — e.g. bench_assign's
        end-to-end train-step timings ride along into BENCH_*.json); a
        SystemExit (a bench's own acceptance gate) is downgraded to a
        warning here — the orchestrator must not abort the remaining
        benchmarks on timing noise, and CI gates regressions via
        tools/check_bench.py instead."""
        t = time.time()
        out = None
        try:
            out = fn()
        except SystemExit as e:
            print(f"[benchmarks] WARNING (continuing): {e}")
        entry = {"name": name, "config": {"mode": mode},
                 "wall_clock_s": time.time() - t}
        if isinstance(out, dict):
            entry["result"] = out
        entries.append(entry)

    def dump():
        if args.json:
            with open(args.json, "w") as f:
                json.dump({"schema": 1, "mode": mode, "entries": entries},
                          f, indent=1, default=float)
            print(f"[benchmarks] machine-readable summary -> {args.json}")

    print("=" * 78)
    print("BENCHMARKS — Distributed 3D-GS for High-Resolution Isosurface "
          "Visualization")
    print("=" * 78)

    from benchmarks import bench_batched_render
    # relaxed floor: the strict 2x gate is for standalone runs (CI uses
    # --gate-floor 1.3 as its own step)
    bench("batched_render",
          lambda: bench_batched_render.run(quick=quick or args.smoke,
                                           gate_floor=1.3))

    from benchmarks import bench_tiered_raster
    bench("tiered_raster",
          lambda: bench_tiered_raster.run(quick=quick or args.smoke,
                                          dense_slack=1.5))

    from benchmarks import bench_assign
    # gate floor below the standalone 1.0: the orchestrator only warns on
    # noise; the committed-baseline comparison is the CI regression gate
    bench("assign",
          lambda: bench_assign.run(quick=quick or args.smoke,
                                   gate_floor=0.8))

    from benchmarks import bench_exchange
    # payload floor 1.5: the probed kingsnake budget sits at ~50% of the
    # local table, so a healthy exchange halves the communicated bytes;
    # dropping under 1.5x means the probe/budget path stopped undercutting
    # the full-table all-gather
    bench("exchange",
          lambda: bench_exchange.run(quick=quick or args.smoke,
                                     gate_floor=1.5))

    from benchmarks import bench_dtype
    # payload halving + checkpoint shrink are asserted inside the bench
    # (exact dtype arithmetic, not a timing floor)
    bench("dtype", lambda: bench_dtype.run(quick=quick or args.smoke))

    from benchmarks import bench_serving
    # warm/cold floor 1.5 at V=16: the pose-bucket cache must keep
    # deleting the assignment phase from repeat views
    bench("serving",
          lambda: bench_serving.run(quick=quick or args.smoke,
                                    gate_floor=1.5))

    from benchmarks import bench_timeseries
    # warm-started timesteps must reach the cold run's final loss in
    # <= 60% of its steps, and densify_cap must hold the live-splat
    # count flat across timesteps (both gates live inside the bench)
    bench("timeseries",
          lambda: bench_timeseries.run(quick=quick or args.smoke))

    if args.smoke:
        print(f"\n[benchmarks] smoke tier done in {time.time()-t0:.0f}s; "
              "JSON under experiments/benchmarks/")
        dump()
        return

    from benchmarks import quality_ablation
    bench("quality_ablation", lambda: quality_ablation.run(quick=quick))

    from benchmarks import table1_single_node
    bench("table1_single_node", lambda: table1_single_node.run(quick=quick))

    from benchmarks import table4_multinode
    bench("table4_multinode", lambda: table4_multinode.run(quick=quick))

    from benchmarks import table_quality
    bench("table_quality", lambda: table_quality.run(quick=quick))

    if not args.skip_roofline:
        print("\n" + "=" * 78)
        from benchmarks import roofline
        print(roofline.summarize("experiments/dryrun", full_notes=False))

    print("\n" + "=" * 78)
    print(f"[benchmarks] done in {(time.time()-t0)/60:.1f} min; JSON under "
          "experiments/benchmarks/")
    dump()


if __name__ == "__main__":
    main()
