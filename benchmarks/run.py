"""Benchmark orchestrator: one entry per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--full]

Default mode balances coverage vs CPU time (~10-20 min); --full runs the
longer protocols.  Results are printed AND saved under
experiments/benchmarks/*.json; the roofline section reads the dry-run
records under experiments/dryrun (run `python -m repro.launch.dryrun` first
for fresh ones).
"""

from __future__ import annotations

import argparse
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke tier: batched-render microbench only "
                         "(~1 min on CPU)")
    ap.add_argument("--skip-roofline", action="store_true")
    args = ap.parse_args()
    quick = not args.full
    t0 = time.time()

    print("=" * 78)
    print("BENCHMARKS — Distributed 3D-GS for High-Resolution Isosurface "
          "Visualization")
    print("=" * 78)

    from benchmarks import bench_batched_render
    try:
        # relaxed floor here: the orchestrator must not abort the remaining
        # benchmarks on timing noise; the strict 2x gate is for standalone
        # runs (CI uses --gate-floor 1.3 as its own step)
        bench_batched_render.run(quick=quick or args.smoke, gate_floor=1.3)
    except SystemExit as e:
        print(f"[benchmarks] WARNING (continuing): {e}")

    from benchmarks import bench_tiered_raster
    try:
        # generous dense slack for the same reason: the orchestrator only
        # warns on timing noise; standalone runs use the strict default
        bench_tiered_raster.run(quick=quick or args.smoke, dense_slack=1.5)
    except SystemExit as e:
        print(f"[benchmarks] WARNING (continuing): {e}")
    if args.smoke:
        print(f"\n[benchmarks] smoke tier done in {time.time()-t0:.0f}s; "
              f"JSON under experiments/benchmarks/")
        return

    from benchmarks import quality_ablation
    quality_ablation.run(quick=quick)

    from benchmarks import table1_single_node
    table1_single_node.run(quick=quick)

    from benchmarks import table4_multinode
    table4_multinode.run(quick=quick)

    from benchmarks import table_quality
    table_quality.run(quick=quick)

    if not args.skip_roofline:
        print("\n" + "=" * 78)
        from benchmarks import roofline
        print(roofline.summarize("experiments/dryrun", full_notes=False))

    print("\n" + "=" * 78)
    print(f"[benchmarks] done in {(time.time()-t0)/60:.1f} min; JSON under "
          f"experiments/benchmarks/")


if __name__ == "__main__":
    main()
