"""Table I — single-node multi-GPU scaling (Grendel intra-node parallelism).

Protocol: within one node, Grendel splits *gaussians* across GPUs and
*pixels* across GPUs; per-step work per GPU is ~ N/g gaussians + T/g tiles.
We measure the per-step wall time of the per-partition trainer at work/g for
g in {1, 2, 4} on the CPU tier of each dataset and at two resolutions,
mirroring Table I's layout (time to a fixed step budget).

A calibrated work model (t = a*N + b*pixels + c per step, least squares over
the measured grid) extrapolates to the paper's point counts; extrapolations
are labelled as such and stored next to the measured numbers.
"""

from __future__ import annotations

import argparse
import time

import jax.numpy as jnp
import numpy as np

from benchmarks.common import fmt_minutes, save_result
from repro.configs.gs_datasets import get_gs_dataset
from repro.core.cameras import orbital_rig
from repro.core.gaussians import from_points
from repro.core.pipeline import build_scene, gt_gaussians, render_views
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, fit_partition


def measure_step_time(points, colors, extent, res, *, steps, K=32,
                      n_views=6):
    center = 0.5 * (points.max(0) + points.min(0))
    grid = TileGrid(res, res, 8, 16)
    cams = orbital_rig(n_views, center, 1.6 * extent / 2 + 1e-3,
                       width=res, height=res)
    cfg = GSTrainCfg(K=K)
    gts, _ = render_views(gt_gaussians(points, colors), cams, grid, K=K)
    g0 = from_points(jnp.asarray(points), jnp.asarray(colors), opacity=0.5)
    t0 = time.perf_counter()
    fit_partition(g0, cams, jnp.asarray(gts), None, cfg, steps=steps,
                  extent=extent, grid=grid)
    total = time.perf_counter() - t0
    return total / steps


def run(datasets=("kingsnake", "rayleigh_taylor"), resolutions=(48, 64),
        gpus=(1, 2, 4), steps=30, quick=False, step_budget=1000):
    if quick:
        steps = 12
        resolutions = (48,)
    rows = {}
    samples = []           # (N, pixels, t) for the work model
    for ds_name in datasets:
        ds = get_gs_dataset(ds_name, "scale")
        points, colors, extent = build_scene(ds)
        for res in resolutions:
            for g in gpus:
                n = len(points) // g
                t = measure_step_time(points[:n], colors[:n], extent, res,
                                      steps=steps)
                rows[(ds_name, res, g)] = t
                samples.append((n, res * res, t))

    # calibrate t = a*N + b*pixels + c
    A = np.array([[n, p, 1.0] for n, p, _ in samples])
    y = np.array([t for _, _, t in samples])
    coef, *_ = np.linalg.lstsq(A, y, rcond=None)

    print("\n[table1] single-node scaling — measured s/step at work/g "
          "(CPU tier; paper Table I protocol)")
    print(f"{'dataset':18s} {'res':>5s} " +
          " ".join(f"{'g=' + str(g):>9s}" for g in gpus) +
          f" {'speedup g=4':>12s}")
    for ds_name in datasets:
        for res in resolutions:
            if (ds_name, res, gpus[0]) not in rows:
                continue
            ts = [rows[(ds_name, res, g)] for g in gpus]
            speed = ts[0] / ts[-1]
            print(f"{ds_name:18s} {res:5d} " +
                  " ".join(f"{t*1e3:8.1f}m" for t in ts) +
                  f" {speed:11.2f}x")
    print(f"[table1] work model: t/step = {coef[0]:.2e}*N + "
          f"{coef[1]:.2e}*pix + {coef[2]:.2e}")
    print(f"[table1] extrapolated minutes to {step_budget} steps at paper "
          "scale (labelled extrapolation):")
    for ds_name, n_paper in (("kingsnake", 4e6), ("rayleigh_taylor", 18.2e6)):
        for res in (1024, 2048):
            for g in gpus:
                t = coef[0] * n_paper / g + coef[1] * res * res / g + coef[2]
                if g == gpus[0]:
                    print(f"  {ds_name:18s} {res:5d}: ", end="")
                print(f"g={g} {fmt_minutes(t*step_budget):>8s}", end="  ")
            print()
    save_result("table1_single_node", dict(
        rows={f"{k[0]}|{k[1]}|{k[2]}": v for k, v in rows.items()},
        model_coef=coef.tolist(), steps=steps))
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(quick=a.quick)
