"""Table IV — multi-node scaling (the paper's headline: 3.1x at 8 nodes).

Nodes = independent spatial partitions (paper §II): the wall-clock of an
n-node run is the MAX over per-partition training times (they run
concurrently on the cluster; we train them sequentially on CPU and report
the max, plus the sum for reference).  Work per node shrinks ~1/n in
gaussians — the paper's speedup mechanism — while fixed per-step costs
(camera, pixel pipeline) bound the curve exactly as the paper observes for
the smaller Rayleigh–Taylor dataset at 8 nodes.

A second, MESH-SHAPE axis sweeps the distributed shard_map step itself
(docs/distributed-training.md): for each ("part"=p, "view"=v) shape a
subprocess forces p*v host CPU devices and times the tiered 2-D-mesh train
step — per-step wall-clock, not quality.  CPU numbers only sanity-check
the collective schedule (host "devices" share the same cores, so don't
expect speedups; see ROADMAP); the same harness pointed at a real pod
slice is the true Table IV reproduction.  Enable with
``--mesh-shapes 1x1,2x1,2x2`` (or mesh_shapes=...; full runs default to a
small sweep).
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys

from benchmarks.common import fmt_minutes, parallel_time, save_result
from repro.core.pipeline import PipelineCfg, run_pipeline
from repro.core.train import GSTrainCfg

_MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=%(dev)d "
                           + os.environ.get("XLA_FLAGS", ""))
import time
import jax, jax.numpy as jnp
from repro.core.cameras import orbital_rig, select
from repro.core.distributed import gs_shardings, make_gs_train_step
from repro.core.gaussians import from_points
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, GSOptState
from repro.data.isosurface import point_cloud_for

p, v = %(p)d, %(v)d
Pn, N, res, V, steps = 1, %(n)d, %(res)d, %(views)d, %(steps)d
grid = TileGrid(res, res, 8, 16)
pts, cols = point_cloud_for("sphere_shell", N)
g = jax.tree.map(lambda x: x[None],
                 from_points(jnp.asarray(pts), jnp.asarray(cols),
                             opacity=0.8))
N = g.means.shape[1]        # the extractor may return fewer than requested
cams = orbital_rig(V, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
cam_b = select(cams, jnp.arange(V))
gt = jnp.full((V, Pn * grid.n_tiles, 3, grid.tile_h, grid.tile_w), 0.5)
mask = jnp.ones((V, Pn * grid.n_tiles, grid.tile_h, grid.tile_w), bool)

mesh = jax.make_mesh((p, v), ("part", "view"))
cfg = GSTrainCfg(K=32)                      # tiered by default
g_sh, opt_sh, b_sh = gs_shardings(mesh, views=V)
# production shape: probe measured tier caps first (the tier_caps=None
# fallback is always-exact but strip-sized — not what a real run pays).
# probe_gs_schedule is the driver's shared in-mesh probe: occupancy over
# each device's folded (Vl*T,) binning domain, pmax-reduced so every host
# lands on the same cap ladder (it replaced this benchmark's old ad-hoc
# host-side occupancy reshape).
from repro.core.distributed import probe_gs_schedule
sched = cfg.tier_schedule()
probe_gs_schedule(sched, mesh, grid, jax.device_put(g, g_sh),
                  jax.device_put(cam_b, b_sh["cam"]), views=V)
step = make_gs_train_step(mesh, cfg, grid, extent=1.0, impl="ref", views=V,
                          k_tiers=sched.k_tiers, tier_caps=sched.tier_caps)
tr = g.trainable()
opt = GSOptState(
    m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    step=jnp.int32(0),
    grad_accum=jnp.zeros((Pn, N)), grad_count=jnp.zeros((Pn, N)))
batch = {"gt_tiles": jax.device_put(gt, b_sh["gt_tiles"]),
         "mask_tiles": jax.device_put(mask, b_sh["mask_tiles"]),
         "cam": jax.device_put(cam_b, b_sh["cam"])}
gd, od = jax.device_put(g, g_sh), jax.device_put(opt, opt_sh)
gd, od, l = step(gd, od, batch)             # compile + warm
jax.block_until_ready(l)
t0 = time.perf_counter()
for _ in range(steps):
    gd, od, l = step(gd, od, batch)
jax.block_until_ready(l)
dt = (time.perf_counter() - t0) / steps
print(f"MESHRESULT part={p} view={v} step_ms={dt * 1e3:.1f} "
      f"loss={float(l):.5f}")
"""


def run_mesh_sweep(shapes, *, n=4096, res=64, views=4, steps=5):
    """Time the tiered ("part", "view") train step per mesh shape.

    shapes: iterable of (part, view) ints.  Each shape runs in its own
    subprocess (XLA's host-device count is fixed at import time).  Returns
    {(p, v): step_ms}.
    """
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    out = {}
    for p, v in shapes:
        code = _MESH_SCRIPT % dict(dev=p * v, p=p, v=v, n=n, res=res,
                                   views=views, steps=steps)
        env = dict(os.environ)
        env["PYTHONPATH"] = src + (
            os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
        try:
            proc = subprocess.run([sys.executable, "-c", code], env=env,
                                  capture_output=True, text=True,
                                  timeout=1200)
        except subprocess.TimeoutExpired:
            print(f"[table4] mesh {p}x{v} FAILED: timed out after 1200s")
            continue
        m = re.search(r"MESHRESULT part=\d+ view=\d+ step_ms=([\d.]+)",
                      proc.stdout)
        if proc.returncode != 0 or not m:
            print(f"[table4] mesh {p}x{v} FAILED:\n{proc.stderr[-1500:]}")
            continue
        out[(p, v)] = float(m.group(1))
    if out:
        print("\n[table4] mesh-shape sweep — tiered ('part', 'view') step "
              f"({n} splats, {views} views @ {res}^2, host CPU devices)")
        print(f"{'mesh':>8s} {'devices':>8s} {'step_ms':>9s}")
        for (p, v), ms in out.items():
            print(f"{p:>4d}x{v:<3d} {p * v:8d} {ms:9.1f}")
        save_result("table4_mesh_sweep",
                    {f"{p}x{v}": ms for (p, v), ms in out.items()})
    return out


def run(datasets=("rayleigh_taylor", "richtmyer_meshkov"),
        nodes=(2, 4, 8), steps=60, resolution=48, views=8, quick=False,
        mesh_shapes=None):
    if quick:
        steps, views, nodes = 30, 6, (2, 4, 8)
        datasets = ("rayleigh_taylor",)
    if mesh_shapes is None and not quick:
        mesh_shapes = ((1, 1), (2, 1), (2, 2))
    results = {}
    for ds in datasets:
        for n in nodes:
            res = run_pipeline(PipelineCfg(
                dataset=ds, tier="scale", n_parts=n, resolution=resolution,
                steps=steps, n_views=views, train=GSTrainCfg()))
            results[(ds, n)] = dict(
                wall=parallel_time(res.train_seconds),
                total=sum(res.train_seconds),
                psnr=res.psnr, ssim=res.ssim,
                n_gaussians=res.n_gaussians)

    print("\n[table4] multi-node scaling — wall = max over partitions "
          f"({steps} steps @ {resolution}^2, CPU tier; paper Table IV)")
    print(f"{'dataset':20s} {'nodes':>5s} {'wall':>9s} {'speedup':>8s} "
          f"{'PSNR':>7s} {'SSIM':>7s}")
    for ds in datasets:
        base = None
        for n in nodes:
            if (ds, n) not in results:
                continue
            r = results[(ds, n)]
            base = base or r["wall"] * nodes[0]  # normalise vs smallest run
            speed = results[(ds, nodes[0])]["wall"] / r["wall"]
            print(f"{ds:20s} {n:5d} {fmt_minutes(r['wall']):>9s} "
                  f"{speed:7.2f}x {r['psnr']:7.2f} {r['ssim']:7.4f}")
    save_result("table4_multinode", {
        f"{k[0]}|{k[1]}": v for k, v in results.items()})
    if mesh_shapes:
        run_mesh_sweep(mesh_shapes)
    return results


def _parse_shapes(spec: str):
    """"2x1,2x2" -> ((2, 1), (2, 2))."""
    shapes = []
    for part in spec.split(","):
        p, v = part.lower().split("x")
        shapes.append((int(p), int(v)))
    return tuple(shapes)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--mesh-shapes", default=None,
                    help="comma list of PARTxVIEW mesh shapes to sweep the "
                         "distributed step over, e.g. 1x1,2x1,2x2 "
                         "(quick runs skip the sweep unless given)")
    ap.add_argument("--mesh-only", action="store_true",
                    help="run only the mesh-shape sweep")
    a = ap.parse_args()
    shapes = _parse_shapes(a.mesh_shapes) if a.mesh_shapes else None
    if a.mesh_only:
        run_mesh_sweep(shapes or ((1, 1), (2, 1), (2, 2)))
    else:
        run(quick=a.quick, mesh_shapes=shapes)
