"""Table IV — multi-node scaling (the paper's headline: 3.1x at 8 nodes).

Nodes = independent spatial partitions (paper §II): the wall-clock of an
n-node run is the MAX over per-partition training times (they run
concurrently on the cluster; we train them sequentially on CPU and report
the max, plus the sum for reference).  Work per node shrinks ~1/n in
gaussians — the paper's speedup mechanism — while fixed per-step costs
(camera, pixel pipeline) bound the curve exactly as the paper observes for
the smaller Rayleigh–Taylor dataset at 8 nodes.
"""

from __future__ import annotations

import argparse
import time

from benchmarks.common import fmt_minutes, parallel_time, save_result
from repro.core.pipeline import PipelineCfg, run_pipeline
from repro.core.train import GSTrainCfg


def run(datasets=("rayleigh_taylor", "richtmyer_meshkov"),
        nodes=(2, 4, 8), steps=60, resolution=48, views=8, quick=False):
    if quick:
        steps, views, nodes = 30, 6, (2, 4, 8)
        datasets = ("rayleigh_taylor",)
    results = {}
    for ds in datasets:
        for n in nodes:
            res = run_pipeline(PipelineCfg(
                dataset=ds, tier="scale", n_parts=n, resolution=resolution,
                steps=steps, n_views=views, train=GSTrainCfg()))
            results[(ds, n)] = dict(
                wall=parallel_time(res.train_seconds),
                total=sum(res.train_seconds),
                psnr=res.psnr, ssim=res.ssim,
                n_gaussians=res.n_gaussians)

    print(f"\n[table4] multi-node scaling — wall = max over partitions "
          f"({steps} steps @ {resolution}^2, CPU tier; paper Table IV)")
    print(f"{'dataset':20s} {'nodes':>5s} {'wall':>9s} {'speedup':>8s} "
          f"{'PSNR':>7s} {'SSIM':>7s}")
    for ds in datasets:
        base = None
        for n in nodes:
            if (ds, n) not in results:
                continue
            r = results[(ds, n)]
            base = base or r["wall"] * nodes[0]  # normalise vs smallest run
            speed = results[(ds, nodes[0])]["wall"] / r["wall"]
            print(f"{ds:20s} {n:5d} {fmt_minutes(r['wall']):>9s} "
                  f"{speed:7.2f}x {r['psnr']:7.2f} {r['ssim']:7.4f}")
    save_result("table4_multinode", {
        f"{k[0]}|{k[1]}": v for k, v in results.items()})
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(quick=a.quick)
