"""Tables II/III/V/VI — PSNR/SSIM (+grad_sim as the LPIPS stand-in) across
image resolutions and partition counts.

Tables II/III vary resolution x intra-node shards at fixed dataset; since
quality in our pipeline is a function of the merged model (not of the
intra-node split, which is numerically identical math), the resolution axis
carries the signal — reproduced here.  Tables V/VI vary node (=partition)
counts, reproduced directly.
"""

from __future__ import annotations

import argparse

from benchmarks.common import save_result
from repro.core.pipeline import PipelineCfg, run_pipeline
from repro.core.train import GSTrainCfg


def run(quick=False):
    resolutions = (48, 64, 96)          # stands in for 512/1024/2048
    nodes = (2, 4, 8)
    steps, views = 120, 10
    if quick:
        resolutions = (48, 64)
        nodes = (2, 4)
        steps, views = 50, 6

    print("\n[quality] Tables II/III — quality vs resolution "
          f"({steps} steps, {views} views, 2 partitions)")
    print(f"{'dataset':20s} {'res':>5s} {'PSNR':>7s} {'SSIM':>7s} "
          f"{'grad_sim':>9s}")
    res_rows = {}
    for ds in ("kingsnake", "rayleigh_taylor"):
        for r in resolutions:
            out = run_pipeline(PipelineCfg(
                dataset=ds, n_parts=2, resolution=r, steps=steps,
                n_views=views, train=GSTrainCfg()))
            res_rows[(ds, r)] = dict(psnr=out.psnr, ssim=out.ssim,
                                     grad_sim=out.grad_sim)
            print(f"{ds:20s} {r:5d} {out.psnr:7.2f} {out.ssim:7.4f} "
                  f"{out.grad_sim:9.4f}")

    print("\n[quality] Tables V/VI — quality vs partition count "
          f"(res 64, {steps} steps)")
    print(f"{'dataset':20s} {'nodes':>5s} {'PSNR':>7s} {'SSIM':>7s} "
          f"{'grad_sim':>9s}")
    node_rows = {}
    for ds in ("rayleigh_taylor", "richtmyer_meshkov"):
        for n in nodes:
            out = run_pipeline(PipelineCfg(
                dataset=ds, n_parts=n, resolution=64, steps=steps,
                n_views=views, train=GSTrainCfg()))
            node_rows[(ds, n)] = dict(psnr=out.psnr, ssim=out.ssim,
                                      grad_sim=out.grad_sim)
            print(f"{ds:20s} {n:5d} {out.psnr:7.2f} {out.ssim:7.4f} "
                  f"{out.grad_sim:9.4f}")
    # paper claim: quality is stable under distribution
    for ds in ("rayleigh_taylor", "richtmyer_meshkov"):
        ps = [node_rows[(ds, n)]["psnr"] for n in nodes if (ds, n) in node_rows]
        spread = max(ps) - min(ps)
        print(f"[quality] {ds}: PSNR spread across node counts "
              f"{spread:.2f} dB (paper: stable)")
    save_result("table_quality", dict(
        resolution={f"{k[0]}|{k[1]}": v for k, v in res_rows.items()},
        nodes={f"{k[0]}|{k[1]}": v for k, v in node_rows.items()}))
    return res_rows, node_rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(quick=a.quick)
