"""End-to-end driver for the paper's distributed pipeline (Fig. 1).

    PYTHONPATH=src python examples/distributed_isosurface.py \
        --dataset rayleigh_taylor --parts 4 --steps 150 --resolution 64

Every stage of §II runs: isosurface extraction -> orbital cameras ->
spatial partitioning with ghost cells -> per-partition GT renders +
background masks -> independent per-partition training -> merge ->
global evaluation, plus the ablation render (no ghosts/masks) so the
Fig. 2 comparison is visible in numbers.  Checkpoints land per partition
(the paper's O(1/n) failure-recovery property).
"""

import argparse


from repro.core.pipeline import PipelineCfg, run_pipeline
from repro.core.train import GSTrainCfg
from repro.runtime import CheckpointManager


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="rayleigh_taylor",
                    choices=["sphere_shell", "kingsnake", "rayleigh_taylor",
                             "richtmyer_meshkov"])
    ap.add_argument("--parts", type=int, default=4)
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--resolution", type=int, default=64)
    ap.add_argument("--views", type=int, default=16)
    ap.add_argument("--ablation", action="store_true",
                    help="also run without ghosts/masks (Fig. 2b)")
    ap.add_argument("--dense-k", type=int, default=None,
                    help="escape hatch: train with DENSE fixed-K "
                         "rasterization at this depth instead of the "
                         "default occupancy-tiered schedule")
    ap.add_argument("--ckpt-dir", default="checkpoints/distributed_iso")
    args = ap.parse_args()

    train_cfg = GSTrainCfg(dense_k=args.dense_k)
    common = dict(dataset=args.dataset, n_parts=args.parts,
                  resolution=args.resolution, steps=args.steps,
                  n_views=args.views, train=train_cfg)

    kt = train_cfg.resolved_k_tiers()
    raster = (f"tiered k_tiers={kt} (TierSchedule re-probes caps per "
              "densify)" if kt else f"dense K={train_cfg.assign_K}")
    print(f"[pipeline] {args.dataset}: {args.parts} partitions, "
          f"{args.steps} steps @ {args.resolution}^2, {args.views} views, "
          f"rasterizer: {raster}")
    ours = run_pipeline(PipelineCfg(use_ghost=True, use_mask=True, **common))
    print(f"[pipeline] ghosts+masks:  PSNR {ours.psnr:6.2f}  "
          f"SSIM {ours.ssim:.4f}  grad_sim {ours.grad_sim:.4f}  "
          f"splats {ours.n_gaussians:,}")
    print("[pipeline] per-partition train seconds: "
          f"{[round(t, 1) for t in ours.train_seconds]}")

    ckpt = CheckpointManager(args.ckpt_dir, keep=1)
    for p, g in enumerate(ours.parts):
        ckpt.save(args.steps, g, partition=p,
                  extra={"dataset": args.dataset})
    print(f"[pipeline] per-partition checkpoints -> {args.ckpt_dir}")

    if args.ablation:
        broken = run_pipeline(PipelineCfg(use_ghost=False, use_mask=False,
                                          **common))
        print(f"[pipeline] ablated (no GC/mask): PSNR {broken.psnr:6.2f}  "
              f"SSIM {broken.ssim:.4f}   <- Fig. 2b artifacts")
        print(f"[pipeline] delta: +{ours.psnr - broken.psnr:.2f} dB PSNR "
              "from ghost cells + background masks")


if __name__ == "__main__":
    main()
