"""Quickstart: fit 3D Gaussians to a tiny isosurface and render it.

    PYTHONPATH=src python examples/quickstart.py

Runs in ~1 minute on CPU: extracts an isosurface point cloud from an
analytic volume, initialises one gaussian per point, trains against
orbital ground-truth renders, and reports PSNR/SSIM of held-out views.
"""

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.cameras import orbital_rig, select
from repro.core.gaussians import from_points
from repro.core.pipeline import gt_gaussians, render_views
from repro.core.render import render
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, fit_partition
from repro.data.isosurface import point_cloud_for


def main():
    res, n_views, steps = 64, 10, 120
    points, colors = point_cloud_for("sphere_shell", 1500)
    extent = float(np.linalg.norm(points.max(0) - points.min(0)))
    center = 0.5 * (points.max(0) + points.min(0))
    print(f"[quickstart] {len(points)} isosurface points, extent {extent:.2f}")

    cams = orbital_rig(n_views, center, 1.5 * extent, width=res, height=res)
    grid = TileGrid(res, res, 8, 16)
    cfg = GSTrainCfg(K=32)

    # ground truth: rendered straight from the point cloud (paper Fig. 4a)
    gts, _ = render_views(gt_gaussians(points, colors), cams, grid, K=32)

    # init splats from the same cloud, but grey + translucent; training
    # recovers colors/opacity/shape
    g0 = from_points(jnp.asarray(points), None, opacity=0.3)
    t0 = time.perf_counter()
    g1, _, losses = fit_partition(
        g0, cams, jnp.asarray(gts), None, cfg, steps=steps, extent=extent,
        log_every=40, grid=grid)
    print(f"[quickstart] {steps} steps in {time.perf_counter()-t0:.1f}s  "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    out = render(g1, select(cams, 0), grid, K=32)
    ps = float(metrics.psnr(out.rgb, jnp.asarray(gts[0])))
    ss = float(metrics.ssim(out.rgb, jnp.asarray(gts[0])))
    print(f"[quickstart] view 0: PSNR {ps:.2f} dB  SSIM {ss:.4f}")
    assert ps > 20, "training failed to converge"
    print("[quickstart] ok")


if __name__ == "__main__":
    main()
