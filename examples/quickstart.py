"""Quickstart: fit 3D Gaussians to a tiny isosurface and render it.

    PYTHONPATH=src python examples/quickstart.py

Runs in ~1 minute on CPU: extracts an isosurface point cloud from an
analytic volume, initialises one gaussian per point, trains against
orbital ground-truth renders, and reports PSNR/SSIM of held-out views.
"""

import time

import jax.numpy as jnp
import numpy as np

from repro.core import metrics
from repro.core.cameras import orbital_rig, select
from repro.core.gaussians import from_points
from repro.core.pipeline import gt_gaussians, render_views
from repro.core.render import render_batch
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, fit_partition
from repro.data.isosurface import point_cloud_for


def main():
    res, n_views, steps = 64, 10, 60
    points, colors = point_cloud_for("sphere_shell", 1500)
    extent = float(np.linalg.norm(points.max(0) - points.min(0)))
    center = 0.5 * (points.max(0) + points.min(0))
    print(f"[quickstart] {len(points)} isosurface points, extent {extent:.2f}")

    cams = orbital_rig(n_views, center, 1.5 * extent, width=res, height=res)
    grid = TileGrid(res, res, 8, 16)
    # view_batch=2: each optimizer step averages the loss over a 2-view
    # minibatch rendered through one batched dispatch (render_batch)
    cfg = GSTrainCfg(K=32, view_batch=2)

    # ground truth: rendered straight from the point cloud (paper Fig. 4a),
    # all views in one batched dispatch
    gts, _ = render_views(gt_gaussians(points, colors), cams, grid, K=32,
                          batch=n_views)

    # init splats from the same cloud, but grey + translucent; training
    # recovers colors/opacity/shape
    g0 = from_points(jnp.asarray(points), None, opacity=0.3)
    t0 = time.perf_counter()
    g1, _, losses = fit_partition(
        g0, cams, jnp.asarray(gts), None, cfg, steps=steps, extent=extent,
        log_every=20, grid=grid)
    print(f"[quickstart] {steps} steps (view_batch=2) in "
          f"{time.perf_counter()-t0:.1f}s  "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f}")

    # eval: first two views in one batched render, metrics averaged
    n_eval = 2
    out = render_batch(g1, select(cams, jnp.arange(n_eval)), grid, K=32)
    ps = float(np.mean([metrics.psnr(out.rgb[v], jnp.asarray(gts[v]))
                        for v in range(n_eval)]))
    ss = float(np.mean([metrics.ssim(out.rgb[v], jnp.asarray(gts[v]))
                        for v in range(n_eval)]))
    print(f"[quickstart] {n_eval}-view eval: PSNR {ps:.2f} dB  SSIM {ss:.4f}")
    assert ps > 20, "training failed to converge"
    print("[quickstart] ok")


if __name__ == "__main__":
    main()
