"""Batched-serving example over the public API (prefill + decode).

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-780m

Uses the reduced same-family config on CPU; on a pod, drop --smoke to serve
the full config across the mesh (the decode step is what the dry-run lowers
for decode_32k / long_500k).
"""

import sys

from repro.launch.serve import main

if __name__ == "__main__":
    if "--smoke" not in sys.argv and "--full" not in sys.argv:
        sys.argv.append("--smoke")
    sys.argv = [a for a in sys.argv if a != "--full"]
    main()
