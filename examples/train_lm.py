"""LM end-to-end driver: train a ~100M-param decoder for a few hundred steps.

    PYTHONPATH=src python examples/train_lm.py --preset tiny --steps 30
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

The 100m preset is a minicpm-family model scaled to ~100M params (the
assignment's end-to-end training target); `tiny` is the CI-speed variant.
Demonstrates the full LM substrate on one host: synthetic deterministic
corpus, WSD schedule, grad compression, checkpoint/resume.
"""

import argparse
import time

import jax
import numpy as np

from repro.data.tokens import SyntheticTokens
from repro.models import (TrainCfg, init_opt_state, init_params,
                          make_train_step)
from repro.models.spec import ModelSpec
from repro.runtime import CheckpointManager

PRESETS = {
    # ~100M params: 12L d=768 12H ff=2048 vocab=32000 (embeddings dominate)
    "100m": ModelSpec(name="lm-100m", family="dense", n_layers=12,
                      d_model=768, n_q=12, n_kv=12, d_ff=2048, vocab=32000,
                      tie_embeddings=True, lr_schedule="wsd"),
    "10m": ModelSpec(name="lm-10m", family="dense", n_layers=6, d_model=384,
                     n_q=6, n_kv=6, d_ff=1024, vocab=8192,
                     tie_embeddings=True, lr_schedule="wsd"),
    "tiny": ModelSpec(name="lm-tiny", family="dense", n_layers=2, d_model=128,
                      n_q=4, n_kv=4, d_ff=256, vocab=1024,
                      tie_embeddings=True, lr_schedule="wsd"),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=list(PRESETS))
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    spec = PRESETS[args.preset]
    print(f"[train_lm] {spec.name}: {spec.param_count():,} params, "
          f"{args.steps} steps, batch {args.batch} x seq {args.seq}")
    cfg = TrainCfg(total_steps=args.steps, schedule="wsd",
                   compression=args.compression, kv_chunk=args.seq)
    params = init_params(spec, jax.random.PRNGKey(0))
    opt = init_opt_state(spec, params, cfg)
    step_fn = jax.jit(make_train_step(spec, cfg))
    data = SyntheticTokens(vocab=spec.vocab, seq=args.seq,
                           global_batch=args.batch)
    ckpt = CheckpointManager(args.ckpt_dir, keep=1)

    start = ckpt.latest_step() or 0
    if start:
        (params, opt), _ = ckpt.restore(start, (params, opt))
        print(f"[train_lm] resumed @ step {start}")

    losses = []
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        params, opt, m = step_fn(params, opt, data.batch(step))
        losses.append(float(m["loss"]))
        if (step + 1) % 10 == 0:
            dt = (time.perf_counter() - t0) / 10
            t0 = time.perf_counter()
            tok_s = args.batch * args.seq / dt
            print(f"  step {step+1:4d} loss {losses[-1]:.4f} "
                  f"({dt*1e3:.0f} ms/step, {tok_s:,.0f} tok/s)")
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt))
    ckpt.save(args.steps, (params, opt))
    if len(losses) >= 20:
        a, b = np.mean(losses[:5]), np.mean(losses[-5:])
        print(f"[train_lm] loss {a:.3f} -> {b:.3f} "
              f"({'improving' if b < a else 'NOT improving'})")
        assert b < a, "loss did not improve"
    print("[train_lm] ok")


if __name__ == "__main__":
    main()
