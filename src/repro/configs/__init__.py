"""Architecture registry: ``get_spec("<id>")`` / ``get_smoke("<id>")``.

Each ``configs/<id>.py`` exports SPEC (exact published config) and SMOKE (a
reduced same-family config for CPU tests).
"""

from __future__ import annotations

import importlib

ARCHS = (
    "minicpm_2b",
    "h2o_danube_1_8b",
    "qwen1_5_4b",
    "codeqwen1_5_7b",
    "llama4_maverick_400b_a17b",
    "mixtral_8x22b",
    "mamba2_780m",
    "jamba_v0_1_52b",
    "whisper_tiny",
    "paligemma_3b",
)

#: canonical assignment ids -> module names
ALIASES = {
    "minicpm-2b": "minicpm_2b",
    "h2o-danube-1.8b": "h2o_danube_1_8b",
    "qwen1.5-4b": "qwen1_5_4b",
    "codeqwen1.5-7b": "codeqwen1_5_7b",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "mixtral-8x22b": "mixtral_8x22b",
    "mamba2-780m": "mamba2_780m",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "whisper-tiny": "whisper_tiny",
    "paligemma-3b": "paligemma_3b",
}


def _module(arch: str):
    name = ALIASES.get(arch, arch.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{name}")


def get_spec(arch: str):
    return _module(arch).SPEC


def get_smoke(arch: str):
    return _module(arch).SMOKE


def all_arch_ids():
    return list(ALIASES.keys())
