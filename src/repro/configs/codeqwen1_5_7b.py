"""CodeQwen1.5-7B [hf:Qwen/CodeQwen1.5-7B; hf] — qwen1.5 arch.

32L d_model=4096 32H (GQA kv=32 == MHA) d_ff=13440 vocab=92416, QKV bias.
long_500k skipped (pure full attention).
"""
from repro.models.spec import ModelSpec

SPEC = ModelSpec(
    name="codeqwen1.5-7b", family="dense",
    n_layers=32, d_model=4096, n_q=32, n_kv=32, d_ff=13440, vocab=92416,
    qkv_bias=True, tie_embeddings=False, sharding_policy="tp",
    skip_shapes=("long_500k",),
    source="hf:Qwen/CodeQwen1.5-7B",
)

SMOKE = ModelSpec(
    name="codeqwen-smoke", family="dense",
    n_layers=2, d_model=128, n_q=4, n_kv=4, d_ff=352, vocab=512,
    qkv_bias=True, tie_embeddings=False,
)
