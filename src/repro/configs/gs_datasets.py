"""GS dataset configs — the paper's three benchmarks + a debug set.

``full`` tiers match the paper's point counts (dry-run / production only);
``cpu`` tiers are CPU-tractable reductions used by tests, examples and the
quality benchmarks (same pipeline, smaller N / images / views).
"""

from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class GSDataset:
    name: str
    volume: str                  # key into repro.data.volumes.VOLUMES
    n_points: int                # isosurface point budget (== #initial splats)
    n_views: int = 448           # paper: 448 training images per dataset
    resolutions: Tuple[int, ...] = (512, 1024, 2048)
    # training defaults
    capacity_factor: float = 1.3  # gaussian buffer headroom for densification
    ghost_frac: float = 0.03      # ghost halo width as fraction of extent
    source: str = ""


FULL = {
    "kingsnake": GSDataset(
        "kingsnake", "kingsnake", n_points=4_000_000,
        source="digimorph kingsnake scan, ~4M points (paper §III)"),
    "rayleigh_taylor": GSDataset(
        "rayleigh_taylor", "rayleigh_taylor", n_points=18_200_000,
        source="Cook et al. [7], ~18.2M points"),
    "richtmyer_meshkov": GSDataset(
        "richtmyer_meshkov", "richtmyer_meshkov", n_points=106_700_000,
        source="Cohen et al. [8], ~106.7M points"),
}

CPU = {
    "kingsnake": GSDataset(
        "kingsnake", "kingsnake", n_points=6_000, n_views=24,
        resolutions=(64, 128)),
    "rayleigh_taylor": GSDataset(
        "rayleigh_taylor", "rayleigh_taylor", n_points=12_000, n_views=24,
        resolutions=(64, 128)),
    "richtmyer_meshkov": GSDataset(
        "richtmyer_meshkov", "richtmyer_meshkov", n_points=24_000, n_views=24,
        resolutions=(64, 128)),
    "sphere_shell": GSDataset(
        "sphere_shell", "sphere_shell", n_points=2_000, n_views=12,
        resolutions=(64,)),
}

# scaling-benchmark tier: large enough that per-step cost is dominated by the
# gaussian count (the paper's speedup mechanism), still CPU-tractable.  Keeps
# the paper's ~1 : 4.5 : 26 size ratios.
SCALE = {
    "kingsnake": GSDataset(
        "kingsnake", "kingsnake", n_points=60_000, n_views=8,
        resolutions=(48, 64)),
    "rayleigh_taylor": GSDataset(
        "rayleigh_taylor", "rayleigh_taylor", n_points=270_000, n_views=8,
        resolutions=(48, 64)),
    "richtmyer_meshkov": GSDataset(
        "richtmyer_meshkov", "richtmyer_meshkov", n_points=540_000, n_views=8,
        resolutions=(48, 64)),
}


def get_gs_dataset(name: str, tier: str = "cpu") -> GSDataset:
    return {"full": FULL, "cpu": CPU, "scale": SCALE}[tier][name]
