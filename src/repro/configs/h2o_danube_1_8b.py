"""H2O-Danube-1.8B [arXiv:2401.16818; hf] — llama+mistral mix with SWA.

24L d_model=2560 32H (GQA kv=8) d_ff=6912 vocab=32000, sliding window 4096.
Runs long_500k: SWA decode uses a rolling window-sized KV cache (sub-quadratic).
"""
from repro.models.spec import ModelSpec

SPEC = ModelSpec(
    name="h2o-danube-1.8b", family="dense",
    n_layers=24, d_model=2560, n_q=32, n_kv=8, d_ff=6912, vocab=32000,
    swa_window=4096, tie_embeddings=False, sharding_policy="tp",
    source="arXiv:2401.16818; hf",
)

SMOKE = ModelSpec(
    name="h2o-danube-smoke", family="dense",
    n_layers=2, d_model=128, n_q=4, n_kv=2, d_ff=320, vocab=512,
    swa_window=64, tie_embeddings=False,
)
