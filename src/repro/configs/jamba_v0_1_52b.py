"""Jamba-v0.1-52B [arXiv:2403.19887; hf] — hybrid Mamba+attention, MoE.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2 every
2nd layer, attention:mamba 1:7 (one attention layer per 8-layer period, slot 4
as in the released model).  Jamba's mamba layers use d_state=16.
Runs long_500k: mamba state decode + 4 attention layers whose KV caches are
sequence-sharded over ("data","model") (distributed flash-decoding).
"""
from repro.models.spec import ModelSpec, MoECfg, SSMCfg

SPEC = ModelSpec(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_q=32, n_kv=8, d_ff=14336, vocab=65536,
    head_dim=128, moe=MoECfg(n_experts=16, top_k=2, every=2),
    ssm=SSMCfg(d_state=16, head_dim=64, expand=2, chunk=256),
    period=8, attn_slots=(4,), tie_embeddings=False, sharding_policy="fsdp",
    source="arXiv:2403.19887; hf",
)

SMOKE = ModelSpec(
    name="jamba-smoke", family="hybrid",
    n_layers=8, d_model=128, n_q=4, n_kv=2, d_ff=256, vocab=512,
    head_dim=32, moe=MoECfg(n_experts=4, top_k=2, every=2),
    ssm=SSMCfg(d_state=16, head_dim=32, expand=2, chunk=32),
    period=8, attn_slots=(4,), tie_embeddings=False,
)
