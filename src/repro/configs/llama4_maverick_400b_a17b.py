"""Llama-4-Maverick-400B-A17B [hf:meta-llama/Llama-4-*; unverified] — MoE.

48L d_model=5120 40H (GQA kv=8) d_ff=8192 (per expert) vocab=202048,
MoE 128 experts top-1.  MoE layers interleave with dense layers (every 2nd
layer MoE -> ~400B total params as the checkpoint name states; the assignment
line gives per-layer numbers only, interleave documented here).
fsdp_pod sharding: params+Adam state (~400B * 10B) need all 512 chips.
long_500k skipped (full attention at this scale).
"""
from repro.models.spec import ModelSpec, MoECfg

SPEC = ModelSpec(
    name="llama4-maverick-400b-a17b", family="moe",
    n_layers=48, d_model=5120, n_q=40, n_kv=8, d_ff=8192, vocab=202048,
    head_dim=128, moe=MoECfg(n_experts=128, top_k=1, every=2),
    period=2, tie_embeddings=False, sharding_policy="fsdp_pod",
    skip_shapes=("long_500k",),
    source="hf:meta-llama/Llama-4-Scout-17B-16E (unverified)",
)

SMOKE = ModelSpec(
    name="llama4-smoke", family="moe",
    n_layers=2, d_model=128, n_q=4, n_kv=2, d_ff=256, vocab=512,
    head_dim=32, moe=MoECfg(n_experts=4, top_k=1, every=2), period=2,
    tie_embeddings=False,
)
