"""Mamba2-780M [arXiv:2405.21060; unverified] — SSD (state-space duality).

48L d_model=1536, attention-free, vocab=50280, ssm_state=128.
d_inner = 2*1536 = 3072, 48 SSD heads of dim 64.  Runs long_500k (O(1) state).
"""
from repro.models.spec import ModelSpec, SSMCfg

SPEC = ModelSpec(
    name="mamba2-780m", family="ssm",
    n_layers=48, d_model=1536, n_q=0, n_kv=0, d_ff=0, vocab=50280,
    ssm=SSMCfg(d_state=128, head_dim=64, expand=2, chunk=256),
    attn_slots=(), tie_embeddings=True, sharding_policy="tp",
    source="arXiv:2405.21060 (unverified)",
)

SMOKE = ModelSpec(
    name="mamba2-smoke", family="ssm",
    n_layers=2, d_model=128, n_q=0, n_kv=0, d_ff=0, vocab=512,
    ssm=SSMCfg(d_state=16, head_dim=32, expand=2, chunk=32),
    attn_slots=(), tie_embeddings=True,
)
