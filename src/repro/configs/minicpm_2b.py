"""MiniCPM-2B [arXiv:2404.06395; hf] — dense llama-like, WSD schedule.

40L d_model=2304 36H (GQA kv=36 == MHA) d_ff=5760 vocab=122753.
long_500k skipped: pure full attention (500k KV cache ~1.8 TB; see DESIGN.md §5).
"""
from repro.models.spec import ModelSpec

SPEC = ModelSpec(
    name="minicpm-2b", family="dense",
    n_layers=40, d_model=2304, n_q=36, n_kv=36, d_ff=5760, vocab=122753,
    tie_embeddings=True, lr_schedule="wsd", sharding_policy="tp",
    skip_shapes=("long_500k",),
    source="arXiv:2404.06395; hf",
)

SMOKE = ModelSpec(
    name="minicpm-2b-smoke", family="dense",
    n_layers=2, d_model=128, n_q=4, n_kv=4, d_ff=320, vocab=512,
    tie_embeddings=True, lr_schedule="wsd",
)
