"""Mixtral-8x22B [arXiv:2401.04088; hf] — 8 experts top-2, SWA.

56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768, MoE 8e top-2 per layer,
sliding-window attention (window 4096).
Runs long_500k via the SWA rolling cache.  fsdp: 141B params + Adam.
"""
from repro.models.spec import ModelSpec, MoECfg

SPEC = ModelSpec(
    name="mixtral-8x22b", family="moe",
    n_layers=56, d_model=6144, n_q=48, n_kv=8, d_ff=16384, vocab=32768,
    head_dim=128, moe=MoECfg(n_experts=8, top_k=2, every=1),
    swa_window=4096, tie_embeddings=False, sharding_policy="fsdp",
    source="arXiv:2401.04088; hf",
)

SMOKE = ModelSpec(
    name="mixtral-smoke", family="moe",
    n_layers=2, d_model=128, n_q=4, n_kv=2, d_ff=256, vocab=512,
    head_dim=32, moe=MoECfg(n_experts=4, top_k=2, every=1),
    swa_window=64, tie_embeddings=False,
)
