"""PaliGemma-3B [arXiv:2407.07726; hf] — SigLIP + gemma, vision STUB.

18L d_model=2048 8H (GQA kv=1, MQA) head_dim=256 d_ff=16384 vocab=257216,
GeGLU, prefix-LM attention over 256 image tokens.  The SigLIP frontend is a
stub: input_specs() provides precomputed patch embeddings (B, 256, 1152).
long_500k skipped (pure full attention).
"""
from repro.models.spec import ModelSpec

SPEC = ModelSpec(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_q=8, n_kv=1, d_ff=16384, vocab=257216,
    head_dim=256, act="geglu", frontend="vision", frontend_dim=1152,
    n_prefix_tokens=256, tie_embeddings=True, sharding_policy="tp",
    skip_shapes=("long_500k",),
    source="arXiv:2407.07726; hf",
)

SMOKE = ModelSpec(
    name="paligemma-smoke", family="vlm",
    n_layers=2, d_model=128, n_q=4, n_kv=1, d_ff=256, vocab=512,
    head_dim=32, act="geglu", frontend="vision", frontend_dim=48,
    n_prefix_tokens=16, tie_embeddings=True,
)
