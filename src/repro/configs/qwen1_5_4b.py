"""Qwen1.5-4B [hf:Qwen/Qwen1.5-0.5B family; hf] — dense with QKV bias.

40L d_model=2560 20H (GQA kv=20 == MHA) d_ff=6912 vocab=151936.
long_500k skipped (pure full attention).
Note: 20 heads pad to 32 for the model-axis=16 sharding (DESIGN.md §5) — the
padding waste shows up in the roofline useful/total ratio and is a §Perf target.
"""
from repro.models.spec import ModelSpec

SPEC = ModelSpec(
    name="qwen1.5-4b", family="dense",
    n_layers=40, d_model=2560, n_q=20, n_kv=20, d_ff=6912, vocab=151936,
    qkv_bias=True, tie_embeddings=False, sharding_policy="tp",
    skip_shapes=("long_500k",),
    source="hf:Qwen/Qwen1.5-4B",
)

SMOKE = ModelSpec(
    name="qwen1.5-smoke", family="dense",
    n_layers=2, d_model=128, n_q=4, n_kv=4, d_ff=320, vocab=512,
    qkv_bias=True, tie_embeddings=False,
)
