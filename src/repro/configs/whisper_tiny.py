"""Whisper-tiny [arXiv:2212.04356; unverified] — enc-dec, conv frontend STUB.

4L enc + 4L dec, d_model=384 6H (MHA) d_ff=1536 vocab=51865, LayerNorm, GELU,
sinusoidal positions (no RoPE).  The conv audio frontend is a stub:
input_specs() provides precomputed frame embeddings (B, S, 384).
long_500k skipped (pure full attention).  Decode shapes run (it has a decoder).
"""
from repro.models.spec import ModelSpec

SPEC = ModelSpec(
    name="whisper-tiny", family="encdec",
    n_layers=4, enc_layers=4, d_model=384, n_q=6, n_kv=6, d_ff=1536,
    vocab=51865, qkv_bias=True, norm="layernorm", act="gelu", rope_theta=0.0,
    frontend="audio", frontend_dim=384,
    tie_embeddings=True, sharding_policy="tp",
    skip_shapes=("long_500k",),
    source="arXiv:2212.04356 (unverified)",
)

SMOKE = ModelSpec(
    name="whisper-smoke", family="encdec",
    n_layers=2, enc_layers=2, d_model=64, n_q=2, n_kv=2, d_ff=128,
    vocab=512, qkv_bias=True, norm="layernorm", act="gelu", rope_theta=0.0,
    frontend="audio", frontend_dim=64, tie_embeddings=True,
)
