"""Core: the paper's contribution — distributed 3D-GS for isosurface vis.

Geometry/primitives (gaussians, projection, cameras, tiling), the TPU render
path (render, kernels/), partitioning + ghost cells, background masks, the
per-partition trainer, merge, and the mesh-distributed Grendel-style step.
"""

from repro.core.cameras import Camera, orbital_rig, select
from repro.core.gaussians import Gaussians, from_points
from repro.core.pipeline import PipelineCfg, PipelineResult, run_pipeline
from repro.core.render import render
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, fit_partition
