"""Structured orbital camera rig (paper §II "Camera Setup").

All partitions/nodes use the *identical* rig — the paper's consistency
requirement — so we generate it deterministically from (n_views, radius,
center): a Fibonacci-spiral orbit gives near-uniform sphere coverage (the
paper uses 448 views per dataset).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Camera(NamedTuple):
    """Pinhole camera. view: (4,4) world->camera; fx/fy in pixels."""
    view: jax.Array        # (..., 4, 4)
    fx: jax.Array
    fy: jax.Array
    width: int
    height: int

    @property
    def cx(self):
        return self.width / 2.0

    @property
    def cy(self):
        return self.height / 2.0


def look_at(eye, center, up=(0.0, 0.0, 1.0)):
    eye = np.asarray(eye, np.float64)
    center = np.asarray(center, np.float64)
    up = np.asarray(up, np.float64)
    f = center - eye
    f = f / np.linalg.norm(f)
    s = np.cross(f, up)
    if np.linalg.norm(s) < 1e-8:           # looking along up: pick another up
        s = np.cross(f, np.array([1.0, 0.0, 0.0]))
    s = s / np.linalg.norm(s)
    u = np.cross(s, f)
    m = np.eye(4)
    m[0, :3], m[1, :3], m[2, :3] = s, u, f   # camera looks down +z
    m[0, 3] = -s @ eye
    m[1, 3] = -u @ eye
    m[2, 3] = -f @ eye
    return m


def orbital_rig(n_views: int, center, radius: float, *, width: int, height: int,
                fov_deg: float = 50.0) -> Camera:
    """Fibonacci-spiral orbit: identical on every node given identical args."""
    center = np.asarray(center, np.float64)
    golden = (1 + 5**0.5) / 2
    views = []
    for i in range(n_views):
        # z in (-0.95, 0.95) avoids degenerate poles
        z = 0.95 * (2 * (i + 0.5) / n_views - 1)
        r = np.sqrt(max(1 - z * z, 1e-9))
        phi = 2 * np.pi * i / golden
        eye = center + radius * np.array([r * np.cos(phi), r * np.sin(phi), z])
        views.append(look_at(eye, center))
    view = jnp.asarray(np.stack(views), jnp.float32)
    focal = 0.5 * width / np.tan(np.radians(fov_deg) / 2)
    fx = jnp.full((n_views,), focal, jnp.float32)
    fy = jnp.full((n_views,), focal, jnp.float32)
    return Camera(view=view, fx=fx, fy=fy, width=width, height=height)


def select(rig: Camera, idx) -> Camera:
    """Scalar idx -> one camera; array idx -> a view-batched Camera."""
    return Camera(rig.view[idx], rig.fx[idx], rig.fy[idx], rig.width, rig.height)


#: jax.vmap in_axes spec for a view-batched Camera: view/fx/fy carry the
#: leading view axis, width/height are static ints shared by every view
CAM_VAXES = Camera(view=0, fx=0, fy=0, width=None, height=None)
