"""Distributed 3D-GS training step (paper §II + Grendel [6]), shard_map-native.

Mesh mapping (docs/distributed-training.md has the full guide):

  pod    one spatial partition per pod — *independent* training, the paper's
         node-level parallelism.  Every tensor carries a leading partition
         dim P sharded over "pod"; the only cross-pod traffic is the 4-byte
         scalar-loss psum (metrics), verified in the dry-run HLO.  Optional.
  part   gaussian-parallel: the partition's gaussians are sharded over
         "part"; projection is local; the *projected splat table* (small,
         Grendel's key insight) is all-gathered over "part" — raw gaussians
         and optimizer state never move.  "data" is accepted as a legacy
         alias for this axis.  Required.
  model  pixel-parallel: image tiles are sharded over "model"; each device
         builds top-K lists, rasterizes and evaluates the loss only for its
         own tile strip.  Optional (absent -> every device rasterizes the
         full tile grid for its views).
  view   view-parallel: the view minibatch is sharded over "view" — each
         device projects, gathers and rasterizes only its V/n_view views,
         so the per-device table-gather payload and rasterization work stop
         scaling with the global view batch.  The only collective this axis
         adds is a scalar per-step loss pmean (the per-view losses are
         already averaged with equal weight); gaussians/optimizer state are
         replicated along it, and their gradients are summed across the
         axis by the shard_map transpose automatically.  Optional (absent
         == the degenerate n_view=1 case: views replicated, the pre-2-D
         behaviour).

Canonical production meshes: ``("part", "view")`` for the 2-D trainer and
``("pod", "part", "model")`` for the legacy pixel-sharded layout; any subset
containing a "part"/"data" axis works (see ``_axes``).

Sparse-overlap exchange (``exchange=True`` / cfg.exchange): the full-table
all-gather is the scaling wall at paper-scale splat counts — every device
pays O(N_total) wire bytes per step regardless of how little of the image
its splats touch.  The exchange path replaces it: each device's window is
further split over "part" into per-device sub-windows, each source packs
ONLY the local splats whose tile bboxes overlap each destination's
sub-window (``core.tiling.window_overlap_mask`` — the same bbox math as the
sorted assignment) into a static per-(src, dst) edge budget, and the packed
slabs move via one ``lax.all_to_all`` over "part".  Budgets are probed
(``probe_gs_exchange`` / ``ExchangeSchedule``), overflow is counted and
psum'd — never silent truncation — and the ``fit_partitions`` driver grows
starved budgets geometrically, exactly the probe/overflow honesty contract
the tier schedule and sorted assignment already follow.  The received
table is a src-major, order-preserving subsequence of the all-gather
table, so the two-key (score, index) assignment selects identical splats
and the step matches the gather path to float association.

Implemented with ``shard_map`` + explicit ``lax.all_gather`` so the
collective schedule is *by construction* (an earlier pjit-constraint version
let the SPMD partitioner sink the table all-gather into the tile-assignment
scan and replicate the partition axis across pods through the top-k sort —
500x the wire bytes; see EXPERIMENTS.md §Perf).  The backward pass of
``all_gather`` is ``psum_scatter``, which lands per-gaussian grads back on
their "part" shards automatically.
"""

from __future__ import annotations

import dataclasses
import functools
from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.cameras import CAM_VAXES, Camera, select
from repro.core.dtypes import cast_tables
from repro.core.gaussians import Gaussians
from repro.core.metrics import ssim_map
from repro.core.projection import project
from repro.core.render import resolve_assignment
from repro.core.tiling import (DEFAULT_ASSIGN_IMPL, DEFAULT_TILE_BUDGET,
                               FEAT_DIM, TierSchedule, TileGrid,
                               bin_tiles_by_occupancy, grow_tile_budget,
                               resolve_assign_impl, sorted_assign_window,
                               splat_features, tile_bounds, tile_image,
                               tile_occupancy, tile_tiers,
                               topk_by_score_then_index,
                               window_overlap_mask)
from repro.core.train import (GSTrainCfg, GSOptState, _check_resume_policy,
                              densify_and_prune, group_lrs, init_opt)
from repro.optim.compress import compress_grads
from repro.kernels import rasterize_tiles
from repro.kernels.ops import rasterize_tiles_tiered

NEG = -1e30


class MeshAxes(NamedTuple):
    """Resolved mesh-axis names; None = axis absent from this mesh."""
    pod: Optional[str]
    data: str            # gaussian axis: "part" (canonical) or "data" alias
    model: Optional[str]
    view: Optional[str]


def _axes(mesh) -> MeshAxes:
    """Map a mesh's axis names onto the four roles above.

    The gaussian axis is mandatory and is named "part" (canonical) or
    "data" (legacy alias); "pod", "model" and "view" are optional.  Any
    other axis name is an error — better loud than silently replicated.
    """
    names = mesh.axis_names
    data = "part" if "part" in names else ("data" if "data" in names else None)
    if data is None:
        raise ValueError(
            "mesh must carry a gaussian axis named 'part' (or legacy "
            f"'data'); got axes {names}")
    ax = MeshAxes(pod="pod" if "pod" in names else None, data=data,
                  model="model" if "model" in names else None,
                  view="view" if "view" in names else None)
    known = {a for a in ax if a is not None}
    extra = [n for n in names if n not in known]
    if extra:
        raise ValueError(f"unknown mesh axes {extra}; expected a subset of "
                         "('pod', 'part'|'data', 'model', 'view')")
    return ax


def _tile_axes(ax: MeshAxes):
    """PartitionSpec entry for the flat (P*T,) tile dim: sharded over the
    present subset of (pod, model), replicated when neither exists."""
    present = tuple(a for a in (ax.pod, ax.model) if a)
    return present if present else None


def gs_shardings(mesh, *, views: Optional[int] = None):
    """(gaussians, opt, batch) NamedSharding trees for the (P, N) layout.

    Mesh-axis contract (see module docstring / docs/distributed-training.md):
    gaussian + optimizer leaves are sharded (pod, part) on their leading
    (P, N) dims and REPLICATED along "model"/"view"; gt/mask tile batches
    are sharded over (pod, model) on the flat (P*T,) tile dim.

    views=V: gt/mask (and cam.view/fx/fy) gain a leading view axis.  On a
    mesh WITH a "view" axis that leading dim is sharded over it — each
    device holds only V/n_view views and the table all-gather stays on
    "part" with a per-device payload of V/n_view tables.  Without a "view"
    axis the leading dim is replicated (the degenerate n_view=1 case): view
    batches ride along with the gaussian shards and the view axis folds
    into the partition axis inside the shard_map body."""
    ax = _axes(mesh)
    pod, data = ax.pod, ax.data
    tile0 = _tile_axes(ax)
    vlead = (ax.view,) if views else ()
    g = Gaussians(
        means=P(pod, data, None),
        log_scales=P(pod, data, None),
        quats=P(pod, data, None),
        opacity_logit=P(pod, data),
        colors=P(pod, data, None),
        active=P(pod, data),
        owner=P(pod, data),
    )
    ns = lambda spec: NamedSharding(mesh, spec)
    g = Gaussians(*[ns(s) for s in g])
    tr = {k: getattr(g, k) for k in
          ("means", "log_scales", "quats", "opacity_logit", "colors")}
    opt = GSOptState(
        m=dict(tr), v=dict(tr),
        step=ns(P()),
        grad_accum=ns(P(pod, data)),
        grad_count=ns(P(pod, data)),
    )
    cam_v = P(*vlead, None, None) if views else P()
    cam_f = P(*vlead) if views else P()
    batch = {
        "gt_tiles": ns(P(*vlead, tile0, None, None, None)),
        "mask_tiles": ns(P(*vlead, tile0, None, None)),
        "cam": Camera(view=ns(cam_v), fx=ns(cam_f), fy=ns(cam_f),
                      width=ns(P()), height=ns(P())),
    }
    return g, opt, batch


# ---------------------------------------------------------------------------
# Per-shard (local) pipeline — runs inside shard_map
# ---------------------------------------------------------------------------


def _assign_tiles_local(mean2d, radius, depth, valid, lo, hi, *, K: int,
                        block: int, impl: str = "dense",
                        grid: Optional[TileGrid] = None, t0=None,
                        tile_budget: Optional[int] = None):
    """Top-K front-most splats for THIS shard's tile strip.

    mean2d (Pl, N, 2), radius/depth/valid (Pl, N); lo/hi (Tl, 2) strip bounds.
    -> idx (Pl, Tl, K) int32, score (Pl, Tl, K), overflow () int32 — the
    sorted path's dropped bbox-candidate count summed over the partition
    axis (always 0 on the dense sweep, which has no budget to starve);
    the distributed forward psums it into the step's ``"assign"`` counter
    so the driver can grow a starved ``tile_budget`` instead of silently
    truncating.

    ``impl="sorted"`` switches to the duplicate-and-sort scatter
    (core.tiling.sorted_assign_window, vmapped over the partition axis):
    ``grid`` is then the FULL image grid and ``t0`` the (traced) flat-tile
    offset of this shard's strip (None = the strip is the whole grid — the
    "model"-axis-free production mesh).  "auto" resolves on the GLOBAL
    grid's tile count, exactly like the single-device dispatcher, so both
    layouts pick the same algorithm.  Both impls share the two-key
    (score desc, splat index asc) order, so they are bit-identical whenever
    the sorted path's ``tile_budget`` covers the scene — the dense sweep
    stays as the escape hatch / oracle.
    """
    Pl, N = mean2d.shape[:2]
    if grid is not None:
        impl = resolve_assign_impl(impl, grid.n_tiles, tile_budget)
    if impl == "sorted":
        Tl = lo.shape[0]

        def one(m, r, d, v):
            return sorted_assign_window(
                m[:, 0], m[:, 1], r, v, d, grid, K=K, t0=t0, n_local=Tl,
                tile_budget=tile_budget)

        idx, score, ov = jax.vmap(one)(mean2d, radius, depth, valid)
        return idx, score, ov.sum().astype(jnp.int32)
    block = min(block, max(N, K))
    nb = (N + block - 1) // block
    Np = nb * block

    def pad(x, fill=0.0):
        return jnp.pad(x, ((0, 0), (0, Np - N)) + ((0, 0),) * (x.ndim - 2),
                       constant_values=fill)

    mb = pad(mean2d).reshape(Pl, nb, block, 2).transpose(1, 0, 2, 3)
    rb = pad(radius).reshape(Pl, nb, block).transpose(1, 0, 2)
    db = pad(depth, 1e30).reshape(Pl, nb, block).transpose(1, 0, 2)
    vb = jnp.pad(valid, ((0, 0), (0, Np - N)), constant_values=False) \
        .reshape(Pl, nb, block).transpose(1, 0, 2)

    def body(carry, xs):
        top_s, top_i = carry                       # (Pl, Tl, K)
        m, r, d, v, b0 = xs
        cx = jnp.clip(m[:, None, :, 0], lo[None, :, :1], hi[None, :, :1])
        cy = jnp.clip(m[:, None, :, 1], lo[None, :, 1:], hi[None, :, 1:])
        dx = m[:, None, :, 0] - cx
        dy = m[:, None, :, 1] - cy
        hit = (dx * dx + dy * dy) <= (r * r)[:, None, :]
        score = jnp.where(hit & v[:, None, :], -d[:, None, :], NEG)
        idx = b0 + jnp.arange(block, dtype=jnp.int32)
        cat_s = jnp.concatenate([top_s, score], axis=-1)
        cat_i = jnp.concatenate(
            [top_i, jnp.broadcast_to(idx, score.shape)], axis=-1)
        # two-key merge (score desc, index asc): the same deterministic
        # tie-break as the global assign_tiles, so strip-local and global
        # assignment agree bit-for-bit even when depths tie at the K
        # boundary (ROADMAP tie-break divergence item)
        new_s, new_i = topk_by_score_then_index(cat_s, cat_i, K)
        return (new_s, new_i), None

    Tl = lo.shape[0]
    init = (jnp.full((Pl, Tl, K), NEG, jnp.float32),
            jnp.zeros((Pl, Tl, K), jnp.int32))
    b0s = jnp.arange(nb, dtype=jnp.int32) * block
    (score, idx), _ = lax.scan(body, init, (mb, rb, db, vb, b0s))
    return idx, score, jnp.zeros((), jnp.int32)


def _loss_partials(pred, gt, mask, *, win_size: int = 7):
    """Local partial sums for masked L1 + per-tile D-SSIM.

    pred/gt (Tl', C, th, tw); mask (Tl', th, tw).  Returns 4 scalars
    (l1_num, l1_den, ssim_num, ssim_den) to be psum'd across shards.
    """
    a = pred.astype(jnp.float32)
    b = gt.astype(jnp.float32)
    m = mask.astype(jnp.float32)
    mc = m[:, None]
    l1n = (jnp.abs(a - b) * mc).sum()
    l1d = mc.sum() * a.shape[1]
    sm = jax.vmap(
        lambda x, y: ssim_map(x.transpose(1, 2, 0), y.transpose(1, 2, 0),
                              win_size=win_size)
    )(a, b)                                        # (Tl', th, tw, C)
    sn = (sm * m[..., None]).sum()
    sd = m.sum() * sm.shape[-1]
    return l1n, l1d, sn, sd


def make_gs_forward(mesh, grid: TileGrid, *, K: int, impl: str = "auto",
                    lambda_dssim: float = 0.2,
                    assign_block: Optional[int] = None,
                    return_tiles: bool = False, gather_mode: str = "f32",
                    strip_budget: float = 1.0, views: Optional[int] = None,
                    k_tiers: Optional[tuple] = None,
                    tier_caps: Optional[tuple] = None,
                    return_overflow: bool = False, win_size: int = 7,
                    assign_impl: str = DEFAULT_ASSIGN_IMPL,
                    assign_budget: Optional[int] = None,
                    exchange: bool = False,
                    exchange_budget: Optional[int] = None,
                    dtype_policy: str = "f32"):
    """shard_map'd distributed forward: (gaussians, cam, gt, mask) -> loss.

    ``dtype_policy="bf16"`` (core/dtypes.py) casts BOTH local per-splat
    tables to bf16 BEFORE the "part"-axis collective — the
    all-gather/``all_to_all`` payload halves (and so does its transpose:
    the backward psum-scatter reduces bf16) — and keeps the gathered
    tables in bf16 through the per-tile feature gather; the rasterizer
    promotes to f32 at entry and every accumulator (kernel planes, loss
    partials, psums) stays f32.  The geometry the tile ASSIGNMENT consumes
    (mean2d / radius / depth / valid) is promoted back to f32 right after
    the collective — scoring runs in f32 arithmetic on bf16-ROUNDED
    values, deterministic per policy, so exchange==gather parity holds
    bit-for-bit within the bf16 policy (both paths move identically
    rounded rows).  "f32" (default) is bit-identical to pre-policy builds:
    ``cast_tables`` is the identity and the promotes are same-dtype
    no-ops.  Under ``gather_mode="split"`` the policy additionally drops
    the f32 ``geo`` half to bf16 (the split mode's own ``rest`` table is
    bf16 under every policy).

    ``exchange=True`` swaps the table all-gather for the SPARSE-OVERLAP
    EXCHANGE (module docstring): the window is additionally split over the
    gaussian axis into per-device sub-windows of ``ceil(Tl / n_part)``
    tiles (a strip whose tile count does not divide pads the trailing
    sub-windows with degenerate tiles that hit no splat and are masked out
    of the loss — the padded step still matches the gather path's loss
    exactly, because the masked partials never count pad pixels), each
    source packs only its splats whose bboxes overlap each destination's
    sub-window into static per-(src, dst)-edge slots, and the packed slabs
    move over "part".  ``exchange_budget`` is either a scalar — every edge
    gets the same slot count, moved via one uniform ``lax.all_to_all`` —
    or an (n_part, n_part) int matrix ``B[src, dst]`` of per-edge budgets
    (``ExchangeSchedule``/``probe_gs_exchange(per_edge=True)``), realized
    as a RAGGED exchange: ``lax.all_to_all`` requires uniform chunks, so
    the matrix is carried by a ppermute ladder — one shifted permute per
    ring offset k, whose static slab height is the worst edge ON THAT
    SHIFT (``max_src B[src, (src+k) % n]``) — and each source additionally
    masks its slab past its OWN edge budget, so the per-edge cap is exact
    and the per-device wire payload is ``sum_k max_src B[src, (src+k)%n]``
    rows instead of ``n_part * max_edge``.  Received slabs are re-packed
    src-major (traced offsets from the static per-shift sizes), keeping
    the table an order-preserving subsequence of the all-gather table — so
    the two-key (score, index) top-k still selects identical splats and
    exchange==gather parity holds at float association whenever the
    per-edge overflow counters are zero.  ``exchange_budget=None``
    defaults to the local table size (always exact, payload == all_gather
    — pass a probed budget for the sparse win); a starved edge drops its
    overflowing splats from the receiver's table and FIRES the psum'd
    ``"exchange"`` overflow counter (see ``return_overflow``) — the output
    stays well-formed, and the ``fit_partitions`` driver grows the budget.
    Each device rasterizes (and pays loss partials for) only its own
    sub-window, so per-device rasterization work also drops by the
    gaussian-axis size relative to the gather path's redundant strips.
    Incompatible with ``strip_budget < 1.0`` (the prefilter is the gather
    path's halfway optimization; exchange subsumes it — a loud,
    deliberate validation, not a TODO).  With ``return_tiles=True`` the
    tiles come back UNFLATTENED as ([V,] P, T, 4, th, tw) — the flat
    (P*T,) layout of the gather path would interleave sub-windows
    non-contiguously, so return_tiles DOES still require the strip tile
    count to divide by the gaussian-axis size (pad sub-windows cannot
    reassemble into the (P, T) tile layout; the loss-only path has no such
    restriction).

    ``assign_impl`` selects the strip-local tile assignment: "auto" (the
    default — sort-based scatter on grids past the measured tile-count
    crossover, dense sweep below; resolved on the GLOBAL grid so every
    layout of one scene picks the same algorithm), "sorted"
    (duplicate-and-sort scatter, O(N*B log) independent of the strip tile
    count) or "dense" (the O(Tl*N) sweep — escape hatch / test oracle);
    both share the two-key tie-break, so the step's math is IDENTICAL
    whenever the sorted path's static per-splat ``assign_budget`` covers
    the scene (test_distributed.py pins sorted == dense through the 2-D
    mesh step).  ``assign_block`` only shapes the dense sweep's
    temporaries.

    ``win_size`` is the per-tile D-SSIM window (default 7: tiles are as
    small as 8 pixels tall, see masking.tile_l1_dssim_loss; a grid whose
    single tile covers the whole image with win_size=11 reproduces the
    single-device full-image gs_loss exactly — the driver parity tests
    pin this).

    gt_tiles (P*T, 3, th, tw) / mask_tiles (P*T, th, tw) arrive sharded over
    ("pod", "model") on the flat tile axis.

    k_tiers=(16, 64, 256)-style schedules switch each device's strip to
    occupancy-tiered rasterization: the strip-local assignment runs at
    k_tiers[-1] (K is then ignored), the strip's (Pl*Tl,) flat tiles are
    binned with core.tiling.bin_tiles_by_occupancy — the SAME binning as
    the single-device renderer, so tiered distributed == tiered
    single-device — and each non-empty tier gets its own kernel launch,
    scattered back into the strip image.  tier_caps are static per-strip
    tile capacities shared by all devices (they must cover the worst
    strip); None defaults to the always-exact full strip size (no tile is
    ever dropped, but every tier launch is strip-sized — pass measured
    caps in production).  ``return_overflow=True`` appends a DICT of three
    globally psum'd () int32 counters to the outputs — ``"tiles"`` (tiered
    dropped tiles; 0 == the tiered step is exact), ``"assign"`` (sorted
    assignment's dropped bbox candidates past ``assign_budget``) and
    ``"exchange"`` (splats dropped past a starved ``exchange_budget``; 0
    on the gather path) — the telemetry ``fit_partitions`` consumes for
    geometric budget growth, mirroring RenderOut.overflow /
    RenderOut.assign_overflow on the single-device path.  No counter is
    ever silently swallowed: every truncation path in the step reports
    here.

    views=V enables the view-batched step: cam carries (V, 4, 4) view
    matrices, gt/mask gain a leading V axis, and the loss is the MEAN OF
    PER-VIEW losses (each view's masked pixel normalization stays its own —
    the same equal-view weighting as train.py's minibatch step).  On a mesh
    WITHOUT a "view" axis that leading axis is replicated; on a 2-D
    ``("part", "view")``-style mesh it is SHARDED over "view": each device
    projects/gathers/rasterizes only its V/n_view views, the table
    all-gather stays on "part" only (per-device payload V/n_view tables,
    not V), and the collective schedule grows exactly one cheap "view"-axis
    loss pmean rather than a second gather.  Inside the shard body the
    local view axis is folded into the partition axis right after the table
    all-gather, so tile assignment and the kernel launch (one
    (Vl*Pl*Tl,) grid) are shared verbatim with the single-view path; the
    loss psum carries (Vl,) vectors instead of scalars.  V must divide by
    the "view" axis size; the view=1 (or axis-absent) case degenerates to
    the replicated pre-2-D behaviour bit-for-bit.

    Beyond-paper options (EXPERIMENTS.md §Perf, GS hillclimb):

    gather_mode="split"  all-gather two compact tables instead of one f32
        feature table + aux: ``geo`` (mx, my, radius, depth) f32 — pixel
        coordinates need f32 at 2048^2 — and ``rest`` (conic, rgb, alpha)
        bf16.  32 B/splat on the wire vs 76 B baseline (2.4x collective).
    strip_budget<1.0     per-device tile strips cover ~1/n_model of the
        image: prefilter gathered splats to those whose y-span touches MY
        strip and compact to a budget of ceil(N*strip_budget) before the
        O(T_l x N) assignment sweep — the dominant memory/compute term
        scales down by the strip hit rate (~1/n_model + halo).  The budget
        must exceed the true strip occupancy or overflow splats are dropped
        (set >= 3x the mean occupancy; exactness tested at budget 1.0).
    """
    ax = _axes(mesh)
    pod, data, model, view = ax
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_model = sizes.get(model, 1)
    n_view = sizes.get(view, 1)
    if views is None and n_view > 1:
        raise ValueError(
            f"mesh has a 'view' axis of size {n_view} but views=None; pass "
            f"views=V (a multiple of {n_view}) to shard the view minibatch")
    vloc = None
    if views is not None:
        if views % n_view:
            raise ValueError(f"views={views} must divide by the 'view' axis "
                             f"size {n_view}")
        vloc = views // n_view           # per-device view count
    T = grid.n_tiles
    assert T % n_model == 0, (T, n_model)
    Tl = T // n_model
    n_data = sizes[data]
    sub, pad = Tl, 0
    ex_budget_mat = None
    if exchange:
        if strip_budget < 1.0:
            raise ValueError(
                "exchange=True subsumes the strip prefilter; "
                f"strip_budget must stay 1.0 (got {strip_budget})")
        sub = -(-Tl // n_data)                  # ceil: pad, never refuse
        pad = sub * n_data - Tl
        if pad and return_tiles:
            raise ValueError(
                f"return_tiles with exchange=True needs the {Tl}-tile "
                f"window to divide by the '{data}' axis (size {n_data}): "
                "padded sub-windows cannot reassemble into the (P, T) "
                "tile layout (the loss-only path pads instead)")
        if exchange_budget is not None and np.ndim(exchange_budget) != 0:
            ex_budget_mat = check_budget_matrix(exchange_budget, n_data)
    tile0 = _tile_axes(ax)
    if k_tiers is not None:
        k_tiers = tuple(int(k) for k in k_tiers)
        K = k_tiers[-1]                  # assignment depth = largest tier
    if assign_block is None:
        # auto block: the view fold multiplies the assign sweep's leading
        # axis by the LOCAL view count, so shrink the gaussian block to keep
        # per-device peak temporaries roughly view-count independent
        # (mirrors render_batch's auto block).  An explicit assign_block is
        # honored verbatim.
        assign_block = max(1024, 4096 // vloc) if views else 4096

    g_spec = Gaussians(
        means=P(pod, data, None), log_scales=P(pod, data, None),
        quats=P(pod, data, None), opacity_logit=P(pod, data),
        colors=P(pod, data, None), active=P(pod, data), owner=P(pod, data),
    )
    vlead = (view,) if views else ()
    cam_spec = Camera(view=P(*vlead, None, None) if views else P(),
                      fx=P(*vlead) if views else P(),
                      fy=P(*vlead) if views else P(),
                      width=P(), height=P())
    in_specs = (g_spec, cam_spec, P(*vlead, tile0, None, None, None),
                P(*vlead, tile0, None, None))
    if exchange:
        # unflattened ([V,] P, T, ...) tiles: the T axis shards over
        # (model-major, part-minor), exactly the sub-window decomposition
        # t = mi*Tl + pi*sub — each device's chunk is contiguous there,
        # which the flat (P*T,) layout can't offer for P > 1
        win_axes = tuple(a for a in (model, data) if a)
        tiles_spec = P(*vlead, pod, win_axes, None, None, None)
    else:
        tiles_spec = P(*vlead, tile0, None, None, None)
    out_specs = (P(),)
    if return_tiles:
        out_specs += (tiles_spec,)
    if return_overflow:
        ov_spec = {"tiles": P(), "assign": P(), "exchange": P()}
        if ex_budget_mat is not None:
            # per-edge telemetry (replicated (n, n) matrices): psum'd
            # dropped-splat counts and the pmax'd in-step demand probe
            ov_spec["exchange_edges"] = P()
            ov_spec["exchange_demand"] = P()
        out_specs += (ov_spec,)
    out_specs = out_specs if len(out_specs) > 1 else P()

    lo_full, hi_full = tile_bounds(grid)            # (T, 2) host constants
    lo_pad = hi_pad = None
    if exchange and pad:
        # padded per-strip rect tables: each strip's Tl real tiles followed
        # by `pad` degenerate rects (lo > hi) no circle can hit — pad slots
        # assign nothing, rasterize to zeros and are loss-masked below
        lo_np, hi_np = np.asarray(lo_full), np.asarray(hi_full)
        lo_w = np.full((n_model * n_data * sub, 2), 1e9, np.float32)
        hi_w = np.full((n_model * n_data * sub, 2), -1e9, np.float32)
        for mi in range(n_model):
            lo_w[mi * n_data * sub: mi * n_data * sub + Tl] = \
                lo_np[mi * Tl: (mi + 1) * Tl]
            hi_w[mi * n_data * sub: mi * n_data * sub + Tl] = \
                hi_np[mi * Tl: (mi + 1) * Tl]
        lo_pad, hi_pad = jnp.asarray(lo_w), jnp.asarray(hi_w)
    # all-gather axis: N sits one deeper when a view axis leads
    nax = 2 if views else 1

    def shard_fn(g: Gaussians, cam: Camera, gt, mask):
        # ---- stage 1 (gaussian-parallel over "part"): project locally.
        # With a "view" mesh axis, cam/gt/mask arrive already view-sharded:
        # this body only ever sees its Vl = V/n_view local views.
        if views:
            # (Vl, Pl, Nl, ...): per-view projection of the same local shard
            splats = jax.vmap(lambda c: project(g, c),
                              in_axes=(CAM_VAXES,))(cam)
        else:
            splats = project(g, cam)                # (Pl, Nl, ...)

        # ---- local compact tables: the per-splat rows both handoffs move
        if gather_mode == "split":
            radius_v = jnp.where(splats.valid, splats.radius, 0.0)
            geo_l = jnp.stack(
                [splats.mean2d[..., 0], splats.mean2d[..., 1],
                 radius_v, splats.depth], axis=-1)             # (Pl,Nl,4) f32
            a, b, c = (splats.cov2d[..., 0], splats.cov2d[..., 1],
                       splats.cov2d[..., 2])
            det = jnp.maximum(a * c - b * b, 1e-12)
            alpha_v = jnp.where(splats.valid, splats.alpha, 0.0)
            rest_l = jnp.stack(
                [c / det, -b / det, a / det,
                 splats.rgb[..., 0], splats.rgb[..., 1], splats.rgb[..., 2],
                 alpha_v, jnp.zeros_like(alpha_v)],
                axis=-1).astype(jnp.bfloat16)                  # (Pl,Nl,8)
            tabs_l = (geo_l, rest_l)
        else:
            feat_l = splat_features(splats)                    # (Pl,Nl,F)
            aux_l = jnp.stack(
                [splats.radius, splats.depth,
                 splats.valid.astype(jnp.float32)], axis=-1)   # (Pl,Nl,3)
            tabs_l = (feat_l, aux_l)

        # mixed-precision boundary: drop the wire tables to the policy's
        # storage dtype BEFORE the collective (identity under "f32") —
        # payload halves here, and the backward psum-scatter of the
        # all-gather reduces in the same dtype (honest 2x both directions)
        tabs_l = cast_tables(tabs_l, dtype_policy)

        fold = lambda x: x.reshape((-1,) + x.shape[2:])
        t0_strip = lax.axis_index(model) * Tl if model is not None else None

        if exchange:
            # ---- sparse-overlap exchange: pack only the splats whose
            # bboxes overlap each destination's sub-window (module
            # docstring).  A scalar budget moves one uniform all_to_all;
            # a per-edge budget matrix moves a ragged ppermute ladder.
            if views:
                tabs_l = tuple(fold(x) for x in tabs_l)        # (R, Nl, C)
            Nl = tabs_l[0].shape[1]
            # overlap geometry in f32 (promote is a no-op under "f32"):
            # the send-side bbox test must run the same arithmetic as the
            # receive-side assignment on the same rounded values
            mx_l = tabs_l[0][..., 0].astype(jnp.float32)
            my_l = tabs_l[0][..., 1].astype(jnp.float32)
            if gather_mode == "split":
                rad_l = tabs_l[0][..., 2].astype(jnp.float32)
                val_l = rad_l > 0                  # geo radius, valid-masked
            else:
                rad_l = tabs_l[1][..., 0].astype(jnp.float32)  # aux (raw)
                val_l = tabs_l[1][..., 2] > 0.5
            base = 0 if t0_strip is None else t0_strip
            t0_all = base + jnp.arange(n_data, dtype=jnp.int32) * sub
            # t_end clips padded sub-windows at the strip's real tiles:
            # pad slots pack (and count) nothing, partial windows never
            # charge the next strip's rows against an edge budget
            hit = window_overlap_mask(mx_l, my_l, rad_l, val_l, grid,
                                      t0=t0_all, n_local=sub,
                                      t_end=(base + Tl) if pad else None)
            # hit (n_data, R, Nl): slab d = MY splats destined for the
            # device at part-index d.  Candidates past the edge budget are
            # counted, never silently dropped.
            counts = hit.sum(-1, dtype=jnp.int32)
            if ex_budget_mat is None:
                E = min(int(exchange_budget), Nl) if exchange_budget \
                    else Nl
                exchange_ov_l = jnp.maximum(counts - E, 0).sum() \
                    .astype(jnp.int32)
                slots = jax.vmap(jax.vmap(
                    lambda m: jnp.nonzero(m, size=E, fill_value=Nl)[0]))(hit)

                def exch(x):
                    sent = jax.vmap(lambda s: jax.vmap(
                        lambda row, i: jnp.take(row, i, axis=0, mode="fill",
                                                fill_value=0))(x, s))(slots)
                    got = lax.all_to_all(sent, data, 0, 0, tiled=True)
                    # got's axis 0 is the SOURCE part index: flattening it
                    # src-major keeps ascending local rows inside each
                    # source — an order-preserving subsequence of the
                    # all-gather table, so the two-key (score, index) top-k
                    # selects the identical splats whenever E covers.  Fill
                    # slots carry radius 0 / valid 0: dead to assignment
                    # and compositing.
                    return got.transpose(1, 0, 2, 3).reshape(
                        (got.shape[1], n_data * E) + got.shape[3:])
            else:
                # ---- ragged per-edge transport: all_to_all needs uniform
                # chunks, so the (n, n) budget matrix rides a ppermute
                # LADDER — ring shift k carries every (s -> (s+k) % n) edge
                # at once in a slab sized by the worst edge on that shift;
                # each source masks its slab past its own B[src, dst], so
                # the per-edge cap is exact and the wire payload is
                # sum_k E_shift[k] rows, not n * max(B).
                Bm = np.minimum(ex_budget_mat, Nl).astype(np.int32)
                ring = (np.arange(n_data) + np.arange(n_data)[:, None]) \
                    % n_data                       # ring[k, s] = (s+k) % n
                # overlap-aware window assignment: device i renders band
                # tau[i], chosen so each brick's dominant band rides the
                # free local shift (window_assignment docstring).  The
                # (P, T) tile layout of return_tiles is band-ordered, so
                # that path keeps the identity assignment.
                tau_np = np.arange(n_data, dtype=np.int64) if return_tiles \
                    else window_assignment(Bm)
                tau_arr = jnp.asarray(tau_np, jnp.int32)
                band = tau_np[ring]        # band[k, s]: dst band, shift k
                E_shift = tuple(
                    int(Bm[np.arange(n_data), band[k]].max())
                    for k in range(n_data))
                R_tot = int(sum(E_shift))
                me = lax.axis_index(data)
                b_row = jnp.take(jnp.asarray(Bm), me, axis=0)      # (n,)
                exchange_ov_edges = jnp.maximum(
                    counts - b_row[:, None], 0).sum(1).astype(jnp.int32)
                exchange_ov_l = exchange_ov_edges.sum()
                exchange_demand_l = counts.max(1).astype(jnp.int32)
                slot_by_shift = []
                for k in range(n_data):
                    # rows for the BAND the shift-k destination renders
                    hk = jnp.take(hit, jnp.take(tau_arr, (me + k) % n_data),
                                  axis=0)                          # (R, Nl)
                    sl = jax.vmap(
                        lambda m, _E=E_shift[k]: jnp.nonzero(
                            m, size=_E, fill_value=Nl)[0])(hk)
                    # my own edge budget on this shift, B[me, tau[(me+k)
                    # % n]]: slots past it become fill rows (counted above)
                    cap = jnp.take(
                        jnp.asarray(Bm[np.arange(n_data), band[k]]), me)
                    slot_by_shift.append(
                        jnp.where(jnp.arange(E_shift[k]) < cap, sl, Nl))
                # receive side: shift k delivers src (me - k) % n; packing
                # the slabs back in SRC order (exclusive cumsum of the
                # static per-shift sizes, permuted to src order) keeps the
                # table an order-preserving subsequence of the all-gather
                # table — same two-key top-k parity as the uniform path
                src_shift = (me - jnp.arange(n_data)) % n_data
                sizes_by_src = jnp.take(
                    jnp.asarray(E_shift, jnp.int32), src_shift)
                offs = jnp.concatenate(
                    [jnp.zeros((1,), jnp.int32),
                     jnp.cumsum(sizes_by_src)[:-1].astype(jnp.int32)])

                def exch(x):
                    out = jnp.zeros((x.shape[0], R_tot) + x.shape[2:],
                                    x.dtype)
                    for k in range(n_data):
                        sent = jax.vmap(
                            lambda row, i: jnp.take(
                                row, i, axis=0, mode="fill",
                                fill_value=0))(x, slot_by_shift[k])
                        got = sent if k == 0 else lax.ppermute(
                            sent, data,
                            perm=[(s, (s + k) % n_data)
                                  for s in range(n_data)])
                        off = jnp.take(offs, (me - k) % n_data)
                        out = lax.dynamic_update_slice_in_dim(
                            out, got, off, axis=1)
                    return out

            tabs = tuple(exch(x) for x in tabs_l)
        else:
            # ---- Grendel handoff: all-gather the SMALL projected table
            # over "part".  bwd(all_gather) = psum_scatter -> grads return
            # sharded.
            tabs = tuple(lax.all_gather(x, data, axis=nax, tiled=True)
                         for x in tabs_l)
            if views:
                # fold the LOCAL view axis into the partition axis:
                # (Vl, Pl, ...) -> (Vl*Pl, ...) — stage 2 and the kernel
                # launch are view-count agnostic
                tabs = tuple(fold(x) for x in tabs)
            exchange_ov_l = jnp.zeros((), jnp.int32)

        # assignment geometry promotes to f32 (no-op under "f32"): scoring
        # and depth ordering run f32 arithmetic on the policy-rounded
        # values; the kernel feature tables (feat / rest) STAY in the
        # storage dtype — halved gather volume is the point
        if gather_mode == "split":
            geo, rest = tabs
            geo = geo.astype(jnp.float32)
            mean_g = geo[..., 0:2]
            radius_g = geo[..., 2]
            depth_g = geo[..., 3]
            valid_g = radius_g > 0
        else:
            feat, aux = tabs
            mean_g = feat[..., 0:2].astype(jnp.float32)
            radius_g = aux[..., 0].astype(jnp.float32)
            depth_g = aux[..., 1].astype(jnp.float32)
            valid_g = aux[..., 2] > 0.5

        # ---- stage 2 (pixel-parallel over "model"): my tile window — the
        # model-axis strip, further split over "part" into sub-windows
        # under exchange; without either axis the window is the whole grid
        if exchange:
            pi = lax.axis_index(data)
            if ex_budget_mat is not None:
                # window assignment: this device renders band tau[me] of
                # its strip (loss partials psum across "part", so the loss
                # is assignment-invariant; gt/mask slice the same band)
                pi = jnp.take(tau_arr, pi)
            t0 = (0 if t0_strip is None else t0_strip) + pi * sub
            if pad:
                # slice the PADDED per-strip rect table (strip-major window
                # index), so pad slots get degenerate rects no circle hits
                mi = lax.axis_index(model) if model is not None else 0
                w0 = (mi * n_data + pi) * sub
                lo = lax.dynamic_slice_in_dim(lo_pad, w0, sub, 0)
                hi = lax.dynamic_slice_in_dim(hi_pad, w0, sub, 0)
            else:
                lo = lax.dynamic_slice_in_dim(lo_full, t0, sub, 0)
                hi = lax.dynamic_slice_in_dim(hi_full, t0, sub, 0)
        elif model is not None:
            t0 = t0_strip                    # strip's flat-tile offset
            lo = lax.dynamic_slice_in_dim(lo_full, t0, Tl, 0)
            hi = lax.dynamic_slice_in_dim(hi_full, t0, Tl, 0)
        else:
            t0 = None                        # window == the whole grid
            lo, hi = lo_full, hi_full
        Wl = sub if exchange else Tl

        if exchange:
            # gt/mask arrive replicated along "part" with the full strip's
            # tiles: slice MY sub-window out of each partition's block
            # (zero-padding the strip's tile axis first when it does not
            # divide — pad tiles carry mask=0, so the masked loss partials
            # never count them and the loss equals the gather loss exactly)
            def subwin(x):
                lead = 1 if views else 0
                y = x.reshape(x.shape[:lead] + (-1, Tl) + x.shape[lead + 1:])
                if pad:
                    widths = [(0, 0)] * y.ndim
                    widths[lead + 1] = (0, pad)
                    y = jnp.pad(y, widths)
                y = lax.dynamic_slice_in_dim(y, pi * sub, sub, lead + 1)
                return y.reshape(x.shape[:lead] + (-1,) + x.shape[lead + 1:])
            gt = subwin(gt)
            mask = subwin(mask)

        N = mean_g.shape[1]
        if strip_budget < 1.0:
            # strip prefilter: only splats whose circle touches MY strip
            ylo = lo[:, 1].min()
            yhi = hi[:, 1].max()
            touch = (valid_g
                     & (mean_g[..., 1] + radius_g >= ylo)
                     & (mean_g[..., 1] - radius_g <= yhi))
            M = -(-int(N * strip_budget) // 128) * 128
            cand = jax.vmap(
                lambda m: jnp.nonzero(m, size=M, fill_value=N)[0])(touch)
            take = lambda x: jax.vmap(
                lambda arr, i: jnp.take(arr, i, axis=0, mode="fill",
                                        fill_value=0))(x, cand)
            mean_g, radius_g, depth_g = (take(mean_g), take(radius_g),
                                         take(depth_g))
            valid_g = take(valid_g.astype(jnp.float32)) > 0.5
            if gather_mode == "split":
                rest = take(rest)
            else:
                feat = take(feat)

        idx, score, assign_ov_l = _assign_tiles_local(
            mean_g, radius_g, depth_g, valid_g,
            lo, hi, K=K, block=assign_block, impl=assign_impl,
            grid=grid, t0=t0, tile_budget=assign_budget)
        idx = lax.stop_gradient(idx)
        live = lax.stop_gradient(score) > NEG / 2   # (Pl, Tl, K)

        def features_for(p_rows, idx_rows, live_rows):
            """Kernel features for arbitrary tile rows: p_rows (...,) picks
            the partition slice of the gathered table, idx_rows (..., K')
            the splat rows within it, live_rows masks dead slots' alpha.
            Serves both the dense (Pl, Tl, K) gather and the per-tier
            compacted (cap_i, K_i) gathers."""
            if gather_mode == "split":
                mean_t = mean_g[p_rows[..., None], idx_rows]
                rest_t = rest[p_rows[..., None], idx_rows] \
                    .astype(jnp.float32)
                alpha = jnp.where(live_rows, rest_t[..., 6], 0.0)
                return jnp.concatenate(
                    [mean_t, rest_t[..., :6], alpha[..., None],
                     jnp.zeros(mean_t.shape[:-1] + (FEAT_DIM - 9,),
                               jnp.float32)], axis=-1)
            feat_t = feat[p_rows[..., None], idx_rows]
            alpha = jnp.where(live_rows, feat_t[..., 8], 0.0)
            return jnp.concatenate(
                [feat_t[..., :8], alpha[..., None], feat_t[..., 9:]], -1)

        Pl = mean_g.shape[0]
        origins = jnp.tile(lo, (Pl, 1))                 # (Pl*Tl, 2)
        if k_tiers is not None:
            # ---- tiered dispatch over the window's flat tile axis ----
            M = Pl * Wl
            idx_f = idx.reshape(M, K)
            live_f = live.reshape(M, K)
            occ = live_f.sum(-1).astype(jnp.int32)
            caps = tier_caps if tier_caps is not None \
                else (M,) * len(k_tiers)
            plan = bin_tiles_by_occupancy(occ, k_tiers, caps)
            overflow_l = plan.overflow
            tier_feats, tier_origins = [], []
            for k, ids in zip(k_tiers, plan.tile_ids):
                safe = jnp.minimum(ids, M - 1)          # sentinel-safe rows
                live_rows = live_f[safe, :k] & (ids < M)[:, None]
                tier_feats.append(
                    features_for(safe // Wl, idx_f[safe, :k], live_rows))
                tier_origins.append(jnp.take(origins, ids, axis=0,
                                             mode="fill", fill_value=0.0))
            tiles = rasterize_tiles_tiered(
                tier_feats, tier_origins, plan.tile_ids, M,
                tile_h=grid.tile_h, tile_w=grid.tile_w, impl=impl)
        else:
            p_rows = jnp.broadcast_to(
                jnp.arange(Pl, dtype=jnp.int32)[:, None], idx.shape[:2])
            tile_feat = features_for(p_rows, idx, live)  # (Pl,Wl,K,F)
            flat = tile_feat.reshape(Pl * Wl, K, FEAT_DIM)
            tiles = rasterize_tiles(flat, origins, tile_h=grid.tile_h,
                                    tile_w=grid.tile_w, impl=impl)
            overflow_l = jnp.zeros((), jnp.int32)   # dense path never drops

        # ---- masked loss partials -> psum (scalar-only cross-pod traffic).
        # The partial psum runs over the present (pod, part, model) axes —
        # it must NOT cross "view" shards, whose partials belong to
        # different views; the view axis contributes one scalar pmean at
        # the very end instead.
        axes = tuple(a for a in (pod, data, model) if a)
        if views:
            # per-view partials ((Vl,) vectors through the psum), then the
            # mean of per-view losses — the same equal-view weighting as
            # train.py's minibatch step, regardless of how many masked
            # pixels each view has.  mean over local views + pmean over the
            # "view" axis == the global V-view mean (equal local counts).
            pred_v = tiles[:, :3].reshape((vloc, -1, 3) + tiles.shape[2:])
            l1n, l1d, sn, sd = jax.vmap(
                partial(_loss_partials, win_size=win_size))(pred_v, gt, mask)
            l1n, l1d, sn, sd = (lax.psum(x, axes) for x in (l1n, l1d, sn, sd))
            loss = ((1 - lambda_dssim) * l1n / jnp.maximum(l1d, 1.0)
                    + lambda_dssim
                    * (1.0 - sn / jnp.maximum(sd, 1.0)) / 2.0).mean()
            if view is not None:
                loss = lax.pmean(loss, view)
        else:
            l1n, l1d, sn, sd = _loss_partials(tiles[:, :3], gt, mask,
                                              win_size=win_size)
            l1n, l1d, sn, sd = (lax.psum(x, axes) for x in (l1n, l1d, sn, sd))
            loss = ((1 - lambda_dssim) * l1n / jnp.maximum(l1d, 1.0)
                    + lambda_dssim * (1.0 - sn / jnp.maximum(sd, 1.0)) / 2.0)
        if return_tiles or return_overflow:
            outs = (loss,)
            if return_tiles:
                if exchange:
                    # unflattened ([Vl,] Pl, Wl, ...) — see tiles_spec
                    lead = (vloc, -1, Wl) if views else (-1, Wl)
                    tiles = tiles.reshape(lead + tiles.shape[1:])
                elif views:
                    tiles = tiles.reshape((vloc, -1) + tiles.shape[1:])
                outs += (tiles,)
            if return_overflow:
                # tiles/assign counters: each window is computed once per
                # strip-distinct device; under gather the "part" devices
                # hold REDUNDANT copies of the strip (summing across them
                # would multiply by n_part), under exchange they hold
                # DISTINCT sub-windows (the sum must cross "part" too).
                # The exchange counter is send-side and per-device-distinct
                # always: sum over every axis.
                strip_axes = tuple(a for a in (pod, model, view) if a) \
                    + ((data,) if exchange else ())
                red = (lambda x: lax.psum(x, strip_axes)) if strip_axes \
                    else (lambda x: x)
                all_axes = tuple(a for a in (pod, data, model, view) if a)
                ov_out = {"tiles": red(overflow_l),
                          "assign": red(assign_ov_l),
                          "exchange": lax.psum(exchange_ov_l, all_axes)}
                if ex_budget_mat is not None:
                    # per-edge matrices: each "part" device owns row `me`
                    # (its send side); scatter into an (n, n) zeros and let
                    # the collective assemble the disjoint rows.  edges =
                    # total dropped per (src, dst) summed over replicas;
                    # demand = the in-step probe, the max overlap any
                    # (view, strip) replica saw on each edge.
                    em = lax.dynamic_update_slice(
                        jnp.zeros((n_data, n_data), jnp.int32),
                        exchange_ov_edges[None, :], (me, 0))
                    ov_out["exchange_edges"] = lax.psum(em, all_axes)
                    dm = lax.dynamic_update_slice(
                        jnp.zeros((n_data, n_data), jnp.int32),
                        exchange_demand_l[None, :], (me, 0))
                    dm = lax.psum(dm, data)
                    rest_axes = tuple(a for a in (pod, model, view) if a)
                    if rest_axes:
                        dm = lax.pmax(dm, rest_axes)
                    ov_out["exchange_demand"] = dm
                outs += (ov_out,)
            return outs
        return loss

    return shard_map(shard_fn, mesh=mesh, in_specs=in_specs,
                     out_specs=out_specs, check_rep=False)


# ---------------------------------------------------------------------------
# Distributed occupancy probe (tier-schedule telemetry)
# ---------------------------------------------------------------------------


def make_gs_probe(mesh, grid: TileGrid, *, k_tiers, views: Optional[int] = None,
                  assign_block: Optional[int] = None,
                  assign_impl: str = DEFAULT_ASSIGN_IMPL,
                  assign_budget: Optional[int] = None,
                  exchange: bool = False):
    """shard_map'd tier-schedule probe: (gaussians, cam) ->
    (tier_counts (n_tiers,) int32, max_occ () int32), REPLICATED.

    The distributed tiered forward bins each device's FOLDED
    ``(Vl * Pl * Tl,)`` flat tile axis (local views x local partitions x
    strip tiles), so tier caps must cover the worst such folded domain
    across the whole mesh — not the worst single view.  This probe runs the
    same project -> table all-gather -> view fold -> strip-local assignment
    pipeline as ``make_gs_forward`` at the ladder's Kmax, measures per-tile
    occupancy over the folded domain, counts tiles per desired tier
    (``core.tiling.tile_tiers`` over the FULL ladder), and pmax-reduces
    (counts, max occupancy) over every mesh axis.  The outputs are
    therefore identical on every device AND every host, which is what lets
    each process of a multi-host run feed them to
    ``TierSchedule.probe_counts`` independently and still compile the
    identical program — no out-of-band schedule broadcast needed.

    ``k_tiers`` must be the schedule's FULL ladder (``TierSchedule.ladder``:
    assignment runs at ladder[-1]; probing a trimmed ladder would under-
    measure).  The probe ignores ``strip_budget``/``gather_mode`` — it uses
    the exact f32 path, whose occupancy upper-bounds every budgeted
    variant, so caps sized here cover them too.  It DOES honor
    ``assign_impl``/``assign_budget``: the probe must measure occupancy
    with the same assignment the training step runs, or a budget-truncated
    step could be capped from un-truncated telemetry.

    ``exchange=True`` matches the sparse-exchange forward's binning domain:
    each device's window shrinks to its per-"part" sub-window of the strip
    (folded domain (Vl*Pl*sub,)), and the pmax makes every device agree on
    the worst sub-window.  The probe still builds its table via the full
    all-gather — occupancy of the complete table upper-bounds the
    budget-truncated exchange table, so caps sized here stay conservative
    regardless of the edge budget (and the probe needs no budget to exist
    yet; ``probe_gs_exchange`` sizes that knob independently).
    """
    ax = _axes(mesh)
    pod, data, model, view = ax
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_model = sizes.get(model, 1)
    n_view = sizes.get(view, 1)
    if views is None and n_view > 1:
        raise ValueError(
            f"mesh has a 'view' axis of size {n_view} but views=None; pass "
            f"views=V (a multiple of {n_view}) to probe the view-sharded "
            "domain")
    if views is not None and views % n_view:
        raise ValueError(f"views={views} must divide by the 'view' axis "
                         f"size {n_view}")
    vloc = views // n_view if views else None
    ladder = tuple(int(k) for k in k_tiers)
    K = ladder[-1]
    T = grid.n_tiles
    assert T % n_model == 0, (T, n_model)
    Tl = T // n_model
    n_data = sizes[data]
    sub = Tl
    pad = 0
    if exchange:
        sub = -(-Tl // n_data)                  # ceil: pad, never refuse
        pad = sub * n_data - Tl
    if assign_block is None:
        assign_block = max(1024, 4096 // vloc) if views else 4096

    g_spec = Gaussians(
        means=P(pod, data, None), log_scales=P(pod, data, None),
        quats=P(pod, data, None), opacity_logit=P(pod, data),
        colors=P(pod, data, None), active=P(pod, data), owner=P(pod, data),
    )
    vlead = (view,) if views else ()
    cam_spec = Camera(view=P(*vlead, None, None) if views else P(),
                      fx=P(*vlead) if views else P(),
                      fy=P(*vlead) if views else P(),
                      width=P(), height=P())
    lo_full, hi_full = tile_bounds(grid)
    lo_pad = hi_pad = None
    if exchange and pad:
        # padded per-strip rect tables (as in make_gs_forward): pad slots
        # get degenerate rects, so they bin zero occupancy
        lo_np, hi_np = np.asarray(lo_full), np.asarray(hi_full)
        lo_w = np.full((n_model * n_data * sub, 2), 1e9, np.float32)
        hi_w = np.full((n_model * n_data * sub, 2), -1e9, np.float32)
        for mi in range(n_model):
            lo_w[mi * n_data * sub: mi * n_data * sub + Tl] = \
                lo_np[mi * Tl: (mi + 1) * Tl]
            hi_w[mi * n_data * sub: mi * n_data * sub + Tl] = \
                hi_np[mi * Tl: (mi + 1) * Tl]
        lo_pad, hi_pad = jnp.asarray(lo_w), jnp.asarray(hi_w)
    nax = 2 if views else 1
    reduce_axes = tuple(a for a in (pod, data, model, view) if a)

    def shard_fn(g: Gaussians, cam: Camera):
        if views:
            splats = jax.vmap(lambda c: project(g, c),
                              in_axes=(CAM_VAXES,))(cam)
        else:
            splats = project(g, cam)
        aux_l = jnp.stack(
            [splats.mean2d[..., 0], splats.mean2d[..., 1],
             jnp.where(splats.valid, splats.radius, 0.0),
             splats.depth], axis=-1)                     # (Pl, Nl, 4)
        aux = lax.all_gather(aux_l, data, axis=nax, tiled=True)
        if views:
            aux = aux.reshape((-1,) + aux.shape[2:])     # fold Vl into Pl
        mean_g = aux[..., 0:2]
        radius_g = aux[..., 2]
        depth_g = aux[..., 3]
        valid_g = radius_g > 0

        if exchange:
            mi = lax.axis_index(model) if model is not None else 0
            pi = lax.axis_index(data)
            t0 = mi * Tl + pi * sub
            if pad:
                w0 = (mi * n_data + pi) * sub
                lo = lax.dynamic_slice_in_dim(lo_pad, w0, sub, 0)
                hi = lax.dynamic_slice_in_dim(hi_pad, w0, sub, 0)
            else:
                lo = lax.dynamic_slice_in_dim(lo_full, t0, sub, 0)
                hi = lax.dynamic_slice_in_dim(hi_full, t0, sub, 0)
        elif model is not None:
            mi = lax.axis_index(model)
            t0 = mi * Tl
            lo = lax.dynamic_slice_in_dim(lo_full, t0, Tl, 0)
            hi = lax.dynamic_slice_in_dim(hi_full, t0, Tl, 0)
        else:
            t0 = None
            lo, hi = lo_full, hi_full

        _, score, _ = _assign_tiles_local(mean_g, radius_g, depth_g, valid_g,
                                          lo, hi, K=K, block=assign_block,
                                          impl=assign_impl, grid=grid, t0=t0,
                                          tile_budget=assign_budget)
        occ = tile_occupancy(score).reshape(-1)   # (Vl*Pl*Tl,) or (..*sub,)
        tiers = tile_tiers(occ, ladder)
        counts = jnp.stack(
            [(tiers == i).sum() for i in range(len(ladder))]
        ).astype(jnp.int32)
        if reduce_axes:
            counts = lax.pmax(counts, reduce_axes)
            max_occ = lax.pmax(occ.max(), reduce_axes)
        else:
            max_occ = occ.max()
        return counts, max_occ

    return shard_map(shard_fn, mesh=mesh, in_specs=(g_spec, cam_spec),
                     out_specs=(P(), P()), check_rep=False)


def folded_tile_count(mesh, grid: TileGrid, n_parts: int,
                      views: Optional[int] = None,
                      exchange: bool = False) -> int:
    """Per-device flat tile count of the distributed binning domain,
    ``Vl * Pl * Tl`` — the cap clamp / ``note_overflow`` ``n_tiles``
    argument (binning over a domain of this size provably cannot drop).
    ``exchange=True`` shrinks the window to the per-"part" sub-window,
    ``Vl * Pl * ceil(Tl / n_data)``, matching the sparse-exchange step
    (which pads non-divisible strips)."""
    ax = _axes(mesh)
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    vloc = views // sizes.get(ax.view, 1) if views else 1
    t_loc = grid.n_tiles // sizes.get(ax.model, 1)
    if exchange:
        t_loc = -(-t_loc // sizes[ax.data])
    return vloc * (n_parts // sizes.get(ax.pod, 1)) * t_loc


@functools.lru_cache(maxsize=32)
def _gs_probe_jit(mesh, grid: TileGrid, ladder: tuple,
                  views: Optional[int],
                  assign_impl: str = DEFAULT_ASSIGN_IMPL,
                  assign_budget: Optional[int] = None,
                  exchange: bool = False):
    return jax.jit(make_gs_probe(mesh, grid, k_tiers=ladder, views=views,
                                 assign_impl=assign_impl,
                                 assign_budget=assign_budget,
                                 exchange=exchange))


def probe_gs_schedule(sched: TierSchedule, mesh, grid: TileGrid,
                      g: Gaussians, cam, *, views: Optional[int] = None,
                      assign_impl: str = DEFAULT_ASSIGN_IMPL,
                      assign_budget: Optional[int] = None,
                      exchange: bool = False):
    """Probe ``sched`` against the mesh: run the (cached, jitted)
    ``make_gs_probe`` telemetry reduction and update the schedule host-side
    via ``probe_counts``.  Returns the new ``(k_tiers, tier_caps)`` —
    identical on every host by construction (pmax'd telemetry).

    ``cam`` is one view-batch Camera (shaped for ``views``) or a sequence
    of them; with several, the per-tier counts are max-merged host-side so
    the caps cover the WORST probed batch of the step's exact folded
    domain.

    This is the shared probe for everything driving the distributed tiered
    step: ``fit_partitions`` calls it at init and after every densify
    (with two probe batches when the view batch is a single view), and
    benchmarks/table4_multinode.py sizes its swept steps with it.
    """
    cam_batches = [cam] if isinstance(cam, Camera) else list(cam)
    probe_fn = _gs_probe_jit(mesh, grid, tuple(sched.ladder), views,
                             assign_impl, assign_budget, exchange)
    counts, max_occ = None, 0
    for cb in cam_batches:
        c, m = probe_fn(g, cb)
        c = np.asarray(c)
        counts = c if counts is None else np.maximum(counts, c)
        max_occ = max(max_occ, int(m))
    n_parts = g.means.shape[0]
    return sched.probe_counts(
        counts, max_occ,
        n_tiles=folded_tile_count(mesh, grid, n_parts, views,
                                  exchange=exchange))


# ---------------------------------------------------------------------------
# Sparse-exchange edge budget: probe + schedule
# ---------------------------------------------------------------------------


def check_budget_matrix(budget, n_data: Optional[int] = None) -> np.ndarray:
    """Validate a per-edge exchange budget matrix LOUDLY.

    ``budget`` must be a square 2-D (n_part, n_part) array of edge budgets
    ``B[src, dst] >= 1``; with ``n_data`` given it must match the mesh's
    "part" axis size exactly (an undersized matrix would silently starve
    the missing edges, an oversized one would address devices that do not
    exist).  Returns the validated int64 numpy matrix.
    """
    B = np.asarray(budget)
    if B.ndim != 2 or B.shape[0] != B.shape[1]:
        raise ValueError(
            "exchange budget matrix must be square (n_part, n_part); got "
            f"shape {B.shape}")
    if n_data is not None and B.shape[0] != n_data:
        raise ValueError(
            f"exchange budget matrix is {B.shape[0]}x{B.shape[1]} but the "
            f"'part' axis has {n_data} devices — one row/column per device "
            "is required (undersized/oversized matrices are refused, never "
            "padded)")
    if not np.issubdtype(B.dtype, np.integer):
        if not np.all(B == np.floor(B)):
            raise ValueError("exchange budget matrix entries must be "
                             "integers")
    B = B.astype(np.int64)
    if (B < 1).any():
        raise ValueError(
            "exchange budget matrix entries must be >= 1 (every edge needs "
            f"at least one slot); min entry is {int(B.min())}")
    return B


def window_assignment(budget) -> np.ndarray:
    """Overlap-aware window assignment: which tile sub-window each "part"
    device renders, chosen from the per-edge budget matrix.

    The ragged ppermute ladder's wire cost is ``sum_k max_s B[s, tau[(s+k)
    % n]]`` — the per-shift slab is sized by the worst edge it carries, and
    shift 0 (each device keeping rows for its OWN window) is local, hence
    free.  With spatially compact (Morton-sorted) partitions each brick's
    overlap concentrates on a few screen bands, but the identity
    brick->band assignment scatters those heavy edges across every ring
    shift, so each slab pays a heavy max and the wire payload stops
    shrinking with n_part.  This routine returns a permutation ``tau``
    (``tau[i]`` = the band device ``i`` renders) that pulls each brick's
    dominant band onto the free local shift and packs the residue tightly:
    greedy dominant-band seeding (steepest brick first) refined by 2-opt
    swaps on the exact ladder objective.  Deterministic, pure numpy, a few
    ms at real part counts; the forward caches per budget matrix.
    """
    B = np.asarray(budget, np.int64)
    n = B.shape[0]
    if n <= 1:
        return np.zeros((n,), np.int64)
    shifts = [(np.arange(n) + k) % n for k in range(1, n)]

    def cost(tau):
        return sum(int(B[np.arange(n), tau[s]].max()) for s in shifts)

    tau = -np.ones(n, np.int64)
    used = np.zeros(n, bool)
    for s in np.argsort(-B.max(1), kind="stable"):
        d = int(np.argmax(np.where(used, -1, B[s])))
        tau[s] = d
        used[d] = True
    best = cost(tau)
    improved = True
    while improved:
        improved = False
        for i in range(n):
            for j in range(i + 1, n):
                t2 = tau.copy()
                t2[i], t2[j] = t2[j], t2[i]
                w = cost(t2)
                if w < best:
                    best, tau, improved = w, t2, True
    ident = np.arange(n, dtype=np.int64)
    return tau if best < cost(ident) else ident


class ExchangeSchedule:
    """Telemetry-driven per-(src, dst) edge budget for the sparse exchange.

    The exchange packs, per destination, the local splats overlapping that
    destination's sub-window into a static number of slots.  Like the tier
    caps, the budget is a STATIC shape fed from concrete telemetry and
    guarded by a psum'd overflow counter — the same probe/overflow honesty
    contract.  ``budget`` is either one scalar edge budget (every edge
    packs the same slot count — the legacy shape) or an (n_part, n_part)
    int matrix ``B[src, dst]`` (per-edge: spatially distant shard pairs
    get small budgets, neighbours get large ones — the shape that scales
    with n_part; see ``probe_gs_exchange(per_edge=True)``):

      probe_budget(max_edge, n_local)   size the budget from the pmax'd
          worst overlap count — a scalar (worst edge anywhere) or an
          (n, n) demand matrix (worst per edge) — scaled by ``slack`` and
          rounded so nearby probes hash to the same jit entry; clamped to
          ``n_local`` (a source can never send more splats than it holds,
          so overflow is impossible at the clamp).
      note_overflow(ov, n_local)        a step reported dropped splats: the
          budget grows geometrically (clamped at ``n_local``).  With a
          matrix budget and the step's psum'd per-edge counter matrix,
          ONLY the starved edges grow — a congested neighbour edge never
          inflates the whole table.  Returns True when it changed —
          rebuild the step.  Never silent truncation: every dropped splat
          shows up in the counter first.
      ensure(demand, n_local)           grow (never shrink) the budget to
          cover a demand measured IN-STEP (the forward's pmax'd
          ``"exchange_demand"`` matrix) — the no-host-round-trip resize
          ``fit_partitions`` uses after densify.
      state_dict / load_state           checkpointed via the manager's
          ``extra`` payload so a resumed run keeps its probed budget
          instead of re-probing (matrices ride as nested lists).
    """

    def __init__(self, *, slack: float = 1.5, round_to: int = 16,
                 growth: float = 2.0, budget=None):
        self.slack = float(slack)
        self.round_to = int(round_to)
        self.growth = float(growth)
        self.budget = self._coerce(budget)

    def _coerce(self, budget):
        if budget is None:
            return None
        if np.ndim(budget) == 0:
            return int(budget)
        return check_budget_matrix(budget)

    def _sized(self, demand, n_local: int) -> np.ndarray:
        """slack -> round_to -> [1, n_local] clamp, elementwise."""
        b = np.ceil(np.maximum(np.asarray(demand, np.int64), 1)
                    * self.slack).astype(np.int64)
        b = -(-b // self.round_to) * self.round_to
        return np.clip(b, 1, int(n_local))

    def probe_budget(self, max_edge, n_local: int):
        """Size the edge budget from the pmax'd worst overlap count: a
        scalar count -> scalar budget, an (n, n) per-edge demand matrix ->
        per-edge budget matrix."""
        if np.ndim(max_edge) == 2:
            self.budget = check_budget_matrix(
                self._sized(np.asarray(max_edge), n_local))
            return self.budget
        self.budget = int(self._sized(int(max_edge), n_local))
        return self.budget

    def note_overflow(self, overflow, n_local: int) -> bool:
        """React to a step's dropped-splat counter: grow the budget by
        ``growth`` (clamped at ``n_local``, where overflow is impossible).
        With a matrix budget and a matching (n, n) counter, only the
        starved edges grow.  Returns True when it changed — rebuild the
        step."""
        if self.budget is None:
            return False
        ov = np.asarray(overflow)
        if np.ndim(self.budget) == 2:
            B = np.asarray(self.budget)
            starved = (ov > 0) if ov.shape == B.shape \
                else np.full(B.shape, int(ov.sum()) > 0)
            if not starved.any():
                return False
            grown = np.minimum(
                int(n_local),
                np.maximum(self.round_to,
                           np.ceil(B * self.growth).astype(np.int64)))
            new = np.where(starved, np.maximum(B, grown), B)
            if (new == B).all():
                return False
            self.budget = new
            return True
        if int(ov.sum()) <= 0:
            return False
        grown = min(int(n_local),
                    max(self.round_to, int(np.ceil(self.budget
                                                   * self.growth))))
        if grown <= self.budget:
            return False
        self.budget = grown
        return True

    def ensure(self, demand, n_local: int) -> bool:
        """Grow (never shrink) the budget to cover ``demand`` splats per
        edge — rounded to ``round_to``, clamped at ``n_local``.  This is
        the in-step resize path: ``fit_partitions`` feeds it the running
        max of the step's own pmax'd demand matrix (plus the densify
        growth bound), so budget growth needs no host probe round-trip.
        Returns True when the budget changed — rebuild the step."""
        if self.budget is None:
            return False
        d = np.maximum(np.asarray(demand, np.int64), 1)
        need = np.clip(-(-d // self.round_to) * self.round_to,
                       1, int(n_local))
        if np.ndim(self.budget) == 2:
            need = check_budget_matrix(need, np.asarray(self.budget).shape[0])
            new = np.maximum(np.asarray(self.budget), need)
            if (new == np.asarray(self.budget)).all():
                return False
            self.budget = new
            return True
        new = max(int(self.budget), int(need))
        if new == self.budget:
            return False
        self.budget = new
        return True

    def budget_key(self):
        """Hashable snapshot of the budget (int or tuple-of-tuples) — the
        jit/step-cache key for the static exchange shapes."""
        if self.budget is None or np.ndim(self.budget) == 0:
            return self.budget
        return tuple(tuple(int(x) for x in row)
                     for row in np.asarray(self.budget))

    def state_dict(self) -> dict:
        """JSON-able snapshot, stored under CheckpointManager extra
        ["exchange"] by ``fit_partitions``.  A matrix budget serializes as
        nested lists."""
        b = self.budget
        if b is not None and np.ndim(b) == 2:
            b = [[int(x) for x in row] for row in np.asarray(b)]
        return {"slack": self.slack, "round_to": self.round_to,
                "growth": self.growth, "budget": b}

    def load_state(self, state: dict) -> "ExchangeSchedule":
        """Restore a snapshot IN PLACE (the checkpoint wins) — a resumed
        run keeps its probed/grown budget without re-probing.  Matrix
        budgets are validated loudly (``check_budget_matrix``)."""
        self.slack = float(state["slack"])
        self.round_to = int(state["round_to"])
        self.growth = float(state["growth"])
        self.budget = self._coerce(state["budget"])
        return self

    @classmethod
    def from_state(cls, state: dict) -> "ExchangeSchedule":
        """Rebuild a schedule from a ``state_dict`` snapshot."""
        return cls().load_state(state)

    def __repr__(self):
        b = self.budget
        if b is not None and np.ndim(b) == 2:
            B = np.asarray(b)
            b = (f"{B.shape[0]}x{B.shape[1]}"
                 f"[{int(B.min())}..{int(B.max())}]")
        return (f"ExchangeSchedule(budget={b}, "
                f"slack={self.slack}, round_to={self.round_to})")


def make_gs_exchange_probe(mesh, grid: TileGrid, *,
                           views: Optional[int] = None,
                           per_edge: bool = False):
    """(gaussians, cam) -> exchange-overlap telemetry, REPLICATED — what
    ``ExchangeSchedule.probe_budget`` sizes the edge budget(s) from.

    Each device projects its local splats and counts, per destination
    sub-window, how many overlap (``window_overlap_mask`` — the exchange's
    exact packing predicate, so the count is the exact slot demand).
    ``per_edge=False`` returns the () int32 WORST count over every edge,
    pmax'd over every mesh axis; ``per_edge=True`` returns the full
    (n_part, n_part) int32 demand matrix — row ``s`` is what partition
    ``s`` must send to each destination's sub-window, assembled by a psum
    of disjoint rows over "part" and pmax'd over the remaining axes.
    Either way all hosts agree on the result and land on the identical
    budget.  No collective moves table data — the probe is cheaper than
    one gather step.  A strip that does not divide by the "part" axis is
    padded exactly like the forward (pad sub-windows count nothing).
    """
    ax = _axes(mesh)
    pod, data, model, view = ax
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_model = sizes.get(model, 1)
    n_data = sizes[data]
    n_view = sizes.get(view, 1)
    if views is not None and views % n_view:
        raise ValueError(f"views={views} must divide by the 'view' axis "
                         f"size {n_view}")
    if views is None and n_view > 1:
        raise ValueError(f"mesh has a 'view' axis of size {n_view} but "
                         "views=None; pass views=V")
    T = grid.n_tiles
    assert T % n_model == 0, (T, n_model)
    Tl = T // n_model
    sub = -(-Tl // n_data)                      # ceil: pad, never refuse
    pad = sub * n_data - Tl

    g_spec = Gaussians(
        means=P(pod, data, None), log_scales=P(pod, data, None),
        quats=P(pod, data, None), opacity_logit=P(pod, data),
        colors=P(pod, data, None), active=P(pod, data), owner=P(pod, data),
    )
    vlead = (view,) if views else ()
    cam_spec = Camera(view=P(*vlead, None, None) if views else P(),
                      fx=P(*vlead) if views else P(),
                      fy=P(*vlead) if views else P(),
                      width=P(), height=P())
    reduce_axes = tuple(a for a in (pod, data, model, view) if a)

    def shard_fn(g: Gaussians, cam: Camera):
        if views:
            splats = jax.vmap(lambda c: project(g, c),
                              in_axes=(CAM_VAXES,))(cam)
        else:
            splats = project(g, cam)
        mx = splats.mean2d[..., 0]
        my = splats.mean2d[..., 1]
        rad = jnp.where(splats.valid, splats.radius, 0.0)
        val = splats.valid
        if views:  # fold Vl into the partition axis: (Vl*Pl, Nl)
            fold = lambda x: x.reshape((-1,) + x.shape[2:])
            mx, my, rad, val = fold(mx), fold(my), fold(rad), fold(val)
        base = lax.axis_index(model) * Tl if model is not None else 0
        t0_all = base + jnp.arange(n_data, dtype=jnp.int32) * sub
        hit = window_overlap_mask(mx, my, rad, val, grid,
                                  t0=t0_all, n_local=sub,
                                  t_end=(base + Tl) if pad else None)
        counts = hit.sum(-1, dtype=jnp.int32)    # (n_data, R)
        if per_edge:
            row = counts.max(1)                  # my demand toward each dst
            dm = lax.dynamic_update_slice(
                jnp.zeros((n_data, n_data), jnp.int32),
                row[None, :], (lax.axis_index(data), 0))
            dm = lax.psum(dm, data)
            rest_axes = tuple(a for a in (pod, model, view) if a)
            return lax.pmax(dm, rest_axes) if rest_axes else dm
        m = counts.max()
        return lax.pmax(m, reduce_axes) if reduce_axes else m

    return shard_map(shard_fn, mesh=mesh, in_specs=(g_spec, cam_spec),
                     out_specs=P(), check_rep=False)


@functools.lru_cache(maxsize=32)
def _gs_exchange_probe_jit(mesh, grid: TileGrid, views: Optional[int],
                           per_edge: bool = False):
    return jax.jit(make_gs_exchange_probe(mesh, grid, views=views,
                                          per_edge=per_edge))


def probe_gs_exchange(esched: ExchangeSchedule, mesh, grid: TileGrid,
                      g: Gaussians, cam, *,
                      views: Optional[int] = None, per_edge: bool = False):
    """Probe ``esched`` against the mesh: measure the worst per-edge
    overlap over one or more view batches (max-merged host-side, like
    ``probe_gs_schedule``) and size the edge budget.  ``per_edge=True``
    probes the full (n_part, n_part) demand matrix and sizes a matrix
    budget.  Returns the new budget — identical on every host (pmax'd /
    psum'd-disjoint telemetry)."""
    cam_batches = [cam] if isinstance(cam, Camera) else list(cam)
    probe_fn = _gs_exchange_probe_jit(mesh, grid, views, per_edge)
    if per_edge:
        mx = None
        for cb in cam_batches:
            got = np.asarray(probe_fn(g, cb))
            mx = got if mx is None else np.maximum(mx, got)
    else:
        mx = 0
        for cb in cam_batches:
            mx = max(mx, int(probe_fn(g, cb)))
    ax = _axes(mesh)
    n_data = dict(zip(mesh.axis_names, mesh.devices.shape))[ax.data]
    n_local = g.means.shape[1] // n_data
    return esched.probe_budget(mx, n_local)


# ---------------------------------------------------------------------------
# Distributed train step
# ---------------------------------------------------------------------------


#: sentinel: "no explicit k_tiers argument — resolve from the train cfg"
_FROM_CFG = object()


def make_gs_train_step(mesh, cfg: GSTrainCfg, grid: TileGrid, extent: float,
                       *, impl: str = "auto", views: Optional[int] = None,
                       assign_block: Optional[int] = None,
                       k_tiers=_FROM_CFG,
                       tier_caps: Optional[tuple] = None,
                       return_overflow: bool = False, win_size: int = 7,
                       assign_impl=_FROM_CFG, assign_budget=_FROM_CFG,
                       exchange=_FROM_CFG, exchange_budget=_FROM_CFG):
    """jit'd (gaussians, opt, batch) -> (gaussians, opt, loss).

    Per-partition losses are averaged globally, but gradients never mix
    partitions (each gaussian belongs to exactly one P slice): the paper's
    independent-training semantics inside one SPMD program.

    views=V runs the minibatch-of-views step: batch["gt_tiles"] is
    (V, P*T, 3, th, tw), batch["cam"] carries (V, 4, 4) views, and the loss
    (hence the gradient) averages over the view batch.  On a mesh with a
    "view" axis the batch's leading V dim is sharded over it (see
    make_gs_forward / gs_shardings).

    Rasterization defaults to OCCUPANCY TIERS: ``k_tiers`` left unset pulls
    ``cfg.resolved_k_tiers()`` (the trainer-wide default schedule; set
    ``cfg.dense_k=`` to escape back to dense-K rasterization).  An explicit
    ``k_tiers=None`` forces dense, an explicit tuple pins the ladder.
    ``tier_caps=None`` uses the always-exact strip-sized caps — correct but
    unmeasured; production drives this factory through a
    ``core.tiling.TierSchedule`` (probe -> train -> densify -> re-probe)
    and passes ``(schedule.k_tiers, schedule.tier_caps)``.  cfg.K (or
    cfg.dense_k) is the dense path's assignment depth.

    ``return_overflow=True`` makes the step return
    ``(gaussians, opt, loss, overflow)`` where overflow is a dict of
    globally psum'd () int32 counters — ``"tiles"`` (tiered dropped tiles,
    for ``TierSchedule.note_overflow``), ``"assign"`` (sorted-assignment
    budget truncation, grows ``assign_budget``) and ``"exchange"``
    (sparse-exchange dropped splats, for ``ExchangeSchedule.note_overflow``)
    — the telemetry the ``fit_partitions`` driver consumes, mirroring
    train.make_train_step.  ``win_size`` is the per-tile D-SSIM window
    (see make_gs_forward).

    ``exchange``/``exchange_budget`` (default: from cfg) select the
    sparse-overlap table exchange instead of the full all-gather — see
    make_gs_forward.

    ``cfg.dtype_policy="bf16"`` runs the forward/backward with bf16 wire
    tables (see make_gs_forward); the Adam state, loss and every update
    stay f32 under every policy.

    ``cfg.grad_compress != "none"`` wires optim.compress.compress_grads
    over the per-partition gradient tree (quantise→dequantise with error
    feedback, Seide et al. practice) and CHANGES THE STEP SIGNATURE to
    ``step(g, opt, err, batch) -> (g, opt, err, loss[, overflow])``: the
    error-feedback tree (zeros-like the trainables for "int8"; None for
    the stateless "bf16") is carried by the caller across steps — and
    through checkpoints by ``fit_partitions``.  With the default "none"
    the signature, donation pattern and compiled program are exactly the
    pre-knob ones.
    """
    if k_tiers is _FROM_CFG:
        k_tiers = cfg.resolved_k_tiers()
    if assign_impl is _FROM_CFG:
        assign_impl = cfg.assign_impl
    if assign_budget is _FROM_CFG:
        assign_budget = cfg.assign_budget
    if exchange is _FROM_CFG:
        exchange = cfg.exchange
    if exchange_budget is _FROM_CFG:
        exchange_budget = cfg.exchange_budget
    lrs = group_lrs(cfg, extent)
    g_sh, opt_sh, b_sh = gs_shardings(mesh, views=views)
    fwd = make_gs_forward(mesh, grid, K=cfg.assign_K, impl=impl,
                          lambda_dssim=cfg.lambda_dssim,
                          gather_mode=cfg.gather_mode,
                          strip_budget=cfg.strip_budget, views=views,
                          assign_block=assign_block,
                          k_tiers=k_tiers, tier_caps=tier_caps,
                          return_overflow=return_overflow, win_size=win_size,
                          assign_impl=assign_impl,
                          assign_budget=assign_budget,
                          exchange=exchange, exchange_budget=exchange_budget,
                          dtype_policy=cfg.dtype_policy)

    def loss_fn(tr, g, cam, gt, mask):
        out = fwd(g.with_trainable(tr), cam, gt, mask)
        if return_overflow:
            return out
        z = jnp.zeros((), jnp.int32)
        return out, {"tiles": z, "assign": z, "exchange": z}

    compress = cfg.grad_compress

    def adam(g: Gaussians, opt: GSOptState, grads, loss, overflow):
        s = opt.step + 1
        bc1 = 1.0 - cfg.b1 ** s.astype(jnp.float32)
        bc2 = 1.0 - cfg.b2 ** s.astype(jnp.float32)
        tr = g.trainable()
        new_tr, new_m, new_v = {}, {}, {}
        for k in tr:
            gr = grads[k].astype(jnp.float32)
            m = cfg.b1 * opt.m[k] + (1 - cfg.b1) * gr
            v = cfg.b2 * opt.v[k] + (1 - cfg.b2) * gr * gr
            d = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            new_tr[k] = (tr[k] - lrs[k] * d).astype(tr[k].dtype)
            new_m[k], new_v[k] = m, v
        gnorm = jnp.linalg.norm(grads["means"].astype(jnp.float32), axis=-1)
        new_opt = GSOptState(new_m, new_v, s,
                             opt.grad_accum + gnorm,
                             opt.grad_count + (gnorm > 0))
        return g.with_trainable(new_tr), new_opt, loss, overflow

    def step(g: Gaussians, opt: GSOptState, batch):
        (loss, overflow), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            g.trainable(), g, batch["cam"], batch["gt_tiles"],
            batch["mask_tiles"])
        g, opt, loss, overflow = adam(g, opt, grads, loss, overflow)
        out = (g, opt, loss)
        return out + (overflow,) if return_overflow else out

    def step_compressed(g: Gaussians, opt: GSOptState, err, batch):
        (loss, overflow), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            g.trainable(), g, batch["cam"], batch["gt_tiles"],
            batch["mask_tiles"])
        grads = jax.tree.map(lambda x: x.astype(jnp.float32), grads)
        grads, err, _ = compress_grads(grads, compress, err)
        g, opt, loss, overflow = adam(g, opt, grads, loss, overflow)
        out = (g, opt, err, loss)
        return out + (overflow,) if return_overflow else out

    rep = NamedSharding(mesh, P())
    ov_sh = {"tiles": rep, "assign": rep, "exchange": rep}
    if exchange and exchange_budget is not None \
            and np.ndim(exchange_budget) == 2:
        # matrix budgets add the per-edge counters (replicated matrices)
        ov_sh["exchange_edges"] = rep
        ov_sh["exchange_demand"] = rep
    if compress == "none":
        out_sh = (g_sh, opt_sh, rep) + ((ov_sh,) if return_overflow else ())
        return jax.jit(
            step,
            in_shardings=(g_sh, opt_sh, b_sh),
            out_shardings=out_sh,
            donate_argnums=(0, 1),
        )
    # err tree shards like the Adam moments (same trainables structure);
    # the stateless "bf16" mode carries err=None (an empty pytree) through
    # the same signature so both compressed modes share one calling shape
    err_sh = opt_sh.m if compress == "int8" else None
    out_sh = (g_sh, opt_sh, err_sh, rep) \
        + ((ov_sh,) if return_overflow else ())
    return jax.jit(
        step_compressed,
        in_shardings=(g_sh, opt_sh, err_sh, b_sh),
        out_shardings=out_sh,
        donate_argnums=(0, 1, 2),
    )


# ---------------------------------------------------------------------------
# Dry-run input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------


def gs_state_specs(n_parts: int, n_gaussians: int):
    """Gaussian + opt state ShapeDtypeStructs for the (P, N) batched layout.

    Shapes are GLOBAL (pre-sharding): pair with ``gs_shardings`` to get the
    device layout — leading P sharded over "pod", N over "part"/"data",
    replicated along "model" and "view" (every device needs the full local
    gaussian shard to project its own views/strips).
    """
    Pn, N = n_parts, n_gaussians
    f32 = jnp.float32
    g = Gaussians(
        means=jax.ShapeDtypeStruct((Pn, N, 3), f32),
        log_scales=jax.ShapeDtypeStruct((Pn, N, 3), f32),
        quats=jax.ShapeDtypeStruct((Pn, N, 4), f32),
        opacity_logit=jax.ShapeDtypeStruct((Pn, N), f32),
        colors=jax.ShapeDtypeStruct((Pn, N, 3), f32),
        active=jax.ShapeDtypeStruct((Pn, N), jnp.bool_),
        owner=jax.ShapeDtypeStruct((Pn, N), jnp.int32),
    )
    tr = {k: getattr(g, k) for k in
          ("means", "log_scales", "quats", "opacity_logit", "colors")}
    opt = GSOptState(
        m=dict(tr), v=dict(tr),
        step=jax.ShapeDtypeStruct((), jnp.int32),
        grad_accum=jax.ShapeDtypeStruct((Pn, N), f32),
        grad_count=jax.ShapeDtypeStruct((Pn, N), f32),
    )
    return g, opt


def gs_batch_specs(n_parts: int, grid: TileGrid,
                   views: Optional[int] = None):
    """Batch ShapeDtypeStructs for the flat-tile (P*T, ...) layout.

    Shapes are GLOBAL: with ``views=V`` the leading V axis is what a mesh's
    "view" axis shards (V must divide it) and the flat (P*T,) tile axis is
    what ("pod", "model") shard; without views the V axis is absent.
    cam.view is (V, 4, 4) ("view"-sharded alongside gt/mask), width/height
    stay replicated scalars.
    """
    T = grid.n_tiles
    f32 = jnp.float32
    vlead = (views,) if views else ()
    return {
        "gt_tiles": jax.ShapeDtypeStruct(
            vlead + (n_parts * T, 3, grid.tile_h, grid.tile_w), f32),
        "mask_tiles": jax.ShapeDtypeStruct(
            vlead + (n_parts * T, grid.tile_h, grid.tile_w), jnp.bool_),
        "cam": Camera(
            view=jax.ShapeDtypeStruct(vlead + (4, 4), f32),
            fx=jax.ShapeDtypeStruct(vlead, f32),
            fy=jax.ShapeDtypeStruct(vlead, f32),
            width=jax.ShapeDtypeStruct((), jnp.int32),
            height=jax.ShapeDtypeStruct((), jnp.int32),
        ),
    }


# ---------------------------------------------------------------------------
# Distributed schedule driver (host loop)
# ---------------------------------------------------------------------------


def _tile_view_batches(gts, masks, grid: TileGrid):
    """Per-partition images -> the distributed flat-tile batch layout.

    gts (P, V, H, W, 3), masks (P, V, H, W) bool or None ->
    (gt_tiles (V, P*T, 3, th, tw), mask_tiles (V, P*T, th, tw)) as host
    numpy arrays (sliced per minibatch by the driver).  masks=None means
    "every IMAGE pixel counts" — grid padding rows/columns (a resolution
    that isn't a tile multiple) are still masked OFF, matching the
    single-device full-image loss, which never sees pad pixels."""
    Pn, V = gts.shape[:2]
    tiler = jax.jit(jax.vmap(jax.vmap(partial(tile_image, grid=grid))))
    gt_t = np.asarray(tiler(jnp.asarray(gts)))           # (P, V, T, 3, th, tw)
    gt_t = gt_t.transpose(1, 0, 2, 3, 4, 5).reshape(
        (V, Pn * grid.n_tiles) + gt_t.shape[3:])
    if masks is None:
        masks = jnp.ones((Pn, V) + gts.shape[2:4], jnp.float32)
    mask_t = np.asarray(
        tiler(jnp.asarray(masks)[..., None].astype(jnp.float32)))
    mask_t = (mask_t.transpose(1, 0, 2, 3, 4, 5)[:, :, :, 0]
              .reshape((V, Pn * grid.n_tiles) + mask_t.shape[4:]) > 0.5)
    return gt_t, mask_t


def rebalance_partitions(g: Gaussians, opt: GSOptState, mesh, *,
                         threshold: float = 1.5):
    """Host-side dynamic load rebalance for the sparse exchange: permute
    each partition's rows so LIVE splats spread evenly over the "part"
    shards of the equal-capacity (P, N) stacks.

    Densify/prune is data-dependent, so per-shard live counts drift apart
    over training; under ``exchange=True`` a crowded shard both sends and
    rasterizes more than its peers (the gather path is insensitive — every
    device holds the full table either way).  When the worst shard's live
    count exceeds ``threshold`` x the partition mean, live rows are dealt
    in CONTIGUOUS near-equal blocks across shards (a pure PERMUTATION of
    rows — capacities, shapes and jit caches are untouched; no reshard,
    no recompile).  Contiguous dealing preserves the Morton row order the
    overlap-aware partitioning established (partition.spatial_order):
    each shard stays a compact spatial brick, which is what keeps the
    probed per-edge exchange budgets small — a round-robin deal would
    re-scramble every shard back to ~uniform overlap.  ``threshold=0.0``
    forces the permutation unconditionally (tests).

    Optimizer rows (m/v/grad accumulators) travel with their splats, so
    training is equivalent up to row order: assignment top-k breaks ties by
    row index, so a scene with tie-free scores composites identically and
    the loss trajectory is bit-stable (see tests/test_distributed.py).

    Returns ``(g, opt, moved)`` with host (numpy) leaves when ``moved`` —
    callers re-``device_put`` onto their shardings — or the inputs
    untouched when the skew is under threshold.
    """
    ax = _axes(mesh)
    n_data = dict(zip(mesh.axis_names, mesh.devices.shape))[ax.data]
    gh = jax.device_get(g)
    oh = jax.device_get(opt)
    active = np.asarray(gh.active)
    Pn, N = active.shape
    Nl = N // n_data
    shard_live = active.reshape(Pn, n_data, Nl).sum(-1)
    skew = shard_live.max(-1) / np.maximum(shard_live.mean(-1), 1.0)
    if float(skew.max()) <= threshold:
        return g, opt, False
    # stable live-first order, dealt in contiguous blocks: the live rows
    # (which keep their Morton order) split into n_data near-equal chunks
    # — chunk i fills the front of shard i, dead rows fill the leftover
    # slots.  Every shard gets within one of the same live count, each
    # chunk is a contiguous (spatially compact) run, and equal inputs
    # produce the identical permutation on every host (numpy stable sort,
    # no RNG).
    perm = np.empty((Pn, N), np.int64)
    for p in range(Pn):
        order = np.argsort(~active[p], kind="stable")
        L = int(active[p].sum())
        szs = np.full(n_data, L // n_data, np.int64)
        szs[: L % n_data] += 1
        starts = np.concatenate([[0], np.cumsum(szs)[:-1]])
        dest = np.empty(N, np.int64)
        for i in range(n_data):
            dest[starts[i]: starts[i] + szs[i]] = i * Nl + np.arange(szs[i])
        dest[L:] = np.concatenate(
            [np.arange(i * Nl + szs[i], (i + 1) * Nl)
             for i in range(n_data)])
        perm[p, dest] = order

    def take(x):
        x = np.asarray(x)
        if x.ndim >= 2 and x.shape[:2] == (Pn, N):
            return np.stack([x[p][perm[p]] for p in range(Pn)])
        return x

    return jax.tree.map(take, gh), jax.tree.map(take, oh), True


def fit_partitions(g: Gaussians, cams: Camera, gts, masks, cfg: GSTrainCfg,
                   *, mesh, steps: int, extent: float, key=None,
                   densify_every: int = 0, densify_from: int = 100,
                   grid: Optional[TileGrid] = None,
                   view_batch: Optional[int] = None,
                   schedule: Optional[TierSchedule] = None,
                   impl: str = "auto", win_size: int = 7,
                   rebalance_every: int = 0,
                   rebalance_threshold: float = 1.5,
                   ckpt=None, ckpt_every: int = 0, log_every: int = 0,
                   warm_start=None, densify_cap: Optional[int] = None,
                   exchange_schedule=None):
    """Distributed tier-schedule driver: train every partition of the
    batched (P, N) layout in ONE SPMD program on ``mesh``, running the same
    probe -> train -> densify -> re-probe lifecycle as the single-device
    ``train.fit_partition``.

    g: (P, N, ...) batched Gaussians (host or device); gts (P, V, H, W, 3)
    per-partition GT images; masks (P, V, H, W) bool or None.  Each step
    consumes ``view_batch`` consecutive views (default cfg.view_batch; the
    minibatch is sharded over the mesh's "view" axis, so it must divide by
    that axis' size).  Returns (g, opt, losses) with the state still
    device-sharded per ``gs_shardings``.

    Tier-schedule lifecycle (tiered-by-default; ``cfg.dense_k=`` opts out):
    the schedule is probed through ``probe_gs_schedule`` — occupancy over
    each device's folded (Vl*T,) binning domain, pmax-reduced across the
    mesh so every host lands on the same cap ladder — the step trains with
    its static (k_tiers, tier_caps) and reports the psum'd overflow
    counter, any overflow grows the caps (bounded recompile), and every
    densify event (vmapped over partitions inside jit) re-probes.

    Sparse exchange (``cfg.exchange=True``): the step swaps the table
    all-gather for the budgeted sparse exchange.  The budget comes from
    ``cfg.exchange_budget`` when set (pinned — never re-probed), else from
    an ``ExchangeSchedule`` probed PER EDGE at init (a full (n, n) demand
    matrix whenever the "part" axis has more than one shard, so each
    (src, dst) pair gets its own budget); a starved edge surfaces in the
    psum'd ``"exchange_edges"`` counter and grows geometrically — only
    that edge, bounded recompile, never silent truncation.  The step's
    pmax'd ``"exchange_demand"`` matrix is the IN-STEP probe: the driver
    keeps its running max and resizes budgets after densify via
    ``ExchangeSchedule.ensure`` (demand + cfg.max_new upper-bounds the
    post-densify overlap) with no host probe round-trip; only a rebalance
    — which re-deals rows across shards — still re-probes on the host.
    ``rebalance_every=R`` additionally checks per-shard live-splat skew
    every R steps and deals live rows in contiguous Morton-preserving
    blocks across the "part" shards when it passes
    ``rebalance_threshold`` (see ``rebalance_partitions``; works with or
    without exchange).

    Checkpoint/resume: with ``ckpt`` (a runtime.CheckpointManager) the
    driver restores the newest complete (g, opt) checkpoint, loads the
    TierSchedule state saved alongside it (``extra["schedule"]``) — so a
    resumed run keeps its probed caps instead of re-probing from scratch —
    plus the exchange-budget state (``extra["exchange"]``, same contract:
    restored budgets are NOT re-probed), fast-forwards the densify key
    stream, and continues from that step; ``ckpt_every`` saves (g, opt) +
    schedules periodically and a final checkpoint always lands at
    ``steps``.  ``losses`` covers only the steps this call actually ran.

    Warm start (timeseries): ``warm_start=(state_tree, extra, step)`` is an
    in-memory resume — ``state_tree`` is a ``(g, opt[, err])`` host tree,
    ``extra`` the checkpoint-extra dict whose ``schedule``/``exchange``
    states are loaded (so init probes are SKIPPED, same contract as a disk
    resume), and ``step`` the global step the seed was saved at (the caller
    passes ``steps = step + n`` to run n more).  The int8 error-feedback
    residual is always re-zeroed at the boundary.  A restorable on-disk
    checkpoint takes precedence.  ``densify_cap=`` bounds the LIVE splat
    count per partition during densify (see ``GSTrainCfg.densify_cap``).
    """
    if grid is None:
        grid = TileGrid(cams.width, cams.height, cfg.tile_h, cfg.tile_w)
    if key is None:
        key = jax.random.PRNGKey(0)
    Pn = g.means.shape[0]
    V = gts.shape[1]
    vb = max(1, min(view_batch or cfg.view_batch, V))
    sched = schedule if schedule is not None else cfg.tier_schedule()
    m_dev = folded_tile_count(mesh, grid, Pn, views=vb,
                              exchange=cfg.exchange)
    # exchange_schedule= mirrors schedule=: the caller keeps the handle, so
    # a timeseries driver can carry probed/grown budgets across timesteps
    ex = exchange_schedule if exchange_schedule is not None else (
        ExchangeSchedule(budget=cfg.exchange_budget) if cfg.exchange
        else None)
    ex_pinned = cfg.exchange_budget is not None
    n_data = dict(zip(mesh.axis_names, mesh.devices.shape))[_axes(mesh).data]
    Nl = g.means.shape[1] // n_data
    # per-edge budgets need a real "part" axis (a 1x1 matrix is a scalar)
    ex_per_edge = cfg.exchange and not ex_pinned and n_data > 1

    gt_tiles, mask_tiles = _tile_view_batches(gts, masks, grid)
    g_sh, opt_sh, b_sh = gs_shardings(mesh, views=vb)
    opt = init_opt(g)       # layout-polymorphic: (P, N) accumulators here

    # grad-compress error feedback (optim/compress.py): int8 carries a
    # residual tree shaped like the trainables; "bf16" is stateless (err
    # stays None through the compressed step's uniform signature); "none"
    # keeps the original (g, opt, batch) step untouched
    compress = cfg.grad_compress
    err = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                       g.trainable()) if compress == "int8" else None
    err_sh = opt_sh.m if compress == "int8" else None

    def state_tree(gg, oo, ee):
        # the int8 residual RIDES THE CHECKPOINT (it is step state: dropping
        # it on resume would silently re-inject the accumulated error)
        return (gg, oo, ee) if compress == "int8" else (gg, oo)

    start, losses = 0, []
    if ckpt is not None:
        latest = ckpt.latest_restorable_step()
        if latest is not None:
            # config-compat peek BEFORE the tree restore: a grad_compress
            # mismatch changes the leaf count, and a dtype_policy mismatch
            # must fail loudly, not fork the loss curve silently
            _check_resume_policy(ckpt.manifest_extra(latest), cfg)
            restored, extra = ckpt.restore(latest, state_tree(g, opt, err))
            if compress == "int8":
                g, opt, err = restored
            else:
                g, opt = restored
            if sched is not None and extra.get("schedule"):
                sched.load_state(extra["schedule"])
            if ex is not None and extra.get("exchange"):
                ex.load_state(extra["exchange"])
            start = latest
    if start == 0 and warm_start is not None:
        # warm start = an IN-MEMORY resume: the timeseries driver hands us
        # the previous timestep's merged state + schedule extras, and we
        # take the exact resume path (restored caps/budgets, no init
        # re-probe, densify-key fast-forward below).  An on-disk checkpoint
        # for THIS run wins — it is strictly newer than the warm seed.
        wtree, wextra, wstep = warm_start
        wextra = wextra or {}
        _check_resume_policy(wextra, cfg)
        g, opt = wtree[0], wtree[1]
        # err stays zeros: the int8 error-feedback residual never crosses
        # a timestep boundary (same reset contract as densify/rebalance —
        # the new timestep's field moved under the rows)
        if sched is not None and wextra.get("schedule"):
            sched.load_state(wextra["schedule"])
        if ex is not None and wextra.get("exchange"):
            ex.load_state(wextra["exchange"])
        start = wstep
    # fast-forward the densify key stream consumed before ``start`` so a
    # resumed run splits the same keys as an uninterrupted one
    for i in range(start):
        if densify_every and i >= densify_from \
                and (i + 1) % densify_every == 0:
            key = jax.random.split(key, 1 + Pn)[0]

    g_dev = jax.device_put(g, g_sh)
    opt_dev = jax.device_put(opt, opt_sh)
    err_dev = jax.device_put(err, err_sh) if compress == "int8" else None

    # tile-assignment resolution — the same render.resolve_assignment
    # policy as fit_partition (probe the WHOLE rig's concrete bbox counts
    # for a static sorted budget, or demote "auto" to dense for big-splat
    # scenes), so both drivers land on identical (impl, budget) for the
    # same scene; the probe is a jitted GLOBAL max, identical on every
    # host.  Re-resolved after every densify (radii train).
    assign = {"impl": cfg.assign_impl, "budget": cfg.assign_budget}

    def probe_assign(gg):
        impl, budget = resolve_assignment(gg, cams, grid,
                                          assign_impl=cfg.assign_impl,
                                          assign_budget=cfg.assign_budget)
        assign.update(impl=impl, budget=budget)

    # probe minibatches, shared by the tier probe and the exchange-budget
    # probe: the first one — and, mirroring fit_partition's
    # min(n_views, max(vb, 2))-view probe, a SECOND minibatch when vb == 1
    # (a single-view probe would size caps/budgets from one view only);
    # both probes max-merge the telemetry so the static shapes cover the
    # worst probed minibatch of the step's exact folded domain
    n_probe = 2 if vb < 2 and V > 1 else 1
    if cfg.exchange:
        # per-edge budgets have no worst-edge slack to hide behind: an
        # unprobed view whose overlap pattern differs can starve a single
        # edge.  Probe a few more minibatches (still bounded) — the
        # overflow counter + in-step demand remain the safety net.
        n_probe = max(n_probe, min(-(-V // vb), 4))
    probe_cams = [
        jax.device_put(
            select(cams, jnp.asarray((b * vb + np.arange(vb)) % V)),
            b_sh["cam"])
        for b in range(n_probe)]

    reprobe = None
    if sched is not None:
        def reprobe(gg):
            probe_gs_schedule(sched, mesh, grid, gg, probe_cams, views=vb,
                              assign_impl=assign["impl"],
                              assign_budget=assign["budget"],
                              exchange=cfg.exchange)

    def reprobe_exchange(gg):
        # pinned budgets (explicit cfg.exchange_budget / checkpoint-restored
        # state) are never re-probed — resume keeps its grown budget
        if ex is not None and not ex_pinned:
            probe_gs_exchange(ex, mesh, grid, gg, probe_cams, views=vb,
                              per_edge=ex_per_edge)

    probe_assign(g_dev)
    if sched is not None and sched.tier_caps is None:
        # a resume restored caps: no re-probe
        reprobe(g_dev)
    if ex is not None and ex.budget is None:
        # a resume restored the budget: no re-probe
        probe_gs_exchange(ex, mesh, grid, g_dev, probe_cams, views=vb,
                          per_edge=ex_per_edge)

    opt_vax = GSOptState(m=0, v=0, step=None, grad_accum=0, grad_count=0)
    dcfg = dataclasses.replace(cfg, densify_cap=densify_cap) \
        if densify_cap is not None else cfg
    densify = jax.jit(jax.vmap(
        partial(densify_and_prune, cfg=dcfg, extent=extent),
        in_axes=(0, opt_vax, 0), out_axes=(0, opt_vax)))

    step_cache = {}
    ex_demand = None        # running max of the step's in-step demand probe

    def get_step():
        spec = ((sched.k_tiers, sched.tier_caps) if sched else None,
                assign["impl"], assign["budget"],
                cfg.exchange, ex.budget_key() if ex else None)
        if spec not in step_cache:
            step_cache[spec] = make_gs_train_step(
                mesh, cfg, grid, extent, impl=impl, views=vb,
                k_tiers=sched.k_tiers if sched else None,
                tier_caps=sched.tier_caps if sched else None,
                return_overflow=True, win_size=win_size,
                assign_impl=assign["impl"], assign_budget=assign["budget"],
                exchange=cfg.exchange,
                exchange_budget=ex.budget if ex else None)
        return step_cache[spec]

    def save(step_no):
        tree = jax.tree.map(jax.device_get,
                            state_tree(g_dev, opt_dev, err_dev))
        ckpt.save(step_no, tree,
                  extra={"schedule": sched.state_dict() if sched else None,
                         "exchange": ex.state_dict() if ex else None,
                         "dtype_policy": cfg.dtype_policy,
                         "grad_compress": cfg.grad_compress})

    def reset_err():
        # re-layout events (densify grow/prune, rebalance permutation)
        # invalidate the per-row int8 residuals: rows moved or changed
        # count, so the carried error no longer aligns.  Dropping it is
        # bounded (one quantisation step of error, at rare events) and
        # honest — stale residuals would inject noise into the WRONG rows.
        nonlocal err_dev
        if compress == "int8":
            err_dev = jax.device_put(
                jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                             g_dev.trainable()), err_sh)

    for i in range(start, steps):
        vi = (i * vb + np.arange(vb)) % V
        batch = {
            "gt_tiles": jax.device_put(jnp.asarray(gt_tiles[vi]),
                                       b_sh["gt_tiles"]),
            "mask_tiles": jax.device_put(jnp.asarray(mask_tiles[vi]),
                                         b_sh["mask_tiles"]),
            "cam": jax.device_put(select(cams, jnp.asarray(vi)),
                                  b_sh["cam"]),
        }
        if compress == "none":
            out = get_step()(g_dev, opt_dev, batch)
            g_dev, opt_dev, loss = out[:3]
            ov = out[3]
        else:
            out = get_step()(g_dev, opt_dev, err_dev, batch)
            g_dev, opt_dev, err_dev, loss = out[:4]
            ov = out[4]
        losses.append(float(loss))
        if sched is not None:
            # a non-zero (psum'd) counter grows the caps for the NEXT
            # steps — a one-step blip, never a persistent silent truncation
            sched.note_overflow(ov["tiles"], m_dev)
        if assign["impl"] == "sorted" \
                and int(np.asarray(ov["assign"]).sum()) > 0:
            # radii drifted past the sorted budget's probe slack between
            # densify events: grow it geometrically (same honesty contract)
            assign["budget"] = grow_tile_budget(
                assign["budget"] or DEFAULT_TILE_BUDGET, grid.n_tiles)
        if ex is not None:
            # matrix budgets grow only the starved edges (per-edge psum'd
            # counter); scalar budgets keep the total-count contract
            ex.note_overflow(ov.get("exchange_edges", ov["exchange"]), Nl)
            if "exchange_demand" in ov:
                dm = np.asarray(ov["exchange_demand"])
                ex_demand = dm if ex_demand is None \
                    else np.maximum(ex_demand, dm)
        if densify_every and i >= densify_from \
                and (i + 1) % densify_every == 0:
            ks = jax.random.split(key, 1 + Pn)
            key = ks[0]
            g_dev, opt_dev = densify(g_dev, opt_dev, ks[1:])
            # the vmapped densify jit picks its own output shardings; pin
            # the state back onto the step's (pod, part) layout before the
            # next donating pjit call
            g_dev = jax.device_put(g_dev, g_sh)
            opt_dev = jax.device_put(opt_dev, opt_sh)
            reset_err()  # row count changed: residuals no longer aligned
            probe_assign(g_dev)  # splat sizes shifted: re-size the budget
            if sched is not None:
                reprobe(g_dev)  # occupancy shifted: re-pick tiers/caps
            if ex is not None and not ex_pinned and ex_demand is not None:
                # in-step resize, no host probe round-trip: densify clones
                # at most cfg.max_new rows per partition, so the running
                # per-edge demand + max_new upper-bounds the post-densify
                # overlap on every edge
                ex.ensure(ex_demand + cfg.max_new, Nl)
            else:
                reprobe_exchange(g_dev)  # overlap pattern shifted too
        if rebalance_every and (i + 1) % rebalance_every == 0:
            g_reb, opt_reb, moved = rebalance_partitions(
                g_dev, opt_dev, mesh, threshold=rebalance_threshold)
            if moved:
                g_dev = jax.device_put(g_reb, g_sh)
                opt_dev = jax.device_put(opt_reb, opt_sh)
                reset_err()  # rows permuted across shards
                # rows moved to different shards: the demand history no
                # longer describes any edge — drop it and host-probe once
                ex_demand = None
                reprobe_exchange(g_dev)
        if ckpt is not None and ckpt_every and (i + 1) % ckpt_every == 0 \
                and (i + 1) < steps:
            save(i + 1)
        if log_every and (i + 1) % log_every == 0:
            print(f"  step {i+1:5d}  loss {losses[-1]:.4f}  "
                  f"schedule {sched if sched else 'dense'}")
    if ckpt is not None and steps > start:
        save(steps)
    return g_dev, opt_dev, losses
