"""Mixed-precision dtype policy: bf16 tables, f32 accumulators.

One knob — ``dtype_policy`` ("f32" | "bf16", default "f32") — threaded from
``GSTrainCfg`` through the render/distributed/serving stacks.  Its contract:

  * "f32"   everything stays float32 (bit-identical to the pre-policy
            code: ``cast_tables`` returns its input untouched, and the
            ``astype(float32)`` promotes at the kernel boundary are elided
            by JAX for same-dtype inputs, so the compiled program is the
            exact pre-policy program).
  * "bf16"  STORAGE and WIRE dtypes drop to bfloat16: the gathered /
            exchanged per-splat feature tables (core/distributed.py) and
            the per-tile (T, K, F) kernel feature blocks (core/render.py)
            are cast at the boundary — halving the "part"-axis
            all-gather / ``all_to_all`` payload and the kernel's gather
            volume — while every ACCUMULATOR stays f32: the rasterizer
            promotes its inputs back to f32 at entry
            (kernels/ops.rasterize_tiles) and composites in f32 VREG
            planes, the loss partials, psums and the Adam state never
            leave f32.  bf16 keeps f32's 8-bit exponent, so the cast can
            round (2^-9 relative) but never overflow — there is no loss
            scaling to get wrong, and no silent saturation to count.

The conversion helpers follow the mesh-transformer-jax idiom (SNIPPETS.md
snippet 1): cast at the boundary by *dtype predicate* over a pytree, so
bool masks / int32 ids ride through untouched.

Tolerance ladder (what the per-dtype test matrix pins, see
docs/mixed-precision.md and tests/test_kernel_rasterize.py): f32 parity
pins stay at 1e-6; bf16 parity vs the f32 oracle gets explicit tolerances
derived from the 8-bit mantissa (unit roundoff 2^-9 ~ 2e-3 relative on
every table entry, amplified by the conic quadratic form and the
front-to-back alpha product) — documented next to each assert.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

#: supported dtype policies, in ladder order (f32 is the parity oracle)
POLICIES = ("f32", "bf16")


def check_policy(policy: str) -> str:
    """Validate (and return) a dtype policy; loud on unknown values."""
    if policy not in POLICIES:
        raise ValueError(
            f"unknown dtype_policy {policy!r}; expected one of {POLICIES}")
    return policy


def table_dtype(policy: str):
    """The storage/wire dtype feature tables are held in under ``policy``."""
    check_policy(policy)
    return jnp.bfloat16 if policy == "bf16" else jnp.float32


def cast_tables(tree, policy: str):
    """Cast the float32 leaves of ``tree`` to the policy's storage dtype.

    The one boundary-cast entry point: IDENTITY under "f32" (returns the
    input tree object untouched — no convert ops enter the jaxpr, which is
    what keeps the default policy bit-identical to pre-policy builds).
    Non-f32 leaves (bool validity masks, int32 ids, already-bf16 tables)
    pass through unchanged.
    """
    check_policy(policy)
    if policy == "f32":
        return tree
    return jax.tree.map(
        lambda x: x.astype(jnp.bfloat16) if x.dtype == jnp.float32 else x,
        tree)


def to_f32(tree):
    """Promote bf16 leaves back to f32 (the mesh-transformer-jax ``to_f32``
    idiom): compute-side of the boundary.  Leaves already f32 (or non-float)
    are returned untouched, so this is also identity under the f32 policy."""
    return jax.tree.map(
        lambda x: x.astype(jnp.float32) if x.dtype == jnp.bfloat16 else x,
        tree)
