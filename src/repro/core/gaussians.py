"""Gaussian primitive parameterisation (3D-GS, Kerbl et al. 2023).

A scene is a fixed-capacity buffer of Gaussians with an ``active`` mask —
fixed shapes keep every training step jit-compatible; densify/prune edit the
mask and free slots rather than reallocating (DESIGN.md §3).

Parameterisation (trainable, unconstrained):
  means    (N, 3)      world-space centers
  log_scales (N, 3)    exp() -> per-axis std dev
  quats    (N, 4)      normalised on use -> rotation
  opacity_logit (N,)   sigmoid() -> alpha in (0,1)
  colors   (N, 3)      SH degree-0 (isosurface splats are view-independent;
                       DESIGN.md §8); sigmoid() -> rgb
plus non-trainable:
  active   (N,) bool
  owner    (N,) int32  spatial partition that owns this gaussian (ghosts carry
                       their *source* partition id -> merge dedupe)
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Gaussians(NamedTuple):
    means: jax.Array
    log_scales: jax.Array
    quats: jax.Array
    opacity_logit: jax.Array
    colors: jax.Array
    active: jax.Array
    owner: jax.Array

    @property
    def capacity(self) -> int:
        return self.means.shape[0]

    def trainable(self):
        return {
            "means": self.means,
            "log_scales": self.log_scales,
            "quats": self.quats,
            "opacity_logit": self.opacity_logit,
            "colors": self.colors,
        }

    def with_trainable(self, t):
        return self._replace(
            means=t["means"],
            log_scales=t["log_scales"],
            quats=t["quats"],
            opacity_logit=t["opacity_logit"],
            colors=t["colors"],
        )


def from_points(points, colors=None, *, capacity=None, init_scale=None,
                owner_id=0, opacity=0.6):
    """Initialise one Gaussian per point (paper: isosurface point cloud ->
    initial primitives). init_scale defaults to mean nearest-neighbour-ish
    spacing estimated from the bounding box and point count."""
    n = points.shape[0]
    capacity = capacity or n
    assert capacity >= n
    if init_scale is None:
        bbox = points.max(0) - points.min(0)
        vol = jnp.maximum(jnp.prod(bbox), 1e-12)
        init_scale = (vol / max(n, 1)) ** (1.0 / 3.0)
    pad = capacity - n

    def padded(x, fill=0.0):
        return jnp.concatenate(
            [x, jnp.full((pad,) + x.shape[1:], fill, x.dtype)], axis=0
        ) if pad else x

    means = padded(points.astype(jnp.float32))
    log_scales = jnp.full((capacity, 3), jnp.log(init_scale), jnp.float32)
    quats = jnp.tile(jnp.array([1.0, 0, 0, 0], jnp.float32), (capacity, 1))
    op = jnp.full((capacity,), jnp.log(opacity / (1 - opacity)), jnp.float32)
    if colors is None:
        colors = jnp.full((n, 3), 0.0, jnp.float32)  # sigmoid(0)=0.5 grey
    else:
        colors = jnp.log(jnp.clip(colors, 1e-4, 1 - 1e-4) /
                         (1 - jnp.clip(colors, 1e-4, 1 - 1e-4)))
    colors = padded(colors.astype(jnp.float32))
    active = jnp.concatenate([jnp.ones((n,), bool), jnp.zeros((pad,), bool)])
    owner = jnp.full((capacity,), owner_id, jnp.int32)
    return Gaussians(means, log_scales, quats, op, colors, active, owner)


def quat_to_rotmat(q):
    """(..., 4) normalised-on-use quaternion -> (..., 3, 3)."""
    q = q / jnp.maximum(jnp.linalg.norm(q, axis=-1, keepdims=True), 1e-12)
    w, x, y, z = q[..., 0], q[..., 1], q[..., 2], q[..., 3]
    return jnp.stack(
        [
            jnp.stack([1 - 2 * (y * y + z * z), 2 * (x * y - w * z), 2 * (x * z + w * y)], -1),
            jnp.stack([2 * (x * y + w * z), 1 - 2 * (x * x + z * z), 2 * (y * z - w * x)], -1),
            jnp.stack([2 * (x * z - w * y), 2 * (y * z + w * x), 1 - 2 * (x * x + y * y)], -1),
        ],
        axis=-2,
    )


def covariance3d(log_scales, quats):
    """Sigma = R S S^T R^T, (..., 3, 3)."""
    R = quat_to_rotmat(quats)
    S = jnp.exp(log_scales)
    RS = R * S[..., None, :]
    return RS @ jnp.swapaxes(RS, -1, -2)
