"""Background masks + masked training loss (paper §II steps 4-5).

Each partition renders *its own* data's coverage per camera; the training loss
is evaluated only on covered pixels (plus a small dilation so silhouette
gradients survive).  This is what prevents a partition's model from growing
white "background" splats over pixels that other partitions own — the white
streak artifact of Fig. 2b/4b.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.core import metrics
from repro.core.cameras import Camera
from repro.core.gaussians import Gaussians
from repro.core.render import render
from repro.core.tiling import TileGrid


def dilate_mask(mask, it: int = 2):
    """Binary dilation with a 3x3 structuring element, ``it`` iterations."""
    m = mask.astype(jnp.float32)[None, None]        # (1,1,H,W)
    k = jnp.ones((1, 1, 3, 3), jnp.float32)
    for _ in range(it):
        m = lax.conv_general_dilated(
            m, k, (1, 1), [(1, 1), (1, 1)],
            dimension_numbers=("NCHW", "OIHW", "NCHW"),
        )
        m = jnp.minimum(m, 1.0)
    return m[0, 0] > 0.5


def background_mask(g: Gaussians, cam: Camera, grid: TileGrid, *,
                    K: int = 64, impl: str = "auto",
                    threshold: float = 1.0 / 255.0, dilation: int = 2):
    """Coverage mask of this partition's own (non-ghost is NOT required —
    ghosts are part of the partition's render responsibility) data."""
    out = render(g, cam, grid, K=K, impl=impl, bg=0.0)
    return dilate_mask(out.coverage > threshold, dilation)


def gs_loss(pred_rgb, gt_rgb, mask=None, *, lambda_dssim: float = 0.2):
    """3D-GS loss: (1-l)*L1 + l*D-SSIM, both restricted to masked pixels.

    mask=None reproduces the unmasked baseline (the ablation's broken mode).
    """
    a = pred_rgb.astype(jnp.float32)
    b = gt_rgb.astype(jnp.float32)
    if mask is None:
        l1 = jnp.abs(a - b).mean()
    else:
        m = mask.astype(jnp.float32)[..., None]
        l1 = (jnp.abs(a - b) * m).sum() / jnp.maximum(m.sum() * 3.0, 1.0)
    dss = metrics.d_ssim(a, b, mask=mask)
    return (1.0 - lambda_dssim) * l1 + lambda_dssim * dss


def tile_l1_dssim_loss(pred_tiles, gt_tiles, mask_tiles=None, *,
                       lambda_dssim: float = 0.2, win_size: int = 7):
    """Per-tile loss for the *distributed* path: tiles stay sharded over the
    "model" axis, so SSIM windows are evaluated within each tile (win 7 on
    8x128 tiles; the cross-tile border band is excluded by construction).
    pred/gt: (T, C, th, tw); mask: (T, th, tw) or None.
    """
    a = pred_tiles.astype(jnp.float32)
    b = gt_tiles.astype(jnp.float32)
    if mask_tiles is None:
        m = jnp.ones(a.shape[:1] + a.shape[2:], jnp.float32)
    else:
        m = mask_tiles.astype(jnp.float32)
    mc = m[:, None]
    l1 = (jnp.abs(a - b) * mc).sum() / jnp.maximum(mc.sum() * a.shape[1], 1.0)

    # batched per-tile SSIM: treat tiles as batch, channels as C
    def tile_ssim(x, y, w):
        sm = jax.vmap(
            lambda xi, yi: metrics.ssim_map(
                xi.transpose(1, 2, 0), yi.transpose(1, 2, 0), win_size=win_size
            )
        )(x, y)                                      # (T, th, tw, C)
        ww = w[..., None]
        return (sm * ww).sum() / jnp.maximum(ww.sum() * sm.shape[-1], 1.0)

    dss = (1.0 - tile_ssim(a, b, m)) / 2.0
    return (1.0 - lambda_dssim) * l1 + lambda_dssim * dss
