"""Global reconstruction: merge per-partition splats (paper §II step 6).

Each partition trains on owned + ghost gaussians; at merge time a partition
contributes only gaussians it *owns* (``owner == part_id``) — ghosts are the
neighbour's responsibility, so every source gaussian appears exactly once in
the merged scene.  Densified children inherit their parent's owner, keeping
the dedupe exact under clone/split.
"""

from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.gaussians import Gaussians


def dedupe_mask(g: Gaussians, part_id: int):
    return g.active & (g.owner == part_id)


def merge_partitions(parts: Sequence[Gaussians],
                     part_ids: Sequence[int] = None) -> Gaussians:
    """Concatenate owner-deduped gaussians from every partition.

    Host-level (runs once after training): compacts each partition's buffer
    with numpy boolean indexing, then concatenates.
    """
    if part_ids is None:
        part_ids = range(len(parts))
    fields = {k: [] for k in Gaussians._fields}
    for pid, g in zip(part_ids, parts):
        keep = np.asarray(dedupe_mask(g, pid))
        for k in Gaussians._fields:
            fields[k].append(np.asarray(getattr(g, k))[keep])
    cat = {k: jnp.asarray(np.concatenate(v)) for k, v in fields.items()}
    return Gaussians(**cat)


def merge_padded(parts: Sequence[Gaussians], part_ids: Sequence[int] = None,
                 capacity: int = None) -> Gaussians:
    """Jit-friendly merge: keeps fixed capacity = sum of partition capacities
    (or ``capacity``), deactivating deduped slots instead of compacting.
    Used by the distributed pipeline where shapes must be static."""
    if part_ids is None:
        part_ids = list(range(len(parts)))
    cat = {}
    for k in Gaussians._fields:
        cat[k] = jnp.concatenate([getattr(g, k) for g in parts])
    active = jnp.concatenate(
        [dedupe_mask(g, pid) for g, pid in zip(parts, part_ids)]
    )
    out = Gaussians(**dict(cat, active=active))
    if capacity is not None and capacity != out.capacity:
        assert capacity >= out.capacity
        pad = capacity - out.capacity
        out = Gaussians(*[
            jnp.pad(f, ((0, pad),) + ((0, 0),) * (f.ndim - 1))
            for f in out
        ])
    return out
