"""Image quality metrics: PSNR, SSIM (+ masked variants), D-SSIM loss term.

LPIPS requires a pretrained VGG (unavailable offline) — DESIGN.md §8 documents
the substitution: we report PSNR/SSIM everywhere the paper does and a
gradient-similarity proxy (``grad_sim``) where the paper reports LPIPS.
"""

from __future__ import annotations


import jax.numpy as jnp
from jax import lax


def psnr(a, b, mask=None):
    """a, b: (..., H, W, C) in [0, 1]."""
    se = (a.astype(jnp.float32) - b.astype(jnp.float32)) ** 2
    if mask is None:
        mse = se.mean()
    else:
        m = mask.astype(jnp.float32)[..., None]
        mse = (se * m).sum() / jnp.maximum(m.sum() * se.shape[-1], 1.0)
    return 10.0 * jnp.log10(1.0 / jnp.maximum(mse, 1e-12))


def _gaussian_window(size: int = 11, sigma: float = 1.5):
    x = jnp.arange(size, dtype=jnp.float32) - (size - 1) / 2.0
    g = jnp.exp(-(x**2) / (2 * sigma**2))
    g = g / g.sum()
    return jnp.outer(g, g)


def _filter2d(img, win):
    """img: (H, W, C); win: (k, k) -> same-size 'valid-centred' conv (SAME)."""
    k = win.shape[0]
    x = img.transpose(2, 0, 1)[:, None]                     # (C,1,H,W)
    w = win[None, None]                                     # (1,1,k,k)
    y = lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding=[(k // 2, k // 2)] * 2,
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )
    return y[:, 0].transpose(1, 2, 0)


def ssim_map(a, b, *, win_size: int = 11, sigma: float = 1.5):
    """Per-pixel SSIM map, (H, W, C) inputs in [0,1] -> (H, W, C)."""
    c1, c2 = 0.01**2, 0.03**2
    a = a.astype(jnp.float32)
    b = b.astype(jnp.float32)
    win = _gaussian_window(win_size, sigma)
    mu_a = _filter2d(a, win)
    mu_b = _filter2d(b, win)
    mu_aa, mu_bb, mu_ab = mu_a * mu_a, mu_b * mu_b, mu_a * mu_b
    s_aa = _filter2d(a * a, win) - mu_aa
    s_bb = _filter2d(b * b, win) - mu_bb
    s_ab = _filter2d(a * b, win) - mu_ab
    return ((2 * mu_ab + c1) * (2 * s_ab + c2)) / (
        (mu_aa + mu_bb + c1) * (s_aa + s_bb + c2)
    )


def ssim(a, b, mask=None, **kw):
    m = ssim_map(a, b, **kw)
    if mask is None:
        return m.mean()
    w = mask.astype(jnp.float32)[..., None]
    return (m * w).sum() / jnp.maximum(w.sum() * m.shape[-1], 1.0)


def d_ssim(a, b, mask=None, **kw):
    """3D-GS loss term: (1 - SSIM) / 2."""
    return (1.0 - ssim(a, b, mask=mask, **kw)) / 2.0


def grad_sim(a, b, mask=None):
    """LPIPS stand-in (documented proxy): 1 - cosine similarity of image
    gradients, lower is better, in [0, 2]."""
    def grads(x):
        x = x.astype(jnp.float32).mean(-1)
        gx = x[:, 1:] - x[:, :-1]
        gy = x[1:, :] - x[:-1, :]
        return gx[:-1], gy[:, :-1]

    ax, ay = grads(a)
    bx, by = grads(b)
    if mask is not None:
        m = mask.astype(jnp.float32)[:-1, :-1]
        ax, ay, bx, by = ax * m, ay * m, bx * m, by * m
    num = (ax * bx + ay * by).sum()
    den = jnp.sqrt((ax**2 + ay**2).sum() * (bx**2 + by**2).sum())
    return 1.0 - num / jnp.maximum(den, 1e-12)
