"""Spatial data partitioning with ghost cells (paper §II step 3).

The point cloud is split into ``n`` spatial partitions — one per compute node
(mesh "pod" axis entry) — on a regular grid whose per-axis bin edges are
*quantiles* of the point coordinates, so partitions are load-balanced by point
count even for skewed isosurfaces.  Points within ``ghost_width`` of a
neighbouring partition's boundary are replicated into that neighbour as
*ghost cells*; ghosts keep their source partition id in ``owner`` so the final
merge deduplicates them (core/merge.py).

This is host-level setup code (runs once, before training): plain numpy,
deterministic given (points, n_parts, ghost_width).
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

import numpy as np


def _spread_bits(v: np.ndarray) -> np.ndarray:
    """Spread the low 21 bits of ``v`` (uint64) so consecutive input bits
    land 3 apart — one axis' lane of a 3-D Morton code."""
    v = v.astype(np.uint64) & np.uint64(0x1FFFFF)
    v = (v | (v << np.uint64(32))) & np.uint64(0x1F00000000FFFF)
    v = (v | (v << np.uint64(16))) & np.uint64(0x1F0000FF0000FF)
    v = (v | (v << np.uint64(8))) & np.uint64(0x100F00F00F00F00F)
    v = (v | (v << np.uint64(4))) & np.uint64(0x10C30C30C30C30C3)
    v = (v | (v << np.uint64(2))) & np.uint64(0x1249249249249249)
    return v


def morton_codes(points: np.ndarray, bits: int = 21) -> np.ndarray:
    """(N, 3) -> (N,) uint64 Morton (Z-order) codes.

    Coordinates are quantised per axis to ``bits`` levels over the cloud's
    bounding box and bit-interleaved (x lowest lane).  Sorting by the code
    is a space-filling-curve order: rows adjacent in the sorted sequence
    are adjacent in space, so any contiguous row block is a spatially
    compact brick.  Deterministic given ``points`` (plain numpy, no RNG).
    """
    p = np.asarray(points, np.float64).reshape(-1, 3)
    if len(p) == 0:
        return np.zeros((0,), np.uint64)
    lo = p.min(axis=0)
    span = np.maximum(p.max(axis=0) - lo, 1e-12)
    top = (1 << bits) - 1
    q = np.minimum((p - lo) / span * top, top).astype(np.uint64)
    return (_spread_bits(q[:, 0])
            | (_spread_bits(q[:, 1]) << np.uint64(1))
            | (_spread_bits(q[:, 2]) << np.uint64(2)))


def spatial_order(points: np.ndarray, bits: int = 21) -> np.ndarray:
    """(N, 3) -> (N,) argsort by Morton code (stable): the row order that
    makes equal row blocks spatially compact.

    This is the overlap-aware layout for the sparse splat exchange
    (core/distributed.py): the equal-capacity (P, N) gaussian stacks shard
    their N axis into contiguous row blocks over the mesh "part" axis, so
    Morton-ordering the rows turns each shard into a compact spatial brick
    — its splats project onto few screen-tile sub-windows, and the probed
    per-(src, dst) edge overlap genuinely shrinks as the shard count
    grows (instead of every edge seeing ~uniform overlap from spatially
    scrambled rows).
    """
    return np.argsort(morton_codes(points, bits), kind="stable")


def factor3(n: int) -> Tuple[int, int, int]:
    """Factor n into (nx, ny, nz) as close to cubic as possible."""
    best = (n, 1, 1)
    best_cost = float("inf")
    for a in range(1, n + 1):
        if n % a:
            continue
        m = n // a
        for b in range(1, m + 1):
            if m % b:
                continue
            c = m // b
            cost = max(a, b, c) / min(a, b, c)
            if cost < best_cost:
                best_cost = cost
                best = (a, b, c)
    return best


@dataclasses.dataclass(frozen=True)
class Partitioning:
    n_parts: int
    grid: Tuple[int, int, int]
    edges: Tuple[np.ndarray, np.ndarray, np.ndarray]  # per-axis bin edges
    ghost_width: float

    def cell_of(self, points: np.ndarray) -> np.ndarray:
        """(N, 3) -> (N,) partition id."""
        ids = np.zeros(len(points), np.int64)
        mult = 1
        for ax, (g, e) in enumerate(zip(self.grid, self.edges)):
            ids += np.clip(np.searchsorted(e[1:-1], points[:, ax],
                                           side="right"), 0, g - 1) * mult
            mult *= g
        return ids


@dataclasses.dataclass
class PartitionData:
    """One partition's working set: owned points + ghosts from neighbours."""
    part_id: int
    points: np.ndarray      # (Np, 3) owned + ghost points
    colors: np.ndarray      # (Np, 3)
    owner: np.ndarray       # (Np,) source partition id (== part_id for owned)
    n_owned: int

    @property
    def n_ghost(self) -> int:
        return len(self.points) - self.n_owned


def make_partitioning(points: np.ndarray, n_parts: int,
                      ghost_width: float) -> Partitioning:
    grid = factor3(n_parts)
    edges = []
    for ax, g in enumerate(grid):
        qs = np.quantile(points[:, ax], np.linspace(0, 1, g + 1))
        qs[0] -= 1e-6
        qs[-1] += 1e-6
        # guard against degenerate (duplicate) quantiles
        for i in range(1, len(qs)):
            qs[i] = max(qs[i], qs[i - 1] + 1e-9)
        edges.append(qs)
    return Partitioning(n_parts, grid, tuple(edges), ghost_width)


def _neighbour_cells(part: Partitioning, points: np.ndarray,
                     ids: np.ndarray) -> List[np.ndarray]:
    """For each point, the set of *other* partitions whose slab it is within
    ghost_width of — computed per axis then combined over the <=3^3 offsets."""
    gw = part.ghost_width
    per_axis = []  # per axis: (N,) in {-1, 0, +1} masks for lo/hi proximity
    coords = []
    mult = 1
    for ax, (g, e) in enumerate(zip(part.grid, part.edges)):
        c = np.clip(np.searchsorted(e[1:-1], points[:, ax], side="right"),
                    0, g - 1)
        coords.append(c)
        lo = points[:, ax] - e[c] < gw          # close to lower edge
        hi = e[c + 1] - points[:, ax] < gw      # close to upper edge
        per_axis.append((lo & (c > 0), hi & (c < g - 1)))
        mult *= g
    out = []
    gx, gy, gz = part.grid
    for dx in (-1, 0, 1):
        for dy in (-1, 0, 1):
            for dz in (-1, 0, 1):
                if dx == dy == dz == 0:
                    continue
                m = np.ones(len(points), bool)
                for ax, d in enumerate((dx, dy, dz)):
                    if d == -1:
                        m &= per_axis[ax][0]
                    elif d == 1:
                        m &= per_axis[ax][1]
                if not m.any():
                    continue
                nb = (
                    (coords[0] + dx)
                    + (coords[1] + dy) * gx
                    + (coords[2] + dz) * gx * gy
                )
                out.append((m, nb))
    return out


def partition_points(points: np.ndarray, colors: np.ndarray, n_parts: int,
                     *, ghost_width: float,
                     spatial_sort: bool = True) -> List[PartitionData]:
    """Split a point cloud into n partitions with ghost replication.

    Invariants (tested): every point is *owned* by exactly one partition;
    every ghost lies within ghost_width of its host partition's slab; the
    union of owned points over partitions is the input set.

    ``spatial_sort`` (default on) Morton-orders the rows WITHIN each
    partition's owned block and ghost block (``spatial_order``), so the
    contiguous row blocks the distributed layout shards over the mesh
    "part" axis are spatially compact — the overlap-aware layout the
    sparse splat exchange's per-edge budgets depend on.  It permutes rows
    only inside those two blocks: ownership, ghost membership and the
    owned-then-ghost layout are unchanged.  ``spatial_sort=False`` keeps
    the raw extraction order (spatially scrambled; every exchange edge
    then sees ~uniform overlap).
    """
    points = np.asarray(points, np.float32)
    colors = np.asarray(colors, np.float32)
    part = make_partitioning(points, n_parts, ghost_width)
    ids = part.cell_of(points)

    ghosts: List[List[np.ndarray]] = [[] for _ in range(n_parts)]
    for mask, nb in _neighbour_cells(part, points, ids):
        for p in np.unique(nb[mask]):
            sel = mask & (nb == p)
            ghosts[int(p)].append(np.nonzero(sel)[0])

    out = []
    for p in range(n_parts):
        own = np.nonzero(ids == p)[0]
        gh = (np.unique(np.concatenate(ghosts[p]))
              if ghosts[p] else np.zeros((0,), np.int64))
        gh = gh[ids[gh] != p]                   # never ghost your own points
        if spatial_sort:
            own = own[spatial_order(points[own])]
            gh = gh[spatial_order(points[gh])]
        idx = np.concatenate([own, gh])
        out.append(PartitionData(
            part_id=p,
            points=points[idx],
            colors=colors[idx],
            owner=ids[idx].astype(np.int32),
            n_owned=len(own),
        ))
    return out, part
