"""End-to-end single-host pipeline for the paper's workflow (§II, Fig. 1):

  volume -> isosurface point cloud -> camera rig -> spatial partitioning
  (+ghost cells) -> per-partition GT renders + background masks ->
  independent per-partition training -> merge -> global evaluation.

This is the CPU-tractable mirror of the production path (launch/train.py +
core/distributed.py run the same stages sharded over the mesh); benchmarks
and the quality-ablation tests drive this module.
"""

from __future__ import annotations

import concurrent.futures
import dataclasses
import functools
import time
import warnings
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.gs_datasets import GSDataset, get_gs_dataset
from repro.core import merge as merge_mod
from repro.core import metrics
from repro.core.cameras import Camera, orbital_rig, select
from repro.core.gaussians import Gaussians, from_points
from repro.core.masking import dilate_mask
from repro.core.partition import PartitionData, partition_points
from repro.core.render import (occupancy_probe_jit, render_batch,
                              resolve_assignment)
from repro.core.tiling import (DEFAULT_ASSIGN_IMPL, TierSchedule, TileGrid,
                               auto_tier_caps)
from repro.core.train import GSTrainCfg, fit_partition
from repro.data.isosurface import point_cloud_for


@dataclasses.dataclass
class PipelineCfg:
    dataset: str = "sphere_shell"
    tier: str = "cpu"
    n_parts: int = 2
    resolution: int = 64
    steps: int = 200
    K: int = 48
    use_ghost: bool = True          # ablation switches (Fig. 2/4)
    use_mask: bool = True
    densify_every: int = 0
    train: GSTrainCfg = dataclasses.field(default_factory=GSTrainCfg)
    n_views: Optional[int] = None   # override dataset default
    seed: int = 0


@dataclasses.dataclass
class PipelineResult:
    merged: Gaussians
    parts: List[Gaussians]
    psnr: float
    ssim: float
    grad_sim: float
    train_seconds: List[float]
    n_gaussians: int
    gt_images: np.ndarray
    renders: np.ndarray
    # metrics restricted to partition-boundary pixels — where the paper's
    # Fig. 2 artifacts (gaps/streaks) live; the global numbers dilute them
    boundary_psnr: float = float("nan")
    boundary_ssim: float = float("nan")
    boundary_frac: float = 0.0


def build_scene(ds: GSDataset, seed: int = 0, t: float = 0.0):
    """``t`` extracts the time-evolved field's isosurface (timeseries
    driver); ``t=0`` is bit-identical to the static scene."""
    points, colors = point_cloud_for(ds.volume, ds.n_points, seed=seed, t=t)
    extent = float(np.linalg.norm(points.max(0) - points.min(0)))
    return points, colors, extent


def gt_gaussians(points, colors, *, owner_id: int = 0) -> Gaussians:
    """Ground-truth splats straight from the point cloud (paper Fig. 4a:
    'ground truth image rendered directly from the point cloud')."""
    return from_points(jnp.asarray(points), jnp.asarray(colors),
                       owner_id=owner_id, opacity=0.95)


def init_partition_gaussians(pd: PartitionData, *,
                             capacity: Optional[int] = None,
                             opacity: float = 0.6) -> Gaussians:
    """Trainable splats for one partition's (owned + ghost) points.

    ``capacity`` reserves free slots for densification (padding slots carry
    the partition's own id so densified children merge-dedupe correctly).
    Shared by run_pipeline and the distributed CLI driver
    (launch/train.py --gs), which needs EQUAL capacities across partitions
    for the batched (P, N) mesh layout.
    """
    cap = capacity or len(pd.points)
    g0 = from_points(jnp.asarray(pd.points), jnp.asarray(pd.colors),
                     capacity=cap, opacity=opacity)
    return g0._replace(owner=jnp.concatenate([
        jnp.asarray(pd.owner),
        jnp.full((cap - len(pd.points),), pd.part_id, jnp.int32)]))


def coverage_masks(part_cov, *, threshold: float = 1.0 / 255.0,
                   dilation: int = 2) -> np.ndarray:
    """(V, H, W) coverage renders -> (V, H, W) bool training masks
    (thresholded + dilated; paper §II step 4)."""
    return np.stack([
        np.asarray(dilate_mask(jnp.asarray(c > threshold), dilation))
        for c in part_cov
    ])


@functools.lru_cache(maxsize=64)
def _render_batch_jit(grid: TileGrid, K: int, impl: str, bg: float,
                      coarse: Optional[int],
                      k_tiers: Optional[tuple] = None,
                      tier_caps: Optional[tuple] = None,
                      assign_impl: str = DEFAULT_ASSIGN_IMPL,
                      assign_budget: Optional[int] = None,
                      coarse_budget: Optional[int] = None):
    """Cached jitted render_batch: the seed's render_views rebuilt its jit
    closure per call, recompiling the renderer every time the pipeline
    rendered a new gaussian set (GT, per-partition GT, merged, boundary —
    4+2P compiles per run).  Keying on the static render config (incl. the
    tier schedule and caps — auto_tier_caps rounds caps so nearby scenes
    share an entry — and the assignment impl + EVERY static budget: two
    callers differing only in ``assign_budget`` or ``coarse_budget`` must
    never share a compiled fn, since the budget is baked into the traced
    graph) makes every same-shaped call after the first dispatch-only.
    ``tests/test_batched_render.py::test_render_batch_jit_cache_keys_distinct``
    pins the key."""
    return jax.jit(lambda gg, cc: render_batch(gg, cc, grid, K=K, impl=impl,
                                               bg=bg, coarse=coarse,
                                               coarse_budget=coarse_budget,
                                               k_tiers=k_tiers,
                                               tier_caps=tier_caps,
                                               assign_impl=assign_impl,
                                               assign_budget=assign_budget))


def render_views(g: Gaussians, cams: Camera, grid: TileGrid, *, K: int,
                 impl: str = "auto", bg: float = 1.0, batch: int = 8,
                 coarse: Optional[int] = None,
                 coarse_budget: Optional[int] = None,
                 k_tiers: Optional[tuple] = None,
                 tier_caps: Optional[tuple] = None,
                 schedule: Optional[TierSchedule] = None,
                 assign_impl: str = DEFAULT_ASSIGN_IMPL,
                 assign_budget: Optional[int] = None):
    """-> (V, H, W, 3) rgb + (V, H, W) coverage.

    View-batched: renders ``batch`` views per dispatch through
    ``render_batch`` (one flattened kernel launch per chunk) instead of the
    former one-jit-call-per-view Python loop.  The tail chunk is padded by
    repeating the last view (then cropped) so every dispatch shares one
    traced shape.

    ``k_tiers`` enables occupancy-tiered rasterization; ``K`` is then
    ignored (both the render and the cap-sizing prepass assign at
    k_tiers[-1], since occupancy must be measured at the depth the render
    uses).  When ``tier_caps`` is None the caps are sized from an occupancy
    prepass of the FIRST chunk only (with slack), and the per-chunk
    overflow counter closes the loop: a later chunk that outgrows the caps
    is re-rendered with doubled caps (a bounded number of extra compiles)
    — so every returned image is exact without paying a full-rig prepass.
    Explicit ``tier_caps`` are never altered; if they drop tiles, a
    RuntimeWarning reports the overflow instead of silently returning
    background where geometry was.

    ``schedule=`` plugs a ``core.tiling.TierSchedule`` into the same loop
    (mutually exclusive with k_tiers/tier_caps): its active
    (k_tiers, tier_caps) drive the render — probed here on the first chunk
    when it has no caps yet — and overflow growth is written BACK via
    ``schedule.note_overflow``, so a caller alternating training and
    rendering keeps one consistent, telemetry-updated schedule.

    ``assign_impl``/``assign_budget`` pick the tile-assignment algorithm
    ("auto": sort-based on large grids, dense below the crossover; the
    occupancy probes run with the same impl as the render they size);
    ``coarse_budget`` pins the coarse pre-cull's per-superblock candidate
    budget (``coarse`` mode only — both budgets are part of the cached
    jit's key, so distinct budgets never share a compiled fn).
    When the sorted path is in play and no budget is given,
    ``render.resolve_assignment`` probes the WHOLE rig's concrete bbox
    counts to size the static per-splat budget (with slack, so the
    renders stay exact) — and demotes "auto" back to the dense sweep when
    the probed per-splat overlap is too fat for duplicate-and-sort to win
    (tiling.SORTED_BUDGET_RATIO).
    """
    assign_impl, assign_budget = resolve_assignment(
        g, cams, grid, assign_impl=assign_impl, assign_budget=assign_budget)
    if schedule is not None:
        if k_tiers is not None or tier_caps is not None:
            raise ValueError("pass either schedule= or explicit "
                             "k_tiers/tier_caps, not both")
        if schedule.tier_caps is None:
            vi0 = jnp.clip(jnp.arange(max(1, min(batch, cams.view.shape[0]))),
                           0, cams.view.shape[0] - 1)
            schedule.probe(occupancy_probe_jit(
                grid, schedule.kmax, coarse, assign_impl, assign_budget)(
                g, select(cams, vi0)))
        k_tiers, tier_caps = schedule.k_tiers, schedule.tier_caps
    V = cams.view.shape[0]
    batch = max(1, min(batch, V))
    auto_caps = k_tiers is not None and (tier_caps is None
                                         or schedule is not None)
    if k_tiers is not None:
        k_tiers = tuple(int(k) for k in k_tiers)
        K = k_tiers[-1]      # dead in tiered mode: pin the jit cache key
        if tier_caps is None:
            vi0 = jnp.clip(jnp.arange(batch), 0, V - 1)
            occ0 = occupancy_probe_jit(
                grid, k_tiers[-1], coarse, assign_impl, assign_budget)(
                g, select(cams, vi0))
            tier_caps = auto_tier_caps(occ0, k_tiers, slack=1.25)
        tier_caps = tuple(int(c) for c in tier_caps)
    rfn = _render_batch_jit(grid, K, impl, bg, coarse, k_tiers, tier_caps,
                            assign_impl, assign_budget, coarse_budget)
    rgbs, covs = [], []
    for s in range(0, V, batch):
        take = min(batch, V - s)
        vi = jnp.clip(jnp.arange(s, s + batch), 0, V - 1)
        out = rfn(g, select(cams, vi))
        if k_tiers is not None:
            ov = int(np.asarray(out.overflow).sum())
            while ov and auto_caps:
                # this chunk outgrew the first-chunk caps: double and retry
                # (terminates: caps are clamped at the tile count, where
                # binning provably cannot overflow)
                if schedule is not None:
                    if not schedule.note_overflow(ov, grid.n_tiles):
                        break    # caps already at the clamp: warn below
                    tier_caps = schedule.tier_caps
                else:
                    tier_caps = tuple(min(grid.n_tiles, max(8, 2 * c))
                                      for c in tier_caps)
                rfn = _render_batch_jit(grid, K, impl, bg, coarse, k_tiers,
                                        tier_caps, assign_impl, assign_budget,
                                        coarse_budget)
                out = rfn(g, select(cams, vi))
                ov = int(np.asarray(out.overflow).sum())
            if ov:
                warnings.warn(
                    f"render_views: {ov} tile(s) in views [{s}, {s + take})"
                    f" overflowed the explicit tier_caps={tier_caps} and "
                    "rendered as background; grow the caps (or pass "
                    "tier_caps=None to auto-size)", RuntimeWarning)
        rgbs.append(np.asarray(out.rgb[:take]))
        covs.append(np.asarray(out.coverage[:take]))
    return np.concatenate(rgbs), np.concatenate(covs)


@dataclasses.dataclass
class TimestepData:
    """Everything the distributed driver consumes for one timestep."""
    t: float
    points: np.ndarray
    colors: np.ndarray
    extent: float
    parts: List[PartitionData]
    g0: Gaussians                   # fresh batched (P, N) init: cold-start
    #                                 state AND the restore/warm template
    gts: np.ndarray                 # (P, V, H, W, 3) bg=0 training targets
    masks: Optional[np.ndarray]     # (P, V, H, W) bool, or None


def prepare_timestep(ds: GSDataset, cams: Camera, grid: TileGrid, *,
                     t: float = 0.0, seed: int = 0, n_parts: int = 2,
                     capacity: int, K: int = 48, use_ghost: bool = True,
                     use_mask: bool = True) -> TimestepData:
    """Host-side ingest for ONE timestep of the timeseries driver:
    extraction -> partition (+ghosts) -> fresh equal-capacity (P, N) init
    -> per-partition bg=0 GT renders -> coverage masks.

    This is exactly ``launch/train.py --gs``'s per-scene prep, factored out
    so the streaming loop can run timestep t+1's ingest on a background
    thread (``TimestepPrefetcher``) while timestep t trains on the devices.
    The camera rig and tile grid are FIXED across the series (passed in,
    built once from the t=0 scene), so every timestep's GT tensors share
    one shape; ``capacity`` is likewise series-constant — the warm-started
    state must keep its (P, N) layout — and a partition that outgrows it
    fails loudly rather than silently dropping points.
    """
    points, colors, extent = build_scene(ds, seed, t=t)
    ghost_w = ds.ghost_frac * extent if use_ghost else 0.0
    parts, _ = partition_points(points, colors, n_parts,
                                ghost_width=ghost_w)
    over = [(pd.part_id, len(pd.points)) for pd in parts
            if len(pd.points) > capacity]
    if over:
        raise ValueError(
            f"timestep t={t}: partition(s) {over} exceed the series "
            f"capacity {capacity} — raise the dataset capacity_factor (the "
            "(P, N) layout is fixed across the series by the warm-started "
            "state)")
    g0 = jax.tree.map(lambda *xs: jnp.stack(xs),
                      *[init_partition_gaussians(pd, capacity=capacity)
                        for pd in parts])
    gts, masks = [], []
    for pd in parts:
        part_gt, part_cov = render_views(
            gt_gaussians(pd.points, pd.colors), cams, grid, K=K, bg=0.0)
        gts.append(part_gt)
        if use_mask:
            masks.append(coverage_masks(part_cov))
    return TimestepData(
        t=t, points=points, colors=colors, extent=extent, parts=parts,
        g0=g0, gts=np.stack(gts),
        masks=np.stack(masks) if use_mask else None)


class TimestepPrefetcher:
    """One-slot background ingest: ``submit`` schedules a
    ``prepare_timestep`` call on a single worker thread, ``get`` blocks for
    (and clears) the result.  While timestep t trains on the devices, the
    worker extracts/partitions/renders t+1 on the host — jax dispatch is
    thread-safe, so the GT renders interleave with training dispatches and
    the ingest latency hides behind the training wall-clock.  One slot is
    deliberate: prefetching more than one timestep ahead would hold extra
    (P, V, H, W, 3) GT tensors alive for no latency win."""

    def __init__(self):
        self._pool = concurrent.futures.ThreadPoolExecutor(max_workers=1)
        self._fut = None

    def submit(self, fn, /, *args, **kwargs):
        if self._fut is not None:
            raise RuntimeError("prefetch slot already occupied — get() the "
                               "pending timestep first")
        self._fut = self._pool.submit(fn, *args, **kwargs)

    def get(self):
        if self._fut is None:
            raise RuntimeError("nothing prefetched — submit() first")
        fut, self._fut = self._fut, None
        return fut.result()

    def close(self):
        self._pool.shutdown(wait=True)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False


def run_pipeline(cfg: PipelineCfg) -> PipelineResult:
    ds = get_gs_dataset(cfg.dataset, cfg.tier)
    n_views = cfg.n_views or ds.n_views
    points, colors, extent = build_scene(ds, cfg.seed)
    center = 0.5 * (points.max(0) + points.min(0))
    radius = 1.6 * extent / 2 + 1e-3
    W = H = cfg.resolution
    grid = TileGrid(W, H, cfg.train.tile_h, cfg.train.tile_w)
    cams = orbital_rig(n_views, center, radius, width=W, height=H)

    # global ground truth (full point cloud)
    g_gt = gt_gaussians(points, colors)
    gt_imgs, _ = render_views(g_gt, cams, grid, K=cfg.K)

    # partition (+ optional ghosts)
    ghost_w = ds.ghost_frac * extent if cfg.use_ghost else 0.0
    parts, _ = partition_points(points, colors, cfg.n_parts,
                                ghost_width=ghost_w)

    trained: List[Gaussians] = []
    times: List[float] = []
    key = jax.random.PRNGKey(cfg.seed)
    for pd in parts:
        cap = int(len(pd.points) * ds.capacity_factor) if cfg.densify_every \
            else len(pd.points)
        g0 = init_partition_gaussians(pd, capacity=cap)

        # per-partition GT renders of OWN data (+ghosts) and coverage masks
        part_gt, part_cov = render_views(
            gt_gaussians(pd.points, pd.colors), cams, grid, K=cfg.K)
        masks = coverage_masks(part_cov) if cfg.use_mask else None

        key, sub = jax.random.split(key)
        t0 = time.perf_counter()
        g1, _, _ = fit_partition(
            g0, cams, jnp.asarray(part_gt),
            None if masks is None else jnp.asarray(masks),
            cfg.train, steps=cfg.steps, extent=extent, key=sub,
            densify_every=cfg.densify_every, grid=grid,
        )
        times.append(time.perf_counter() - t0)
        trained.append(g1)

    merged = merge_mod.merge_partitions(trained,
                                        [p.part_id for p in parts])
    renders, _ = render_views(merged, cams, grid, K=cfg.K)

    ps = float(np.mean([
        metrics.psnr(jnp.asarray(renders[v]), jnp.asarray(gt_imgs[v]))
        for v in range(n_views)
    ]))
    ss = float(np.mean([
        metrics.ssim(jnp.asarray(renders[v]), jnp.asarray(gt_imgs[v]))
        for v in range(n_views)
    ]))
    gs = float(np.mean([
        metrics.grad_sim(jnp.asarray(renders[v]), jnp.asarray(gt_imgs[v]))
        for v in range(n_views)
    ]))

    # ---- boundary-region metrics (paper Fig. 2): evaluate on pixels covered
    # by points within the ghost halo of any partition boundary, computed
    # with a FIXED eval halo regardless of cfg.use_ghost so all ablation
    # variants share the same mask
    eval_gw = ds.ghost_frac * extent
    eparts, _ = partition_points(points, colors, cfg.n_parts,
                                 ghost_width=eval_gw)
    bpts = [p.points[p.n_owned:] for p in eparts if p.n_ghost]
    b_ps, b_ss, b_frac = float("nan"), float("nan"), 0.0
    if bpts:
        bpts = np.concatenate(bpts)
        _, bcov = render_views(
            gt_gaussians(bpts, np.zeros_like(bpts)), cams, grid, K=cfg.K)
        # tight mask: substantial boundary coverage only (no dilation —
        # CPU-tier splats are already several pixels wide)
        bmasks = np.stack([np.asarray(c) > 0.5 for c in bcov])
        b_frac = float(bmasks.mean())
        if bmasks.any():
            b_ps = float(np.mean([
                metrics.psnr(jnp.asarray(renders[v]), jnp.asarray(gt_imgs[v]),
                             jnp.asarray(bmasks[v]))
                for v in range(n_views)]))
            b_ss = float(np.mean([
                metrics.ssim(jnp.asarray(renders[v]), jnp.asarray(gt_imgs[v]),
                             jnp.asarray(bmasks[v]))
                for v in range(n_views)]))

    return PipelineResult(
        merged=merged, parts=trained, psnr=ps, ssim=ss, grad_sim=gs,
        train_seconds=times, n_gaussians=int(merged.active.sum()),
        gt_images=gt_imgs, renders=renders,
        boundary_psnr=b_ps, boundary_ssim=b_ss, boundary_frac=b_frac,
    )
