"""EWA projection of 3D Gaussians to screen space (Zwicker EWA splatting, as
used by 3D-GS) + frustum culling.

Output per gaussian: 2D mean (pixels), 2D covariance (2x2 via [a,b,c] packed),
depth, rgb, alpha, valid flag.  This "projected splat" table is the small
representation that Grendel-style parallelism all-gathers between the
gaussian-parallel and pixel-parallel stages (DESIGN.md §3).

Batch-polymorphic: gaussian fields may carry arbitrary leading dims (the
distributed pipeline batches a partition axis P in front of N).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.cameras import Camera
from repro.core.gaussians import Gaussians, covariance3d

# anti-aliasing dilation as in 3D-GS reference (0.3 px)
COV2D_DILATE = 0.3


class Splats2D(NamedTuple):
    mean2d: jax.Array     # (..., 2) pixel coords
    cov2d: jax.Array      # (..., 3) packed [a, b, c] of [[a, b], [b, c]]
    depth: jax.Array      # (...,)
    rgb: jax.Array        # (..., 3) in [0,1]
    alpha: jax.Array      # (...,)
    radius: jax.Array     # (...,) conservative pixel radius
    valid: jax.Array      # (...,) bool


def project(g: Gaussians, cam: Camera, *, near: float = 0.05,
            alpha_min: float = 1.0 / 255.0) -> Splats2D:
    """Project all gaussians for one camera. Fully vectorised over leading dims."""
    R = cam.view[:3, :3]
    t = cam.view[:3, 3]
    p_cam = g.means @ R.T + t                     # (..., 3), camera looks +z
    x = p_cam[..., 0]
    y = p_cam[..., 1]
    z = p_cam[..., 2]
    zc = jnp.maximum(z, near)
    u = cam.fx * x / zc + cam.cx
    v = cam.fy * y / zc + cam.cy

    # Jacobian of perspective projection (EWA affine approximation)
    zero = jnp.zeros_like(zc)
    J = jnp.stack(
        [
            jnp.stack([cam.fx / zc, zero, -cam.fx * x / (zc * zc)], -1),
            jnp.stack([zero, cam.fy / zc, -cam.fy * y / (zc * zc)], -1),
        ],
        axis=-2,
    )                                             # (..., 2, 3)
    cov3 = covariance3d(g.log_scales, g.quats)    # (..., 3, 3)
    T = J @ R                                     # (..., 2, 3)
    cov2 = T @ cov3 @ jnp.swapaxes(T, -1, -2)     # (..., 2, 2)
    a = cov2[..., 0, 0] + COV2D_DILATE
    b = cov2[..., 0, 1]
    c = cov2[..., 1, 1] + COV2D_DILATE

    det = a * c - b * b
    mid = 0.5 * (a + c)
    lam1 = mid + jnp.sqrt(jnp.maximum(mid * mid - det, 1e-9))
    radius = jnp.ceil(3.0 * jnp.sqrt(jnp.maximum(lam1, 1e-9)))

    alpha = jax.nn.sigmoid(g.opacity_logit)
    rgb = jax.nn.sigmoid(g.colors)

    inside = (
        (z > near)
        & (u + radius > 0) & (u - radius < cam.width)
        & (v + radius > 0) & (v - radius < cam.height)
    )
    valid = inside & g.active & (alpha > alpha_min) & (det > 1e-12)
    return Splats2D(
        mean2d=jnp.stack([u, v], -1),
        cov2d=jnp.stack([a, b, c], -1),
        depth=z,
        rgb=rgb,
        alpha=alpha,
        radius=radius,
        valid=valid,
    )
