"""Single-device render path: project -> tile-assign -> gather -> kernel ->
untile -> composite.  This is the building block for the trainer, merge, and
ground-truth generation; the multi-device variant (sharding constraints at
each stage) lives in core/distributed.py.

Two rasterizer dispatch modes:

  dense (K=)        every tile carries the same static top-K list — one
                    kernel launch over all T tiles.
  tiered (k_tiers=) tiles are binned by occupancy into K-tiers (e.g.
                    K in {16, 64, 256}); each non-empty tier gets its own
                    launch at its own K, and tier outputs scatter back into
                    the full tile image.  Sparse/background tiles stop
                    paying the dense-K gather+compute, heavy tiles stop
                    truncating at a too-small K.  Exact vs dense at
                    K = k_tiers[-1] whenever the static tier capacities
                    cover the occupancy histogram (see
                    core.tiling.bin_tiles_by_occupancy).
"""

from __future__ import annotations

import functools
from typing import NamedTuple, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.cameras import CAM_VAXES, Camera, select
from repro.core.dtypes import cast_tables
from repro.core.gaussians import Gaussians
from repro.core.projection import project
from repro.core.tiling import (
    DEFAULT_ASSIGN_IMPL,
    NEG,
    SORTED_MIN_TILES,
    TileGrid,
    assign_tiles,
    auto_tier_caps,
    auto_tile_budget,
    bin_tiles_by_occupancy,
    gather_features_at,
    gather_tile_features,
    resolve_assign_impl,
    splat_features,
    splat_tile_counts,
    tile_occupancy,
    tile_origins,
    untile_image,
)
from repro.kernels import rasterize_tiles
from repro.kernels.ops import rasterize_tiles_batched, rasterize_tiles_tiered


class RenderOut(NamedTuple):
    rgb: jax.Array        # (H, W, 3) or (V, H, W, 3), background-composited
    coverage: jax.Array   # (H, W) / (V, H, W) alpha coverage in [0, 1]
    #: tiered renders only: tiles dropped because every tier cap from their
    #: desired tier upward was full (0 when caps cover the scene; scalar, or
    #: (V,) for batched renders).  None on the dense path.
    overflow: Optional[jax.Array] = None
    #: tile-ASSIGNMENT budget counter (scalar; (V,) for batched renders):
    #: bbox candidate slots dropped past the sorted path's static
    #: ``assign_budget`` (coarse pre-cull drops count here too).  Always 0
    #: on the dense sweep.  Separate from ``overflow`` (tier capacities) so
    #: drivers can grow the right static knob — see
    #: ``tiling.grow_tile_budget`` / ``TierSchedule.note_overflow``.
    assign_overflow: Optional[jax.Array] = None


def _gather_feats(g: Gaussians, cam: Camera, grid: TileGrid, *, K: int,
                  coarse: Optional[int], coarse_budget: Optional[int],
                  block: int = 4096,
                  assign_impl: str = DEFAULT_ASSIGN_IMPL,
                  assign_budget: Optional[int] = None,
                  dtype_policy: str = "f32"):
    """Shared first half of the render: project -> tile-assign (indices
    stop-gradiented: discrete assignment) -> per-tile feature gather.

    -> (tile_feats (T, K, FEAT_DIM), idx (T, K), score (T, K),
    assign_ov () int32 assignment-budget drop counter).

    ``dtype_policy="bf16"`` casts the gathered (T, K, F) feature block to
    bf16 at this boundary (halving the kernel's feature footprint; the
    rasterizer promotes back to f32 at entry and accumulates in f32 —
    core.dtypes contract).  Projection and tile ASSIGNMENT stay f32 under
    every policy: assignment is index bookkeeping, not payload, and
    keeping it exact means the bf16 image differs from the f32 oracle only
    by input rounding — never by a swapped splat list."""
    splats = project(g, cam)
    idx, score, assign_ov = assign_tiles(
        splats, grid, K=K, block=block, coarse=coarse,
        coarse_budget=coarse_budget, impl=assign_impl,
        tile_budget=assign_budget, return_overflow=True)
    idx = lax.stop_gradient(idx)
    score = lax.stop_gradient(score)
    feats = cast_tables(gather_tile_features(splats, idx, score),
                        dtype_policy)
    return feats, idx, score, assign_ov


def _composite(img, bg):
    """(..., H, W, 4) kernel output -> RenderOut over a solid background."""
    cov = img[..., 3]
    rgb = img[..., :3] + (1.0 - cov[..., None]) * bg
    return RenderOut(rgb=rgb, coverage=cov)


# ---------------------------------------------------------------------------
# Tiered (variable-K) dispatch
# ---------------------------------------------------------------------------


def _tiered_tiles(feat, idx, score, grid: TileGrid, *, k_tiers, tier_caps,
                  impl: str):
    """Tier-compact a flat (T, Kmax) assignment and rasterize per tier.

    feat (N, F) differentiable feature table; idx/score (T, Kmax) static
    assignment (already stop-gradiented).  -> (tiles (T, 4, th, tw), plan).
    Each tier's tables are compacted to its static cap with K_i columns —
    the gather volume shrinks together with the kernel work.
    """
    T = grid.n_tiles
    plan = bin_tiles_by_occupancy(tile_occupancy(score), k_tiers, tier_caps)
    origins = tile_origins(grid)
    tier_feats, tier_origins = [], []
    for k, ids in zip(k_tiers, plan.tile_ids):
        idx_k = jnp.take(idx[:, :k], ids, axis=0, mode="fill", fill_value=0)
        sc_k = jnp.take(score[:, :k], ids, axis=0, mode="fill",
                        fill_value=NEG)
        tier_feats.append(gather_features_at(feat, idx_k, sc_k))
        tier_origins.append(jnp.take(origins, ids, axis=0, mode="fill",
                                     fill_value=0.0))
    tiles = rasterize_tiles_tiered(tier_feats, tier_origins, plan.tile_ids,
                                   T, tile_h=grid.tile_h, tile_w=grid.tile_w,
                                   impl=impl)
    return tiles, plan


def _tiered_tiles_batched(feat, idx, score, grid: TileGrid, *, k_tiers,
                          tier_caps, impl: str):
    """View-batched tiered dispatch: bin each view's tiles independently
    (shared static caps), then ONE launch per tier over the flattened
    (V * cap_i,) tier tables — the tiered analogue of
    rasterize_tiles_batched's (V*T,) flattening.

    feat (V, N, F); idx/score (V, T, Kmax) -> (tiles (V, T, 4, th, tw),
    plan with per-view counts/overflow)."""
    V, T = score.shape[0], grid.n_tiles
    M = V * T
    plan = jax.vmap(
        lambda o: bin_tiles_by_occupancy(o, k_tiers, tier_caps)
    )(tile_occupancy(score))
    origins = tile_origins(grid)
    offs = jnp.arange(V, dtype=jnp.int32)[:, None] * T

    def take_rows(arr, ids, fill):
        f = lambda a, i: jnp.take(a, i, axis=0, mode="fill", fill_value=fill)
        return jax.vmap(f)(arr, ids)

    tier_feats, tier_origins, flat_ids = [], [], []
    for k, ids in zip(k_tiers, plan.tile_ids):       # ids (V, cap_i)
        cap = ids.shape[1]
        idx_k = take_rows(idx[:, :, :k], ids, 0)     # (V, cap, k)
        sc_k = take_rows(score[:, :, :k], ids, NEG)
        tf = jax.vmap(gather_features_at)(feat, idx_k, sc_k)
        og = jax.vmap(lambda i: jnp.take(origins, i, axis=0, mode="fill",
                                         fill_value=0.0))(ids)
        tier_feats.append(tf.reshape((V * cap,) + tf.shape[2:]))
        tier_origins.append(og.reshape(V * cap, 2))
        flat_ids.append(jnp.where(ids < T, ids + offs, M).reshape(-1))
    tiles = rasterize_tiles_tiered(tier_feats, tier_origins, flat_ids, M,
                                   tile_h=grid.tile_h, tile_w=grid.tile_w,
                                   impl=impl)
    return tiles.reshape(V, T, 4, grid.tile_h, grid.tile_w), plan


def _resolve_tiers(k_tiers, tier_caps, score):
    """Static (k_tiers, tier_caps) tuples; caps auto-sized from concrete
    occupancy when not given (raises under jit — pass static caps there)."""
    k_tiers = tuple(int(k) for k in k_tiers)
    if tier_caps is None:
        tier_caps = auto_tier_caps(tile_occupancy(score), k_tiers)
    return k_tiers, tuple(int(c) for c in tier_caps)


# ---------------------------------------------------------------------------
# Public render entry points
# ---------------------------------------------------------------------------


def render_tiles(g: Gaussians, cam: Camera, grid: TileGrid, *, K: int = 64,
                 impl: str = "auto", coarse: Optional[int] = None,
                 coarse_budget: Optional[int] = None,
                 k_tiers: Optional[Sequence[int]] = None,
                 tier_caps: Optional[Sequence[int]] = None,
                 assign_impl: str = DEFAULT_ASSIGN_IMPL,
                 assign_budget: Optional[int] = None,
                 dtype_policy: str = "f32"):
    """-> (tiles (T, 4, th, tw), idx (T, K'), score (T, K')).

    Differentiable w.r.t. gaussians (tile index lists are stop-gradiented:
    discrete assignment).  With ``k_tiers`` the assignment runs at
    K' = k_tiers[-1] and the kernel dispatch is tiered (one launch per
    non-empty tier); ``K`` is ignored in that mode.  ``assign_impl``
    selects the tile-assignment algorithm ("auto" default: the sort-based
    scatter on large grids, the dense O(T*N) sweep below the measured
    crossover; "dense"/"sorted" pin one — see core.tiling.assign_tiles)
    and ``assign_budget`` the sorted path's static per-splat tile budget."""
    if k_tiers is None:
        feats, idx, score, _ = _gather_feats(g, cam, grid, K=K, coarse=coarse,
                                             coarse_budget=coarse_budget,
                                             assign_impl=assign_impl,
                                             assign_budget=assign_budget,
                                             dtype_policy=dtype_policy)
        tiles = rasterize_tiles(
            feats, tile_origins(grid),
            tile_h=grid.tile_h, tile_w=grid.tile_w, impl=impl,
        )
        return tiles, idx, score
    tiles, idx, score, _, _ = _render_tiles_tiered(
        g, cam, grid, impl=impl, coarse=coarse, coarse_budget=coarse_budget,
        k_tiers=k_tiers, tier_caps=tier_caps, assign_impl=assign_impl,
        assign_budget=assign_budget, dtype_policy=dtype_policy)
    return tiles, idx, score


def _render_tiles_tiered(g, cam, grid, *, impl, coarse, coarse_budget,
                         k_tiers, tier_caps,
                         assign_impl: str = DEFAULT_ASSIGN_IMPL,
                         assign_budget: Optional[int] = None,
                         dtype_policy: str = "f32"):
    splats = project(g, cam)
    idx, score, assign_ov = assign_tiles(
        splats, grid, K=tuple(k_tiers)[-1],
        coarse=coarse, coarse_budget=coarse_budget,
        impl=assign_impl, tile_budget=assign_budget, return_overflow=True)
    idx = lax.stop_gradient(idx)
    score = lax.stop_gradient(score)
    k_tiers, tier_caps = _resolve_tiers(k_tiers, tier_caps, score)
    # bf16 policy casts the (N, F) feature TABLE (not the per-tier gathers):
    # the tier compaction then moves half the bytes too, matching the
    # distributed path's cast-before-collective placement
    feat = cast_tables(splat_features(splats), dtype_policy)
    tiles, plan = _tiered_tiles(feat, idx, score, grid,
                                k_tiers=k_tiers, tier_caps=tier_caps,
                                impl=impl)
    return tiles, idx, score, plan, assign_ov


def render(g: Gaussians, cam: Camera, grid: TileGrid, *, K: int = 64,
           impl: str = "auto", bg: float = 1.0,
           coarse: Optional[int] = None,
           coarse_budget: Optional[int] = None,
           k_tiers: Optional[Sequence[int]] = None,
           tier_caps: Optional[Sequence[int]] = None,
           assign_impl: str = DEFAULT_ASSIGN_IMPL,
           assign_budget: Optional[int] = None,
           dtype_policy: str = "f32") -> RenderOut:
    """Full-image render with background composite (paper bg is white).

    ``dtype_policy="bf16"`` stores the kernel feature tables in bf16
    (compositing still accumulates f32 — see core.dtypes); "f32" (default)
    is bit-identical to builds that predate the knob.

    ``k_tiers=(16, 64, 256)``-style schedules switch to occupancy-tiered
    rasterization (K is then ignored; K' = k_tiers[-1] bounds per-tile
    depth).  ``tier_caps`` are the static per-tier tile capacities — leave
    None outside jit to auto-size from this scene, pass explicit caps under
    jit.  The returned RenderOut.overflow counts tiles dropped past the top
    tier's cap (0 == the tiered image is exact vs dense at K').

    ``assign_impl``/``assign_budget`` pick the tile-assignment algorithm
    ("auto": sort-based scatter on large grids, dense sweep below the
    crossover; both bit-identical whenever the sorted path's budget covers
    the scene; see core.tiling.assign_tiles)."""
    if k_tiers is None:
        feats, idx, score, assign_ov = _gather_feats(
            g, cam, grid, K=K, coarse=coarse, coarse_budget=coarse_budget,
            assign_impl=assign_impl, assign_budget=assign_budget,
            dtype_policy=dtype_policy)
        tiles = rasterize_tiles(feats, tile_origins(grid),
                                tile_h=grid.tile_h, tile_w=grid.tile_w,
                                impl=impl)
        out = _composite(untile_image(tiles, grid), bg)
        return out._replace(assign_overflow=assign_ov)
    tiles, _, _, plan, assign_ov = _render_tiles_tiered(
        g, cam, grid, impl=impl, coarse=coarse, coarse_budget=coarse_budget,
        k_tiers=k_tiers, tier_caps=tier_caps, assign_impl=assign_impl,
        assign_budget=assign_budget, dtype_policy=dtype_policy)
    out = _composite(untile_image(tiles, grid), bg)
    return out._replace(overflow=plan.overflow, assign_overflow=assign_ov)


def render_batch(g: Gaussians, cams: Camera, grid: TileGrid, *, K: int = 64,
                 impl: str = "auto", bg: float = 1.0,
                 coarse: Optional[int] = None,
                 coarse_budget: Optional[int] = None,
                 assign_block: Optional[int] = None,
                 k_tiers: Optional[Sequence[int]] = None,
                 tier_caps: Optional[Sequence[int]] = None,
                 assign_impl: str = DEFAULT_ASSIGN_IMPL,
                 assign_budget: Optional[int] = None,
                 dtype_policy: str = "f32") -> RenderOut:
    """View-batched render: cams carries a leading V axis on view/fx/fy.

    Projection -> tile assignment -> feature gather are vmapped over the
    view axis, then the Pallas/ref kernel runs ONE flattened (V*T,) grid
    launch instead of V dispatches (the per-view Python loop this replaces).
    Returns rgb (V, H, W, 3) and coverage (V, H, W); matches V sequential
    ``render`` calls to float-associativity tolerance.  Differentiable
    w.r.t. gaussians (the trainer's minibatch-of-views step drives this).

    ``k_tiers`` switches the kernel dispatch to occupancy tiers: each view
    bins its own tiles (shared static ``tier_caps``, which must cover the
    worst view — auto-sized outside jit), and each tier gets one flattened
    (V * cap_i,) launch.  RenderOut.overflow is then (V,) dropped-tile
    counts (all-zero == exact vs the dense path at K = k_tiers[-1]).

    assign_block bounds the tile-assignment sweep's temporaries; under vmap
    those are V-fold, so the auto default shrinks the single-view block by
    V (floored at 1024) to keep the peak footprint roughly view-count
    independent.  ``assign_impl``/``assign_budget`` select the assignment
    algorithm per view (see ``render``); the sorted default ignores
    ``assign_block``/``coarse``.
    """
    V = cams.view.shape[0]
    block = assign_block or max(1024, 4096 // max(V, 1))

    if k_tiers is None:
        def gather_one(cam: Camera):
            out = _gather_feats(g, cam, grid, K=K, coarse=coarse,
                                coarse_budget=coarse_budget, block=block,
                                assign_impl=assign_impl,
                                assign_budget=assign_budget,
                                dtype_policy=dtype_policy)
            return out[0], out[3]

        feats, assign_ov = jax.vmap(
            gather_one, in_axes=(CAM_VAXES,))(cams)            # (V,T,K,F)
        tiles = rasterize_tiles_batched(
            feats, tile_origins(grid),
            tile_h=grid.tile_h, tile_w=grid.tile_w, impl=impl,
        )                                                      # (V, T, 4, ...)
        img = jax.vmap(lambda t: untile_image(t, grid))(tiles)  # (V, H, W, 4)
        return _composite(img, bg)._replace(assign_overflow=assign_ov)

    Kmax = tuple(k_tiers)[-1]

    def gather_one_tiered(cam: Camera):
        splats = project(g, cam)
        idx, score, assign_ov = assign_tiles(
            splats, grid, K=Kmax, block=block,
            coarse=coarse, coarse_budget=coarse_budget,
            impl=assign_impl, tile_budget=assign_budget,
            return_overflow=True)
        return (cast_tables(splat_features(splats), dtype_policy),
                lax.stop_gradient(idx),
                lax.stop_gradient(score), assign_ov)

    feat, idx, score, assign_ov = jax.vmap(
        gather_one_tiered, in_axes=(CAM_VAXES,))(cams)
    k_tiers, tier_caps = _resolve_tiers(k_tiers, tier_caps, score)
    tiles, plan = _tiered_tiles_batched(feat, idx, score, grid,
                                        k_tiers=k_tiers, tier_caps=tier_caps,
                                        impl=impl)
    img = jax.vmap(lambda t: untile_image(t, grid))(tiles)
    return _composite(img, bg)._replace(overflow=plan.overflow,
                                        assign_overflow=assign_ov)


# ---------------------------------------------------------------------------
# Cache-aware entry points (serving): assignment tables as first-class values
# ---------------------------------------------------------------------------


def render_batch_tables(g: Gaussians, cams: Camera, grid: TileGrid,
                        idx, score, *, impl: str = "auto",
                        bg: float = 1.0,
                        dtype_policy: str = "f32") -> RenderOut:
    """View-batched render from a PRECOMPUTED assignment table.

    ``idx``/``score`` (V, T, K) are the tables ``assign_tables_jit``
    extracts (already depth-sorted, NEG marking empty slots).  Projection
    still runs per view — it feeds the differentiable feature gather — but
    ``assign_tiles`` is skipped entirely; the kernel work is the same
    flattened (V*T,) launch as ``render_batch``.

    This is the serving cache's render path for hits AND misses (a miss
    extracts a fresh table first, then renders through here), which is
    what makes a cache hit bit-identical to the cold miss that populated
    it: both render the same table through the same program.  K is the
    table's trailing dim — ``tiling.slice_table`` serves lower ladder
    rungs from one cached Kmax table.
    """
    feat = jax.vmap(lambda cam: splat_features(project(g, cam)),
                    in_axes=(CAM_VAXES,))(cams)               # (V, N, F)
    feat = cast_tables(feat, dtype_policy)   # bf16 storage under the policy
    idx = lax.stop_gradient(idx)
    score = lax.stop_gradient(score)
    tile_feats = jax.vmap(gather_features_at)(feat, idx, score)
    tiles = rasterize_tiles_batched(
        tile_feats, tile_origins(grid),
        tile_h=grid.tile_h, tile_w=grid.tile_w, impl=impl)
    img = jax.vmap(lambda t: untile_image(t, grid))(tiles)
    return _composite(img, bg)


@functools.lru_cache(maxsize=64)
def render_tables_jit(grid: TileGrid, impl: str, bg: float,
                      dtype_policy: str = "f32"):
    """Cached jitted ``render_batch_tables`` closure, keyed on the static
    render config — INCLUDING the dtype policy, so an f32 and a bf16
    server can never share a compiled program; V / N / table-K variation
    retraces inside the one jit.  The serving batcher's hot path — every
    coalesced request batch dispatches through here with tables from the
    pose-bucket cache."""
    return jax.jit(lambda gg, cc, idx, score: render_batch_tables(
        gg, cc, grid, idx, score, impl=impl, bg=bg,
        dtype_policy=dtype_policy))


@functools.lru_cache(maxsize=64)
def assign_tables_jit(grid: TileGrid, K: int,
                      coarse: Optional[int] = None,
                      assign_impl: str = DEFAULT_ASSIGN_IMPL,
                      assign_budget: Optional[int] = None):
    """Cached jitted assignment-TABLE extraction: ``(g, cams) ->
    (idx (V, T, K), score (V, T, K), assign_ov (V,))``.

    The serving cache's MISS path: extract the per-view (T, K) tables
    once, persist them host-side keyed on the quantized pose bucket
    (``tiling.quantize_pose``), and render every later hit through
    ``render_batch_tables`` without re-assigning.  Keyed on the full
    static assignment config — impl AND budget — so two callers with
    different budgets can never share a compiled table extractor
    (the same contract ``pipeline._render_batch_jit`` keys)."""
    def tables(gg, cc):
        block = max(1024, 4096 // max(cc.view.shape[0], 1))

        def one(cam: Camera):
            splats = project(gg, cam)
            idx, score, ov = assign_tiles(
                splats, grid, K=K, block=block, coarse=coarse,
                impl=assign_impl, tile_budget=assign_budget,
                return_overflow=True)
            return idx, score, ov

        return jax.vmap(one, in_axes=(CAM_VAXES,))(cc)
    return jax.jit(tables)


@functools.lru_cache(maxsize=64)
def tile_count_probe_jit(grid: TileGrid):
    """Cached jitted sorted-budget probe: (gaussians, cams) -> () int32 max
    per-splat bbox tile count over the view batch (gaussian fields may
    carry extra leading dims — the distributed (P, N) layout works too).
    Host layers feed the fetched value to ``tiling.auto_tile_budget`` and
    ``tiling.resolve_assign_impl`` to pick a static sorted-path budget —
    or to demote "auto" back to the dense sweep for big-splat scenes.  A
    jitted global reduction, so every host of a mesh sees the same value.
    """
    def probe(gg, cc):
        one = lambda c: splat_tile_counts(project(gg, c), grid).max()
        return jax.vmap(one, in_axes=(CAM_VAXES,))(cc).max()
    return jax.jit(probe)


def max_tile_count(g: Gaussians, cams: Camera, grid: TileGrid, *,
                   chunk: int = 8) -> int:
    """Host-side max per-splat bbox tile count over a WHOLE camera rig,
    probed in fixed-shape chunks of ``chunk`` views (tail chunks repeat
    the last view) so every rig size shares a handful of compiles and the
    peak probe footprint stays bounded."""
    V = cams.view.shape[0]
    best = 0
    for s in range(0, V, chunk):
        vi = jnp.clip(jnp.arange(s, s + chunk), 0, V - 1)
        best = max(best,
                   int(tile_count_probe_jit(grid)(g, select(cams, vi))))
    return best


def resolve_assignment(g: Gaussians, cams: Camera, grid: TileGrid, *,
                       assign_impl: str = DEFAULT_ASSIGN_IMPL,
                       assign_budget: Optional[int] = None):
    """Host-side resolution of the tile-assignment knobs -> a concrete
    ``(impl, budget)`` pair ready for a jitted render/train step.

    The one shared probe-and-resolve policy for every host loop
    (pipeline.render_views, train.fit_partition,
    distributed.fit_partitions): when the sorted path is in play
    ("sorted" pinned, or "auto" on a >= SORTED_MIN_TILES grid) and no
    budget was given, measure the max per-splat bbox tile count over the
    WHOLE rig (not just the first minibatch — a later close-up view must
    not outgrow the budget silently) and size a static budget with slack
    via ``tiling.auto_tile_budget``; then let
    ``tiling.resolve_assign_impl`` decide, demoting "auto" back to the
    always-exact dense sweep when the probed/explicit budget is too fat
    for duplicate-and-sort to win.  Callers re-resolve after every
    densify (radii are trained parameters).  Works on sharded (P, N)
    gaussians: the probe is a jitted global max, identical on every host.
    """
    candidate = (assign_impl == "sorted"
                 or (assign_impl == "auto"
                     and grid.n_tiles >= SORTED_MIN_TILES))
    if assign_budget is None and candidate:
        assign_budget = auto_tile_budget(max_tile_count(g, cams, grid),
                                         grid.n_tiles)
    impl = resolve_assign_impl(assign_impl, grid.n_tiles, assign_budget)
    return impl, (assign_budget if impl == "sorted" else None)


@functools.lru_cache(maxsize=64)
def occupancy_probe_jit(grid: TileGrid, K: int, coarse: Optional[int] = None,
                        assign_impl: str = DEFAULT_ASSIGN_IMPL,
                        assign_budget: Optional[int] = None):
    """Cached jitted ``view_occupancy`` closure — the standard occupancy
    probe for tier-cap sizing (``TierSchedule.probe`` input).  Shared by
    pipeline.render_views and train.fit_partition so the same (grid, K,
    coarse, assign_impl, assign_budget) probe compiles once.  The probe
    must use the same assignment impl/budget as the step it sizes caps for
    (occupancy is exact either way when nothing overflows, but budgets
    truncate consistently only within one impl)."""
    return jax.jit(lambda gg, cc: view_occupancy(
        gg, cc, grid, K=K, coarse=coarse, assign_impl=assign_impl,
        assign_budget=assign_budget))


def view_occupancy(g: Gaussians, cams: Camera, grid: TileGrid, *, K: int,
                   coarse: Optional[int] = None,
                   coarse_budget: Optional[int] = None,
                   assign_block: Optional[int] = None,
                   assign_impl: str = DEFAULT_ASSIGN_IMPL,
                   assign_budget: Optional[int] = None):
    """(V, T) int32 per-view tile occupancy at assignment depth K.

    The cheap prepass pipeline.render_views uses to auto-size static tier
    caps once per gaussian set before entering the cached tiered jit.
    assign_block defaults to the same V-shrunk block as render_batch so the
    vmapped sweep's temporaries stay view-count independent; callers with
    many views should additionally chunk the view axis (render_views does)."""
    V = cams.view.shape[0]
    block = assign_block or max(1024, 4096 // max(V, 1))

    def one(cam: Camera):
        splats = project(g, cam)
        _, score = assign_tiles(splats, grid, K=K, block=block,
                                coarse=coarse, coarse_budget=coarse_budget,
                                impl=assign_impl, tile_budget=assign_budget)
        return tile_occupancy(score)

    return jax.vmap(one, in_axes=(CAM_VAXES,))(cams)
