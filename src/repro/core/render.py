"""Single-device render path: project -> tile-assign -> gather -> kernel ->
untile -> composite.  This is the building block for the trainer, merge, and
ground-truth generation; the multi-device variant (sharding constraints at
each stage) lives in core/distributed.py.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.cameras import CAM_VAXES, Camera
from repro.core.gaussians import Gaussians
from repro.core.projection import project
from repro.core.tiling import (
    TileGrid,
    assign_tiles,
    gather_tile_features,
    tile_origins,
    untile_image,
)
from repro.kernels import rasterize_tiles
from repro.kernels.ops import rasterize_tiles_batched


class RenderOut(NamedTuple):
    rgb: jax.Array        # (H, W, 3), background-composited
    coverage: jax.Array   # (H, W) alpha coverage in [0, 1]


def _gather_feats(g: Gaussians, cam: Camera, grid: TileGrid, *, K: int,
                  coarse: Optional[int], coarse_budget: Optional[int],
                  block: int = 4096):
    """Shared first half of the render: project -> tile-assign (indices
    stop-gradiented: discrete assignment) -> per-tile feature gather."""
    splats = project(g, cam)
    idx, score = assign_tiles(splats, grid, K=K, block=block, coarse=coarse,
                              coarse_budget=coarse_budget)
    idx = lax.stop_gradient(idx)
    score = lax.stop_gradient(score)
    return gather_tile_features(splats, idx, score), idx, score


def _composite(img, bg):
    """(..., H, W, 4) kernel output -> RenderOut over a solid background."""
    cov = img[..., 3]
    rgb = img[..., :3] + (1.0 - cov[..., None]) * bg
    return RenderOut(rgb=rgb, coverage=cov)


def render_tiles(g: Gaussians, cam: Camera, grid: TileGrid, *, K: int = 64,
                 impl: str = "auto", coarse: Optional[int] = None,
                 coarse_budget: Optional[int] = None):
    """-> (tiles (T, 4, th, tw), idx, score). Differentiable w.r.t. gaussians
    (tile index lists are stop-gradiented: discrete assignment)."""
    feats, idx, score = _gather_feats(g, cam, grid, K=K, coarse=coarse,
                                      coarse_budget=coarse_budget)
    tiles = rasterize_tiles(
        feats, tile_origins(grid),
        tile_h=grid.tile_h, tile_w=grid.tile_w, impl=impl,
    )
    return tiles, idx, score


def render(g: Gaussians, cam: Camera, grid: TileGrid, *, K: int = 64,
           impl: str = "auto", bg: float = 1.0,
           coarse: Optional[int] = None,
           coarse_budget: Optional[int] = None) -> RenderOut:
    """Full-image render with background composite (paper bg is white)."""
    tiles, _, _ = render_tiles(g, cam, grid, K=K, impl=impl, coarse=coarse,
                               coarse_budget=coarse_budget)
    return _composite(untile_image(tiles, grid), bg)


def render_batch(g: Gaussians, cams: Camera, grid: TileGrid, *, K: int = 64,
                 impl: str = "auto", bg: float = 1.0,
                 coarse: Optional[int] = None,
                 coarse_budget: Optional[int] = None,
                 assign_block: Optional[int] = None) -> RenderOut:
    """View-batched render: cams carries a leading V axis on view/fx/fy.

    Projection -> tile assignment -> feature gather are vmapped over the
    view axis, then the Pallas/ref kernel runs ONE flattened (V*T,) grid
    launch instead of V dispatches (the per-view Python loop this replaces).
    Returns rgb (V, H, W, 3) and coverage (V, H, W); matches V sequential
    ``render`` calls to float-associativity tolerance.  Differentiable
    w.r.t. gaussians (the trainer's minibatch-of-views step drives this).

    assign_block bounds the tile-assignment sweep's temporaries; under vmap
    those are V-fold, so the auto default shrinks the single-view block by
    V (floored at 1024) to keep the peak footprint roughly view-count
    independent.
    """
    V = cams.view.shape[0]
    block = assign_block or max(1024, 4096 // max(V, 1))

    def gather_one(cam: Camera):
        return _gather_feats(g, cam, grid, K=K, coarse=coarse,
                             coarse_budget=coarse_budget, block=block)[0]

    feats = jax.vmap(gather_one, in_axes=(CAM_VAXES,))(cams)   # (V, T, K, F)
    tiles = rasterize_tiles_batched(
        feats, tile_origins(grid),
        tile_h=grid.tile_h, tile_w=grid.tile_w, impl=impl,
    )                                                          # (V, T, 4, ...)
    img = jax.vmap(lambda t: untile_image(t, grid))(tiles)     # (V, H, W, 4)
    return _composite(img, bg)
