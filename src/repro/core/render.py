"""Single-device render path: project -> tile-assign -> gather -> kernel ->
untile -> composite.  This is the building block for the trainer, merge, and
ground-truth generation; the multi-device variant (sharding constraints at
each stage) lives in core/distributed.py.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.cameras import Camera
from repro.core.gaussians import Gaussians
from repro.core.projection import project
from repro.core.tiling import (
    TileGrid,
    assign_tiles,
    gather_tile_features,
    tile_origins,
    untile_image,
)
from repro.kernels import rasterize_tiles


class RenderOut(NamedTuple):
    rgb: jax.Array        # (H, W, 3), background-composited
    coverage: jax.Array   # (H, W) alpha coverage in [0, 1]


def render_tiles(g: Gaussians, cam: Camera, grid: TileGrid, *, K: int = 64,
                 impl: str = "auto"):
    """-> (tiles (T, 4, th, tw), idx, score). Differentiable w.r.t. gaussians
    (tile index lists are stop-gradiented: discrete assignment)."""
    splats = project(g, cam)
    idx, score = assign_tiles(splats, grid, K=K)
    idx = lax.stop_gradient(idx)
    score = lax.stop_gradient(score)
    feats = gather_tile_features(splats, idx, score)
    tiles = rasterize_tiles(
        feats, tile_origins(grid),
        tile_h=grid.tile_h, tile_w=grid.tile_w, impl=impl,
    )
    return tiles, idx, score


def render(g: Gaussians, cam: Camera, grid: TileGrid, *, K: int = 64,
           impl: str = "auto", bg: float = 1.0) -> RenderOut:
    """Full-image render with background composite (paper bg is white)."""
    tiles, _, _ = render_tiles(g, cam, grid, K=K, impl=impl)
    img = untile_image(tiles, grid)                 # (H, W, 4)
    cov = img[..., 3]
    rgb = img[..., :3] + (1.0 - cov[..., None]) * bg
    return RenderOut(rgb=rgb, coverage=cov)
