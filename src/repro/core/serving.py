"""GS render serving: a batched request-queue server over a merged model.

The training side of the paper ends at "merge splats for global rendering";
this module is the read path that makes the merged model answer camera
requests at production rates (ROADMAP north star).  One server holds ONE
merged gaussian set and turns a stream of camera requests into batched
renders:

  submit(cam) -> bounded queue -> flush() coalesces pending requests into
  view-batched dispatches (the V axis of render_batch is the batching
  axis) -> per-request RenderResult, in submission order.

Three serving mechanisms ride on the batcher:

  pose-bucket assignment cache
      Each request's pose is snapped to a quantized bucket
      (``tiling.quantize_pose``) and the per-view (T, K) assignment table
      is cached host-side under that bucket key.  A hit skips
      ``assign_tiles`` entirely — the render becomes project -> gather ->
      rasterize from the cached table (``render.render_batch_tables``)
      and is BIT-IDENTICAL to the cold miss that populated the entry
      (both render the canonical bucket pose through the same program).
      LRU eviction under a static entry budget; evictions and inserts
      dropped by a zero budget are counted, never silent.

  LOD ladder
      Opacity/scale-pruned variants of the merged model, built once at
      load time by ranking live splats by screen impact (dedupe_mask-style
      boolean compaction; the smallest rung optionally capped
      GeoGaussian-style).  Requests select a rung by camera distance —
      deterministic and monotone (``select_rung``).

  load shedding
      Under queue pressure (pending >= shed_at) requests are still served
      — never dropped — but at a lower rung of the serving K-ladder
      (``TierSchedule`` owns the ladder; the shed render slices the cached
      Kmax table down to the shed K via ``tiling.slice_table``).  Shed
      requests and over-cap rejections are counted.

Telemetry follows the PR-6 honesty contract: every budget that can drop
or degrade work has a counter (``hits/misses/evictions/cache_overflow/
shed/rejected`` plus the render-side ``tiles``/``assign`` overflow keys),
and a zero counter is the machine-checked statement that nothing was
dropped.  Contract suite: tests/test_serving.py; CLI: launch/serve_gs.py.
"""

from __future__ import annotations

import dataclasses
import os
from collections import OrderedDict
from typing import Dict, List, Optional, Sequence, Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.cameras import Camera, select
from repro.core.gaussians import Gaussians
from repro.core.render import assign_tables_jit, render_tables_jit
from repro.core.tiling import (DEFAULT_ASSIGN_IMPL, POSE_BINS, TierSchedule,
                               TileGrid, grow_tile_budget, quantize_pose,
                               slice_table)


class QueueFullError(RuntimeError):
    """Raised by ``submit`` when the bounded queue is at capacity; the
    rejection is counted in telemetry["rejected"] before raising (the
    never-silent half of the shedding contract)."""


# ---------------------------------------------------------------------------
# LOD ladder: impact-ranked pruning masks + compaction
# ---------------------------------------------------------------------------


def splat_impact(g: Gaussians) -> np.ndarray:
    """(N,) float64 screen-impact score for LOD ranking: opacity x mean
    squared scale (~ the splat's expected pixel footprint x its alpha).
    Inactive rows score -inf so they can never outrank a live splat."""
    active = np.asarray(g.active)
    alpha = 1.0 / (1.0 + np.exp(-np.asarray(g.opacity_logit, np.float64)))
    area = np.exp(2.0 * np.asarray(g.log_scales, np.float64)).mean(-1)
    return np.where(active, alpha * area, -np.inf)


def lod_keep_mask(g: Gaussians, frac: float,
                  cap: Optional[int] = None) -> np.ndarray:
    """(N,) bool keep mask: the top ``ceil(frac * n_live)`` live splats by
    ``splat_impact`` (optionally capped at ``cap`` rows — the
    GeoGaussian-style floor for the smallest rung).  Deterministic: stable
    argsort, ties broken by row index; frac=1.0 keeps every live row."""
    active = np.asarray(g.active)
    n_live = int(active.sum())
    n_keep = min(n_live, int(np.ceil(float(frac) * n_live)))
    if cap is not None:
        n_keep = min(n_keep, int(cap))
    order = np.argsort(-splat_impact(g), kind="stable")
    keep = np.zeros(active.shape[0], bool)
    keep[order[:n_keep]] = True
    return keep & active


def compact(g: Gaussians, keep: np.ndarray, *,
            round_to: int = 256) -> Gaussians:
    """dedupe_mask-style boolean compaction of ``keep`` rows into a fresh
    buffer whose capacity rounds up to ``round_to`` (pad rows inactive) so
    nearby rung sizes share jit traces.  Row order is preserved."""
    n = int(np.asarray(keep).sum())
    cap = max(round_to, -(-n // round_to) * round_to)
    fields = {}
    for name in Gaussians._fields:
        a = np.asarray(getattr(g, name))[np.asarray(keep)]
        pad = ((0, cap - n),) + ((0, 0),) * (a.ndim - 1)
        fields[name] = jnp.asarray(np.pad(a, pad))   # bool pad -> False
    return Gaussians(**fields)


def build_lod_ladder(g: Gaussians, fracs: Sequence[float], *,
                     cap: Optional[int] = None,
                     round_to: int = 256) -> List[Gaussians]:
    """One compacted model per rung: rung 0 keeps ``fracs[0]`` (normally
    1.0 — the full merged model), later rungs keep less; only the LAST
    (coarsest) rung is additionally capped at ``cap`` rows."""
    rungs = []
    for i, frac in enumerate(fracs):
        rung_cap = cap if i == len(fracs) - 1 else None
        rungs.append(compact(g, lod_keep_mask(g, frac, rung_cap),
                             round_to=round_to))
    return rungs


def camera_eye(view) -> np.ndarray:
    """(4,4) world->camera matrix -> (3,) world-space camera position
    (view = [R | t] with t = -R @ eye, so eye = -R.T @ t)."""
    v = np.asarray(view, np.float64)
    return -v[:3, :3].T @ v[:3, 3]


def camera_distance(view, center) -> float:
    """Distance from the camera eye to the scene center — the LOD
    selection coordinate."""
    return float(np.linalg.norm(camera_eye(view)
                                - np.asarray(center, np.float64)))


def select_rung(distance: float, thresholds: Sequence[float]) -> int:
    """LOD rung for a camera distance: the number of ladder thresholds the
    camera sits beyond.  Deterministic and monotone non-decreasing in
    ``distance`` by construction (thresholds must be ascending)."""
    rung = 0
    for t in thresholds:
        if distance > float(t):
            rung += 1
    return rung


# ---------------------------------------------------------------------------
# Server
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ServeCfg:
    """Static serving configuration (hashable: jit cache keys derive from
    its fields).  The ladder/caching/shedding knobs all follow the honesty
    contract — each one's effect is visible in the telemetry dict."""
    K: int = 64                       # assignment depth (cached table K)
    k_ladder: Tuple[int, ...] = ()    # serving K ladder; () = auto from K
    impl: str = "auto"
    bg: float = 1.0
    max_batch: int = 8                # views per coalesced dispatch
    queue_cap: int = 64               # bounded queue capacity
    shed_at: Optional[int] = None     # pending depth that starts shedding
                                      # (default: queue_cap // 2)
    shed_rung: int = 0                # ladder rung served under pressure
    cache_entries: int = 64           # pose-bucket cache LRU budget
    pose_bins: float = POSE_BINS      # quantization (buckets per unit)
    lod_fracs: Tuple[float, ...] = (1.0, 0.4)   # keep-fraction per rung
    lod_cap: Optional[int] = None     # cap on the coarsest rung's rows
    lod_dists: Tuple[float, ...] = ()  # rung thresholds; () = auto
    lod_round_to: int = 256
    assign_impl: str = DEFAULT_ASSIGN_IMPL
    assign_budget: Optional[int] = None
    dtype_policy: str = "f32"         # "bf16" halves the cached (T, K)
                                      # tables; compositing stays f32
                                      # (core.dtypes contract)

    def __post_init__(self):
        from repro.core.dtypes import check_policy
        check_policy(self.dtype_policy)

    def resolved_ladder(self) -> Tuple[int, ...]:
        """Serving K ladder, ascending, topped by ``K`` (the GSTrainCfg
        "auto" tier idiom): shed renders pick a lower rung, full-quality
        renders use the top."""
        if self.k_ladder:
            ks = tuple(int(k) for k in self.k_ladder)
            if ks != tuple(sorted(ks)) or ks[-1] != self.K:
                raise ValueError(f"k_ladder must ascend to K={self.K}: {ks}")
            return ks
        return tuple(sorted({max(1, self.K // 8), max(1, self.K // 2),
                             self.K}))


@dataclasses.dataclass
class RenderResult:
    """One served request: images + the serving decisions that shaped them
    (rung/K/hit/shed are the observable halves of the LOD, cache and
    shedding contracts the suite pins)."""
    request_id: int
    rgb: np.ndarray          # (H, W, 3)
    coverage: np.ndarray     # (H, W)
    rung: int                # LOD rung served
    K: int                   # per-tile depth rendered (< ladder top == shed)
    cache_hit: bool
    shed: bool


@dataclasses.dataclass
class _Request:
    rid: int
    cam: Camera              # canonical (bucket-snapped) single-view camera
    key: tuple               # pose bucket key
    rung: int
    k: int
    shed: bool
    hit: bool


def _pad_pow2(n: int, cap: int) -> int:
    """Next power-of-two batch size <= cap: bounded trace count per config
    (log2(max_batch)+1) without render_views' fixed full-batch padding —
    a lone request must not pay an 8-view dispatch."""
    p = 1
    while p < n:
        p *= 2
    return min(p, cap)


class GSRenderServer:
    """One merged model, served.  Synchronous core (submit/flush), so tests
    and CI drive it deterministically; a transport layer would own threads.

    ``g`` is the merged model (``merge.merge_partitions`` output or a
    restored merged checkpoint — see ``from_checkpoint``); ``center`` /
    ``radius`` anchor the LOD distance ladder (probed from the live means
    when omitted)."""

    def __init__(self, g: Gaussians, grid: TileGrid,
                 cfg: Optional[ServeCfg] = None, *, center=None,
                 radius: Optional[float] = None):
        self.cfg = cfg = cfg or ServeCfg()
        self.grid = grid
        # TierSchedule owns the serving K ladder (the same cap machinery
        # the trainer grows); shedding serves schedule.k_tiers[shed_rung],
        # full quality serves schedule.kmax == cfg.K.
        self.schedule = TierSchedule(cfg.resolved_ladder())
        if not (0 <= cfg.shed_rung < len(self.schedule.k_tiers)):
            raise ValueError(f"shed_rung {cfg.shed_rung} outside ladder "
                             f"{self.schedule.k_tiers}")

        live = np.asarray(g.active)
        means = np.asarray(g.means, np.float64)[live]
        if center is None:
            center = 0.5 * (means.max(0) + means.min(0)) if len(means) \
                else np.zeros(3)
        self.center = np.asarray(center, np.float64)
        if radius is None:
            radius = float(np.linalg.norm(means - self.center, axis=-1).max()) \
                if len(means) else 1.0
        self.radius = float(radius)

        self.ladder = build_lod_ladder(g, cfg.lod_fracs, cap=cfg.lod_cap,
                                       round_to=cfg.lod_round_to)
        n_thresh = len(cfg.lod_fracs) - 1
        if cfg.lod_dists:
            if len(cfg.lod_dists) != n_thresh:
                raise ValueError(
                    f"lod_dists needs {n_thresh} thresholds for "
                    f"{len(cfg.lod_fracs)} rungs, got {len(cfg.lod_dists)}")
            self.lod_dists = tuple(float(d) for d in cfg.lod_dists)
        else:
            # auto ladder: rung i+1 beyond ~4x the scene radius, doubling
            # per rung — orbit-distance cameras stay on the full model
            self.lod_dists = tuple(self.radius * 4.0 * (2.0 ** i)
                                   for i in range(n_thresh))

        # per-rung assignment impl/budget, re-resolved on assign overflow
        # (grow_tile_budget) so a starved budget is counted AND repaired
        self._assign: List[Tuple[str, Optional[int]]] = [
            (cfg.assign_impl, cfg.assign_budget) for _ in self.ladder]
        self._cache: "OrderedDict[tuple, Tuple[np.ndarray, np.ndarray]]" = \
            OrderedDict()
        self._queue: List[_Request] = []
        self._next_rid = 0
        self._telemetry: Dict[str, int] = {
            "requests": 0, "batches": 0, "hits": 0, "misses": 0,
            "evictions": 0, "cache_overflow": 0, "shed": 0, "rejected": 0,
            "tiles": 0, "assign": 0,
        }

    # -- checkpoint loading -------------------------------------------------

    #: subdirectory of a ``launch/train.py --gs`` checkpoint tree holding
    #: the merged-model checkpoint (written after merge, alongside the
    #: per-partition ``partitions/`` tree)
    MERGED_SUBDIR = "merged"

    @classmethod
    def from_checkpoint(cls, ckpt_dir: str,
                        cfg: Optional[ServeCfg] = None, **overrides):
        """Load the merged checkpoint a ``launch/train.py --gs`` run wrote
        under ``<ckpt_dir>/merged`` and build a server around it ->
        ``(server, extra)``.  The template is shape-free
        (``checkpoint.unshaped_like``): the merged capacity is a training
        outcome the serving process cannot know ahead of the restore.
        ``extra["scene"]`` (center/radius/resolution/tile shape) anchors
        the grid and the LOD ladder; cfg.K defaults to the training K.
        ``overrides`` are ServeCfg field replacements applied over the
        meta-defaulted cfg (CLI idiom; mutually exclusive with ``cfg``)."""
        from repro.runtime.checkpoint import (CheckpointManager,
                                              dequantize_cold, unshaped_like)
        if cfg is not None and overrides:
            raise ValueError("pass cfg= or field overrides, not both")
        mgr = CheckpointManager(os.path.join(ckpt_dir, cls.MERGED_SUBDIR),
                                keep=2)
        g, extra, step = mgr.restore_latest(unshaped_like(Gaussians))
        # int8 cold-attribute checkpoints (launch/train.py --ckpt-quantize)
        # ride their per-tensor scales on extra["quant"]; no-op otherwise
        if step is not None:
            g = dequantize_cold(g, extra.get("quant"))
        if step is None:
            raise FileNotFoundError(
                f"no merged checkpoint under {ckpt_dir}/{cls.MERGED_SUBDIR} "
                "(run launch/train.py --gs first)")
        meta = extra.get("scene", {})
        res = int(meta.get("resolution", 64))
        grid = TileGrid(res, res, int(meta.get("tile_h", 8)),
                        int(meta.get("tile_w", 16)))
        if cfg is None:
            cfg = dataclasses.replace(
                ServeCfg(K=int(meta.get("K", ServeCfg.K))), **overrides)
        center = meta.get("center")
        radius = meta.get("radius")
        server = cls(g, grid, cfg,
                     center=None if center is None else np.asarray(center),
                     radius=None if radius is None else float(radius))
        return server, extra

    # -- request intake -----------------------------------------------------

    @property
    def pending(self) -> int:
        return len(self._queue)

    def telemetry(self) -> Dict[str, int]:
        """Copy of the serving counters.  Honesty contract: ``shed`` /
        ``rejected`` / ``evictions`` / ``cache_overflow`` count every
        degraded or refused unit of work, ``assign`` / ``tiles`` are the
        render-side overflow counters (0 == every image exact)."""
        return dict(self._telemetry)

    def clear_cache(self):
        """Drop every cached table (bench/test hook for re-measuring the
        cold path); telemetry counters are NOT reset."""
        self._cache.clear()

    def cached_table(self, cam: Camera, *, rung: int = 0):
        """The cached (idx, score) table a request for ``cam`` at ``rung``
        would hit, or None — test/introspection hook (does not touch LRU
        order or counters)."""
        key, _ = quantize_pose(cam.view, cam.fx, cam.fy,
                               bins=self.cfg.pose_bins)
        return self._cache.get((key, rung))

    def submit(self, cam: Camera) -> int:
        """Enqueue one camera request -> request id (dense from 0, the
        order ``flush`` results preserve).  Raises QueueFullError at the
        queue cap (counted).  Past ``shed_at`` pending requests the
        request is marked shed: still served, at the ladder's
        ``shed_rung`` K (counted, never dropped)."""
        if np.asarray(cam.view).shape != (4, 4):
            raise ValueError("submit takes a single-view Camera; use "
                             "serve() for a batched rig")
        if (cam.width, cam.height) != (self.grid.width, self.grid.height):
            raise ValueError(
                f"camera {cam.width}x{cam.height} does not match the "
                f"serving grid {self.grid.width}x{self.grid.height}")
        cfg = self.cfg
        if len(self._queue) >= cfg.queue_cap:
            self._telemetry["rejected"] += 1
            raise QueueFullError(
                f"request queue at cap {cfg.queue_cap}; rejection counted "
                "(telemetry['rejected'])")
        shed_at = cfg.shed_at if cfg.shed_at is not None \
            else max(1, cfg.queue_cap // 2)
        shed = len(self._queue) >= shed_at
        key, (cview, cfx, cfy) = quantize_pose(
            cam.view, cam.fx, cam.fy, bins=cfg.pose_bins)
        canon = Camera(jnp.asarray(cview), jnp.float32(cfx), jnp.float32(cfy),
                       cam.width, cam.height)
        rung = select_rung(camera_distance(cview, self.center),
                           self.lod_dists)
        k = int(self.schedule.k_tiers[cfg.shed_rung]) if shed \
            else int(self.schedule.kmax)
        rid = self._next_rid
        self._next_rid += 1
        self._telemetry["requests"] += 1
        if shed:
            self._telemetry["shed"] += 1
        self._queue.append(_Request(rid=rid, cam=canon, key=key, rung=rung,
                                    k=k, shed=shed, hit=False))
        return rid

    # -- cache --------------------------------------------------------------

    def _cache_get(self, key: tuple, rung: int):
        entry = self._cache.get((key, rung))
        if entry is not None:
            self._cache.move_to_end((key, rung))
            self._telemetry["hits"] += 1
        else:
            self._telemetry["misses"] += 1
        return entry

    def _cache_put(self, key: tuple, rung: int, idx: np.ndarray,
                   score: np.ndarray):
        if self.cfg.cache_entries <= 0:
            # zero budget: nothing can be cached — counted, not silent
            self._telemetry["cache_overflow"] += 1
            return
        self._cache[(key, rung)] = (idx, score)
        self._cache.move_to_end((key, rung))
        while len(self._cache) > self.cfg.cache_entries:
            self._cache.popitem(last=False)
            self._telemetry["evictions"] += 1

    # -- batching -----------------------------------------------------------

    def _stack_cams(self, reqs: List[_Request], pad_to: int) -> Camera:
        take = reqs + [reqs[-1]] * (pad_to - len(reqs))
        return Camera(view=jnp.stack([r.cam.view for r in take]),
                      fx=jnp.stack([r.cam.fx for r in take]),
                      fy=jnp.stack([r.cam.fy for r in take]),
                      width=self.grid.width, height=self.grid.height)

    def _tables_for(self, reqs: List[_Request], rung: int):
        """Per-request (T, Kmax) tables: cache hits read host-side, misses
        batch through assign_tables_jit and populate the cache."""
        cfg = self.cfg
        tables: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        misses = []
        for i, r in enumerate(reqs):
            entry = self._cache_get(r.key, rung)
            if entry is None:
                misses.append(i)
            else:
                r.hit = True
                tables[i] = entry
        if misses:
            impl, budget = self._assign[rung]
            pad = _pad_pow2(len(misses), cfg.max_batch)
            miss_reqs = [reqs[i] for i in misses]
            cams = self._stack_cams(miss_reqs, pad)
            idx, score, ov = assign_tables_jit(
                self.grid, cfg.K, None, impl, budget)(self.ladder[rung],
                                                      cams)
            idx, score = np.asarray(idx), np.asarray(score)
            n_ov = int(np.asarray(ov)[: len(misses)].sum())
            if n_ov:
                # starved sorted-path budget: count it and grow for future
                # misses (already-cached tables stay as extracted — their
                # drops were counted when they happened)
                self._telemetry["assign"] += n_ov
                if budget is not None:
                    self._assign[rung] = (
                        impl, grow_tile_budget(budget, self.grid.n_tiles))
            for j, i in enumerate(misses):
                entry = (idx[j], score[j])
                tables[i] = entry
                self._cache_put(reqs[i].key, rung, *entry)
        return [tables[i] for i in range(len(reqs))]

    def _dispatch(self, reqs: List[_Request]) -> List[RenderResult]:
        """Render one (rung, k)-homogeneous group of <= max_batch requests
        as a single view-batched dispatch from assignment tables."""
        cfg = self.cfg
        rung, k = reqs[0].rung, reqs[0].k
        tables = self._tables_for(reqs, rung)
        pad = _pad_pow2(len(reqs), cfg.max_batch)
        take = tables + [tables[-1]] * (pad - len(reqs))
        idx = np.stack([t[0] for t in take])
        score = np.stack([t[1] for t in take])
        idx, score = slice_table(idx, score, k)       # shed rungs: prefix
        cams = self._stack_cams(reqs, pad)
        out = render_tables_jit(self.grid, cfg.impl, cfg.bg,
                                dtype_policy=cfg.dtype_policy)(
            self.ladder[rung], cams, jnp.asarray(idx), jnp.asarray(score))
        self._telemetry["batches"] += 1
        rgb = np.asarray(out.rgb)
        cov = np.asarray(out.coverage)
        return [RenderResult(request_id=r.rid, rgb=rgb[i], coverage=cov[i],
                             rung=rung, K=k, cache_hit=r.hit, shed=r.shed)
                for i, r in enumerate(reqs)]

    def flush(self) -> List[RenderResult]:
        """Serve EVERY pending request -> results in submission order.

        Requests group by (rung, k) — one model and one table depth per
        dispatch — and each group coalesces into view-batched renders of
        up to ``max_batch`` views (padded to the next power of two, so
        each config compiles a bounded trace set)."""
        reqs, self._queue = self._queue, []
        groups: Dict[Tuple[int, int], List[_Request]] = {}
        for r in reqs:
            groups.setdefault((r.rung, r.k), []).append(r)
        results: List[RenderResult] = []
        for key in sorted(groups):
            rs = groups[key]
            for s in range(0, len(rs), self.cfg.max_batch):
                results.extend(self._dispatch(rs[s:s + self.cfg.max_batch]))
        return sorted(results, key=lambda r: r.request_id)

    def serve(self, rig: Camera) -> List[RenderResult]:
        """Convenience driver: submit every view of a batched rig and
        flush, in waves that respect the queue bound WITHOUT tripping the
        rejection counter (flush-before-full), -> results in rig order."""
        results = []
        for v in range(rig.view.shape[0]):
            if self.pending >= self.cfg.queue_cap:
                results.extend(self.flush())
            self.submit(select(rig, v))
        results.extend(self.flush())
        return sorted(results, key=lambda r: r.request_id)
