"""TPU-adapted tile assignment: fixed-K per-tile gaussian lists.

GPU 3D-GS builds variable-length per-tile lists by radix-sorting (tile|depth)
keys with atomics.  On TPU we keep the top-K *front-most* gaussians per tile
(conservative circle/rect overlap test), built as a blockwise running top-k —
dense, regular compute, no atomics/sort (DESIGN.md §3).  K >= the local
overlap depth makes this exact; tests validate the approximation.

The resulting (T, K) index lists come out depth-sorted (top-k on -depth), which
is exactly the order front-to-back compositing needs.

Tiles are rectangular: the TPU-native shape is (8, 128) — one VREG row of
pixels per compositing step (DESIGN.md §3) — while CPU tests use small tiles.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.projection import Splats2D

NEG = -1e30

#: per-splat feature vector length fed to the rasterizer kernel
#: [mx, my, conicA, conicB, conicC, r, g, b, alpha, pad...] — padded to 16 so
#: the (K, F) VMEM block rows are power-of-two aligned.
FEAT_DIM = 16


class TileGrid(NamedTuple):
    width: int
    height: int
    tile_h: int = 8
    tile_w: int = 128

    @property
    def nx(self) -> int:
        return (self.width + self.tile_w - 1) // self.tile_w

    @property
    def ny(self) -> int:
        return (self.height + self.tile_h - 1) // self.tile_h

    @property
    def n_tiles(self) -> int:
        return self.nx * self.ny


def tile_bounds(grid: TileGrid):
    """Tile rects: (T, 2) lo, (T, 2) hi in pixel coords (x, y)."""
    ty, tx = jnp.meshgrid(
        jnp.arange(grid.ny), jnp.arange(grid.nx), indexing="ij"
    )
    lo = jnp.stack(
        [tx.reshape(-1) * grid.tile_w, ty.reshape(-1) * grid.tile_h], -1
    )
    hi = lo + jnp.array([grid.tile_w, grid.tile_h])
    return lo.astype(jnp.float32), hi.astype(jnp.float32)


def tile_origins(grid: TileGrid):
    """(T, 2) float32 pixel coords of each tile's top-left corner (x, y)."""
    lo, _ = tile_bounds(grid)
    return lo


def assign_tiles(splats: Splats2D, grid: TileGrid, *, K: int = 64,
                 block: int = 4096):
    """Top-K front-most gaussians per tile.

    Returns (idx (T, K) int32 into the splat table, score (T, K); score==NEG
    marks empty slots).  Blockwise over gaussians: carry a running top-k and
    merge each block with lax.top_k — O(T * N) work, O(T * block) memory.
    """
    lo, hi = tile_bounds(grid)                      # (T, 2)
    N = splats.mean2d.shape[0]
    block = min(block, max(N, K))
    nb = (N + block - 1) // block
    Np = nb * block

    def pad(x, fill=0.0):
        return jnp.pad(x, ((0, Np - N),) + ((0, 0),) * (x.ndim - 1),
                       constant_values=fill)

    mean = pad(splats.mean2d)
    rad = pad(splats.radius)
    depth = pad(splats.depth, 1e30)
    valid = jnp.pad(splats.valid, (0, Np - N), constant_values=False)

    meanb = mean.reshape(nb, block, 2)
    radb = rad.reshape(nb, block)
    depthb = depth.reshape(nb, block)
    validb = valid.reshape(nb, block)

    def body(carry, xs):
        top_score, top_idx = carry                  # (T, K)
        mb, rb, db, vb, b0 = xs
        # circle/rect overlap: clamp center to rect, compare distance to radius
        cx = jnp.clip(mb[None, :, 0], lo[:, :1], hi[:, :1])   # (T, block)
        cy = jnp.clip(mb[None, :, 1], lo[:, 1:], hi[:, 1:])
        dx = mb[None, :, 0] - cx
        dy = mb[None, :, 1] - cy
        hit = (dx * dx + dy * dy) <= (rb * rb)[None, :]
        score = jnp.where(hit & vb[None, :], -db[None, :], NEG)  # (T, block)
        idx = b0 + jnp.arange(block, dtype=jnp.int32)[None, :]
        cat_s = jnp.concatenate([top_score, score], axis=1)
        cat_i = jnp.concatenate([top_idx, jnp.broadcast_to(idx, score.shape)], 1)
        new_s, sel = lax.top_k(cat_s, K)
        new_i = jnp.take_along_axis(cat_i, sel, axis=1)
        return (new_s, new_i), None

    T = grid.n_tiles
    init = (jnp.full((T, K), NEG, jnp.float32), jnp.zeros((T, K), jnp.int32))
    b0s = jnp.arange(nb, dtype=jnp.int32) * block
    (score, idx), _ = lax.scan(body, init, (meanb, radb, depthb, validb, b0s))
    return idx, score


def splat_features(splats: Splats2D):
    """Per-splat kernel features (..., FEAT_DIM); invalid splats get alpha=0.
    Batch-polymorphic over leading dims."""
    a, b, c = splats.cov2d[..., 0], splats.cov2d[..., 1], splats.cov2d[..., 2]
    det = jnp.maximum(a * c - b * b, 1e-12)
    conic = jnp.stack([c / det, -b / det, a / det], -1)      # (..., 3)
    alpha = jnp.where(splats.valid, splats.alpha, 0.0)
    feat = jnp.concatenate(
        [splats.mean2d, conic, splats.rgb, alpha[..., None]], axis=-1
    )                                                        # (..., 9)
    pad = FEAT_DIM - feat.shape[-1]
    return jnp.pad(feat, ((0, 0),) * (feat.ndim - 1) + ((0, pad),))


def gather_tile_features(splats: Splats2D, idx, score):
    """Pack per-tile splat features: (T, K, FEAT_DIM).

    Empty slots (score==NEG) get alpha=0 -> contribute nothing.  This gather is
    plain jnp (differentiable); its transpose (scatter-add) is what routes the
    kernel's per-tile grads back to gaussians.
    """
    feat = splat_features(splats)                            # (N, F)
    tile_feat = feat[idx]                                    # (T, K, F)
    live = score > NEG / 2                                   # (T, K)
    alpha = jnp.where(live, tile_feat[..., 8], 0.0)
    return jnp.concatenate(
        [tile_feat[..., :8], alpha[..., None], tile_feat[..., 9:]], axis=-1
    )


def untile_image(tiles, grid: TileGrid):
    """(T, 4, th, tw) kernel output -> (H, W, 4) image (cropped to grid size)."""
    th, tw = grid.tile_h, grid.tile_w
    img = tiles.reshape(grid.ny, grid.nx, 4, th, tw)
    img = img.transpose(0, 3, 1, 4, 2).reshape(grid.ny * th, grid.nx * tw, 4)
    return img[: grid.height, : grid.width]
