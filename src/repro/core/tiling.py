"""TPU-adapted tile assignment: fixed-K per-tile gaussian lists.

GPU 3D-GS builds variable-length per-tile lists by radix-sorting (tile|depth)
keys with atomics.  On TPU we keep the top-K *front-most* gaussians per tile
(conservative circle/rect overlap test), built as a blockwise running top-k —
dense, regular compute, no atomics/sort (DESIGN.md §3).  K >= the local
overlap depth makes this exact; tests validate the approximation.

The resulting (T, K) index lists come out depth-sorted (top-k on -depth,
ties broken by splat index so every merge order yields the same list), which
is exactly the order front-to-back compositing needs.

Tiles are rectangular: the TPU-native shape is (8, 128) — one VREG row of
pixels per compositing step (DESIGN.md §3) — while CPU tests use small tiles.

Shape-contract glossary (used across tiling/render/kernels docstrings):
  N  gaussians in the (projected) splat table
  T  image tiles (grid.n_tiles); M for a generic flat tile axis
  K  per-tile splat-list depth; Kmax = the largest tier when tiered
  V  views in a batched render
  S  superblocks in the coarse pre-cull

Variable-K tiers: ``bin_tiles_by_occupancy`` groups tiles into K-tiers
(e.g. K in {16, 64, 256}) by their live-entry count so the rasterizer can
launch one kernel per tier instead of paying the max K everywhere; see
``TierPlan`` and kernels/ops.rasterize_tiles_tiered.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.projection import Splats2D

NEG = -1e30

#: per-splat feature vector length fed to the rasterizer kernel
#: [mx, my, conicA, conicB, conicC, r, g, b, alpha, pad...] — padded to 16 so
#: the (K, F) VMEM block rows are power-of-two aligned.
FEAT_DIM = 16


class TileGrid(NamedTuple):
    """Static image/tile geometry: (height, width) pixels split into
    row-major (tile_h, tile_w) tiles — T = n_tiles = ny * nx.  Hashable, so
    it can key jit caches (pipeline._render_batch_jit) and be closed over
    as a static argument."""
    width: int
    height: int
    tile_h: int = 8
    tile_w: int = 128

    @property
    def nx(self) -> int:
        return (self.width + self.tile_w - 1) // self.tile_w

    @property
    def ny(self) -> int:
        return (self.height + self.tile_h - 1) // self.tile_h

    @property
    def n_tiles(self) -> int:
        return self.nx * self.ny


def tile_bounds(grid: TileGrid):
    """Tile rects: (T, 2) lo, (T, 2) hi in pixel coords (x, y)."""
    ty, tx = jnp.meshgrid(
        jnp.arange(grid.ny), jnp.arange(grid.nx), indexing="ij"
    )
    lo = jnp.stack(
        [tx.reshape(-1) * grid.tile_w, ty.reshape(-1) * grid.tile_h], -1
    )
    hi = lo + jnp.array([grid.tile_w, grid.tile_h])
    return lo.astype(jnp.float32), hi.astype(jnp.float32)


def tile_origins(grid: TileGrid):
    """(T, 2) float32 pixel coords of each tile's top-left corner (x, y)."""
    lo, _ = tile_bounds(grid)
    return lo


def topk_by_score_then_index(cat_s, cat_i, K: int):
    """Top-K of (score, idx) pairs: score descending, splat index ascending.

    cat_s (..., C) float32 scores, cat_i (..., C) int32 indices ->
    (..., K) of each.  The secondary index key makes the selection a pure
    function of the (score, idx) SET — any blockwise/strip-wise merge order
    (dense sweep, coarse survivors, distributed tile strips) lands on the
    same K entries even when scores tie at the boundary, which is what keeps
    single-device and distributed assignment bit-identical (ROADMAP
    tie-break divergence item).

    Implemented with lax.top_k, which breaks value ties by the LOWER input
    position (the chlo.top_k contract; ~30x cheaper on CPU than an explicit
    two-key lax.sort over the (K + block)-wide merge).  Positional ties
    equal index-order ties under one PRECONDITION every caller satisfies:
    within any run of equal scores, cat_i must be ascending.  The blockwise
    scans guarantee it structurally — the carry holds only earlier
    (lower-index) blocks and is inductively index-sorted within ties, and
    each block's candidates are generated in index order (coarse candidate
    lists and strip-compacted tables preserve table order too).  The
    merge-order-invariance test in test_tiling_properties.py pins this
    against backend regressions.
    """
    new_s, sel = lax.top_k(cat_s, K)
    return new_s, jnp.take_along_axis(cat_i.astype(jnp.int32), sel,
                                      axis=-1)


# ---------------------------------------------------------------------------
# Coarse superblock pre-cull
# ---------------------------------------------------------------------------


def superblock_bounds(grid: TileGrid, sb: int):
    """Bounds of sb x sb tile superblocks: (S, 2) lo / hi pixel rects.

    The last row/column of superblocks may extend past the image — harmless,
    the coarse test is conservative (a superset of true tile overlaps).
    """
    sx = (grid.nx + sb - 1) // sb
    sy = (grid.ny + sb - 1) // sb
    syi, sxi = jnp.meshgrid(jnp.arange(sy), jnp.arange(sx), indexing="ij")
    lo = jnp.stack(
        [sxi.reshape(-1) * grid.tile_w * sb, syi.reshape(-1) * grid.tile_h * sb],
        -1,
    ).astype(jnp.float32)
    hi = lo + jnp.array([grid.tile_w * sb, grid.tile_h * sb], jnp.float32)
    return lo, hi


def coarse_candidates(mean2d, radius, valid, grid: TileGrid, *, sb: int,
                      budget: int, block: int = 4096):
    """Per-superblock candidate splat lists via one cheap circle/rect pass.

    -> (cand (S, budget) int32, overflow () int32).  ``cand`` holds indices
    into the splat table; slots past the true per-superblock occupancy hold
    N (one-past-the-end sentinel).  If a superblock's occupancy exceeds
    ``budget``, the HIGHEST-INDEXED splats overflow and are dropped — table
    order, not depth order, so the loss is arbitrary w.r.t. visibility.
    ``overflow`` counts exactly those dropped (superblock, splat) candidate
    pairs; 0 means the cull was exact.  Callers must size the budget to the
    scene (assign_tiles' auto budget is documented there; budget >=
    occupancy makes the cull exact) and should monitor the counter in
    production instead of trusting the budget blindly.

    Blockwise over gaussians like the dense sweep — O(S * block)
    temporaries, not O(S * N) — carrying per-superblock running counts so
    each block's hits compact to their final columns with one cumsum + one
    scatter (a vmapped size-bounded nonzero costs ~3x the whole dense
    assignment sweep on CPU).
    """
    lo, hi = superblock_bounds(grid, sb)             # (S, 2)
    N = mean2d.shape[0]
    S = lo.shape[0]
    block = min(block, max(N, 1))
    nb = (N + block - 1) // block
    Np = nb * block

    pad = lambda x, fill: jnp.pad(x, (0, Np - N), constant_values=fill)
    mx = pad(mean2d[:, 0], 0.0).reshape(nb, block)
    my = pad(mean2d[:, 1], 0.0).reshape(nb, block)
    rd = pad(radius, 0.0).reshape(nb, block)
    vd = pad(valid, False).reshape(nb, block)        # padded rows never hit
    idxb = jnp.arange(Np, dtype=jnp.int32).reshape(nb, block)

    rows = jnp.arange(S)[:, None]

    def body(carry, x):
        count, cand = carry                          # (S,), (S, budget+1)
        bmx, bmy, brd, bvd, bidx = x
        cx = jnp.clip(bmx[None, :], lo[:, :1], hi[:, :1])     # (S, block)
        cy = jnp.clip(bmy[None, :], lo[:, 1:], hi[:, 1:])
        dx = bmx[None, :] - cx
        dy = bmy[None, :] - cy
        hit = ((dx * dx + dy * dy) <= (brd * brd)[None, :]) & bvd[None, :]
        # overflow (and non-hits) land in scratch column ``budget`` ->
        # sliced off below
        pos = jnp.where(hit, count[:, None] + jnp.cumsum(hit, axis=1) - 1,
                        budget)
        pos = jnp.minimum(pos, budget)
        cand = cand.at[rows, pos].set(jnp.broadcast_to(bidx, hit.shape),
                                      mode="drop")
        return (count + hit.sum(axis=1), cand), None

    init = (jnp.zeros((S,), jnp.int32),
            jnp.full((S, budget + 1), N, jnp.int32))
    (count, cand), _ = lax.scan(body, init, (mx, my, rd, vd, idxb))
    overflow = jnp.maximum(count - budget, 0).sum().astype(jnp.int32)
    return cand[:, :budget], overflow


def _coarse_budget(N: int, S: int, K: int, budget) -> int:
    """Resolve the per-superblock candidate budget (see assign_tiles)."""
    if budget is None:
        # auto budget: 4x headroom over uniform splat->superblock occupancy.
        # On coarse grids (S < 8) the radius halo rivals the superblock size
        # and the uniform model breaks down — fall back to exact (budget=N).
        budget = N if S < 8 else max(4 * K, -(-4 * N // S))
    budget = min(max(int(budget), K), N)
    budget = -(-budget // 128) * 128 if budget >= 128 else budget
    return min(budget, N)


def _assign_tiles_coarse(splats: Splats2D, grid: TileGrid, *, K: int,
                         block: int, sb: int, budget: int):
    """Exact circle/rect top-K restricted to coarse-pass survivors.

    Same contract as assign_tiles (returns (idx, score, overflow)); work
    drops from O(T*N) to O(S*N + T*budget) where S = T / sb^2.  Candidate
    features are gathered ONCE per superblock (gather volume S*budget rows,
    not T*budget) and the fine test runs superblock-major over (S, sb^2
    tile slots, block) panes, scattered back to row-major tile order at the
    end.
    """
    N = splats.mean2d.shape[0]
    sx = (grid.nx + sb - 1) // sb
    sy = (grid.ny + sb - 1) // sb
    S, sb2 = sx * sy, sb * sb

    cand, overflow = coarse_candidates(splats.mean2d, splats.radius,
                                       splats.valid, grid, sb=sb,
                                       budget=budget,
                                       block=block)            # (S, M)
    M = cand.shape[1]
    cb = min(block, M)
    nb = (M + cb - 1) // cb
    cand = jnp.pad(cand, ((0, 0), (0, nb * cb - M)), constant_values=N)

    # one gather per field per superblock; sentinel N -> fill (invalid)
    take = lambda arr, fill: jnp.take(arr, cand, axis=0, mode="fill",
                                      fill_value=fill)
    mean_c = take(splats.mean2d, 0.0)                # (S, Mp, 2)
    rad_c = take(splats.radius, 0.0)
    depth_c = take(splats.depth, 1e30)
    valid_c = take(splats.valid, False)

    # tile-slot rects per superblock, (S, sb2, 2); slots past the image edge
    # are dead weight (sliced away by the scatter-back below)
    syi, sxi = jnp.meshgrid(jnp.arange(sy), jnp.arange(sx), indexing="ij")
    jy, jx = jnp.meshgrid(jnp.arange(sb), jnp.arange(sb), indexing="ij")
    ty = syi.reshape(-1, 1) * sb + jy.reshape(-1)    # (S, sb2)
    tx = sxi.reshape(-1, 1) * sb + jx.reshape(-1)
    lo_sb = jnp.stack([tx * grid.tile_w, ty * grid.tile_h], -1) \
        .astype(jnp.float32)
    hi_sb = lo_sb + jnp.array([grid.tile_w, grid.tile_h], jnp.float32)

    xs = (mean_c.reshape(S, nb, cb, 2).transpose(1, 0, 2, 3),
          rad_c.reshape(S, nb, cb).transpose(1, 0, 2),
          depth_c.reshape(S, nb, cb).transpose(1, 0, 2),
          valid_c.reshape(S, nb, cb).transpose(1, 0, 2),
          cand.reshape(S, nb, cb).transpose(1, 0, 2))

    def body(carry, x):
        top_score, top_idx = carry                   # (S, sb2, K)
        mb, rb, db, vb, ci = x                       # (S, cb, ...)
        cx = jnp.clip(mb[:, None, :, 0], lo_sb[..., :1], hi_sb[..., :1])
        cy = jnp.clip(mb[:, None, :, 1], lo_sb[..., 1:], hi_sb[..., 1:])
        dx = mb[:, None, :, 0] - cx                  # (S, sb2, cb)
        dy = mb[:, None, :, 1] - cy
        hit = (dx * dx + dy * dy) <= (rb * rb)[:, None, :]
        score = jnp.where(hit & vb[:, None, :], -db[:, None, :], NEG)
        cat_s = jnp.concatenate([top_score, score], axis=-1)
        cat_i = jnp.concatenate(
            [top_idx, jnp.broadcast_to(ci[:, None, :].astype(jnp.int32),
                                       score.shape)], axis=-1)
        new_s, new_i = topk_by_score_then_index(cat_s, cat_i, K)
        return (new_s, new_i), None

    init = (jnp.full((S, sb2, K), NEG, jnp.float32),
            jnp.zeros((S, sb2, K), jnp.int32))
    (score_s, idx_s), _ = lax.scan(body, init, xs)

    # scatter back: tile t (row-major) lives at slot (sbid, (ty%sb)*sb+tx%sb)
    tyf, txf = jnp.meshgrid(jnp.arange(grid.ny), jnp.arange(grid.nx),
                            indexing="ij")
    pos = ((tyf // sb) * sx + txf // sb) * sb2 + (tyf % sb) * sb + txf % sb
    pos = pos.reshape(-1)                            # (T,)
    score = score_s.reshape(S * sb2, K)[pos]
    idx = idx_s.reshape(S * sb2, K)[pos]
    # map sentinel slots back to a safe in-range index (they carry score NEG)
    idx = jnp.where(score > NEG / 2, idx, 0)
    return idx, score, overflow


def assign_tiles(splats: Splats2D, grid: TileGrid, *, K: int = 64,
                 block: int = 4096, coarse: Optional[int] = None,
                 coarse_budget: Optional[int] = None,
                 return_overflow: bool = False, impl: str = "dense",
                 tile_budget: Optional[int] = None):
    """Top-K front-most gaussians per tile.

    Returns (idx (T, K) int32 into the splat table, score (T, K); score==NEG
    marks empty slots).  With ``return_overflow=True`` a third () int32 is
    appended: the number of candidates the assignment dropped past a static
    budget (always 0 on the dense path without ``coarse``) — production
    configs should log it and treat nonzero as "grow the budget".

    ``impl`` selects the assignment algorithm (same contract either way —
    the two are bit-identical whenever no budget overflows, empty slots
    included):

      "auto"    "sorted" when the grid has >= SORTED_MIN_TILES flat tiles
                AND a ``tile_budget`` is in hand and lean enough to win
                (see resolve_assign_impl; the measured CPU crossover is in
                benchmarks/bench_assign.py), "dense" otherwise — what the
                render/train layers default to via ``assign_impl``; their
                host loops probe the budget (render.resolve_assignment).
      "dense"   blockwise O(T * N) sweep: carry a running top-k and merge
                each gaussian block with a two-key sort (score desc, splat
                index asc) — O(T * block) memory; the index tie-break makes
                the result independent of the merge order (see
                topk_by_score_then_index).  This is the test oracle and the
                escape hatch — always exact, never drops a candidate.
      "sorted"  duplicate-and-sort scatter (``assign_tiles_sorted``): each
                splat expands into its overlapped-tile candidates under a
                static per-splat ``tile_budget``, one global three-key sort
                groups and orders them, and a segmented scatter emits the
                (T, K) layout — O(N * B log(N * B)), independent of T, the
                production default (render/train wire it via
                ``assign_impl``).  ``coarse`` is ignored (the expansion
                already skips non-overlapped tiles).

    ``coarse=sb`` (dense only) enables a two-level cull: a cheap circle/rect
    pass against sb x sb tile superblocks compacts per-superblock candidate
    lists of size ``coarse_budget`` (auto: N when the grid has S < 8
    superblocks, else max(4K, ceil(4N/S)) — 4x headroom over uniform
    occupancy — rounded up to 128), and the exact per-tile test runs only
    against those survivors — O(S*N + T*budget) instead of O(T*N).  With
    budget >= true superblock occupancy the result is identical to the
    dense path on live slots (empty-slot idx values are unspecified in both
    paths); on overflow the highest-INDEXED candidates are dropped
    (arbitrary w.r.t. depth — see coarse_candidates), so size budgets
    generously.  When the resolved budget reaches N the coarse pass cannot
    cull anything, so the dense path runs directly (identical result, none
    of the pre-cull overhead).
    """
    if resolve_assign_impl(impl, grid.n_tiles, tile_budget) == "sorted":
        idx, score, ov = assign_tiles_sorted(splats, grid, K=K,
                                             tile_budget=tile_budget,
                                             return_overflow=True)
        return (idx, score, ov) if return_overflow else (idx, score)
    if coarse is not None and coarse > 1:
        N = splats.mean2d.shape[0]
        S = (((grid.nx + coarse - 1) // coarse)
             * ((grid.ny + coarse - 1) // coarse))
        budget = _coarse_budget(N, S, K, coarse_budget) if N else 0
        if 0 < budget < N:
            idx, score, overflow = _assign_tiles_coarse(
                splats, grid, K=K, block=block, sb=coarse, budget=budget)
            return (idx, score, overflow) if return_overflow else (idx, score)
        # budget >= N (or empty table): fall through to the dense sweep
    lo, hi = tile_bounds(grid)                      # (T, 2)
    N = splats.mean2d.shape[0]
    block = min(block, max(N, K))
    nb = (N + block - 1) // block
    Np = nb * block

    def pad(x, fill=0.0):
        return jnp.pad(x, ((0, Np - N),) + ((0, 0),) * (x.ndim - 1),
                       constant_values=fill)

    mean = pad(splats.mean2d)
    rad = pad(splats.radius)
    depth = pad(splats.depth, 1e30)
    valid = jnp.pad(splats.valid, (0, Np - N), constant_values=False)

    meanb = mean.reshape(nb, block, 2)
    radb = rad.reshape(nb, block)
    depthb = depth.reshape(nb, block)
    validb = valid.reshape(nb, block)

    def body(carry, xs):
        top_score, top_idx = carry                  # (T, K)
        mb, rb, db, vb, b0 = xs
        # circle/rect overlap: clamp center to rect, compare distance to radius
        cx = jnp.clip(mb[None, :, 0], lo[:, :1], hi[:, :1])   # (T, block)
        cy = jnp.clip(mb[None, :, 1], lo[:, 1:], hi[:, 1:])
        dx = mb[None, :, 0] - cx
        dy = mb[None, :, 1] - cy
        hit = (dx * dx + dy * dy) <= (rb * rb)[None, :]
        score = jnp.where(hit & vb[None, :], -db[None, :], NEG)  # (T, block)
        idx = b0 + jnp.arange(block, dtype=jnp.int32)[None, :]
        cat_s = jnp.concatenate([top_score, score], axis=1)
        cat_i = jnp.concatenate([top_idx, jnp.broadcast_to(idx, score.shape)], 1)
        new_s, new_i = topk_by_score_then_index(cat_s, cat_i, K)
        return (new_s, new_i), None

    T = grid.n_tiles
    init = (jnp.full((T, K), NEG, jnp.float32), jnp.zeros((T, K), jnp.int32))
    b0s = jnp.arange(nb, dtype=jnp.int32) * block
    (score, idx), _ = lax.scan(body, init, (meanb, radb, depthb, validb, b0s))
    if return_overflow:
        return idx, score, jnp.zeros((), jnp.int32)   # dense path never drops
    return idx, score


# ---------------------------------------------------------------------------
# Sort-based assignment (duplicate-and-sort scatter)
# ---------------------------------------------------------------------------


#: default static per-splat tile budget for the sorted assignment path: a
#: 4x4-tile bbox neighbourhood.  The sorted path's work is O(N * B), so the
#: default stays lean; scenes with larger splats (or callers that want
#: provable exactness, budget = T) pass an explicit ``tile_budget`` and
#: watch the overflow counter (0 == nothing was dropped).
DEFAULT_TILE_BUDGET = 16

#: assignment impl the render/train layers default to (``assign_impl=``):
#: "auto" picks the sort-based scatter when the grid is large enough AND a
#: per-splat budget is known to be lean enough for it to win (see
#: resolve_assign_impl; bench_assign measures the crossover) — the host
#: entry points probe that budget from concrete splats, and traced
#: building blocks without one stay on the always-exact dense sweep.
#: "dense"/"sorted" pin one path.
DEFAULT_ASSIGN_IMPL = "auto"

#: "auto" crossover: grids with fewer flat tiles than this stay on the
#: dense sweep (small-T CPU grids — the test tier — where the sweep's
#: T*N work is trivial and the sort constant dominates).
SORTED_MIN_TILES = 512

#: "auto" crossover, per-splat axis: the sorted path's O(N*B) work beats
#: the dense O(T*N) sweep only while B (the per-splat tile budget) stays
#: under ~T / this ratio (measured on CPU: ~20x higher per-element cost
#: for expand+sort vs the sweep's hit test).  Callers that PROBE a budget
#: from concrete splats (render_views / fit_partition / fit_partitions)
#: feed it to resolve_assign_impl so big-splat scenes — where every splat
#: touches ~a hundred tiles — honestly fall back to the sweep.
SORTED_BUDGET_RATIO = 20


def resolve_assign_impl(impl: str, n_tiles: int,
                        tile_budget: Optional[int] = None) -> str:
    """Resolve an ``assign_impl`` knob ("auto" | "dense" | "sorted") to a
    concrete algorithm for a grid with ``n_tiles`` flat tiles.  "auto" is
    resolved from the GLOBAL grid size everywhere (the distributed strip
    assignment resolves on the full grid, not its strip window), so one
    scene picks one algorithm across every execution layout.

    "auto" picks the sorted path only when it can PROVE it should: the
    grid must carry >= SORTED_MIN_TILES flat tiles AND the caller must
    know a per-splat ``tile_budget`` (probed from concrete splats — the
    host entry points render_views / fit_partition(s) do this via
    ``render.resolve_assignment`` — or passed explicitly) that stays under
    n_tiles / SORTED_BUDGET_RATIO.  With no budget in hand (a directly
    jitted building block) "auto" stays on the always-exact dense sweep —
    a silent candidate-dropping default would violate the overflow-counter
    honesty contract; pin ``assign_impl="sorted"`` (and size the budget)
    to force the sorted path there.  Budgets past the ratio demote to
    dense too: scenes of few huge splats are where duplicate-and-sort
    loses."""
    if impl == "auto":
        if n_tiles < SORTED_MIN_TILES or tile_budget is None \
                or tile_budget * SORTED_BUDGET_RATIO > n_tiles:
            return "dense"
        return "sorted"
    if impl not in ("dense", "sorted"):
        raise ValueError(f"unknown assignment impl {impl!r}; expected "
                         "'auto', 'dense' or 'sorted'")
    return impl


def resolve_tile_budget(n_tiles: int, tile_budget: Optional[int]) -> int:
    """Static per-splat budget: auto = min(T, DEFAULT_TILE_BUDGET); clamped
    to [1, T] (a splat can overlap at most all T tiles, where the expansion
    provably cannot drop)."""
    b = DEFAULT_TILE_BUDGET if tile_budget is None else int(tile_budget)
    return max(1, min(b, max(n_tiles, 1)))


def _bbox_bounds(mx, my, rad, grid: TileGrid):
    """Clipped tile-coordinate bbox of each splat's circle: (x0, x1, y0, y1),
    batch-polymorphic over leading dims.  The low edges use ceil-1 (not
    floor) so a circle exactly tangent to a tile boundary still covers the
    tile the dense sweep's clamp test counts as a hit."""
    tw = jnp.float32(grid.tile_w)
    th = jnp.float32(grid.tile_h)
    x0 = jnp.clip(jnp.ceil((mx - rad) / tw).astype(jnp.int32) - 1,
                  0, grid.nx - 1)
    x1 = jnp.clip(jnp.floor((mx + rad) / tw).astype(jnp.int32),
                  0, grid.nx - 1)
    y0 = jnp.clip(jnp.ceil((my - rad) / th).astype(jnp.int32) - 1,
                  0, grid.ny - 1)
    y1 = jnp.clip(jnp.floor((my + rad) / th).astype(jnp.int32),
                  0, grid.ny - 1)
    return x0, x1, y0, y1


def splat_tile_counts(splats: Splats2D, grid: TileGrid):
    """(..., N) int32 per-splat bbox candidate-tile counts — the quantity
    the sorted path's ``tile_budget`` must cover for bit-exactness (and
    what its overflow counter reports when it doesn't).  Batch-polymorphic;
    this is the budget-probe input for host layers (render.
    tile_count_probe_jit -> auto_tile_budget)."""
    x0, x1, y0, y1 = _bbox_bounds(splats.mean2d[..., 0],
                                  splats.mean2d[..., 1], splats.radius, grid)
    cnt = jnp.maximum(x1 - x0 + 1, 0) * jnp.maximum(y1 - y0 + 1, 0)
    return jnp.where(splats.valid, cnt, 0).astype(jnp.int32)


def auto_tile_budget(max_count, n_tiles: int, *, slack: float = 1.5,
                     round_to: int = 16) -> int:
    """CONCRETE max per-splat bbox count -> static sorted-path budget:
    scaled by ``slack`` (splat radii drift between probes — they are
    trained parameters), rounded up to ``round_to`` so nearby probes hash
    to the same jit cache entry, clamped to [1, n_tiles] (where the
    expansion provably cannot drop).  Host-side only — raises under
    tracing, exactly like auto_tier_caps (budgets are static shapes)."""
    _reject_tracers("auto_tile_budget", max_count)
    b = int(np.ceil(max(int(max_count), 1) * slack))
    b = -(-b // round_to) * round_to
    return max(1, min(b, max(int(n_tiles), 1)))


def window_overlap_mask(mx, my, rad, valid, grid: TileGrid, *,
                        t0, n_local: int, t_end=None):
    """Which splats' clipped tile bboxes can touch the contiguous row-major
    flat-tile window ``[t0, t0 + n_local)``.

    mx/my/rad/valid (..., N) splat columns; ``t0`` a (possibly traced)
    scalar window offset or a (W,) vector of offsets (a new leading window
    axis is prepended).  -> bool (..., N) (or (W, ..., N)).

    Same bbox-row arithmetic as ``_expand_splat_tiles``'s window clamp: a
    window is a contiguous row-major tile range, so its tiles live in rows
    ``[t0 // nx, (t0 + n_local - 1) // nx]`` and a splat whose clipped bbox
    rows intersect that span is a SUPERSET of the splats whose circles hit
    any window tile — filtering by this mask provably drops no true hit.
    This is the per-(src, dst)-edge overlap test of the sparse splat
    exchange (core.distributed): each destination's sub-strip is one such
    window.

    ``t_end`` (optional, traced ok) clips every window at an exclusive
    flat-tile bound: the effective range is ``[t0, min(t0+n_local,
    t_end))`` and a window starting at/after ``t_end`` matches nothing.
    The exchange uses this for strips that do not divide by the "part"
    axis — padded sub-windows must not count the NEXT strip's tiles (or
    anything at all, when fully past the strip) against an edge budget.
    """
    _, _, y0, y1 = _bbox_bounds(mx, my, rad, grid)
    t0 = jnp.asarray(t0, jnp.int32)
    if t_end is None:
        lim = t0 + n_local
        live = None
    else:
        t_end = jnp.asarray(t_end, jnp.int32)
        lim = jnp.minimum(t0 + n_local, t_end)
        live = t0 < t_end
    r0 = t0 // grid.nx
    r1 = (lim - 1) // grid.nx
    if t0.ndim:
        shape = t0.shape + (1,) * y0.ndim
        r0 = r0.reshape(shape)
        r1 = r1.reshape(shape)
        if live is not None:
            live = live.reshape(shape)
    out = valid & (y0 <= r1) & (y1 >= r0)
    return out if live is None else out & live


def grow_tile_budget(budget: int, n_tiles: int, *, growth: float = 2.0,
                     round_to: int = 16) -> int:
    """Geometric growth for a static per-splat tile budget that reported
    overflow — the sorted-assignment mirror of ``TierSchedule.
    note_overflow`` (drivers rebuild the step with the grown budget instead
    of letting truncation persist).  Clamped to [1, n_tiles], where the
    bbox expansion provably cannot drop."""
    b = int(np.ceil(max(int(budget), 1) * growth))
    b = -(-b // round_to) * round_to
    return max(1, min(b, max(int(n_tiles), 1)))


def _expand_splat_tiles(mx, my, rad, valid, grid: TileGrid, *,
                        budget: int, t0=None, n_local: Optional[int] = None):
    """Expand one splat table into per-splat candidate (tile, depth, idx)
    triples over a static ``budget`` of bbox tile slots.

    mx/my/rad/valid (N,); ``t0`` (dynamic scalar, default 0) is the
    flat-tile offset of a LOCAL window of ``n_local`` row-major tiles (the
    distributed strip case; None/None = the full grid).  Returns
    (tile (N, B) int32 LOCAL ids with n_local as miss/pad sentinel,
    overflow () int32 counting bbox candidate slots dropped past the
    budget — conservative: bbox slots, a superset of true circle hits, so
    0 still proves exactness).

    The bbox low edge uses ceil-1 (not floor, see _bbox_bounds) so a circle
    exactly tangent to a tile boundary still enumerates the tile the dense
    sweep's clamp test counts as a hit; the exact circle/rect test then
    decides membership with the same arithmetic as the dense path.
    """
    Tl = grid.n_tiles if n_local is None else n_local
    tw = jnp.float32(grid.tile_w)
    th = jnp.float32(grid.tile_h)
    x0, x1, y0, y1 = _bbox_bounds(mx, my, rad, grid)
    if t0 is not None:
        # clamp the bbox rows to the window's row span (the window is a
        # contiguous row-major tile range, so rows [t0//nx, (t0+Tl-1)//nx]
        # are a superset of its tiles) — budget slots stop paying for
        # strip-foreign rows
        y0 = jnp.maximum(y0, t0 // grid.nx)
        y1 = jnp.minimum(y1, (t0 + Tl - 1) // grid.nx)

    # bw >= 1 for any rad >= 0 (the clamped range is non-empty); the
    # maximum() only guards the integer division against degenerate
    # negative-radius inputs, whose nt is already 0
    bw = jnp.maximum(x1 - x0 + 1, 1)
    nt = jnp.where(valid,
                   jnp.maximum(x1 - x0 + 1, 0)
                   * jnp.maximum(y1 - y0 + 1, 0), 0)
    jj = jnp.arange(budget, dtype=jnp.int32)[None, :]
    inb = jj < nt[:, None]                            # (N, B)
    ty = y0[:, None] + jj // bw[:, None]
    tx = x0[:, None] + jj % bw[:, None]
    # exact circle/rect test — identical arithmetic to the dense sweep
    lox = tx.astype(jnp.float32) * tw
    loy = ty.astype(jnp.float32) * th
    cx = jnp.clip(mx[:, None], lox, lox + tw)
    cy = jnp.clip(my[:, None], loy, loy + th)
    dx = mx[:, None] - cx
    dy = my[:, None] - cy
    hit = inb & (dx * dx + dy * dy <= (rad * rad)[:, None])
    flat = ty * grid.nx + tx
    if t0 is not None:
        flat = flat - t0
        hit &= (flat >= 0) & (flat < Tl)
    tile = jnp.where(hit, flat, Tl).astype(jnp.int32)
    overflow = jnp.maximum(nt - budget, 0).sum().astype(jnp.int32)
    return tile, overflow


def _splat_depth_ranks(depth):
    """Stable (depth asc, splat idx asc) ranking of a (N,) depth table.

    -> (rank_of (N,) int32 rank per ORIGINAL splat, perm (N,) int32
    original index per rank).  Depths are positive, so their float32 bit
    patterns are monotone as unsigned ints; the stable sort realizes the
    splat-index tie-break — together exactly topk_by_score_then_index's
    (score desc, idx asc) order.  Invalid splats may carry arbitrary
    depths; they rank SOMEWHERE, harmlessly, since they emit no candidates.
    """
    N = depth.shape[0]
    iota = jnp.arange(N, dtype=jnp.int32)
    _, perm = lax.sort((lax.bitcast_convert_type(depth, jnp.uint32), iota),
                       num_keys=1)
    rank_of = jnp.zeros((N,), jnp.int32).at[perm].set(iota)
    return rank_of, perm


def _segment_topk_packed(tile, rank_of, perm, depth, *, n_tiles: int,
                         K: int, rank_bits: int):
    """Per-tile first-K of the candidate set via ONE single-operand sort.

    tile (N, B) LOCAL ids (sentinel == ``n_tiles``) from
    _expand_splat_tiles; rank_of/perm/depth from _splat_depth_ranks.  Each
    candidate packs into a single uint32 key ``tile << rank_bits | rank``
    — ascending keys are exactly the (tile, depth, splat idx) lexicographic
    order, and the key alone DECODES back to (tile, splat idx, depth), so
    the sort carries no payload.  XLA's single-operand u32 sort stays on a
    fast vectorized path (~25 ms / 384k on CPU) where the variadic
    multi-key comparator sort is ~10x slower — that difference is the whole
    CPU viability of this path.  Group boundaries come from one
    ``searchsorted`` over the tile prefixes and the (T, K) output is pure
    gathers — no scatter (XLA CPU scatter costs ~55 ns/element).

    Ranks past K fall off (the same depth-ordered truncation as the dense
    top-k); empty slots carry (idx 0, score NEG) — bit-identical to the
    dense sweep.
    """
    N, B = tile.shape
    M = N * B
    hit = tile < n_tiles
    packed = jnp.where(
        hit,
        (tile.astype(jnp.uint32) << rank_bits)
        | rank_of[:, None].astype(jnp.uint32),
        jnp.uint32(0xFFFFFFFF)).reshape(-1)
    skeys = lax.sort(packed)                          # (M,) single-operand
    bounds = jnp.searchsorted(
        skeys, jnp.arange(n_tiles + 1, dtype=jnp.uint32) << rank_bits)
    pos = bounds[:n_tiles, None] + jnp.arange(K, dtype=bounds.dtype)[None, :]
    live = pos < bounds[1:, None]                     # within my tile's run
    key_at = skeys[jnp.minimum(pos, M - 1)]
    r = jnp.minimum((key_at
                     & jnp.uint32((1 << rank_bits) - 1)).astype(jnp.int32),
                    N - 1)
    src = perm[r]                                     # original splat index
    idx = jnp.where(live, src, 0)
    score = jnp.where(live, -depth[src], NEG)
    return idx, score


def _segment_topk_sort3(tile, depth, *, n_tiles: int, K: int):
    """Variadic-sort fallback for _segment_topk_packed when
    ``log2(T+1) + log2(N)`` exceeds the 32 packed key bits: a stable
    three-key lax.sort over (tile, depth, splat idx) — same output, ~10x
    slower on CPU (scalar comparator lowering); huge-N/huge-T callers
    should shard (the distributed strip windows keep both factors small).
    """
    N, B = tile.shape
    M = N * B
    dk = jnp.where(tile < n_tiles,
                   jnp.broadcast_to(depth[:, None], tile.shape),
                   jnp.float32(1e30))
    sidx = jnp.broadcast_to(jnp.arange(N, dtype=jnp.int32)[:, None], (N, B))
    tile_s, _, idx_s = lax.sort(
        (tile.reshape(-1), dk.reshape(-1), sidx.reshape(-1)), num_keys=3)
    pos = jnp.arange(M, dtype=jnp.int32)
    start = jnp.concatenate([jnp.ones((1,), bool), tile_s[1:] != tile_s[:-1]])
    rank = pos - lax.cummax(jnp.where(start, pos, 0), axis=0)
    live = (tile_s < n_tiles) & (rank < K)
    row = jnp.where(live, tile_s, n_tiles)            # scratch row/col
    col = jnp.where(live, rank, K)
    idx = jnp.zeros((n_tiles + 1, K + 1), jnp.int32) \
        .at[row, col].set(jnp.where(live, idx_s, 0))
    score = jnp.full((n_tiles + 1, K + 1), NEG, jnp.float32) \
        .at[row, col].set(jnp.where(live, -depth[idx_s], NEG))
    return idx[:n_tiles, :K], score[:n_tiles, :K]


def sorted_assign_window(mx, my, rad, valid, depth, grid: TileGrid, *,
                         K: int, t0=None, n_local: Optional[int] = None,
                         tile_budget: Optional[int] = None):
    """Sort-based assignment of one raw splat table over a LOCAL tile
    window: the building block ``assign_tiles_sorted`` (full grid) and the
    distributed strip-local assignment (core.distributed) share.

    mx/my/rad/valid/depth (N,) splat columns; ``t0`` a (possibly traced)
    flat-tile offset and ``n_local`` the static window length — None/None
    means the full grid.  -> (idx (Tl, K) int32 LOCAL rows, score (Tl, K),
    overflow () int32) with exactly ``assign_tiles``'s slot semantics
    (bit-identical to the dense sweep restricted to the window whenever the
    budget covers every splat's bbox candidate count).
    """
    Tl = grid.n_tiles if n_local is None else int(n_local)
    N = mx.shape[0]
    if N == 0:
        return (jnp.zeros((Tl, K), jnp.int32),
                jnp.full((Tl, K), NEG, jnp.float32),
                jnp.zeros((), jnp.int32))
    if tile_budget is None and not isinstance(mx, jax.core.Tracer):
        # concrete splats (outside jit/vmap): size the budget exactly from
        # this table — provably no drops, the analogue of auto_tier_caps'
        # outside-jit auto-sizing.  Under tracing the static
        # DEFAULT_TILE_BUDGET applies; callers with a hot jitted loop
        # probe a budget host-side instead (render.tile_count_probe_jit).
        x0, x1, y0, y1 = _bbox_bounds(mx, my, rad, grid)
        cnt = jnp.maximum(x1 - x0 + 1, 0) * jnp.maximum(y1 - y0 + 1, 0)
        tile_budget = int(np.asarray(jnp.where(valid, cnt, 0).max()))
    budget = resolve_tile_budget(grid.n_tiles, tile_budget)
    tile, overflow = _expand_splat_tiles(
        mx, my, rad, valid, grid, budget=budget, t0=t0, n_local=Tl)
    rank_of, perm = _splat_depth_ranks(depth)
    rank_bits = max(1, (N - 1).bit_length())
    if Tl.bit_length() + rank_bits <= 32:
        idx, score = _segment_topk_packed(tile, rank_of, perm, depth,
                                          n_tiles=Tl, K=K,
                                          rank_bits=rank_bits)
    else:
        idx, score = _segment_topk_sort3(tile, depth, n_tiles=Tl, K=K)
    return idx, score, overflow


def assign_tiles_sorted(splats: Splats2D, grid: TileGrid, *, K: int = 64,
                        tile_budget: Optional[int] = None,
                        return_overflow: bool = False):
    """Sort-based top-K assignment: same contract as ``assign_tiles``.

    The GPU 3D-GS duplicate-and-sort scatter, TPU/static-shape adapted:
    every projected splat expands into the tiles its circle overlaps
    (static per-splat ``tile_budget`` bbox slots; ``None`` sizes it
    EXACTLY from the concrete table outside tracing, and falls back to
    min(T, DEFAULT_TILE_BUDGET) under jit — hot jitted loops probe a
    budget host-side via ``splat_tile_counts`` + ``auto_tile_budget``,
    which is what render_views / fit_partition(s) do), one global stable
    sort by
    (tile, depth, splat idx) groups and orders the candidates, and a
    segmented scatter writes each tile's first K into the (T, K)
    idx/score layout — O(N * B log(N * B)) work, independent of the tile
    count, vs the dense sweep's O(T * N).  The three-key order reproduces
    ``topk_by_score_then_index``'s (score desc, index asc) tie-break, so
    the output — indices, scores, empty slots (idx 0 / score NEG) — is
    BIT-IDENTICAL to the dense sweep whenever the budget covers every
    splat's bbox tile count (``benchmarks/bench_assign.py`` measures the
    crossover; tests/test_tiling_properties.py pins the parity).

    With ``return_overflow=True`` a third () int32 counts bbox candidate
    slots dropped past the budget (the same "0 means provably exact"
    telemetry contract as the coarse pre-cull's counter; conservative —
    dropped slots may not have been true hits).  On overflow a splat keeps
    its budget-first bbox tiles in row-major order, so the loss is
    arbitrary w.r.t. visibility: size budgets to the scene and monitor the
    counter in production.
    """
    idx, score, overflow = sorted_assign_window(
        splats.mean2d[..., 0], splats.mean2d[..., 1], splats.radius,
        splats.valid, splats.depth, grid, K=K, tile_budget=tile_budget)
    return (idx, score, overflow) if return_overflow else (idx, score)


# ---------------------------------------------------------------------------
# Variable-K occupancy binning (tiered rasterization)
# ---------------------------------------------------------------------------


class TierPlan(NamedTuple):
    """Static-shape dispatch schedule for tiered rasterization.

    tile_ids  per tier i: (cap_i,) int32 flat tile ids compacted to the
              front; slots past ``counts[i]`` hold M (one-past-the-end
              sentinel, M = the flat tile count) so scatters with
              ``mode="drop"`` ignore them.  cap_i is STATIC — it is part of
              the traced shape, so a jit cache keyed on the caps never
              recompiles for scenes with the same cap signature.
    counts    (n_tiers,) int32: tiles actually placed per tier (<= cap_i).
    overflow  () int32: tiles that fit no tier because every cap from their
              desired tier upward was full — those tiles are DROPPED from
              rasterization (they render as background).  0 whenever caps
              cover the true tier histogram (auto_tier_caps guarantees it).
    """
    tile_ids: Tuple[jax.Array, ...]
    counts: jax.Array
    overflow: jax.Array


def tile_occupancy(score):
    """(..., T, K) assignment scores -> (..., T) int32 live-entry counts.

    Occupancy is exact when the assignment K covered the true per-tile
    overlap depth; tiles saturating all K slots may be undercounted, which
    is why tiered callers assign at Kmax = the largest tier first.
    """
    return (score > NEG / 2).sum(axis=-1).astype(jnp.int32)


def tile_tiers(occupancy, k_tiers: Sequence[int]):
    """Per-tile tier index: the smallest tier whose K covers the occupancy.

    occupancy (..., T) int32 -> (..., T) int32 in [-1, n_tiers).  Empty
    tiles (occupancy 0) get tier -1 — "no rasterization work at all" (their
    output is exactly zero under the kernel semantics: every slot carries
    alpha 0, so color 0 / coverage 0).  Tiles whose occupancy exceeds even
    the top tier land in the top tier (truncation, same as the dense path
    at K = k_tiers[-1]).
    """
    kt = jnp.asarray(tuple(k_tiers), jnp.int32)
    covered = occupancy[..., None] <= kt               # (..., T, n_tiers)
    tier = jnp.argmax(covered, axis=-1).astype(jnp.int32)
    tier = jnp.where(covered.any(-1), tier, len(tuple(k_tiers)) - 1)
    return jnp.where(occupancy > 0, tier, -1)


def bin_tiles_by_occupancy(occupancy, k_tiers: Sequence[int],
                           tier_caps: Sequence[int]) -> TierPlan:
    """Bin flat tiles into K-tiers with STATIC per-tier capacities.

    occupancy (M,) int32; k_tiers strictly increasing per-tile K budgets;
    tier_caps same length, static ints.  Tiles fill their desired tier
    (smallest K covering their occupancy) in flat-tile-id order; a tile
    whose tier is full PROMOTES to the next larger tier (a bigger K is
    still exact), and tiles that fall off the top are counted in
    ``overflow`` and dropped.  Empty tiles (occupancy 0) are placed in no
    tier — the rasterizer's output for them is identically zero, so the
    scatter's zero-initialised image already IS their result.

    Fully jit-compatible: every output shape depends only on ``tier_caps``.
    """
    k_tiers = tuple(int(k) for k in k_tiers)
    tier_caps = tuple(int(c) for c in tier_caps)
    if len(tier_caps) != len(k_tiers):
        raise ValueError(f"{len(k_tiers)} tiers but {len(tier_caps)} caps")
    if any(b <= a for a, b in zip(k_tiers, k_tiers[1:])):
        raise ValueError(f"k_tiers must be strictly increasing: {k_tiers}")
    M = occupancy.shape[0]
    tier = tile_tiers(occupancy, k_tiers)
    ids = jnp.arange(M, dtype=jnp.int32)
    tile_ids, counts = [], []
    carry = jnp.zeros((M,), bool)           # overflow promoted from below
    for i, cap in enumerate(tier_caps):
        want = (tier == i) | carry
        rank = jnp.cumsum(want) - 1         # id-order position within tier
        take = want & (rank < cap)
        pos = jnp.where(take, jnp.minimum(rank, cap), cap)  # cap = scratch
        buf = jnp.full((cap + 1,), M, jnp.int32)
        buf = buf.at[pos].set(jnp.where(take, ids, M))
        tile_ids.append(buf[:cap])
        counts.append(jnp.minimum(want.sum(), cap).astype(jnp.int32))
        carry = want & ~take
    return TierPlan(tile_ids=tuple(tile_ids),
                    counts=jnp.stack(counts),
                    overflow=carry.sum().astype(jnp.int32))


#: shared "you called a host-side cap sizer under jit" guidance — tier caps
#: are STATIC shapes, so they can only be chosen from concrete telemetry
_TRACED_PROBE_MSG = (
    "{what} was called with traced (abstract) telemetry — it is running "
    "inside jit/vmap/grad/shard_map tracing.  Tier caps are STATIC kernel "
    "shapes, so they must be sized from CONCRETE host-side values.  Move "
    "the probe outside the traced computation: e.g. "
    "occ = occupancy_probe_jit(grid, sched.kmax)(g, cams); sched.probe(occ) "
    "on a single device, or reduce telemetry across a mesh with "
    "core.distributed.make_gs_probe / probe_gs_schedule and feed the "
    "fetched (counts, max_occ) to TierSchedule.probe_counts.  Under jit, "
    "pass the schedule's already-static (k_tiers, tier_caps) instead.")


def _reject_tracers(what: str, *vals):
    if any(isinstance(v, jax.core.Tracer) for v in vals):
        raise TypeError(_TRACED_PROBE_MSG.format(what=what))


def _tier_counts(occupancy, k_tiers: Sequence[int]):
    """Concrete (..., T) occupancy -> (per-tier worst-slice counts, max occ).

    counts[i] = max over leading batch slices of the number of tiles whose
    DESIRED tier (smallest covering K) is i — exactly what
    bin_tiles_by_occupancy fills before promotion, hence what caps must
    cover.  This is the host half of the cross-host telemetry contract:
    core.distributed.make_gs_probe computes the same counts per device and
    pmax-reduces them over the mesh.
    """
    occ = np.asarray(occupancy)
    if occ.size == 0:
        return [0] * len(tuple(k_tiers)), 0
    occ = occ.reshape(-1, occ.shape[-1])
    tiers = np.asarray(tile_tiers(jnp.asarray(occ), k_tiers))
    counts = [int((tiers == i).sum(axis=-1).max())
              for i in range(len(tuple(k_tiers)))]
    return counts, int(occ.max())


def caps_from_tier_counts(counts: Sequence[int], *, slack: float = 1.0,
                          round_to: int = 8, limit: int) -> Tuple[int, ...]:
    """Per-tier tile counts -> static caps: scale by ``slack``, round up to
    ``round_to`` (so nearby probes hash to the same jit cache entry), clamp
    at ``limit`` (the flat tile count of the binning domain, where binning
    provably cannot overflow).  Zero counts keep cap 0 — a zero-cost launch
    that keeps overflow telemetry live if occupancy later grows."""
    caps = []
    for c in counts:
        c = int(c)
        if c:
            c = int(np.ceil(c * slack))
            c = min(-(-c // round_to) * round_to, int(limit))
        caps.append(c)
    return tuple(caps)


def auto_tier_caps(occupancy, k_tiers: Sequence[int], *, slack: float = 1.0,
                   round_to: int = 8) -> Tuple[int, ...]:
    """Host-side cap sizing from CONCRETE occupancy counts.

    occupancy (..., T) (any leading batch axes, e.g. a view axis) ->
    static per-tier caps covering the worst slice of the batch, scaled by
    ``slack`` and rounded up to a multiple of ``round_to`` so nearby scenes
    hash to the same jit cache entry.  Raises under tracing — pass explicit
    ``tier_caps`` inside jit (see the error text for the full recipe).
    """
    _reject_tracers("auto_tier_caps", occupancy)
    occ = np.asarray(occupancy)
    counts, _ = _tier_counts(occ, k_tiers)
    return caps_from_tier_counts(counts, slack=slack, round_to=round_to,
                                 limit=occ.shape[-1] if occ.size else 0)


class TierSchedule:
    """Telemetry-driven (k_tiers, tier_caps) picker for tiered-by-default
    training.

    The tiered rasterizer needs two STATIC inputs — a K ladder and per-tier
    tile capacities — but occupancy is a moving target during training
    (densify adds splats, prune removes them).  TierSchedule closes that
    loop from the telemetry the pipeline already surfaces
    (``tile_occupancy`` of an assignment sweep; ``RenderOut.overflow`` /
    the distributed forward's overflow counter):

      probe(occupancy)   feed CONCRETE per-tile occupancy measured at the
          ladder's Kmax (``render.view_occupancy`` is the standard probe);
          caps are re-sized via ``auto_tier_caps``.  Unoccupied upper
          tiers get cap 0 — a zero-cost launch — which is what keeps the
          telemetry honest: if occupancy later grows into them, their
          tiles overflow LOUDLY (note_overflow grows the caps) instead of
          being silently truncated.  Host-side only — raises under
          tracing, exactly like auto_tier_caps.  ``trim=True`` opts into
          additionally trimming the ladder to the occupied prefix (sparse
          phases stop paying large-K assignment) — but a trimmed Kmax also
          CAPS the occupancy the training step can measure, so growth past
          it is invisible between probes; only enable it for runs that
          re-probe on a schedule (e.g. every densify event), never with a
          single init-time probe.
      train              pass ``(schedule.k_tiers, schedule.tier_caps)`` to
          the step factory; jit caches key on them, so the step recompiles
          only when the schedule actually changes (caps are rounded so
          nearby probes hash identically).
      note_overflow(ov, n_tiles)   a step that reports dropped tiles calls
          this: caps grow geometrically (clamped at ``n_tiles``, where
          binning provably cannot drop).  Returns True when caps changed —
          the signal to rebuild the step.
      densify / prune    occupancy shifted: probe again.

    The full lifecycle (probe -> train -> densify -> re-probe) is
    documented in docs/distributed-training.md.  The coarse pre-cull's
    budget counter (``assign_tiles(return_overflow=True)``) is a separate
    knob: it guards candidate lists, not tier capacities.
    """

    def __init__(self, k_tiers: Sequence[int] = (8, 32, 128), *,
                 slack: float = 1.25, round_to: int = 8,
                 growth: float = 2.0, trim: bool = False):
        ladder = tuple(int(k) for k in k_tiers)
        if not ladder or any(b <= a for a, b in zip(ladder, ladder[1:])):
            raise ValueError("k_tiers must be a non-empty strictly "
                             f"increasing ladder: {ladder}")
        self.ladder = ladder             # full ladder (probe depth = max)
        self.slack = float(slack)
        self.round_to = int(round_to)
        self.growth = float(growth)
        self.trim = bool(trim)           # see class docstring before enabling
        self.k_tiers: Tuple[int, ...] = ladder   # active tiers
        self.tier_caps: Optional[Tuple[int, ...]] = None  # None until probe

    @property
    def kmax(self) -> int:
        """Assignment depth probes must use (occupancy is a lower bound for
        tiles that saturate it, so probing shallower would under-cap)."""
        return self.ladder[-1]

    def probe(self, occupancy):
        """Re-pick (k_tiers, tier_caps) from concrete (..., T) occupancy.

        Returns the new ``(k_tiers, tier_caps)``.  Call after every
        densify/prune event — and at init — with occupancy measured at
        ``self.kmax``.  Raises with a how-to-fix recipe when called under
        JAX tracing (caps are static shapes; see ``probe_counts`` for the
        distributed/multi-host entry point).
        """
        _reject_tracers("TierSchedule.probe", occupancy)
        occ = np.asarray(occupancy)
        counts, max_occ = _tier_counts(occ, self.ladder)
        return self.probe_counts(counts, max_occ,
                                 n_tiles=occ.shape[-1] if occ.size else 0)

    def probe_counts(self, tier_counts, max_occ, *, n_tiles: int):
        """Re-pick (k_tiers, tier_caps) from REDUCED telemetry: per-tier
        worst-domain tile counts (over the FULL ladder) plus the max
        occupancy, with ``n_tiles`` the flat tile count of one binning
        domain (the cap clamp, where binning provably cannot drop).

        This is the cross-host probe entry point: every device of a mesh
        computes (counts, max_occ) over its own folded (Vl*T,) strip and a
        pmax reduction (core.distributed.make_gs_probe) makes the result
        identical on every host — so each host independently lands on the
        SAME cap ladder and compiles the identical program.  ``probe``
        delegates here after counting host-side.
        """
        _reject_tracers("TierSchedule.probe_counts", tier_counts, max_occ)
        counts = [int(c) for c in np.asarray(tier_counts).reshape(-1)]
        if len(counts) != len(self.ladder):
            raise ValueError(
                f"probe_counts got {len(counts)} tier counts for the "
                f"{len(self.ladder)}-tier ladder {self.ladder}; counts must "
                "be measured over the schedule's FULL ladder")
        max_occ = int(max_occ)
        # default: keep the FULL ladder — unoccupied upper tiers cost
        # nothing (cap 0 -> no launch) and keep overflow telemetry live.
        # trim=True: smallest ladder prefix covering max occupancy; a probe
        # that saturated Kmax keeps the full ladder (true occupancy may be
        # deeper than we could measure).  Counts are tier-for-tier valid on
        # the trimmed prefix: trimming only happens when max_occ fits it,
        # so the dropped upper tiers were empty.
        active = self.ladder
        if self.trim:
            for i, k in enumerate(self.ladder):
                if max_occ <= k and k < self.ladder[-1]:
                    active = self.ladder[: i + 1]
                    break
        self.k_tiers = active
        self.tier_caps = caps_from_tier_counts(
            counts[: len(active)], slack=self.slack, round_to=self.round_to,
            limit=n_tiles)
        return self.k_tiers, self.tier_caps

    def note_overflow(self, overflow, n_tiles: int) -> bool:
        """React to a step's dropped-tile counter: grow every cap by
        ``growth`` (clamped at ``n_tiles``, the flat tile count of the
        binning domain, where overflow is impossible).  Returns True when
        the caps changed — rebuild the step before the next iteration.
        No-op (False) when the counter is 0 or no probe has run yet."""
        ov = int(np.asarray(overflow).sum())
        if ov <= 0 or self.tier_caps is None:
            return False
        grown = tuple(
            min(int(n_tiles), max(self.round_to,
                                  int(np.ceil(c * self.growth))))
            for c in self.tier_caps)
        if grown == self.tier_caps:
            return False
        self.tier_caps = grown
        return True

    # -- (de)serialization: checkpoint the schedule alongside params so a
    # resumed run keeps its probed caps instead of re-probing from scratch

    def state_dict(self) -> dict:
        """JSON-able snapshot of the full schedule state (ladder, knobs,
        active tiers, caps).  Stored in CheckpointManager ``extra`` by
        ``fit_partition`` / ``core.distributed.fit_partitions``."""
        return {
            "ladder": list(self.ladder),
            "slack": self.slack,
            "round_to": self.round_to,
            "growth": self.growth,
            "trim": self.trim,
            "k_tiers": list(self.k_tiers),
            "tier_caps": None if self.tier_caps is None
            else list(self.tier_caps),
        }

    def load_state(self, state: dict) -> "TierSchedule":
        """Restore a ``state_dict`` snapshot IN PLACE (the checkpoint wins
        over constructor arguments) and return self."""
        ladder = tuple(int(k) for k in state["ladder"])
        if not ladder or any(b <= a for a, b in zip(ladder, ladder[1:])):
            raise ValueError(f"checkpointed ladder is invalid: {ladder}")
        self.ladder = ladder
        self.slack = float(state["slack"])
        self.round_to = int(state["round_to"])
        self.growth = float(state["growth"])
        self.trim = bool(state["trim"])
        self.k_tiers = tuple(int(k) for k in state["k_tiers"])
        caps = state["tier_caps"]
        self.tier_caps = None if caps is None else tuple(int(c) for c in caps)
        return self

    @classmethod
    def from_state(cls, state: dict) -> "TierSchedule":
        """Rebuild a schedule from a ``state_dict`` snapshot."""
        return cls(state["ladder"]).load_state(state)

    def __repr__(self):
        return (f"TierSchedule(k_tiers={self.k_tiers}, "
                f"tier_caps={self.tier_caps}, ladder={self.ladder})")


def splat_features(splats: Splats2D):
    """Per-splat kernel features: (N, FEAT_DIM) rows [mx, my, conicA, conicB,
    conicC, r, g, b, alpha, 0-pad]; invalid splats get alpha=0.
    Batch-polymorphic over leading dims ((..., N, FEAT_DIM) in general —
    the distributed path carries (P, N), render_batch (V, N))."""
    a, b, c = splats.cov2d[..., 0], splats.cov2d[..., 1], splats.cov2d[..., 2]
    det = jnp.maximum(a * c - b * b, 1e-12)
    conic = jnp.stack([c / det, -b / det, a / det], -1)      # (..., 3)
    alpha = jnp.where(splats.valid, splats.alpha, 0.0)
    feat = jnp.concatenate(
        [splats.mean2d, conic, splats.rgb, alpha[..., None]], axis=-1
    )                                                        # (..., 9)
    pad = FEAT_DIM - feat.shape[-1]
    return jnp.pad(feat, ((0, 0),) * (feat.ndim - 1) + ((0, pad),))


def gather_features_at(feat, idx, score):
    """Gather rows of a (N, FEAT_DIM) feature table into per-tile lists.

    feat (N, F); idx (..., K) int32 rows; score (..., K) with NEG marking
    empty slots -> (..., K, F).  Empty slots get alpha=0 -> contribute
    nothing.  This gather is plain jnp (differentiable); its transpose
    (scatter-add) is what routes the kernel's per-tile grads back to
    gaussians.  The tiered path calls this once per K-tier with that tier's
    compacted (cap_i, K_i) index table.
    """
    tile_feat = feat[idx]                                    # (..., K, F)
    live = score > NEG / 2                                   # (..., K)
    alpha = jnp.where(live, tile_feat[..., 8], 0.0)
    return jnp.concatenate(
        [tile_feat[..., :8], alpha[..., None], tile_feat[..., 9:]], axis=-1
    )


def gather_tile_features(splats: Splats2D, idx, score):
    """Pack per-tile splat features: (T, K, FEAT_DIM).

    splats with (N,) leading axis; idx/score (T, K) from assign_tiles.
    See gather_features_at for the slot semantics.
    """
    return gather_features_at(splat_features(splats), idx, score)


def untile_image(tiles, grid: TileGrid):
    """(T, 4, th, tw) kernel output -> (H, W, 4) image (cropped to grid size)."""
    th, tw = grid.tile_h, grid.tile_w
    img = tiles.reshape(grid.ny, grid.nx, 4, th, tw)
    img = img.transpose(0, 3, 1, 4, 2).reshape(grid.ny * th, grid.nx * tw, 4)
    return img[: grid.height, : grid.width]


def tile_image(img, grid: TileGrid):
    """(H, W, C) image -> (T, C, th, tw) tile layout (inverse of
    untile_image; pixels past the image edge — the grid's padding rows /
    columns — are zero-filled).  This is how host images become the
    ``gt_tiles`` batches the distributed step consumes; masks tile the same
    way via a singleton channel."""
    th, tw = grid.tile_h, grid.tile_w
    Hp, Wp = grid.ny * th, grid.nx * tw
    img = jnp.pad(img, ((0, Hp - img.shape[0]), (0, Wp - img.shape[1]),
                        (0, 0)))
    t = img.reshape(grid.ny, th, grid.nx, tw, img.shape[-1])
    return t.transpose(0, 2, 4, 1, 3).reshape(
        grid.n_tiles, img.shape[-1], th, tw)


# ---------------------------------------------------------------------------
# Serving-cache helpers: pose-bucket keys + assignment-table reuse
# ---------------------------------------------------------------------------

#: default pose-quantization resolution for the serving assignment cache:
#: bucket edge = 1/POSE_BINS in view-matrix / normalized-focal units, i.e.
#: sub-millimeter pose snapping on a unit-scale scene — fine enough that
#: snapped renders are visually identical, coarse enough that a camera
#: jittering around a viewpoint keeps hitting one bucket.
POSE_BINS = 1024.0


def quantize_pose(view, fx, fy, *, bins: float = POSE_BINS):
    """Quantize one camera pose onto a lattice of bucket edge ``1/bins``.

    -> ``(key, (view', fx', fy'))`` where ``key`` is a hashable tuple of
    int bucket coordinates (the 16 view-matrix entries + the two focals,
    focals scaled into the same lattice by 1/1024 so pixel-unit focal
    lengths quantize at a comparable relative resolution) and the primed
    triple is the CANONICAL pose — the dequantized lattice point, float32.

    The serving cache renders the canonical pose, not the requested one:
    any two cameras inside one bucket therefore produce *bit-identical*
    renders, and a cache HIT is bit-identical to the cold MISS that
    populated the entry by construction (the (T, K) table was extracted
    from the exact pose being rendered).  ``bins`` is the fidelity /
    hit-rate knob — snapping error is <= 1/(2*bins) per matrix entry.
    Entry-wise rounding leaves the rotation block orthonormal only to
    O(1/bins); projection never re-orthonormalizes, so this is pure pose
    noise, not a correctness hazard.
    """
    v = np.asarray(view, np.float64).reshape(4, 4)
    qv = np.rint(v * bins)
    qf = np.rint(np.asarray([fx, fy], np.float64) * (bins / 1024.0))
    key = tuple(int(x) for x in qv.ravel()) + tuple(int(x) for x in qf)
    canon_view = (qv / bins).astype(np.float32)
    canon_f = (qf * (1024.0 / bins)).astype(np.float32)
    return key, (canon_view, canon_f[0], canon_f[1])


def slice_table(idx, score, k: int):
    """Depth-``k`` prefix of a cached ``(..., K)`` assignment table.

    ``assign_tiles`` emits every tile's list in the total order
    (score desc, index asc), so the first ``k`` columns of a depth-K table
    ARE the depth-``k`` assignment, bit for bit — one cached Kmax table
    serves every ladder rung k <= Kmax without re-running assignment
    (``tests/test_serving.py::test_slice_table_prefix_property`` pins
    this against a direct K=k assignment).
    """
    if k > idx.shape[-1]:
        raise ValueError(
            f"slice_table: k={k} exceeds cached table depth {idx.shape[-1]}")
    return idx[..., :k], score[..., :k]
