"""TPU-adapted tile assignment: fixed-K per-tile gaussian lists.

GPU 3D-GS builds variable-length per-tile lists by radix-sorting (tile|depth)
keys with atomics.  On TPU we keep the top-K *front-most* gaussians per tile
(conservative circle/rect overlap test), built as a blockwise running top-k —
dense, regular compute, no atomics/sort (DESIGN.md §3).  K >= the local
overlap depth makes this exact; tests validate the approximation.

The resulting (T, K) index lists come out depth-sorted (top-k on -depth), which
is exactly the order front-to-back compositing needs.

Tiles are rectangular: the TPU-native shape is (8, 128) — one VREG row of
pixels per compositing step (DESIGN.md §3) — while CPU tests use small tiles.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.projection import Splats2D

NEG = -1e30

#: per-splat feature vector length fed to the rasterizer kernel
#: [mx, my, conicA, conicB, conicC, r, g, b, alpha, pad...] — padded to 16 so
#: the (K, F) VMEM block rows are power-of-two aligned.
FEAT_DIM = 16


class TileGrid(NamedTuple):
    width: int
    height: int
    tile_h: int = 8
    tile_w: int = 128

    @property
    def nx(self) -> int:
        return (self.width + self.tile_w - 1) // self.tile_w

    @property
    def ny(self) -> int:
        return (self.height + self.tile_h - 1) // self.tile_h

    @property
    def n_tiles(self) -> int:
        return self.nx * self.ny


def tile_bounds(grid: TileGrid):
    """Tile rects: (T, 2) lo, (T, 2) hi in pixel coords (x, y)."""
    ty, tx = jnp.meshgrid(
        jnp.arange(grid.ny), jnp.arange(grid.nx), indexing="ij"
    )
    lo = jnp.stack(
        [tx.reshape(-1) * grid.tile_w, ty.reshape(-1) * grid.tile_h], -1
    )
    hi = lo + jnp.array([grid.tile_w, grid.tile_h])
    return lo.astype(jnp.float32), hi.astype(jnp.float32)


def tile_origins(grid: TileGrid):
    """(T, 2) float32 pixel coords of each tile's top-left corner (x, y)."""
    lo, _ = tile_bounds(grid)
    return lo


# ---------------------------------------------------------------------------
# Coarse superblock pre-cull
# ---------------------------------------------------------------------------


def superblock_bounds(grid: TileGrid, sb: int):
    """Bounds of sb x sb tile superblocks: (S, 2) lo / hi pixel rects.

    The last row/column of superblocks may extend past the image — harmless,
    the coarse test is conservative (a superset of true tile overlaps).
    """
    sx = (grid.nx + sb - 1) // sb
    sy = (grid.ny + sb - 1) // sb
    syi, sxi = jnp.meshgrid(jnp.arange(sy), jnp.arange(sx), indexing="ij")
    lo = jnp.stack(
        [sxi.reshape(-1) * grid.tile_w * sb, syi.reshape(-1) * grid.tile_h * sb],
        -1,
    ).astype(jnp.float32)
    hi = lo + jnp.array([grid.tile_w * sb, grid.tile_h * sb], jnp.float32)
    return lo, hi


def coarse_candidates(mean2d, radius, valid, grid: TileGrid, *, sb: int,
                      budget: int, block: int = 4096):
    """Per-superblock candidate splat lists via one cheap circle/rect pass.

    -> cand (S, budget) int32 indices into the splat table; slots past the
    true per-superblock occupancy hold N (one-past-the-end sentinel).  If a
    superblock's occupancy exceeds ``budget``, the HIGHEST-INDEXED splats
    overflow and are dropped — table order, not depth order, so the loss is
    arbitrary w.r.t. visibility.  Callers must size the budget to the scene
    (assign_tiles' auto budget is documented there; budget >= occupancy
    makes the cull exact).

    Blockwise over gaussians like the dense sweep — O(S * block)
    temporaries, not O(S * N) — carrying per-superblock running counts so
    each block's hits compact to their final columns with one cumsum + one
    scatter (a vmapped size-bounded nonzero costs ~3x the whole dense
    assignment sweep on CPU).
    """
    lo, hi = superblock_bounds(grid, sb)             # (S, 2)
    N = mean2d.shape[0]
    S = lo.shape[0]
    block = min(block, max(N, 1))
    nb = (N + block - 1) // block
    Np = nb * block

    pad = lambda x, fill: jnp.pad(x, (0, Np - N), constant_values=fill)
    mx = pad(mean2d[:, 0], 0.0).reshape(nb, block)
    my = pad(mean2d[:, 1], 0.0).reshape(nb, block)
    rd = pad(radius, 0.0).reshape(nb, block)
    vd = pad(valid, False).reshape(nb, block)        # padded rows never hit
    idxb = jnp.arange(Np, dtype=jnp.int32).reshape(nb, block)

    rows = jnp.arange(S)[:, None]

    def body(carry, x):
        count, cand = carry                          # (S,), (S, budget+1)
        bmx, bmy, brd, bvd, bidx = x
        cx = jnp.clip(bmx[None, :], lo[:, :1], hi[:, :1])     # (S, block)
        cy = jnp.clip(bmy[None, :], lo[:, 1:], hi[:, 1:])
        dx = bmx[None, :] - cx
        dy = bmy[None, :] - cy
        hit = ((dx * dx + dy * dy) <= (brd * brd)[None, :]) & bvd[None, :]
        # overflow (and non-hits) land in scratch column ``budget`` ->
        # sliced off below
        pos = jnp.where(hit, count[:, None] + jnp.cumsum(hit, axis=1) - 1,
                        budget)
        pos = jnp.minimum(pos, budget)
        cand = cand.at[rows, pos].set(jnp.broadcast_to(bidx, hit.shape),
                                      mode="drop")
        return (count + hit.sum(axis=1), cand), None

    init = (jnp.zeros((S,), jnp.int32),
            jnp.full((S, budget + 1), N, jnp.int32))
    (_, cand), _ = lax.scan(body, init, (mx, my, rd, vd, idxb))
    return cand[:, :budget]


def _coarse_budget(N: int, S: int, K: int, budget) -> int:
    """Resolve the per-superblock candidate budget (see assign_tiles)."""
    if budget is None:
        # auto budget: 4x headroom over uniform splat->superblock occupancy.
        # On coarse grids (S < 8) the radius halo rivals the superblock size
        # and the uniform model breaks down — fall back to exact (budget=N).
        budget = N if S < 8 else max(4 * K, -(-4 * N // S))
    budget = min(max(int(budget), K), N)
    budget = -(-budget // 128) * 128 if budget >= 128 else budget
    return min(budget, N)


def _assign_tiles_coarse(splats: Splats2D, grid: TileGrid, *, K: int,
                         block: int, sb: int, budget: int):
    """Exact circle/rect top-K restricted to coarse-pass survivors.

    Same contract as assign_tiles; work drops from O(T*N) to
    O(S*N + T*budget) where S = T / sb^2.  Candidate features are gathered
    ONCE per superblock (gather volume S*budget rows, not T*budget) and the
    fine test runs superblock-major over (S, sb^2 tile slots, block) panes,
    scattered back to row-major tile order at the end.
    """
    T = grid.n_tiles
    N = splats.mean2d.shape[0]
    sx = (grid.nx + sb - 1) // sb
    sy = (grid.ny + sb - 1) // sb
    S, sb2 = sx * sy, sb * sb

    cand = coarse_candidates(splats.mean2d, splats.radius, splats.valid,
                             grid, sb=sb, budget=budget,
                             block=block)                      # (S, M)
    M = cand.shape[1]
    cb = min(block, M)
    nb = (M + cb - 1) // cb
    cand = jnp.pad(cand, ((0, 0), (0, nb * cb - M)), constant_values=N)

    # one gather per field per superblock; sentinel N -> fill (invalid)
    take = lambda arr, fill: jnp.take(arr, cand, axis=0, mode="fill",
                                      fill_value=fill)
    mean_c = take(splats.mean2d, 0.0)                # (S, Mp, 2)
    rad_c = take(splats.radius, 0.0)
    depth_c = take(splats.depth, 1e30)
    valid_c = take(splats.valid, False)

    # tile-slot rects per superblock, (S, sb2, 2); slots past the image edge
    # are dead weight (sliced away by the scatter-back below)
    syi, sxi = jnp.meshgrid(jnp.arange(sy), jnp.arange(sx), indexing="ij")
    jy, jx = jnp.meshgrid(jnp.arange(sb), jnp.arange(sb), indexing="ij")
    ty = syi.reshape(-1, 1) * sb + jy.reshape(-1)    # (S, sb2)
    tx = sxi.reshape(-1, 1) * sb + jx.reshape(-1)
    lo_sb = jnp.stack([tx * grid.tile_w, ty * grid.tile_h], -1) \
        .astype(jnp.float32)
    hi_sb = lo_sb + jnp.array([grid.tile_w, grid.tile_h], jnp.float32)

    xs = (mean_c.reshape(S, nb, cb, 2).transpose(1, 0, 2, 3),
          rad_c.reshape(S, nb, cb).transpose(1, 0, 2),
          depth_c.reshape(S, nb, cb).transpose(1, 0, 2),
          valid_c.reshape(S, nb, cb).transpose(1, 0, 2),
          cand.reshape(S, nb, cb).transpose(1, 0, 2))

    def body(carry, x):
        top_score, top_idx = carry                   # (S, sb2, K)
        mb, rb, db, vb, ci = x                       # (S, cb, ...)
        cx = jnp.clip(mb[:, None, :, 0], lo_sb[..., :1], hi_sb[..., :1])
        cy = jnp.clip(mb[:, None, :, 1], lo_sb[..., 1:], hi_sb[..., 1:])
        dx = mb[:, None, :, 0] - cx                  # (S, sb2, cb)
        dy = mb[:, None, :, 1] - cy
        hit = (dx * dx + dy * dy) <= (rb * rb)[:, None, :]
        score = jnp.where(hit & vb[:, None, :], -db[:, None, :], NEG)
        cat_s = jnp.concatenate([top_score, score], axis=-1)
        cat_i = jnp.concatenate(
            [top_idx, jnp.broadcast_to(ci[:, None, :].astype(jnp.int32),
                                       score.shape)], axis=-1)
        new_s, sel = lax.top_k(cat_s, K)
        new_i = jnp.take_along_axis(cat_i, sel, axis=-1)
        return (new_s, new_i), None

    init = (jnp.full((S, sb2, K), NEG, jnp.float32),
            jnp.zeros((S, sb2, K), jnp.int32))
    (score_s, idx_s), _ = lax.scan(body, init, xs)

    # scatter back: tile t (row-major) lives at slot (sbid, (ty%sb)*sb+tx%sb)
    tyf, txf = jnp.meshgrid(jnp.arange(grid.ny), jnp.arange(grid.nx),
                            indexing="ij")
    pos = ((tyf // sb) * sx + txf // sb) * sb2 + (tyf % sb) * sb + txf % sb
    pos = pos.reshape(-1)                            # (T,)
    score = score_s.reshape(S * sb2, K)[pos]
    idx = idx_s.reshape(S * sb2, K)[pos]
    # map sentinel slots back to a safe in-range index (they carry score NEG)
    idx = jnp.where(score > NEG / 2, idx, 0)
    return idx, score


def assign_tiles(splats: Splats2D, grid: TileGrid, *, K: int = 64,
                 block: int = 4096, coarse: Optional[int] = None,
                 coarse_budget: Optional[int] = None):
    """Top-K front-most gaussians per tile.

    Returns (idx (T, K) int32 into the splat table, score (T, K); score==NEG
    marks empty slots).  Blockwise over gaussians: carry a running top-k and
    merge each block with lax.top_k — O(T * N) work, O(T * block) memory.

    ``coarse=sb`` enables a two-level cull: a cheap circle/rect pass against
    sb x sb tile superblocks compacts per-superblock candidate lists of size
    ``coarse_budget`` (auto: N when the grid has S < 8 superblocks, else
    max(4K, ceil(4N/S)) — 4x headroom over uniform occupancy — rounded up
    to 128), and the exact per-tile test runs only against those survivors
    — O(S*N + T*budget) instead of O(T*N).  With budget >= true superblock
    occupancy the result is identical to the dense path on live slots
    (empty-slot idx values are unspecified in both paths); on overflow the
    highest-INDEXED candidates are dropped (arbitrary w.r.t. depth — see
    coarse_candidates), so size budgets generously.  When the resolved
    budget reaches N the coarse pass cannot cull anything, so the dense
    path runs directly (identical result, none of the pre-cull overhead).
    """
    if coarse is not None and coarse > 1:
        N = splats.mean2d.shape[0]
        S = (((grid.nx + coarse - 1) // coarse)
             * ((grid.ny + coarse - 1) // coarse))
        budget = _coarse_budget(N, S, K, coarse_budget) if N else 0
        if 0 < budget < N:
            return _assign_tiles_coarse(splats, grid, K=K, block=block,
                                        sb=coarse, budget=budget)
        # budget >= N (or empty table): fall through to the dense sweep
    lo, hi = tile_bounds(grid)                      # (T, 2)
    N = splats.mean2d.shape[0]
    block = min(block, max(N, K))
    nb = (N + block - 1) // block
    Np = nb * block

    def pad(x, fill=0.0):
        return jnp.pad(x, ((0, Np - N),) + ((0, 0),) * (x.ndim - 1),
                       constant_values=fill)

    mean = pad(splats.mean2d)
    rad = pad(splats.radius)
    depth = pad(splats.depth, 1e30)
    valid = jnp.pad(splats.valid, (0, Np - N), constant_values=False)

    meanb = mean.reshape(nb, block, 2)
    radb = rad.reshape(nb, block)
    depthb = depth.reshape(nb, block)
    validb = valid.reshape(nb, block)

    def body(carry, xs):
        top_score, top_idx = carry                  # (T, K)
        mb, rb, db, vb, b0 = xs
        # circle/rect overlap: clamp center to rect, compare distance to radius
        cx = jnp.clip(mb[None, :, 0], lo[:, :1], hi[:, :1])   # (T, block)
        cy = jnp.clip(mb[None, :, 1], lo[:, 1:], hi[:, 1:])
        dx = mb[None, :, 0] - cx
        dy = mb[None, :, 1] - cy
        hit = (dx * dx + dy * dy) <= (rb * rb)[None, :]
        score = jnp.where(hit & vb[None, :], -db[None, :], NEG)  # (T, block)
        idx = b0 + jnp.arange(block, dtype=jnp.int32)[None, :]
        cat_s = jnp.concatenate([top_score, score], axis=1)
        cat_i = jnp.concatenate([top_idx, jnp.broadcast_to(idx, score.shape)], 1)
        new_s, sel = lax.top_k(cat_s, K)
        new_i = jnp.take_along_axis(cat_i, sel, axis=1)
        return (new_s, new_i), None

    T = grid.n_tiles
    init = (jnp.full((T, K), NEG, jnp.float32), jnp.zeros((T, K), jnp.int32))
    b0s = jnp.arange(nb, dtype=jnp.int32) * block
    (score, idx), _ = lax.scan(body, init, (meanb, radb, depthb, validb, b0s))
    return idx, score


def splat_features(splats: Splats2D):
    """Per-splat kernel features (..., FEAT_DIM); invalid splats get alpha=0.
    Batch-polymorphic over leading dims."""
    a, b, c = splats.cov2d[..., 0], splats.cov2d[..., 1], splats.cov2d[..., 2]
    det = jnp.maximum(a * c - b * b, 1e-12)
    conic = jnp.stack([c / det, -b / det, a / det], -1)      # (..., 3)
    alpha = jnp.where(splats.valid, splats.alpha, 0.0)
    feat = jnp.concatenate(
        [splats.mean2d, conic, splats.rgb, alpha[..., None]], axis=-1
    )                                                        # (..., 9)
    pad = FEAT_DIM - feat.shape[-1]
    return jnp.pad(feat, ((0, 0),) * (feat.ndim - 1) + ((0, pad),))


def gather_tile_features(splats: Splats2D, idx, score):
    """Pack per-tile splat features: (T, K, FEAT_DIM).

    Empty slots (score==NEG) get alpha=0 -> contribute nothing.  This gather is
    plain jnp (differentiable); its transpose (scatter-add) is what routes the
    kernel's per-tile grads back to gaussians.
    """
    feat = splat_features(splats)                            # (N, F)
    tile_feat = feat[idx]                                    # (T, K, F)
    live = score > NEG / 2                                   # (T, K)
    alpha = jnp.where(live, tile_feat[..., 8], 0.0)
    return jnp.concatenate(
        [tile_feat[..., :8], alpha[..., None], tile_feat[..., 9:]], axis=-1
    )


def untile_image(tiles, grid: TileGrid):
    """(T, 4, th, tw) kernel output -> (H, W, 4) image (cropped to grid size)."""
    th, tw = grid.tile_h, grid.tile_w
    img = tiles.reshape(grid.ny, grid.nx, 4, th, tw)
    img = img.transpose(0, 3, 1, 4, 2).reshape(grid.ny * th, grid.nx * tw, 4)
    return img[: grid.height, : grid.width]
