"""Per-partition 3D-GS trainer: per-group Adam + densify/clone/split/prune.

Faithful to Kerbl et al. training dynamics, jit-stable on TPU (DESIGN.md §3):
the gaussian buffer has *fixed capacity* with an ``active`` mask; densify
writes children into free slots (budgeted, ``max_new`` per event) and prune
clears the mask — no reallocation inside jit.  Densification pressure is the
accumulated positional gradient norm, as in the reference.

Every partition of the paper's pipeline runs one instance of this trainer on
its own (owned + ghost) gaussians with its own masked loss; partitions never
exchange gradients (paper §II step 5).
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import NamedTuple, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cameras import Camera, select
from repro.core.gaussians import Gaussians
from repro.core.masking import gs_loss
from repro.core.render import (occupancy_probe_jit, render_batch,
                               resolve_assignment)
from repro.core.tiling import (DEFAULT_TILE_BUDGET, TierSchedule, TileGrid,
                               grow_tile_budget)


@dataclasses.dataclass(frozen=True)
class GSTrainCfg:
    """Trainer config.  Mesh-axis / tier-schedule contract:

    The trainer rasterizes with OCCUPANCY TIERS by default: ``k_tiers``
    resolves to a K ladder (``"auto"`` derives one from ``K``; an explicit
    tuple pins it; ``None`` — or setting ``dense_k=`` — escapes back to the
    dense fixed-K rasterizer, exactly the pre-tiered behaviour).  ``K`` /
    ``dense_k`` is the dense path's per-tile list depth; in tiered mode the
    assignment depth is the ladder's Kmax and K is ignored.  Tier CAPS are
    not config: they are telemetry, owned by a ``core.tiling.TierSchedule``
    that ``fit_partition`` (and the distributed driver) re-probes after
    every densify/prune; ``tier_slack`` is that schedule's cap headroom.

    On the distributed ("part", "view") mesh (core/distributed.py):
    gaussians + optimizer state are sharded over "part" and replicated over
    "view"; the ``view_batch`` view minibatch is sharded over "view"
    (``view_batch`` must divide by the axis size); ``gather_mode`` /
    ``strip_budget`` shape the "part"-axis table gather and the
    "model"-axis strip work respectively.
    """
    # per-group LRs (3D-GS reference); lr_means is additionally scaled by the
    # scene extent, as in the reference implementation
    lr_means: float = 1.6e-4
    lr_scales: float = 5e-3
    lr_quats: float = 1e-3
    lr_opacity: float = 5e-2
    lr_colors: float = 2.5e-3
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-15
    lambda_dssim: float = 0.2
    K: int = 64
    tile_h: int = 8
    tile_w: int = 16            # CPU default; production (TPU) uses 8x128
    bg: float = 1.0             # white background (paper renders)
    impl: str = "auto"
    view_batch: int = 1         # views per minibatch step (loss = view mean)
    coarse: Optional[int] = None  # superblock pre-cull factor (tiling.py)
    # tile-assignment algorithm: "auto" (sort-based scatter, O(N*B log), on
    # grids of >= tiling.SORTED_MIN_TILES tiles; the O(T*N) dense sweep
    # below — the measured CPU crossover) | "sorted" | "dense" (escape
    # hatch / test oracle); assign_budget is the sorted path's static
    # per-splat tile budget (None = auto, core.tiling.resolve_tile_budget)
    assign_impl: str = "auto"
    assign_budget: Optional[int] = None
    # rasterization schedule: occupancy-tiered by DEFAULT
    #   "auto"  ladder derived from K (e.g. K=64 -> (8, 32, 64))
    #   tuple   explicit ladder, e.g. (16, 64, 256)
    #   None    dense rasterization at K
    k_tiers: Union[str, Tuple[int, ...], None] = "auto"
    dense_k: Optional[int] = None   # escape hatch: dense-K at this depth
    #                                 (disables tiering entirely)
    tier_slack: float = 1.25        # TierSchedule cap headroom over probes
    # densification
    densify_grad_thresh: float = 5e-6
    percent_dense: float = 0.01     # split/clone size boundary (x extent)
    max_new: int = 512              # per densify event (static budget)
    # hard ceiling on LIVE splats per partition (GeoGaussian-style
    # ``num_max``): densify stops adding children once the live count
    # reaches the cap, so memory stays bounded over long / timeseries
    # runs.  None = uncapped (the pre-timeseries behaviour).  Prune still
    # runs below the cap; the cap only gates GROWTH.
    densify_cap: Optional[int] = None
    prune_opacity: float = 0.005
    prune_scale: float = 0.5        # x extent: prune absurdly large splats
    split_shrink: float = 1.6
    # distributed-step options (core/distributed.py; §Perf GS hillclimb)
    gather_mode: str = "f32"        # "f32" (paper baseline) | "split" (bf16)
    strip_budget: float = 1.0       # <1: per-strip candidate prefilter
    # sparse-overlap splat exchange (core/distributed.py): replace the
    # "part"-axis full-table all-gather with a lax.all_to_all under a
    # static per-(src, dst)-edge budget — each device sends only the splats
    # whose tile bboxes overlap the destination's sub-strip.
    # ``exchange_budget=None`` lets fit_partitions probe the budget
    # (distributed.probe_gs_exchange, with ExchangeSchedule slack) and grow
    # it on overflow; an explicit int pins it.
    exchange: bool = False
    exchange_budget: Optional[int] = None
    # mixed precision (core/dtypes.py): "f32" (default; bit-identical to
    # pre-policy builds) | "bf16" — feature tables / collective payloads
    # store bf16, every accumulator (kernel planes, loss, Adam state)
    # stays f32.  Parity per policy is pinned by the per-dtype tolerance
    # ladder in tests/ (docs/mixed-precision.md).
    dtype_policy: str = "f32"
    # gradient compression for the DISTRIBUTED step (optim/compress.py):
    # "none" | "bf16" (stateless round-trip, 2x wire) | "int8" (per-tensor
    # scale + error feedback, 4x wire).  With a mode != "none" the
    # make_gs_train_step signature gains an error-feedback tree that
    # fit_partitions carries in step state and through checkpoints.
    grad_compress: str = "none"

    def __post_init__(self):
        from repro.core.dtypes import check_policy
        check_policy(self.dtype_policy)
        if self.grad_compress not in ("none", "bf16", "int8"):
            raise ValueError(
                f"unknown grad_compress {self.grad_compress!r}; expected "
                "'none', 'bf16' or 'int8'")

    def resolved_k_tiers(self) -> Optional[Tuple[int, ...]]:
        """The active K ladder, or None for dense rasterization.

        ``dense_k`` (the escape hatch) wins over everything; ``"auto"``
        builds a K-capped ladder so the tiered default never assigns deeper
        (= never costs more in the worst case) than the dense K it
        replaces."""
        if self.dense_k is not None or self.k_tiers is None:
            return None
        if self.k_tiers == "auto":
            ladder = []
            for k in (self.K // 8, self.K // 2, self.K):
                k = int(k)
                if k >= 1 and (not ladder or k > ladder[-1]):
                    ladder.append(k)
            return tuple(ladder)
        return tuple(int(k) for k in self.k_tiers)

    @property
    def assign_K(self) -> int:
        """Dense-path assignment depth (``dense_k`` overrides ``K``)."""
        return self.dense_k if self.dense_k is not None else self.K

    def tier_schedule(self) -> Optional[TierSchedule]:
        """A fresh TierSchedule for this cfg, or None when training dense."""
        kt = self.resolved_k_tiers()
        return None if kt is None else TierSchedule(kt, slack=self.tier_slack)


class GSOptState(NamedTuple):
    m: dict
    v: dict
    step: jax.Array
    grad_accum: jax.Array    # (N,) accumulated positional grad norms
    grad_count: jax.Array    # (N,)


def init_opt(g: Gaussians) -> GSOptState:
    """Fresh optimizer state; layout-polymorphic — the densify-stat
    accumulators take the gaussian-index shape, so the single-partition
    (N, ...) layout gets (N,) and the distributed batched (P, N, ...)
    layout gets (P, N)."""
    tr = g.trainable()
    zeros = lambda: jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), tr)
    acc = g.means.shape[:-1]
    return GSOptState(zeros(), zeros(), jnp.zeros((), jnp.int32),
                      jnp.zeros(acc, jnp.float32), jnp.zeros(acc, jnp.float32))


def group_lrs(cfg: GSTrainCfg, extent: float) -> dict:
    return {
        "means": cfg.lr_means * extent,
        "log_scales": cfg.lr_scales,
        "quats": cfg.lr_quats,
        "opacity_logit": cfg.lr_opacity,
        "colors": cfg.lr_colors,
    }


def _as_view_batch(cam: Camera, gt, mask):
    """Canonicalize (cam, gt, mask) to carry a leading view axis V.

    Accepts either a single view (cam.view (4,4), gt (H,W,3)) or a view
    minibatch (cam.view (V,4,4), gt (V,H,W,3)); the single-view form becomes
    a V=1 batch.  Trace-time branch: jit re-traces per input rank anyway.
    """
    if cam.view.ndim == 2:
        cam = Camera(cam.view[None], jnp.reshape(cam.fx, (1,)),
                     jnp.reshape(cam.fy, (1,)), cam.width, cam.height)
        gt = gt[None]
        mask = None if mask is None else mask[None]
    return cam, gt, mask


#: sentinel: "no explicit k_tiers argument — resolve from the train cfg"
_FROM_CFG = object()


def _check_resume_policy(extra: dict, cfg: GSTrainCfg):
    """Refuse to resume across a dtype-policy / grad-compress boundary.

    A checkpoint trains forward under the SAME numerics it was written
    with: silently switching dtype_policy mid-run would fork the loss
    curve with no record, and switching grad_compress changes the step
    state layout (the int8 error-feedback tree).  Checkpoints that predate
    the knobs carry no record and are treated as the defaults
    ("f32"/"none").  Both drivers (fit_partition / fit_partitions) call
    this on every restore — the CLI surfaces it as a loud, documented
    error rather than a silent divergence."""
    saved_pol = extra.get("dtype_policy", "f32")
    if saved_pol != cfg.dtype_policy:
        raise ValueError(
            f"checkpoint was written under dtype_policy={saved_pol!r} but "
            f"this run uses {cfg.dtype_policy!r}; resume must keep the "
            f"policy — rerun with --dtype-policy {saved_pol} or point "
            "--ckpt-dir at a fresh directory")
    saved_gc = extra.get("grad_compress", "none")
    if saved_gc != cfg.grad_compress:
        raise ValueError(
            f"checkpoint was written under grad_compress={saved_gc!r} but "
            f"this run uses {cfg.grad_compress!r}; resume must keep the "
            "mode (the error-feedback state rides the checkpoint) — rerun "
            f"with --grad-compress {saved_gc} or use a fresh --ckpt-dir")


def make_train_step(cfg: GSTrainCfg, grid: TileGrid, extent: float, *,
                    k_tiers=_FROM_CFG, tier_caps: Optional[tuple] = None,
                    return_overflow: bool = False,
                    assign_impl=_FROM_CFG, assign_budget=_FROM_CFG):
    """Minibatch-of-views train step: cam/gt/mask may carry a leading view
    axis (loss is averaged over the batch); plain single-view inputs still
    work (treated as V=1).

    Rasterization defaults to OCCUPANCY TIERS (``k_tiers`` unset pulls
    ``cfg.resolved_k_tiers()``; ``cfg.dense_k=`` escapes to dense-K).  An
    explicit ``k_tiers=None`` forces dense; a tuple pins the ladder.
    ``tier_caps`` must be static under jit — None falls back to the
    always-exact (but unmeasured) full-grid caps; ``fit_partition`` passes
    measured caps from its ``TierSchedule`` instead.  With
    ``return_overflow=True`` the step returns ``(g, opt, loss, overflow)``
    where overflow is a dict of () int32 counters summed over the view
    batch: ``"tiles"`` — the tiered dropped-tile counter (always 0 on the
    dense path) that ``TierSchedule.note_overflow`` consumes — and
    ``"assign"`` — the tile-ASSIGNMENT budget counter (sorted-path bbox
    slots dropped past ``assign_budget``; always 0 on the dense sweep)
    that the driver feeds to ``tiling.grow_tile_budget`` so radii drifting
    past the probe slack between densify events grow the budget instead of
    truncating silently.  ``assign_impl`` /
    ``assign_budget`` override the cfg's tile-assignment knobs —
    ``fit_partition`` passes host-probed values (a static budget sized
    from concrete bbox counts, or a demotion of "auto" to dense for
    big-splat scenes)."""
    lrs = group_lrs(cfg, extent)
    if k_tiers is _FROM_CFG:
        k_tiers = cfg.resolved_k_tiers()
    if assign_impl is _FROM_CFG:
        assign_impl = cfg.assign_impl
    if assign_budget is _FROM_CFG:
        assign_budget = cfg.assign_budget
    if k_tiers is not None:
        k_tiers = tuple(int(k) for k in k_tiers)
        if tier_caps is None:
            # always-exact fallback: every tier can hold the whole grid
            tier_caps = (grid.n_tiles,) * len(k_tiers)
        tier_caps = tuple(int(c) for c in tier_caps)

    def loss_fn(tr, g: Gaussians, cam: Camera, gt, mask):
        gg = g.with_trainable(tr)
        cam, gt, mask = _as_view_batch(cam, gt, mask)
        out = render_batch(gg, cam, grid, K=cfg.assign_K, impl=cfg.impl,
                           bg=cfg.bg, coarse=cfg.coarse,
                           k_tiers=k_tiers, tier_caps=tier_caps,
                           assign_impl=assign_impl,
                           assign_budget=assign_budget,
                           dtype_policy=cfg.dtype_policy)
        per_view = partial(gs_loss, lambda_dssim=cfg.lambda_dssim)
        if mask is None:
            losses = jax.vmap(lambda p, t: per_view(p, t, None))(out.rgb, gt)
        else:
            losses = jax.vmap(per_view)(out.rgb, gt, mask)
        overflow = {
            "tiles": (jnp.zeros((), jnp.int32) if out.overflow is None
                      else out.overflow.sum().astype(jnp.int32)),
            "assign": (jnp.zeros((), jnp.int32)
                       if out.assign_overflow is None
                       else out.assign_overflow.sum().astype(jnp.int32)),
        }
        return losses.mean(), overflow

    def step(g: Gaussians, opt: GSOptState, cam: Camera, gt, mask=None):
        (loss, overflow), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            g.trainable(), g, cam, gt, mask)
        step_i = opt.step + 1
        bc1 = 1.0 - cfg.b1 ** step_i.astype(jnp.float32)
        bc2 = 1.0 - cfg.b2 ** step_i.astype(jnp.float32)

        def upd(name, p, gr, m, v):
            gr = gr.astype(jnp.float32)
            m = cfg.b1 * m + (1 - cfg.b1) * gr
            v = cfg.b2 * v + (1 - cfg.b2) * gr * gr
            d = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            return (p - lrs[name] * d).astype(p.dtype), m, v

        tr = g.trainable()
        new_tr, new_m, new_v = {}, {}, {}
        for k in tr:
            new_tr[k], new_m[k], new_v[k] = upd(k, tr[k], grads[k],
                                                opt.m[k], opt.v[k])
        gnorm = jnp.linalg.norm(grads["means"].astype(jnp.float32), axis=-1)
        new_opt = GSOptState(
            m=new_m, v=new_v, step=step_i,
            grad_accum=opt.grad_accum + gnorm,
            grad_count=opt.grad_count + (gnorm > 0),
        )
        out = (g.with_trainable(new_tr), new_opt, loss)
        return out + (overflow,) if return_overflow else out

    return step


# ---------------------------------------------------------------------------
# Densification (fixed-capacity, budgeted)
# ---------------------------------------------------------------------------


def densify_and_prune(g: Gaussians, opt: GSOptState, key, cfg: GSTrainCfg,
                      extent: float):
    """One densify event. Static shapes throughout: up to ``cfg.max_new``
    sources act; children land in free slots found via fixed-size nonzero.
    ``cfg.densify_cap`` additionally bounds the LIVE count: only enough
    children to reach the cap are admitted (the valid (src, free) pairs
    form a prefix of the fixed-size nonzero output, so the cap is a prefix
    mask — static shapes preserved)."""
    cap = g.capacity
    M = min(cfg.max_new, cap)
    avg = opt.grad_accum / jnp.maximum(opt.grad_count, 1.0)
    scales = jnp.exp(g.log_scales)
    smax = scales.max(axis=-1)

    hot = (avg > cfg.densify_grad_thresh) & g.active
    is_split = hot & (smax > cfg.percent_dense * extent)

    src_idx = jnp.nonzero(hot, size=M, fill_value=-1)[0]
    free_idx = jnp.nonzero(~g.active, size=M, fill_value=-1)[0]
    ok = (src_idx >= 0) & (free_idx >= 0)
    if cfg.densify_cap is not None:
        headroom = jnp.maximum(
            jnp.int32(cfg.densify_cap) - g.active.sum().astype(jnp.int32), 0)
        ok = ok & (jnp.arange(M) < headroom)
    # OOB dest indices are dropped by .at[...] mode="drop"
    dest = jnp.where(ok, free_idx, cap)
    src = jnp.where(ok, src_idx, 0)

    src_split = is_split[src]
    # split offset: sample along the gaussian's own shape (R @ (s * eps))
    eps = jax.random.normal(key, (M, 3))
    from repro.core.gaussians import quat_to_rotmat
    R = quat_to_rotmat(g.quats[src])
    offset = jnp.einsum("nij,nj->ni", R, jnp.exp(g.log_scales[src]) * eps)
    offset = jnp.where(src_split[:, None], offset, 0.0)
    shrink = jnp.where(src_split[:, None],
                       jnp.log(cfg.split_shrink), 0.0)

    child_means = g.means[src] + offset
    child_ls = g.log_scales[src] - shrink

    at = lambda arr, idx, val: arr.at[idx].set(val, mode="drop")
    new = g._replace(
        means=at(g.means, dest, child_means),
        log_scales=at(g.log_scales, dest, child_ls),
        quats=at(g.quats, dest, g.quats[src]),
        opacity_logit=at(g.opacity_logit, dest, g.opacity_logit[src]),
        colors=at(g.colors, dest, g.colors[src]),
        active=at(g.active, dest, ok),
        owner=at(g.owner, dest, g.owner[src]),
    )
    # split sources shrink in place (the "two children" of the reference:
    # one stays in the source slot, one lands in the free slot)
    upd_src = jnp.where(ok & src_split, src, cap)
    new = new._replace(
        means=new.means.at[upd_src].add(-offset, mode="drop"),
        log_scales=new.log_scales.at[upd_src].add(-jnp.log(cfg.split_shrink),
                                                  mode="drop"),
    )

    # prune: transparent or absurdly large
    alpha = jax.nn.sigmoid(new.opacity_logit)
    keep = (alpha > cfg.prune_opacity) & (jnp.exp(new.log_scales).max(-1)
                                          < cfg.prune_scale * extent)
    new = new._replace(active=new.active & keep)

    # zero adam moments of written slots; reset densify stats
    def zero_at(tree):
        return jax.tree.map(lambda x: x.at[dest].set(0.0, mode="drop"), tree)

    opt = GSOptState(
        m=zero_at(opt.m), v=zero_at(opt.v), step=opt.step,
        grad_accum=jnp.zeros_like(opt.grad_accum),
        grad_count=jnp.zeros_like(opt.grad_count),
    )
    return new, opt


def reset_opacity(g: Gaussians, ceiling: float = 0.01) -> Gaussians:
    """Periodic opacity clamp (reference: counters floaters)."""
    cap_logit = jnp.log(ceiling / (1 - ceiling))
    return g._replace(opacity_logit=jnp.minimum(g.opacity_logit, cap_logit))


# ---------------------------------------------------------------------------
# Convenience host-loop trainer (examples / benchmarks / tests)
# ---------------------------------------------------------------------------


def fit_partition(g: Gaussians, cams: Camera, gts, masks, cfg: GSTrainCfg,
                  *, steps: int, extent: float, key=None,
                  densify_every: int = 0, densify_from: int = 100,
                  log_every: int = 0, grid: Optional[TileGrid] = None,
                  view_batch: Optional[int] = None,
                  schedule: Optional[TierSchedule] = None,
                  ckpt=None, ckpt_every: int = 0,
                  partition: Optional[int] = None,
                  densify_cap: Optional[int] = None):
    """Train one partition for ``steps`` steps cycling over its camera set.

    gts: (V, H, W, 3); masks: (V, H, W) bool or None.  Returns
    (g, opt, losses).  Each step consumes a minibatch of ``view_batch``
    consecutive views (default cfg.view_batch; loss is the view mean)
    rendered through one batched dispatch.

    Tier-schedule lifecycle (tiered-by-default; ``cfg.dense_k=`` opts out):
    a ``TierSchedule`` (``schedule=`` or a fresh one from the cfg) is
    PROBED on the first minibatch's occupancy — unless it already carries
    caps (a resumed/pre-probed schedule trains as-is) — the step trains
    with its static (k_tiers, tier_caps), each densify/prune RE-PROBES
    (occupancy shifted), and any step that reports tiered overflow grows
    the caps — so every cap change is a bounded, telemetry-driven recompile
    and dropped tiles never silently persist.

    Checkpoint/resume: with ``ckpt`` (a runtime.CheckpointManager) the
    newest complete checkpoint is restored — (g, opt) plus the
    TierSchedule state stored alongside them, so the resumed run keeps its
    probed caps instead of re-probing from scratch — the densify key
    stream is fast-forwarded, and training continues from that step;
    ``ckpt_every`` saves periodically (under ``partition_<k>/`` when
    ``partition`` is given).  ``losses`` covers only the steps this call
    actually ran.  core.distributed.fit_partitions is the mesh-parallel
    mirror of this loop.
    """
    if grid is None:
        grid = TileGrid(cams.width, cams.height, cfg.tile_h, cfg.tile_w)
    if key is None:
        key = jax.random.PRNGKey(0)
    sched = schedule if schedule is not None else cfg.tier_schedule()
    # densify_cap= overrides the cfg knob (the timeseries driver passes a
    # computed cap); only the densify closure sees the replaced cfg
    dcfg = dataclasses.replace(cfg, densify_cap=densify_cap) \
        if densify_cap is not None else cfg
    densify = jax.jit(partial(densify_and_prune, cfg=dcfg, extent=extent))
    opt = init_opt(g)
    n_views = gts.shape[0]
    vb = max(1, min(view_batch or cfg.view_batch, n_views))

    start = 0
    if ckpt is not None:
        (g, opt), extra, latest = ckpt.restore_latest((g, opt),
                                                      partition=partition)
        if latest is not None:
            _check_resume_policy(extra, cfg)
            if sched is not None and extra.get("schedule"):
                sched.load_state(extra["schedule"])
            start = latest
    # fast-forward the densify key stream consumed before ``start`` so a
    # resumed run splits the same keys as an uninterrupted one
    for i in range(start):
        if densify_every and i >= densify_from \
                and (i + 1) % densify_every == 0:
            key = jax.random.split(key)[0]

    probe_vi = jnp.arange(min(n_views, max(vb, 2))) % n_views

    # tile-assignment resolution (render.resolve_assignment: probe a
    # static sorted budget from the whole rig's concrete bbox counts, or
    # demote "auto" to dense for big-splat scenes) — re-resolved after
    # every densify, since radii are trained parameters
    assign = {"impl": cfg.assign_impl, "budget": cfg.assign_budget}

    def probe_assign(gg):
        impl, budget = resolve_assignment(gg, cams, grid,
                                          assign_impl=cfg.assign_impl,
                                          assign_budget=cfg.assign_budget)
        assign.update(impl=impl, budget=budget)

    def reprobe(gg):
        occ = occupancy_probe_jit(grid, sched.kmax, cfg.coarse,
                                  assign["impl"], assign["budget"])(
            gg, select(cams, probe_vi))
        sched.probe(occ)

    step_cache = {}

    def get_step():
        spec = ((sched.k_tiers, sched.tier_caps) if sched else None,
                assign["impl"], assign["budget"])
        if spec not in step_cache:
            step_cache[spec] = jax.jit(make_train_step(
                cfg, grid, extent,
                k_tiers=sched.k_tiers if sched else None,
                tier_caps=sched.tier_caps if sched else None,
                return_overflow=True,
                assign_impl=assign["impl"], assign_budget=assign["budget"]))
        return step_cache[spec]

    def note_assign_overflow(ov):
        # the sorted path's static budget truncated candidates this step
        # (radii drifted past the probe slack between densify events): grow
        # it geometrically — the next get_step() rebuilds — mirroring
        # TierSchedule.note_overflow.  Never silent truncation.
        if assign["impl"] != "sorted" or int(np.asarray(ov).sum()) <= 0:
            return
        cur = assign["budget"] or DEFAULT_TILE_BUDGET
        assign["budget"] = grow_tile_budget(cur, grid.n_tiles)

    probe_assign(g)
    if sched is not None and sched.tier_caps is None:
        reprobe(g)
    losses = []
    for i in range(start, steps):
        vi = (i * vb + jnp.arange(vb)) % n_views
        cam = select(cams, vi)
        mask = None if masks is None else masks[vi]
        out = get_step()(g, opt, cam, gts[vi], mask)
        g, opt, loss = out[:3]
        losses.append(float(loss))
        if sched is not None:
            # a non-zero counter grows the caps for the NEXT steps (this
            # step dropped a few tiles — rendered as background in the
            # loss — a one-step blip, not a persistent silent truncation)
            sched.note_overflow(out[3]["tiles"], grid.n_tiles)
        note_assign_overflow(out[3]["assign"])
        if densify_every and i >= densify_from and (i + 1) % densify_every == 0:
            key, sub = jax.random.split(key)
            g, opt = densify(g, opt, sub)
            probe_assign(g)     # splat sizes shifted: re-size the budget
            if sched is not None:
                reprobe(g)      # occupancy shifted: re-pick tiers/caps
        if ckpt is not None and ckpt_every and (i + 1) % ckpt_every == 0:
            ckpt.save(i + 1, (g, opt), partition=partition,
                      extra={"schedule":
                             sched.state_dict() if sched else None,
                             "dtype_policy": cfg.dtype_policy,
                             "grad_compress": cfg.grad_compress})
        if log_every and (i + 1) % log_every == 0:
            print(f"  step {i+1:5d}  loss {losses[-1]:.4f} "
                  f"active {int(g.active.sum())}")
    return g, opt, losses
