"""Data substrate: analytic volumes, in-JAX isosurface extraction, synthetic
token streams, deterministic sharded loaders."""

from repro.data.volumes import VOLUMES, make_volume
from repro.data.isosurface import extract_isosurface, point_cloud_for
from repro.data.tokens import SyntheticTokens
