"""In-JAX isosurface extraction (replaces the paper's ParaView step).

Marching-cubes-style *edge-crossing* extraction: for every grid edge along
x/y/z where the field crosses the iso value, emit the linearly-interpolated
crossing point.  This yields the isosurface point cloud that seeds the
Gaussians (paper §II step 1) — for splat initialisation a vertex cloud is
exactly what is needed (the reference pipeline also discards connectivity).

Fixed-capacity output (``max_points``) keeps the extractor jit-compatible;
the host wrapper ``point_cloud_for`` picks the grid resolution that hits a
requested point budget and subsamples deterministically.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import volumes as V


@partial(jax.jit, static_argnames=("max_points",))
def extract_isosurface(field, iso, *, max_points: int):
    """field: (R, R, R); -> (points (max_points, 3) in [0,1]^3, count).

    Points beyond ``count`` are filled with the last valid point (renderable
    padding); count saturates at max_points.
    """
    R = field.shape[0]
    f = field - iso

    pts = []
    valid = []
    for ax in range(3):
        a = jax.lax.slice_in_dim(f, 0, R - 1, axis=ax)
        b = jax.lax.slice_in_dim(f, 1, R, axis=ax)
        cross = (a * b) < 0
        t = a / (a - b + 1e-30)                       # in (0,1) where cross
        ii, jj, kk = jnp.meshgrid(*(jnp.arange(s, dtype=jnp.float32)
                                    for s in a.shape), indexing="ij")
        base = jnp.stack([ii, jj, kk], -1)
        step = jnp.zeros((3,)).at[ax].set(1.0)
        p = (base + t[..., None] * step + 0.5) / R
        pts.append(p.reshape(-1, 3))
        valid.append(cross.reshape(-1))
    pts = jnp.concatenate(pts)
    valid = jnp.concatenate(valid)
    idx = jnp.nonzero(valid, size=max_points, fill_value=0)[0]
    count = jnp.minimum(valid.sum(), max_points)
    got = pts[idx]
    # fill padding with the first valid point so padded splats overlap real ones
    got = jnp.where((jnp.arange(max_points) < count)[:, None], got, got[0])
    return got, count


_RES_CACHE = {}


def point_cloud_for(name: str, n_points: int, *, seed: int = 0,
                    t: float = 0.0):
    """Extract ~n_points isosurface points from the named analytic volume.

    -> (points (n, 3) float32, colors (n, 3) float32).  Deterministic.
    Crossing count scales ~ R^2 x surface complexity; we search R once per
    (name, n_points) and memoise.  ``t`` samples the time-evolved field
    (``volumes.make_volume(..., t=t)``) at the SAME cached resolution R —
    the R search always probes t=0, so every timestep of a series extracts
    from an identical grid and point counts stay comparable across t.
    """
    key = (name, n_points)
    if key not in _RES_CACHE:
        # surface area heuristic: crossings ~ c * R^2; estimate c at R=64
        field, iso = V.make_volume(name, 64)
        f = field - iso
        c = sum(
            int((np.take(f, range(0, 63), axis=ax)
                 * np.take(f, range(1, 64), axis=ax) < 0).sum())
            for ax in range(3)
        )
        c = max(c, 1)
        R = int(np.clip(np.sqrt(n_points / c) * 64, 16, 1024))
        _RES_CACHE[key] = R
    R = _RES_CACHE[key]
    field, iso = V.make_volume(name, R, t=t)
    f = field - iso
    pts = []
    for ax in range(3):
        sl0 = [slice(None)] * 3
        sl1 = [slice(None)] * 3
        sl0[ax] = slice(0, R - 1)
        sl1[ax] = slice(1, R)
        a, b = f[tuple(sl0)], f[tuple(sl1)]
        cross = (a * b) < 0
        t = a / (a - b + 1e-30)
        idx = np.argwhere(cross).astype(np.float32)
        tt = t[cross][:, None]
        step = np.zeros((1, 3), np.float32)
        step[0, ax] = 1.0
        pts.append((idx + tt * step + 0.5) / R)
    pts = np.concatenate(pts).astype(np.float32)
    rng = np.random.default_rng(seed)
    if len(pts) > n_points:
        sel = rng.choice(len(pts), n_points, replace=False)
        pts = pts[sel]
    return pts, V.height_colors(pts)
