"""Deterministic synthetic LM token streams with sharded loading.

Each global step's batch is a pure function of (seed, step, shard), so every
DP shard materialises exactly its slice with no coordination, any shard can
be replayed after a failure (checkpoint stores only the step counter), and
elastic re-sharding (restore onto a different DP width) keeps the stream
byte-identical.

The stream is learnable, not uniform noise: tokens follow a per-document
affine recurrence t[i+1] = (a * t[i] + b) mod vocab_eff with document-id-
dependent (a, b) — a next-token structure a transformer fits quickly, which
gives training curves (and loss drops) something real to show.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class SyntheticTokens:
    vocab: int
    seq: int
    global_batch: int
    seed: int = 0
    vocab_eff: int = 0     # 0 -> min(vocab, 32768)

    def _veff(self):
        return self.vocab_eff or min(self.vocab, 32768)

    def batch(self, step: int, *, shard: int = 0, n_shards: int = 1):
        """-> {tokens, labels} for this shard's rows of the global batch."""
        assert self.global_batch % n_shards == 0
        rows = self.global_batch // n_shards
        veff = self._veff()
        row0 = shard * rows
        doc = (np.int64(self.seed) * 1_000_003
               + np.int64(step) * self.global_batch
               + row0 + np.arange(rows, dtype=np.int64))
        # per-doc affine params (odd multiplier -> full period)
        a = (doc * 2654435761 % (veff - 3)) * 2 + 3
        b = doc * 40503 % veff
        t0 = doc * 9176 % veff
        toks = np.empty((rows, self.seq + 1), np.int64)
        toks[:, 0] = t0
        for i in range(self.seq):
            toks[:, i + 1] = (a * toks[:, i] + b) % veff
        toks = toks % veff
        return {
            "tokens": jnp.asarray(toks[:, :-1], jnp.int32),
            "labels": jnp.asarray(toks[:, 1:], jnp.int32),
        }
