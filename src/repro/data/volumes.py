"""Analytic stand-ins for the paper's volume datasets (DESIGN.md §8).

Kingsnake / Rayleigh-Taylor / Richtmyer-Meshkov are not redistributable; we
generate analytic scalar fields with matched isosurface point-count tiers so
the *pipeline* (extraction -> partitioning -> ghosting -> training -> merge)
is exercised identically.  All fields are deterministic functions of (x,y,z)
on [0,1]^3 — no stored data, resolution-scalable to any point budget.

  kingsnake          gyroid lattice — intricate thin tubular structure, the
                     closest analytic analogue of a CT-scan isosurface
  rayleigh_taylor    perturbed mixing interface: z displaced by a sum of
                     sinusoidal modes + growing plume harmonics [7]
  richtmyer_meshkov  two-scale multimode interface (the RM setup of [8]):
                     long-wavelength modes + deterministic high-frequency
                     turbulent spectrum
  sphere_shell       trivial debug dataset
"""

from __future__ import annotations

import numpy as np


def _grid(res: int):
    ax = (np.arange(res, dtype=np.float32) + 0.5) / res
    return np.meshgrid(ax, ax, ax, indexing="ij")


def sphere_shell(res: int):
    x, y, z = _grid(res)
    r = np.sqrt((x - 0.5) ** 2 + (y - 0.5) ** 2 + (z - 0.5) ** 2)
    return r, 0.35


def kingsnake(res: int):
    """Gyroid: sin(kx)cos(ky) + sin(ky)cos(kz) + sin(kz)cos(kx) = iso."""
    x, y, z = _grid(res)
    k = 6 * np.pi
    f = (np.sin(k * x) * np.cos(k * y)
         + np.sin(k * y) * np.cos(k * z)
         + np.sin(k * z) * np.cos(k * x))
    return f, 0.0


def rayleigh_taylor(res: int):
    x, y, z = _grid(res)
    rng = np.random.default_rng(7)
    f = z - 0.5
    for kx, ky in [(2, 3), (3, 2), (5, 4), (4, 5)]:
        amp = 0.06 / max(kx, ky)
        ph1, ph2 = rng.uniform(0, 2 * np.pi, 2)
        f -= amp * np.sin(2 * np.pi * kx * x + ph1) * np.sin(2 * np.pi * ky * y + ph2)
    # plume harmonics: sharpen spikes/bubbles
    f -= 0.05 * np.sin(2 * np.pi * 2 * x) ** 3 * np.sin(2 * np.pi * 3 * y) ** 3
    return f, 0.0


def richtmyer_meshkov(res: int):
    """Two-scale initial perturbation (Cohen et al. [8]): one long mode +
    a band of short modes with deterministic pseudo-random phases."""
    x, y, z = _grid(res)
    rng = np.random.default_rng(42)
    f = z - 0.5
    f -= 0.08 * np.sin(2 * np.pi * x) * np.sin(2 * np.pi * y)  # long mode
    for _ in range(12):                                        # short band
        kx, ky = rng.integers(6, 14, 2)
        ph1, ph2 = rng.uniform(0, 2 * np.pi, 2)
        f -= (0.16 / (kx + ky)) * np.sin(2 * np.pi * kx * x + ph1) \
            * np.sin(2 * np.pi * ky * y + ph2)
    # roll-up wrinkles (post-shock turbulence proxy)
    f += 0.01 * np.sin(24 * np.pi * x) * np.sin(24 * np.pi * y) \
        * np.sin(12 * np.pi * z)
    return f, 0.0


VOLUMES = {
    "sphere_shell": sphere_shell,
    "kingsnake": kingsnake,
    "rayleigh_taylor": rayleigh_taylor,
    "richtmyer_meshkov": richtmyer_meshkov,
}


def make_volume(name: str, res: int, t: float = 0.0):
    """-> (field (res,res,res) float32 numpy, iso value).

    ``t`` evolves the field in time (the timeseries driver's analytic
    stand-in for a simulation dumping one snapshot per step): a bounded
    travelling multi-mode displacement advects the isosurface smoothly and
    deterministically, so successive timesteps share large-scale structure
    — exactly the regime warm-starting exploits — while every crossing
    moves.  ``t=0`` is bit-identical to the static field (the guard skips
    the perturbation entirely), so all pre-timeseries callers and caches
    are unaffected.
    """
    f, iso = VOLUMES[name](res)
    f = f.astype(np.float32)
    if t:
        tt = float(t)
        x, y, z = _grid(res)
        w = (np.sin(2 * np.pi * (2.0 * x + 0.61 * tt))
             * np.sin(2 * np.pi * (3.0 * y - 0.83 * tt))
             * np.cos(2 * np.pi * (1.0 * z + 0.47 * tt)))
        w += 0.5 * np.sin(2 * np.pi * (5.0 * x - 0.31 * tt)) \
            * np.sin(2 * np.pi * (4.0 * y + 0.53 * tt))
        # tanh bounds the amplitude so late timesteps deform, never destroy,
        # the surface (the field's own structure stays dominant)
        f = f + (0.06 * np.tanh(tt)) * w.astype(np.float32)
        f = f.astype(np.float32)
    return f, float(iso)


def height_colors(points: np.ndarray) -> np.ndarray:
    """Simple deterministic colormap: height + radial blend, in [0.05, 0.95]
    (kept off the sigmoid saturation ends so colors are trainable)."""
    z = points[:, 2]
    r = np.linalg.norm(points[:, :2] - 0.5, axis=1)
    c = np.stack([
        0.15 + 0.7 * z,
        0.2 + 0.6 * (1 - z) * (1 - np.clip(r * 1.4, 0, 1)),
        0.25 + 0.6 * np.clip(r * 1.4, 0, 1),
    ], axis=-1)
    return np.clip(c, 0.05, 0.95).astype(np.float32)
