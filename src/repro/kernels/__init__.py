"""Pallas TPU kernels (+ pure-jnp oracles) for perf-critical GS compute."""

from repro.kernels.ops import rasterize_tiles, resolve_impl
