"""Jit'd public entry points for the rasterizer kernel.

``rasterize_tiles(feats, origins, tile_h=, tile_w=, impl=)``:

  impl="pallas"   pl.pallas_call kernels (custom_vjp: analytic backward)
  impl="ref"      pure-jnp oracle (jax autodiff) — CPU training path
  impl="interpret" pallas kernels in interpret mode (kernel-body validation
                  on CPU; used by tests)
  impl="auto"     "pallas" on TPU, "ref" otherwise

All impls share semantics exactly (see kernels/ref.py) so swapping impl never
changes training math beyond float-associativity noise.

Three dispatch shapes share these kernels:

  rasterize_tiles          one (T,) grid launch at a single static K
  rasterize_tiles_batched  view-batched: (V, T) flattened to one (V*T,) launch
  rasterize_tiles_tiered   variable-K: one launch per occupancy tier (each at
                           its own K_i over its own compacted tile list),
                           scattered back into the full flat tile image
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import rasterize as rk
from repro.kernels import ref as ref_impl


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def _rasterize_pallas(feats, origins, tile_h, tile_w, interpret):
    return rk.rasterize_fwd(feats, origins, tile_h=tile_h, tile_w=tile_w,
                            interpret=interpret)


def _pallas_fwd(feats, origins, tile_h, tile_w, interpret):
    out = rk.rasterize_fwd(feats, origins, tile_h=tile_h, tile_w=tile_w,
                           interpret=interpret)
    return out, (feats, origins, out)


def _pallas_bwd(tile_h, tile_w, interpret, res, gout):
    feats, origins, out = res
    gfeats = rk.rasterize_bwd(feats, origins, out, gout,
                              tile_h=tile_h, tile_w=tile_w,
                              interpret=interpret)
    return gfeats.astype(feats.dtype), jnp.zeros_like(origins)


_rasterize_pallas.defvjp(_pallas_fwd, _pallas_bwd)


def resolve_impl(impl: str) -> str:
    if impl != "auto":
        return impl
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def rasterize_tiles(feats, origins, *, tile_h: int, tile_w: int,
                    impl: str = "auto"):
    """feats (T, K, F) -> (T, 4, th, tw) [r, g, b, coverage]. Differentiable
    w.r.t. feats under every impl.

    Mixed-precision boundary: feature blocks may arrive in a reduced
    storage dtype (core.dtypes casts them at the gather/exchange boundary
    under dtype_policy="bf16"); the compositor contract is f32 ACCUMULATION
    regardless, so inputs are promoted here — the single funnel all three
    impls (and the batched/tiered dispatchers below) share, keeping
    ref == interpret == pallas semantics per dtype.  For f32 inputs the
    promote is elided (same-dtype convert), so the default policy compiles
    the exact pre-policy program.  Output is always f32; the backward pass
    rounds the feature cotangents back to the input dtype at this same
    boundary (the transpose of the promote)."""
    feats = feats.astype(jnp.float32)
    origins = origins.astype(jnp.float32)
    impl = resolve_impl(impl)
    if impl == "ref":
        return ref_impl.rasterize_tiles_ref(feats, origins,
                                            tile_h=tile_h, tile_w=tile_w)
    if impl == "pallas":
        return _rasterize_pallas(feats, origins, tile_h, tile_w, False)
    if impl == "interpret":
        return _rasterize_pallas(feats, origins, tile_h, tile_w, True)
    raise ValueError(impl)


def rasterize_tiles_batched(feats, origins, *, tile_h: int, tile_w: int,
                            impl: str = "auto"):
    """View-batched entry point: feats (V, T, K, F) -> (V, T, 4, th, tw).

    origins may be (T, 2) (shared rig geometry, the common case) or
    (V, T, 2).  The V and T axes are flattened into one (V*T,) kernel grid
    launch — one dispatch for the whole view batch instead of V — and
    unflattened afterwards.  Semantics are identical to V independent
    ``rasterize_tiles`` calls (tiles are independent programs)."""
    V, T, K, F = feats.shape
    if origins.ndim == 2:
        origins = jnp.broadcast_to(origins[None], (V,) + origins.shape)
    out = rasterize_tiles(
        feats.reshape(V * T, K, F), origins.reshape(V * T, 2),
        tile_h=tile_h, tile_w=tile_w, impl=impl,
    )
    return out.reshape(V, T, 4, tile_h, tile_w)


def rasterize_tiles_tiered(tier_feats, tier_origins, tier_ids, n_tiles: int,
                           *, tile_h: int, tile_w: int, impl: str = "auto"):
    """Variable-K dispatch: one kernel launch per non-empty occupancy tier.

    tier_feats    per tier i: (cap_i, K_i, F) compacted feature tables —
                  each tier carries its OWN static K_i, so sparse tiles pay
                  K_i=16 gather/compute instead of the dense Kmax.
    tier_origins  per tier i: (cap_i, 2) tile origins aligned with the feats.
    tier_ids      per tier i: (cap_i,) int32 flat tile ids (TierPlan.tile_ids
                  from core.tiling.bin_tiles_by_occupancy); slots holding the
                  sentinel ``n_tiles`` are padding and are dropped by the
                  scatter.
    n_tiles       M: the flat tile count of the full image.

    -> (M, 4, th, tw).  Tiles placed in no tier (empty tiles, or overflow
    past the top tier's cap) come back as exact zeros — identical to what
    the kernel produces for an all-alpha-0 list.  Differentiable w.r.t.
    every tier_feats entry: each launch goes through the same custom-VJP
    (pallas/interpret) or autodiff (ref) path as rasterize_tiles, and the
    scatter's transpose routes the per-tier output cotangents back to the
    corresponding tier table (padding slots get zeros via mode="drop").
    Tier capacities are static, so this traces to a fixed launch schedule —
    cap_i == 0 tiers are skipped at trace time ("non-empty tier" dispatch).
    """
    out = jnp.zeros((n_tiles, 4, tile_h, tile_w), jnp.float32)
    for feats, origins, ids in zip(tier_feats, tier_origins, tier_ids):
        if feats.shape[0] == 0:
            continue
        tiles = rasterize_tiles(feats, origins, tile_h=tile_h, tile_w=tile_w,
                                impl=impl)
        out = out.at[ids].set(tiles, mode="drop")
    return out
