"""Pallas TPU tile rasterizer for 3D-GS compositing (forward + backward).

TPU-native redesign of the CUDA 3D-GS rasterizer (DESIGN.md §3):

* one Pallas program per image tile (grid = (T,));
* the tile's fixed-K splat list (K, FEAT_DIM) lives in VMEM — one 4 KB block
  for K=64 — loaded to registers once per program;
* the (tile_h, tile_w) pixel accumulators (transmittance + 3 color channels)
  are VREG-resident f32 planes; with the production tile shape (8, 128) each
  compositing step is one VREG row op per plane;
* front-to-back compositing is a ``fori_loop`` over K — branchless: the GPU
  per-pixel early-termination break becomes masked lanes (alpha below 1/255
  contributes exactly 0), the alpha clamp (0.99) and sigma>=0 guard match the
  3D-GS reference semantics;
* the backward pass is a *single forward* loop (no reverse sweep): with
  C = sum_k w_k rgb_k, w_k = T_k alpha_k, the suffix sums the gradient needs
  are recovered as  S_k = C - prefix_k, so d out / d alpha_k =
  T_k rgb_k - S_k / (1 - alpha_k) using only the running prefix — this is the
  TPU replacement for the CUDA back-to-front replay.

VMEM budget per program (production tile 8x128, K=64):
  feats 4 KB + out 16 KB + gout/out residuals 32 KB (bwd) + accumulators in
  VREGs — far below the ~16 MB/core VMEM limit, so many programs pipeline.

Layouts: feats (T, K, 16) f32, origins (T, 2) f32, out (T, 4, th, tw) f32
(channels [r, g, b, coverage]).

K is a trace-time constant, not a baked-in config: each pallas_call
specializes its (1, K, F) block spec and fori_loop bound to the incoming
feats shape.  The variable-K tiered dispatch (kernels/ops.
rasterize_tiles_tiered) relies on exactly this — it calls these kernels
once per occupancy tier with that tier's own (cap_i, K_i, F) table, so a
K=16 tier runs a 16-step compositing loop over a 1 KB VMEM block instead
of paying the top tier's K everywhere.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

ALPHA_MAX = 0.99
ALPHA_MIN = 1.0 / 255.0


def _pixel_grids(origin_x, origin_y, th: int, tw: int):
    px = origin_x + 0.5 + lax.broadcasted_iota(jnp.float32, (th, tw), 1)
    py = origin_y + 0.5 + lax.broadcasted_iota(jnp.float32, (th, tw), 0)
    return px, py


def _alpha_terms(f, px, py):
    """Shared fwd/bwd per-splat math. f: (F,) feature row."""
    dx = px - f[0]
    dy = py - f[1]
    sigma = 0.5 * (f[2] * dx * dx + f[4] * dy * dy) + f[3] * dx * dy
    g = jnp.exp(-jnp.maximum(sigma, 0.0))
    a_g = f[8] * g
    alpha = jnp.minimum(a_g, ALPHA_MAX)
    live = alpha >= ALPHA_MIN
    alpha = jnp.where(live, alpha, 0.0)
    return dx, dy, sigma, g, a_g, alpha, live


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _fwd_kernel(feat_ref, origin_ref, out_ref, *, K: int, th: int, tw: int):
    feats = feat_ref[0]                      # (K, F) -> registers
    px, py = _pixel_grids(origin_ref[0, 0], origin_ref[0, 1], th, tw)

    def body(k, carry):
        trans, r, g, b = carry
        f = lax.dynamic_index_in_dim(feats, k, 0, keepdims=False)
        *_, alpha, _ = _alpha_terms(f, px, py)
        w = trans * alpha
        return (trans * (1.0 - alpha),
                r + w * f[5], g + w * f[6], b + w * f[7])

    zero = jnp.zeros((th, tw), jnp.float32)
    trans, r, g, b = lax.fori_loop(
        0, K, body, (jnp.ones((th, tw), jnp.float32), zero, zero, zero)
    )
    out_ref[0, 0] = r
    out_ref[0, 1] = g
    out_ref[0, 2] = b
    out_ref[0, 3] = 1.0 - trans


def rasterize_fwd(feats, origins, *, tile_h: int, tile_w: int,
                  interpret: bool = False):
    T, K, F = feats.shape
    kernel = functools.partial(_fwd_kernel, K=K, th=tile_h, tw=tile_w)
    return pl.pallas_call(
        kernel,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, K, F), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, 2), lambda t: (t, 0)),
        ],
        out_specs=pl.BlockSpec((1, 4, tile_h, tile_w), lambda t: (t, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, 4, tile_h, tile_w), jnp.float32),
        interpret=interpret,
    )(feats.astype(jnp.float32), origins.astype(jnp.float32))


# ---------------------------------------------------------------------------
# Backward (single forward sweep, prefix-sum trick)
# ---------------------------------------------------------------------------


def _bwd_kernel(feat_ref, origin_ref, out_ref, gout_ref, gfeat_ref,
                *, K: int, th: int, tw: int):
    feats = feat_ref[0]                       # (K, F)
    px, py = _pixel_grids(origin_ref[0, 0], origin_ref[0, 1], th, tw)
    c_r, c_g, c_b = out_ref[0, 0], out_ref[0, 1], out_ref[0, 2]
    t_final = 1.0 - out_ref[0, 3]
    g_r, g_g, g_b, g_cov = (gout_ref[0, 0], gout_ref[0, 1],
                            gout_ref[0, 2], gout_ref[0, 3])

    def body(k, carry):
        trans, pr, pg, pb, gf = carry
        f = lax.dynamic_index_in_dim(feats, k, 0, keepdims=False)
        dx, dy, sigma, g, a_g, alpha, live = _alpha_terms(f, px, py)
        w = trans * alpha
        pr = pr + w * f[5]
        pg = pg + w * f[6]
        pb = pb + w * f[7]
        denom = 1.0 - alpha                   # >= 1 - ALPHA_MAX = 0.01
        g_alpha = (
            g_r * (trans * f[5] - (c_r - pr) / denom)
            + g_g * (trans * f[6] - (c_g - pg) / denom)
            + g_b * (trans * f[7] - (c_b - pb) / denom)
            + g_cov * (t_final / denom)
        )
        mask = live & (a_g < ALPHA_MAX)
        g_ag = jnp.where(mask, g_alpha, 0.0)
        g_sigma = jnp.where(sigma > 0.0, -a_g * g_ag, 0.0)
        row = jnp.stack([
            jnp.sum(-(f[2] * dx + f[3] * dy) * g_sigma),     # d/d mean_x
            jnp.sum(-(f[4] * dy + f[3] * dx) * g_sigma),     # d/d mean_y
            jnp.sum(0.5 * dx * dx * g_sigma),                # d/d conic A
            jnp.sum(dx * dy * g_sigma),                      # d/d conic B
            jnp.sum(0.5 * dy * dy * g_sigma),                # d/d conic C
            jnp.sum(g_r * w),                                # d/d r
            jnp.sum(g_g * w),                                # d/d g
            jnp.sum(g_b * w),                                # d/d b
            jnp.sum(g_ag * g),                               # d/d alpha
        ])
        row = jnp.concatenate(
            [row, jnp.zeros((feats.shape[1] - 9,), jnp.float32)]
        )
        gf = lax.dynamic_update_index_in_dim(gf, row, k, 0)
        return (trans * denom, pr, pg, pb, gf)

    zero = jnp.zeros((th, tw), jnp.float32)
    init = (jnp.ones((th, tw), jnp.float32), zero, zero, zero,
            jnp.zeros(feats.shape, jnp.float32))
    *_, gf = lax.fori_loop(0, K, body, init)
    gfeat_ref[0] = gf


def rasterize_bwd(feats, origins, out, gout, *, tile_h: int, tile_w: int,
                  interpret: bool = False):
    T, K, F = feats.shape
    kernel = functools.partial(_bwd_kernel, K=K, th=tile_h, tw=tile_w)
    return pl.pallas_call(
        kernel,
        grid=(T,),
        in_specs=[
            pl.BlockSpec((1, K, F), lambda t: (t, 0, 0)),
            pl.BlockSpec((1, 2), lambda t: (t, 0)),
            pl.BlockSpec((1, 4, tile_h, tile_w), lambda t: (t, 0, 0, 0)),
            pl.BlockSpec((1, 4, tile_h, tile_w), lambda t: (t, 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, K, F), lambda t: (t, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((T, K, F), jnp.float32),
        interpret=interpret,
    )(
        feats.astype(jnp.float32),
        origins.astype(jnp.float32),
        out.astype(jnp.float32),
        gout.astype(jnp.float32),
    )
