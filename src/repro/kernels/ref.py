"""Pure-jnp oracle for the tile rasterizer (differentiable, CPU-fast).

Math is *bit-identical* in spirit to the Pallas kernel (`rasterize.py`):
front-to-back alpha compositing of the per-tile top-K splat lists, with the
3D-GS reference clamps (alpha <= 0.99, alpha < 1/255 skipped, sigma >= 0).
No early termination — the GPU reference's T < 1e-4 break is replaced by
simply continuing to accumulate negligible terms (branchless; identical to the
TPU kernel), so oracle and kernel agree to float tolerance.

Two implementations:
  * ``rasterize_tiles_ref``      — lax.scan over K (O(pixels) live memory);
                                   this is the CPU *training* path.
  * ``rasterize_tiles_unrolled`` — fully vectorised cumprod over K (used by
                                   tests as an independent second oracle).

Output per tile: (T, 4, th, tw) float32 = [r, g, b, coverage], coverage =
1 - prod(1 - alpha).  Composite over a background outside:
``img_rgb = out_rgb + (1 - coverage) * bg``.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

ALPHA_MAX = 0.99
ALPHA_MIN = 1.0 / 255.0


def _pixel_centers(origins, tile_h: int, tile_w: int):
    """origins: (T, 2) (x, y) -> px, py: (T, th, tw) pixel-center coords."""
    jx = jnp.arange(tile_w, dtype=jnp.float32) + 0.5
    iy = jnp.arange(tile_h, dtype=jnp.float32) + 0.5
    px = origins[:, 0, None, None] + jx[None, None, :]
    py = origins[:, 1, None, None] + iy[None, :, None]
    px = jnp.broadcast_to(px, (origins.shape[0], tile_h, tile_w))
    py = jnp.broadcast_to(py, (origins.shape[0], tile_h, tile_w))
    return px, py


def _splat_alpha(f, px, py):
    """f: (..., F) feature rows broadcast against pixel grids px/py."""
    dx = px - f[..., 0]
    dy = py - f[..., 1]
    sigma = 0.5 * (f[..., 2] * dx * dx + f[..., 4] * dy * dy) + f[..., 3] * dx * dy
    g = jnp.exp(-jnp.maximum(sigma, 0.0))
    alpha = jnp.minimum(f[..., 8] * g, ALPHA_MAX)
    return jnp.where(alpha < ALPHA_MIN, 0.0, alpha)


@partial(jax.jit, static_argnames=("tile_h", "tile_w"))
def rasterize_tiles_ref(feats, origins, *, tile_h: int, tile_w: int):
    """feats: (T, K, F) float32; origins: (T, 2) -> (T, 4, th, tw)."""
    T, K, F = feats.shape
    px, py = _pixel_centers(origins, tile_h, tile_w)   # (T, th, tw)

    def body(carry, fk):
        trans, r, g, b = carry                          # each (T, th, tw)
        alpha = _splat_alpha(fk[:, None, None, :], px, py)
        w = trans * alpha
        return (
            trans * (1.0 - alpha),
            r + w * fk[:, 5, None, None],
            g + w * fk[:, 6, None, None],
            b + w * fk[:, 7, None, None],
        ), None

    z = jnp.zeros((T, tile_h, tile_w), jnp.float32)
    init = (jnp.ones_like(z), z, z, z)
    # scan over the K axis: feats (T, K, F) -> iterate fk (T, F)
    (trans, r, g, b), _ = lax.scan(body, init, feats.transpose(1, 0, 2))
    return jnp.stack([r, g, b, 1.0 - trans], axis=1)


@partial(jax.jit, static_argnames=("tile_h", "tile_w"))
def rasterize_tiles_unrolled(feats, origins, *, tile_h: int, tile_w: int):
    """Independent second oracle: vectorised over K with an exclusive cumprod."""
    T, K, F = feats.shape
    px, py = _pixel_centers(origins, tile_h, tile_w)
    alpha = _splat_alpha(
        feats[:, :, None, None, :], px[:, None], py[:, None]
    )                                                   # (T, K, th, tw)
    keep = 1.0 - alpha
    # exclusive cumulative product along K: T_k = prod_{j<k} (1 - alpha_j)
    trans = jnp.cumprod(keep, axis=1) / jnp.maximum(keep, 1e-12)
    # exact exclusive form (robust to keep==0): shift instead of divide
    trans = jnp.concatenate(
        [jnp.ones((T, 1, tile_h, tile_w)), jnp.cumprod(keep, axis=1)[:, :-1]],
        axis=1,
    )
    w = trans * alpha                                   # (T, K, th, tw)
    rgb = jnp.einsum("tkhw,tkc->tchw", w, feats[:, :, 5:8])
    cov = 1.0 - jnp.prod(keep, axis=1)
    return jnp.concatenate([rgb, cov[:, None]], axis=1)
