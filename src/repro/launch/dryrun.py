import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST precede every other import (jax locks the device
# count at first init).  REPRO_DRYRUN_DEVICES overrides for reduced tests.
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"]
    )

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and record memory / cost / collective analyses.

  python -m repro.launch.dryrun --arch all --shape all --mesh both
  python -m repro.launch.dryrun --gs --mesh both
  python -m repro.launch.dryrun --arch mixtral-8x22b --shape train_4k

Per-cell JSON lands in experiments/dryrun/<mesh>/<arch>__<shape>.json and is
cached (re-runs skip finished cells unless --force).  benchmarks/roofline.py
consumes these files.
"""

import argparse
import json
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import all_arch_ids, get_spec
from repro.configs.gs_datasets import FULL as GS_FULL
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh
from repro.models.params import param_shardings, param_specs
from repro.models.steps import (
    SHAPES,
    TrainCfg,
    cache_pspecs,
    input_pspecs,
    input_specs,
    make_decode_step,
    make_prefill_step,
    make_train_step,
    opt_state_shardings,
    opt_state_specs,
)

# TPU v5e roofline constants (assignment)
PEAK_FLOPS = 197e12        # bf16 / chip
HBM_BW = 819e9             # bytes/s / chip
ICI_BW = 50e9              # bytes/s / link

GS_CELLS = {
    # name -> (dataset, resolution)
    "gs-kingsnake": ("kingsnake", 2048),
    "gs-rayleigh-taylor": ("rayleigh_taylor", 2048),
    "gs-richtmyer-meshkov": ("richtmyer_meshkov", 2048),
    "gs-richtmyer-meshkov-1k": ("richtmyer_meshkov", 1024),
}


def make_meshes(which: str):
    out = {}
    n = len(jax.devices())
    if n == 512:
        if which in ("single", "both"):
            out["single"] = make_production_mesh(multi_pod=False)
        if which in ("multi", "both"):
            out["multi"] = make_production_mesh(multi_pod=True)
    else:  # reduced test meshes (REPRO_DRYRUN_DEVICES)
        if which in ("single", "both"):
            out["single"] = jax.make_mesh((2, n // 2), ("data", "model"))
        if which in ("multi", "both"):
            out["multi"] = jax.make_mesh((2, 2, n // 4),
                                         ("pod", "data", "model"))
    return out


def _mem_analysis(compiled):
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "alias_size_in_bytes",
              "generated_code_size_in_bytes"):
        v = getattr(ma, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def _ns_tree(mesh, pspec_tree):
    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), pspec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def model_flops(spec, shape_name: str) -> float:
    """Assignment definition: 6*N*D train / 2*N*D inference, N active params,
    D tokens processed globally."""
    sh = SHAPES[shape_name]
    n = spec.param_count(active_only=True)
    if sh["kind"] == "train":
        return 6.0 * n * sh["batch"] * sh["seq"]
    if sh["kind"] == "prefill":
        return 2.0 * n * sh["batch"] * sh["seq"]
    return 2.0 * n * sh["batch"]  # decode: one token per sequence


def lower_lm_cell(spec, shape_name: str, mesh):
    with mesh:   # mesh context so in-model sharding constraints bind
        return _lower_lm_cell(spec, shape_name, mesh)


def _lower_lm_cell(spec, shape_name: str, mesh):
    sh = SHAPES[shape_name]
    kind = sh["kind"]
    iospecs = input_specs(spec, shape_name)
    iopspec = input_pspecs(spec, mesh, shape_name)

    if kind == "train":
        cfg = TrainCfg(total_steps=10_000)
        step = make_train_step(spec, cfg)
        p_sh = param_shardings(spec, mesh)
        o_sh = opt_state_shardings(spec, mesh, cfg)
        b_sh = _ns_tree(mesh, iopspec["batch"])
        metrics_sh = {k: NamedSharding(mesh, P())
                      for k in ("loss", "aux", "grad_norm", "lr_scale")}
        jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, metrics_sh),
                         donate_argnums=(0, 1))
        return jitted.lower(param_specs(spec), opt_state_specs(spec, cfg),
                            iospecs["batch"])
    if kind == "prefill":
        step = make_prefill_step(spec)
        p_sh = param_shardings(spec, mesh)
        b_sh = _ns_tree(mesh, iopspec["batch"])
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
        return jitted.lower(param_specs(spec), iospecs["batch"])
    # decode
    step = make_decode_step(spec)
    p_sh = param_shardings(spec, mesh)
    c_sh = _ns_tree(mesh, cache_pspecs(spec, mesh, sh["batch"]))
    t_sh = _ns_tree(mesh, iopspec["tokens"])
    pos_sh = NamedSharding(mesh, P())
    jitted = jax.jit(step, in_shardings=(p_sh, c_sh, t_sh, pos_sh),
                     donate_argnums=(1,))
    return jitted.lower(param_specs(spec), iospecs["caches"],
                        iospecs["tokens"], iospecs["pos"])


def lower_gs_cell(cell: str, mesh, *, opt: bool = False):
    from repro.core.distributed import (
        gs_batch_specs, gs_state_specs, make_gs_train_step,
    )
    from repro.core.tiling import TileGrid
    from repro.core.train import GSTrainCfg

    ds_name, res = GS_CELLS[cell]
    ds = GS_FULL[ds_name]
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_parts = sizes.get("pod", 1)
    # round shard-divisible (shard_map over the "data" axis)
    mult = sizes["data"] * 4096
    n_per_part = -(-ds.n_points // n_parts // mult) * mult
    grid = TileGrid(res, res, 8, 128)
    if opt:   # beyond-paper optimized variant (§Perf GS hillclimb)
        n_model = sizes["model"]
        cfg = GSTrainCfg(K=64, tile_h=8, tile_w=128, gather_mode="split",
                         strip_budget=min(1.0, 4.0 / n_model))
    else:
        cfg = GSTrainCfg(K=64, tile_h=8, tile_w=128)
    # k_tiers=None: lower the DENSE step — the analytic flop model and the
    # recorded meta K below describe dense-K rasterization, and the tiered
    # dispatch's work depends on runtime occupancy the dry run cannot see
    step = make_gs_train_step(mesh, cfg, grid, extent=1.0, impl="ref",
                              k_tiers=None)
    g, opt = gs_state_specs(n_parts, n_per_part)
    batch = gs_batch_specs(n_parts, grid)
    lowered = step.lower(g, opt, batch)
    meta = {
        "dataset": ds_name, "resolution": res, "n_parts": n_parts,
        "gaussians_per_part": n_per_part, "K": cfg.K,
        "tiles": grid.n_tiles,
    }
    # analytic "useful" flops (fwd+bwd rasterize + projection + loss; the
    # dense tile-assignment is implementation overhead, not model flops)
    T, K, pix = grid.n_tiles, cfg.K, grid.tile_h * grid.tile_w
    raster = n_parts * T * K * pix * (30 + 45)
    proj = n_parts * n_per_part * 300 * 3          # fwd + bwd
    loss = n_parts * T * pix * 3 * 2 * 49 * 6      # ssim convs fwd+bwd
    return lowered, meta, float(raster + proj + loss)


def lower_gs_train_cell(dataset: str, mesh, *, res: int = 64,
                        n_parts: int = 2, view_batch: int = 0,
                        tier: str = "cpu"):
    """Lower the PRODUCTION GS train step — the same tiered
    ``make_gs_train_step`` the distributed driver (``fit_partitions``) and
    the timeseries loop dispatch every step — on a ("part", "view") mesh.

    Unlike ``lower_gs_cell`` (dense-K, analysis-friendly flop model, dryrun
    meshes) this profiles what training actually runs: occupancy-tiered
    rasterization (strip-sized caps: the always-exact shape, an upper bound
    on any probed-cap step), the view-minibatch forward, and the trainer's
    collective layout.  -> (lowered, meta).
    """
    from repro.configs.gs_datasets import get_gs_dataset
    from repro.core.distributed import (gs_batch_specs, gs_state_specs,
                                        make_gs_train_step)
    from repro.core.tiling import TileGrid
    from repro.core.train import GSTrainCfg

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    vb = view_batch or sizes.get("view", 1)
    cfg = GSTrainCfg(view_batch=vb)
    ds = get_gs_dataset(dataset, tier)
    mult = sizes.get("part", 1)           # N is sharded over "part"
    n_per_part = -(-int(ds.n_points * ds.capacity_factor)
                   // n_parts // mult) * mult
    grid = TileGrid(res, res, cfg.tile_h, cfg.tile_w)
    step = make_gs_train_step(mesh, cfg, grid, extent=1.0, impl="ref",
                              views=vb, return_overflow=True)
    g, opt = gs_state_specs(n_parts, n_per_part)
    batch = gs_batch_specs(n_parts, grid, views=vb)
    meta = {
        "dataset": dataset, "resolution": res, "n_parts": n_parts,
        "gaussians_per_part": n_per_part, "view_batch": vb,
        "k_tiers": cfg.resolved_k_tiers(), "tiles": grid.n_tiles,
    }
    return step.lower(g, opt, batch), meta


def run_cell(arch: str, shape: str, mesh, mesh_tag: str, out_dir: str,
             force: bool = False, gs_opt: bool = False) -> str:
    os.makedirs(f"{out_dir}/{mesh_tag}", exist_ok=True)
    path = f"{out_dir}/{mesh_tag}/{arch}__{shape}.json"
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)["status"] + " (cached)"

    rec = {
        "arch": arch, "shape": shape, "mesh": mesh_tag,
        "mesh_shape": list(mesh.devices.shape),
        "mesh_axes": list(mesh.axis_names),
        "n_devices": int(mesh.devices.size),
    }
    is_gs = arch.startswith("gs-")
    if not is_gs:
        spec = get_spec(arch)
        if shape in spec.skip_shapes:
            rec.update(status="skip",
                       reason="long_500k needs sub-quadratic attention "
                              "(pure full-attention arch; DESIGN.md §5)")
            with open(path, "w") as f:
                json.dump(rec, f, indent=1)
            return "skip"

    pod_size = 1
    if "pod" in mesh.axis_names:
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        pod_size = int(mesh.devices.size // sizes["pod"])

    try:
        t0 = time.time()
        if is_gs:
            lowered, meta, mflops = lower_gs_cell(arch, mesh, opt=gs_opt)
            rec["gs_meta"] = meta
        else:
            lowered = lower_lm_cell(spec, shape, mesh)
            mflops = model_flops(spec, shape)
        rec["lower_s"] = round(time.time() - t0, 2)

        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

        rec["memory_analysis"] = _mem_analysis(compiled)
        try:
            ca = compiled.cost_analysis()
            rec["xla_cost_analysis"] = {
                k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and (
                    "flops" in k or "bytes accessed" == k or "utilization" in k)
            }
        except Exception:
            rec["xla_cost_analysis"] = {}

        t0 = time.time()
        hlo = hlo_analysis.analyze(
            compiled.as_text(),
            pod_size=pod_size if "pod" in mesh.axis_names else 0)
        rec["analyze_s"] = round(time.time() - t0, 2)
        rec["hlo"] = hlo

        n = rec["n_devices"]
        rec["model_flops_global"] = mflops
        rec["model_flops_per_device"] = mflops / n
        rec["roofline"] = {
            "compute_s": hlo["flops"] / PEAK_FLOPS,
            "memory_s": hlo["hbm_bytes"] / HBM_BW,
            "collective_s": hlo["collective_wire_bytes"] / ICI_BW,
        }
        dom = max(rec["roofline"], key=rec["roofline"].get)
        rec["bottleneck"] = dom
        rec["useful_flops_ratio"] = (
            rec["model_flops_per_device"] / hlo["flops"]
            if hlo["flops"] else 0.0)
        rec["status"] = "ok"
    except Exception:
        rec["status"] = "error"
        rec["traceback"] = traceback.format_exc()[-4000:]
    finally:
        jax.clear_caches()

    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if rec["status"] == "error":
        return "error: " + rec["traceback"].strip().splitlines()[-1][:150]
    r = rec["roofline"]
    return (f"ok  lower {rec['lower_s']:.0f}s compile {rec['compile_s']:.0f}s  "
            f"compute {r['compute_s']*1e3:.2f}ms mem {r['memory_s']*1e3:.2f}ms "
            f"coll {r['collective_s']*1e3:.2f}ms -> {rec['bottleneck']}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="csv of arch ids, 'all' (LM), or gs cell names")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both", choices=["single", "multi", "both"])
    ap.add_argument("--gs", action="store_true", help="run the GS cells")
    ap.add_argument("--gs-opt", action="store_true",
                    help="optimized GS variant (split gather + strip prefilter)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    cells = []
    if args.gs:
        archs = list(GS_CELLS)
        shapes = ["train"]
    else:
        archs = all_arch_ids() if args.arch == "all" else args.arch.split(",")
        shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = make_meshes(args.mesh)
    for mesh_tag, mesh in meshes.items():
        for arch in archs:
            for shape in shapes:
                cells.append((arch, shape, mesh, mesh_tag))

    print(f"dry-run: {len(cells)} cells on {len(jax.devices())} devices")
    for i, (arch, shape, mesh, mesh_tag) in enumerate(cells):
        t0 = time.time()
        msg = run_cell(arch, shape, mesh, mesh_tag, args.out, args.force,
                       gs_opt=args.gs_opt)
        print(f"[{i+1}/{len(cells)}] {mesh_tag:6s} {arch:28s} {shape:12s} "
              f"{msg}  ({time.time()-t0:.0f}s)", flush=True)


if __name__ == "__main__":
    main()
