"""Post-optimization HLO analyzer for the dry-run roofline.

``compiled.cost_analysis()`` visits every instruction ONCE — a ``lax.scan``
over 56 layers contributes a single body's flops (verified; see
EXPERIMENTS.md §Dry-run).  For a roofline that would undercount compute by
the layer count, so we parse ``compiled.as_text()`` (the SPMD-partitioned,
per-device module) ourselves:

  * while bodies are multiplied by XLA's ``known_trip_count``;
  * flops: dot (2*M*N*K from shapes + contracting dims), convolution
    (2 * out_elems * kernel_elems / out_features), elementwise and reduce
    ops at 1 flop/element (dots dominate every model here);
  * HBM bytes: operand + output bytes of *top-level* (fusion-boundary) ops —
    fusion internals are VMEM-resident by construction.  Slice-aware:
    dynamic-slice / gather read (and dynamic-update-slice writes) count the
    *slice*, not the full buffer — otherwise every scan iteration would be
    charged the whole stacked parameter array;
  * collectives: per-op operand/output bytes, ring-model wire bytes
    (all-gather -> out-in, all-reduce -> 2x(g-1)/g, reduce-scatter/
    all-to-all/collective-permute -> 1x operand), replica-group size, and
    whether any group spans the pod axis (must be NO for the GS pipeline —
    paper partitions are independent).

Scheduled HLO prints operands as bare ``%name`` (no shapes), so we build a
module-wide symbol table (instruction -> shape) in a first pass and resolve
operand sizes through it.  Everything is per-device (the module is already
partitioned).
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

SHAPE_RE = re.compile(r"(%s)\[([0-9,]*)\]" % "|".join(_DTYPE_BYTES))
DEF_RE = re.compile(r"^\s*(ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
OPCODE_RE = re.compile(r"\s([a-z][a-z0-9\-]*)\(")
OPERAND_RE = re.compile(r"%([\w\.\-]+)")
CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
BODY_RE = re.compile(r"body=%?([\w\.\-]+)")
TRIP_RE = re.compile(r"known_trip_count[^0-9]*(\d+)")
GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\}(?:,\{[^}]*\})*)\}")
GROUPS_IOTA_RE = re.compile(
    r"replica_groups=\[(\d+),(\d+)\]<=\[([0-9,]+)\](?:T\(([0-9,]+)\))?")
HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(.*\)\s*->\s*.*\{\s*$")

COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

#: ops with no flops and no real HBM traffic of their own
_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "iota", "partition-id", "replica-id",
             "reshape"}

#: ops looked through when tracing fusion-internal dataflow.  ``convert`` is
#: here deliberately: XLA:CPU materialises whole-buffer f32<->bf16 round
#: trips around in-place updates (measured 978 GB/step on minicpm's 12 GB
#: remat stash) that XLA:TPU performs natively in bf16 — dtype casts are
#: charged at their *consumers'* access granularity, which is the TPU
#: fusion semantics this roofline targets.
_TRANSPARENT = {"bitcast", "reshape", "get-tuple-element", "tuple", "copy",
                "convert"}

#: operand-sparse readers: charge the *output* (slice) not the operand
_SLICE_READERS = {"dynamic-slice", "gather", "slice"}


def _shape_elems(dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n


@dataclasses.dataclass
class Sym:
    bytes: int
    elems: int
    dims: Optional[List[int]]


@dataclasses.dataclass
class Inst:
    name: str
    opcode: str
    operands: List[str]
    line: str
    is_root: bool


@dataclasses.dataclass
class CollectiveOp:
    op: str
    operand_bytes: int
    output_bytes: int
    wire_bytes: int
    group_size: int
    spans_pod: bool
    count: int = 1


@dataclasses.dataclass
class HloCosts:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    collectives: List[CollectiveOp] = dataclasses.field(default_factory=list)

    @property
    def collective_wire_bytes(self) -> float:
        return sum(c.wire_bytes * c.count for c in self.collectives)

    @property
    def pod_spanning_bytes(self) -> float:
        return sum(c.wire_bytes * c.count for c in self.collectives
                   if c.spans_pod)


def _parse_groups(line: str, pod_size: int) -> Tuple[int, bool]:
    m = GROUPS_RE.search(line)
    if m:
        groups = m.group(1).split("},{")
        ids0 = [int(x) for x in groups[0].strip("{}").split(",") if x]
        size = len(ids0)
        spans = False
        if pod_size:
            for g in groups:
                ids = [int(x) for x in g.strip("{}").split(",") if x]
                if len({i // pod_size for i in ids}) > 1:
                    spans = True
                    break
        return size, spans
    m = GROUPS_IOTA_RE.search(line)
    if m:
        import numpy as np
        n_groups, group_size = int(m.group(1)), int(m.group(2))
        dims = [int(x) for x in m.group(3).split(",")]
        perm = ([int(x) for x in m.group(4).split(",")]
                if m.group(4) else list(range(len(dims))))
        ids = np.arange(int(np.prod(dims))).reshape(dims)
        ids = ids.transpose(perm).reshape(n_groups, group_size)
        spans = False
        if pod_size:
            spans = any(len({int(i) // pod_size for i in g}) > 1 for g in ids)
        return group_size, spans
    return 1, False


def _wire_bytes(op: str, operand_b: int, output_b: int, group: int) -> int:
    if group <= 1:
        return 0
    if op == "all-gather":
        return max(output_b - operand_b, 0)
    if op == "all-reduce":
        return 2 * operand_b * (group - 1) // max(group, 1)
    return operand_b   # reduce-scatter / all-to-all / collective-permute


class HloModule:
    """Minimal parse of a post-optimization (scheduled) HLO text dump."""

    def __init__(self, text: str, *, pod_size: int = 0):
        self.pod_size = pod_size
        raw: Dict[str, List[str]] = {}
        self.entry: Optional[str] = None
        cur = None
        for rawline in text.splitlines():
            ls = rawline.strip()
            if cur is None:
                m = HEADER_RE.match(ls)
                if m and " = " not in ls:
                    cur = m.group(2)
                    raw[cur] = []
                    if m.group(1):
                        self.entry = cur
                continue
            if ls.startswith("}"):
                cur = None
            elif ls:
                raw[cur].append(ls)
        if self.entry is None:
            for cand in ("main", "main.0"):
                if cand in raw:
                    self.entry = cand

        # pass 1: parse instructions + module-wide symbol table
        self.symbols: Dict[str, Sym] = {}
        self.insts: Dict[str, List[Inst]] = {}
        for comp, lines in raw.items():
            out = []
            for line in lines:
                dm = DEF_RE.match(line)
                if not dm:
                    continue
                is_root, name, rest = bool(dm.group(1)), dm.group(2), dm.group(3)
                om = OPCODE_RE.search(" " + rest)
                if not om:
                    continue
                opcode = om.group(1)
                head = rest[: max(om.start() - 1, 0)]
                shapes = SHAPE_RE.findall(head)
                if shapes:
                    b = sum(_DTYPE_BYTES[t] * _shape_elems(d)
                            for t, d in shapes)
                    e = sum(_shape_elems(d) for _, d in shapes)
                    dims = [int(x) for x in shapes[0][1].split(",") if x]
                    self.symbols[name] = Sym(b, e, dims)
                out.append(Inst(name, opcode,
                                self._parse_operands(rest, om.start() - 1),
                                line, is_root))
            self.insts[comp] = out
        self._memo: Dict[str, HloCosts] = {}

    @staticmethod
    def _parse_operands(rest: str, op_start: int) -> List[str]:
        tail = rest[op_start:]
        depth, end = 0, len(tail)
        for i, ch in enumerate(tail):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        return OPERAND_RE.findall(tail[:end])

    def _sym(self, name: str) -> Sym:
        return self.symbols.get(name, Sym(0, 0, None))

    # ------------------------------------------------------------------
    # Fusion I/O: slice-aware reads/writes
    # ------------------------------------------------------------------

    def _fusion_io_bytes(self, comp: str, operand_names: List[str],
                         out_bytes: int) -> Tuple[int, int]:
        insts = self.insts.get(comp, [])
        by_name = {i.name: i for i in insts}
        params: Dict[int, str] = {}
        consumers: Dict[str, List[Inst]] = {}
        root: Optional[Inst] = None
        for inst in insts:
            if inst.opcode == "parameter":
                pm = re.search(r"parameter\((\d+)\)", inst.line)
                if pm:
                    params[int(pm.group(1))] = inst.name
            else:
                for o in inst.operands:
                    consumers.setdefault(o, []).append(inst)
            if inst.is_root:
                root = inst

        def effective_consumers(name: str, depth: int = 0) -> List[Inst]:
            """Consumers, looking through pure layout ops (bitcast & co)."""
            out: List[Inst] = []
            for c in consumers.get(name, ()):
                if c.opcode in _TRANSPARENT and depth < 8:
                    out += effective_consumers(c.name, depth + 1)
                else:
                    out.append(c)
            return out

        read = 0
        for idx, pname in params.items():
            if idx >= len(operand_names):
                continue
            full = self._sym(operand_names[idx]).bytes
            got = 0
            sliced = True
            for c in effective_consumers(pname):
                if c.opcode in _SLICE_READERS:
                    got += self._sym(c.name).bytes
                elif c.opcode == "dynamic-update-slice":
                    # in-place update of an aliased buffer: the old buffer is
                    # not re-read; charge the update-sized region
                    got += (self._sym(c.operands[1]).bytes
                            if len(c.operands) > 1 else full)
                else:
                    sliced = False
                    break
            read += min(full, got) if sliced else full

        def resolve(name: str, depth: int = 0) -> Optional[Inst]:
            inst = by_name.get(name)
            while (inst is not None and inst.opcode in ("bitcast", "reshape",
                                                        "copy", "convert")
                   and inst.operands and depth < 8):
                inst = by_name.get(inst.operands[0])
                depth += 1
            return inst

        def elem_write(name: str) -> int:
            inst = resolve(name)
            if inst is None:
                return self._sym(name).bytes
            if inst.opcode == "dynamic-update-slice" and len(inst.operands) > 1:
                return self._sym(inst.operands[1]).bytes
            return self._sym(inst.name).bytes

        write = out_bytes
        if root is not None:
            r = resolve(root.name) or root
            if r.opcode == "dynamic-update-slice":
                write = elem_write(r.name)
            elif r.opcode == "tuple":
                write = sum(elem_write(o) for o in r.operands)
        return read, write

    # ------------------------------------------------------------------

    def _inst_costs(self, inst: Inst, costs: HloCosts, top_level: bool):
        opcode, line = inst.opcode, inst.line
        if opcode in _FREE_OPS:
            return
        sym = self._sym(inst.name)
        operand_b = sum(self._sym(o).bytes for o in inst.operands)

        base = opcode[:-6] if opcode.endswith("-start") else opcode
        if base in COLLECTIVES:
            group, spans = _parse_groups(line, self.pod_size)
            costs.collectives.append(CollectiveOp(
                op=base, operand_bytes=operand_b, output_bytes=sym.bytes,
                wire_bytes=_wire_bytes(base, operand_b, sym.bytes, group),
                group_size=group, spans_pod=spans,
            ))
            if top_level:
                costs.hbm_bytes += operand_b + sym.bytes
            return
        if opcode.endswith("-done"):
            return

        if opcode == "dot":
            contract = 1
            cm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", line)
            lhs = self._sym(inst.operands[0]) if inst.operands else None
            if cm and lhs and lhs.dims is not None:
                for d in (cm.group(1).split(",") if cm.group(1) else []):
                    contract *= lhs.dims[int(d)]
            costs.flops += 2.0 * sym.elems * contract
        elif opcode == "convolution":
            rhs = (self._sym(inst.operands[1])
                   if len(inst.operands) > 1 else None)
            o_size = 1
            m = re.search(r"dim_labels=\w+_(\w+)->", line)
            if m and rhs and rhs.dims is not None:
                for i, ch in enumerate(m.group(1)):
                    if ch == "o" and i < len(rhs.dims):
                        o_size = rhs.dims[i]
            rhs_elems = rhs.elems if rhs else 1
            costs.flops += 2.0 * sym.elems * (rhs_elems / max(o_size, 1))
        elif opcode in ("fusion", "while", "conditional", "call",
                        "custom-call"):
            return  # handled via recursion in _comp_costs
        else:
            costs.flops += float(sym.elems)

        if top_level:
            if opcode in _SLICE_READERS:
                costs.hbm_bytes += 2 * sym.bytes
            elif opcode == "dynamic-update-slice":
                upd = (self._sym(inst.operands[1]).bytes
                       if len(inst.operands) > 1 else sym.bytes)
                costs.hbm_bytes += 2 * upd
            elif opcode == "scatter":
                upd = (self._sym(inst.operands[2]).bytes
                       if len(inst.operands) > 2 else sym.bytes)
                costs.hbm_bytes += 2 * upd
            else:
                costs.hbm_bytes += operand_b + sym.bytes

    def _comp_costs(self, name: str, top_level: bool) -> HloCosts:
        key = f"{name}:{top_level}"
        if key in self._memo:
            return self._memo[key]
        costs = HloCosts()
        for inst in self.insts.get(name, ()):
            self._inst_costs(inst, costs, top_level)
            if inst.opcode == "fusion":
                cm = CALLS_RE.search(inst.line)
                if cm:
                    sub = self._comp_costs(cm.group(1), False)
                    costs.flops += sub.flops
                    costs.collectives += [dataclasses.replace(c)
                                          for c in sub.collectives]
                    if top_level:
                        r, w = self._fusion_io_bytes(
                            cm.group(1), inst.operands,
                            self._sym(inst.name).bytes)
                        costs.hbm_bytes += r + w
            elif inst.opcode == "while":
                bm = BODY_RE.search(inst.line)
                tm = TRIP_RE.search(inst.line)
                trip = int(tm.group(1)) if tm else 1
                if bm:
                    sub = self._comp_costs(bm.group(1), top_level)
                    costs.flops += sub.flops * trip
                    costs.hbm_bytes += sub.hbm_bytes * trip
                    for c in sub.collectives:
                        cc = dataclasses.replace(c)
                        cc.count = c.count * trip
                        costs.collectives.append(cc)
            elif inst.opcode in ("call", "conditional", "custom-call"):
                cm = re.search(
                    r"(?:to_apply|called_computations)=\{?%?([\w\.\-]+)",
                    inst.line)
                if cm and cm.group(1) in self.insts:
                    sub = self._comp_costs(cm.group(1), top_level)
                    costs.flops += sub.flops
                    costs.hbm_bytes += sub.hbm_bytes
                    costs.collectives += [dataclasses.replace(c)
                                          for c in sub.collectives]
        self._memo[key] = costs
        return costs

    def entry_costs(self) -> HloCosts:
        assert self.entry is not None, "no ENTRY computation found"
        return self._comp_costs(self.entry, True)


def analyze(compiled_text: str, *, pod_size: int = 0) -> dict:
    """-> JSON-friendly cost summary of a partitioned HLO module."""
    mod = HloModule(compiled_text, pod_size=pod_size)
    c = mod.entry_costs()
    per_op: Dict[str, dict] = {}
    for col in c.collectives:
        d = per_op.setdefault(col.op, {"count": 0, "wire_bytes": 0.0,
                                       "operand_bytes": 0.0, "max_group": 0})
        d["count"] += col.count
        d["wire_bytes"] += col.wire_bytes * col.count
        d["operand_bytes"] += col.operand_bytes * col.count
        d["max_group"] = max(d["max_group"], col.group_size)
    return {
        "flops": c.flops,
        "hbm_bytes": c.hbm_bytes,
        "collective_wire_bytes": c.collective_wire_bytes,
        "pod_spanning_bytes": c.pod_spanning_bytes,
        "collectives": per_op,
        "n_collective_sites": len(c.collectives),
    }
