"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this module
never touches jax device state — required because the dry-run forces 512
host devices while tests/benches must see 1 (assignment, MULTI-POD DRY-RUN
step 1).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (elastic restore targets, tests)."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def single_device_mesh():
    return jax.make_mesh((1, 1), ("data", "model"))
