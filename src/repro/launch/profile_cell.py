import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
if os.environ.get("REPRO_DRYRUN_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        "--xla_force_host_platform_device_count="
        + os.environ["REPRO_DRYRUN_DEVICES"])

"""Per-instruction HBM/flop attribution for one dry-run cell — the §Perf
"profiler" (we have no wall-clock on CPU; the lowered module is the profile).

    python -m repro.launch.profile_cell --arch minicpm-2b --shape train_4k \
        [--gs gs-richtmyer-meshkov] [--top 20] [--by flops]

``--gs-train DATASET`` profiles the PRODUCTION trainer instead of the
dense dry-run cell: the tiered ``make_gs_train_step`` that
``fit_partitions`` (and the ``--timeseries`` loop, once per timestep)
dispatches, lowered on the real ("part", "view") mesh — so per-timestep
profiles attribute the step the devices actually run:

    REPRO_DRYRUN_DEVICES=4 python -m repro.launch.profile_cell \
        --gs-train sphere_shell --gs-res 32 --top 10
"""

import argparse
import math
import re
from collections import Counter

import jax

from repro.launch import hlo_analysis as H
from repro.launch.dryrun import (lower_gs_cell, lower_gs_train_cell,
                                 lower_lm_cell, make_meshes)
from repro.configs import get_spec

OPNAME_RE = re.compile(r'op_name="([^"]*)"')


def attribute(mod: H.HloModule, by: str = "hbm"):
    contrib = Counter()

    def walk(comp, mult, top):
        for inst in mod.insts[comp]:
            c = H.HloCosts()
            mod._inst_costs(inst, c, top)
            val = c.hbm_bytes if by == "hbm" else c.flops
            if inst.opcode == "fusion":
                m = H.CALLS_RE.search(inst.line)
                if m:
                    sub = mod._comp_costs(m.group(1), False)
                    if by == "flops":
                        val += sub.flops
                    elif top:
                        r, w = mod._fusion_io_bytes(
                            m.group(1), inst.operands,
                            mod._sym(inst.name).bytes)
                        val += r + w
            if val:
                om = OPNAME_RE.search(inst.line)
                tag = om.group(1) if om else inst.opcode
                # collapse jit/transpose noise to the semantic op
                tag = re.sub(r"jit\(\w+\)/", "", tag)
                contrib[(inst.opcode, tag[:95])] += val * mult
            if inst.opcode == "while":
                bm = H.BODY_RE.search(inst.line)
                tm = H.TRIP_RE.search(inst.line)
                if bm:
                    walk(bm.group(1), mult * (int(tm.group(1)) if tm else 1),
                         top)

    walk(mod.entry, 1, True)
    return contrib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--gs", default="")
    ap.add_argument("--gs-train", default="",
                    help="profile the production tiered GS train step for "
                         "this dataset (sphere_shell/kingsnake/...) on a "
                         "('part','view') mesh")
    ap.add_argument("--gs-res", type=int, default=64)
    ap.add_argument("--gs-parts", type=int, default=2)
    ap.add_argument("--gs-view-batch", type=int, default=2)
    ap.add_argument("--mesh", default="single")
    ap.add_argument("--top", type=int, default=20)
    ap.add_argument("--by", default="hbm", choices=["hbm", "flops"])
    args = ap.parse_args()

    if args.gs_train:
        n = len(jax.devices())
        v = math.gcd(max(1, args.gs_view_batch), n)
        mesh = jax.make_mesh((n // v, v), ("part", "view"))
        lowered, meta = lower_gs_train_cell(
            args.gs_train, mesh, res=args.gs_res, n_parts=args.gs_parts,
            view_batch=args.gs_view_batch)
        name = (f"gs-train-{args.gs_train} res={meta['resolution']} "
                f"parts={meta['n_parts']} N/part="
                f"{meta['gaussians_per_part']} k_tiers={meta['k_tiers']}")
        args.mesh = f"{n // v}x{v} part,view"
    elif args.gs:
        mesh = make_meshes(args.mesh)[args.mesh]
        lowered, _, _ = lower_gs_cell(args.gs, mesh)
        name = args.gs
    else:
        mesh = make_meshes(args.mesh)[args.mesh]
        lowered = lower_lm_cell(get_spec(args.arch), args.shape, mesh)
        name = f"{args.arch}__{args.shape}"
    txt = lowered.compile().as_text()
    pod = 0
    mod = H.HloModule(txt, pod_size=pod)
    contrib = attribute(mod, args.by)
    total = sum(contrib.values())
    unit = "GB" if args.by == "hbm" else "GFLOP"
    print(f"{name} [{args.mesh}]  total {total/1e9:.1f} {unit} per device")
    for (opcode, tag), v in contrib.most_common(args.top):
        print(f"{v/1e9:10.2f} {unit}  {100*v/total:5.1f}%  {opcode:18s} {tag}")


if __name__ == "__main__":
    main()
