"""Serving driver: batched prefill + decode loop (CLI).

  python -m repro.launch.serve --arch qwen1.5-4b --smoke --batch 4 \
      --prompt-len 32 --gen 16

Serves a batch of synthetic prompts: one prefill step builds the KV caches,
then greedy decode streams tokens.  The same step functions are what the
dry-run lowers for decode_32k / long_500k on the production mesh.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_smoke, get_spec
    from repro.models import init_params, make_decode_step, make_prefill_step
    from repro.models.steps import cache_len, cache_specs

    spec = get_smoke(args.arch) if args.smoke else get_spec(args.arch)
    print(f"[serve] arch={spec.name} params={spec.param_count():,}")
    params = init_params(spec, jax.random.PRNGKey(args.seed))

    B, S = args.batch, args.prompt_len
    rng = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": jax.random.randint(rng, (B, S), 0, spec.vocab,
                                          jnp.int32)}
    if spec.family == "encdec":
        batch["frames"] = jax.random.normal(
            rng, (B, S, spec.frontend_dim), jnp.bfloat16)
    if spec.family == "vlm":
        batch = {
            "patches": jax.random.normal(
                rng, (B, spec.n_prefix_tokens, spec.frontend_dim),
                jnp.bfloat16),
            "tokens": batch["tokens"][:, : max(S - spec.n_prefix_tokens, 1)],
        }

    prefill = jax.jit(make_prefill_step(spec, kv_chunk=min(S, 128)))
    decode = jax.jit(make_decode_step(spec))

    t0 = time.perf_counter()
    logits, _prefill_caches = prefill(params, batch)
    logits.block_until_ready()
    t_prefill = time.perf_counter() - t0

    # fresh fixed-size decode cache (prompt replay then generation)
    total = S + args.gen + 1
    Lc = cache_len(spec, total)
    caches = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                          cache_specs(spec, B, Lc))
    toks = batch["tokens"]
    out_tokens = []
    t0 = time.perf_counter()
    pos = 0
    for i in range(toks.shape[1]):          # replay prompt through the cache
        tok, caches = decode(params, caches, toks[:, i:i + 1], jnp.int32(pos))
        pos += 1
    for i in range(args.gen):               # generate
        tok, caches = decode(params, caches, tok, jnp.int32(pos))
        out_tokens.append(np.asarray(tok[:, 0]))
        pos += 1
    jax.block_until_ready(tok)
    t_decode = time.perf_counter() - t0

    gen = np.stack(out_tokens, 1)
    print(f"[serve] prefill {B}x{S}: {t_prefill*1e3:.1f}ms   "
          f"decode {args.gen + toks.shape[1]} steps: {t_decode*1e3:.1f}ms "
          f"({t_decode/(args.gen+toks.shape[1])*1e3:.1f}ms/tok)")
    print(f"[serve] sample generations (token ids): {gen[:2, :8].tolist()}")
    assert int(gen.max()) < spec.vocab
    print("[serve] ok")


if __name__ == "__main__":
    main()
