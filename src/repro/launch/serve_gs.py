"""GS render serving driver (CLI).

    # train + checkpoint (writes <ckpt>/merged + scene meta):
    python -m repro.launch.train --gs --smoke --host-devices 4 \
        --steps 4 --ckpt-dir /tmp/gs
    # serve it: mixed near/far camera batches, two passes (the second
    # must hit the pose-bucket cache), telemetry JSON out:
    python -m repro.launch.serve_gs --ckpt-dir /tmp/gs --views 6 \
        --passes 2 --telemetry-json /tmp/serve.json

Loads the merged checkpoint ONCE (shape-free restore — the merged capacity
is a training outcome), builds the LOD ladder, then answers camera
requests through the bounded-queue batcher (core/serving.py): each pass
submits a mixed near/far orbital rig (near views exercise rung 0, far
views the pruned rungs) and flushes.  Exit is nonzero if a repeat pass
fails to hit the cache — the serving contract this driver exists to
demonstrate.  ``--host-devices N`` forces N host CPU devices before jax
imports (module level stays jax-free), mirroring launch/train.py so CI
can serve against the same forced-device smoke checkpoint it trained.
"""

from __future__ import annotations

import argparse
import json
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--ckpt-dir", default="checkpoints",
                    help="a launch/train.py --gs checkpoint tree (must "
                         "contain merged/)")
    ap.add_argument("--views", type=int, default=6,
                    help="cameras per pass (half near, half far)")
    ap.add_argument("--passes", type=int, default=2,
                    help="times to serve the SAME rig (pass >= 2 must hit "
                         "the pose-bucket cache)")
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--cache-entries", type=int, default=64)
    ap.add_argument("--near", type=float, default=1.0,
                    help="near orbit radius, in units of the training rig "
                         "radius")
    ap.add_argument("--far", type=float, default=5.0,
                    help="far orbit radius (same units) — drives LOD rung "
                         "selection")
    ap.add_argument("--impl", default="auto")
    ap.add_argument("--telemetry-json", default=None,
                    help="write the serving telemetry + per-pass stats "
                         "as JSON")
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N host CPU devices (before jax import)")
    args = ap.parse_args()
    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices} "
            + os.environ.get("XLA_FLAGS", ""))

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.core.cameras import Camera, orbital_rig
    from repro.core.serving import GSRenderServer

    server, extra = GSRenderServer.from_checkpoint(
        args.ckpt_dir, impl=args.impl, max_batch=args.max_batch,
        cache_entries=args.cache_entries)
    meta = extra.get("scene", {})
    g0 = server.ladder[0]
    print(f"[serve-gs] devices={len(jax.devices())} "
          f"model={int(np.asarray(g0.active).sum()):,} live splats "
          f"grid={server.grid.width}x{server.grid.height} "
          f"ladder K={server.schedule.k_tiers} "
          f"lod rungs={[int(np.asarray(r.active).sum()) for r in server.ladder]} "
          f"dists={tuple(round(d, 3) for d in server.lod_dists)}")

    # mixed near/far rig around the checkpointed scene frame: near views
    # stay on rung 0, far views select the pruned rungs
    rig_r = float(meta.get("radius", server.radius))
    center = meta.get("center", server.center)
    res = server.grid.width
    n_near = max(1, args.views // 2)
    n_far = max(1, args.views - n_near)
    near = orbital_rig(n_near, center, rig_r * args.near,
                       width=res, height=res)
    far = orbital_rig(n_far, center, rig_r * args.far,
                      width=res, height=res)
    rig = Camera(view=jnp.concatenate([near.view, far.view]),
                 fx=jnp.concatenate([near.fx, far.fx]),
                 fy=jnp.concatenate([near.fy, far.fy]),
                 width=res, height=res)

    passes = []
    for p in range(args.passes):
        t0 = time.perf_counter()
        results = server.serve(rig)
        dt = time.perf_counter() - t0
        hits = sum(r.cache_hit for r in results)
        rungs = sorted({r.rung for r in results})
        assert all(np.isfinite(r.rgb).all() for r in results)
        print(f"[serve-gs] pass {p}: {len(results)} requests in "
              f"{dt * 1e3:.1f}ms ({len(results) / dt:.1f} req/s)  "
              f"cache hits {hits}/{len(results)}  rungs {rungs}")
        passes.append({"requests": len(results), "wall_s": dt,
                       "req_per_s": len(results) / dt, "hits": hits,
                       "rungs": rungs})

    tel = server.telemetry()
    print(f"[serve-gs] telemetry {tel}")
    if args.telemetry_json:
        with open(args.telemetry_json, "w") as f:
            json.dump({"telemetry": tel, "passes": passes,
                       "scene": meta}, f, indent=1)
        print(f"[serve-gs] telemetry -> {args.telemetry_json}")
    if args.passes >= 2 and passes[-1]["hits"] < passes[-1]["requests"]:
        raise SystemExit(
            "[serve-gs] FAIL: repeat pass hit the cache on only "
            f"{passes[-1]['hits']}/{passes[-1]['requests']} requests")
    print("[serve-gs] ok")


if __name__ == "__main__":
    main()
