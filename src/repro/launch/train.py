"""Training driver (CLI).

Two modes, one runtime:

  LM:  python -m repro.launch.train --arch minicpm-2b --smoke --steps 20
  GS:  python -m repro.launch.train --gs --dataset kingsnake --parts 2 \
           --steps 200 --resolution 64

Both wire the full production substrate: mesh construction, sharded-state
init, checkpoint/restart (resumes automatically from the latest complete
checkpoint), heartbeats, retry, gradient compression (LM), and the paper's
partition pipeline (GS).  On CPU this runs reduced configs; on a pod the
same driver runs the full ones (--full).
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def run_lm(args):
    from repro.configs import get_smoke, get_spec
    from repro.data.tokens import SyntheticTokens
    from repro.models import (TrainCfg, init_opt_state, init_params,
                              make_train_step)
    from repro.runtime import CheckpointManager, Heartbeat, retry_step

    spec = get_smoke(args.arch) if args.smoke else get_spec(args.arch)
    cfg = TrainCfg(total_steps=args.steps, compression=args.compression,
                   schedule=spec.lr_schedule, kv_chunk=args.kv_chunk,
                   n_microbatches=args.microbatches)
    print(f"[train] arch={spec.name} params={spec.param_count():,} "
          f"policy={spec.sharding_policy}")
    params = init_params(spec, jax.random.PRNGKey(args.seed))
    opt = init_opt_state(spec, params, cfg)
    step_fn = jax.jit(make_train_step(spec, cfg))

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    hb = Heartbeat(args.ckpt_dir, "worker0")
    start = 0
    latest = ckpt.latest_step()
    if latest is not None:
        (params, opt), extra = ckpt.restore(latest, (params, opt))
        start = latest
        print(f"[train] resumed from step {start}")

    data = SyntheticTokens(vocab=spec.vocab, seq=args.seq,
                           global_batch=args.batch, seed=args.seed)
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = data.batch(step)
        params, opt, metrics = retry_step(step_fn, params, opt, batch)
        hb.beat(step)
        if (step + 1) % args.log_every == 0:
            dt = (time.perf_counter() - t0) / args.log_every
            t0 = time.perf_counter()
            print(f"  step {step+1:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms/step")
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt), extra={"arch": spec.name})
    ckpt.save(args.steps, (params, opt), extra={"arch": spec.name})
    print("[train] done")


def run_gs(args):
    from repro.core.pipeline import PipelineCfg, run_pipeline
    from repro.core.train import GSTrainCfg
    from repro.runtime import CheckpointManager

    cfg = PipelineCfg(
        dataset=args.dataset, tier="full" if args.full else "cpu",
        n_parts=args.parts, resolution=args.resolution, steps=args.steps,
        n_views=args.views, densify_every=args.densify_every,
        use_ghost=not args.no_ghost, use_mask=not args.no_mask,
        train=GSTrainCfg(), seed=args.seed,
    )
    print(f"[train-gs] dataset={args.dataset} parts={args.parts} "
          f"res={args.resolution} ghost={cfg.use_ghost} mask={cfg.use_mask}")
    res = run_pipeline(cfg)
    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    for p, g in enumerate(res.parts):
        ckpt.save(args.steps, g, partition=p,
                  extra={"dataset": args.dataset, "psnr": res.psnr})
    print(f"[train-gs] PSNR {res.psnr:.2f}  SSIM {res.ssim:.4f}  "
          f"grad_sim {res.grad_sim:.4f}  gaussians {res.n_gaussians:,}")
    print(f"[train-gs] per-partition train time "
          f"{[round(t,1) for t in res.train_seconds]}s")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gs", action="store_true")
    # LM
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--kv-chunk", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8"])
    # GS
    ap.add_argument("--dataset", default="sphere_shell")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--parts", type=int, default=2)
    ap.add_argument("--resolution", type=int, default=64)
    ap.add_argument("--views", type=int, default=None)
    ap.add_argument("--densify-every", type=int, default=0)
    ap.add_argument("--no-ghost", action="store_true")
    ap.add_argument("--no-mask", action="store_true")
    # common
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()
    (run_gs if args.gs else run_lm)(args)


if __name__ == "__main__":
    main()
