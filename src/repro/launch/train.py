"""Training driver (CLI).

Two modes, one runtime:

  LM:  python -m repro.launch.train --arch minicpm-2b --smoke --steps 20
  GS:  python -m repro.launch.train --gs --dataset kingsnake --parts 2 \
           --steps 200 --resolution 64

Both wire the full production substrate: mesh construction, sharded-state
init, checkpoint/restart (resumes automatically from the latest complete
checkpoint), heartbeats, retry, gradient compression (LM), and the paper's
partition pipeline (GS).  On CPU this runs reduced configs; on a pod the
same driver runs the full ones (--full).

The GS mode is the paper's end-to-end workflow on the distributed
tier-schedule driver (core/distributed.py::fit_partitions): partition (+
ghost cells) -> per-partition GT renders + coverage masks -> TIERED
distributed training of every partition in one SPMD program on the
("part", "view") mesh (probe -> train -> densify -> re-probe; TierSchedule
state checkpointed alongside params, so a restart resumes without
re-probing) -> merge -> global render + metrics.  ``--host-devices N``
forces N host-backed CPU devices (set before jax import), so the whole
multi-device lifecycle runs on a laptop or in CI:

    python -m repro.launch.train --gs --smoke --host-devices 4 --steps 6

jax is imported lazily (inside the run functions) so the flag can take
effect; keep module-level imports jax-free.
"""

from __future__ import annotations

import argparse
import math
import os
import time


def run_lm(args):
    import jax

    from repro.configs import get_smoke, get_spec
    from repro.data.tokens import SyntheticTokens
    from repro.models import (TrainCfg, init_opt_state, init_params,
                              make_train_step)
    from repro.runtime import CheckpointManager, Heartbeat, retry_step

    spec = get_smoke(args.arch) if args.smoke else get_spec(args.arch)
    cfg = TrainCfg(total_steps=args.steps, compression=args.compression,
                   schedule=spec.lr_schedule, kv_chunk=args.kv_chunk,
                   n_microbatches=args.microbatches)
    print(f"[train] arch={spec.name} params={spec.param_count():,} "
          f"policy={spec.sharding_policy}")
    params = init_params(spec, jax.random.PRNGKey(args.seed))
    opt = init_opt_state(spec, params, cfg)
    step_fn = jax.jit(make_train_step(spec, cfg))

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    hb = Heartbeat(args.ckpt_dir, "worker0")
    (params, opt), _, latest = ckpt.restore_latest((params, opt))
    start = latest or 0
    if latest is not None:
        print(f"[train] resumed from step {start}")

    data = SyntheticTokens(vocab=spec.vocab, seq=args.seq,
                           global_batch=args.batch, seed=args.seed)
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = data.batch(step)
        params, opt, metrics = retry_step(step_fn, params, opt, batch)
        hb.beat(step)
        if (step + 1) % args.log_every == 0:
            dt = (time.perf_counter() - t0) / args.log_every
            t0 = time.perf_counter()
            print(f"  step {step+1:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} {dt*1e3:.0f}ms/step")
        if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
            ckpt.save(step + 1, (params, opt), extra={"arch": spec.name})
    ckpt.save(args.steps, (params, opt), extra={"arch": spec.name})
    print("[train] done")


def run_gs(args):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.gs_datasets import get_gs_dataset
    from repro.core import merge as merge_mod
    from repro.core import metrics
    from repro.core.cameras import orbital_rig
    from repro.core.distributed import fit_partitions
    from repro.core.partition import partition_points
    from repro.core.pipeline import (build_scene, coverage_masks,
                                     gt_gaussians, init_partition_gaussians,
                                     render_views)
    from repro.core.tiling import TileGrid
    from repro.core.train import GSTrainCfg
    from repro.runtime import CheckpointManager

    if args.smoke:
        # tiny full-lifecycle config: 2 partitions, small scene, densify
        # mid-run so the probe -> train -> densify -> re-probe loop (and a
        # checkpointed schedule) is exercised end to end on forced host
        # devices.  --steps/--ckpt-dir stay caller-controlled so CI can run
        # the resume path with a second invocation.
        args.dataset = "sphere_shell"
        args.parts = 2
        args.resolution = min(args.resolution, 32)
        args.views = args.views or 4
        args.view_batch = args.view_batch or 2
        if args.densify_every == 0:
            args.densify_every, args.densify_from = 2, 1
        if args.ckpt_every == 0:
            args.ckpt_every = 2

    cfg = GSTrainCfg(view_batch=args.view_batch or 1,
                     exchange=args.exchange,
                     exchange_budget=args.exchange_budget,
                     dtype_policy=args.dtype_policy,
                     grad_compress=args.grad_compress)
    ds = get_gs_dataset(args.dataset, "full" if args.full else "cpu")
    n_views = args.views or ds.n_views
    points, colors, extent = build_scene(ds, args.seed)
    center = 0.5 * (points.max(0) + points.min(0))
    radius = 1.6 * extent / 2 + 1e-3
    W = H = args.resolution
    grid = TileGrid(W, H, cfg.tile_h, cfg.tile_w)
    cams = orbital_rig(n_views, center, radius, width=W, height=H)

    # partition (+ ghost halo) -> equal-capacity batched (P, N) layout
    ghost_w = ds.ghost_frac * extent if not args.no_ghost else 0.0
    parts, _ = partition_points(points, colors, args.parts,
                                ghost_width=ghost_w)

    n_dev = len(jax.devices())
    if args.mesh:
        p, v = (int(x) for x in args.mesh.lower().split("x"))
        if p * v != n_dev:
            raise SystemExit(f"--mesh {args.mesh} needs {p * v} devices, "
                             f"have {n_dev} (try --host-devices {p * v})")
    else:
        # widest "view" axis the EFFECTIVE minibatch supports (the driver
        # clamps view_batch to the view count); the rest go to "part"
        v = math.gcd(max(1, min(cfg.view_batch, n_views)), n_dev)
        p = n_dev // v
    mesh = jax.make_mesh((p, v), ("part", "view"))

    base = max(len(pd.points) for pd in parts)
    cap = int(base * ds.capacity_factor) if args.densify_every else base
    cap = -(-cap // p) * p          # "part"-shardable capacity
    g = jax.tree.map(lambda *xs: jnp.stack(xs),
                     *[init_partition_gaussians(pd, capacity=cap)
                       for pd in parts])

    # per-partition GT renders of own (+ghost) data and coverage masks.
    # Training GT is rendered at bg=0: the distributed tile loss compares
    # RAW premultiplied color tiles (no background composite), so a
    # white-composited target would carry a bias the prediction can never
    # produce (the driver parity tests pin the same convention); the
    # white-background renders stay eval-only below.
    gts, masks = [], []
    for pd in parts:
        part_gt, part_cov = render_views(
            gt_gaussians(pd.points, pd.colors), cams, grid, K=cfg.K,
            bg=0.0)
        gts.append(part_gt)
        if not args.no_mask:
            masks.append(coverage_masks(part_cov))
    gts = jnp.asarray(np.stack(gts))
    masks = None if args.no_mask else jnp.asarray(np.stack(masks))

    kt = cfg.resolved_k_tiers()
    table = "exchange" if cfg.exchange else "all-gather"
    if cfg.exchange and cfg.exchange_budget:
        table += f"(budget={cfg.exchange_budget})"
    print(f"[train-gs] dataset={args.dataset} parts={args.parts} "
          f"res={args.resolution} views={n_views} mesh={p}x{v} "
          f"({n_dev} devices) ghost={not args.no_ghost} "
          f"mask={not args.no_mask} table={table} raster="
          f"{'tiered ' + str(kt) if kt else 'dense K=' + str(cfg.assign_K)} "
          f"dtype={cfg.dtype_policy} grad-compress={cfg.grad_compress}")

    ckpt = CheckpointManager(args.ckpt_dir, keep=2)
    latest = ckpt.latest_restorable_step()
    if latest is not None:
        print(f"[train-gs] resuming from checkpoint step {latest} "
              "(schedule restored, no re-probe)")
    sched = cfg.tier_schedule()
    t0 = time.perf_counter()
    g1, _, losses = fit_partitions(
        g, cams, gts, masks, cfg, mesh=mesh, steps=args.steps,
        extent=extent, key=jax.random.PRNGKey(args.seed),
        densify_every=args.densify_every, densify_from=args.densify_from,
        grid=grid, schedule=sched, ckpt=ckpt, ckpt_every=args.ckpt_every,
        rebalance_every=args.rebalance_every,
        log_every=args.log_every)
    train_s = time.perf_counter() - t0
    # a restored checkpoint may already be PAST --steps; label everything
    # downstream (log line, per-partition checkpoints) with the step the
    # parameters actually correspond to
    done = max(args.steps, latest or 0)
    if losses:
        print(f"[train-gs] trained steps {latest or 0}->{done} "
              f"({len(losses)} ran, {train_s:.1f}s)  "
              f"final loss {losses[-1]:.4f}")
    else:
        print(f"[train-gs] checkpoint already at step {done}; "
              "skipping to merge")
    if sched is not None:
        print(f"[train-gs] schedule: {sched}")

    # per-partition checkpoints (paper's O(1/n) failure recovery), then the
    # global reconstruction: merge -> render -> metrics
    host = jax.device_get(g1)
    part_list = [jax.tree.map(lambda x: x[i], host)
                 for i in range(args.parts)]
    pckpt = CheckpointManager(os.path.join(args.ckpt_dir, "partitions"),
                              keep=2)
    for pid, gp in enumerate(part_list):
        pckpt.save(done, gp, partition=pid,
                   extra={"dataset": args.dataset})

    merged = merge_mod.merge_partitions(part_list,
                                        [pd.part_id for pd in parts])
    gt_imgs, _ = render_views(gt_gaussians(points, colors), cams, grid,
                              K=cfg.K)
    renders, _ = render_views(merged, cams, grid, K=cfg.K)
    ps = float(np.mean([metrics.psnr(jnp.asarray(renders[i]),
                                     jnp.asarray(gt_imgs[i]))
                        for i in range(n_views)]))
    ss = float(np.mean([metrics.ssim(jnp.asarray(renders[i]),
                                     jnp.asarray(gt_imgs[i]))
                        for i in range(n_views)]))
    print(f"[train-gs] PSNR {ps:.2f}  SSIM {ss:.4f}  "
          f"gaussians {int(np.asarray(merged.active).sum()):,}")

    # train->serve handoff: the MERGED model as its own checkpoint (the
    # per-partition tree above is the recovery path; the serving driver
    # launch/serve_gs.py restores THIS one, shape-free) + the scene frame
    # it needs to rebuild the grid/rig, + the final merged render so the
    # round-trip test can pin restore-and-render == trainer output at 1e-6
    mckpt = CheckpointManager(os.path.join(args.ckpt_dir, "merged"), keep=2)
    merged_extra = {"scene": {
        "dataset": args.dataset, "resolution": args.resolution,
        "center": [float(c) for c in center], "radius": float(radius),
        "extent": float(extent), "n_views": int(n_views), "K": int(cfg.K),
        "tile_h": int(cfg.tile_h), "tile_w": int(cfg.tile_w),
    }}
    merged_save = merged
    if args.ckpt_quantize == "int8":
        # cold attributes (SH color, opacity logit) as int8 with per-tensor
        # scales riding extra["quant"]; serving dequantizes on restore
        from repro.runtime.checkpoint import quantize_cold
        merged_save, quant_meta = quantize_cold(merged)
        merged_extra["quant"] = quant_meta
        print("[train-gs] merged checkpoint cold attributes quantized "
              f"(int8, fields={list(quant_meta['fields'])})")
    mckpt.save(done, merged_save, extra=merged_extra)
    np.save(os.path.join(args.ckpt_dir, "render_final.npy"), renders)
    print(f"[train-gs] merged checkpoint (step {done}) + final render "
          f"saved under {args.ckpt_dir}")


def run_gs_timeseries(args):
    """Time-series training loop (``--gs --timeseries``): timesteps
    t=0..T-1 of the evolving volume, each warm-started from the previous
    timestep's committed state via the resume path (restored TierSchedule
    caps + ExchangeSchedule budgets, NO init re-probe), with delta
    checkpoints between timesteps and timestep t+1's host ingest
    (extraction -> partition -> GT renders -> masks) prefetched on a
    background thread while timestep t trains on the devices.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs.gs_datasets import get_gs_dataset
    from repro.core import merge as merge_mod
    from repro.core import metrics
    from repro.core.cameras import orbital_rig
    from repro.core.distributed import ExchangeSchedule, fit_partitions
    from repro.core.pipeline import (TimestepPrefetcher, build_scene,
                                     gt_gaussians, prepare_timestep,
                                     render_views)
    from repro.core.tiling import TileGrid
    from repro.core.train import GSTrainCfg, init_opt
    from repro.runtime import CheckpointManager

    if args.smoke:
        args.dataset = "sphere_shell"
        args.parts = 2
        args.resolution = min(args.resolution, 32)
        args.views = args.views or 4
        args.view_batch = args.view_batch or 2
        args.timesteps = min(args.timesteps, 2)
        if args.densify_every == 0:
            args.densify_every, args.densify_from = 2, 1
        if args.densify_cap is None:
            args.densify_cap = 4096

    cfg = GSTrainCfg(view_batch=args.view_batch or 1,
                     exchange=args.exchange,
                     exchange_budget=args.exchange_budget,
                     dtype_policy=args.dtype_policy,
                     grad_compress=args.grad_compress)
    ds = get_gs_dataset(args.dataset, "full" if args.full else "cpu")
    n_views = args.views or ds.n_views
    T, S = args.timesteps, args.steps

    # series-fixed frame: rig, grid, capacity all come from the t=0 scene
    # so every timestep shares ONE (P, N)/(P, V, H, W) layout — the
    # warm-started state and the delta diffs both depend on it
    points, colors, extent = build_scene(ds, args.seed, t=0.0)
    center = 0.5 * (points.max(0) + points.min(0))
    radius = 1.6 * extent / 2 + 1e-3
    W = H = args.resolution
    grid = TileGrid(W, H, cfg.tile_h, cfg.tile_w)
    cams = orbital_rig(n_views, center, radius, width=W, height=H)

    n_dev = len(jax.devices())
    if args.mesh:
        p, v = (int(x) for x in args.mesh.lower().split("x"))
        if p * v != n_dev:
            raise SystemExit(f"--mesh {args.mesh} needs {p * v} devices, "
                             f"have {n_dev} (try --host-devices {p * v})")
    else:
        v = math.gcd(max(1, min(cfg.view_batch, n_views)), n_dev)
        p = n_dev // v
    mesh = jax.make_mesh((p, v), ("part", "view"))

    from repro.core.partition import partition_points
    parts0, _ = partition_points(
        points, colors, args.parts,
        ghost_width=ds.ghost_frac * extent if not args.no_ghost else 0.0)
    base = max(len(pd.points) for pd in parts0)
    # capacity_factor slack covers both densify growth AND per-timestep
    # extraction drift (prepare_timestep fails loudly if a later timestep
    # outgrows it)
    cap = int(base * ds.capacity_factor) if args.densify_every else base
    cap = -(-cap // p) * p

    print(f"[train-gs-ts] dataset={args.dataset} timesteps={T} dt={args.dt} "
          f"steps/timestep={S} parts={args.parts} res={args.resolution} "
          f"mesh={p}x{v} ({n_dev} devices) capacity={cap} "
          f"densify_cap={args.densify_cap} "
          f"dtype={cfg.dtype_policy} grad-compress={cfg.grad_compress}")

    # delta-checkpoint chain: one manager, keep=0 (deltas need their whole
    # base chain on disk), full save at timestep 0, per-field sparse row
    # diffs after that.  A restart resumes at the last COMMITTED timestep.
    tck = CheckpointManager(os.path.join(args.ckpt_dir, "timeseries"),
                            keep=0)
    latest = tck.latest_restorable_step()
    t_start = 0 if latest is None else latest // S
    if t_start:
        print(f"[train-gs-ts] restarting at timestep {t_start} "
              f"(chain committed through step {latest})")

    def prep(t_idx):
        return prepare_timestep(
            ds, cams, grid, t=t_idx * args.dt, seed=args.seed,
            n_parts=args.parts, capacity=cap, K=cfg.K,
            use_ghost=not args.no_ghost, use_mask=not args.no_mask)

    warm = None          # (host state tree, extra, global step)
    td = None
    g1 = None
    key = jax.random.PRNGKey(args.seed)
    with TimestepPrefetcher() as pf:
        pf.submit(prep, t_start)
        for t in range(t_start, T):
            td = pf.get()
            if t + 1 < T:
                # streaming ingest: t+1's host prep overlaps t's training
                pf.submit(prep, t + 1)
            if warm is None and t > 0:
                # restart path: rebuild the warm seed from the committed
                # delta chain (exactly what a fresh process has)
                like = (jax.device_get(td.g0),
                        jax.device_get(init_opt(td.g0)))
                warm = (*tck.restore_delta(t * S, like), t * S)
            if t > 0:
                src = warm[1].get("timestep", t - 1)
                print(f"[train-gs-ts] timestep {t}: warm-start from "
                      f"timestep {src} (step {warm[2]}) — schedule + "
                      "exchange restored, no init probe")
            else:
                print("[train-gs-ts] timestep 0: cold start")

            sched = cfg.tier_schedule()
            ex = ExchangeSchedule(budget=cfg.exchange_budget) \
                if cfg.exchange else None
            t0 = time.perf_counter()
            g1, opt1, losses = fit_partitions(
                td.g0, cams, jnp.asarray(td.gts),
                None if td.masks is None else jnp.asarray(td.masks),
                cfg, mesh=mesh, steps=(t + 1) * S, extent=td.extent,
                key=key, densify_every=args.densify_every,
                # densify_from stays SERIES-absolute: the per-call key
                # fast-forward then replays exactly the densify keys a
                # continuous (or disk-resumed) run would have consumed, so
                # a repeated static timestep is bit-on the resume oracle
                densify_from=args.densify_from, grid=grid,
                schedule=sched, exchange_schedule=ex,
                rebalance_every=args.rebalance_every,
                log_every=args.log_every, warm_start=warm,
                densify_cap=args.densify_cap)
            dt_s = time.perf_counter() - t0
            live = int(np.asarray(g1.active).sum())
            print(f"[train-gs-ts] timestep {t} (t={td.t:.3f}): "
                  f"steps {t * S}->{(t + 1) * S} ({dt_s:.1f}s)  "
                  f"final loss {losses[-1]:.4f}  live splats {live:,}")

            # commit the timestep: full checkpoint for the chain head,
            # sparse row-delta against the previous timestep after that
            tree = jax.tree.map(jax.device_get, (g1, opt1))
            extra = {"timestep": t, "t": float(td.t),
                     "schedule": sched.state_dict() if sched else None,
                     "exchange": ex.state_dict() if ex else None,
                     "dtype_policy": cfg.dtype_policy,
                     "grad_compress": cfg.grad_compress}
            if t == 0:
                tck.save(S, tree, extra=extra)
            else:
                tck.save_delta((t + 1) * S, tree, base_step=t * S,
                               extra=extra)
            warm = (tree, extra, (t + 1) * S)

    if g1 is None:
        # the chain is already complete: reload the final timestep for the
        # merge/eval tail below
        td = prep(T - 1)
        like = (jax.device_get(td.g0), jax.device_get(init_opt(td.g0)))
        (g1, _), _ = tck.restore_delta(T * S, like)
        print(f"[train-gs-ts] chain already complete at timestep {T - 1}; "
              "skipping to merge")

    # merge + eval + serving checkpoint for the FINAL timestep (same tail
    # as the single-snapshot driver, labelled with the series step)
    done = T * S
    host = jax.device_get(g1)
    part_list = [jax.tree.map(lambda x: x[i], host)
                 for i in range(args.parts)]
    pckpt = CheckpointManager(os.path.join(args.ckpt_dir, "partitions"),
                              keep=2)
    for pid, gp in enumerate(part_list):
        pckpt.save(done, gp, partition=pid,
                   extra={"dataset": args.dataset, "timestep": T - 1})

    merged = merge_mod.merge_partitions(part_list,
                                        [pd.part_id for pd in td.parts])
    gt_imgs, _ = render_views(gt_gaussians(td.points, td.colors), cams,
                              grid, K=cfg.K)
    renders, _ = render_views(merged, cams, grid, K=cfg.K)
    ps = float(np.mean([metrics.psnr(jnp.asarray(renders[i]),
                                     jnp.asarray(gt_imgs[i]))
                        for i in range(n_views)]))
    ss = float(np.mean([metrics.ssim(jnp.asarray(renders[i]),
                                     jnp.asarray(gt_imgs[i]))
                        for i in range(n_views)]))
    print(f"[train-gs-ts] timestep {T - 1} PSNR {ps:.2f}  SSIM {ss:.4f}  "
          f"gaussians {int(np.asarray(merged.active).sum()):,}")

    mckpt = CheckpointManager(os.path.join(args.ckpt_dir, "merged"), keep=2)
    merged_extra = {"scene": {
        "dataset": args.dataset, "resolution": args.resolution,
        "center": [float(c) for c in center], "radius": float(radius),
        "extent": float(td.extent), "n_views": int(n_views),
        "K": int(cfg.K), "tile_h": int(cfg.tile_h),
        "tile_w": int(cfg.tile_w),
    }, "timestep": T - 1, "t": float(td.t)}
    merged_save = merged
    if args.ckpt_quantize == "int8":
        from repro.runtime.checkpoint import quantize_cold
        merged_save, quant_meta = quantize_cold(merged)
        merged_extra["quant"] = quant_meta
        print("[train-gs-ts] merged checkpoint cold attributes quantized "
              f"(int8, fields={list(quant_meta['fields'])})")
    mckpt.save(done, merged_save, extra=merged_extra)
    np.save(os.path.join(args.ckpt_dir, "render_final.npy"), renders)
    print(f"[train-gs-ts] merged checkpoint (step {done}) + final render "
          f"saved under {args.ckpt_dir}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--gs", action="store_true")
    # LM
    ap.add_argument("--arch", default="minicpm-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="LM: reduced same-family config (CPU); GS: tiny "
                         "full-lifecycle run (2 parts, small scene, densify "
                         "+ checkpoint on)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--kv-chunk", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8"])
    # GS
    ap.add_argument("--dataset", default="sphere_shell")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--parts", type=int, default=2)
    ap.add_argument("--resolution", type=int, default=64)
    ap.add_argument("--views", type=int, default=None)
    ap.add_argument("--view-batch", type=int, default=None,
                    help="views per minibatch step (sharded over the mesh's "
                         "'view' axis; must divide by its size)")
    ap.add_argument("--mesh", default=None,
                    help="PARTxVIEW device mesh shape, e.g. 2x2 (default: "
                         "widest 'view' axis the view batch supports)")
    ap.add_argument("--densify-every", type=int, default=0)
    ap.add_argument("--densify-from", type=int, default=100)
    ap.add_argument("--exchange", action="store_true",
                    help="sparse-overlap splat exchange instead of the "
                         "full-table all-gather (probed edge budgets, "
                         "psum'd overflow counters)")
    ap.add_argument("--exchange-budget", type=int, default=None,
                    help="pin the per-(src,dst) edge budget instead of "
                         "probing it")
    ap.add_argument("--rebalance-every", type=int, default=0,
                    help="check per-shard live-splat skew every N steps "
                         "and permute rows to rebalance (0 = off)")
    ap.add_argument("--no-ghost", action="store_true")
    ap.add_argument("--no-mask", action="store_true")
    ap.add_argument("--dtype-policy", default="f32",
                    choices=["f32", "bf16"],
                    help="GS storage/wire dtype: bf16 halves gathered/"
                         "exchanged splat tables and collective payload; "
                         "compositing, loss and optimizer stay f32. Resume "
                         "across a policy change fails loudly.")
    ap.add_argument("--grad-compress", default="none",
                    choices=["none", "bf16", "int8"],
                    help="GS gradient wire compression (optim/compress.py); "
                         "int8 carries error feedback in step state and "
                         "through checkpoints")
    ap.add_argument("--timeseries", action="store_true",
                    help="GS: train timesteps t=0..T-1 of the evolving "
                         "volume; each timestep warm-starts from the "
                         "previous one's committed state (restored "
                         "schedule/exchange, no init re-probe) with delta "
                         "checkpoints between timesteps and next-timestep "
                         "ingest prefetched during training")
    ap.add_argument("--timesteps", type=int, default=4,
                    help="number of timesteps T for --timeseries")
    ap.add_argument("--dt", type=float, default=0.1,
                    help="simulation-time spacing between timesteps "
                         "(volume fields evolve as t = index * dt)")
    ap.add_argument("--densify-cap", type=int, default=None,
                    help="hard ceiling on LIVE splats per partition: "
                         "densify stops growing at the cap, so memory "
                         "stays bounded across timesteps (GeoGaussian-"
                         "style num_max; default: uncapped)")
    ap.add_argument("--ckpt-quantize", default="none",
                    choices=["none", "int8"],
                    help="quantize merged-checkpoint cold attributes "
                         "(SH color, opacity logit) to int8 with per-tensor "
                         "scales; geometry stays f32")
    # common
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--host-devices", type=int, default=0,
                    help="force N host-backed CPU devices (applied BEFORE "
                         "jax import; lets the distributed GS driver run "
                         "its real multi-device mesh on one machine/CI)")
    args = ap.parse_args()
    if args.host_devices:
        os.environ["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={args.host_devices} "
            + os.environ.get("XLA_FLAGS", ""))
    if args.gs and args.timeseries:
        run_gs_timeseries(args)
    elif args.gs:
        run_gs(args)
    else:
        run_lm(args)


if __name__ == "__main__":
    main()
