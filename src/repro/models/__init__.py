from repro.models.spec import ModelSpec, MoECfg, SSMCfg
from repro.models.params import init_params, param_specs, param_shardings, param_pspecs
from repro.models.steps import (
    SHAPES,
    TrainCfg,
    make_train_step,
    make_prefill_step,
    make_decode_step,
    input_specs,
    input_pspecs,
    cache_specs,
    cache_pspecs,
    init_opt_state,
    opt_state_specs,
    opt_state_shardings,
)
