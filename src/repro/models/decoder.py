"""Model forward passes: train/prefill forward, single-token decode, enc-dec.

The layer stack is a ``lax.scan`` over *superblocks* (see spec.py): each
superblock applies ``period`` slots whose types (attention / mamba / MLP / MoE)
are static Python, so heterogeneous architectures (Jamba) compile to one small
scanned HLO body.  Remat wraps the superblock body.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as L
from repro.models.spec import ModelSpec


# ---------------------------------------------------------------------------
# Embedding / head
# ---------------------------------------------------------------------------


def sinusoidal_pe(positions, d_model: int, dtype=jnp.float32):
    """positions: (S,) -> (S, d_model) fixed sinusoidal embeddings."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


def embed_tokens(spec: ModelSpec, params, tokens, positions=None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if spec.name.startswith("paligemma"):
        x = x * jnp.asarray(spec.d_model**0.5, x.dtype)  # gemma embed scaling
    if spec.rope_theta == 0.0 and positions is not None:
        # no RoPE (whisper): absolute sinusoidal positions on the decoder side
        x = x + sinusoidal_pe(positions, spec.d_model, x.dtype)[None]
    return L.constrain_batch(x)


def lm_logits(spec: ModelSpec, params, x):
    if spec.tie_embeddings:
        return x @ params["embed"].T
    return x @ params["head"]


def vocab_mask_bias(spec: ModelSpec, dtype=jnp.float32):
    """Additive bias masking padded vocab entries out of the softmax."""
    idx = jnp.arange(spec.padded_vocab)
    return jnp.where(idx < spec.vocab, 0.0, L.NEG_INF).astype(dtype)


# ---------------------------------------------------------------------------
# Superblock bodies
# ---------------------------------------------------------------------------


def _apply_slot_train(spec: ModelSpec, slot: int, x, sp, positions, prefix_len,
                      kv_chunk, want_cache, enc_h=None):
    """One slot (layer) of a superblock, training/prefill mode.

    Returns (x, aux_loss, cache_or_None).
    """
    aux = jnp.float32(0.0)
    cache = None
    if spec.is_attn_slot(slot):
        h = L.apply_norm(spec, x, sp["ln_attn"])
        o, kv = L.attention_block(
            spec, h, sp["attn"], positions=positions, prefix_len=prefix_len,
            kv_chunk=kv_chunk,
        )
        if want_cache:
            cache = {"k": kv[0], "v": kv[1]}
        x = x + o
        if "cross" in sp:
            assert enc_h is not None
            B, Se, _ = enc_h.shape
            Hkv, hd = spec.padded_n_kv, spec.hd
            ck = (enc_h @ sp["cross"]["wk"]).reshape(B, Se, Hkv, hd)
            cv = (enc_h @ sp["cross"]["wv"]).reshape(B, Se, Hkv, hd)
            h = L.apply_norm(spec, x, sp["ln_cross"])
            x = x + L.cross_attention_block(spec, h, sp["cross"], (ck, cv))
            if want_cache:
                cache = dict(cache or {}, cross_k=ck, cross_v=cv)
    else:
        h = L.apply_norm(spec, x, sp["ln_ssm"])
        o, ssm_state = L.mamba2_block(spec, h, sp["ssm"])
        if want_cache:
            cache = {"ssm": ssm_state}
        x = x + o
    if "moe" in sp:
        h = L.apply_norm(spec, x, sp["ln_mlp"])
        o, aux = L.moe_block(spec, h, sp["moe"])
        x = x + o
    elif "mlp" in sp:
        h = L.apply_norm(spec, x, sp["ln_mlp"])
        x = x + L.mlp_block(spec, h, sp["mlp"])
    return x, aux, cache


def decoder_forward(
    spec: ModelSpec,
    params,
    x,
    *,
    positions,
    prefix_len: int = 0,
    kv_chunk: int = 1024,
    remat: bool = True,
    want_cache: bool = False,
    enc_h=None,
):
    """Run the decoder stack. x: (B, S, D) embedded inputs.

    Returns (hidden (B,S,D), aux_loss, caches) — caches stacked per slot over
    superblocks when want_cache.
    """

    def superblock(x, sb_params):
        # re-pin batch sharding every superblock: fsdp weight shardings
        # otherwise pull activations into replication (see constrain_batch)
        x = L.constrain_batch(x)
        aux_total = jnp.float32(0.0)
        caches = {}
        for s in range(spec.period):
            x, aux, cache = _apply_slot_train(
                spec, s, x, sb_params[f"slot{s}"], positions, prefix_len,
                kv_chunk, want_cache, enc_h,
            )
            aux_total = aux_total + aux
            if cache is not None:
                caches[f"slot{s}"] = cache
        return x, (aux_total, caches)

    body = jax.checkpoint(superblock) if remat else superblock

    def scan_fn(carry, sb_params):
        return body(carry, sb_params)

    x, (aux, caches) = lax.scan(scan_fn, x, params["sb"])
    x = L.apply_norm(spec, x, params["final_norm"])
    return x, aux.sum(), caches


def _apply_slot_decode(spec: ModelSpec, slot: int, x, sp, cache, pos):
    new_cache = cache
    if spec.is_attn_slot(slot):
        h = L.apply_norm(spec, x, sp["ln_attn"])
        self_cache = {"k": cache["k"], "v": cache["v"]}
        o, upd = L.attention_decode_block(spec, h, sp["attn"], self_cache, pos)
        new_cache = dict(cache, **upd)
        x = x + o
        if "cross" in sp:
            h = L.apply_norm(spec, x, sp["ln_cross"])
            x = x + L.cross_attention_block(
                spec, h, sp["cross"], (cache["cross_k"], cache["cross_v"])
            )
    else:
        h = L.apply_norm(spec, x, sp["ln_ssm"])
        o, new_cache = L.mamba2_decode_block(spec, h, sp["ssm"], cache)
        x = x + o
    if "moe" in sp:
        h = L.apply_norm(spec, x, sp["ln_mlp"])
        o, _ = L.moe_decode_block(spec, h, sp["moe"])
        x = x + o
    elif "mlp" in sp:
        h = L.apply_norm(spec, x, sp["ln_mlp"])
        x = x + L.mlp_block(spec, h, sp["mlp"])
    return x, new_cache


def decoder_decode(spec: ModelSpec, params, x, caches, pos):
    """Single-token decode. x: (B, 1, D); caches: per-slot stacked trees.

    Returns (hidden (B,1,D), new_caches).
    """

    def scan_fn(x, xs):
        sb_params, sb_caches = xs
        new_caches = {}
        for s in range(spec.period):
            key = f"slot{s}"
            x, nc = _apply_slot_decode(spec, s, x, sb_params[key], sb_caches[key], pos)
            new_caches[key] = nc
        return x, new_caches

    x, new_caches = lax.scan(scan_fn, x, (params["sb"], caches))
    x = L.apply_norm(spec, x, params["final_norm"])
    return x, new_caches


# ---------------------------------------------------------------------------
# Encoder (whisper) — bidirectional transformer over frame embeddings
# ---------------------------------------------------------------------------


def encoder_forward(spec: ModelSpec, params, frames, *, remat: bool = True):
    """frames: (B, S_f, frontend_dim) stub embeddings -> (B, S_f, D)."""
    x = frames.astype(params["frontend_proj"].dtype) @ params["frontend_proj"]
    S = x.shape[1]
    pos = jnp.arange(S)
    # fixed sinusoidal positions
    D = spec.d_model
    half = D // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos.astype(jnp.float32)[:, None] * freqs[None, :]
    pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(x.dtype)
    x = x + pe[None]

    enc = params["encoder"]

    def block(x, lp):
        h = L.apply_norm(spec, x, lp["ln_attn"])
        B, S_, _ = h.shape
        Hq, hd = spec.padded_n_q, spec.hd
        q = (h @ lp["attn"]["wq"]).reshape(B, S_, Hq, hd)
        k = (h @ lp["attn"]["wk"]).reshape(B, S_, spec.padded_n_kv, hd)
        v = (h @ lp["attn"]["wv"]).reshape(B, S_, spec.padded_n_kv, hd)
        o = L.flash_attention(q, k, v, causal=False)
        x = x + o.reshape(B, S_, Hq * hd) @ lp["attn"]["wo"]
        h = L.apply_norm(spec, x, lp["ln_mlp"])
        return x + L.mlp_block(spec, h, lp["mlp"]), None

    body = jax.checkpoint(block) if remat else block
    x, _ = lax.scan(body, x, enc)
    return L.apply_norm(spec, x, params["enc_final_norm"])
