"""Core NN layers for the assigned architectures (pure JAX, shard-friendly).

Design notes (see DESIGN.md §5/§6):

* Attention is a *chunked online-softmax* ("flash-style") implementation: a
  ``lax.scan`` over KV blocks carrying (max, sum, acc).  This bounds the live
  logits to (B, H_local, S_q, kv_chunk) instead of (…, S_kv), which is what lets
  32k-prefill fit 16 GB/chip.  Heads are sharded over the "model" mesh axis
  (padded when the published head count doesn't divide it); KV heads are
  replicated when n_kv < model-axis and grouped (GQA) otherwise.
* Sliding-window attention (SWA) is the same kernel with a lower band on the
  position mask; decode uses a rolling KV cache of window size.
* MoE uses per-sequence capacity dispatch (GShard-style) with scatter-add into
  (B, E, C, D) buffers — batch-sharded, so routing is collective-free; the
  expert FFN is "expert-TP" in the baseline (d_ff sharded over "model"), which
  makes a MoE layer communication-identical to a dense Megatron MLP.  True
  expert-parallel all-to-all dispatch is a §Perf hillclimb variant.
* Mamba2 uses the chunked SSD (state-space duality) algorithm: intra-chunk
  quadratic term + inter-chunk recurrence (scan over chunks), heads sharded
  over "model".
"""

from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.spec import ModelSpec, MoECfg, SSMCfg

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * lax.rsqrt(var + eps)
    return (y * w.astype(jnp.float32) + b.astype(jnp.float32)).astype(dt)


def apply_norm(spec: ModelSpec, x, p):
    if spec.norm == "layernorm":
        return layer_norm(x, p["w"], p["b"])
    return rms_norm(x, p["w"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_tables(positions, head_dim: int, theta: float):
    """positions: int32 (...,) -> cos/sin tables (..., head_dim/2)."""
    half = head_dim // 2
    freqs = jnp.exp(-math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, S, H, hd); cos/sin: (B, S, half) or (S, half)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == 2:
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    else:
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    return jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1
    ).astype(dt)


# ---------------------------------------------------------------------------
# Flash-style chunked attention (training / prefill)
# ---------------------------------------------------------------------------

NEG_INF = -1e30

import os

#: "vjp"  — custom-vjp flash attention: backward recomputes per-chunk
#:          probabilities (true flash backward; no O(S*S) stash).
#: "scan" — plain lax.scan online softmax: jax autodiff saves every chunk's
#:          probability matrix as a scan residual (the paper-faithful
#:          BASELINE recorded in experiments/dryrun; measured ~51 GB/layer
#:          stash on minicpm train_4k — see EXPERIMENTS.md §Perf).
FLASH_IMPL = os.environ.get("REPRO_ATTN_IMPL", "vjp")


def set_flash_impl(impl: str):
    global FLASH_IMPL
    assert impl in ("vjp", "scan")
    FLASH_IMPL = impl


def constrain_batch(x, batch_axes=("pod", "data")):
    """Pin the leading (batch) dim of an activation to the DP mesh axes.

    With "fsdp"/"fsdp_pod" policies the weights' d_model dim is sharded over
    "data" — at the contracting dim of every matmul that CONFLICTS with the
    activations' batch sharding, and XLA's resolution was to replicate the
    batch (measured 16x attention traffic on mixtral; EXPERIMENTS.md §Perf).
    ZeRO-3 semantics require gathering the WEIGHTS instead, which this
    constraint forces.  No-op unless a mesh context is active (smoke tests,
    single-device runs).
    """
    try:
        from jax._src import mesh as mesh_lib
        m = mesh_lib.thread_resources.env.physical_mesh
        if m.empty:
            return x
        axes = tuple(a for a in batch_axes if a in m.axis_names)
        if not axes:
            return x
        from jax.sharding import NamedSharding, PartitionSpec
        spec = PartitionSpec(axes, *([None] * (x.ndim - 1)))
        return lax.with_sharding_constraint(x, NamedSharding(m, spec))
    except Exception:
        return x


def _attn_mask(causal, prefix_len, window, q_pos, kv_pos):
    """Shared position mask: causal + prefix-LM bidirectional + SWA band."""
    if not causal:
        return None
    ok = kv_pos[None, :] <= q_pos[:, None]
    if prefix_len:
        bidir = (q_pos[:, None] < prefix_len) & (kv_pos[None, :] < prefix_len)
        ok = ok | bidir
    if window is not None:
        ok = ok & (kv_pos[None, :] > q_pos[:, None] - window)
    return ok


def flash_attention(
    q, k, v,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,
    kv_offset=0,
    kv_chunk: int = 1024,
    prefix_len: int = 0,
    kv_len_mask=None,
    impl: Optional[str] = None,
):
    """Chunked online-softmax attention.

    q: (B, Sq, Hq, hd);  k, v: (B, Skv, Hkv, hd) with Hq = G * Hkv.
    ``prefix_len``: positions < prefix_len attend bidirectionally (PaliGemma
    prefix-LM); only meaningful with causal=True.
    ``kv_len_mask``: optional (B, Skv) bool validity mask (ragged caches).
    ``impl``: "vjp" (flash backward, default) or "scan" (baseline; autodiff
    stashes every chunk's probabilities).  Returns (B, Sq, Hq, hd).
    """
    impl = impl or FLASH_IMPL
    if impl == "vjp" and kv_len_mask is None and isinstance(q_offset, int) \
            and isinstance(kv_offset, int):
        return _flash_vjp(q, k, v, causal, window, q_offset, kv_offset,
                          kv_chunk, prefix_len)
    return _flash_scan(
        q, k, v, causal=causal, window=window, q_offset=q_offset,
        kv_offset=kv_offset, kv_chunk=kv_chunk, prefix_len=prefix_len,
        kv_len_mask=kv_len_mask)


def _flash_scan(
    q, k, v,
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset=0,
    kv_offset=0,
    kv_chunk: int = 1024,
    prefix_len: int = 0,
    kv_len_mask=None,
):
    """Baseline scan implementation (jax autodiff through the scan)."""
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)

    # Repeat KV heads to the query head count.  This keeps every attention
    # intermediate sharded cleanly on the (padded) head axis even when the
    # published n_kv does not divide the model axis (GQA groups < axis size);
    # the repeat of a replicated KV tensor is a local slice per shard.
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)

    nchunks = max(1, (Skv + kv_chunk - 1) // kv_chunk)
    pad = nchunks * kv_chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        extra = jnp.zeros((B, pad), dtype=bool)
        kv_len_mask = (
            jnp.concatenate([jnp.ones((B, Skv), bool), extra], 1)
            if kv_len_mask is None
            else jnp.concatenate([kv_len_mask, extra], 1)
        )
    kc = k.reshape(B, nchunks, kv_chunk, Hq, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunks, kv_chunk, Hq, hd).transpose(1, 0, 2, 3, 4)
    vmask = (
        kv_len_mask.reshape(B, nchunks, kv_chunk).transpose(1, 0, 2)
        if kv_len_mask is not None
        else None
    )

    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, xs):
        m, l, acc = carry
        if vmask is None:
            kcb, vcb, cidx = xs
            msk_b = None
        else:
            kcb, vcb, msk_b, cidx = xs
        kv_pos = kv_offset + cidx * kv_chunk + jnp.arange(kv_chunk)
        # logits: (B, Hq, Sq, C)
        s = jnp.einsum("bqhd,bchd->bhqc", q, kcb,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            ok = kv_pos[None, :] <= q_pos[:, None]
            if prefix_len:
                bidir = (q_pos[:, None] < prefix_len) & (kv_pos[None, :] < prefix_len)
                ok = ok | bidir
            if window is not None:
                ok = ok & (kv_pos[None, :] > q_pos[:, None] - window)
            s = jnp.where(ok[None, None], s, NEG_INF)
        if msk_b is not None:
            s = jnp.where(msk_b[:, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhqc,bchd->bhqd", p.astype(vcb.dtype), vcb,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    # carry inits derived from q so the head sharding (model axis) PROPAGATES
    # into the scan carry — literal zeros made XLA replicate the carry and
    # compute every head on every device (measured 16x traffic on mixtral;
    # EXPERIMENTS.md §Perf)
    qz = lax.stop_gradient(q[..., 0].transpose(0, 2, 1)).astype(jnp.float32) * 0.0
    m0 = qz + NEG_INF
    l0 = qz
    a0 = lax.stop_gradient(q.transpose(0, 2, 1, 3)).astype(jnp.float32) * 0.0
    cidx = jnp.arange(nchunks)
    xs = (kc, vc, cidx) if vmask is None else (kc, vc, vmask, cidx)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), xs)
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 2, 1, 3).reshape(B, Sq, Hq, hd)
    return out.astype(v.dtype)


# ---------------------------------------------------------------------------
# Flash attention with custom VJP (true flash backward — §Perf hillclimb)
#
# jax autodiff through the _flash_scan online-softmax saves every kv-chunk's
# probability matrix as a scan residual: an O(B*H*Sq*Skv) bf16 stash (measured
# 51 GB/device/layer on minicpm train_4k @ 8 fake devices).  The flash
# backward stores only (out, m, l) and RECOMPUTES p chunk-by-chunk:
#   delta = rowsum(g * out)
#   p     = exp(s - lse)
#   ds    = p * (dp - delta) * scale,  dp = g @ v^T
#   dq   += ds @ k;   dk_c = ds^T @ q;   dv_c = p^T @ g
# ---------------------------------------------------------------------------


def _flash_chunks(k, v, Skv, B, kv_chunk):
    nchunks = max(1, (Skv + kv_chunk - 1) // kv_chunk)
    pad = nchunks * kv_chunk - Skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Hq, hd = k.shape[2], k.shape[3]
    kc = k.reshape(B, nchunks, kv_chunk, Hq, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(B, nchunks, kv_chunk, Hq, hd).transpose(1, 0, 2, 3, 4)
    return kc, vc, nchunks, pad


def _flash_fwd_impl(q, k, v, causal, window, q_offset, kv_offset, kv_chunk,
                    prefix_len):
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    kc, vc, nchunks, _ = _flash_chunks(k, v, Skv, B, kv_chunk)
    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, xs):
        m, l, acc = carry
        kcb, vcb, cidx = xs
        kv_idx = cidx * kv_chunk + jnp.arange(kv_chunk)
        kv_pos = kv_offset + kv_idx
        s = jnp.einsum("bqhd,bchd->bhqc", q, kcb,
                       preferred_element_type=jnp.float32) * scale
        ok = _attn_mask(causal, prefix_len, window, q_pos, kv_pos)
        valid = kv_idx < Skv                       # padding chunk tail
        ok = valid[None, :] if ok is None else ok & valid[None, :]
        s = jnp.where(ok[None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhqc,bchd->bhqd", p.astype(vcb.dtype), vcb,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    # carry inits derived from q so the head sharding (model axis) PROPAGATES
    # into the scan carry — literal zeros made XLA replicate the carry and
    # compute every head on every device (measured 16x traffic on mixtral;
    # EXPERIMENTS.md §Perf)
    qz = lax.stop_gradient(q[..., 0].transpose(0, 2, 1)).astype(jnp.float32) * 0.0
    m0 = qz + NEG_INF
    l0 = qz
    a0 = lax.stop_gradient(q.transpose(0, 2, 1, 3)).astype(jnp.float32) * 0.0
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0),
                              (kc, vc, jnp.arange(nchunks)))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = out.transpose(0, 2, 1, 3).reshape(B, Sq, Hq, hd).astype(v.dtype)
    return out, m, l


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash_vjp(q, k, v, causal, window, q_offset, kv_offset, kv_chunk,
               prefix_len):
    out, _, _ = _flash_fwd_impl(q, k, v, causal, window, q_offset, kv_offset,
                                kv_chunk, prefix_len)
    return out


def _flash_vjp_fwd(q, k, v, causal, window, q_offset, kv_offset, kv_chunk,
                   prefix_len):
    out, m, l = _flash_fwd_impl(q, k, v, causal, window, q_offset, kv_offset,
                                kv_chunk, prefix_len)
    return out, (q, k, v, out, m, l)


def _flash_vjp_bwd(causal, window, q_offset, kv_offset, kv_chunk, prefix_len,
                   res, g):
    q, k, v, out, m, l = res
    B, Sq, Hq, hd = q.shape
    _, Skv, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    kr = jnp.repeat(k, G, axis=2) if G > 1 else k
    vr = jnp.repeat(v, G, axis=2) if G > 1 else v
    kc, vc, nchunks, pad = _flash_chunks(kr, vr, Skv, B, kv_chunk)

    lse = jnp.where(l > 0, m + jnp.log(jnp.maximum(l, 1e-30)), 0.0)
    gf = g.astype(jnp.float32).transpose(0, 2, 1, 3)      # (B,Hq,Sq,hd)
    of = out.astype(jnp.float32).transpose(0, 2, 1, 3)
    delta = jnp.sum(gf * of, axis=-1)                     # (B,Hq,Sq)
    q_pos = q_offset + jnp.arange(Sq)

    def body(dq, xs):
        kcb, vcb, cidx = xs
        kv_idx = cidx * kv_chunk + jnp.arange(kv_chunk)
        kv_pos = kv_offset + kv_idx
        s = jnp.einsum("bqhd,bchd->bhqc", q, kcb,
                       preferred_element_type=jnp.float32) * scale
        ok = _attn_mask(causal, prefix_len, window, q_pos, kv_pos)
        valid = kv_idx < Skv
        ok = valid[None, :] if ok is None else ok & valid[None, :]
        p = jnp.where(ok[None, None], jnp.exp(s - lse[..., None]), 0.0)
        dp = jnp.einsum("bhqd,bchd->bhqc", gf, vcb.astype(jnp.float32))
        ds = p * (dp - delta[..., None]) * scale          # (B,Hq,Sq,C)
        dq = dq + jnp.einsum("bhqc,bchd->bhqd", ds,
                             kcb.astype(jnp.float32))
        dk_c = jnp.einsum("bhqc,bqhd->bchd", ds, q.astype(jnp.float32))
        dv_c = jnp.einsum("bhqc,bhqd->bchd", p, gf)
        return dq, (dk_c, dv_c)

    dq0 = q.astype(jnp.float32).transpose(0, 2, 1, 3) * 0.0  # keep sharding
    dq, (dk_s, dv_s) = lax.scan(body, dq0, (kc, vc, jnp.arange(nchunks)))
    dq = dq.transpose(0, 2, 1, 3).astype(q.dtype)
    dk = dk_s.transpose(1, 0, 2, 3, 4).reshape(B, -1, Hq, hd)[:, :Skv]
    dv = dv_s.transpose(1, 0, 2, 3, 4).reshape(B, -1, Hq, hd)[:, :Skv]
    if G > 1:
        dk = dk.reshape(B, Skv, Hkv, G, hd).sum(3)
        dv = dv.reshape(B, Skv, Hkv, G, hd).sum(3)
    return dq, dk.astype(k.dtype), dv.astype(v.dtype)


_flash_vjp.defvjp(_flash_vjp_fwd, _flash_vjp_bwd)


def decode_attention(q, k_cache, v_cache, cache_pos, *, window: Optional[int] = None):
    """Single-token decode attention against a (possibly sequence-sharded) cache.

    q: (B, 1, Hq, hd); caches: (B, L_cache, Hkv, hd); cache_pos: scalar int —
    number of valid entries (for rolling SWA caches the whole buffer is valid
    once full; validity is handled by the caller-provided mask semantics here:
    entries with index >= cache_pos are masked).

    Softmax reductions over the cache-length axis are plain jnp reductions —
    when the cache is sharded over "data" (long_500k), XLA inserts the
    max/sum all-reduces (log-sum-exp combine), i.e. distributed flash-decoding.
    """
    B, _, Hq, hd = q.shape
    _, Lc, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(hd)
    qg = q.reshape(B, Hq, hd)
    # logits grouped by kv head: (B, Hkv, G, Lc) -> keep kv heads unexpanded so
    # the (possibly seq-sharded) cache is contracted without materialising a
    # repeated copy; softmax reductions over Lc become lse all-reduces when the
    # cache is sequence-sharded.
    qg = qg.reshape(B, Hkv, G, hd)
    s = jnp.einsum("bhgd,bchd->bhgc", qg, k_cache,
                   preferred_element_type=jnp.float32) * scale
    idx = jnp.arange(Lc)
    valid = idx[None, :] < cache_pos
    s = jnp.where(valid[:, None, None, :] if valid.ndim == 2 else valid[None, None, None, :],
                  s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgc,bchd->bhgd", p.astype(v_cache.dtype), v_cache,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, 1, Hq, hd).astype(v_cache.dtype)


# ---------------------------------------------------------------------------
# Attention block (projections + rope + flash / decode)
# ---------------------------------------------------------------------------


def attn_project_qkv(spec: ModelSpec, x, p, positions):
    B, S, D = x.shape
    Hq, Hkv, hd = spec.padded_n_q, spec.padded_n_kv, spec.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if spec.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    q = q.reshape(B, S, Hq, hd)
    k = k.reshape(B, S, Hkv, hd)
    v = v.reshape(B, S, Hkv, hd)
    if spec.rope_theta > 0:
        cos, sin = rope_tables(positions, hd, spec.rope_theta)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def attention_block(spec: ModelSpec, x, p, *, positions, prefix_len: int = 0,
                    kv_chunk: int = 1024):
    """Full training/prefill attention. x: (B,S,D) -> (B,S,D), plus (k,v) for caching."""
    q, k, v = attn_project_qkv(spec, x, p, positions)
    o = flash_attention(
        q, k, v,
        causal=True,
        window=spec.swa_window,
        prefix_len=prefix_len,
        kv_chunk=kv_chunk,
    )
    B, S, _, _ = q.shape
    o = o.reshape(B, S, spec.padded_n_q * spec.hd)
    return o @ p["wo"], (k, v)


def attention_decode_block(spec: ModelSpec, x, p, cache, pos):
    """x: (B,1,D); cache: dict(k,v) (B, Lc, Hkv, hd); pos: scalar current length.

    Returns (out (B,1,D), new_cache).  SWA uses a rolling buffer (Lc = window).
    """
    B = x.shape[0]
    q, k, v = attn_project_qkv(spec, x, p, positions=jnp.full((1,), pos))
    Lc = cache["k"].shape[1]
    if spec.swa_window is not None and Lc == spec.swa_window:
        slot = pos % Lc
        new_k = lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        new_v = lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        n_valid = jnp.minimum(pos + 1, Lc)
    else:
        new_k = lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
        new_v = lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
        n_valid = pos + 1
    o = decode_attention(q, new_k, new_v, n_valid, window=spec.swa_window)
    o = o.reshape(B, 1, spec.padded_n_q * spec.hd)
    return o @ p["wo"], {"k": new_k, "v": new_v}


def cross_attention_block(spec: ModelSpec, x, p, enc_kv):
    """Enc-dec cross attention (whisper). enc_kv: (k, v) from encoder output."""
    B, S, D = x.shape
    Hq, hd = spec.padded_n_q, spec.hd
    q = (x @ p["wq"]).reshape(B, S, Hq, hd)
    k, v = enc_kv
    o = flash_attention(q, k, v, causal=False)
    return o.reshape(B, S, Hq * hd) @ p["wo"]


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------


def mlp_block(spec: ModelSpec, x, p):
    if spec.act == "silu":
        h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    elif spec.act == "geglu":
        h = jax.nn.gelu(x @ p["w1"]) * (x @ p["w3"])
    else:
        h = jax.nn.gelu(x @ p["w1"])
    return h @ p["w2"]


def moe_block(spec: ModelSpec, x, p):
    """GShard-style per-sequence capacity routing; expert-TP compute.

    x: (B, S, D).  Router in fp32.  Returns (B, S, D) plus aux load-balance loss.
    """
    cfg: MoECfg = spec.moe
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    C = max(K, int(S * K * cfg.capacity_factor / E))

    logits = (x.astype(jnp.float32) @ p["router"].astype(jnp.float32))  # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, K)                                  # (B,S,K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch): E * sum_e f_e * p_e
    me = probs.mean(axis=(0, 1))
    one = jax.nn.one_hot(top_e[..., 0], E, dtype=jnp.float32)
    fe = one.mean(axis=(0, 1))
    aux = E * jnp.sum(me * fe)

    flat_e = top_e.reshape(B, S * K)                                    # (B, N)
    # position of each routed token within its expert (per sequence)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)                 # (B, N, E)
    pos = jnp.cumsum(onehot, axis=1) - 1                                # (B, N, E)
    pos_in_e = jnp.take_along_axis(pos, flat_e[..., None], axis=-1)[..., 0]  # (B,N)
    keep = pos_in_e < C

    xr = jnp.repeat(x, K, axis=1)                                       # (B, N, D)
    safe_pos = jnp.where(keep, pos_in_e, C - 1)
    w = jnp.where(keep, 1.0, 0.0).astype(x.dtype)

    def disp(xb, eb, pb, wb):
        buf = jnp.zeros((E, C, D), x.dtype)
        return buf.at[eb, pb].add(xb * wb[:, None])

    # the batched scatter-add dispatch defeats sharding propagation (XLA
    # replicated the batch dim and all-reduced every (B,E,C,*) buffer —
    # EXPERIMENTS.md §Perf); pin batch on every MoE intermediate
    buf = constrain_batch(jax.vmap(disp)(xr, flat_e, safe_pos, w))      # (B,E,C,D)

    h1 = jnp.einsum("becd,edf->becf", buf, p["w1"])
    if spec.act == "silu":
        h = jax.nn.silu(h1) * jnp.einsum("becd,edf->becf", buf, p["w3"])
    else:
        h = jax.nn.gelu(h1)
    h = constrain_batch(h)
    yb = constrain_batch(
        jnp.einsum("becf,efd->becd", h, p["w2"]))                       # (B,E,C,D)

    def gath(yb_, eb, pb):
        return yb_[eb, pb]

    y = constrain_batch(jax.vmap(gath)(yb, flat_e, safe_pos))           # (B,N,D)
    y = y * (w * top_p.reshape(B, S * K).astype(x.dtype))[..., None]
    y = y.reshape(B, S, K, D).sum(axis=2)
    return y, aux


def moe_decode_block(spec: ModelSpec, x, p):
    """Decode-time MoE (S small): dense top-k combine without capacity buffers."""
    cfg: MoECfg = spec.moe
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    logits = x.astype(jnp.float32) @ p["router"].astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, K)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    w1 = p["w1"][top_e]  # (B,S,K,D,F)
    w3 = p["w3"][top_e] if spec.act == "silu" else None
    w2 = p["w2"][top_e]
    h1 = jnp.einsum("bsd,bskdf->bskf", x, w1)
    if spec.act == "silu":
        h = jax.nn.silu(h1) * jnp.einsum("bsd,bskdf->bskf", x, w3)
    else:
        h = jax.nn.gelu(h1)
    y = jnp.einsum("bskf,bskfd->bskd", h, w2)
    return (y * top_p.astype(x.dtype)[..., None]).sum(axis=2), jnp.float32(0.0)


# ---------------------------------------------------------------------------
# Mamba2 (SSD — state-space duality, chunked)
# ---------------------------------------------------------------------------


def mamba2_block(spec: ModelSpec, x, p):
    """Chunked SSD forward. x: (B, S, D) -> (B, S, D), final_state.

    Params: in_proj (D, 2*di + 2*ds + nh), conv (4, di + 2*ds), A_log (nh,),
    dt_bias (nh,), D_skip (nh,), norm_w (di,), out_proj (di, D).
    """
    cfg: SSMCfg = spec.ssm
    B, S, D = x.shape
    di = cfg.d_inner(D)
    nh = cfg.n_heads(D)
    ds = cfg.d_state
    ph = cfg.head_dim
    cl = min(cfg.chunk, S)
    assert S % cl == 0
    nc = S // cl

    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [di, di + di + 2 * ds], axis=-1)

    # causal depthwise conv over (x, B, C), kernel 4
    kw = p["conv"].shape[0]
    xbc_pad = jnp.pad(xbc, ((0, 0), (kw - 1, 0), (0, 0)))
    conv = sum(
        xbc_pad[:, i : i + S, :] * p["conv"][i][None, None, :] for i in range(kw)
    )
    xbc = jax.nn.silu(conv + p["conv_b"][None, None, :])
    xs, Bc, Cc = jnp.split(xbc, [di, di + ds], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])          # (B,S,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                         # (nh,)
    dA = dt * A[None, None, :]                                           # (B,S,nh) <= 0

    xh = xs.reshape(B, nc, cl, nh, ph)
    Bh = Bc.reshape(B, nc, cl, ds)
    Ch = Cc.reshape(B, nc, cl, ds)
    dAh = dA.reshape(B, nc, cl, nh)
    dth = dt.reshape(B, nc, cl, nh)

    seg = jnp.cumsum(dAh, axis=2)                                        # (B,nc,cl,nh)
    # intra-chunk (quadratic within chunk, causal decay):
    # L[i,j] = exp(seg_i - seg_j) for i >= j
    rel = seg[:, :, :, None, :] - seg[:, :, None, :, :]                  # (B,nc,i,j,nh)
    causal = jnp.tril(jnp.ones((cl, cl), bool))
    # mask BEFORE exp: upper-triangle rel is positive and can overflow exp
    rel = jnp.where(causal[None, None, :, :, None], rel, NEG_INF)
    decay = jnp.exp(rel)
    sBC = jnp.einsum("bnis,bnjs->bnij", Ch, Bh,
                     preferred_element_type=jnp.float32)                 # (B,nc,i,j)
    gate = sBC[..., None] * decay * dth[:, :, None, :, :]                # (B,nc,i,j,nh)
    y_intra = jnp.einsum("bnijh,bnjhp->bnihp", gate.astype(xh.dtype), xh,
                         preferred_element_type=jnp.float32)

    # chunk end-states: h_c = sum_j exp(seg_end - seg_j) * dt_j * B_j x_j^T
    end = seg[:, :, -1:, :]                                              # (B,nc,1,nh)
    w_end = jnp.exp(end - seg) * dth                                     # (B,nc,cl,nh)
    hc = jnp.einsum("bnjs,bnjh,bnjhp->bnhps", Bh, w_end.astype(xh.dtype), xh,
                    preferred_element_type=jnp.float32)                  # (B,nc,nh,ph,ds)

    # inter-chunk recurrence over chunks
    chunk_decay = jnp.exp(end[:, :, 0, :])                               # (B,nc,nh)

    def scan_fn(h_prev, inp):
        hc_n, dec_n = inp
        h_new = h_prev * dec_n[:, :, None, None] + hc_n
        return h_new, h_prev

    h0 = jnp.zeros((B, nh, ph, ds), jnp.float32)
    hT, h_prevs = lax.scan(
        scan_fn,
        h0,
        (hc.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)),
    )
    h_prevs = h_prevs.transpose(1, 0, 2, 3, 4)                           # (B,nc,nh,ph,ds)

    # inter-chunk output: y_j += C_j · (decay-from-chunk-start_j * h_prev)
    w_start = jnp.exp(seg)                                               # (B,nc,cl,nh)
    y_inter = jnp.einsum("bnis,bnhps,bnih->bnihp", Ch, h_prevs.astype(Ch.dtype),
                         w_start.astype(Ch.dtype),
                         preferred_element_type=jnp.float32)

    y = (y_intra + y_inter).astype(x.dtype) + xh * p["D_skip"].astype(x.dtype)[None, None, None, :, None]
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    return y @ p["out_proj"], hT


def mamba2_decode_block(spec: ModelSpec, x, p, state):
    """Single-token SSD decode. state: dict(ssm (B,nh,ph,ds), conv (B,kw-1,di+2ds))."""
    cfg: SSMCfg = spec.ssm
    B, S, D = x.shape  # S == 1
    di = cfg.d_inner(D)
    nh = cfg.n_heads(D)
    ds = cfg.d_state
    ph = cfg.head_dim

    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [di, di + di + 2 * ds], axis=-1)
    hist = jnp.concatenate([state["conv"], xbc], axis=1)                 # (B,kw,·)
    conv = jnp.einsum("bkc,kc->bc", hist, p["conv"])[:, None, :]
    xbc_t = jax.nn.silu(conv + p["conv_b"][None, None, :])
    new_conv = hist[:, 1:, :]
    xs, Bc, Cc = jnp.split(xbc_t, [di, di + ds], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]    # (B,nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A[None, :])                                        # (B,nh)

    xh = xs.reshape(B, nh, ph)
    Bv = Bc[:, 0, :]                                                     # (B,ds)
    Cv = Cc[:, 0, :]
    upd = dt[:, :, None, None] * jnp.einsum("bhp,bs->bhps", xh.astype(jnp.float32),
                                            Bv.astype(jnp.float32))
    ssm = state["ssm"] * dA[:, :, None, None] + upd
    y = jnp.einsum("bhps,bs->bhp", ssm, Cv.astype(jnp.float32)).astype(x.dtype)
    y = y + xh * p["D_skip"].astype(x.dtype)[None, :, None]
    y = y.reshape(B, 1, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm_w"])
    return y @ p["out_proj"], {"ssm": ssm, "conv": new_conv}
