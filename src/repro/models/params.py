"""Parameter trees: shapes, logical sharding axes, initialisation.

Every leaf is described once by a ``PDef(shape, logical, scale)``; from that we
derive (a) ``ShapeDtypeStruct`` trees for the dry-run, (b) ``NamedSharding``
trees for pjit, and (c) real initialised params for tests/examples.

Layout: ``params["sb"]["slot{i}"][name]`` — arrays stacked over superblocks
(leading "layers" dim, scanned), plus top-level ``embed`` / ``head`` / ``final_norm``
/ encoder stack / frontend projector.
"""

from __future__ import annotations


import jax
import jax.numpy as jnp
import numpy as np

from repro.models.spec import ModelSpec, logical_to_pspec

PARAM_DTYPE = jnp.bfloat16


class PDef:
    __slots__ = ("shape", "logical", "scale")

    def __init__(self, shape, logical, scale=0.02):
        assert len(shape) == len(logical)
        self.shape = tuple(int(s) for s in shape)
        self.logical = tuple(logical)
        self.scale = scale


def _norm_defs(spec: ModelSpec, prefix_dims=(), prefix_log=()):
    d = {"w": PDef(prefix_dims + (spec.d_model,), prefix_log + ("embed_act",), 0.0)}
    if spec.norm == "layernorm":
        d["b"] = PDef(prefix_dims + (spec.d_model,), prefix_log + ("embed_act",), 0.0)
    return d


def _attn_defs(spec: ModelSpec, L, cross=False):
    D, hd = spec.d_model, spec.hd
    Hq, Hkv = spec.padded_n_q, spec.padded_n_kv
    res_scale = 0.02 / np.sqrt(2 * spec.n_layers)
    d = {
        "wq": PDef((L, D, Hq * hd), ("layers", "embed", "q_heads")),
        "wk": PDef((L, D, Hkv * hd), ("layers", "embed", "kv_heads")),
        "wv": PDef((L, D, Hkv * hd), ("layers", "embed", "kv_heads")),
        "wo": PDef((L, Hq * hd, D), ("layers", "q_heads", "embed"), res_scale),
    }
    if spec.qkv_bias and not cross:
        d["bq"] = PDef((L, Hq * hd), ("layers", "q_heads"), 0.0)
        d["bk"] = PDef((L, Hkv * hd), ("layers", "kv_heads"), 0.0)
        d["bv"] = PDef((L, Hkv * hd), ("layers", "kv_heads"), 0.0)
    return d


def _mlp_defs(spec: ModelSpec, L):
    D, F = spec.d_model, spec.d_ff
    res_scale = 0.02 / np.sqrt(2 * spec.n_layers)
    d = {
        "w1": PDef((L, D, F), ("layers", "embed", "ff")),
        "w2": PDef((L, F, D), ("layers", "ff", "embed"), res_scale),
    }
    if spec.act in ("silu", "geglu"):
        d["w3"] = PDef((L, D, F), ("layers", "embed", "ff"))
    return d


def _moe_defs(spec: ModelSpec, L):
    D, F, E = spec.d_model, spec.d_ff, spec.moe.n_experts
    res_scale = 0.02 / np.sqrt(2 * spec.n_layers)
    d = {
        "router": PDef((L, D, E), ("layers", "embed", None)),
        "w1": PDef((L, E, D, F), ("layers", "experts", "embed", "ff")),
        "w2": PDef((L, E, F, D), ("layers", "experts", "ff", "embed"), res_scale),
    }
    if spec.act in ("silu", "geglu"):
        d["w3"] = PDef((L, E, D, F), ("layers", "experts", "embed", "ff"))
    return d


def _mamba_defs(spec: ModelSpec, L):
    D = spec.d_model
    cfg = spec.ssm
    di = cfg.d_inner(D)
    nh = cfg.n_heads(D)
    ds = cfg.d_state
    conv_dim = di + 2 * ds
    res_scale = 0.02 / np.sqrt(2 * spec.n_layers)
    return {
        "in_proj": PDef((L, D, 2 * di + 2 * ds + nh), ("layers", "embed", "ssm_heads")),
        "conv": PDef((L, 4, conv_dim), ("layers", "conv", "ssm_heads"), 0.1),
        "conv_b": PDef((L, conv_dim), ("layers", "ssm_heads"), 0.0),
        "A_log": PDef((L, nh), ("layers", "ssm_heads"), -1.0),   # init exp(A_log)~e^-1
        "dt_bias": PDef((L, nh), ("layers", "ssm_heads"), 0.0),
        "D_skip": PDef((L, nh), ("layers", "ssm_heads"), 0.0),
        "norm_w": PDef((L, di), ("layers", "ssm_heads"), 0.0),
        "out_proj": PDef((L, di, D), ("layers", "ssm_heads", "embed"), res_scale),
    }


def _slot_defs(spec: ModelSpec, slot: int, L: int):
    d = {}
    is_attn = spec.is_attn_slot(slot)
    if is_attn:
        d["ln_attn"] = _norm_defs(spec, (L,), ("layers",))
        d["attn"] = _attn_defs(spec, L)
    else:
        d["ln_ssm"] = _norm_defs(spec, (L,), ("layers",))
        d["ssm"] = _mamba_defs(spec, L)
    if spec.family == "encdec":
        d["ln_cross"] = _norm_defs(spec, (L,), ("layers",))
        d["cross"] = _attn_defs(spec, L, cross=True)
    if spec.family == "ssm":
        return d  # mamba2 blocks have no separate FFN
    # layer index of this slot in superblock sb is sb*period + slot; moe-ness
    # depends only on slot when period % moe.every == 0 (asserted in configs).
    if spec.moe is not None and spec.is_moe_slot(slot, slot):
        d["ln_mlp"] = _norm_defs(spec, (L,), ("layers",))
        d["moe"] = _moe_defs(spec, L)
    elif spec.d_ff:
        d["ln_mlp"] = _norm_defs(spec, (L,), ("layers",))
        d["mlp"] = _mlp_defs(spec, L)
    return d


def param_defs(spec: ModelSpec):
    """Full PDef tree for a spec."""
    D, Vp = spec.d_model, spec.padded_vocab
    sb = {}
    for s in range(spec.period):
        sb[f"slot{s}"] = _slot_defs(spec, s, spec.n_superblocks)
    tree = {
        "embed": PDef((Vp, D), ("vocab", "embed_act")),
        "final_norm": _norm_defs(spec),
        "sb": sb,
    }
    if not spec.tie_embeddings:
        tree["head"] = PDef((D, Vp), ("embed_act", "vocab"))
    if spec.family == "encdec":
        enc = {
            "ln_attn": _norm_defs(spec, (spec.enc_layers,), ("layers",)),
            "attn": _attn_defs(spec, spec.enc_layers),
            "ln_mlp": _norm_defs(spec, (spec.enc_layers,), ("layers",)),
            "mlp": _mlp_defs(spec, spec.enc_layers),
        }
        tree["encoder"] = enc
        tree["enc_final_norm"] = _norm_defs(spec)
    if spec.frontend != "none":
        fd = spec.frontend_dim or D
        tree["frontend_proj"] = PDef((fd, D), (None, "embed_act"))
    return tree


# ---------------------------------------------------------------------------


def _map_defs(tree, fn):
    if isinstance(tree, PDef):
        return fn(tree)
    return {k: _map_defs(v, fn) for k, v in tree.items()}


def param_specs(spec: ModelSpec, dtype=PARAM_DTYPE):
    """ShapeDtypeStruct tree (dry-run stand-ins, no allocation)."""
    return _map_defs(param_defs(spec), lambda d: jax.ShapeDtypeStruct(d.shape, dtype))


def param_pspecs(spec: ModelSpec, mesh):
    """PartitionSpec tree for the current mesh."""
    names = tuple(mesh.axis_names)
    return _map_defs(
        param_defs(spec),
        lambda d: logical_to_pspec(
            d.logical, spec.sharding_policy, names, spec.kv_shardable
        ),
    )


def param_shardings(spec: ModelSpec, mesh):
    from jax.sharding import NamedSharding

    return jax.tree.map(
        lambda ps: NamedSharding(mesh, ps), param_pspecs(spec, mesh),
        is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec),
    )


def init_params(spec: ModelSpec, rng, dtype=PARAM_DTYPE):
    """Real initialisation (tests / examples; small configs only)."""
    defs = param_defs(spec)
    leaves = []

    def collect(tree, path):
        if isinstance(tree, PDef):
            leaves.append((path, tree))
        else:
            for k, v in tree.items():
                collect(v, path + (k,))

    collect(defs, ())
    keys = jax.random.split(rng, len(leaves))
    out = {}
    for (path, d), key in zip(leaves, keys):
        if d.scale == 0.0:
            arr = jnp.zeros(d.shape, dtype)
        elif d.scale == -1.0:  # A_log special init: log(uniform[1,16])
            arr = jnp.log(
                jax.random.uniform(key, d.shape, jnp.float32, 1.0, 16.0)
            ).astype(dtype)
        else:
            arr = (jax.random.normal(key, d.shape, jnp.float32) * d.scale).astype(dtype)
        node = out
        for k in path[:-1]:
            node = node.setdefault(k, {})
        node[path[-1]] = arr
    # zero out padded vocab rows & padded head columns so padding is exact
    vp, v = spec.padded_vocab, spec.vocab
    if vp > v:
        out["embed"] = out["embed"].at[v:].set(0)
        if "head" in out:
            out["head"] = out["head"].at[:, v:].set(0)
    return out
