"""Model specification & logical sharding rules for the assigned architectures.

Every architecture (dense / MoE / SSM / hybrid / enc-dec / VLM) is described by a
single :class:`ModelSpec`.  The decoder is built as a scan over "superblocks": a
superblock is ``period`` consecutive layers with statically-known types, so
heterogeneous stacks (e.g. Jamba's 1:7 attention:mamba interleave with MoE every
other layer) compile to a single small HLO body scanned ``n_layers/period`` times.

Sharding is expressed with *logical axes*; :func:`logical_to_mesh` maps them onto
the physical mesh axes ("pod", "data", "model") according to the spec's
``sharding_policy``:

  tp        params sharded over "model" only (heads / ff / vocab / experts);
            replicated over pod+data.  For models whose (params + Adam state)
            fit 16 GB/chip when divided by 16.
  fsdp      tp + the d_model dim of every weight matrix sharded over "data".
  fsdp_pod  tp + d_model sharded over ("pod","data")  (400B-class models).

Head counts / vocab are padded to the next multiple that the model axis divides;
pad rows/cols are zero-initialised and masked out of the loss, so the math is
exact (standard Megatron/MaxText practice).  The *published* numbers are kept in
the spec; ``padded_*`` properties expose the shardable values.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

MODEL_AXIS_SIZE = 16  # production mesh model-axis size; padding targets this


def pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


@dataclasses.dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    every: int = 1          # a MoE layer every `every` layers (others dense MLP)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMCfg:
    d_state: int = 128
    head_dim: int = 64
    expand: int = 2
    chunk: int = 256        # SSD chunk length (state-space duality blocking)

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def n_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclasses.dataclass(frozen=True)
class ModelSpec:
    name: str
    family: str                      # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_q: int                         # query heads (0 for attn-free)
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0                # 0 -> d_model // n_q
    qkv_bias: bool = False
    swa_window: Optional[int] = None  # sliding-window attention width
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    act: str = "silu"                # silu (swiglu) | gelu (plain mlp)
    tie_embeddings: bool = True
    moe: Optional[MoECfg] = None
    ssm: Optional[SSMCfg] = None
    # hybrid: within each `period`, which slots are attention (others are mamba)
    period: int = 1
    attn_slots: Tuple[int, ...] = (0,)   # slots in [0, period) that use attention
    # enc-dec (whisper): encoder layer count; decoder = n_layers
    enc_layers: int = 0
    # frontend stub: none | audio | vision
    frontend: str = "none"
    n_prefix_tokens: int = 0         # VLM prefix (bidirectional attention region)
    frontend_dim: int = 0            # raw embedding dim provided by the stub
    sharding_policy: str = "tp"      # tp | fsdp | fsdp_pod
    # which sequence-length shapes are runnable (see DESIGN.md §Arch-applicability)
    skip_shapes: Tuple[str, ...] = ()
    lr_schedule: str = "cosine"      # cosine | wsd
    source: str = ""

    # ---- derived (padded for model-axis sharding) -------------------------

    @property
    def hd(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.n_q if self.n_q else 0

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab, 128 * MODEL_AXIS_SIZE)

    @property
    def padded_n_q(self) -> int:
        return pad_to(self.n_q, MODEL_AXIS_SIZE) if self.n_q else 0

    @property
    def padded_n_kv(self) -> int:
        if not self.n_kv:
            return 0
        if self.n_kv == self.n_q:        # MHA: pad together
            return self.padded_n_q
        # GQA: smallest kv-head count >= published that divides the padded
        # q-head count (llama4: 40q/8kv pads to 48q -> group 6 instead of 5;
        # padded q heads are zero-init and dead, so the math of the published
        # heads is exact — only the head->group mapping shifts, documented).
        nq = self.padded_n_q
        for nkv in range(self.n_kv, nq + 1):
            if nq % nkv == 0:
                return nkv
        return nq

    @property
    def q_group(self) -> int:
        return self.padded_n_q // self.padded_n_kv if self.n_kv else 0

    @property
    def kv_shardable(self) -> bool:
        return bool(self.n_kv) and self.padded_n_kv % MODEL_AXIS_SIZE == 0

    @property
    def attn_every_layer(self) -> bool:
        return self.family in ("dense", "moe", "encdec", "vlm")

    def is_attn_slot(self, slot: int) -> bool:
        if self.family in ("dense", "moe", "encdec", "vlm"):
            return True
        if self.family == "ssm":
            return False
        return slot in self.attn_slots

    def is_moe_slot(self, slot: int, layer_idx: int) -> bool:
        if self.moe is None:
            return False
        return (layer_idx % self.moe.every) == (self.moe.every - 1)

    @property
    def n_superblocks(self) -> int:
        assert self.n_layers % self.period == 0, (self.name, self.n_layers, self.period)
        return self.n_layers // self.period

    # ---- parameter counting (for roofline MODEL_FLOPS) --------------------

    def param_count(self, active_only: bool = False) -> int:
        """Published-config parameter count (unpadded), optionally MoE-active."""
        D, F, V = self.d_model, self.d_ff, self.vocab
        hd = self.hd
        total = V * D  # embedding
        if not self.tie_embeddings:
            total += V * D
        enc = self.enc_layers
        for li in range(self.n_layers + enc):
            slot = li % self.period if li < self.n_layers else 0
            is_attn = self.is_attn_slot(slot) if li < self.n_layers else True
            if self.family == "ssm":
                is_attn = False
            if is_attn and self.n_q:
                total += D * self.n_q * hd + 2 * D * self.n_kv * hd + self.n_q * hd * D
                if li >= self.n_layers:  # encoder layer; decoder cross-attn added below
                    pass
            if self.family == "encdec" and li < self.n_layers:
                # decoder cross-attention
                total += D * self.n_q * hd + 2 * D * self.n_kv * hd + self.n_q * hd * D
            if not is_attn and self.ssm is not None:
                di = self.ssm.d_inner(D)
                nh = self.ssm.n_heads(D)
                # in_proj (x, z, B, C, dt) + out_proj + conv
                total += D * (2 * di + 2 * self.ssm.d_state + nh) + di * D + 4 * di
            # FFN / MoE
            if li < self.n_layers and self.moe is not None and self.is_moe_slot(slot, li):
                n_ff_mats = 3 if self.act == "silu" else 2
                e = self.moe.top_k if active_only else self.moe.n_experts
                total += e * n_ff_mats * D * F + D * self.moe.n_experts  # + router
            elif F:
                n_ff_mats = 3 if self.act == "silu" else 2
                total += n_ff_mats * D * F
        return total


# ---------------------------------------------------------------------------
# Logical -> mesh axis mapping
# ---------------------------------------------------------------------------

#: logical axis names used in params trees (see models/params.py)
LOGICAL_AXES = (
    "layers",      # stacked superblock dim - never sharded
    "embed",       # d_model dim of weight matrices
    "embed_act",   # d_model dim of embedding table (activations side)
    "q_heads",     # padded query-head dim (sharded over model)
    "kv_heads",    # kv-head dim (replicated when < model axis)
    "head_dim",
    "ff",          # d_ff dim
    "vocab",       # padded vocab dim
    "experts",     # expert dim (NOT sharded in baseline "expert-TP"; see DESIGN)
    "ssm_heads",   # mamba heads
    "ssm_state",
    "conv",
    "batch", "seq", "frames",
)


def rules_for(policy: str, kv_shardable: bool = False) -> dict:
    """logical axis -> mesh axis (or None) for a sharding policy."""
    base = {
        "layers": None,
        "embed": None,
        "embed_act": None,
        "q_heads": "model",
        # kv heads shard over model only when the padded count divides the axis
        # (MHA / large-GQA); otherwise replicated (q-grouping handles the math).
        "kv_heads": "model" if kv_shardable else None,
        "head_dim": None,
        "ff": "model",
        "vocab": "model",
        "experts": None,           # baseline expert-TP: shard ff dim instead
        "ssm_heads": "model",
        "ssm_state": None,
        "conv": None,
        "batch": ("pod", "data"),
        "seq": None,
        "frames": None,
    }
    if policy == "fsdp":
        base["embed"] = "data"
    elif policy == "fsdp_pod":
        base["embed"] = ("pod", "data")
    elif policy != "tp":
        raise ValueError(policy)
    return base


def logical_to_pspec(logical: Tuple[Optional[str], ...], policy: str,
                     mesh_axis_names: Tuple[str, ...], kv_shardable: bool = False):
    """Map a tuple of logical axis names to a PartitionSpec, dropping mesh axes
    that don't exist on the current mesh (e.g. "pod" on the single-pod mesh)."""
    from jax.sharding import PartitionSpec as P

    rules = rules_for(policy, kv_shardable)
    out = []
    for ax in logical:
        if ax is None:
            out.append(None)
            continue
        tgt = rules[ax]
        if tgt is None:
            out.append(None)
        elif isinstance(tgt, tuple):
            kept = tuple(t for t in tgt if t in mesh_axis_names)
            out.append(kept if kept else None)
        else:
            out.append(tgt if tgt in mesh_axis_names else None)
    return P(*out)
