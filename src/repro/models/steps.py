"""Train / serve step builders + ShapeDtypeStruct input specs per shape.

Shapes (assignment):
  train_4k     seq 4096,  global_batch 256   -> train_step
  prefill_32k  seq 32768, global_batch 32    -> prefill_step (serve)
  decode_32k   seq 32768 (KV cache), batch 128 -> decode_step (serve)
  long_500k    seq 524288 (cache), batch 1   -> decode_step, sub-quadratic only

Sharding of activations / caches:
  tokens, labels          (B, S)           P(batch, None)
  decode KV caches        (nsb, B, L, H, d) P(None, batch, "model" on L, None, None)
    — sequence-sharded caches turn decode softmax into a distributed
    log-sum-exp (flash-decoding); for batch=1 long-context the cache seq dim
    shards over ("data","model") so all 256 chips participate.
  mamba ssm state         (nsb, B, nh, p, ds) P(None, batch, "model", None, None)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import decoder as dec
from repro.models.params import param_shardings, param_specs
from repro.models.spec import ModelSpec
from repro.optim import AdamWConfig, adamw_update, compress_grads, make_schedule

SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def batch_axes(mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _pad_batch_axes(mesh, batch):
    """Largest prefix of (pod, data) whose product divides batch."""
    axes = []
    prod = 1
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for a in batch_axes(mesh):
        if batch % (prod * sizes[a]) == 0:
            axes.append(a)
            prod *= sizes[a]
    return tuple(axes)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------


def lm_loss(spec: ModelSpec, params, hidden, labels, loss_mask=None):
    logits = dec.lm_logits(spec, params, hidden).astype(jnp.float32)
    logits = logits + dec.vocab_mask_bias(spec)[None, None, :]
    lse = jax.nn.logsumexp(logits, axis=-1)
    # one-hot contraction keeps the gather shard-friendly on a vocab-sharded axis
    onehot = jax.nn.one_hot(labels, spec.padded_vocab, dtype=logits.dtype)
    gold = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = lse - gold
    if loss_mask is not None:
        nll = nll * loss_mask
        return nll.sum() / jnp.maximum(loss_mask.sum(), 1.0)
    return nll.mean()


# ---------------------------------------------------------------------------
# Forward passes per family
# ---------------------------------------------------------------------------


def forward_train(spec: ModelSpec, params, batch, *, remat=True, kv_chunk=1024):
    """Returns (loss, aux) for one (micro)batch dict."""
    if spec.family == "encdec":
        enc_h = dec.encoder_forward(spec, params, batch["frames"], remat=remat)
        S = batch["tokens"].shape[1]
        positions = jnp.arange(S)
        x = dec.embed_tokens(spec, params, batch["tokens"], positions)
        h, aux, _ = dec.decoder_forward(
            spec, params, x, positions=positions, remat=remat,
            kv_chunk=kv_chunk, enc_h=enc_h,
        )
        return lm_loss(spec, params, h, batch["labels"]), aux
    if spec.family == "vlm":
        pre = batch["patches"].astype(params["embed"].dtype) @ params["frontend_proj"]
        tx = dec.embed_tokens(spec, params, batch["tokens"])
        x = jnp.concatenate([pre, tx], axis=1)
        S = x.shape[1]
        h, aux, _ = dec.decoder_forward(
            spec, params, x, positions=jnp.arange(S),
            prefix_len=spec.n_prefix_tokens, remat=remat, kv_chunk=kv_chunk,
        )
        npre = pre.shape[1]
        h_text = h[:, npre:, :]
        return lm_loss(spec, params, h_text, batch["labels"]), aux
    x = dec.embed_tokens(spec, params, batch["tokens"])
    S = x.shape[1]
    h, aux, _ = dec.decoder_forward(
        spec, params, x, positions=jnp.arange(S), remat=remat, kv_chunk=kv_chunk,
    )
    return lm_loss(spec, params, h, batch["labels"]), aux


# ---------------------------------------------------------------------------
# Train step (with gradient accumulation + optional grad compression)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TrainCfg:
    optimizer: AdamWConfig = AdamWConfig()
    n_microbatches: int = 1
    aux_weight: float = 0.01          # MoE load-balance loss weight
    compression: str = "none"         # none | bf16 | int8
    schedule: str = "cosine"
    total_steps: int = 10_000
    remat: bool = True
    kv_chunk: int = 1024


def make_train_step(spec: ModelSpec, cfg: TrainCfg = TrainCfg()):
    sched = make_schedule(
        cfg.schedule if cfg.schedule != "auto" else spec.lr_schedule, cfg.total_steps
    )

    def loss_fn(params, mb):
        loss, aux = forward_train(spec, params, mb, remat=cfg.remat,
                                  kv_chunk=cfg.kv_chunk)
        return loss + cfg.aux_weight * aux, (loss, aux)

    def train_step(params, opt_state, batch):
        nmb = cfg.n_microbatches
        if nmb == 1:
            (tot, (loss, aux)), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, batch
            )
        else:
            def split(x):
                return x.reshape((nmb, x.shape[0] // nmb) + x.shape[1:])

            mbs = jax.tree.map(split, batch)

            def acc_fn(carry, mb):
                g_acc, l_acc, a_acc = carry
                (tot, (loss, aux)), g = jax.value_and_grad(loss_fn, has_aux=True)(
                    params, mb
                )
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32) / nmb, g_acc, g
                )
                return (g_acc, l_acc + loss / nmb, a_acc + aux / nmb), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss, aux), _ = lax.scan(
                acc_fn, (g0, jnp.float32(0.0), jnp.float32(0.0)), mbs
            )

        err = opt_state.get("compress_err")
        grads, new_err, _ = compress_grads(grads, cfg.compression, err)
        lr_scale = sched(opt_state["adam"]["step"])
        new_params, new_adam, stats = adamw_update(
            cfg.optimizer, params, grads, opt_state["adam"], lr_scale
        )
        new_opt = {"adam": new_adam}
        if cfg.compression == "int8":
            new_opt["compress_err"] = new_err
        metrics = {"loss": loss, "aux": aux, "grad_norm": stats["grad_norm"],
                   "lr_scale": lr_scale}
        return new_params, new_opt, metrics

    return train_step


def opt_state_specs(spec: ModelSpec, cfg: TrainCfg = TrainCfg()):
    ps = param_specs(spec)
    st = {
        "adam": {
            "m": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), ps),
            "v": jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), ps),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }
    }
    if cfg.compression == "int8":
        st["compress_err"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), ps
        )
    return st


def opt_state_shardings(spec: ModelSpec, mesh, cfg: TrainCfg = TrainCfg()):
    psh = param_shardings(spec, mesh)
    st = {
        "adam": {
            "m": psh,
            "v": psh,
            "step": NamedSharding(mesh, P()),
        }
    }
    if cfg.compression == "int8":
        st["compress_err"] = psh
    return st


def init_opt_state(spec: ModelSpec, params, cfg: TrainCfg = TrainCfg()):
    from repro.optim import adamw_init

    st = {"adam": adamw_init(params)}
    if cfg.compression == "int8":
        st["compress_err"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params
        )
    return st


# ---------------------------------------------------------------------------
# Serve: prefill + decode
# ---------------------------------------------------------------------------


def make_prefill_step(spec: ModelSpec, kv_chunk: int = 1024):
    def prefill(params, batch):
        if spec.family == "encdec":
            enc_h = dec.encoder_forward(spec, params, batch["frames"], remat=True)
            x = dec.embed_tokens(spec, params, batch["tokens"],
                                 jnp.arange(batch["tokens"].shape[1]))
            h, _, caches = dec.decoder_forward(
                spec, params, x, positions=jnp.arange(x.shape[1]),
                want_cache=True, kv_chunk=kv_chunk, enc_h=enc_h, remat=True,
            )
        elif spec.family == "vlm":
            pre = batch["patches"].astype(params["embed"].dtype) @ params["frontend_proj"]
            tx = dec.embed_tokens(spec, params, batch["tokens"])
            x = jnp.concatenate([pre, tx], axis=1)
            h, _, caches = dec.decoder_forward(
                spec, params, x, positions=jnp.arange(x.shape[1]),
                prefix_len=spec.n_prefix_tokens, want_cache=True,
                kv_chunk=kv_chunk, remat=True,
            )
        else:
            x = dec.embed_tokens(spec, params, batch["tokens"])
            h, _, caches = dec.decoder_forward(
                spec, params, x, positions=jnp.arange(x.shape[1]),
                want_cache=True, kv_chunk=kv_chunk, remat=True,
            )
        last = h[:, -1, :]
        logits = dec.lm_logits(spec, params, last[:, None, :])
        return logits, caches

    return prefill


def make_decode_step(spec: ModelSpec):
    def decode(params, caches, tokens, pos):
        """tokens: (B, 1) int32; pos: scalar int32 current length."""
        x = dec.embed_tokens(spec, params, tokens, jnp.full((1,), pos))
        h, new_caches = dec.decoder_decode(spec, params, x, caches, pos)
        logits = dec.lm_logits(spec, params, h).astype(jnp.float32)
        logits = logits + dec.vocab_mask_bias(spec)[None, None, :]
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok, new_caches

    return decode


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct) + shardings per shape
# ---------------------------------------------------------------------------


def cache_len(spec: ModelSpec, seq: int) -> int:
    if spec.swa_window is not None:
        return min(spec.swa_window, seq)
    return seq


def cache_specs(spec: ModelSpec, batch: int, seq: int, dtype=jnp.bfloat16):
    """ShapeDtypeStruct tree of decode caches (stacked over superblocks)."""
    nsb = spec.n_superblocks
    Hkv, hd = spec.padded_n_kv, spec.hd
    Lc = cache_len(spec, seq)
    out = {}
    for s in range(spec.period):
        if spec.is_attn_slot(s):
            c = {
                "k": jax.ShapeDtypeStruct((nsb, batch, Lc, Hkv, hd), dtype),
                "v": jax.ShapeDtypeStruct((nsb, batch, Lc, Hkv, hd), dtype),
            }
            if spec.family == "encdec":
                Se = 1500  # whisper encoder frames
                c["cross_k"] = jax.ShapeDtypeStruct((nsb, batch, Se, Hkv, hd), dtype)
                c["cross_v"] = jax.ShapeDtypeStruct((nsb, batch, Se, Hkv, hd), dtype)
        else:
            cfg = spec.ssm
            di = cfg.d_inner(spec.d_model)
            nh = cfg.n_heads(spec.d_model)
            c = {
                "ssm": jax.ShapeDtypeStruct(
                    (nsb, batch, nh, cfg.head_dim, cfg.d_state), jnp.float32
                ),
                "conv": jax.ShapeDtypeStruct(
                    (nsb, batch, 3, di + 2 * cfg.d_state), dtype
                ),
            }
        out[f"slot{s}"] = c
    return out


def cache_pspecs(spec: ModelSpec, mesh, batch: int):
    """PartitionSpec tree matching cache_specs."""
    baxes = _pad_batch_axes(mesh, batch)
    b = baxes if baxes else None
    # sequence dim of KV caches: shard over "model"; for batch=1 long-context
    # also shard over "data" (flash-decoding over 256 chips).
    seq_ax = ("data", "model") if batch == 1 else "model"
    kvh = "model" if spec.kv_shardable else None
    seq_ax = None if kvh == "model" else seq_ax
    out = {}
    for s in range(spec.period):
        if spec.is_attn_slot(s):
            c = {"k": P(None, b, seq_ax, kvh, None),
                 "v": P(None, b, seq_ax, kvh, None)}
            if spec.family == "encdec":
                c["cross_k"] = P(None, b, None, kvh, None)
                c["cross_v"] = P(None, b, None, kvh, None)
        else:
            c = {"ssm": P(None, b, "model", None, None),
                 "conv": P(None, b, None, None)}
        out[f"slot{s}"] = c
    return out


def input_specs(spec: ModelSpec, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of a shape cell."""
    sh = SHAPES[shape_name]
    B, S = sh["batch"], sh["seq"]
    tok = lambda b, s: jax.ShapeDtypeStruct((b, s), jnp.int32)
    if sh["kind"] == "train":
        batch = {"tokens": tok(B, S), "labels": tok(B, S)}
        if spec.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, S, spec.frontend_dim), jnp.bfloat16
            )
        if spec.family == "vlm":
            npre = spec.n_prefix_tokens
            batch = {
                "patches": jax.ShapeDtypeStruct((B, npre, spec.frontend_dim),
                                                jnp.bfloat16),
                "tokens": tok(B, S - npre),
                "labels": tok(B, S - npre),
            }
        return {"batch": batch}
    if sh["kind"] == "prefill":
        batch = {"tokens": tok(B, S)}
        if spec.family == "encdec":
            batch["frames"] = jax.ShapeDtypeStruct(
                (B, S, spec.frontend_dim), jnp.bfloat16
            )
        if spec.family == "vlm":
            npre = spec.n_prefix_tokens
            batch = {
                "patches": jax.ShapeDtypeStruct((B, npre, spec.frontend_dim),
                                                jnp.bfloat16),
                "tokens": tok(B, S - npre),
            }
        return {"batch": batch}
    # decode
    return {
        "caches": cache_specs(spec, B, S),
        "tokens": tok(B, 1),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def input_pspecs(spec: ModelSpec, mesh, shape_name: str):
    sh = SHAPES[shape_name]
    B = sh["batch"]
    baxes = _pad_batch_axes(mesh, B)
    b = baxes if baxes else None
    if sh["kind"] in ("train", "prefill"):
        batch = {k: P(b, None) for k in ("tokens", "labels") }
        if sh["kind"] == "prefill":
            batch = {"tokens": P(b, None)}
        if spec.family == "encdec":
            batch["frames"] = P(b, None, None)
        if spec.family == "vlm":
            batch["patches"] = P(b, None, None)
        return {"batch": batch}
    return {
        "caches": cache_pspecs(spec, mesh, B),
        "tokens": P(b, None),
        "pos": P(),
    }
