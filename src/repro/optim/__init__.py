from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update
from repro.optim.schedules import make_schedule
from repro.optim.compress import compress_grads
