"""AdamW with bf16 params / fp32 moments, global-norm clipping.

Moments inherit the parameter sharding (they're tree-mapped from params), so
optimizer state is sharded exactly like weights — with "fsdp"/"fsdp_pod"
policies that is ZeRO-3-equivalent placement.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params):
    return {
        "m": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(tree))
    return jnp.sqrt(sq)


def adamw_update(cfg: AdamWConfig, params, grads, state, lr_scale=1.0):
    """Returns (new_params, new_state, stats). grads may be bf16 or fp32."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)
    lr = cfg.lr * lr_scale

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, {"m": new_m, "v": new_v, "step": step}, {"grad_norm": gnorm}
