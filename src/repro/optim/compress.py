"""Gradient compression for the DP all-reduce, with error feedback.

On a real pod the compression wraps the cross-replica all-reduce (compress →
reduce → decompress).  Under single-program jit the DP reduction is implicit in
XLA's sharding propagation, so what we implement — and what matters for
*convergence* behaviour — is the quantise→dequantise transform applied to the
gradient contribution of each replica, plus an error-feedback accumulator that
carries the quantisation residual to the next step (Seide et al. / PowerSGD
practice).  The *bandwidth* effect is accounted analytically in the roofline
(collective bytes ÷ compression ratio); see EXPERIMENTS.md §Perf.

Modes: "none", "bf16" (fp32→bf16 on the wire, 2×), "int8" (8-bit per-tensor
scale, 4×, with error feedback).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def compress_grads(grads, mode: str, err_state=None):
    """Returns (decompressed_grads, new_err_state, wire_ratio)."""
    if mode == "none":
        return grads, err_state, 1.0
    if mode == "bf16":
        out = jax.tree.map(lambda g: g.astype(jnp.bfloat16).astype(jnp.float32), grads)
        return out, err_state, 2.0
    if mode == "int8":
        if err_state is None:
            err_state = jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), grads)

        def q(g, e):
            g = g.astype(jnp.float32) + e
            scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
            qi = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
            deq = qi.astype(jnp.float32) * scale
            return deq, g - deq

        flat, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(err_state)
        pairs = [q(g, e) for g, e in zip(flat, flat_e)]
        out = tdef.unflatten([p[0] for p in pairs])
        new_err = tdef.unflatten([p[1] for p in pairs])
        return out, new_err, 4.0
    raise ValueError(mode)
