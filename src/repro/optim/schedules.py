"""LR schedules: cosine (default) and WSD (warmup-stable-decay, MiniCPM)."""

from __future__ import annotations

import jax.numpy as jnp


def make_schedule(kind: str, total_steps: int, warmup: int = 100,
                  decay_frac: float = 0.1, min_ratio: float = 0.1):
    """Returns step -> lr multiplier in [0, 1]."""

    def cosine(step):
        step = jnp.asarray(step, jnp.float32)
        w = jnp.clip(step / jnp.maximum(warmup, 1), 0.0, 1.0)
        t = jnp.clip((step - warmup) / jnp.maximum(total_steps - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return w * cos

    def wsd(step):
        """MiniCPM warmup-stable-decay: flat LR, then a short sharp decay tail."""
        step = jnp.asarray(step, jnp.float32)
        w = jnp.clip(step / jnp.maximum(warmup, 1), 0.0, 1.0)
        decay_start = total_steps * (1.0 - decay_frac)
        t = jnp.clip((step - decay_start) / jnp.maximum(total_steps - decay_start, 1),
                     0.0, 1.0)
        stable = jnp.where(step < decay_start, 1.0, 1.0 - (1.0 - min_ratio) * t)
        return w * stable

    return {"cosine": cosine, "wsd": wsd}[kind]
