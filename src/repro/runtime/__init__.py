"""Distributed runtime: checkpoint/restart (elastic), fault tolerance."""

from repro.runtime.checkpoint import (CheckpointManager, UNSHAPED,
                                      unshaped_like)
from repro.runtime.ft import Heartbeat, retry_step, bounded_staleness_merge
