"""Sharded checkpoint/restart with elastic re-sharding.

Layout (one directory per step)::

    <root>/step_000123/
        manifest.json          # pytree structure, shapes, dtypes, step meta
        arr_000000.npy ...     # one file per leaf (host-gathered)
        _COMPLETE              # written LAST -> crash-safe commit marker

Design points for 1000+-node runs (DESIGN.md §6):

  * atomic commit: everything is written into ``<dir>.tmp`` then renamed;
    readers only trust directories containing ``_COMPLETE``.  A job killed
    mid-write never corrupts the latest checkpoint.
  * elastic restore: leaves are stored UNSHARDED (host-gathered); ``restore``
    re-shards onto whatever mesh/sharding the *restoring* job provides — a
    512-chip checkpoint restores onto 256 chips after losing a pod (tested
    in tests/test_runtime.py with forced multi-device CPU).
  * per-partition GS checkpoints: the paper's partitions are independent, so
    each partition saves its own tree under ``partition_<k>/`` and a failed
    node retrains/restores alone — failure recovery cost is O(1/n).
  * retention: ``keep`` newest checkpoints are kept, older ones pruned.
  * delta checkpoints (timeseries lineage): ``save_delta`` stores per-leaf
    sparse ROW diffs against a committed base step (``idx_*.npy`` +
    ``rows_*.npy``; full per-leaf fallback when the diff is dense or the
    shape changed) and ``restore_delta`` resolves the chain — with a loud
    refusal when a base is missing or no longer the manifest the delta was
    diffed against (sha256 fingerprint).  Use ``keep=0`` on managers that
    hold delta chains so retention cannot prune a base away.

On a real multi-host pod, `jax.experimental.multihost_utils` gathers would
replace ``jax.device_get`` and only process 0 would write; the layout and
commit protocol stay identical (single-process here).
"""

from __future__ import annotations

import hashlib
import json
import os
import shutil
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class _Unshaped:
    """Shape-free template leaf: ``restore`` checks leaf shapes against the
    ``like`` template only when the template leaf HAS a shape, so a tree of
    these sentinels restores whatever the checkpoint holds.  This is the
    serve-side loading idiom — a merged GS model's capacity is a training
    outcome (densify/prune + merge compaction), so the serving process
    cannot build a shaped template without reading the checkpoint first::

        g, extra, step = mgr.restore_latest(unshaped_like(Gaussians))

    Structure (leaf count / order) is still asserted; only shapes float.
    """
    __slots__ = ()

    def __repr__(self):
        return "UNSHAPED"


UNSHAPED = _Unshaped()


def unshaped_like(structure):
    """A pytree of ``UNSHAPED`` sentinels matching ``structure``: pass a
    template tree (leaf values ignored) or a NamedTuple CLASS with only
    array fields (e.g. ``core.gaussians.Gaussians``)."""
    if isinstance(structure, type) and issubclass(structure, tuple) \
            and hasattr(structure, "_fields"):
        return structure(*([UNSHAPED] * len(structure._fields)))
    return jax.tree.map(lambda _: UNSHAPED, structure)


class CheckpointManager:
    def __init__(self, root: str, *, keep: int = 3):
        self.root = root
        self.keep = keep
        os.makedirs(root, exist_ok=True)

    # ------------------------------------------------------------------

    def _step_dir(self, step: int, partition: Optional[int] = None) -> str:
        d = os.path.join(self.root, f"step_{step:09d}")
        if partition is not None:
            d = os.path.join(d, f"partition_{partition}")
        return d

    def save(self, step: int, tree: Any, *, partition: Optional[int] = None,
             extra: Optional[dict] = None):
        """Host-gather every leaf and atomically write one checkpoint.

        ``extra`` is a JSON-able dict stored in manifest.json verbatim —
        the drivers ride schedule state on it (``extra["schedule"]`` for
        TierSchedule caps, ``extra["exchange"]`` for the sparse-exchange
        edge budget) so a resumed run keeps its probed static shapes
        instead of re-probing.
        """
        final = self._step_dir(step, partition)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)

        leaves, treedef = _flatten_with_paths(tree)
        manifest = {
            "step": step,
            "time": time.time(),
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "extra": extra or {},
            "leaves": [],
        }
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            np.save(os.path.join(tmp, f"arr_{i:06d}.npy"), arr)
            manifest["leaves"].append(
                {"shape": list(arr.shape), "dtype": str(arr.dtype)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "_COMPLETE"), "w") as f:
            f.write("ok")
        os.makedirs(os.path.dirname(final) or ".", exist_ok=True)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        self._prune()
        return final

    def _prune(self):
        steps = self.all_steps()
        for s in steps[: -self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.root, f"step_{s:09d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------------

    def all_steps(self, partition: Optional[int] = None):
        """Complete checkpoint steps, ascending.  ``partition=None`` counts
        a step complete when the root OR any partition subtree committed
        (retention semantics); ``partition=k`` counts only steps where THAT
        partition's own subtree committed — per-partition saves are
        independent, so one partition's progress must not advertise a step
        its peers never wrote."""
        out = []
        for name in sorted(os.listdir(self.root)):
            if not name.startswith("step_") or name.endswith(".tmp"):
                continue
            d = os.path.join(self.root, name)
            if partition is not None:
                complete = os.path.exists(os.path.join(
                    d, f"partition_{partition}", "_COMPLETE"))
            else:
                complete = os.path.exists(os.path.join(d, "_COMPLETE")) \
                    or any(
                        os.path.exists(os.path.join(d, p, "_COMPLETE"))
                        for p in os.listdir(d) if p.startswith("partition_")
                    )
            if complete:
                out.append(int(name[5:]))
        return out

    def latest_step(self, partition: Optional[int] = None) -> Optional[int]:
        steps = self.all_steps(partition)
        return steps[-1] if steps else None

    def latest_restorable_step(self,
                               partition: Optional[int] = None
                               ) -> Optional[int]:
        """Newest step whose EXACT target tree committed: the root tree for
        ``partition=None``, that partition's subtree otherwise.  This is
        stricter than ``latest_step(None)``, which (for retention) counts a
        step complete when ANY partition committed — restoring the root
        tree from such a step would fail."""
        for s in reversed(self.all_steps(partition)):
            if os.path.exists(os.path.join(self._step_dir(s, partition),
                                           "_COMPLETE")):
                return s
        return None

    def manifest_extra(self, step: int,
                       partition: Optional[int] = None) -> dict:
        """The ``extra`` dict of a committed checkpoint WITHOUT restoring
        its tree.  The resume-compatibility peek: drivers whose step-state
        LAYOUT depends on config (grad_compress error feedback changes the
        leaf count) must read the recorded config and raise the documented
        mismatch error BEFORE the leaf-count assert in ``restore`` could
        fire an opaque one."""
        d = self._step_dir(step, partition)
        assert os.path.exists(os.path.join(d, "_COMPLETE")), d
        with open(os.path.join(d, "manifest.json")) as f:
            return json.load(f)["extra"]

    def restore_latest(self, like: Any, *, partition: Optional[int] = None,
                       shardings: Any = None):
        """Restore the newest RESTORABLE checkpoint: (tree, extra, step).

        None restorable (for THIS tree/partition) -> ``(like, {}, None)``
        — callers can unpack unconditionally and branch on ``step is
        None`` (the resume idiom of train.fit_partition /
        core.distributed.fit_partitions).  A directory holding only
        per-partition saves is NOT restorable as a root tree (and vice
        versa): such steps are skipped rather than crashing mid-restore."""
        step = self.latest_restorable_step(partition)
        if step is None:
            return like, {}, None
        tree, extra = self.restore(step, like, partition=partition,
                                   shardings=shardings)
        return tree, extra, step

    def restore(self, step: int, like: Any, *,
                partition: Optional[int] = None, shardings: Any = None):
        """Restore into the structure of ``like``; if ``shardings`` is given
        (a matching tree of NamedSharding), leaves are device_put with it —
        this is the elastic path: the target mesh may differ arbitrarily
        from the mesh that saved."""
        d = self._step_dir(step, partition)
        assert os.path.exists(os.path.join(d, "_COMPLETE")), d
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        if "delta" in manifest:
            raise ValueError(
                f"checkpoint step {step} under {self.root} is a DELTA "
                "checkpoint (diffed against base step "
                f"{manifest['delta']['base_step']}); restore it with "
                "restore_delta, which resolves the base chain")
        leaves, treedef = _flatten_with_paths(like)
        assert len(leaves) == manifest["n_leaves"], (
            f"leaf count mismatch: have {len(leaves)}, "
            f"checkpoint {manifest['n_leaves']}")
        arrs = []
        for i, ref in enumerate(leaves):
            arr = np.load(os.path.join(d, f"arr_{i:06d}.npy"))
            want = tuple(ref.shape) if hasattr(ref, "shape") else None
            assert want is None or want == arr.shape, (
                f"leaf {i}: shape {arr.shape} != expected {want}")
            arrs.append(arr)
        out = jax.tree.unflatten(treedef, arrs)
        if shardings is not None:
            out = jax.tree.map(
                lambda a, s: jax.device_put(a, s), out, shardings)
        else:
            out = jax.tree.map(jnp.asarray, out)
        return out, manifest["extra"]

    # ------------------------------------------------------------------
    # Delta checkpoints (timeseries lineage: per-leaf sparse row diffs)
    # ------------------------------------------------------------------

    def _manifest_digest(self, step: int,
                         partition: Optional[int] = None) -> str:
        """sha256 of a committed checkpoint's raw manifest.json bytes —
        the base-identity fingerprint recorded inside every delta."""
        path = os.path.join(self._step_dir(step, partition), "manifest.json")
        with open(path, "rb") as f:
            return hashlib.sha256(f.read()).hexdigest()

    def save_delta(self, step: int, tree: Any, *, base_step: int,
                   partition: Optional[int] = None,
                   extra: Optional[dict] = None):
        """Atomically write ``tree`` as a DELTA against the committed
        checkpoint at ``base_step``: per-leaf sparse ROW diffs (indices +
        changed rows along the leading axis) instead of full arrays.

        The timeseries idiom: timestep t's state differs from t-1's mostly
        in the rows training actually moved, so the delta is small; leaves
        whose shape/dtype changed (or whose diff is dense enough that the
        row encoding would not win) fall back to a full per-leaf copy —
        ``restore_delta`` round-trips EXACTLY either way, including int8
        cold-quantized fields (bit-compared like any other dtype).

        The delta manifest records ``base_step`` plus the sha256 of the
        base's manifest.json; ``restore_delta`` refuses to apply a delta
        whose base is missing or was replaced.  Deltas may CHAIN (the base
        may itself be a delta).  Retention is the caller's concern: this
        method never prunes, and a manager holding a delta chain should be
        built with ``keep=0`` so ``save`` cannot prune a base away.

        Raises ValueError when the base is missing/incomplete or the tree
        structure does not match the base's.
        """
        base_dir = self._step_dir(base_step, partition)
        if not os.path.exists(os.path.join(base_dir, "_COMPLETE")):
            raise ValueError(
                f"save_delta(step={step}): base checkpoint step "
                f"{base_step} is missing or incomplete under {self.root} — "
                "a delta needs its base committed first")
        with open(os.path.join(base_dir, "manifest.json")) as f:
            base_manifest = json.load(f)
        leaves, treedef = _flatten_with_paths(tree)
        if len(leaves) != base_manifest["n_leaves"] \
                or str(treedef) != base_manifest["treedef"]:
            raise ValueError(
                f"save_delta(step={step}): tree structure does not match "
                f"base step {base_step} ({len(leaves)} leaves vs "
                f"{base_manifest['n_leaves']}) — delta checkpoints diff "
                "like against like")

        final = self._step_dir(step, partition)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        manifest = {
            "step": step,
            "time": time.time(),
            "treedef": str(treedef),
            "n_leaves": len(leaves),
            "extra": extra or {},
            "delta": {
                "base_step": base_step,
                "base_digest": self._manifest_digest(base_step, partition),
            },
            "leaves": [],
        }
        # materialize the base leaves THROUGH its own chain (the base may
        # itself be a delta, whose dir holds only idx/rows files)
        base_arrs, _ = self._resolve_leaves(base_step, partition)
        for i, leaf in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            base_arr = base_arrs[i]
            meta = {"shape": list(arr.shape), "dtype": str(arr.dtype)}
            rows = None
            if base_arr is not None and arr.shape == base_arr.shape \
                    and arr.dtype == base_arr.dtype and arr.ndim >= 1:
                # NaN-conservative: a NaN row always compares unequal, so
                # it is re-saved — exactness beats a smaller diff
                changed = (arr != base_arr).reshape(arr.shape[0], -1).any(1)
                idx = np.flatnonzero(changed)
                rows = arr[idx]
                if idx.nbytes + rows.nbytes >= arr.nbytes:
                    rows = None           # dense diff: full copy is smaller
            if rows is None:
                np.save(os.path.join(tmp, f"arr_{i:06d}.npy"), arr)
                meta["delta"] = "full"
            else:
                np.save(os.path.join(tmp, f"idx_{i:06d}.npy"), idx)
                np.save(os.path.join(tmp, f"rows_{i:06d}.npy"), rows)
                meta["delta"] = "rows"
                meta["n_rows"] = int(idx.size)
            manifest["leaves"].append(meta)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        with open(os.path.join(tmp, "_COMPLETE"), "w") as f:
            f.write("ok")
        os.makedirs(os.path.dirname(final) or ".", exist_ok=True)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)
        return final

    def _resolve_leaves(self, step: int, partition: Optional[int] = None):
        """-> (host numpy leaf list, manifest), resolving delta chains
        recursively; no template needed (shapes come from the manifests)."""
        d = self._step_dir(step, partition)
        if not os.path.exists(os.path.join(d, "_COMPLETE")):
            raise ValueError(
                f"checkpoint step {step} is missing or incomplete under "
                f"{self.root}" + ("" if partition is None
                                  else f" (partition {partition})"))
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        n = manifest["n_leaves"]
        if "delta" not in manifest:
            return [np.load(os.path.join(d, f"arr_{i:06d}.npy"))
                    for i in range(n)], manifest

        info = manifest["delta"]
        base_step = info["base_step"]
        base_dir = self._step_dir(base_step, partition)
        if not os.path.exists(os.path.join(base_dir, "_COMPLETE")):
            raise ValueError(
                f"delta checkpoint step {step} needs base step "
                f"{base_step}, but {base_dir} is missing or incomplete "
                "— the delta chain must be retained (build the "
                "manager with keep=0 for timeseries lineage)")
        digest = self._manifest_digest(base_step, partition)
        if digest != info["base_digest"]:
            raise ValueError(
                f"delta checkpoint step {step} was diffed against a "
                f"DIFFERENT base: step {base_step}'s manifest digest "
                f"{digest[:12]}... != recorded "
                f"{info['base_digest'][:12]}... — the base was "
                "overwritten or replaced; refusing to apply the delta")
        arrs, _ = self._resolve_leaves(base_step, partition)
        for i, meta in enumerate(manifest["leaves"]):
            if meta["delta"] == "full":
                arrs[i] = np.load(os.path.join(d, f"arr_{i:06d}.npy"))
            else:
                arr = np.array(arrs[i])          # writable copy of the base
                idx = np.load(os.path.join(d, f"idx_{i:06d}.npy"))
                if idx.size:
                    arr[idx] = np.load(os.path.join(d, f"rows_{i:06d}.npy"))
                arrs[i] = arr
        return arrs, manifest

    def _load_leaves(self, step: int, like: Any,
                     partition: Optional[int] = None):
        """``_resolve_leaves`` + structure/shape checks against ``like``."""
        arrs, manifest = self._resolve_leaves(step, partition)
        leaves, treedef = _flatten_with_paths(like)
        assert len(leaves) == manifest["n_leaves"], (
            f"leaf count mismatch: have {len(leaves)}, "
            f"checkpoint {manifest['n_leaves']}")
        for i, (ref, arr) in enumerate(zip(leaves, arrs)):
            want = tuple(ref.shape) if hasattr(ref, "shape") else None
            assert want is None or want == arr.shape, (
                f"leaf {i}: shape {arr.shape} != expected {want}")
        return arrs, treedef, manifest

    def restore_delta(self, step: int, like: Any, *,
                      partition: Optional[int] = None, shardings: Any = None):
        """Restore the checkpoint at ``step``, applying its delta chain:
        full checkpoints load directly, deltas load their base (itself
        possibly a delta) and overwrite the recorded rows — the result is
        bit-identical to the tree ``save_delta`` was given.  Returns
        ``(tree, extra)`` like ``restore``.  Loud ValueError when any base
        in the chain is missing, incomplete or no longer the manifest the
        delta was diffed against."""
        arrs, treedef, manifest = self._load_leaves(step, like, partition)
        out = jax.tree.unflatten(treedef, arrs)
        if shardings is not None:
            out = jax.tree.map(
                lambda a, s: jax.device_put(a, s), out, shardings)
        else:
            out = jax.tree.map(jnp.asarray, out)
        return out, manifest["extra"]


# ---------------------------------------------------------------------------
# Quantized cold-attribute checkpointing (int8 per-tensor scale)
# ---------------------------------------------------------------------------

#: merged-model fields cold enough for int8 storage: degree-0 SH color and
#: the opacity logit.  GEOMETRY (means/scales/quats) stays f32 — position
#: error is a rendering error at every pixel a splat touches, while color /
#: opacity error is bounded by the 8-bit step of a per-tensor scale.
COLD_QUANT_FIELDS = ("colors", "opacity_logit")


def quantize_cold(tree, fields=COLD_QUANT_FIELDS):
    """-> (tree with ``fields`` as int8, JSON-able meta for ``extra``).

    Symmetric int8 per-tensor scale (scale = max|x| / 127, the
    optim/compress.py convention): each named leaf is stored as int8 with
    its f32 scale recorded in the returned meta dict — pass the meta as
    ``extra={"quant": meta}`` on save so ``dequantize_cold`` (and
    serving's ``from_checkpoint``) can restore.  Quantization error per
    element is <= scale/2 = max|x|/254.  Fields are a NamedTuple's
    attribute names (the merged ``Gaussians``); untouched leaves keep
    their dtype, so the checkpoint byte win is exactly 3 bytes per
    quantized element."""
    meta = {"mode": "int8", "fields": {}}
    repl = {}
    for name in fields:
        x = np.asarray(jax.device_get(getattr(tree, name)), np.float32)
        scale = float(max(np.abs(x).max(), 1e-12) / 127.0)
        q = np.clip(np.round(x / scale), -127, 127).astype(np.int8)
        repl[name] = q
        meta["fields"][name] = scale
    return tree._replace(**repl), meta


def dequantize_cold(tree, meta: dict):
    """Invert ``quantize_cold`` using the scales recorded in ``meta``
    (``extra["quant"]``).  Leaves restore to f32; a tree saved WITHOUT
    quantization passes through untouched when ``meta`` is falsy."""
    if not meta:
        return tree
    if meta.get("mode") != "int8":
        raise ValueError(f"unknown checkpoint quant mode: {meta.get('mode')!r}")
    repl = {}
    for name, scale in meta["fields"].items():
        q = getattr(tree, name)
        repl[name] = jnp.asarray(q, jnp.float32) * jnp.float32(scale)
    return tree._replace(**repl)
