"""Fault tolerance: step retry, heartbeats, straggler-tolerant merge.

The paper's partition independence is the backbone of the FT story: a failed
node invalidates ONE partition, which restores from its own checkpoint and
retrains alone (cost O(1/n) of the job), while the merge proceeds with
*bounded staleness* — it reads the latest complete checkpoint of every
partition rather than blocking on the barrier (DESIGN.md §6).
"""

from __future__ import annotations

import json
import os
import time
from typing import Any, Callable, List, Optional



def retry_step(fn: Callable, *args, retries: int = 2,
               on_failure: Optional[Callable] = None, **kw):
    """Run a (re-runnable, functional) step with retry.

    Training steps here are pure functions of (state, batch) — a transient
    failure (preempted host, flaky interconnect) is retried with the SAME
    inputs, so retries are semantically invisible.  Deterministic failures
    exhaust retries and re-raise.
    """
    err = None
    for attempt in range(retries + 1):
        try:
            return fn(*args, **kw)
        except Exception as e:  # noqa: BLE001 — deliberate catch-all boundary
            err = e
            if on_failure is not None:
                on_failure(attempt, e)
    raise err


class Heartbeat:
    """Health-file heartbeat for external watchdogs.

    Each worker touches ``<dir>/hb_<name>.json`` every ``interval`` seconds
    with its step counter; an external supervisor (or another worker) calls
    ``stale()`` to list members whose heartbeat is older than ``timeout`` —
    those are straggler/failure suspects whose partitions get rescheduled.
    """

    def __init__(self, dir: str, name: str, *, interval: float = 10.0):
        self.dir = dir
        self.name = name
        self.interval = interval
        self._last = 0.0
        os.makedirs(dir, exist_ok=True)

    def path(self, name: Optional[str] = None) -> str:
        return os.path.join(self.dir, f"hb_{name or self.name}.json")

    def beat(self, step: int, force: bool = False, **info):
        now = time.time()
        if not force and now - self._last < self.interval:
            return
        self._last = now
        tmp = self.path() + ".tmp"
        with open(tmp, "w") as f:
            json.dump({"time": now, "step": step, **info}, f)
        os.replace(tmp, self.path())

    def stale(self, timeout: float, now: Optional[float] = None) -> List[str]:
        now = now or time.time()
        out = []
        for fn in os.listdir(self.dir):
            if not fn.startswith("hb_"):
                continue
            try:
                with open(os.path.join(self.dir, fn)) as f:
                    hb = json.load(f)
            except Exception:
                out.append(fn[3:-5])
                continue
            if now - hb["time"] > timeout:
                out.append(fn[3:-5])
        return sorted(out)


def bounded_staleness_merge(ckpt_mgr, n_parts: int, like: Any, *,
                            max_lag: int = 0):
    """Merge inputs under stragglers: for each partition pick its LATEST
    complete checkpoint (optionally requiring step >= newest - max_lag).

    Returns (list of restored trees, list of steps used, laggards). The
    caller merges with core/merge.py; a laggard beyond max_lag is reported
    so the supervisor can reschedule it, but the merge never blocks.
    """
    newest = ckpt_mgr.latest_step()
    assert newest is not None, "no checkpoints at all"
    trees, steps, laggards = [], [], []
    for p in range(n_parts):
        got = None
        for s in reversed(ckpt_mgr.all_steps()):
            d = ckpt_mgr._step_dir(s, p)
            if os.path.exists(os.path.join(d, "_COMPLETE")):
                got = s
                break
        assert got is not None, f"partition {p} has no checkpoint"
        if max_lag and newest - got > max_lag:
            laggards.append(p)
        tree, _ = ckpt_mgr.restore(got, like, partition=p)
        trees.append(tree)
        steps.append(got)
    return trees, steps, laggards
