"""Minimal fallback shim for ``hypothesis`` (tier-1 must collect without it).

Implements just the surface the test suite uses — ``given``, ``settings``,
``strategies.{integers,floats,sampled_from,composite}`` — by drawing a fixed
number of deterministic pseudo-random examples per test.  No shrinking, no
database, no adaptive search: this is a degraded-but-green mode so the rest
of the suite keeps running on machines without hypothesis installed.  When
hypothesis IS installed the test modules import the real thing instead (see
the try/except at their top).
"""

from __future__ import annotations

import inspect

import numpy as np


class Strategy:
    def __init__(self, sample):
        self.sample = sample          # rng -> value


class strategies:
    @staticmethod
    def integers(min_value, max_value):
        return Strategy(lambda rng: int(rng.integers(min_value, max_value + 1)))

    @staticmethod
    def floats(min_value, max_value, **_kw):
        return Strategy(lambda rng: float(rng.uniform(min_value, max_value)))

    @staticmethod
    def sampled_from(elements):
        seq = list(elements)
        return Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    @staticmethod
    def composite(fn):
        def make(*args, **kwargs):
            def sample(rng):
                draw = lambda strat: strat.sample(rng)
                return fn(draw, *args, **kwargs)
            return Strategy(sample)
        return make


st = strategies


def settings(max_examples: int = 10, **_kw):
    """Records max_examples on the (already-wrapped) test function."""
    def deco(fn):
        fn._shim_max_examples = max_examples
        return fn
    return deco


def given(*strats):
    """Strategies fill the test's trailing parameters (hypothesis's
    positional convention); the wrapper's visible signature drops them so
    pytest doesn't look for same-named fixtures."""
    def deco(fn):
        sig = inspect.signature(fn)
        params = list(sig.parameters.values())
        keep = params[: len(params) - len(strats)]
        # strategy values bind BY NAME to the trailing parameters, so
        # fixture/parametrize arguments (passed by pytest as kwargs) keep
        # working in shim mode
        filled = [p.name for p in params[len(params) - len(strats):]]

        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_shim_max_examples", 10)
            for i in range(n):
                rng = np.random.default_rng(0xC0FFEE + i)
                vals = dict(zip(filled, (s.sample(rng) for s in strats)))
                fn(*args, **kwargs, **vals)

        wrapper.__signature__ = sig.replace(parameters=keep)
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco
