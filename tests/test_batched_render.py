"""View-batched rendering + minibatch-of-views training (the tentpole).

render_batch over V views must match V sequential render calls to
float-associativity tolerance under BOTH CPU impls (ref autodiff path and
interpret-mode Pallas kernel bodies), the chunked pipeline render_views must
agree with it for any chunk size, and the view-batched train step must
reduce to the single-view step when the batch repeats one view.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cameras import orbital_rig, select
from repro.core.gaussians import from_points
from repro.core.pipeline import gt_gaussians, render_views
from repro.core.render import render, render_batch
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, init_opt, make_train_step
from repro.data.isosurface import point_cloud_for


def scene(n=600, res=48, n_views=5, seed=0):
    pts, cols = point_cloud_for("sphere_shell", n, seed=seed)
    g = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.9)
    extent = float(np.linalg.norm(pts.max(0) - pts.min(0)))
    cams = orbital_rig(n_views, (0.5, 0.5, 0.5), 1.5, width=res, height=res)
    grid = TileGrid(res, res, 8, 16)
    return g, cams, grid, extent


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_render_batch_matches_sequential(impl):
    g, cams, grid, _ = scene()
    V = cams.view.shape[0]
    out_b = render_batch(g, cams, grid, K=16, impl=impl)
    assert out_b.rgb.shape == (V, 48, 48, 3)
    assert out_b.coverage.shape == (V, 48, 48)
    for v in range(V):
        out_s = render(g, select(cams, v), grid, K=16, impl=impl)
        np.testing.assert_allclose(np.asarray(out_b.rgb[v]),
                                   np.asarray(out_s.rgb),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(out_b.coverage[v]),
                                   np.asarray(out_s.coverage),
                                   rtol=1e-5, atol=1e-5)


def test_render_batch_coarse_matches_dense():
    g, cams, grid, _ = scene()
    out_d = render_batch(g, cams, grid, K=16, impl="ref")
    out_c = render_batch(g, cams, grid, K=16, impl="ref", coarse=2)
    np.testing.assert_allclose(np.asarray(out_c.rgb), np.asarray(out_d.rgb),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("batch", [1, 2, 5, 8])
def test_render_views_chunking_invariant(batch):
    """Chunk size (incl. padded tail chunks) never changes the images."""
    g, cams, grid, _ = scene(n=300, n_views=5)
    rgb, cov = render_views(g, cams, grid, K=16, impl="ref", batch=batch)
    rgb1, cov1 = render_views(g, cams, grid, K=16, impl="ref", batch=3)
    assert rgb.shape == (5, 48, 48, 3)
    np.testing.assert_allclose(rgb, rgb1, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(cov, cov1, rtol=1e-5, atol=1e-5)


def test_batched_step_equals_single_view_step():
    """A V=2 batch repeating one view = the single-view step (same loss and
    same parameter update, since the view-mean is over identical terms)."""
    g, cams, grid, extent = scene(n=300, res=32, n_views=3)
    gt = render(g, select(cams, 0), grid, K=16).rgb
    cfg = GSTrainCfg(K=16)
    step = jax.jit(make_train_step(cfg, grid, extent))
    g0 = g._replace(colors=g.colors + 0.5)

    g1, _, l1 = step(g0, init_opt(g0), select(cams, 0), gt)
    cam_b = select(cams, jnp.array([0, 0]))
    g2, _, l2 = step(g0, init_opt(g0), cam_b, jnp.stack([gt, gt]))
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(g1.colors), np.asarray(g2.colors),
                               atol=1e-6)
    np.testing.assert_allclose(np.asarray(g1.means), np.asarray(g2.means),
                               atol=1e-6)


def test_batched_step_with_masks_and_distinct_views_trains():
    """Minibatch of DISTINCT masked views: loss decreases and the loss of
    the first step equals the mean of the per-view single-view losses."""
    g, cams, grid, extent = scene(n=300, res=32, n_views=4)
    gts, covs = render_views(gt_gaussians(*point_cloud_for("sphere_shell",
                                                           300)),
                             cams, grid, K=16, impl="ref")
    masks = jnp.asarray(covs > 1.0 / 255.0)
    gts = jnp.asarray(gts)
    cfg = GSTrainCfg(K=16, lr_colors=5e-2)
    step = jax.jit(make_train_step(cfg, grid, extent))
    g0 = g._replace(colors=g.colors + 1.0)

    # per-view losses at theta_0
    singles = [float(step(g0, init_opt(g0), select(cams, v), gts[v],
                          masks[v])[2]) for v in range(4)]
    vi = jnp.arange(4)
    gb, opt, l0 = step(g0, init_opt(g0), select(cams, vi), gts, masks)
    np.testing.assert_allclose(float(l0), np.mean(singles), rtol=1e-5)

    losses = [float(l0)]
    for _ in range(15):
        gb, opt, l = step(gb, opt, select(cams, vi), gts, masks)
        losses.append(float(l))
    assert losses[-1] < 0.7 * losses[0], losses


def test_render_batch_jit_cache_keys_distinct():
    """Every static budget is part of the jit-cache key: callers differing
    only in assign_budget or coarse_budget bake different budgets into the
    traced graph and must never share a compiled fn (while identical
    configs must — that cache is the whole point of _render_batch_jit)."""
    from repro.core.pipeline import _render_batch_jit
    grid = TileGrid(48, 48, 8, 16)
    base = (grid, 16, "ref", 1.0, None, None, None, "dense")
    f0 = _render_batch_jit(*base, None, None)
    assert _render_batch_jit(*base, None, None) is f0
    assert _render_batch_jit(*base, 4096, None) is not f0   # assign_budget
    assert _render_batch_jit(*base, None, 512) is not f0    # coarse_budget
    assert _render_batch_jit(*base, 4096, None) \
        is _render_batch_jit(*base, 4096, None)
