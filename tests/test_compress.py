"""Unit tests for optim/compress.py (gradient wire compression).

The module predates its first caller (core.distributed.make_gs_train_step
wires it behind GSTrainCfg.grad_compress); these tests pin its contract
directly so the driver integration can rely on it:

  * "none"  is an identity passthrough (same leaves, ratio 1.0)
  * "bf16"  is a stateless fp32->bf16->fp32 round-trip (ratio 2.0) whose
            per-element error is bounded by the bf16 unit roundoff
  * "int8"  quantises with a per-tensor scale (ratio 4.0) and CARRIES the
            residual: cumulative dequantised output over steps equals the
            cumulative true gradient minus only the final residual
  * unknown modes raise loudly

The timeseries boundary contract (PR 9) rides at the bottom: the int8
error-feedback residual must NOT cross a timestep boundary — a
``warm_start=`` resume of ``core.distributed.fit_partitions`` drops the
saved residual (the new timestep's field moved under the rows, so the
carried error is stale) and matches a per-timestep-fresh run bit-for-bit,
while a same-timestep DISK resume keeps it and diverges from both.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.compress import compress_grads


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    ka, kb = jax.random.split(k)
    return {
        "a": jax.random.normal(ka, (33, 7), jnp.float32),
        "b": 1e-3 * jax.random.normal(kb, (128,), jnp.float32),
    }


def test_none_is_identity():
    g = _tree()
    out, err, ratio = compress_grads(g, "none", err_state=None)
    assert ratio == 1.0
    assert err is None
    # identity, not a copy: the driver's "none" path must stay zero-cost
    assert out is g


def test_bf16_round_trip():
    g = _tree()
    out, err, ratio = compress_grads(g, "bf16", err_state=None)
    assert ratio == 2.0
    assert err is None           # stateless: no residual to carry
    for name in g:
        o, x = np.asarray(out[name]), np.asarray(g[name])
        assert o.dtype == np.float32   # decompressed back to f32
        # bf16 keeps f32's exponent; 8-bit mantissa -> relative error
        # <= 2^-9 per element (round-to-nearest unit roundoff)
        assert np.all(np.abs(o - x) <= np.abs(x) * 2.0 ** -8 + 1e-12)
        # and it actually quantised: exact only where bf16-representable
        assert o == pytest.approx(x, rel=2.0 ** -8)


def test_int8_error_feedback_carries_residual():
    g = _tree()
    # step 1: err_state=None must zeros-init internally
    d1, e1, ratio = compress_grads(g, "int8", err_state=None)
    assert ratio == 4.0
    for name in g:
        # per-tensor scale = max|g|/127 -> error <= scale/2 per element
        scale = float(np.abs(np.asarray(g[name])).max()) / 127.0
        assert np.abs(np.asarray(d1[name] - g[name])).max() <= 0.5 * scale \
            + 1e-7
        # residual is exactly what the wire dropped
        np.testing.assert_allclose(np.asarray(e1[name]),
                                   np.asarray(g[name] - d1[name]),
                                   rtol=0, atol=1e-7)
    # step 2 with the SAME gradient: the carried residual compensates, so
    # cumulative dequantised == cumulative true gradient - final residual
    # (the error-feedback invariant that makes long-run bias vanish)
    d2, e2, _ = compress_grads(g, "int8", err_state=e1)
    for name in g:
        lhs = np.asarray(d1[name] + d2[name] + e2[name])
        rhs = np.asarray(g[name] + g[name])
        np.testing.assert_allclose(lhs, rhs, rtol=0, atol=1e-5)


def test_int8_zero_init_matches_explicit_zeros():
    g = _tree(1)
    zeros = jax.tree.map(lambda x: jnp.zeros_like(x), g)
    d_none, e_none, _ = compress_grads(g, "int8", err_state=None)
    d_zero, e_zero, _ = compress_grads(g, "int8", err_state=zeros)
    for name in g:
        np.testing.assert_array_equal(np.asarray(d_none[name]),
                                      np.asarray(d_zero[name]))
        np.testing.assert_array_equal(np.asarray(e_none[name]),
                                      np.asarray(e_zero[name]))


def test_unknown_mode_raises():
    with pytest.raises(ValueError):
        compress_grads(_tree(), "fp4", err_state=None)


# ---------------------------------------------------------------------------
# Timestep-boundary reset (PR 9): the residual never crosses a warm start
# ---------------------------------------------------------------------------


def _scene(res=16, V=2, N=64):
    """Tiny driver scene, rebuilt from host numpy on EVERY call: the
    donating train step consumes the init buffers, so each fit_partitions
    call needs fresh device arrays."""
    from repro.core.cameras import orbital_rig
    from repro.core.gaussians import from_points
    from repro.core.pipeline import render_views
    from repro.core.tiling import TileGrid
    from repro.data.isosurface import point_cloud_for

    pts, cols = point_cloud_for("sphere_shell", N)
    pts, cols = np.array(pts[:N]), np.array(cols[:N])
    cams = orbital_rig(V, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
    grid = TileGrid(res, res, 8, 8)
    g_gt = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.95)
    gts = np.asarray(render_views(g_gt, cams, grid, K=8, bg=0.0)[0])
    g0 = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.7)
    g_b = jax.tree.map(lambda x: x[None], g0)
    masks = jnp.ones((1, V, res, res), bool)
    return g_b, cams, jnp.asarray(gts)[None], masks, grid


@pytest.mark.slow
def test_int8_error_feedback_resets_at_timestep_boundary(tmp_path):
    """An int8-compressed run checkpoints (g, opt, err) with a NONZERO
    residual; resuming it via ``warm_start=`` (the timeseries boundary)
    drops that residual — bit-identical losses and params to a
    per-timestep-fresh run handed only (g, opt) — while a same-timestep
    DISK resume keeps it and diverges from both.  The divergence check is
    what gives the reset assertion teeth: the residual demonstrably
    changes the trajectory when it IS carried."""
    from repro.core.distributed import fit_partitions
    from repro.core.train import GSTrainCfg, init_opt
    from repro.runtime import CheckpointManager

    cfg = GSTrainCfg(K=8, lambda_dssim=0.0, bg=0.0, view_batch=1,
                     lr_colors=5e-2, grad_compress="int8")
    mesh = jax.make_mesh((1, 1), ("part", "view"))
    key = jax.random.PRNGKey(7)

    def run(**over):
        g_b, cams, gts, masks, grid = _scene()
        return fit_partitions(g_b, cams, gts, masks, cfg, mesh=mesh,
                              extent=1.0, grid=grid, key=key,
                              schedule=cfg.tier_schedule(), **over)

    ck = CheckpointManager(str(tmp_path), keep=0)
    run(steps=3, ckpt=ck, ckpt_every=3)

    def restore():
        g_b, *_ = _scene()
        err0 = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32),
                            g_b.trainable())
        return ck.restore(3, (g_b, init_opt(g_b), err0))

    (g3, opt3, err3), extra = restore()
    err_mag = max(float(np.abs(np.asarray(v)).max())
                  for v in jax.tree.leaves(err3))
    assert err_mag > 0.0          # the saved residual really is step state

    # timestep boundary: warm start handed the FULL (g, opt, err) tree
    _, _, l_warm = run(steps=6, warm_start=((g3, opt3, err3), extra, 3))
    # per-timestep-fresh: only (g, opt) — no residual exists to carry
    (g3b, opt3b, _), extrab = restore()
    g_f, _, l_fresh = run(steps=6, warm_start=((g3b, opt3b), extrab, 3))
    np.testing.assert_allclose(l_warm, l_fresh, rtol=0, atol=0)

    # same-timestep disk resume: residual restored -> trajectory diverges
    # once the first compressed grad lands (losses[0] predates the update)
    _, _, l_resume = run(steps=6, ckpt=ck)
    assert l_resume[0] == l_warm[0]
    assert l_resume[1:] != l_warm[1:], (l_resume, l_warm)
