"""Unit tests for optim/compress.py (gradient wire compression).

The module predates its first caller (core.distributed.make_gs_train_step
wires it behind GSTrainCfg.grad_compress); these tests pin its contract
directly so the driver integration can rely on it:

  * "none"  is an identity passthrough (same leaves, ratio 1.0)
  * "bf16"  is a stateless fp32->bf16->fp32 round-trip (ratio 2.0) whose
            per-element error is bounded by the bf16 unit roundoff
  * "int8"  quantises with a per-tensor scale (ratio 4.0) and CARRIES the
            residual: cumulative dequantised output over steps equals the
            cumulative true gradient minus only the final residual
  * unknown modes raise loudly
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim.compress import compress_grads


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    ka, kb = jax.random.split(k)
    return {
        "a": jax.random.normal(ka, (33, 7), jnp.float32),
        "b": 1e-3 * jax.random.normal(kb, (128,), jnp.float32),
    }


def test_none_is_identity():
    g = _tree()
    out, err, ratio = compress_grads(g, "none", err_state=None)
    assert ratio == 1.0
    assert err is None
    # identity, not a copy: the driver's "none" path must stay zero-cost
    assert out is g


def test_bf16_round_trip():
    g = _tree()
    out, err, ratio = compress_grads(g, "bf16", err_state=None)
    assert ratio == 2.0
    assert err is None           # stateless: no residual to carry
    for name in g:
        o, x = np.asarray(out[name]), np.asarray(g[name])
        assert o.dtype == np.float32   # decompressed back to f32
        # bf16 keeps f32's exponent; 8-bit mantissa -> relative error
        # <= 2^-9 per element (round-to-nearest unit roundoff)
        assert np.all(np.abs(o - x) <= np.abs(x) * 2.0 ** -8 + 1e-12)
        # and it actually quantised: exact only where bf16-representable
        assert o == pytest.approx(x, rel=2.0 ** -8)


def test_int8_error_feedback_carries_residual():
    g = _tree()
    # step 1: err_state=None must zeros-init internally
    d1, e1, ratio = compress_grads(g, "int8", err_state=None)
    assert ratio == 4.0
    for name in g:
        # per-tensor scale = max|g|/127 -> error <= scale/2 per element
        scale = float(np.abs(np.asarray(g[name])).max()) / 127.0
        assert np.abs(np.asarray(d1[name] - g[name])).max() <= 0.5 * scale \
            + 1e-7
        # residual is exactly what the wire dropped
        np.testing.assert_allclose(np.asarray(e1[name]),
                                   np.asarray(g[name] - d1[name]),
                                   rtol=0, atol=1e-7)
    # step 2 with the SAME gradient: the carried residual compensates, so
    # cumulative dequantised == cumulative true gradient - final residual
    # (the error-feedback invariant that makes long-run bias vanish)
    d2, e2, _ = compress_grads(g, "int8", err_state=e1)
    for name in g:
        lhs = np.asarray(d1[name] + d2[name] + e2[name])
        rhs = np.asarray(g[name] + g[name])
        np.testing.assert_allclose(lhs, rhs, rtol=0, atol=1e-5)


def test_int8_zero_init_matches_explicit_zeros():
    g = _tree(1)
    zeros = jax.tree.map(lambda x: jnp.zeros_like(x), g)
    d_none, e_none, _ = compress_grads(g, "int8", err_state=None)
    d_zero, e_zero, _ = compress_grads(g, "int8", err_state=zeros)
    for name in g:
        np.testing.assert_array_equal(np.asarray(d_none[name]),
                                      np.asarray(d_zero[name]))
        np.testing.assert_array_equal(np.asarray(e_none[name]),
                                      np.asarray(e_zero[name]))


def test_unknown_mode_raises():
    with pytest.raises(ValueError):
        compress_grads(_tree(), "fp4", err_state=None)
