"""Data substrate: volumes, isosurface extraction, token streams."""

import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # degraded fallback (see tests/_hyp.py)
    from _hyp import given, settings, st

from repro.data.isosurface import extract_isosurface, point_cloud_for
from repro.data.tokens import SyntheticTokens
from repro.data.volumes import VOLUMES, make_volume


@pytest.mark.parametrize("name", list(VOLUMES))
def test_volume_fields_finite_and_crossing(name):
    f, iso = make_volume(name, 32)
    assert f.shape == (32, 32, 32)
    assert np.isfinite(f).all()
    assert (f < iso).any() and (f > iso).any(), "iso must intersect volume"


def test_extract_isosurface_points_near_surface():
    f, iso = make_volume("sphere_shell", 48)
    pts, count = extract_isosurface(jnp.asarray(f), iso, max_points=5000)
    n = int(count)
    assert n > 500
    r = np.linalg.norm(np.asarray(pts[:n]) - 0.5, axis=1)
    # crossing points lie within one voxel of the r=0.35 shell
    assert np.abs(r - 0.35).max() < 2.0 / 48


def test_point_cloud_budget_and_determinism():
    p1, c1 = point_cloud_for("kingsnake", 3000)
    p2, c2 = point_cloud_for("kingsnake", 3000)
    np.testing.assert_array_equal(p1, p2)
    assert abs(len(p1) - 3000) <= 3000 * 0.5
    assert c1.shape == p1.shape
    assert (c1 >= 0).all() and (c1 <= 1).all()


def test_tokens_deterministic_and_sharded():
    ds = SyntheticTokens(vocab=1000, seq=32, global_batch=8, seed=3)
    a = ds.batch(5)
    b = ds.batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(a["tokens"][:, 1:], a["labels"][:, :-1])
    # sharded loading covers the global batch exactly
    sh0 = ds.batch(5, shard=0, n_shards=2)
    sh1 = ds.batch(5, shard=1, n_shards=2)
    glob = np.concatenate([sh0["tokens"], sh1["tokens"]])
    np.testing.assert_array_equal(glob, a["tokens"])
    # different steps differ
    c = ds.batch(6)
    assert not np.array_equal(a["tokens"], c["tokens"])


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10_000), st.integers(1, 4))
def test_tokens_in_range(step, shards):
    ds = SyntheticTokens(vocab=512, seq=16, global_batch=4 * shards)
    for s in range(shards):
        b = ds.batch(step, shard=s, n_shards=shards)
        t = np.asarray(b["tokens"])
        assert t.min() >= 0 and t.max() < 512
