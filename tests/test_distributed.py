"""Distributed GS step: shard_map correctness on forced multi-device CPU.

The key invariant: the mesh-distributed forward/step computes the SAME math
as the single-device pipeline (modulo float association) — gaussian-parallel
all-gather + pixel-parallel strips are an execution strategy, not a model
change.  Runs in a subprocess so the 8-device XLA flag doesn't leak.
"""

import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, r"%(src)s")
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.cameras import orbital_rig, select
from repro.core.distributed import (gs_shardings, make_gs_forward,
                                    make_gs_train_step)
from repro.core.gaussians import from_points
from repro.core.masking import tile_l1_dssim_loss
from repro.core.render import render_tiles
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg
from repro.data.isosurface import point_cloud_for

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
Pn = 2
N = 256                      # divisible by data axis
res, K = 32, 16
grid = TileGrid(res, res, 8, 16)
T = grid.n_tiles
assert T %% 2 == 0

pts, cols = point_cloud_for("sphere_shell", 2 * N)
pts, cols = pts[: 2 * N], cols[: 2 * N]
cams = orbital_rig(2, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
cam = select(cams, 0)

# two partitions = two halves of the cloud (owner split irrelevant here)
g_all = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.8)

def part(i):
    sl = slice(i * N, (i + 1) * N)
    return jax.tree.map(lambda x: x[sl], g_all)

g_batched = jax.tree.map(lambda *xs: jnp.stack(xs), part(0), part(1))

# ---- reference: single-device per-partition renders + loss ----
ref_tiles = []
for i in range(Pn):
    tiles, _, _ = render_tiles(part(i), cam, grid, K=K, impl="ref")
    ref_tiles.append(tiles)
ref_tiles = jnp.concatenate(ref_tiles)              # (P*T, 4, th, tw)

gt = jnp.clip(ref_tiles[:, :3] + 0.05, 0, 1)
mask = jnp.ones((Pn * T, grid.tile_h, grid.tile_w), bool)
ref_loss = tile_l1_dssim_loss(ref_tiles[:, :3], gt, mask, win_size=7)

# ---- distributed: shard_map forward ----
# tolerance note: the seed pinned these at 2e-4 to absorb the tie-break
# divergence (equal-depth splats at the K boundary could differ between the
# strip-local and global top-k merges on some views).  The two-key
# (score, splat-index) merge makes assignment merge-order invariant, so the
# comparison is now float-reassociation only.
fwd = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True)
g_sh, opt_sh, b_sh = gs_shardings(mesh)
g_dev = jax.device_put(g_batched, g_sh)
loss, tiles = jax.jit(fwd)(g_dev, cam, gt, mask)
np.testing.assert_allclose(np.asarray(tiles), np.asarray(ref_tiles),
                           rtol=1e-6, atol=1e-6)
np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-4, atol=1e-5)
print("FWD-MATCH")

# ---- optimized variants (§Perf GS hillclimb) stay faithful ----
# strip prefilter with budget 1.0 is exact (pure reordering)
fwd_strip = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                            strip_budget=127.0 / 128.0)
_, tiles_s = jax.jit(fwd_strip)(g_dev, cam, gt, mask)
np.testing.assert_allclose(np.asarray(tiles_s), np.asarray(ref_tiles),
                           rtol=1e-6, atol=1e-6)
# split bf16 gather: conic/rgb rounding only (image-level agreement)
fwd_split = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                            gather_mode="split", strip_budget=127.0 / 128.0)
loss_sp, tiles_sp = jax.jit(fwd_split)(g_dev, cam, gt, mask)
err = np.abs(np.asarray(tiles_sp[:, :3]) - np.asarray(ref_tiles[:, :3]))
assert err.max() < 5e-2 and err.mean() < 2e-3, (err.max(), err.mean())
assert abs(float(loss_sp) - float(ref_loss)) < 2e-3
print("OPT-MATCH")

# ---- tiered (variable-K) forward: the strip-local occupancy binning must
# reproduce the single-device dense tiles exactly (caps cover -> exact, and
# single-device tiered == single-device dense is pinned in
# test_tiered_raster.py, so this transitively pins distributed tiered ==
# single-device tiered) ----
fwd_tier = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                           k_tiers=(4, 8, K))
_, tiles_t = jax.jit(fwd_tier)(g_dev, cam, gt, mask)
np.testing.assert_allclose(np.asarray(tiles_t), np.asarray(ref_tiles),
                           rtol=1e-6, atol=1e-6)
# explicit static caps + strip prefilter compose with tiering
fwd_tier2 = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                            k_tiers=(4, 8, K), tier_caps=(8, 8, 8),
                            strip_budget=127.0 / 128.0)
_, tiles_t2 = jax.jit(fwd_tier2)(g_dev, cam, gt, mask)
np.testing.assert_allclose(np.asarray(tiles_t2), np.asarray(ref_tiles),
                           rtol=1e-6, atol=1e-6)
# overflow surfacing: generous caps report 0; starved caps FIRE the counter
# instead of silently rendering dropped tiles as background
_, ov0 = jax.jit(make_gs_forward(mesh, grid, K=K, impl="ref",
                                 k_tiers=(4, 8, K),
                                 return_overflow=True))(g_dev, cam, gt, mask)
assert int(ov0) == 0, int(ov0)
_, ov1 = jax.jit(make_gs_forward(mesh, grid, K=K, impl="ref",
                                 k_tiers=(4, 8, K), tier_caps=(1, 0, 0),
                                 return_overflow=True))(g_dev, cam, gt, mask)
assert int(ov1) > 0, int(ov1)
print("TIER-MATCH")

# ---- distributed train step: loss decreases, state stays sharded ----
from repro.core.train import GSOptState
step = make_gs_train_step(mesh, GSTrainCfg(K=K, lr_colors=5e-2), grid,
                          extent=1.0, impl="ref")
tr = {k: getattr(g_batched, k) for k in
      ("means", "log_scales", "quats", "opacity_logit", "colors")}
opt = GSOptState(
    m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    step=jnp.int32(0),
    grad_accum=jnp.zeros((Pn, N)), grad_count=jnp.zeros((Pn, N)))
opt = jax.device_put(opt, opt_sh)
batch = {"gt_tiles": jax.device_put(gt, b_sh["gt_tiles"]),
         "mask_tiles": jax.device_put(mask, b_sh["mask_tiles"]),
         "cam": cam}
g_cur, losses = g_dev, []
for i in range(8):
    g_cur, opt, l = step(g_cur, opt, batch)
    losses.append(float(l))
assert losses[-1] < losses[0], losses
assert g_cur.means.sharding.num_devices == 8
print("STEP-OK", round(losses[0], 5), "->", round(losses[-1], 5))
"""


@pytest.mark.slow
def test_distributed_matches_single_device(tmp_path):
    code = SCRIPT % {"src": SRC}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "FWD-MATCH" in out.stdout
    assert "OPT-MATCH" in out.stdout
    assert "TIER-MATCH" in out.stdout
    assert "STEP-OK" in out.stdout


VIEWS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, r"%(src)s")
import jax, jax.numpy as jnp
import numpy as np

from repro.core.cameras import orbital_rig, select
from repro.core.distributed import (gs_shardings, make_gs_forward,
                                    make_gs_train_step)
from repro.core.gaussians import from_points
from repro.core.render import render_tiles
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, GSOptState
from repro.data.isosurface import point_cloud_for

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
Pn, N, res, K, V = 2, 256, 32, 16, 3
grid = TileGrid(res, res, 8, 16)
T = grid.n_tiles

pts, cols = point_cloud_for("sphere_shell", 2 * N)
pts, cols = pts[: 2 * N], cols[: 2 * N]
cams = orbital_rig(V, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
g_all = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.8)
part = lambda i: jax.tree.map(lambda x: x[i * N:(i + 1) * N], g_all)
g_batched = jax.tree.map(lambda *xs: jnp.stack(xs), part(0), part(1))

# reference: single-device per-view, per-partition tiles
ref = []
for v in range(V):
    per_p = [render_tiles(part(i), select(cams, v), grid, K=K, impl="ref")[0]
             for i in range(Pn)]
    ref.append(jnp.concatenate(per_p))
ref = jnp.stack(ref)                                 # (V, P*T, 4, th, tw)

gt = jnp.clip(ref[:, :, :3] + 0.05, 0, 1)
mask = jnp.ones((V, Pn * T, grid.tile_h, grid.tile_w), bool)
cam_b = select(cams, jnp.arange(V))

# ---- view-batched forward: tiles per view match the per-view reference ----
fwd = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True, views=V)
g_sh, _, b_sh = gs_shardings(mesh, views=V)
g_dev = jax.device_put(g_batched, g_sh)
loss, tiles = jax.jit(fwd)(g_dev, cam_b,
                           jax.device_put(gt, b_sh["gt_tiles"]),
                           jax.device_put(mask, b_sh["mask_tiles"]))
np.testing.assert_allclose(np.asarray(tiles), np.asarray(ref),
                           rtol=1e-6, atol=1e-6)
print("VFWD-MATCH")

# tiered dispatch under the view fold: per-(view, partition, strip) binning
# must still reproduce the per-view dense tiles exactly
fwd_t = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                        views=V, k_tiers=(4, 8, K))
_, tiles_t = jax.jit(fwd_t)(g_dev, cam_b, gt, mask)
np.testing.assert_allclose(np.asarray(tiles_t), np.asarray(ref),
                           rtol=1e-6, atol=1e-6)
print("VTIER-MATCH")

# heterogeneous per-view masks: the loss must be the MEAN of per-view
# losses (train.py's equal-view weighting), not a pixel-count-weighted pool
from repro.core.masking import tile_l1_dssim_loss
mask_h = mask.at[0].set(False).at[0, :, :2].set(True)   # view 0 nearly empty
loss_h = jax.jit(make_gs_forward(mesh, grid, K=K, impl="ref", views=V))(
    g_dev, cam_b, gt, mask_h)
want = np.mean([float(tile_l1_dssim_loss(ref[v][:, :3], gt[v], mask_h[v],
                                         win_size=7)) for v in range(V)])
np.testing.assert_allclose(float(loss_h), want, rtol=1e-4, atol=1e-5)
print("VLOSS-MEAN")

# perf variants stay faithful under the view axis
fwd_s = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                        views=V, strip_budget=127.0 / 128.0)
_, tiles_s = jax.jit(fwd_s)(g_dev, cam_b, gt, mask)
np.testing.assert_allclose(np.asarray(tiles_s), np.asarray(ref),
                           rtol=1e-6, atol=1e-6)
fwd_sp = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                         views=V, gather_mode="split")
_, tiles_sp = jax.jit(fwd_sp)(g_dev, cam_b, gt, mask)
err = np.abs(np.asarray(tiles_sp[:, :, :3]) - np.asarray(ref[:, :, :3]))
assert err.max() < 5e-2, err.max()
print("VOPT-MATCH")

# ---- view-batched train step: loss decreases, state stays sharded ----
step = make_gs_train_step(mesh, GSTrainCfg(K=K, lr_colors=5e-2), grid,
                          extent=1.0, impl="ref", views=V)
_, opt_sh, _ = gs_shardings(mesh, views=V)
tr = {k: getattr(g_batched, k) for k in
      ("means", "log_scales", "quats", "opacity_logit", "colors")}
opt = GSOptState(
    m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    step=jnp.int32(0),
    grad_accum=jnp.zeros((Pn, N)), grad_count=jnp.zeros((Pn, N)))
opt = jax.device_put(opt, opt_sh)
batch = {"gt_tiles": jax.device_put(gt, b_sh["gt_tiles"]),
         "mask_tiles": jax.device_put(mask, b_sh["mask_tiles"]),
         "cam": cam_b}
g_cur, losses = g_dev, []
for i in range(8):
    g_cur, opt, l = step(g_cur, opt, batch)
    losses.append(float(l))
assert losses[-1] < losses[0], losses
assert g_cur.means.sharding.num_devices == 8
print("VSTEP-OK", round(losses[0], 5), "->", round(losses[-1], 5))
"""


@pytest.mark.slow
def test_view_batched_distributed_matches_per_view(tmp_path):
    """views=V path: vmapped projection + view-axis fold must reproduce the
    per-view single-device tiles, under all gather/strip variants."""
    code = VIEWS_SCRIPT % {"src": SRC}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "VFWD-MATCH" in out.stdout
    assert "VTIER-MATCH" in out.stdout
    assert "VLOSS-MEAN" in out.stdout
    assert "VOPT-MATCH" in out.stdout
    assert "VSTEP-OK" in out.stdout


MESH2D_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, r"%(src)s")
import jax, jax.numpy as jnp
import numpy as np

from repro.core.cameras import orbital_rig, select
from repro.core.distributed import (gs_shardings, make_gs_forward,
                                    make_gs_train_step)
from repro.core.gaussians import from_points
from repro.core.masking import tile_l1_dssim_loss
from repro.core.render import render_tiles
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, GSOptState, group_lrs
from repro.data.isosurface import point_cloud_for

Pn, N, res, K, V = 2, 256, 32, 16, 2
grid = TileGrid(res, res, 8, 16)
T = grid.n_tiles
pts, cols = point_cloud_for("sphere_shell", 2 * N)
pts, cols = pts[: 2 * N], cols[: 2 * N]
cams = orbital_rig(V, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
cam_b = select(cams, jnp.arange(V))
g_all = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.8)
part = lambda i: jax.tree.map(lambda x: x[i * N:(i + 1) * N], g_all)
g_b = jax.tree.map(lambda *xs: jnp.stack(xs), part(0), part(1))

ref = []
for v in range(V):
    per_p = [render_tiles(part(i), select(cams, v), grid, K=K, impl="ref")[0]
             for i in range(Pn)]
    ref.append(jnp.concatenate(per_p))
ref = jnp.stack(ref)                                 # (V, P*T, 4, th, tw)
gt = jnp.clip(ref[:, :, :3] + 0.05, 0, 1)
mask = jnp.ones((V, Pn * T, grid.tile_h, grid.tile_w), bool)

mesh2d = jax.make_mesh((2, 2), ("part", "view"))
mesh1d = jax.make_mesh((2,), ("part",))
cfg = GSTrainCfg(K=K, lr_colors=5e-2)

# ---- 2-D forward: view-sharded tiles/loss match the per-view reference,
# tiered on, overflow 0 ----
fwd = make_gs_forward(mesh2d, grid, K=K, impl="ref", return_tiles=True,
                      views=V, k_tiers=(4, 8, K), return_overflow=True)
g_sh, opt_sh, b_sh = gs_shardings(mesh2d, views=V)
g_dev = jax.device_put(g_b, g_sh)
loss, tiles, ov = jax.jit(fwd)(g_dev,
                               jax.device_put(cam_b, b_sh["cam"]),
                               jax.device_put(gt, b_sh["gt_tiles"]),
                               jax.device_put(mask, b_sh["mask_tiles"]))
np.testing.assert_allclose(np.asarray(tiles), np.asarray(ref),
                           rtol=1e-6, atol=1e-6)
want = np.mean([float(tile_l1_dssim_loss(ref[v][:, :3], gt[v], mask[v],
                                         win_size=7)) for v in range(V)])
np.testing.assert_allclose(float(loss), want, rtol=1e-4, atol=1e-5)
assert int(ov) == 0, int(ov)
print("M2D-FWD-MATCH")

# ---- single-device reference STEP: same tile loss + Adam math, by hand ----
def ref_step(kt):
    lrs = group_lrs(cfg, 1.0)
    def loss_fn(tr):
        g = g_b.with_trainable(tr)
        ls = []
        for v in range(V):
            per_p = [render_tiles(jax.tree.map(lambda x: x[i], g),
                                  select(cams, v), grid, K=K, impl="ref",
                                  k_tiers=kt)[0] for i in range(Pn)]
            t = jnp.concatenate(per_p)
            ls.append(tile_l1_dssim_loss(t[:, :3], gt[v], mask[v],
                                         win_size=7))
        return jnp.stack(ls).mean()
    tr = {k: getattr(g_b, k) for k in
          ("means", "log_scales", "quats", "opacity_logit", "colors")}
    loss, grads = jax.value_and_grad(loss_fn)(tr)
    out = {}
    for k in tr:
        gr = grads[k].astype(jnp.float32)
        m = (1 - cfg.b1) * gr
        v_ = (1 - cfg.b2) * gr * gr
        d = (m / (1 - cfg.b1)) / (jnp.sqrt(v_ / (1 - cfg.b2)) + cfg.eps)
        out[k] = tr[k] - lrs[k] * d
    return {k: np.asarray(x) for k, x in out.items()}, float(loss)

def dist_step(mesh, kt):
    step = make_gs_train_step(mesh, cfg, grid, extent=1.0, impl="ref",
                              views=V, k_tiers=kt)
    gsh, osh, bsh = gs_shardings(mesh, views=V)
    tr = {k: getattr(g_b, k) for k in
          ("means", "log_scales", "quats", "opacity_logit", "colors")}
    opt = GSOptState(
        m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
        v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
        step=jnp.int32(0),
        grad_accum=jnp.zeros((Pn, N)), grad_count=jnp.zeros((Pn, N)))
    batch = {"gt_tiles": jax.device_put(gt, bsh["gt_tiles"]),
             "mask_tiles": jax.device_put(mask, bsh["mask_tiles"]),
             "cam": jax.device_put(cam_b, bsh["cam"])}
    g1, _, l = step(jax.device_put(g_b, gsh), jax.device_put(opt, osh),
                    batch)
    return {k: np.asarray(x) for k, x in g1.trainable().items()}, float(l)

# the key invariant: sharding the view axis is an execution strategy, not a
# model change — 2-D mesh step == 1-D mesh step == single-device step,
# dense AND tiered
for kt in (None, (4, 8, K)):
    r, rl = ref_step(kt)
    p1, l1 = dist_step(mesh1d, kt)
    p2, l2 = dist_step(mesh2d, kt)
    for k in r:
        np.testing.assert_allclose(p1[k], r[k], rtol=1e-6, atol=1e-6,
                                   err_msg=f"1-D mesh {k} kt={kt}")
        np.testing.assert_allclose(p2[k], r[k], rtol=1e-6, atol=1e-6,
                                   err_msg=f"2-D mesh {k} kt={kt}")
    np.testing.assert_allclose([l1, l2], rl, rtol=1e-5, atol=1e-6)
print("M2D-STEP-MATCH")

# tiered-by-DEFAULT cfg (k_tiers resolved from GSTrainCfg, caps fall back
# to the always-exact strip size) must equal the dense escape hatch
p_auto, _ = dist_step(mesh2d, cfg.resolved_k_tiers())
cfg_dense = GSTrainCfg(K=K, lr_colors=5e-2, dense_k=K)
assert cfg_dense.resolved_k_tiers() is None
step_d = make_gs_train_step(mesh2d, cfg_dense, grid, extent=1.0,
                            impl="ref", views=V)
gsh, osh, bsh = gs_shardings(mesh2d, views=V)
tr = {k: getattr(g_b, k) for k in
      ("means", "log_scales", "quats", "opacity_logit", "colors")}
opt = GSOptState(
    m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    step=jnp.int32(0),
    grad_accum=jnp.zeros((Pn, N)), grad_count=jnp.zeros((Pn, N)))
batch = {"gt_tiles": jax.device_put(gt, bsh["gt_tiles"]),
         "mask_tiles": jax.device_put(mask, bsh["mask_tiles"]),
         "cam": jax.device_put(cam_b, bsh["cam"])}
g_d, _, _ = step_d(jax.device_put(g_b, gsh), jax.device_put(opt, osh),
                   batch)
for k, x in g_d.trainable().items():
    np.testing.assert_allclose(p_auto[k], np.asarray(x),
                               rtol=1e-6, atol=1e-6, err_msg=k)
print("M2D-DEFAULT-TIERED")

# odd views must be rejected loudly, not silently truncated
try:
    make_gs_forward(mesh2d, grid, K=K, impl="ref", views=3)
except ValueError as e:
    assert "view" in str(e)
    print("M2D-DIVISIBILITY")
"""


@pytest.mark.slow
def test_2d_mesh_step_matches_1d_and_single_device(tmp_path):
    """The ("part", "view") 2-D mesh: view-sharded forward tiles/loss match
    the per-view reference, and the train step (params after one Adam
    update) matches the 1-D mesh and a hand-built single-device step at
    1e-6 — dense and tiered, overflow 0, tiered-by-default cfg included."""
    code = MESH2D_SCRIPT % {"src": SRC}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "M2D-FWD-MATCH" in out.stdout
    assert "M2D-STEP-MATCH" in out.stdout
    assert "M2D-DEFAULT-TIERED" in out.stdout
    assert "M2D-DIVISIBILITY" in out.stdout
