"""Distributed GS step: shard_map correctness on forced multi-device CPU.

The key invariant: the mesh-distributed forward/step computes the SAME math
as the single-device pipeline (modulo float association) — gaussian-parallel
all-gather + pixel-parallel strips are an execution strategy, not a model
change.  Runs in a subprocess so the 8-device XLA flag doesn't leak.
"""

import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_tile_view_batches_masks_none_excludes_grid_padding():
    """masks=None means "every IMAGE pixel" — grid padding (resolution not
    a tile multiple) must be masked OFF, matching the single-device
    full-image loss, which never sees pad pixels."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.distributed import _tile_view_batches
    from repro.core.tiling import TileGrid

    grid = TileGrid(20, 12, 8, 16)      # pads to 16 x 32
    gts = np.random.default_rng(0).random((1, 2, 12, 20, 3)).astype("f4")
    gt_t, mask_t = _tile_view_batches(jnp.asarray(gts), None, grid)
    assert gt_t.shape == (2, grid.n_tiles, 3, 8, 16)
    assert mask_t.shape == (2, grid.n_tiles, 8, 16)
    assert int(mask_t.sum()) == 2 * 12 * 20      # image pixels only
    # explicit all-ones masks land on the identical tiling
    ones = jnp.ones((1, 2, 12, 20), bool)
    _, mask_t2 = _tile_view_batches(jnp.asarray(gts), ones, grid)
    np.testing.assert_array_equal(mask_t, mask_t2)

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, r"%(src)s")
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.cameras import orbital_rig, select
from repro.core.distributed import (gs_shardings, make_gs_forward,
                                    make_gs_train_step)
from repro.core.gaussians import from_points
from repro.core.masking import tile_l1_dssim_loss
from repro.core.render import render_tiles
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg
from repro.data.isosurface import point_cloud_for

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
Pn = 2
N = 256                      # divisible by data axis
res, K = 32, 16
grid = TileGrid(res, res, 8, 16)
T = grid.n_tiles
assert T %% 2 == 0

pts, cols = point_cloud_for("sphere_shell", 2 * N)
pts, cols = pts[: 2 * N], cols[: 2 * N]
cams = orbital_rig(2, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
cam = select(cams, 0)

# two partitions = two halves of the cloud (owner split irrelevant here)
g_all = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.8)

def part(i):
    sl = slice(i * N, (i + 1) * N)
    return jax.tree.map(lambda x: x[sl], g_all)

g_batched = jax.tree.map(lambda *xs: jnp.stack(xs), part(0), part(1))

# ---- reference: single-device per-partition renders + loss ----
ref_tiles = []
for i in range(Pn):
    tiles, _, _ = render_tiles(part(i), cam, grid, K=K, impl="ref")
    ref_tiles.append(tiles)
ref_tiles = jnp.concatenate(ref_tiles)              # (P*T, 4, th, tw)

gt = jnp.clip(ref_tiles[:, :3] + 0.05, 0, 1)
mask = jnp.ones((Pn * T, grid.tile_h, grid.tile_w), bool)
ref_loss = tile_l1_dssim_loss(ref_tiles[:, :3], gt, mask, win_size=7)

# ---- distributed: shard_map forward ----
# tolerance note: the seed pinned these at 2e-4 to absorb the tie-break
# divergence (equal-depth splats at the K boundary could differ between the
# strip-local and global top-k merges on some views).  The two-key
# (score, splat-index) merge makes assignment merge-order invariant, so the
# comparison is now float-reassociation only.
fwd = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True)
g_sh, opt_sh, b_sh = gs_shardings(mesh)
g_dev = jax.device_put(g_batched, g_sh)
loss, tiles = jax.jit(fwd)(g_dev, cam, gt, mask)
np.testing.assert_allclose(np.asarray(tiles), np.asarray(ref_tiles),
                           rtol=1e-6, atol=1e-6)
np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-4, atol=1e-5)
print("FWD-MATCH")

# ---- optimized variants (§Perf GS hillclimb) stay faithful ----
# strip prefilter with budget 1.0 is exact (pure reordering)
fwd_strip = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                            strip_budget=127.0 / 128.0)
_, tiles_s = jax.jit(fwd_strip)(g_dev, cam, gt, mask)
np.testing.assert_allclose(np.asarray(tiles_s), np.asarray(ref_tiles),
                           rtol=1e-6, atol=1e-6)
# split bf16 gather: conic/rgb rounding only (image-level agreement)
fwd_split = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                            gather_mode="split", strip_budget=127.0 / 128.0)
loss_sp, tiles_sp = jax.jit(fwd_split)(g_dev, cam, gt, mask)
err = np.abs(np.asarray(tiles_sp[:, :3]) - np.asarray(ref_tiles[:, :3]))
assert err.max() < 5e-2 and err.mean() < 2e-3, (err.max(), err.mean())
assert abs(float(loss_sp) - float(ref_loss)) < 2e-3
print("OPT-MATCH")

# ---- tiered (variable-K) forward: the strip-local occupancy binning must
# reproduce the single-device dense tiles exactly (caps cover -> exact, and
# single-device tiered == single-device dense is pinned in
# test_tiered_raster.py, so this transitively pins distributed tiered ==
# single-device tiered) ----
fwd_tier = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                           k_tiers=(4, 8, K))
_, tiles_t = jax.jit(fwd_tier)(g_dev, cam, gt, mask)
np.testing.assert_allclose(np.asarray(tiles_t), np.asarray(ref_tiles),
                           rtol=1e-6, atol=1e-6)
# explicit static caps + strip prefilter compose with tiering
fwd_tier2 = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                            k_tiers=(4, 8, K), tier_caps=(8, 8, 8),
                            strip_budget=127.0 / 128.0)
_, tiles_t2 = jax.jit(fwd_tier2)(g_dev, cam, gt, mask)
np.testing.assert_allclose(np.asarray(tiles_t2), np.asarray(ref_tiles),
                           rtol=1e-6, atol=1e-6)
# overflow surfacing: generous caps report 0; starved caps FIRE the counter
# instead of silently rendering dropped tiles as background
_, ov0 = jax.jit(make_gs_forward(mesh, grid, K=K, impl="ref",
                                 k_tiers=(4, 8, K),
                                 return_overflow=True))(g_dev, cam, gt, mask)
assert int(ov0) == 0, int(ov0)
_, ov1 = jax.jit(make_gs_forward(mesh, grid, K=K, impl="ref",
                                 k_tiers=(4, 8, K), tier_caps=(1, 0, 0),
                                 return_overflow=True))(g_dev, cam, gt, mask)
assert int(ov1) > 0, int(ov1)
print("TIER-MATCH")

# ---- distributed train step: loss decreases, state stays sharded ----
from repro.core.train import GSOptState
step = make_gs_train_step(mesh, GSTrainCfg(K=K, lr_colors=5e-2), grid,
                          extent=1.0, impl="ref")
tr = {k: getattr(g_batched, k) for k in
      ("means", "log_scales", "quats", "opacity_logit", "colors")}
opt = GSOptState(
    m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    step=jnp.int32(0),
    grad_accum=jnp.zeros((Pn, N)), grad_count=jnp.zeros((Pn, N)))
opt = jax.device_put(opt, opt_sh)
batch = {"gt_tiles": jax.device_put(gt, b_sh["gt_tiles"]),
         "mask_tiles": jax.device_put(mask, b_sh["mask_tiles"]),
         "cam": cam}
g_cur, losses = g_dev, []
for i in range(8):
    g_cur, opt, l = step(g_cur, opt, batch)
    losses.append(float(l))
assert losses[-1] < losses[0], losses
assert g_cur.means.sharding.num_devices == 8
print("STEP-OK", round(losses[0], 5), "->", round(losses[-1], 5))
"""


@pytest.mark.slow
def test_distributed_matches_single_device(tmp_path):
    code = SCRIPT % {"src": SRC}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "FWD-MATCH" in out.stdout
    assert "OPT-MATCH" in out.stdout
    assert "TIER-MATCH" in out.stdout
    assert "STEP-OK" in out.stdout


VIEWS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, r"%(src)s")
import jax, jax.numpy as jnp
import numpy as np

from repro.core.cameras import orbital_rig, select
from repro.core.distributed import (gs_shardings, make_gs_forward,
                                    make_gs_train_step)
from repro.core.gaussians import from_points
from repro.core.render import render_tiles
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, GSOptState
from repro.data.isosurface import point_cloud_for

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
Pn, N, res, K, V = 2, 256, 32, 16, 3
grid = TileGrid(res, res, 8, 16)
T = grid.n_tiles

pts, cols = point_cloud_for("sphere_shell", 2 * N)
pts, cols = pts[: 2 * N], cols[: 2 * N]
cams = orbital_rig(V, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
g_all = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.8)
part = lambda i: jax.tree.map(lambda x: x[i * N:(i + 1) * N], g_all)
g_batched = jax.tree.map(lambda *xs: jnp.stack(xs), part(0), part(1))

# reference: single-device per-view, per-partition tiles
ref = []
for v in range(V):
    per_p = [render_tiles(part(i), select(cams, v), grid, K=K, impl="ref")[0]
             for i in range(Pn)]
    ref.append(jnp.concatenate(per_p))
ref = jnp.stack(ref)                                 # (V, P*T, 4, th, tw)

gt = jnp.clip(ref[:, :, :3] + 0.05, 0, 1)
mask = jnp.ones((V, Pn * T, grid.tile_h, grid.tile_w), bool)
cam_b = select(cams, jnp.arange(V))

# ---- view-batched forward: tiles per view match the per-view reference ----
fwd = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True, views=V)
g_sh, _, b_sh = gs_shardings(mesh, views=V)
g_dev = jax.device_put(g_batched, g_sh)
loss, tiles = jax.jit(fwd)(g_dev, cam_b,
                           jax.device_put(gt, b_sh["gt_tiles"]),
                           jax.device_put(mask, b_sh["mask_tiles"]))
np.testing.assert_allclose(np.asarray(tiles), np.asarray(ref),
                           rtol=1e-6, atol=1e-6)
print("VFWD-MATCH")

# tiered dispatch under the view fold: per-(view, partition, strip) binning
# must still reproduce the per-view dense tiles exactly
fwd_t = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                        views=V, k_tiers=(4, 8, K))
_, tiles_t = jax.jit(fwd_t)(g_dev, cam_b, gt, mask)
np.testing.assert_allclose(np.asarray(tiles_t), np.asarray(ref),
                           rtol=1e-6, atol=1e-6)
print("VTIER-MATCH")

# heterogeneous per-view masks: the loss must be the MEAN of per-view
# losses (train.py's equal-view weighting), not a pixel-count-weighted pool
from repro.core.masking import tile_l1_dssim_loss
mask_h = mask.at[0].set(False).at[0, :, :2].set(True)   # view 0 nearly empty
loss_h = jax.jit(make_gs_forward(mesh, grid, K=K, impl="ref", views=V))(
    g_dev, cam_b, gt, mask_h)
want = np.mean([float(tile_l1_dssim_loss(ref[v][:, :3], gt[v], mask_h[v],
                                         win_size=7)) for v in range(V)])
np.testing.assert_allclose(float(loss_h), want, rtol=1e-4, atol=1e-5)
print("VLOSS-MEAN")

# perf variants stay faithful under the view axis
fwd_s = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                        views=V, strip_budget=127.0 / 128.0)
_, tiles_s = jax.jit(fwd_s)(g_dev, cam_b, gt, mask)
np.testing.assert_allclose(np.asarray(tiles_s), np.asarray(ref),
                           rtol=1e-6, atol=1e-6)
fwd_sp = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                         views=V, gather_mode="split")
_, tiles_sp = jax.jit(fwd_sp)(g_dev, cam_b, gt, mask)
err = np.abs(np.asarray(tiles_sp[:, :, :3]) - np.asarray(ref[:, :, :3]))
assert err.max() < 5e-2, err.max()
print("VOPT-MATCH")

# ---- view-batched train step: loss decreases, state stays sharded ----
step = make_gs_train_step(mesh, GSTrainCfg(K=K, lr_colors=5e-2), grid,
                          extent=1.0, impl="ref", views=V)
_, opt_sh, _ = gs_shardings(mesh, views=V)
tr = {k: getattr(g_batched, k) for k in
      ("means", "log_scales", "quats", "opacity_logit", "colors")}
opt = GSOptState(
    m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    step=jnp.int32(0),
    grad_accum=jnp.zeros((Pn, N)), grad_count=jnp.zeros((Pn, N)))
opt = jax.device_put(opt, opt_sh)
batch = {"gt_tiles": jax.device_put(gt, b_sh["gt_tiles"]),
         "mask_tiles": jax.device_put(mask, b_sh["mask_tiles"]),
         "cam": cam_b}
g_cur, losses = g_dev, []
for i in range(8):
    g_cur, opt, l = step(g_cur, opt, batch)
    losses.append(float(l))
assert losses[-1] < losses[0], losses
assert g_cur.means.sharding.num_devices == 8
print("VSTEP-OK", round(losses[0], 5), "->", round(losses[-1], 5))
"""


@pytest.mark.slow
def test_view_batched_distributed_matches_per_view(tmp_path):
    """views=V path: vmapped projection + view-axis fold must reproduce the
    per-view single-device tiles, under all gather/strip variants."""
    code = VIEWS_SCRIPT % {"src": SRC}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "VFWD-MATCH" in out.stdout
    assert "VTIER-MATCH" in out.stdout
    assert "VLOSS-MEAN" in out.stdout
    assert "VOPT-MATCH" in out.stdout
    assert "VSTEP-OK" in out.stdout


MESH2D_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, r"%(src)s")
import jax, jax.numpy as jnp
import numpy as np

from repro.core.cameras import orbital_rig, select
from repro.core.distributed import (gs_shardings, make_gs_forward,
                                    make_gs_train_step)
from repro.core.gaussians import from_points
from repro.core.masking import tile_l1_dssim_loss
from repro.core.render import render_tiles
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, GSOptState, group_lrs
from repro.data.isosurface import point_cloud_for

Pn, N, res, K, V = 2, 256, 32, 16, 2
grid = TileGrid(res, res, 8, 16)
T = grid.n_tiles
pts, cols = point_cloud_for("sphere_shell", 2 * N)
pts, cols = pts[: 2 * N], cols[: 2 * N]
cams = orbital_rig(V, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
cam_b = select(cams, jnp.arange(V))
g_all = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.8)
part = lambda i: jax.tree.map(lambda x: x[i * N:(i + 1) * N], g_all)
g_b = jax.tree.map(lambda *xs: jnp.stack(xs), part(0), part(1))

ref = []
for v in range(V):
    per_p = [render_tiles(part(i), select(cams, v), grid, K=K, impl="ref")[0]
             for i in range(Pn)]
    ref.append(jnp.concatenate(per_p))
ref = jnp.stack(ref)                                 # (V, P*T, 4, th, tw)
gt = jnp.clip(ref[:, :, :3] + 0.05, 0, 1)
mask = jnp.ones((V, Pn * T, grid.tile_h, grid.tile_w), bool)

mesh2d = jax.make_mesh((2, 2), ("part", "view"))
mesh1d = jax.make_mesh((2,), ("part",))
cfg = GSTrainCfg(K=K, lr_colors=5e-2)

# ---- 2-D forward: view-sharded tiles/loss match the per-view reference,
# tiered on, overflow 0 ----
fwd = make_gs_forward(mesh2d, grid, K=K, impl="ref", return_tiles=True,
                      views=V, k_tiers=(4, 8, K), return_overflow=True)
g_sh, opt_sh, b_sh = gs_shardings(mesh2d, views=V)
g_dev = jax.device_put(g_b, g_sh)
loss, tiles, ov = jax.jit(fwd)(g_dev,
                               jax.device_put(cam_b, b_sh["cam"]),
                               jax.device_put(gt, b_sh["gt_tiles"]),
                               jax.device_put(mask, b_sh["mask_tiles"]))
np.testing.assert_allclose(np.asarray(tiles), np.asarray(ref),
                           rtol=1e-6, atol=1e-6)
want = np.mean([float(tile_l1_dssim_loss(ref[v][:, :3], gt[v], mask[v],
                                         win_size=7)) for v in range(V)])
np.testing.assert_allclose(float(loss), want, rtol=1e-4, atol=1e-5)
assert int(ov) == 0, int(ov)
print("M2D-FWD-MATCH")

# ---- single-device reference STEP: same tile loss + Adam math, by hand ----
def ref_step(kt):
    lrs = group_lrs(cfg, 1.0)
    def loss_fn(tr):
        g = g_b.with_trainable(tr)
        ls = []
        for v in range(V):
            per_p = [render_tiles(jax.tree.map(lambda x: x[i], g),
                                  select(cams, v), grid, K=K, impl="ref",
                                  k_tiers=kt)[0] for i in range(Pn)]
            t = jnp.concatenate(per_p)
            ls.append(tile_l1_dssim_loss(t[:, :3], gt[v], mask[v],
                                         win_size=7))
        return jnp.stack(ls).mean()
    tr = {k: getattr(g_b, k) for k in
          ("means", "log_scales", "quats", "opacity_logit", "colors")}
    loss, grads = jax.value_and_grad(loss_fn)(tr)
    out = {}
    for k in tr:
        gr = grads[k].astype(jnp.float32)
        m = (1 - cfg.b1) * gr
        v_ = (1 - cfg.b2) * gr * gr
        d = (m / (1 - cfg.b1)) / (jnp.sqrt(v_ / (1 - cfg.b2)) + cfg.eps)
        out[k] = tr[k] - lrs[k] * d
    return {k: np.asarray(x) for k, x in out.items()}, float(loss)

def dist_step(mesh, kt, step_cfg=None):
    step = make_gs_train_step(mesh, step_cfg or cfg, grid, extent=1.0,
                              impl="ref", views=V, k_tiers=kt)
    gsh, osh, bsh = gs_shardings(mesh, views=V)
    tr = {k: getattr(g_b, k) for k in
          ("means", "log_scales", "quats", "opacity_logit", "colors")}
    opt = GSOptState(
        m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
        v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
        step=jnp.int32(0),
        grad_accum=jnp.zeros((Pn, N)), grad_count=jnp.zeros((Pn, N)))
    batch = {"gt_tiles": jax.device_put(gt, bsh["gt_tiles"]),
             "mask_tiles": jax.device_put(mask, bsh["mask_tiles"]),
             "cam": jax.device_put(cam_b, bsh["cam"])}
    g1, _, l = step(jax.device_put(g_b, gsh), jax.device_put(opt, osh),
                    batch)
    return {k: np.asarray(x) for k, x in g1.trainable().items()}, float(l)

# the key invariant: sharding the view axis is an execution strategy, not a
# model change — 2-D mesh step == 1-D mesh step == single-device step,
# dense AND tiered
for kt in (None, (4, 8, K)):
    r, rl = ref_step(kt)
    p1, l1 = dist_step(mesh1d, kt)
    p2, l2 = dist_step(mesh2d, kt)
    for k in r:
        np.testing.assert_allclose(p1[k], r[k], rtol=1e-6, atol=1e-6,
                                   err_msg=f"1-D mesh {k} kt={kt}")
        np.testing.assert_allclose(p2[k], r[k], rtol=1e-6, atol=1e-6,
                                   err_msg=f"2-D mesh {k} kt={kt}")
    np.testing.assert_allclose([l1, l2], rl, rtol=1e-5, atol=1e-6)
print("M2D-STEP-MATCH")

# sort-based strip-local assignment == dense sweep through the FULL 2-D
# mesh step (params after one Adam update at 1e-6; the two impls share the
# two-key tie-break, so the assignment itself is bit-identical and the
# only differences left are float reassociation downstream)
for kt in (None, (4, 8, K)):
    p_sd, l_sd = dist_step(mesh2d, kt,
                           GSTrainCfg(K=K, lr_colors=5e-2,
                                      assign_impl="sorted"))
    p_dn, l_dn = dist_step(mesh2d, kt,
                           GSTrainCfg(K=K, lr_colors=5e-2,
                                      assign_impl="dense"))
    for k in p_sd:
        np.testing.assert_allclose(p_sd[k], p_dn[k], rtol=1e-6, atol=1e-6,
                                   err_msg=f"sorted-vs-dense {k} kt={kt}")
    np.testing.assert_allclose(l_sd, l_dn, rtol=1e-6, atol=1e-7)
print("M2D-ASSIGN-SORTED")

# tiered-by-DEFAULT cfg (k_tiers resolved from GSTrainCfg, caps fall back
# to the always-exact strip size) must equal the dense escape hatch
p_auto, _ = dist_step(mesh2d, cfg.resolved_k_tiers())
cfg_dense = GSTrainCfg(K=K, lr_colors=5e-2, dense_k=K)
assert cfg_dense.resolved_k_tiers() is None
step_d = make_gs_train_step(mesh2d, cfg_dense, grid, extent=1.0,
                            impl="ref", views=V)
gsh, osh, bsh = gs_shardings(mesh2d, views=V)
tr = {k: getattr(g_b, k) for k in
      ("means", "log_scales", "quats", "opacity_logit", "colors")}
opt = GSOptState(
    m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    step=jnp.int32(0),
    grad_accum=jnp.zeros((Pn, N)), grad_count=jnp.zeros((Pn, N)))
batch = {"gt_tiles": jax.device_put(gt, bsh["gt_tiles"]),
         "mask_tiles": jax.device_put(mask, bsh["mask_tiles"]),
         "cam": jax.device_put(cam_b, bsh["cam"])}
g_d, _, _ = step_d(jax.device_put(g_b, gsh), jax.device_put(opt, osh),
                   batch)
for k, x in g_d.trainable().items():
    np.testing.assert_allclose(p_auto[k], np.asarray(x),
                               rtol=1e-6, atol=1e-6, err_msg=k)
print("M2D-DEFAULT-TIERED")

# odd views must be rejected loudly, not silently truncated
try:
    make_gs_forward(mesh2d, grid, K=K, impl="ref", views=3)
except ValueError as e:
    assert "view" in str(e)
    print("M2D-DIVISIBILITY")
"""


@pytest.mark.slow
def test_2d_mesh_step_matches_1d_and_single_device(tmp_path):
    """The ("part", "view") 2-D mesh: view-sharded forward tiles/loss match
    the per-view reference, and the train step (params after one Adam
    update) matches the 1-D mesh and a hand-built single-device step at
    1e-6 — dense and tiered, overflow 0, tiered-by-default cfg included —
    and the sort-based strip assignment (cfg.assign_impl="sorted") matches
    the dense sweep through the full 2-D step at 1e-6."""
    code = MESH2D_SCRIPT % {"src": SRC}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "M2D-FWD-MATCH" in out.stdout
    assert "M2D-STEP-MATCH" in out.stdout
    assert "M2D-ASSIGN-SORTED" in out.stdout
    assert "M2D-DEFAULT-TIERED" in out.stdout
    assert "M2D-DIVISIBILITY" in out.stdout


DRIVER_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, r"%(src)s")
import jax, jax.numpy as jnp
import numpy as np

from repro.core.cameras import orbital_rig
from repro.core.distributed import fit_partitions
from repro.core.gaussians import from_points
from repro.core.pipeline import render_views
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, fit_partition
from repro.data.isosurface import point_cloud_for
from repro.runtime import CheckpointManager

N, res, V = 256, 32, 4
pts, cols = point_cloud_for("sphere_shell", N)
pts, cols = pts[:N], cols[:N]
cams = orbital_rig(V, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
mesh = jax.make_mesh((2, 2), ("part", "view"))
grid = TileGrid(res, res, 8, 16)

# GT rendered at bg=0: the distributed tile loss compares RAW premultiplied
# color tiles (no background composite), so the single-device reference
# must train with bg=0 too
g_gt = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.95)
gts = jnp.asarray(render_views(g_gt, cams, grid, K=16, bg=0.0)[0])
masks = jnp.ones((V, res, res), bool)
g0 = from_points(jnp.asarray(pts), jnp.asarray(cols), capacity=N + 128,
                 opacity=0.7)
g_b = jax.tree.map(lambda x: x[None], g0)           # (P=1, N) batched

def check(tag, single, dist):
    gs_1, _, l1 = single
    gs_2, _, l2 = dist
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-6, err_msg=tag)
    for k, v in gs_1.trainable().items():
        np.testing.assert_allclose(
            np.asarray(v), np.asarray(getattr(gs_2, k))[0],
            rtol=1e-6, atol=1e-6, err_msg=f"{tag}:{k}")
    assert int(np.asarray(gs_1.active).sum()) \
        == int(np.asarray(gs_2.active).sum()), tag
    print(tag, [round(l, 5) for l in l2])

# ---- TierSchedule lifecycle parity: probe -> train -> densify -> re-probe
# on the 2-D mesh == fit_partition's single-device loop, step for step.
# lambda_dssim=0 isolates the masked-L1 term, which is tile-layout
# invariant (the D-SSIM term is per-tile windowed by construction on the
# distributed path — pinned separately below on a one-tile grid).  A
# trajectory match at 1e-6 through two densify events also proves the
# probed caps never overflowed (a dropped tile would shift the loss).
cfg = GSTrainCfg(K=16, lambda_dssim=0.0, bg=0.0, view_batch=2,
                 lr_colors=5e-2, max_new=64, densify_grad_thresh=1e-9)
kw = dict(steps=6, extent=1.0, densify_every=3, densify_from=0, grid=grid)
check("TIERED-LIFECYCLE-PARITY",
      fit_partition(g0, cams, gts, masks, cfg, key=jax.random.PRNGKey(1),
                    **kw),
      fit_partitions(g_b, cams, gts[None], masks[None], cfg, mesh=mesh,
                     key=jax.random.PRNGKey(1), **kw))

# ---- dense escape hatch: same driver loop, no schedule ----
cfg_d = GSTrainCfg(K=16, dense_k=16, lambda_dssim=0.0, bg=0.0,
                   view_batch=2, lr_colors=5e-2)
assert cfg_d.tier_schedule() is None
kw = dict(steps=3, extent=1.0, grid=grid)
check("DENSE-PARITY",
      fit_partition(g0, cams, gts, masks, cfg_d, key=jax.random.PRNGKey(3),
                    **kw),
      fit_partitions(g_b, cams, gts[None], masks[None], cfg_d, mesh=mesh,
                     key=jax.random.PRNGKey(3), **kw))

# ---- full loss (L1 + D-SSIM): a single tile covering the image makes the
# per-tile windowed D-SSIM identical to gs_loss's full-image win-11 SSIM,
# so the complete loss trajectory must match too ----
grid1 = TileGrid(res, res, res, res)
cfg1 = GSTrainCfg(K=16, lambda_dssim=0.2, bg=0.0, view_batch=2,
                  tile_h=res, tile_w=res, lr_colors=5e-2)
kw = dict(steps=3, extent=1.0, grid=grid1)
check("FULL-LOSS-PARITY",
      fit_partition(g0, cams, gts, masks, cfg1, key=jax.random.PRNGKey(2),
                    **kw),
      fit_partitions(g_b, cams, gts[None], masks[None], cfg1, mesh=mesh,
                     key=jax.random.PRNGKey(2), win_size=11, **kw))

# ---- checkpoint/resume: an interrupted driver run resumes with the saved
# schedule (no re-probe) and reproduces the uninterrupted loss curve ----
import tempfile
cfg = GSTrainCfg(K=16, lambda_dssim=0.0, bg=0.0, view_batch=2,
                 lr_colors=5e-2, max_new=64, densify_grad_thresh=1e-9)
kw = dict(mesh=mesh, extent=1.0, densify_every=3, densify_from=0, grid=grid)
ck_a = CheckpointManager(tempfile.mkdtemp(), keep=0)
_, _, full = fit_partitions(g_b, cams, gts[None], masks[None], cfg,
                            key=jax.random.PRNGKey(1), steps=6,
                            ckpt=ck_a, ckpt_every=3, **kw)
ck_b = CheckpointManager(tempfile.mkdtemp(), keep=0)
sched_b = cfg.tier_schedule()
fit_partitions(g_b, cams, gts[None], masks[None], cfg,
               key=jax.random.PRNGKey(1), steps=3, ckpt=ck_b,
               ckpt_every=3, schedule=sched_b, **kw)
saved_caps = sched_b.tier_caps
sched_c = cfg.tier_schedule()
g_r, _, resumed = fit_partitions(
    g_b, cams, gts[None], masks[None], cfg, key=jax.random.PRNGKey(1),
    steps=6, ckpt=ck_b, ckpt_every=3, schedule=sched_c, **kw)
assert len(resumed) == 3, resumed
np.testing.assert_allclose(resumed, full[3:], rtol=1e-6, atol=1e-7)
print("DRIVER-RESUME-MATCH", [round(l, 5) for l in resumed])
"""


@pytest.mark.slow
def test_distributed_driver_matches_fit_partition(tmp_path):
    """The distributed tier-schedule driver (core.distributed.fit_partitions)
    on the 4-device ("part", "view") mesh reproduces the single-device
    fit_partition trajectory at 1e-6 — tiered (full probe/densify/re-probe
    lifecycle) and dense, L1-only and full loss (one-tile grid, win-11
    D-SSIM == full-image gs_loss) — and resumes from a mid-run checkpoint
    onto the uninterrupted loss curve without re-probing."""
    code = DRIVER_SCRIPT % {"src": SRC}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "TIERED-LIFECYCLE-PARITY" in out.stdout
    assert "DENSE-PARITY" in out.stdout
    assert "FULL-LOSS-PARITY" in out.stdout
    assert "DRIVER-RESUME-MATCH" in out.stdout


@pytest.mark.slow
def test_gs_cli_driver_smoke_and_resume(tmp_path):
    """`python -m repro.launch.train --gs --smoke` on 4 forced host devices
    runs the full partition -> tiered distributed training -> checkpoint ->
    merge -> render lifecycle, and a second invocation resumes from the
    saved checkpoint (restored TierSchedule, no re-probe)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    base = [sys.executable, "-m", "repro.launch.train", "--gs", "--smoke",
            "--host-devices", "4", "--ckpt-dir", str(tmp_path)]
    out = subprocess.run(base + ["--steps", "2"], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "raster=tiered" in out.stdout
    assert "PSNR" in out.stdout
    out2 = subprocess.run(base + ["--steps", "3"], env=env,
                          capture_output=True, text=True, timeout=900)
    assert out2.returncode == 0, (out2.stdout[-2000:], out2.stderr[-3000:])
    assert "resuming from checkpoint step 2" in out2.stdout
    assert "PSNR" in out2.stdout
