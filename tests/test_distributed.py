"""Distributed GS step: shard_map correctness on forced multi-device CPU.

The key invariant: the mesh-distributed forward/step computes the SAME math
as the single-device pipeline (modulo float association) — gaussian-parallel
all-gather + pixel-parallel strips are an execution strategy, not a model
change.  Runs in a subprocess so the 8-device XLA flag doesn't leak.
"""

import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_tile_view_batches_masks_none_excludes_grid_padding():
    """masks=None means "every IMAGE pixel" — grid padding (resolution not
    a tile multiple) must be masked OFF, matching the single-device
    full-image loss, which never sees pad pixels."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core.distributed import _tile_view_batches
    from repro.core.tiling import TileGrid

    grid = TileGrid(20, 12, 8, 16)      # pads to 16 x 32
    gts = np.random.default_rng(0).random((1, 2, 12, 20, 3)).astype("f4")
    gt_t, mask_t = _tile_view_batches(jnp.asarray(gts), None, grid)
    assert gt_t.shape == (2, grid.n_tiles, 3, 8, 16)
    assert mask_t.shape == (2, grid.n_tiles, 8, 16)
    assert int(mask_t.sum()) == 2 * 12 * 20      # image pixels only
    # explicit all-ones masks land on the identical tiling
    ones = jnp.ones((1, 2, 12, 20), bool)
    _, mask_t2 = _tile_view_batches(jnp.asarray(gts), ones, grid)
    np.testing.assert_array_equal(mask_t, mask_t2)

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, r"%(src)s")
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.cameras import orbital_rig, select
from repro.core.distributed import (gs_shardings, make_gs_forward,
                                    make_gs_train_step)
from repro.core.gaussians import from_points
from repro.core.masking import tile_l1_dssim_loss
from repro.core.render import render_tiles
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg
from repro.data.isosurface import point_cloud_for

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
Pn = 2
N = 256                      # divisible by data axis
res, K = 32, 16
grid = TileGrid(res, res, 8, 16)
T = grid.n_tiles
assert T %% 2 == 0

pts, cols = point_cloud_for("sphere_shell", 2 * N)
pts, cols = pts[: 2 * N], cols[: 2 * N]
cams = orbital_rig(2, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
cam = select(cams, 0)

# two partitions = two halves of the cloud (owner split irrelevant here)
g_all = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.8)

def part(i):
    sl = slice(i * N, (i + 1) * N)
    return jax.tree.map(lambda x: x[sl], g_all)

g_batched = jax.tree.map(lambda *xs: jnp.stack(xs), part(0), part(1))

# ---- reference: single-device per-partition renders + loss ----
ref_tiles = []
for i in range(Pn):
    tiles, _, _ = render_tiles(part(i), cam, grid, K=K, impl="ref")
    ref_tiles.append(tiles)
ref_tiles = jnp.concatenate(ref_tiles)              # (P*T, 4, th, tw)

gt = jnp.clip(ref_tiles[:, :3] + 0.05, 0, 1)
mask = jnp.ones((Pn * T, grid.tile_h, grid.tile_w), bool)
ref_loss = tile_l1_dssim_loss(ref_tiles[:, :3], gt, mask, win_size=7)

# ---- distributed: shard_map forward ----
# tolerance note: the seed pinned these at 2e-4 to absorb the tie-break
# divergence (equal-depth splats at the K boundary could differ between the
# strip-local and global top-k merges on some views).  The two-key
# (score, splat-index) merge makes assignment merge-order invariant, so the
# comparison is now float-reassociation only.
fwd = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True)
g_sh, opt_sh, b_sh = gs_shardings(mesh)
g_dev = jax.device_put(g_batched, g_sh)
loss, tiles = jax.jit(fwd)(g_dev, cam, gt, mask)
np.testing.assert_allclose(np.asarray(tiles), np.asarray(ref_tiles),
                           rtol=1e-6, atol=1e-6)
np.testing.assert_allclose(float(loss), float(ref_loss), rtol=1e-4, atol=1e-5)
print("FWD-MATCH")

# ---- optimized variants (§Perf GS hillclimb) stay faithful ----
# strip prefilter with budget 1.0 is exact (pure reordering)
fwd_strip = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                            strip_budget=127.0 / 128.0)
_, tiles_s = jax.jit(fwd_strip)(g_dev, cam, gt, mask)
np.testing.assert_allclose(np.asarray(tiles_s), np.asarray(ref_tiles),
                           rtol=1e-6, atol=1e-6)
# split bf16 gather: conic/rgb rounding only (image-level agreement)
fwd_split = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                            gather_mode="split", strip_budget=127.0 / 128.0)
loss_sp, tiles_sp = jax.jit(fwd_split)(g_dev, cam, gt, mask)
err = np.abs(np.asarray(tiles_sp[:, :3]) - np.asarray(ref_tiles[:, :3]))
assert err.max() < 5e-2 and err.mean() < 2e-3, (err.max(), err.mean())
assert abs(float(loss_sp) - float(ref_loss)) < 2e-3
print("OPT-MATCH")

# ---- tiered (variable-K) forward: the strip-local occupancy binning must
# reproduce the single-device dense tiles exactly (caps cover -> exact, and
# single-device tiered == single-device dense is pinned in
# test_tiered_raster.py, so this transitively pins distributed tiered ==
# single-device tiered) ----
fwd_tier = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                           k_tiers=(4, 8, K))
_, tiles_t = jax.jit(fwd_tier)(g_dev, cam, gt, mask)
np.testing.assert_allclose(np.asarray(tiles_t), np.asarray(ref_tiles),
                           rtol=1e-6, atol=1e-6)
# explicit static caps + strip prefilter compose with tiering
fwd_tier2 = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                            k_tiers=(4, 8, K), tier_caps=(8, 8, 8),
                            strip_budget=127.0 / 128.0)
_, tiles_t2 = jax.jit(fwd_tier2)(g_dev, cam, gt, mask)
np.testing.assert_allclose(np.asarray(tiles_t2), np.asarray(ref_tiles),
                           rtol=1e-6, atol=1e-6)
# overflow surfacing: generous caps report 0; starved caps FIRE the counter
# instead of silently rendering dropped tiles as background
_, ov0 = jax.jit(make_gs_forward(mesh, grid, K=K, impl="ref",
                                 k_tiers=(4, 8, K),
                                 return_overflow=True))(g_dev, cam, gt, mask)
assert int(ov0["tiles"]) == 0, ov0
assert int(ov0["assign"]) == 0 and int(ov0["exchange"]) == 0, ov0
_, ov1 = jax.jit(make_gs_forward(mesh, grid, K=K, impl="ref",
                                 k_tiers=(4, 8, K), tier_caps=(1, 0, 0),
                                 return_overflow=True))(g_dev, cam, gt, mask)
assert int(ov1["tiles"]) > 0, ov1
print("TIER-MATCH")

# ---- distributed train step: loss decreases, state stays sharded ----
from repro.core.train import GSOptState
step = make_gs_train_step(mesh, GSTrainCfg(K=K, lr_colors=5e-2), grid,
                          extent=1.0, impl="ref")
tr = {k: getattr(g_batched, k) for k in
      ("means", "log_scales", "quats", "opacity_logit", "colors")}
opt = GSOptState(
    m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    step=jnp.int32(0),
    grad_accum=jnp.zeros((Pn, N)), grad_count=jnp.zeros((Pn, N)))
opt = jax.device_put(opt, opt_sh)
batch = {"gt_tiles": jax.device_put(gt, b_sh["gt_tiles"]),
         "mask_tiles": jax.device_put(mask, b_sh["mask_tiles"]),
         "cam": cam}
g_cur, losses = g_dev, []
for i in range(8):
    g_cur, opt, l = step(g_cur, opt, batch)
    losses.append(float(l))
assert losses[-1] < losses[0], losses
assert g_cur.means.sharding.num_devices == 8
print("STEP-OK", round(losses[0], 5), "->", round(losses[-1], 5))
"""


@pytest.mark.slow
def test_distributed_matches_single_device(tmp_path):
    code = SCRIPT % {"src": SRC}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "FWD-MATCH" in out.stdout
    assert "OPT-MATCH" in out.stdout
    assert "TIER-MATCH" in out.stdout
    assert "STEP-OK" in out.stdout


VIEWS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, r"%(src)s")
import jax, jax.numpy as jnp
import numpy as np

from repro.core.cameras import orbital_rig, select
from repro.core.distributed import (gs_shardings, make_gs_forward,
                                    make_gs_train_step)
from repro.core.gaussians import from_points
from repro.core.render import render_tiles
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, GSOptState
from repro.data.isosurface import point_cloud_for

mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
Pn, N, res, K, V = 2, 256, 32, 16, 3
grid = TileGrid(res, res, 8, 16)
T = grid.n_tiles

pts, cols = point_cloud_for("sphere_shell", 2 * N)
pts, cols = pts[: 2 * N], cols[: 2 * N]
cams = orbital_rig(V, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
g_all = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.8)
part = lambda i: jax.tree.map(lambda x: x[i * N:(i + 1) * N], g_all)
g_batched = jax.tree.map(lambda *xs: jnp.stack(xs), part(0), part(1))

# reference: single-device per-view, per-partition tiles
ref = []
for v in range(V):
    per_p = [render_tiles(part(i), select(cams, v), grid, K=K, impl="ref")[0]
             for i in range(Pn)]
    ref.append(jnp.concatenate(per_p))
ref = jnp.stack(ref)                                 # (V, P*T, 4, th, tw)

gt = jnp.clip(ref[:, :, :3] + 0.05, 0, 1)
mask = jnp.ones((V, Pn * T, grid.tile_h, grid.tile_w), bool)
cam_b = select(cams, jnp.arange(V))

# ---- view-batched forward: tiles per view match the per-view reference ----
fwd = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True, views=V)
g_sh, _, b_sh = gs_shardings(mesh, views=V)
g_dev = jax.device_put(g_batched, g_sh)
loss, tiles = jax.jit(fwd)(g_dev, cam_b,
                           jax.device_put(gt, b_sh["gt_tiles"]),
                           jax.device_put(mask, b_sh["mask_tiles"]))
np.testing.assert_allclose(np.asarray(tiles), np.asarray(ref),
                           rtol=1e-6, atol=1e-6)
print("VFWD-MATCH")

# tiered dispatch under the view fold: per-(view, partition, strip) binning
# must still reproduce the per-view dense tiles exactly
fwd_t = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                        views=V, k_tiers=(4, 8, K))
_, tiles_t = jax.jit(fwd_t)(g_dev, cam_b, gt, mask)
np.testing.assert_allclose(np.asarray(tiles_t), np.asarray(ref),
                           rtol=1e-6, atol=1e-6)
print("VTIER-MATCH")

# heterogeneous per-view masks: the loss must be the MEAN of per-view
# losses (train.py's equal-view weighting), not a pixel-count-weighted pool
from repro.core.masking import tile_l1_dssim_loss
mask_h = mask.at[0].set(False).at[0, :, :2].set(True)   # view 0 nearly empty
loss_h = jax.jit(make_gs_forward(mesh, grid, K=K, impl="ref", views=V))(
    g_dev, cam_b, gt, mask_h)
want = np.mean([float(tile_l1_dssim_loss(ref[v][:, :3], gt[v], mask_h[v],
                                         win_size=7)) for v in range(V)])
np.testing.assert_allclose(float(loss_h), want, rtol=1e-4, atol=1e-5)
print("VLOSS-MEAN")

# perf variants stay faithful under the view axis
fwd_s = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                        views=V, strip_budget=127.0 / 128.0)
_, tiles_s = jax.jit(fwd_s)(g_dev, cam_b, gt, mask)
np.testing.assert_allclose(np.asarray(tiles_s), np.asarray(ref),
                           rtol=1e-6, atol=1e-6)
fwd_sp = make_gs_forward(mesh, grid, K=K, impl="ref", return_tiles=True,
                         views=V, gather_mode="split")
_, tiles_sp = jax.jit(fwd_sp)(g_dev, cam_b, gt, mask)
err = np.abs(np.asarray(tiles_sp[:, :, :3]) - np.asarray(ref[:, :, :3]))
assert err.max() < 5e-2, err.max()
print("VOPT-MATCH")

# ---- view-batched train step: loss decreases, state stays sharded ----
step = make_gs_train_step(mesh, GSTrainCfg(K=K, lr_colors=5e-2), grid,
                          extent=1.0, impl="ref", views=V)
_, opt_sh, _ = gs_shardings(mesh, views=V)
tr = {k: getattr(g_batched, k) for k in
      ("means", "log_scales", "quats", "opacity_logit", "colors")}
opt = GSOptState(
    m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    step=jnp.int32(0),
    grad_accum=jnp.zeros((Pn, N)), grad_count=jnp.zeros((Pn, N)))
opt = jax.device_put(opt, opt_sh)
batch = {"gt_tiles": jax.device_put(gt, b_sh["gt_tiles"]),
         "mask_tiles": jax.device_put(mask, b_sh["mask_tiles"]),
         "cam": cam_b}
g_cur, losses = g_dev, []
for i in range(8):
    g_cur, opt, l = step(g_cur, opt, batch)
    losses.append(float(l))
assert losses[-1] < losses[0], losses
assert g_cur.means.sharding.num_devices == 8
print("VSTEP-OK", round(losses[0], 5), "->", round(losses[-1], 5))
"""


@pytest.mark.slow
def test_view_batched_distributed_matches_per_view(tmp_path):
    """views=V path: vmapped projection + view-axis fold must reproduce the
    per-view single-device tiles, under all gather/strip variants."""
    code = VIEWS_SCRIPT % {"src": SRC}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "VFWD-MATCH" in out.stdout
    assert "VTIER-MATCH" in out.stdout
    assert "VLOSS-MEAN" in out.stdout
    assert "VOPT-MATCH" in out.stdout
    assert "VSTEP-OK" in out.stdout


MESH2D_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, r"%(src)s")
import jax, jax.numpy as jnp
import numpy as np

from repro.core.cameras import orbital_rig, select
from repro.core.distributed import (gs_shardings, make_gs_forward,
                                    make_gs_train_step)
from repro.core.gaussians import from_points
from repro.core.masking import tile_l1_dssim_loss
from repro.core.render import render_tiles
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, GSOptState, group_lrs
from repro.data.isosurface import point_cloud_for

Pn, N, res, K, V = 2, 256, 32, 16, 2
grid = TileGrid(res, res, 8, 16)
T = grid.n_tiles
pts, cols = point_cloud_for("sphere_shell", 2 * N)
pts, cols = pts[: 2 * N], cols[: 2 * N]
cams = orbital_rig(V, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
cam_b = select(cams, jnp.arange(V))
g_all = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.8)
part = lambda i: jax.tree.map(lambda x: x[i * N:(i + 1) * N], g_all)
g_b = jax.tree.map(lambda *xs: jnp.stack(xs), part(0), part(1))

ref = []
for v in range(V):
    per_p = [render_tiles(part(i), select(cams, v), grid, K=K, impl="ref")[0]
             for i in range(Pn)]
    ref.append(jnp.concatenate(per_p))
ref = jnp.stack(ref)                                 # (V, P*T, 4, th, tw)
gt = jnp.clip(ref[:, :, :3] + 0.05, 0, 1)
mask = jnp.ones((V, Pn * T, grid.tile_h, grid.tile_w), bool)

mesh2d = jax.make_mesh((2, 2), ("part", "view"))
mesh1d = jax.make_mesh((2,), ("part",))
cfg = GSTrainCfg(K=K, lr_colors=5e-2)

# ---- 2-D forward: view-sharded tiles/loss match the per-view reference,
# tiered on, overflow 0 ----
fwd = make_gs_forward(mesh2d, grid, K=K, impl="ref", return_tiles=True,
                      views=V, k_tiers=(4, 8, K), return_overflow=True)
g_sh, opt_sh, b_sh = gs_shardings(mesh2d, views=V)
g_dev = jax.device_put(g_b, g_sh)
loss, tiles, ov = jax.jit(fwd)(g_dev,
                               jax.device_put(cam_b, b_sh["cam"]),
                               jax.device_put(gt, b_sh["gt_tiles"]),
                               jax.device_put(mask, b_sh["mask_tiles"]))
np.testing.assert_allclose(np.asarray(tiles), np.asarray(ref),
                           rtol=1e-6, atol=1e-6)
want = np.mean([float(tile_l1_dssim_loss(ref[v][:, :3], gt[v], mask[v],
                                         win_size=7)) for v in range(V)])
np.testing.assert_allclose(float(loss), want, rtol=1e-4, atol=1e-5)
assert int(ov["tiles"]) == 0, ov
print("M2D-FWD-MATCH")

# ---- single-device reference STEP: same tile loss + Adam math, by hand ----
def ref_step(kt):
    lrs = group_lrs(cfg, 1.0)
    def loss_fn(tr):
        g = g_b.with_trainable(tr)
        ls = []
        for v in range(V):
            per_p = [render_tiles(jax.tree.map(lambda x: x[i], g),
                                  select(cams, v), grid, K=K, impl="ref",
                                  k_tiers=kt)[0] for i in range(Pn)]
            t = jnp.concatenate(per_p)
            ls.append(tile_l1_dssim_loss(t[:, :3], gt[v], mask[v],
                                         win_size=7))
        return jnp.stack(ls).mean()
    tr = {k: getattr(g_b, k) for k in
          ("means", "log_scales", "quats", "opacity_logit", "colors")}
    loss, grads = jax.value_and_grad(loss_fn)(tr)
    out = {}
    for k in tr:
        gr = grads[k].astype(jnp.float32)
        m = (1 - cfg.b1) * gr
        v_ = (1 - cfg.b2) * gr * gr
        d = (m / (1 - cfg.b1)) / (jnp.sqrt(v_ / (1 - cfg.b2)) + cfg.eps)
        out[k] = tr[k] - lrs[k] * d
    return {k: np.asarray(x) for k, x in out.items()}, float(loss)

def dist_step(mesh, kt, step_cfg=None):
    step = make_gs_train_step(mesh, step_cfg or cfg, grid, extent=1.0,
                              impl="ref", views=V, k_tiers=kt)
    gsh, osh, bsh = gs_shardings(mesh, views=V)
    tr = {k: getattr(g_b, k) for k in
          ("means", "log_scales", "quats", "opacity_logit", "colors")}
    opt = GSOptState(
        m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
        v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
        step=jnp.int32(0),
        grad_accum=jnp.zeros((Pn, N)), grad_count=jnp.zeros((Pn, N)))
    batch = {"gt_tiles": jax.device_put(gt, bsh["gt_tiles"]),
             "mask_tiles": jax.device_put(mask, bsh["mask_tiles"]),
             "cam": jax.device_put(cam_b, bsh["cam"])}
    g1, _, l = step(jax.device_put(g_b, gsh), jax.device_put(opt, osh),
                    batch)
    return {k: np.asarray(x) for k, x in g1.trainable().items()}, float(l)

# the key invariant: sharding the view axis is an execution strategy, not a
# model change — 2-D mesh step == 1-D mesh step == single-device step,
# dense AND tiered
for kt in (None, (4, 8, K)):
    r, rl = ref_step(kt)
    p1, l1 = dist_step(mesh1d, kt)
    p2, l2 = dist_step(mesh2d, kt)
    for k in r:
        np.testing.assert_allclose(p1[k], r[k], rtol=1e-6, atol=1e-6,
                                   err_msg=f"1-D mesh {k} kt={kt}")
        np.testing.assert_allclose(p2[k], r[k], rtol=1e-6, atol=1e-6,
                                   err_msg=f"2-D mesh {k} kt={kt}")
    np.testing.assert_allclose([l1, l2], rl, rtol=1e-5, atol=1e-6)
print("M2D-STEP-MATCH")

# sort-based strip-local assignment == dense sweep through the FULL 2-D
# mesh step (params after one Adam update at 1e-6; the two impls share the
# two-key tie-break, so the assignment itself is bit-identical and the
# only differences left are float reassociation downstream)
for kt in (None, (4, 8, K)):
    p_sd, l_sd = dist_step(mesh2d, kt,
                           GSTrainCfg(K=K, lr_colors=5e-2,
                                      assign_impl="sorted"))
    p_dn, l_dn = dist_step(mesh2d, kt,
                           GSTrainCfg(K=K, lr_colors=5e-2,
                                      assign_impl="dense"))
    for k in p_sd:
        np.testing.assert_allclose(p_sd[k], p_dn[k], rtol=1e-6, atol=1e-6,
                                   err_msg=f"sorted-vs-dense {k} kt={kt}")
    np.testing.assert_allclose(l_sd, l_dn, rtol=1e-6, atol=1e-7)
print("M2D-ASSIGN-SORTED")

# tiered-by-DEFAULT cfg (k_tiers resolved from GSTrainCfg, caps fall back
# to the always-exact strip size) must equal the dense escape hatch
p_auto, _ = dist_step(mesh2d, cfg.resolved_k_tiers())
cfg_dense = GSTrainCfg(K=K, lr_colors=5e-2, dense_k=K)
assert cfg_dense.resolved_k_tiers() is None
step_d = make_gs_train_step(mesh2d, cfg_dense, grid, extent=1.0,
                            impl="ref", views=V)
gsh, osh, bsh = gs_shardings(mesh2d, views=V)
tr = {k: getattr(g_b, k) for k in
      ("means", "log_scales", "quats", "opacity_logit", "colors")}
opt = GSOptState(
    m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
    step=jnp.int32(0),
    grad_accum=jnp.zeros((Pn, N)), grad_count=jnp.zeros((Pn, N)))
batch = {"gt_tiles": jax.device_put(gt, bsh["gt_tiles"]),
         "mask_tiles": jax.device_put(mask, bsh["mask_tiles"]),
         "cam": jax.device_put(cam_b, bsh["cam"])}
g_d, _, _ = step_d(jax.device_put(g_b, gsh), jax.device_put(opt, osh),
                   batch)
for k, x in g_d.trainable().items():
    np.testing.assert_allclose(p_auto[k], np.asarray(x),
                               rtol=1e-6, atol=1e-6, err_msg=k)
print("M2D-DEFAULT-TIERED")

# odd views must be rejected loudly, not silently truncated
try:
    make_gs_forward(mesh2d, grid, K=K, impl="ref", views=3)
except ValueError as e:
    assert "view" in str(e)
    print("M2D-DIVISIBILITY")
"""


@pytest.mark.slow
def test_2d_mesh_step_matches_1d_and_single_device(tmp_path):
    """The ("part", "view") 2-D mesh: view-sharded forward tiles/loss match
    the per-view reference, and the train step (params after one Adam
    update) matches the 1-D mesh and a hand-built single-device step at
    1e-6 — dense and tiered, overflow 0, tiered-by-default cfg included —
    and the sort-based strip assignment (cfg.assign_impl="sorted") matches
    the dense sweep through the full 2-D step at 1e-6."""
    code = MESH2D_SCRIPT % {"src": SRC}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "M2D-FWD-MATCH" in out.stdout
    assert "M2D-STEP-MATCH" in out.stdout
    assert "M2D-ASSIGN-SORTED" in out.stdout
    assert "M2D-DEFAULT-TIERED" in out.stdout
    assert "M2D-DIVISIBILITY" in out.stdout


DRIVER_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, r"%(src)s")
import jax, jax.numpy as jnp
import numpy as np

from repro.core.cameras import orbital_rig
from repro.core.distributed import fit_partitions
from repro.core.gaussians import from_points
from repro.core.pipeline import render_views
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, fit_partition
from repro.data.isosurface import point_cloud_for
from repro.runtime import CheckpointManager

N, res, V = 256, 32, 4
pts, cols = point_cloud_for("sphere_shell", N)
pts, cols = pts[:N], cols[:N]
cams = orbital_rig(V, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
mesh = jax.make_mesh((2, 2), ("part", "view"))
grid = TileGrid(res, res, 8, 16)

# GT rendered at bg=0: the distributed tile loss compares RAW premultiplied
# color tiles (no background composite), so the single-device reference
# must train with bg=0 too
g_gt = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.95)
gts = jnp.asarray(render_views(g_gt, cams, grid, K=16, bg=0.0)[0])
masks = jnp.ones((V, res, res), bool)
g0 = from_points(jnp.asarray(pts), jnp.asarray(cols), capacity=N + 128,
                 opacity=0.7)
g_b = jax.tree.map(lambda x: x[None], g0)           # (P=1, N) batched

def check(tag, single, dist):
    gs_1, _, l1 = single
    gs_2, _, l2 = dist
    np.testing.assert_allclose(l1, l2, rtol=1e-5, atol=1e-6, err_msg=tag)
    for k, v in gs_1.trainable().items():
        np.testing.assert_allclose(
            np.asarray(v), np.asarray(getattr(gs_2, k))[0],
            rtol=1e-6, atol=1e-6, err_msg=f"{tag}:{k}")
    assert int(np.asarray(gs_1.active).sum()) \
        == int(np.asarray(gs_2.active).sum()), tag
    print(tag, [round(l, 5) for l in l2])

# ---- TierSchedule lifecycle parity: probe -> train -> densify -> re-probe
# on the 2-D mesh == fit_partition's single-device loop, step for step.
# lambda_dssim=0 isolates the masked-L1 term, which is tile-layout
# invariant (the D-SSIM term is per-tile windowed by construction on the
# distributed path — pinned separately below on a one-tile grid).  A
# trajectory match at 1e-6 through two densify events also proves the
# probed caps never overflowed (a dropped tile would shift the loss).
cfg = GSTrainCfg(K=16, lambda_dssim=0.0, bg=0.0, view_batch=2,
                 lr_colors=5e-2, max_new=64, densify_grad_thresh=1e-9)
kw = dict(steps=6, extent=1.0, densify_every=3, densify_from=0, grid=grid)
check("TIERED-LIFECYCLE-PARITY",
      fit_partition(g0, cams, gts, masks, cfg, key=jax.random.PRNGKey(1),
                    **kw),
      fit_partitions(g_b, cams, gts[None], masks[None], cfg, mesh=mesh,
                     key=jax.random.PRNGKey(1), **kw))

# ---- dense escape hatch: same driver loop, no schedule ----
cfg_d = GSTrainCfg(K=16, dense_k=16, lambda_dssim=0.0, bg=0.0,
                   view_batch=2, lr_colors=5e-2)
assert cfg_d.tier_schedule() is None
kw = dict(steps=3, extent=1.0, grid=grid)
check("DENSE-PARITY",
      fit_partition(g0, cams, gts, masks, cfg_d, key=jax.random.PRNGKey(3),
                    **kw),
      fit_partitions(g_b, cams, gts[None], masks[None], cfg_d, mesh=mesh,
                     key=jax.random.PRNGKey(3), **kw))

# ---- full loss (L1 + D-SSIM): a single tile covering the image makes the
# per-tile windowed D-SSIM identical to gs_loss's full-image win-11 SSIM,
# so the complete loss trajectory must match too ----
grid1 = TileGrid(res, res, res, res)
cfg1 = GSTrainCfg(K=16, lambda_dssim=0.2, bg=0.0, view_batch=2,
                  tile_h=res, tile_w=res, lr_colors=5e-2)
kw = dict(steps=3, extent=1.0, grid=grid1)
check("FULL-LOSS-PARITY",
      fit_partition(g0, cams, gts, masks, cfg1, key=jax.random.PRNGKey(2),
                    **kw),
      fit_partitions(g_b, cams, gts[None], masks[None], cfg1, mesh=mesh,
                     key=jax.random.PRNGKey(2), win_size=11, **kw))

# ---- checkpoint/resume: an interrupted driver run resumes with the saved
# schedule (no re-probe) and reproduces the uninterrupted loss curve ----
import tempfile
cfg = GSTrainCfg(K=16, lambda_dssim=0.0, bg=0.0, view_batch=2,
                 lr_colors=5e-2, max_new=64, densify_grad_thresh=1e-9)
kw = dict(mesh=mesh, extent=1.0, densify_every=3, densify_from=0, grid=grid)
ck_a = CheckpointManager(tempfile.mkdtemp(), keep=0)
_, _, full = fit_partitions(g_b, cams, gts[None], masks[None], cfg,
                            key=jax.random.PRNGKey(1), steps=6,
                            ckpt=ck_a, ckpt_every=3, **kw)
ck_b = CheckpointManager(tempfile.mkdtemp(), keep=0)
sched_b = cfg.tier_schedule()
fit_partitions(g_b, cams, gts[None], masks[None], cfg,
               key=jax.random.PRNGKey(1), steps=3, ckpt=ck_b,
               ckpt_every=3, schedule=sched_b, **kw)
saved_caps = sched_b.tier_caps
sched_c = cfg.tier_schedule()
g_r, _, resumed = fit_partitions(
    g_b, cams, gts[None], masks[None], cfg, key=jax.random.PRNGKey(1),
    steps=6, ckpt=ck_b, ckpt_every=3, schedule=sched_c, **kw)
assert len(resumed) == 3, resumed
np.testing.assert_allclose(resumed, full[3:], rtol=1e-6, atol=1e-7)
print("DRIVER-RESUME-MATCH", [round(l, 5) for l in resumed])
"""


@pytest.mark.slow
def test_distributed_driver_matches_fit_partition(tmp_path):
    """The distributed tier-schedule driver (core.distributed.fit_partitions)
    on the 4-device ("part", "view") mesh reproduces the single-device
    fit_partition trajectory at 1e-6 — tiered (full probe/densify/re-probe
    lifecycle) and dense, L1-only and full loss (one-tile grid, win-11
    D-SSIM == full-image gs_loss) — and resumes from a mid-run checkpoint
    onto the uninterrupted loss curve without re-probing."""
    code = DRIVER_SCRIPT % {"src": SRC}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "TIERED-LIFECYCLE-PARITY" in out.stdout
    assert "DENSE-PARITY" in out.stdout
    assert "FULL-LOSS-PARITY" in out.stdout
    assert "DRIVER-RESUME-MATCH" in out.stdout


@pytest.mark.slow
def test_gs_cli_driver_smoke_and_resume(tmp_path):
    """`python -m repro.launch.train --gs --smoke` on 4 forced host devices
    runs the full partition -> tiered distributed training -> checkpoint ->
    merge -> render lifecycle, and a second invocation resumes from the
    saved checkpoint (restored TierSchedule, no re-probe)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    base = [sys.executable, "-m", "repro.launch.train", "--gs", "--smoke",
            "--host-devices", "4", "--ckpt-dir", str(tmp_path)]
    out = subprocess.run(base + ["--steps", "2"], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "raster=tiered" in out.stdout
    assert "PSNR" in out.stdout
    out2 = subprocess.run(base + ["--steps", "3"], env=env,
                          capture_output=True, text=True, timeout=900)
    assert out2.returncode == 0, (out2.stdout[-2000:], out2.stderr[-3000:])
    assert "resuming from checkpoint step 2" in out2.stdout
    assert "PSNR" in out2.stdout


def test_exchange_schedule_probe_growth_and_state():
    """ExchangeSchedule follows the TierSchedule honesty contract host-side:
    probed budgets carry slack and rounding, overflow grows them
    geometrically (clamped at n_local, where truncation is impossible),
    and the state round-trips through the checkpoint payload."""
    from repro.core.distributed import ExchangeSchedule

    es = ExchangeSchedule()
    assert es.budget is None
    # no probe yet -> overflow is a no-op (nothing to grow)
    assert es.note_overflow(5, 128) is False
    # probe: ceil(121 * 1.5) = 182 -> round to 192 -> clamp at n_local
    assert es.probe_budget(121, 128) == 128
    assert es.probe_budget(10, 512) == 16          # slack + round_to floor
    # geometric growth on a real counter; 0 never grows
    assert es.note_overflow(0, 512) is False and es.budget == 16
    assert es.note_overflow(7, 512) is True and es.budget == 32
    assert es.note_overflow(1, 512) and es.budget == 64
    # clamp: at n_local the budget covers every local splat -> no growth
    es.budget = 512
    assert es.note_overflow(3, 512) is False and es.budget == 512
    # state round-trip (the extra["exchange"] checkpoint payload)
    es2 = ExchangeSchedule.from_state(es.state_dict())
    assert es2.budget == 512 and es2.slack == es.slack
    pinned = ExchangeSchedule(budget=64)
    assert pinned.budget == 64
    assert "budget=64" in repr(pinned)


def test_exchange_schedule_budget_matrix():
    """The (n_part, n_part) budget matrix keeps the same honesty contract
    PER EDGE: probes size each edge independently, overflow grows only the
    starved edges, ``ensure`` is the grow-never-shrink in-step resize, the
    matrix round-trips through the JSON checkpoint payload, and malformed
    matrices are refused loudly."""
    import numpy as np
    import pytest

    from repro.core.distributed import ExchangeSchedule, check_budget_matrix

    es = ExchangeSchedule()
    demand = np.array([[40, 5], [90, 10]])
    B = es.probe_budget(demand, 512)
    # per-edge: ceil(d * 1.5) rounded up to 16 -> [[64, 16], [144, 16]]
    np.testing.assert_array_equal(B, [[64, 16], [144, 16]])

    # overflow on one edge grows ONLY that edge (geometric, clamped)
    ov = np.zeros((2, 2), np.int64)
    ov[0, 1] = 3
    assert es.note_overflow(ov, 512) is True
    B2 = np.asarray(es.budget)
    assert B2[0, 1] == 32
    B_ref = np.array([[64, 32], [144, 16]])
    np.testing.assert_array_equal(B2, B_ref)
    assert es.note_overflow(np.zeros((2, 2)), 512) is False

    # a SCALAR counter against a matrix budget (older telemetry) grows
    # every edge — conservative, never silent
    es_sc = ExchangeSchedule.from_state(es.state_dict())
    assert es_sc.note_overflow(1, 512) is True
    assert (np.asarray(es_sc.budget) >= B_ref).all()

    # ensure: grow-never-shrink to cover a demand bound, no slack
    assert es.ensure(np.full((2, 2), 100), 512) is True
    np.testing.assert_array_equal(np.asarray(es.budget),
                                  np.maximum(B_ref, 112))
    assert es.ensure(np.full((2, 2), 1), 512) is False    # never shrinks

    # round-trip: nested-list JSON payload -> identical matrix + key
    es2 = ExchangeSchedule.from_state(es.state_dict())
    np.testing.assert_array_equal(np.asarray(es2.budget),
                                  np.asarray(es.budget))
    assert es2.budget_key() == es.budget_key()
    assert isinstance(es2.budget_key(), tuple)
    assert "2x2[" in repr(es2)

    # loud validation: non-square, wrong-size and non-positive matrices
    with pytest.raises(ValueError, match="square"):
        check_budget_matrix(np.ones((2, 3)))
    with pytest.raises(ValueError, match="refused"):
        check_budget_matrix(np.ones((2, 2)), 4)
    with pytest.raises(ValueError, match="refused"):
        check_budget_matrix(np.ones((8, 8)), 4)
    with pytest.raises(ValueError):
        check_budget_matrix(np.zeros((2, 2)))
    with pytest.raises(ValueError):
        ExchangeSchedule(budget=np.ones((2, 3)))


def test_window_assignment():
    """The overlap-aware window assignment is a deterministic permutation
    that parks each brick's dominant band on the free local shift: when a
    derangement's edges carry the heavy overlap, tau recovers it and the
    ladder cost collapses to the light residue; with nothing to gain it
    stays the identity."""
    import numpy as np

    from repro.core.distributed import window_assignment

    # uniform overlap: no assignment beats another — identity, both sizes
    np.testing.assert_array_equal(window_assignment(np.full((4, 4), 7)),
                                  np.arange(4))
    np.testing.assert_array_equal(window_assignment(np.ones((1, 1))), [0])

    n = 8
    sigma = np.roll(np.arange(n), 3)       # heavy edges all on one shift
    rng = np.random.default_rng(0)
    B = rng.integers(1, 8, (n, n))
    B[np.arange(n), sigma] = 500
    tau = window_assignment(B)
    assert sorted(tau) == list(range(n)), tau          # a permutation
    shifts = [(np.arange(n) + k) % n for k in range(1, n)]

    def cost(t):
        return sum(int(B[np.arange(n), t[s]].max()) for s in shifts)

    np.testing.assert_array_equal(tau, sigma)
    assert cost(tau) + 400 < cost(np.arange(n)), (cost(tau), cost(sigma))
    np.testing.assert_array_equal(tau, window_assignment(B))  # deterministic


EXCHANGE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, r"%(src)s")
import jax, jax.numpy as jnp
import numpy as np

from repro.core.cameras import orbital_rig, select
from repro.core.distributed import (ExchangeSchedule, gs_shardings,
                                    make_gs_exchange_probe, make_gs_forward,
                                    make_gs_train_step, probe_gs_exchange)
from repro.core.gaussians import from_points
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, GSOptState
from repro.data.isosurface import point_cloud_for

Pn, N, res, K, V = 2, 256, 32, 16, 2
grid = TileGrid(res, res, 8, 16)
T = grid.n_tiles
pts, cols = point_cloud_for("sphere_shell", 2 * N)
pts, cols = pts[: 2 * N], cols[: 2 * N]
cams = orbital_rig(V, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
cam_b = select(cams, jnp.arange(V))
g_all = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.8)
part = lambda i: jax.tree.map(lambda x: x[i * N:(i + 1) * N], g_all)
g_b = jax.tree.map(lambda *xs: jnp.stack(xs), part(0), part(1))

mesh2d = jax.make_mesh((2, 2), ("part", "view"))
mesh1d = jax.make_mesh((4,), ("part",))
g_sh, opt_sh, b_sh = gs_shardings(mesh2d, views=V)
g_dev = jax.device_put(g_b, g_sh)
cam_dev = jax.device_put(cam_b, b_sh["cam"])
gt = jnp.zeros((V, Pn * T, 3, grid.tile_h, grid.tile_w))
mask = jnp.ones((V, Pn * T, grid.tile_h, grid.tile_w), bool)
gt_dev = jax.device_put(gt, b_sh["gt_tiles"])
mask_dev = jax.device_put(mask, b_sh["mask_tiles"])

# ---- edge-budget probe: pmax'd worst overlap, sized with slack ----
es = ExchangeSchedule()
E = probe_gs_exchange(es, mesh2d, grid, g_dev, cam_dev, views=V)
assert 1 <= E <= N // 2, E
raw = int(jax.jit(make_gs_exchange_probe(mesh2d, grid, views=V))(
    g_dev, cam_dev))
assert E >= min(raw, N // 2), (E, raw)
print("EX-PROBE", E, raw)

# ---- per-edge probe: the (n, n) demand matrix agrees with the scalar
# probe (its max IS the worst edge) and sizes a matrix budget ----
esm = ExchangeSchedule()
B = probe_gs_exchange(esm, mesh2d, grid, g_dev, cam_dev, views=V,
                      per_edge=True)
raw_m = np.asarray(jax.jit(make_gs_exchange_probe(
    mesh2d, grid, views=V, per_edge=True))(g_dev, cam_dev))
assert raw_m.shape == (2, 2) and int(raw_m.max()) == raw, (raw_m, raw)
assert (np.asarray(B) >= np.minimum(raw_m, N // 2)).all(), (B, raw_m)
print("EX-PROBE-EDGES", raw_m.tolist())

# ---- forward parity vs the all-gather table, dense AND tiered: identical
# tiles at 1e-6 (the received table is an order-preserving subsequence of
# the gathered table, so the two-key top-k selects identical splats) and a
# zero overflow dict ----
for kt in (None, (4, 8, K)):
    fg = make_gs_forward(mesh2d, grid, K=K, impl="ref", views=V, k_tiers=kt,
                         return_tiles=True, return_overflow=True)
    lg, tg, og = jax.jit(fg)(g_dev, cam_dev, gt_dev, mask_dev)
    for eb in (E, B):   # scalar all_to_all AND ragged per-edge ladder
        fe = make_gs_forward(mesh2d, grid, K=K, impl="ref", views=V,
                             k_tiers=kt, return_tiles=True,
                             return_overflow=True,
                             exchange=True, exchange_budget=eb)
        le, te, oe = jax.jit(fe)(g_dev, cam_dev, gt_dev, mask_dev)
        assert int(oe["exchange"]) == 0 and int(oe["tiles"]) == 0, oe
        if np.ndim(eb) == 2:
            # matrix telemetry: zero per-edge drops, and the in-step
            # demand matrix IS the host probe's measurement
            assert (np.asarray(oe["exchange_edges"]) == 0).all(), oe
            np.testing.assert_array_equal(
                np.asarray(oe["exchange_demand"]), raw_m)
        np.testing.assert_allclose(np.asarray(te).reshape(tg.shape),
                                   np.asarray(tg), rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(float(le), float(lg),
                                   rtol=1e-6, atol=1e-7)
print("EX-FWD-MATCH")

# ---- 1-D ("part",) x4 mesh: the window splits 4 ways (sub = T // 4) and
# the exchange must still match its own gather step — scalar and per-edge
# matrix budgets alike ----
g_sh1, opt_sh1, b_sh1 = gs_shardings(mesh1d, views=V)
es4 = ExchangeSchedule()
B4 = probe_gs_exchange(es4, mesh1d, grid,
                       jax.device_put(g_b, g_sh1),
                       jax.device_put(cam_b, b_sh1["cam"]),
                       views=V, per_edge=True)
fwd_tri = []
for eb in (None, E, B4):
    f = make_gs_forward(mesh1d, grid, K=K, impl="ref", views=V,
                        k_tiers=(4, 8, K), return_overflow=True,
                        exchange=eb is not None, exchange_budget=eb)
    l, ov = jax.jit(f)(jax.device_put(g_b, g_sh1),
                       jax.device_put(cam_b, b_sh1["cam"]),
                       jax.device_put(gt, b_sh1["gt_tiles"]),
                       jax.device_put(mask, b_sh1["mask_tiles"]))
    assert int(ov["exchange"]) == 0 and int(ov["tiles"]) == 0, ov
    fwd_tri.append(float(l))
np.testing.assert_allclose(fwd_tri[1], fwd_tri[0], rtol=1e-6, atol=1e-7)
np.testing.assert_allclose(fwd_tri[2], fwd_tri[0], rtol=1e-6, atol=1e-7)
print("EX-1D-MATCH")

# ---- overlap-aware window assignment: inflating a derangement's edges
# forces window_assignment to pick a non-identity band permutation inside
# the ladder; the loss partials psum across "part", so WHICH device
# renders which band must not change the loss (or fire any counter) ----
from repro.core.distributed import window_assignment
sigma = np.array([3, 2, 1, 0])
B_tau = np.asarray(B4).copy()
B_tau[np.arange(4), sigma] = N
tau = window_assignment(np.minimum(B_tau, N))
assert not (tau == np.arange(4)).all(), tau
f_tau = make_gs_forward(mesh1d, grid, K=K, impl="ref", views=V,
                        k_tiers=(4, 8, K), return_overflow=True,
                        exchange=True, exchange_budget=B_tau)
l_tau, ov_tau = jax.jit(f_tau)(jax.device_put(g_b, g_sh1),
                               jax.device_put(cam_b, b_sh1["cam"]),
                               jax.device_put(gt, b_sh1["gt_tiles"]),
                               jax.device_put(mask, b_sh1["mask_tiles"]))
assert int(ov_tau["exchange"]) == 0, ov_tau
np.testing.assert_allclose(float(l_tau), fwd_tri[0], rtol=1e-6, atol=1e-7)
print("EX-TAU-MATCH", tau.tolist())

# ---- train-step parity: params after one Adam update at 1e-6, dense and
# tiered+sorted (the sorted strip assignment composes with the exchange
# table exactly like with the gathered one) ----
def one(cfgx, kt):
    step = make_gs_train_step(mesh2d, cfgx, grid, extent=1.0, impl="ref",
                              views=V, k_tiers=kt)
    tr = {k: getattr(g_b, k) for k in
          ("means", "log_scales", "quats", "opacity_logit", "colors")}
    opt = GSOptState(
        m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
        v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
        step=jnp.int32(0),
        grad_accum=jnp.zeros((Pn, N)), grad_count=jnp.zeros((Pn, N)))
    batch = {"gt_tiles": gt_dev, "mask_tiles": mask_dev, "cam": cam_dev}
    g1, _, l = step(jax.device_put(g_b, g_sh),
                    jax.device_put(opt, opt_sh), batch)
    return {k: np.asarray(x) for k, x in g1.trainable().items()}, float(l)

for kt, ai in ((None, "dense"), ((4, 8, K), "sorted")):
    pg, lg = one(GSTrainCfg(K=K, lr_colors=5e-2, assign_impl=ai,
                            assign_budget=8 if ai == "sorted" else None), kt)
    pe, le = one(GSTrainCfg(K=K, lr_colors=5e-2, assign_impl=ai,
                            assign_budget=8 if ai == "sorted" else None,
                            exchange=True, exchange_budget=E), kt)
    for k in pg:
        np.testing.assert_allclose(pe[k], pg[k], rtol=1e-6, atol=1e-6,
                                   err_msg=f"{k} kt={kt} assign={ai}")
    np.testing.assert_allclose(le, lg, rtol=1e-6, atol=1e-7)
print("EX-STEP-MATCH")

# ---- adversarial: a starved edge budget REPORTS (psum'd counter > 0) and
# the output stays well-formed — finite loss, finite tiles, finite params
# after a step — never NaN, never a silent crash ----
fs = make_gs_forward(mesh2d, grid, K=K, impl="ref", views=V, k_tiers=None,
                     return_tiles=True, return_overflow=True,
                     exchange=True, exchange_budget=1)
ls, ts, ovs = jax.jit(fs)(g_dev, cam_dev, gt_dev, mask_dev)
assert int(ovs["exchange"]) > 0, ovs
assert np.isfinite(float(ls)) and np.isfinite(np.asarray(ts)).all()
ps, lss = one(GSTrainCfg(K=K, lr_colors=5e-2, exchange=True,
                         exchange_budget=1), None)
assert np.isfinite(lss)
assert all(np.isfinite(v).all() for v in ps.values())
print("EX-STARVED", int(ovs["exchange"]))

# ---- adversarial, per-edge: starving ONE edge of the matrix fires ONLY
# that edge's psum'd counter; every other edge stays zero and the output
# stays finite ----
B_st = np.asarray(B).copy()
B_st[0, 1] = 1
fse = make_gs_forward(mesh2d, grid, K=K, impl="ref", views=V, k_tiers=None,
                      return_overflow=True,
                      exchange=True, exchange_budget=B_st)
lse, ove = jax.jit(fse)(g_dev, cam_dev, gt_dev, mask_dev)
edges = np.asarray(ove["exchange_edges"])
assert edges[0, 1] > 0, edges
others = edges.copy(); others[0, 1] = 0
assert (others == 0).all(), edges
assert int(ove["exchange"]) == int(edges.sum()), ove
assert np.isfinite(float(lse))
print("EX-STARVED-EDGE", edges.tolist())

# ---- non-divisible window: a 3-tile strip over a 2-wide "part" axis is
# PADDED (ceil sub-windows, masked pad tiles) and the loss still equals
# the all-gather loss at 1e-6 — for scalar and matrix budgets ----
bad = TileGrid(24, 8, 8, 8)          # 3 tiles, part axis 2
Tb = bad.n_tiles
cams_b = orbital_rig(V, (0.5, 0.5, 0.5), 1.6, width=24, height=8)
cb_dev = jax.device_put(select(cams_b, jnp.arange(V)), b_sh["cam"])
gtb = jax.device_put(
    jnp.zeros((V, Pn * Tb, 3, bad.tile_h, bad.tile_w)), b_sh["gt_tiles"])
mkb = jax.device_put(
    jnp.ones((V, Pn * Tb, bad.tile_h, bad.tile_w), bool),
    b_sh["mask_tiles"])
esb = ExchangeSchedule()
Bb = probe_gs_exchange(esb, mesh2d, bad, g_dev, cb_dev, views=V,
                       per_edge=True)
fgb = make_gs_forward(mesh2d, bad, K=K, impl="ref", views=V,
                      return_overflow=True)
lgb, _ = jax.jit(fgb)(g_dev, cb_dev, gtb, mkb)
for eb in (None, Bb):                # scalar (unbudgeted) and matrix
    feb = make_gs_forward(mesh2d, bad, K=K, impl="ref", views=V,
                          return_overflow=True, exchange=True,
                          exchange_budget=eb)
    leb, oeb = jax.jit(feb)(g_dev, cb_dev, gtb, mkb)
    assert int(oeb["exchange"]) == 0, oeb
    np.testing.assert_allclose(float(leb), float(lgb),
                               rtol=1e-6, atol=1e-7)
print("EX-PAD-MATCH", float(lgb))

# ---- loud validation: return_tiles cannot reassemble padded sub-windows;
# the strip prefilter composed under exchange still refuses to build ----
try:
    make_gs_forward(mesh2d, bad, K=K, views=V, exchange=True,
                    return_tiles=True)
    raise SystemExit("padded return_tiles not enforced")
except ValueError as e:
    assert "divide" in str(e), e
try:
    make_gs_forward(mesh2d, grid, K=K, views=V, exchange=True,
                    strip_budget=0.5)
    raise SystemExit("strip_budget not enforced")
except ValueError as e:
    assert "strip_budget" in str(e), e
print("EX-VALIDATE")
"""


@pytest.mark.slow
def test_sparse_exchange_matches_all_gather():
    """The sparse-overlap exchange on 4 forced host devices: probed edge
    budgets (scalar AND per-edge matrix), forward tiles/loss == the
    all-gather forward at 1e-6 (dense and tiered, 2-D ("part", "view")
    and 1-D ("part",) meshes, overflow 0, in-step demand == the host
    probe), train-step params == the all-gather step at 1e-6 (dense and
    tiered+sorted), a starved budget fires the psum'd counter — only on
    the starved edge for matrices — with well-formed (finite) outputs, a
    non-divisible window pads instead of refusing (loss parity held), and
    invalid configs are rejected loudly."""
    code = EXCHANGE_SCRIPT % {"src": SRC}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    for tok in ("EX-PROBE", "EX-PROBE-EDGES", "EX-FWD-MATCH", "EX-1D-MATCH",
                "EX-TAU-MATCH", "EX-STEP-MATCH", "EX-STARVED",
                "EX-STARVED-EDGE", "EX-PAD-MATCH", "EX-VALIDATE"):
        assert tok in out.stdout, tok


EXDRIVER_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys, json, glob, tempfile
sys.path.insert(0, r"%(src)s")
import jax, jax.numpy as jnp
import numpy as np

from repro.core.cameras import orbital_rig
import repro.core.distributed as dist
from repro.core.distributed import fit_partitions, rebalance_partitions
from repro.core.gaussians import from_points
from repro.core.pipeline import render_views
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, init_opt
from repro.data.isosurface import point_cloud_for
from repro.runtime import CheckpointManager

N, res, V = 256, 32, 4
pts, cols = point_cloud_for("sphere_shell", N)
pts, cols = pts[:N], cols[:N]
# break the shell's symmetry ties: rebalance bit-stability holds for
# tie-free depth scores (the two-key top-k falls back to ROW INDEX on
# equal scores, and the permutation moves rows), so the fixture must not
# hand the tie-break a coin to flip
pts = pts + 1e-4 * np.random.default_rng(0).standard_normal(pts.shape)
cams = orbital_rig(V, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
mesh = jax.make_mesh((2, 2), ("part", "view"))
grid = TileGrid(res, res, 8, 16)
g_gt = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.95)
gts = jnp.asarray(render_views(g_gt, cams, grid, K=16, bg=0.0)[0])[None]
masks = jnp.ones((1, V, res, res), bool)
g0 = from_points(jnp.asarray(pts), jnp.asarray(cols), capacity=N + 128,
                 opacity=0.7)
g_b = jax.tree.map(lambda x: x[None], g0)           # (P=1, N) batched

def run(cfgx, **kw):
    base = dict(mesh=mesh, steps=4, extent=1.0, grid=grid,
                key=jax.random.PRNGKey(1))
    base.update(kw)
    return fit_partitions(g_b, cams, gts, masks, cfgx, **base)

# ---- full tiered lifecycle (probe -> train -> densify -> re-probe)
# parity: the exchange trajectory equals the all-gather trajectory at
# 1e-6, losses AND trainables, through a densify event ----
kwl = dict(steps=6, densify_every=3, densify_from=0)
cfg_t = GSTrainCfg(K=16, lambda_dssim=0.0, bg=0.0, view_batch=2,
                   lr_colors=5e-2, max_new=64, densify_grad_thresh=1e-9)
cfg_te = GSTrainCfg(K=16, lambda_dssim=0.0, bg=0.0, view_batch=2,
                    lr_colors=5e-2, max_new=64, densify_grad_thresh=1e-9,
                    exchange=True)
gg, _, lg = run(cfg_t, **kwl)
ge, _, le = run(cfg_te, **kwl)
np.testing.assert_allclose(le, lg, rtol=1e-5, atol=1e-6)
for k, v in gg.trainable().items():
    np.testing.assert_allclose(np.asarray(getattr(ge, k)), np.asarray(v),
                               rtol=1e-6, atol=1e-6, err_msg=k)
print("EXD-PARITY", [round(l, 5) for l in le])

# ---- rebalance_partitions unit invariants on a skewed population ----
g_skew = jax.device_get(g_b)
cap = g_skew.means.shape[1]
act = np.zeros((1, cap), bool)
act[0, : cap // 2] = True          # every live splat on shard 0
g_skew = g_skew._replace(active=jnp.asarray(act))
opt0 = init_opt(g_skew)
g_r, o_r, moved = rebalance_partitions(g_skew, opt0, mesh, threshold=1.5)
assert moved
act_r = np.asarray(g_r.active)
live = act_r.reshape(1, 2, cap // 2).sum(-1)
assert abs(int(live[0, 0]) - int(live[0, 1])) <= 1, live
# a pure permutation: the live rows' parameters are preserved as a set
want = np.sort(np.asarray(g_skew.means)[np.asarray(g_skew.active)], axis=0)
got = np.sort(np.asarray(g_r.means)[act_r], axis=0)
np.testing.assert_array_equal(got, want)
# under-threshold skew is left untouched
_, _, moved2 = rebalance_partitions(g_r, opt0, mesh, threshold=1.5)
assert not moved2
print("EXD-REBALANCE-UNIT")

# ---- rebalance leaves the loss trajectory BIT-stable: with tie-free
# scores the two-key top-k is row-order independent, so forced
# permutations (threshold=0) must not move a single float ----
cfg_x = GSTrainCfg(K=16, dense_k=16, lambda_dssim=0.0, bg=0.0,
                   view_batch=2, lr_colors=5e-2, exchange=True)
_, _, l_plain = run(cfg_x)
_, _, l_reb = run(cfg_x, rebalance_every=2, rebalance_threshold=0.0)
np.testing.assert_array_equal(np.asarray(l_plain), np.asarray(l_reb))
print("EXD-REBALANCE-STABLE", [round(l, 5) for l in l_reb])

# ---- starved pinned budget: the psum'd counter feeds geometric growth
# (checkpointed budget ends > 1) and every loss stays finite ----
ck_g = CheckpointManager(tempfile.mkdtemp(), keep=0)
cfg_s = GSTrainCfg(K=16, dense_k=16, lambda_dssim=0.0, bg=0.0,
                   view_batch=2, lr_colors=5e-2, exchange=True,
                   exchange_budget=1)
_, _, l_s = run(cfg_s, steps=3, ckpt=ck_g, ckpt_every=3)
assert np.isfinite(l_s).all(), l_s
man = sorted(glob.glob(os.path.join(ck_g.root, "step_*", "manifest.json")))
state = json.load(open(man[-1]))["extra"]["exchange"]
assert state["budget"] > 1, state
print("EXD-GROWTH", state["budget"])

# ---- checkpoint resume restores the probed budget WITHOUT re-probing:
# with the probe monkeypatched to explode, the resumed run still matches
# the uninterrupted trajectory ----
cfg_r = GSTrainCfg(K=16, dense_k=16, lambda_dssim=0.0, bg=0.0,
                   view_batch=2, lr_colors=5e-2, exchange=True)
_, _, l_full = run(cfg_r, steps=6)
ck_r = CheckpointManager(tempfile.mkdtemp(), keep=0)
run(cfg_r, steps=4, ckpt=ck_r, ckpt_every=4)
def boom(*a, **k):
    raise AssertionError("probe_gs_exchange called on resume")
dist.probe_gs_exchange = boom
_, _, l_resumed = run(cfg_r, steps=6, ckpt=ck_r, ckpt_every=4)
assert len(l_resumed) == 2, l_resumed
np.testing.assert_allclose(l_resumed, l_full[4:], rtol=1e-6, atol=1e-7)
print("EXD-RESUME-NOREPROBE", [round(l, 5) for l in l_resumed])
"""


BF16_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, r"%(src)s")
import jax, jax.numpy as jnp
import numpy as np

from repro.core.cameras import orbital_rig, select
from repro.core.distributed import (ExchangeSchedule, gs_shardings,
                                    make_gs_train_step, probe_gs_exchange)
from repro.core.gaussians import from_points
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, GSOptState
from repro.data.isosurface import point_cloud_for

Pn, N, res, K, V = 2, 256, 32, 16, 2
grid = TileGrid(res, res, 8, 16)
T = grid.n_tiles
pts, cols = point_cloud_for("sphere_shell", 2 * N)
pts, cols = pts[: 2 * N], cols[: 2 * N]
cams = orbital_rig(V, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
cam_b = select(cams, jnp.arange(V))
g_all = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.8)
part = lambda i: jax.tree.map(lambda x: x[i * N:(i + 1) * N], g_all)
g_b = jax.tree.map(lambda *xs: jnp.stack(xs), part(0), part(1))
mesh2d = jax.make_mesh((2, 2), ("part", "view"))
mesh1d = jax.make_mesh((2,), ("part",))
gt = jnp.zeros((V, Pn * T, 3, grid.tile_h, grid.tile_w))
mask = jnp.ones((V, Pn * T, grid.tile_h, grid.tile_w), bool)
TR = ("means", "log_scales", "quats", "opacity_logit", "colors")

def one(mesh, cfgx, kt):
    step = make_gs_train_step(mesh, cfgx, grid, extent=1.0, impl="ref",
                              views=V, k_tiers=kt)
    gsh, osh, bsh = gs_shardings(mesh, views=V)
    tr = {k: getattr(g_b, k) for k in TR}
    opt = GSOptState(
        m=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
        v=jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), tr),
        step=jnp.int32(0),
        grad_accum=jnp.zeros((Pn, N)), grad_count=jnp.zeros((Pn, N)))
    batch = {"gt_tiles": jax.device_put(gt, bsh["gt_tiles"]),
             "mask_tiles": jax.device_put(mask, bsh["mask_tiles"]),
             "cam": jax.device_put(cam_b, bsh["cam"])}
    gd, od = jax.device_put(g_b, gsh), jax.device_put(opt, osh)
    if cfgx.grad_compress == "none":
        g1, _, l = step(gd, od, batch)[:3]
        err = None
    else:
        # compressed steps share one (g, opt, err, batch) signature;
        # the stateless "bf16" mode carries err=None through it
        e0 = None if cfgx.grad_compress == "bf16" else \
            jax.device_put(jax.tree.map(
                lambda x: jnp.zeros_like(x, jnp.float32), tr), osh.m)
        g1, _, err, l = step(gd, od, e0, batch)[:4]
    return ({k: np.asarray(x) for k, x in g1.trainable().items()},
            float(l), err)

cfg32 = GSTrainCfg(K=K, lr_colors=5e-2)
cfgbf = GSTrainCfg(K=K, lr_colors=5e-2, dtype_policy="bf16")

# ---- sharding stays an execution strategy PER DTYPE: the bf16-policy step
# on the 2-D ("part", "view") mesh equals the 1-D ("part",) mesh step
# bit-for-bit (both cast the same f32 rows to bf16 BEFORE the collective
# and promote the same assignment geometry after, so every device composits
# identically rounded tables; measured diff: exactly 0.0) ----
for kt in (None, (4, 8, K)):
    p2, l2, _ = one(mesh2d, cfgbf, kt)
    p1, l1, _ = one(mesh1d, cfgbf, kt)
    for k in p2:
        np.testing.assert_allclose(p2[k], p1[k], rtol=1e-6, atol=1e-6,
                                   err_msg=f"bf16 mesh parity {k} kt={kt}")
    np.testing.assert_allclose(l2, l1, rtol=1e-6, atol=1e-7)
print("BF16-MESH-PARITY")

# ---- policy cost vs the f32 step, measured and bounded: the first Adam
# update has |delta| <= lr exactly (moment bias correction cancels), so any
# two policies differ by <= 2 lr per group; the loss gap is bf16 input
# rounding through the compositor (measured 2.7e-3 relative; asserted 1e-2).
# Spatial params see the smallest gap (measured means <= 3.2e-4) ----
p32, l32, _ = one(mesh2d, cfg32, None)
pbf, lbf, _ = one(mesh2d, cfgbf, None)
assert abs(lbf - l32) / l32 <= 1e-2, (lbf, l32)
for k in p32:
    d = np.abs(pbf[k] - p32[k]).max()
    assert d <= 0.1 + 1e-6, (k, d)      # 2 * max group lr (5e-2)
    assert np.isfinite(pbf[k]).all(), k
assert np.abs(pbf["means"] - p32["means"]).max() <= 1e-3
print("BF16-POLICY-COST")

# ---- exchange == gather WITHIN the bf16 policy: both paths move the same
# bf16-rounded rows (cast happens before either collective) and score
# overlap/assignment on the same promoted f32 geometry, so the sparse
# exchange still matches its own all-gather at the f32 suite's 1e-6 ----
es = ExchangeSchedule()
g_sh2, _, b_sh2 = gs_shardings(mesh2d, views=V)
E = probe_gs_exchange(es, mesh2d, grid, jax.device_put(g_b, g_sh2),
                      jax.device_put(cam_b, b_sh2["cam"]), views=V)
for kt in (None, (4, 8, K)):
    pg, lg, _ = one(mesh2d, cfgbf, kt)
    pe, le, _ = one(mesh2d, GSTrainCfg(K=K, lr_colors=5e-2,
                                       dtype_policy="bf16", exchange=True,
                                       exchange_budget=E), kt)
    for k in pg:
        np.testing.assert_allclose(pe[k], pg[k], rtol=1e-6, atol=1e-6,
                                   err_msg=f"bf16 exchange {k} kt={kt}")
    np.testing.assert_allclose(le, lg, rtol=1e-6, atol=1e-7)
print("BF16-EX-MATCH", E)

# ---- grad_compress through the distributed step: "bf16" wire rounding
# leaves the loss IDENTICAL (compression happens after the forward) and
# params within 3e-8 of the uncompressed step (measured; gradients this
# small round to the same Adam direction); "int8" returns a finite nonzero
# error-feedback tree and params within the 2 lr first-step envelope ----
pc, lc, _ = one(mesh2d, GSTrainCfg(K=K, lr_colors=5e-2,
                                   grad_compress="bf16"), None)
np.testing.assert_allclose(lc, l32, rtol=0, atol=1e-7)
for k in p32:
    np.testing.assert_allclose(pc[k], p32[k], rtol=1e-6, atol=1e-6, err_msg=k)
pi, li, err = one(mesh2d, GSTrainCfg(K=K, lr_colors=5e-2,
                                     grad_compress="int8"), None)
np.testing.assert_allclose(li, l32, rtol=0, atol=1e-7)
leaves = jax.tree.leaves(err)
assert leaves and all(np.isfinite(np.asarray(e)).all() for e in leaves)
assert max(float(jnp.abs(e).max()) for e in leaves) > 0.0
for k in p32:
    assert np.abs(pi[k] - p32[k]).max() <= 0.1 + 1e-6, k
print("BF16-COMPRESS")
"""


@pytest.mark.slow
@pytest.mark.dtype
def test_bf16_policy_distributed_step():
    """dtype_policy="bf16" through the distributed train step on 4 forced
    host devices: 2-D mesh == 1-D mesh bit-for-bit (sharding stays an
    execution strategy per dtype), the policy cost vs the f32 step is
    bounded and documented, the sparse exchange still equals the all-gather
    at 1e-6 WITHIN the policy, and both grad_compress wire modes keep the
    step's loss/params inside their measured envelopes."""
    code = BF16_SCRIPT % {"src": SRC}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    for tok in ("BF16-MESH-PARITY", "BF16-POLICY-COST", "BF16-EX-MATCH",
                "BF16-COMPRESS"):
        assert tok in out.stdout, tok


@pytest.mark.slow
def test_exchange_driver_lifecycle():
    """fit_partitions under cfg.exchange on the 4-device 2-D mesh: the full
    tiered probe/densify/re-probe trajectory equals the all-gather driver
    at 1e-6; rebalance_partitions deals live rows evenly (pure permutation)
    and a forced rebalance leaves the loss trajectory bit-identical; a
    starved pinned budget grows geometrically off the psum'd counter
    (visible in the checkpointed state) with finite losses throughout; and
    a checkpoint resume restores the probed budget without calling the
    probe again."""
    code = EXDRIVER_SCRIPT % {"src": SRC}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    for tok in ("EXD-PARITY", "EXD-REBALANCE-UNIT",
                "EXD-REBALANCE-STABLE", "EXD-GROWTH",
                "EXD-RESUME-NOREPROBE"):
        assert tok in out.stdout, tok
