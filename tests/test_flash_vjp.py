"""Flash-attention custom VJP vs. the baseline scan implementation.

The vjp path must match the scan path bit-for-bit in the forward and to
float tolerance in gradients, across causal/SWA/prefix/GQA/non-causal and
padded (Skv % kv_chunk != 0) shapes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L


def make_qkv(rng, B, Sq, Skv, Hq, Hkv, hd, dtype=jnp.float32):
    r = np.random.default_rng(rng)
    q = jnp.asarray(r.normal(size=(B, Sq, Hq, hd)) * 0.5, dtype)
    k = jnp.asarray(r.normal(size=(B, Skv, Hkv, hd)) * 0.5, dtype)
    v = jnp.asarray(r.normal(size=(B, Skv, Hkv, hd)) * 0.5, dtype)
    return q, k, v


CASES = [
    # (B, Sq, Skv, Hq, Hkv, hd, causal, window, prefix, kv_chunk)
    (2, 16, 16, 4, 4, 8, True, None, 0, 8),
    (2, 16, 16, 4, 2, 8, True, None, 0, 8),     # GQA
    (1, 32, 32, 4, 1, 8, True, 8, 0, 16),       # MQA + SWA
    (2, 16, 16, 4, 4, 8, True, None, 6, 8),     # prefix-LM
    (1, 12, 20, 2, 2, 8, False, None, 0, 8),    # cross-attn, ragged chunk
    (1, 16, 16, 4, 4, 8, True, None, 0, 16),    # single chunk
    (2, 8, 24, 4, 2, 16, True, None, 0, 10),    # Skv % chunk != 0
]


@pytest.mark.parametrize("case", CASES)
def test_vjp_matches_scan(case):
    B, Sq, Skv, Hq, Hkv, hd, causal, window, prefix, chunk = case
    q, k, v = make_qkv(0, B, Sq, Skv, Hq, Hkv, hd)
    kw = dict(causal=causal, window=window, prefix_len=prefix,
              kv_chunk=chunk)

    out_s = L.flash_attention(q, k, v, impl="scan", **kw)
    out_v = L.flash_attention(q, k, v, impl="vjp", **kw)
    np.testing.assert_allclose(out_v, out_s, rtol=2e-5, atol=2e-5)

    g = jnp.asarray(np.random.default_rng(1).normal(size=out_s.shape),
                    jnp.float32)

    def loss(impl):
        def f(q, k, v):
            return jnp.vdot(L.flash_attention(q, k, v, impl=impl, **kw), g)
        return f

    gs = jax.grad(loss("scan"), argnums=(0, 1, 2))(q, k, v)
    gv = jax.grad(loss("vjp"), argnums=(0, 1, 2))(q, k, v)
    for a, b, name in zip(gv, gs, "qkv"):
        np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4,
                                   err_msg=f"d{name} mismatch {case}")


def test_vjp_used_in_train_step_matches_scan_loss():
    """End-to-end: a smoke train step under both impls gives the same loss
    and gradients."""
    from repro.configs import get_smoke
    from repro.models import TrainCfg, init_opt_state, init_params, \
        make_train_step

    spec = get_smoke("h2o-danube-1.8b")     # GQA + SWA coverage
    params = init_params(spec, jax.random.PRNGKey(0))
    cfg = TrainCfg(total_steps=4, kv_chunk=32)
    batch = {
        "tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0,
                                     spec.vocab, jnp.int32),
        "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 64), 0,
                                     spec.vocab, jnp.int32),
    }
    outs = {}
    for impl in ("scan", "vjp"):
        L.set_flash_impl(impl)
        try:
            step = jax.jit(make_train_step(spec, cfg))
            opt = init_opt_state(spec, params, cfg)
            _, _, metrics = step(params, opt, batch)
            outs[impl] = (float(metrics["loss"]),
                          float(metrics["grad_norm"]))
        finally:
            L.set_flash_impl("vjp")
    assert outs["scan"][0] == pytest.approx(outs["vjp"][0], rel=1e-4)
    assert outs["scan"][1] == pytest.approx(outs["vjp"][1], rel=2e-3)
