"""GS pipeline system tests: partition/ghost invariants (hypothesis),
merge dedupe, masks, metrics, densification, and the paper's ghost+mask
ablation as a quantitative check (Fig. 2/4)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # degraded fallback (see tests/_hyp.py)
    from _hyp import given, settings, st

from repro.core import metrics
from repro.core.cameras import orbital_rig, select
from repro.core.gaussians import from_points
from repro.core.masking import background_mask, dilate_mask
from repro.core.merge import merge_partitions
from repro.core.partition import factor3, partition_points
from repro.core.pipeline import PipelineCfg, run_pipeline
from repro.core.render import render
from repro.core.tiling import TileGrid
from repro.core.train import (GSTrainCfg, densify_and_prune, init_opt,
                              make_train_step)
from repro.data.isosurface import point_cloud_for


# ---------------------------------------------------------------------------
# partitioning properties
# ---------------------------------------------------------------------------


@st.composite
def cloud(draw):
    n = draw(st.integers(50, 400))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    mode = draw(st.sampled_from(["uniform", "shell", "clustered"]))
    if mode == "uniform":
        pts = rng.uniform(0, 1, (n, 3))
    elif mode == "shell":
        v = rng.normal(size=(n, 3))
        v /= np.linalg.norm(v, axis=1, keepdims=True) + 1e-9
        pts = 0.5 + 0.35 * v
    else:
        centers = rng.uniform(0.2, 0.8, (4, 3))
        pts = (centers[rng.integers(0, 4, n)]
               + rng.normal(scale=0.05, size=(n, 3)))
    return pts.astype(np.float32)


@settings(max_examples=25, deadline=None)
@given(cloud(), st.integers(1, 8), st.floats(0.0, 0.2))
def test_partition_invariants(pts, n_parts, ghost_frac):
    extent = float(np.linalg.norm(pts.max(0) - pts.min(0))) + 1e-6
    gw = ghost_frac * extent
    colors = np.zeros_like(pts)
    parts, scheme = partition_points(pts, colors, n_parts, ghost_width=gw)

    # every input point owned exactly once
    total_owned = sum(p.n_owned for p in parts)
    assert total_owned == len(pts)
    owned_all = np.concatenate([p.points[: p.n_owned] for p in parts])
    assert sorted(map(tuple, owned_all.tolist())) == \
        sorted(map(tuple, pts.tolist()))

    for p in parts:
        # owner tags: owned rows tagged with own id, ghosts with another
        assert (p.owner[: p.n_owned] == p.part_id).all()
        assert (p.owner[p.n_owned:] != p.part_id).all()
        # ghosts really belong to a neighbouring cell within ghost width:
        # their distance to this partition's slab is < ghost width
        gh = p.points[p.n_owned:]
        if len(gh):
            ids = scheme.cell_of(gh)
            assert (ids != p.part_id).all()


@given(st.integers(1, 64))
def test_factor3_is_exact_and_balanced(n):
    a, b, c = factor3(n)
    assert a * b * c == n


def test_ghost_width_zero_means_no_ghosts():
    pts = np.random.default_rng(0).uniform(0, 1, (500, 3)).astype(np.float32)
    parts, _ = partition_points(pts, np.zeros_like(pts), 4, ghost_width=0.0)
    assert all(p.n_ghost == 0 for p in parts)


def test_ghosts_grow_with_width():
    pts = np.random.default_rng(0).uniform(0, 1, (2000, 3)).astype(np.float32)
    counts = []
    for gw in (0.01, 0.05, 0.15):
        parts, _ = partition_points(pts, np.zeros_like(pts), 4,
                                    ghost_width=gw)
        counts.append(sum(p.n_ghost for p in parts))
    assert counts[0] < counts[1] < counts[2]


# ---------------------------------------------------------------------------
# merge dedupe
# ---------------------------------------------------------------------------


def test_merge_dedupes_ghosts_exactly():
    pts = np.random.default_rng(1).uniform(0, 1, (800, 3)).astype(np.float32)
    parts, _ = partition_points(pts, np.zeros_like(pts), 3, ghost_width=0.08)
    gs = []
    for p in parts:
        g = from_points(jnp.asarray(p.points), jnp.asarray(p.colors))
        gs.append(g._replace(owner=jnp.asarray(p.owner)))
    merged = merge_partitions(gs, [p.part_id for p in parts])
    assert merged.capacity == len(pts)          # every point exactly once
    assert bool(merged.active.all())
    got = np.sort(np.asarray(merged.means), axis=0)
    want = np.sort(pts, axis=0)
    np.testing.assert_allclose(got, want, atol=1e-6)


# ---------------------------------------------------------------------------
# metrics / masks
# ---------------------------------------------------------------------------


def test_psnr_ssim_identity_and_noise():
    rng = np.random.default_rng(0)
    img = jnp.asarray(rng.uniform(0, 1, (48, 48, 3)), jnp.float32)
    assert float(metrics.psnr(img, img)) > 80
    assert float(metrics.ssim(img, img)) > 0.999
    noisy = jnp.clip(img + 0.1 * rng.normal(size=img.shape).astype("f"), 0, 1)
    assert float(metrics.psnr(img, noisy)) < 25
    assert float(metrics.ssim(img, noisy)) < 0.99
    assert float(metrics.grad_sim(img, img)) < 1e-5


def test_masked_metrics_ignore_outside():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(0, 1, (32, 32, 3)), jnp.float32)
    b = a.at[16:, :, :].set(0.0)              # corrupt bottom half
    mask = jnp.zeros((32, 32), bool).at[:16, :].set(True)
    assert float(metrics.psnr(a, b, mask)) > 80
    # SSIM windows are 11x11: keep the mask a window-radius clear of the
    # corruption boundary
    mask_s = jnp.zeros((32, 32), bool).at[:10, :].set(True)
    assert float(metrics.ssim(a, b, mask_s)) > 0.99


def test_dilate_mask_monotone():
    m = jnp.zeros((16, 16), bool).at[8, 8].set(True)
    d1 = dilate_mask(m, 1)
    d2 = dilate_mask(m, 2)
    assert bool((d1 >= m).all()) and bool((d2 >= d1).all())
    assert int(d1.sum()) == 9 and int(d2.sum()) == 25


def test_background_mask_covers_object():
    pts, cols = point_cloud_for("sphere_shell", 500)
    g = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.9)
    cams = orbital_rig(2, (0.5, 0.5, 0.5), 2.0, width=32, height=32)
    grid = TileGrid(32, 32, 8, 16)
    mask = background_mask(g, select(cams, 0), grid, K=16)
    frac = float(mask.mean())
    assert 0.05 < frac < 0.95     # object visible but not the whole frame


# ---------------------------------------------------------------------------
# trainer: loss decreases, densify/prune bookkeeping
# ---------------------------------------------------------------------------


def _tiny_scene(n=300, res=32):
    pts, cols = point_cloud_for("sphere_shell", n)
    extent = float(np.linalg.norm(pts.max(0) - pts.min(0)))
    g = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.9)
    cams = orbital_rig(3, (0.5, 0.5, 0.5), 1.0, width=res, height=res)
    grid = TileGrid(res, res, 8, 16)
    return g, cams, grid, extent


def test_train_step_reduces_loss():
    g_gt, cams, grid, extent = _tiny_scene()
    gts = [render(g_gt, select(cams, v), grid, K=16).rgb for v in range(3)]
    # perturb colors; training should recover them (high color LR so the
    # recovery is visible within a short CPU test)
    g0 = g_gt._replace(colors=g_gt.colors + 1.5)
    cfg = GSTrainCfg(K=16, lr_colors=5e-2)
    step = jax.jit(make_train_step(cfg, grid, extent))
    opt = init_opt(g0)
    first = last = None
    for i in range(60):
        g0, opt, loss = step(g0, opt, select(cams, i % 3), gts[i % 3])
        first = first if first is not None else float(loss)
        last = float(loss)
    assert last < 0.5 * first, (first, last)


def test_densify_and_prune_bookkeeping():
    g, cams, grid, extent = _tiny_scene(n=100)
    cap = 160
    g = from_points(g.means[:100], None, capacity=cap)
    opt = init_opt(g)
    # force: half the actives have hot grads and large scales -> split
    opt = opt._replace(
        grad_accum=opt.grad_accum.at[:50].set(1.0),
        grad_count=opt.grad_count.at[:].set(1.0),
    )
    # default init scale sits between percent_dense*extent (split threshold)
    # and prune_scale*extent (too-large prune), so hot gaussians split
    cfg = GSTrainCfg(densify_grad_thresh=1e-3, max_new=32)
    n_active0 = int(g.active.sum())
    g2, opt2 = densify_and_prune(g, opt, jax.random.PRNGKey(0), cfg, extent)
    n_active2 = int(g2.active.sum())
    assert n_active2 > n_active0            # children appeared
    assert n_active2 <= cap
    assert int(g2.owner.max()) == 0         # children inherit owner
    assert float(opt2.grad_accum.max()) == 0.0  # stats reset
    # prune: make everything transparent -> all pruned
    g3 = g2._replace(opacity_logit=jnp.full_like(g2.opacity_logit, -10.0))
    g4, _ = densify_and_prune(g3, opt2, jax.random.PRNGKey(1), cfg, extent)
    assert int(g4.active.sum()) == 0


# ---------------------------------------------------------------------------
# the paper's ablation (Fig 2/4): ghosts + masks fix the merged render
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_ghost_mask_ablation_improves_merged_quality():
    common = dict(dataset="sphere_shell", n_parts=2, resolution=48,
                  steps=60, K=24, n_views=6,
                  train=GSTrainCfg(K=24, tile_h=8, tile_w=16))
    ours = run_pipeline(PipelineCfg(use_ghost=True, use_mask=True, **common))
    broken = run_pipeline(PipelineCfg(use_ghost=False, use_mask=False,
                                      **common))
    # the paper's qualitative claim, quantified: ghosts+masks must not LOSE
    # to the ablated pipeline.  At CPU tier the artifact mechanism is weak
    # (boundary splat bleed is sub-pixel; EXPERIMENTS.md §Reproduction
    # records the honest null result) so the assertion is a non-regression
    # bound at the observed run-to-run variance, not a win requirement.
    assert ours.psnr >= broken.psnr - 0.9, (ours.psnr, broken.psnr)
    assert ours.ssim >= broken.ssim - 0.02, (ours.ssim, broken.ssim)


# ---------------------------------------------------------------------------
# sorted-assignment budget drift: counter -> geometric growth, never silent
# ---------------------------------------------------------------------------


def test_assign_budget_drift_counter_and_driver_growth(monkeypatch):
    """ROADMAP item 5: radii drifting past the sorted budget's probe slack
    between densify events must surface in the step's ``"assign"`` overflow
    counter and make the driver GROW the budget (geometric, bounded
    recompiles) — truncation never persists silently.  A starved budget
    fires the counter; an ample one reports 0; ``fit_partition`` converges
    to a quiet budget within a few growth events."""
    from repro.core import train as train_mod
    from repro.core.train import fit_partition

    g_gt, cams, grid, extent = _tiny_scene()
    # inflate radii so every visible splat's bbox spans several tiles — a
    # 1-slot budget MUST truncate candidates (this is the drift scenario:
    # scales are trained parameters, so a probed budget can go stale)
    g_big = g_gt._replace(log_scales=g_gt.log_scales + 1.2)
    gts = np.stack([np.asarray(render(g_big, select(cams, v), grid,
                                      K=16).rgb) for v in range(3)])

    cfg = GSTrainCfg(K=16, dense_k=16, assign_impl="sorted", assign_budget=1)
    step = jax.jit(make_train_step(cfg, grid, extent, return_overflow=True))
    opt = init_opt(g_big)
    _, _, _, ov = step(g_big, opt, select(cams, 0), jnp.asarray(gts[0]))
    assert int(ov["assign"]) > 0, "starved budget must fire the counter"
    assert int(ov["tiles"]) == 0   # dense raster: tier counter stays quiet
    ample = GSTrainCfg(K=16, dense_k=16, assign_impl="sorted",
                       assign_budget=grid.n_tiles)
    step_a = jax.jit(make_train_step(ample, grid, extent,
                                     return_overflow=True))
    _, _, _, ov_a = step_a(g_big, opt, select(cams, 0), jnp.asarray(gts[0]))
    assert int(ov_a["assign"]) == 0, int(ov_a["assign"])

    # the driver consumes the counter: grow_tile_budget is called with the
    # current budget, the grown value feeds the rebuilt step, and growth
    # STOPS once the budget covers the drifted radii
    grown = []
    real = train_mod.grow_tile_budget

    def spy(budget, n_tiles, **kw):
        out = real(budget, n_tiles, **kw)
        grown.append((int(budget), int(out)))
        return out

    monkeypatch.setattr(train_mod, "grow_tile_budget", spy)
    _, _, losses = fit_partition(g_big, cams, gts, None, cfg, steps=5,
                                 extent=extent, grid=grid)
    assert np.isfinite(losses).all()
    assert grown, "driver never grew a starved budget"
    assert len(grown) < 5, f"growth never converged: {grown}"
    assert all(b1 > b0 for b0, b1 in grown), grown
    budgets = [b0 for b0, _ in grown]
    assert budgets == sorted(budgets), budgets
