"""HLO analyzer: flop/byte/collective accounting against known-cost programs.

The analyzer is the measurement instrument behind §Roofline — these tests
pin its semantics: scan trip-count multiplication, dot flop formulas,
slice-aware fusion I/O, collective wire models, replica-group parsing.
"""

import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.launch.hlo_analysis import _parse_groups, _wire_bytes, analyze


def _compile_text(f, *args):
    return jax.jit(f).lower(*args).compile().as_text()


def test_dot_flops_exact():
    a = jnp.zeros((32, 64))
    b = jnp.zeros((64, 128))
    r = analyze(_compile_text(lambda x, y: x @ y, a, b))
    assert r["flops"] == pytest.approx(2 * 32 * 64 * 128, rel=0.01)


def test_scan_trip_count_multiplies():
    W = jnp.zeros((8, 64, 64))
    x0 = jnp.zeros((4, 64))

    def f(x, Ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return lax.scan(body, x, Ws)[0].sum()

    r = analyze(_compile_text(f, x0, W))
    dots = 8 * 2 * 4 * 64 * 64
    assert dots <= r["flops"] <= dots * 1.3


def test_scan_hbm_counts_slices_not_whole_buffer():
    # 16 layers x (64x64) weights: per trip the body should read ~one layer
    # (16 KB), not the whole 256 KB stack
    W = jnp.zeros((16, 64, 64))
    x0 = jnp.zeros((1, 64))

    def f(x, Ws):
        return lax.scan(lambda x, w: (x @ w, None), x, Ws)[0].sum()

    r = analyze(_compile_text(f, x0, W))
    whole_stack_every_trip = 16 * (16 * 64 * 64 * 4)
    assert r["hbm_bytes"] < whole_stack_every_trip / 2


def test_no_collectives_on_single_device():
    r = analyze(_compile_text(lambda x: (x * 2).sum(), jnp.zeros((128,))))
    assert r["collective_wire_bytes"] == 0
    assert r["n_collective_sites"] == 0


def test_wire_models():
    # all-gather: out - in
    assert _wire_bytes("all-gather", 100, 800, 8) == 700
    # ring all-reduce: 2x(g-1)/g
    assert _wire_bytes("all-reduce", 800, 800, 8) == 2 * 800 * 7 // 8
    assert _wire_bytes("reduce-scatter", 800, 100, 8) == 800
    # group of 1 = free
    assert _wire_bytes("all-reduce", 800, 800, 1) == 0


def test_replica_group_pod_span_detection():
    line = "replica_groups={{0,1},{2,3}}"
    size, spans = _parse_groups(line, pod_size=2)
    assert size == 2 and spans is False
    line = "replica_groups={{0,2},{1,3}}"
    size, spans = _parse_groups(line, pod_size=2)
    assert size == 2 and spans is True


def test_replica_group_iota_format():
    line = "replica_groups=[2,4]<=[8]"
    size, spans = _parse_groups(line, pod_size=4)
    assert size == 4 and spans is False      # {0..3},{4..7} within pods
    line2 = "replica_groups=[4,2]<=[2,4]T(1,0)"
    size2, spans2 = _parse_groups(line2, pod_size=4)
    assert size2 == 2 and spans2 is True     # pairs {0,4},... cross pods


def test_conv_flops_order_of_magnitude():
    x = jnp.zeros((1, 3, 16, 16))
    k = jnp.zeros((8, 3, 3, 3))

    def f(x, k):
        return lax.conv_general_dilated(
            x, k, (1, 1), "SAME",
            dimension_numbers=("NCHW", "OIHW", "NCHW")).sum()

    r = analyze(_compile_text(f, x, k))
    expect = 2 * (1 * 8 * 16 * 16) * (3 * 3 * 3)
    assert expect * 0.5 <= r["flops"] <= expect * 2.0
