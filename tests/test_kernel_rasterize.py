"""Pallas rasterizer vs. pure-jnp oracle: shape/dtype sweeps + gradient check.

Kernel bodies execute via interpret=True on CPU (assignment instructions);
forward is checked against BOTH oracles (scan + cumprod) and backward against
jax-autodiff of the scan oracle.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops
from repro.kernels import ref as ref_impl


def make_tile_inputs(rng, T, K, th, tw, dtype=jnp.float32, dead_frac=0.2):
    """Random but well-conditioned splat features over a T-tile strip."""
    r = np.random.default_rng(rng)
    W, H = tw * T, th  # tiles laid out in a row
    mean = r.uniform([-4, -4], [W + 4, H + 4], size=(T * K, 2))
    # random SPD conic: R diag(1/s^2) R^T
    ang = r.uniform(0, np.pi, size=T * K)
    s1 = r.uniform(0.8, 6.0, size=T * K)
    s2 = r.uniform(0.8, 6.0, size=T * K)
    ca, sa = np.cos(ang), np.sin(ang)
    ia, ib = 1.0 / s1**2, 1.0 / s2**2
    A = ca * ca * ia + sa * sa * ib
    B = ca * sa * (ia - ib)
    C = sa * sa * ia + ca * ca * ib
    rgb = r.uniform(0, 1, size=(T * K, 3))
    alpha = r.uniform(0.05, 0.95, size=T * K)
    alpha[r.uniform(size=T * K) < dead_frac] = 0.0  # empty list slots
    feat = np.concatenate(
        [mean, np.stack([A, B, C], -1), rgb, alpha[:, None],
         np.zeros((T * K, 7))], axis=-1,
    ).reshape(T, K, 16)
    origins = np.stack(
        [np.arange(T) * tw, np.zeros(T)], -1
    ).astype(np.float32)
    return jnp.asarray(feat, dtype), jnp.asarray(origins, jnp.float32)


SWEEP = [
    # (T, K, th, tw)
    (1, 1, 4, 8),
    (2, 8, 8, 16),
    (4, 32, 8, 16),
    (3, 64, 8, 128),   # production tile shape
    (8, 17, 16, 16),   # odd K
    (2, 5, 8, 256),
]


@pytest.mark.parametrize("T,K,th,tw", SWEEP)
def test_forward_matches_oracles(T, K, th, tw):
    feats, origins = make_tile_inputs(0, T, K, th, tw)
    out_k = ops.rasterize_tiles(feats, origins, tile_h=th, tile_w=tw,
                                impl="interpret")
    out_scan = ref_impl.rasterize_tiles_ref(feats, origins, tile_h=th, tile_w=tw)
    out_unrl = ref_impl.rasterize_tiles_unrolled(feats, origins,
                                                 tile_h=th, tile_w=tw)
    np.testing.assert_allclose(out_k, out_scan, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(out_k, out_unrl, rtol=1e-5, atol=1e-5)
    cov = np.asarray(out_k[:, 3])
    assert (cov >= -1e-6).all() and (cov <= 1 + 1e-6).all()


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_forward_dtypes(dtype):
    feats, origins = make_tile_inputs(1, 2, 16, 8, 16, dtype=dtype)
    out = ops.rasterize_tiles(feats, origins, tile_h=8, tile_w=16,
                              impl="interpret")
    assert out.dtype == jnp.float32  # kernel accumulates f32 regardless
    ref = ref_impl.rasterize_tiles_ref(feats.astype(jnp.float32), origins,
                                       tile_h=8, tile_w=16)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("T,K,th,tw", [(2, 8, 8, 16), (3, 33, 8, 32)])
def test_backward_matches_autodiff(T, K, th, tw):
    feats, origins = make_tile_inputs(2, T, K, th, tw)
    gout = jnp.asarray(
        np.random.default_rng(7).normal(size=(T, 4, th, tw)), jnp.float32
    )

    def loss_k(f):
        return jnp.vdot(
            ops.rasterize_tiles(f, origins, tile_h=th, tile_w=tw,
                                impl="interpret"), gout)

    def loss_r(f):
        return jnp.vdot(
            ref_impl.rasterize_tiles_ref(f, origins, tile_h=th, tile_w=tw),
            gout)

    g_k = jax.grad(loss_k)(feats)
    g_r = jax.grad(loss_r)(feats)
    np.testing.assert_allclose(g_k[..., :9], g_r[..., :9],
                               rtol=2e-4, atol=2e-4)
    # padding lanes carry no gradient
    assert np.abs(np.asarray(g_k[..., 9:])).max() == 0.0


#: gradient-parity sweep: every K regime ({1, 16, 64}) on both the CPU test
#: tile and the production (8, 128) tile, with dead (alpha=0) and saturated
#: (a*G > ALPHA_MAX, where the clamp kills the alpha gradient) splats mixed in
GRAD_SWEEP = [
    # (T, K, th, tw)
    (2, 1, 8, 16),
    (2, 16, 8, 16),
    (3, 64, 8, 16),
    (2, 1, 8, 128),    # production tile shape
    (2, 16, 8, 128),
    (2, 64, 8, 128),
]


@pytest.mark.parametrize("T,K,th,tw", GRAD_SWEEP)
def test_backward_parity_sweep(T, K, th, tw):
    """Pallas rasterize_bwd (interpret) vs jax-autodiff of kernels/ref.py."""
    feats, origins = make_tile_inputs(11, T, K, th, tw, dead_frac=0.25)
    f = np.array(feats)
    # saturate ~20% of the live splats: alpha feature >> 1 makes a*G exceed
    # ALPHA_MAX near the center, exercising the clamp's gradient mask
    r = np.random.default_rng(13)
    sat = (r.uniform(size=(T, K)) < 0.2) & (f[..., 8] > 0)
    f[..., 8] = np.where(sat, 3.0, f[..., 8])
    feats = jnp.asarray(f)
    gout = jnp.asarray(r.normal(size=(T, 4, th, tw)), jnp.float32)

    def loss_k(x):
        return jnp.vdot(
            ops.rasterize_tiles(x, origins, tile_h=th, tile_w=tw,
                                impl="interpret"), gout)

    def loss_r(x):
        return jnp.vdot(
            ref_impl.rasterize_tiles_ref(x, origins, tile_h=th, tile_w=tw),
            gout)

    g_k = jax.grad(loss_k)(feats)
    g_r = jax.grad(loss_r)(feats)
    np.testing.assert_allclose(g_k[..., :9], g_r[..., :9],
                               rtol=5e-4, atol=5e-4)
    assert np.abs(np.asarray(g_k[..., 9:])).max() == 0.0
    assert np.isfinite(np.asarray(g_k)).all()


#: per-dtype parity matrix (PR 8): bf16-policy feature tables through every
#: impl, across the K regimes {1, 16, 64} and the production (8, 128) tile,
#: with dead and saturated splats mixed in (same conditioning as GRAD_SWEEP)
DTYPE_SWEEP = [
    # (T, K, th, tw)
    (2, 1, 8, 16),
    (2, 16, 8, 16),
    (3, 64, 8, 16),
    (2, 64, 8, 128),   # production tile shape
]


def _bf16_case(seed, T, K, th, tw):
    """(f32 feats, bf16 feats, origins, gout) with dead + saturated splats."""
    feats, origins = make_tile_inputs(seed, T, K, th, tw, dead_frac=0.25)
    f = np.array(feats)
    r = np.random.default_rng(seed + 100)
    sat = (r.uniform(size=(T, K)) < 0.2) & (f[..., 8] > 0)
    f[..., 8] = np.where(sat, 3.0, f[..., 8])
    feats = jnp.asarray(f)
    gout = jnp.asarray(r.normal(size=(T, 4, th, tw)), jnp.float32)
    return feats, feats.astype(jnp.bfloat16), origins, gout


@pytest.mark.dtype
@pytest.mark.parametrize("impl", ["ref", "interpret"])
@pytest.mark.parametrize("T,K,th,tw", DTYPE_SWEEP)
def test_bf16_policy_forward(T, K, th, tw, impl):
    """bf16 feature tables: exact impl-parity + bounded error vs f32 truth.

    Two rungs of the tolerance ladder, asserted separately because they
    bound DIFFERENT things:

      exact rung (1e-5): a bf16 table through any impl must equal the f32
        oracle on the PROMOTED table — ops.rasterize_tiles promotes once at
        entry, before any impl divergence, so the only differences left are
        the same float-associativity noise the f32 sweep pins at 1e-5.
        This is the invariant that keeps ref == interpret == pallas per
        dtype (swapping impl under the bf16 policy never changes math).

      truth rung (measured): vs the f32 oracle on the UNROUNDED table the
        error is dominated by bf16 rounding of mean2d at coordinate
        magnitude ~W: ulp(W) = W * 2^-8, i.e. a <= 0.5 px center shift on
        the production strip (W = 256).  Measured over 6 seeds per shape:
        worst-pixel <= 0.44, mean <= 0.008 (pixels in [0, 1]).  Asserted
        with margin at 0.5 / 0.02 — NOT a tight bound, a regression tripwire
        for the policy's real cost.
    """
    feats, fb, origins, _ = _bf16_case(21, T, K, th, tw)
    out_b = ops.rasterize_tiles(fb, origins, tile_h=th, tile_w=tw, impl=impl)
    assert out_b.dtype == jnp.float32  # f32 accumulation regardless of input
    ref_promoted = ref_impl.rasterize_tiles_ref(
        fb.astype(jnp.float32), origins, tile_h=th, tile_w=tw)
    np.testing.assert_allclose(out_b, ref_promoted, rtol=1e-5, atol=1e-5)
    ref_truth = ref_impl.rasterize_tiles_ref(feats, origins,
                                             tile_h=th, tile_w=tw)
    err = np.abs(np.asarray(out_b) - np.asarray(ref_truth))
    assert err.max() <= 0.5, f"worst-pixel {err.max():.3f}"
    assert err.mean() <= 0.02, f"mean {err.mean():.4f}"


@pytest.mark.dtype
@pytest.mark.parametrize("T,K,th,tw", DTYPE_SWEEP)
def test_bf16_policy_gradient(T, K, th, tw):
    """bf16-policy gradients: impl-parity + direction agreement vs f32.

    The custom-VJP boundary rounds feature cotangents back to the input
    dtype (the transpose of the entry promote), so both legs see
    bf16-rounded gradients:

      impl parity (2e-3): interpret vs ref on the SAME bf16 table — both
        compute the cotangent in f32 and round it identically at the
        boundary; residual differences are f32 associativity noise that
        lands the two sides on opposite sides of a bf16 rounding boundary,
        i.e. at most ~1 bf16 ulp of the gradient magnitude (measured
        worst-case 9.8e-4 across the sweep).

      truth (cosine >= 0.95): vs the f32 gradient the pointwise error is
        forward-divergence dominated (the 0.5 px mean2d shift moves which
        pixels a splat touches), so elementwise tolerances are
        meaningless; what training needs is the DIRECTION.  Measured
        cosine >= 0.964 across the sweep (6 seeds/shape); asserted 0.95.
        Skipped when the f32 gradient is ~0 (all-dead seeds).
    """
    feats, fb, origins, gout = _bf16_case(21, T, K, th, tw)

    def loss(x, impl):
        return jnp.vdot(
            ops.rasterize_tiles(x, origins, tile_h=th, tile_w=tw, impl=impl),
            gout)

    gb_ref = jax.grad(lambda x: loss(x, "ref"))(fb)
    gb_int = jax.grad(lambda x: loss(x, "interpret"))(fb)
    assert gb_ref.dtype == jnp.bfloat16  # cotangent rounded at the boundary
    np.testing.assert_allclose(np.asarray(gb_ref, np.float32),
                               np.asarray(gb_int, np.float32),
                               rtol=2e-3, atol=2e-3)
    g32 = jax.grad(lambda x: loss(x, "ref"))(feats)
    a = np.asarray(gb_ref[..., :9], np.float32).ravel()
    b = np.asarray(g32[..., :9]).ravel()
    if np.linalg.norm(b) > 1e-3:
        cos = float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b)))
        assert cos >= 0.95, f"gradient cosine {cos:.4f}"
    # padding lanes carry no gradient under any dtype
    assert np.abs(np.asarray(gb_ref[..., 9:], np.float32)).max() == 0.0
    assert np.isfinite(np.asarray(gb_ref, np.float32)).all()


def test_backward_empty_slots_zero_grad():
    feats, origins = make_tile_inputs(3, 2, 8, 8, 16, dead_frac=1.0)
    g = jax.grad(
        lambda f: ops.rasterize_tiles(f, origins, tile_h=8, tile_w=16,
                                      impl="interpret").sum()
    )(feats)
    # alpha == 0 slots: only d/d alpha may be non-zero (alpha gradient flows
    # through a*G even at a==0); geometry/color grads must be exactly 0
    assert np.abs(np.asarray(g[..., :8])).max() == 0.0


def test_transmittance_saturation():
    """A fully opaque front splat hides everything behind it."""
    feats, origins = make_tile_inputs(1, 1, 16, 8, 16)
    f = np.zeros((1, 16, 16), np.float32)
    # front splat: huge flat gaussian covering the tile, alpha ~ 0.99
    f[0, 0] = [8, 4, 1e-6, 0.0, 1e-6, 1.0, 0.0, 0.0, 0.999] + [0] * 7
    # behind: bright green splat
    f[0, 1] = [8, 4, 1e-6, 0.0, 1e-6, 0.0, 1.0, 0.0, 0.9] + [0] * 7
    out = ops.rasterize_tiles(jnp.asarray(f), origins, tile_h=8, tile_w=16,
                              impl="interpret")
    out = np.asarray(out)
    assert out[0, 0].min() > 0.95          # red dominates
    assert out[0, 1].max() < 0.05          # green hidden (T <= 0.01)
    assert out[0, 3].min() > 0.98          # coverage ~ 1


def test_ref_impl_is_default_on_cpu():
    assert ops.resolve_impl("auto") == "ref"
