"""Merge-path coverage: merge_partitions / merge_padded / dedupe_mask vs a
brute-force oracle (paper §II step 6).

The contract: at merge time a partition contributes exactly its ACTIVE,
OWNED gaussians (ghosts carry their source partition id and are the
neighbour's responsibility), so every source gaussian appears exactly once
in the merged scene — including densified children (which inherit the
parent's owner) and through the padded jit-friendly variant.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.gaussians import Gaussians, from_points
from repro.core.merge import dedupe_mask, merge_padded, merge_partitions


def make_part(key, n, part_id, *, ghost_ids=(), inactive=()):
    """A partition buffer: owner == part_id except ``ghost_ids`` rows, which
    carry a neighbour's id; ``inactive`` rows are masked off."""
    ks = jax.random.split(key, 3)
    owner = np.full((n,), part_id, np.int32)
    for i, src in ghost_ids:
        owner[i] = src
    active = np.ones((n,), bool)
    for i in inactive:
        active[i] = False
    return Gaussians(
        means=jax.random.normal(ks[0], (n, 3)),
        log_scales=jax.random.normal(ks[1], (n, 3)) * 0.1,
        quats=jnp.tile(jnp.array([1.0, 0, 0, 0]), (n, 1)),
        opacity_logit=jax.random.normal(ks[2], (n,)),
        colors=jnp.zeros((n, 3)),
        active=jnp.asarray(active),
        owner=jnp.asarray(owner),
    )


def oracle_merge(parts, part_ids):
    """Brute-force row-by-row reference: walk every partition in order and
    keep each row iff active and owned."""
    rows = {k: [] for k in Gaussians._fields}
    for pid, g in zip(part_ids, parts):
        for i in range(g.capacity):
            if bool(g.active[i]) and int(g.owner[i]) == pid:
                for k in Gaussians._fields:
                    rows[k].append(np.asarray(getattr(g, k)[i]))
    return {k: (np.stack(v) if v else np.zeros((0,))) for k, v in rows.items()}


@pytest.fixture
def three_parts():
    key = jax.random.PRNGKey(0)
    k0, k1, k2 = jax.random.split(key, 3)
    # p0: plain; p1: carries two ghosts sourced from p0 and p2 plus a dead
    # slot; p2: a ghost from p1 that is ALSO inactive (must drop for both
    # reasons)
    p0 = make_part(k0, 5, 0)
    p1 = make_part(k1, 6, 1, ghost_ids=[(0, 0), (3, 2)], inactive=(4,))
    p2 = make_part(k2, 4, 2, ghost_ids=[(1, 1), (1, 1)], inactive=(1,))
    return [p0, p1, p2], [0, 1, 2]


def test_dedupe_mask_is_active_and_owned(three_parts):
    parts, ids = three_parts
    for g, pid in zip(parts, ids):
        want = np.asarray(g.active) & (np.asarray(g.owner) == pid)
        np.testing.assert_array_equal(np.asarray(dedupe_mask(g, pid)), want)


def test_merge_partitions_matches_bruteforce_oracle(three_parts):
    parts, ids = three_parts
    merged = merge_partitions(parts, ids)
    want = oracle_merge(parts, ids)
    assert merged.capacity == len(want["means"])
    for k in Gaussians._fields:
        np.testing.assert_array_equal(np.asarray(getattr(merged, k)),
                                      want[k], err_msg=k)
    # every merged gaussian is owned by its contributor: no ghost survives
    assert bool(merged.active.all())


def test_merge_partitions_ghost_dedupe_exactly_once():
    """The SAME physical gaussian replicated into a neighbour as a ghost
    appears exactly once in the merged scene."""
    pts = np.array([[0.1, 0.2, 0.3], [0.7, 0.8, 0.9]], np.float32)
    cols = np.full((2, 3), 0.5, np.float32)
    # partition 0 owns both points; partition 1 holds a ghost COPY of row 1
    p0 = from_points(jnp.asarray(pts), jnp.asarray(cols), owner_id=0)
    p1 = from_points(jnp.asarray(pts[1:]), jnp.asarray(cols[1:]), owner_id=0)
    merged = merge_partitions([p0, p1], [0, 1])
    assert merged.capacity == 2
    np.testing.assert_allclose(np.asarray(merged.means), pts)


def test_merge_padded_matches_unpadded_on_live_rows(three_parts):
    parts, ids = three_parts
    compact = merge_partitions(parts, ids)
    padded = merge_padded(parts, ids)
    assert padded.capacity == sum(g.capacity for g in parts)
    live = np.asarray(padded.active)
    assert live.sum() == compact.capacity
    for k in Gaussians._fields:
        if k == "active":
            continue
        np.testing.assert_array_equal(
            np.asarray(getattr(padded, k))[live],
            np.asarray(getattr(compact, k)), err_msg=k)
    # explicit capacity pads with INACTIVE zero rows
    padded2 = merge_padded(parts, ids, capacity=32)
    assert padded2.capacity == 32
    assert np.asarray(padded2.active)[15:].sum() == 0
    np.testing.assert_array_equal(
        np.asarray(padded2.means)[np.asarray(padded2.active)],
        np.asarray(getattr(padded, "means"))[live])
    # a capacity below the concatenated size is a loud error, not a crop
    with pytest.raises(AssertionError):
        merge_padded(parts, ids, capacity=8)


def test_merge_empty_partition_contributes_nothing(three_parts):
    parts, ids = three_parts
    # an all-ghost partition (nothing owned) and an all-dead partition
    all_ghost = make_part(jax.random.PRNGKey(7), 3, 3,
                          ghost_ids=[(0, 0), (1, 1), (2, 2)])
    all_dead = make_part(jax.random.PRNGKey(8), 3, 4,
                         inactive=(0, 1, 2))
    merged = merge_partitions(parts + [all_ghost, all_dead], ids + [3, 4])
    base = merge_partitions(parts, ids)
    assert merged.capacity == base.capacity
    for k in Gaussians._fields:
        np.testing.assert_array_equal(np.asarray(getattr(merged, k)),
                                      np.asarray(getattr(base, k)),
                                      err_msg=k)
    # padded variant keeps the dead slots but none of them are active
    padded = merge_padded(parts + [all_ghost, all_dead], ids + [3, 4])
    assert int(np.asarray(padded.active).sum()) == base.capacity


def test_merge_default_part_ids_are_positional(three_parts):
    parts, ids = three_parts
    a = merge_partitions(parts)            # ids default to 0..P-1
    b = merge_partitions(parts, ids)
    for k in Gaussians._fields:
        np.testing.assert_array_equal(np.asarray(getattr(a, k)),
                                      np.asarray(getattr(b, k)))


# ---------------------------------------------------------------------------
# Order invariance (property): the merged SCENE is a set, not a sequence
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # degraded fallback (see tests/_hyp.py)
    from _hyp import given, settings, st


@st.composite
def merge_scenarios(draw):
    """Random partition sets: 2-4 partitions of 1-6 rows, ~30% ghost rows
    (source drawn over ALL partition ids — drawing the holder's own id
    degenerates into an owned row, covering both branches), ~20% dead rows,
    plus a random presentation order."""
    n_parts = draw(st.integers(2, 4))
    seed = draw(st.integers(0, 10_000))
    rng = np.random.default_rng(seed)
    parts, ids = [], list(range(n_parts))
    for pid in ids:
        n = int(rng.integers(1, 7))
        ghosts = [(i, int(rng.integers(0, n_parts)))
                  for i in range(n) if rng.uniform() < 0.3]
        dead = tuple(i for i in range(n) if rng.uniform() < 0.2)
        parts.append(make_part(jax.random.PRNGKey(seed * 31 + pid), n, pid,
                               ghost_ids=ghosts, inactive=dead))
    return parts, ids, [int(i) for i in rng.permutation(n_parts)]


def _canon_rows(g):
    """All fields flattened to one (capacity, D) float64 matrix, rows in a
    content-determined (lexicographic) order — the set-of-gaussians view."""
    mat = np.concatenate(
        [np.asarray(getattr(g, k)).reshape(g.capacity, -1).astype(np.float64)
         for k in Gaussians._fields], axis=1)
    return mat[np.lexsort(mat.T[::-1])]


@settings(max_examples=25, deadline=None)
@given(merge_scenarios())
def test_merge_order_invariance_composed_with_dedupe_oracle(scenario):
    """merge_partitions(perm(parts), perm(ids)) is the same merged model up
    to row order — and what each presentation keeps is EXACTLY the rows
    dedupe_mask selects, so the property composes with the per-partition
    oracle rather than merely self-agreeing."""
    parts, ids, perm = scenario
    merged = merge_partitions(parts, ids)
    # dedupe-mask composition: the merged table IS the concatenation of
    # each partition's mask-selected rows, in partition order
    want = [np.asarray(g.means)[np.asarray(dedupe_mask(g, pid))]
            for g, pid in zip(parts, ids)]
    want = (np.concatenate(want) if sum(len(w) for w in want)
            else np.zeros((0, 3), np.float32))
    np.testing.assert_array_equal(np.asarray(merged.means), want)
    # order invariance, all fields: permute parts AND ids together
    merged_p = merge_partitions([parts[i] for i in perm],
                                [ids[i] for i in perm])
    assert merged_p.capacity == merged.capacity
    np.testing.assert_array_equal(_canon_rows(merged_p), _canon_rows(merged))
    # ... and through the padded variant's live rows
    padded_p = merge_padded([parts[i] for i in perm], [ids[i] for i in perm])
    live = np.asarray(padded_p.active)
    assert int(live.sum()) == merged.capacity
