"""Checkpoint/restart (incl. elastic re-sharding), heartbeats, retry,
bounded-staleness merge."""

import json
import os
import subprocess
import sys
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (CheckpointManager, Heartbeat,
                           bounded_staleness_merge, retry_step)


def tree_eq(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                   "s": jnp.float32(3.5)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = make_tree()
    mgr.save(7, tree, extra={"note": "hi"})
    assert mgr.latest_step() == 7
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    got, extra = mgr.restore(7, like)
    assert tree_eq(got, tree)
    assert extra["note"] == "hi"


def test_atomic_commit_ignores_partial(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, make_tree())
    # simulate a crash mid-write: directory without _COMPLETE
    os.makedirs(tmp_path / "step_000000002")
    (tmp_path / "step_000000002" / "manifest.json").write_text("{}")
    assert mgr.latest_step() == 1


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, make_tree())
    assert mgr.all_steps() == [3, 4]


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, make_tree())
    bad = {"w": jnp.zeros((4, 4)),
           "nested": {"b": jnp.zeros(10, jnp.int32), "s": jnp.float32(0)}}
    with pytest.raises(AssertionError):
        mgr.restore(1, bad)


def test_per_partition_checkpoints(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    for p in range(3):
        mgr.save(5, {"x": jnp.full((4,), p)}, partition=p)
    like = {"x": jnp.zeros((4,))}
    for p in range(3):
        got, _ = mgr.restore(5, like, partition=p)
        assert int(got["x"][0]) == p


def test_bounded_staleness_merge(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=0)
    like = {"x": jnp.zeros((2,))}
    # partition 0 checkpointed at steps 10 and 20; partition 1 only at 10
    mgr.save(10, {"x": jnp.ones((2,)) * 10}, partition=0)
    mgr.save(10, {"x": jnp.ones((2,)) * 11}, partition=1)
    mgr.save(20, {"x": jnp.ones((2,)) * 20}, partition=0)
    trees, steps, laggards = bounded_staleness_merge(mgr, 2, like, max_lag=5)
    assert steps == [20, 10]
    assert laggards == [1]           # partition 1 lags beyond max_lag
    assert float(trees[0]["x"][0]) == 20 and float(trees[1]["x"][0]) == 11


def test_retry_step_recovers():
    calls = {"n": 0}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return x + 1

    assert retry_step(flaky, 1, retries=3) == 2
    assert calls["n"] == 3
    with pytest.raises(RuntimeError):
        retry_step(lambda: (_ for _ in ()).throw(RuntimeError("perm")),
                   retries=1)


def test_heartbeat_staleness(tmp_path):
    hb0 = Heartbeat(str(tmp_path), "w0", interval=0)
    hb1 = Heartbeat(str(tmp_path), "w1", interval=0)
    hb0.beat(1, force=True)
    hb1.beat(1, force=True)
    assert hb0.stale(timeout=60) == []
    # age w1's heartbeat artificially
    p = hb1.path()
    rec = json.loads(open(p).read())
    rec["time"] -= 120
    open(p, "w").write(json.dumps(rec))
    assert hb0.stale(timeout=60) == ["w1"]


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import sys
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
sys.path.insert(0, "{src}")
from repro.runtime import CheckpointManager

mode, root = sys.argv[1], sys.argv[2]
mesh = jax.make_mesh(({d}, 2), ("data", "model"))
sh = NamedSharding(mesh, P("data", "model"))
mgr = CheckpointManager(root)
if mode == "save":
    x = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8), sh)
    mgr.save(3, {{"x": x}})
else:
    like = {{"x": jnp.zeros((8, 8), jnp.float32)}}
    got, _ = mgr.restore(3, like, shardings={{"x": sh}})
    assert got["x"].sharding.num_devices == {n}, got["x"].sharding
    np.testing.assert_array_equal(
        np.asarray(got["x"]), np.arange(64, dtype=np.float32).reshape(8, 8))
print("OK", mode)
"""


@pytest.mark.slow
def test_elastic_restore_across_meshes(tmp_path):
    """Save on an 8-device (4,2) mesh, restore onto 4-device (2,2) — the
    'lost a pod' path.  Subprocesses force different CPU device counts."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    root = str(tmp_path / "ck")

    def run(n, d, mode):
        code = ELASTIC_SCRIPT.format(n=n, d=d, src=os.path.abspath(src))
        out = subprocess.run([sys.executable, "-c", code, mode, root],
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stderr[-2000:]
        assert f"OK {mode}" in out.stdout

    run(8, 4, "save")
    run(4, 2, "restore")
