"""Checkpoint/restart (incl. elastic re-sharding), heartbeats, retry,
bounded-staleness merge."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (CheckpointManager, Heartbeat,
                           bounded_staleness_merge, retry_step)


def tree_eq(a, b):
    return all(
        np.array_equal(np.asarray(x), np.asarray(y))
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b))
    )


def make_tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {
        "w": jax.random.normal(k, (8, 16)),
        "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                   "s": jnp.float32(3.5)},
    }


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = make_tree()
    mgr.save(7, tree, extra={"note": "hi"})
    assert mgr.latest_step() == 7
    like = jax.tree.map(lambda x: jnp.zeros_like(x), tree)
    got, extra = mgr.restore(7, like)
    assert tree_eq(got, tree)
    assert extra["note"] == "hi"


def test_atomic_commit_ignores_partial(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, make_tree())
    # simulate a crash mid-write: directory without _COMPLETE
    os.makedirs(tmp_path / "step_000000002")
    (tmp_path / "step_000000002" / "manifest.json").write_text("{}")
    assert mgr.latest_step() == 1


def test_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save(s, make_tree())
    assert mgr.all_steps() == [3, 4]


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, make_tree())
    bad = {"w": jnp.zeros((4, 4)),
           "nested": {"b": jnp.zeros(10, jnp.int32), "s": jnp.float32(0)}}
    with pytest.raises(AssertionError):
        mgr.restore(1, bad)


def test_per_partition_checkpoints(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    for p in range(3):
        mgr.save(5, {"x": jnp.full((4,), p)}, partition=p)
    like = {"x": jnp.zeros((4,))}
    for p in range(3):
        got, _ = mgr.restore(5, like, partition=p)
        assert int(got["x"][0]) == p


def test_restore_latest_is_partition_aware(tmp_path):
    """Partitions checkpoint independently: a lagging partition must resume
    from ITS OWN newest step, not crash on a step a faster peer advertised
    (all_steps(partition=None) keeps the any-partition retention view)."""
    mgr = CheckpointManager(str(tmp_path), keep=0)
    like = {"x": jnp.zeros((2,))}
    mgr.save(10, {"x": jnp.ones((2,))}, partition=0)
    mgr.save(10, {"x": jnp.ones((2,)) * 2}, partition=1)
    mgr.save(20, {"x": jnp.ones((2,)) * 3}, partition=0)
    got, _, step = mgr.restore_latest(like, partition=1)   # p1 lags at 10
    assert step == 10 and float(got["x"][0]) == 2
    got, _, step = mgr.restore_latest(like, partition=0)
    assert step == 20 and float(got["x"][0]) == 3
    _, _, step = mgr.restore_latest(like, partition=2)     # never saved
    assert step is None
    assert mgr.latest_step() == 20          # retention still sees every step
    assert mgr.all_steps(partition=1) == [10]
    # a dir holding ONLY per-partition saves is not restorable as a root
    # tree: restore_latest must skip those steps (start fresh), not crash
    # on restore()'s root _COMPLETE assert
    got, _, step = mgr.restore_latest(like)
    assert step is None and got is like
    mgr.save(15, {"x": jnp.ones((2,)) * 7})                # root save
    got, _, step = mgr.restore_latest(like)                # 20 is p0-only:
    assert step == 15 and float(got["x"][0]) == 7          # skipped


def test_bounded_staleness_merge(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=0)
    like = {"x": jnp.zeros((2,))}
    # partition 0 checkpointed at steps 10 and 20; partition 1 only at 10
    mgr.save(10, {"x": jnp.ones((2,)) * 10}, partition=0)
    mgr.save(10, {"x": jnp.ones((2,)) * 11}, partition=1)
    mgr.save(20, {"x": jnp.ones((2,)) * 20}, partition=0)
    trees, steps, laggards = bounded_staleness_merge(mgr, 2, like, max_lag=5)
    assert steps == [20, 10]
    assert laggards == [1]           # partition 1 lags beyond max_lag
    assert float(trees[0]["x"][0]) == 20 and float(trees[1]["x"][0]) == 11


def test_retry_step_recovers():
    calls = {"n": 0}

    def flaky(x):
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError("transient")
        return x + 1

    assert retry_step(flaky, 1, retries=3) == 2
    assert calls["n"] == 3
    with pytest.raises(RuntimeError):
        retry_step(lambda: (_ for _ in ()).throw(RuntimeError("perm")),
                   retries=1)


def test_heartbeat_staleness(tmp_path):
    hb0 = Heartbeat(str(tmp_path), "w0", interval=0)
    hb1 = Heartbeat(str(tmp_path), "w1", interval=0)
    hb0.beat(1, force=True)
    hb1.beat(1, force=True)
    assert hb0.stale(timeout=60) == []
    # age w1's heartbeat artificially
    p = hb1.path()
    rec = json.loads(open(p).read())
    rec["time"] -= 120
    open(p, "w").write(json.dumps(rec))
    assert hb0.stale(timeout=60) == ["w1"]


def _tiny_fit_setup():
    import jax.numpy as jnp
    from repro.core.cameras import orbital_rig
    from repro.core.gaussians import from_points
    from repro.core.tiling import TileGrid
    from repro.core.train import GSTrainCfg
    from repro.data.isosurface import point_cloud_for

    N, res, V = 128, 32, 2
    pts, cols = point_cloud_for("sphere_shell", N)
    pts, cols = pts[:N], cols[:N]
    cams = orbital_rig(V, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
    grid = TileGrid(res, res, 8, 16)
    cfg = GSTrainCfg(K=8, lr_colors=5e-2, max_new=32,
                     densify_grad_thresh=1e-9)
    g0 = from_points(jnp.asarray(pts), jnp.asarray(cols), capacity=N + 64,
                     opacity=0.7)
    gts = jnp.full((V, res, res, 3), 0.5)
    return g0, cams, gts, cfg, grid


def test_restore_latest_convenience(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    like = {"x": jnp.zeros((3,))}
    got, extra, step = mgr.restore_latest(like)
    assert step is None and extra == {} and got is like
    mgr.save(4, {"x": jnp.ones((3,))}, extra={"k": 1})
    got, extra, step = mgr.restore_latest(like)
    assert step == 4 and extra == {"k": 1}
    assert float(got["x"][0]) == 1.0


def test_fit_partition_checkpoint_roundtrip_resumes_schedule(tmp_path,
                                                            monkeypatch):
    """Mid-lifecycle save/restore of (params, opt, TierSchedule): the
    resumed run keeps the checkpointed caps (NO init re-probe — counted via
    a monkeypatched probe), and its loss curve equals the uninterrupted
    run's tail."""
    from repro.core import train as train_mod
    from repro.core.train import fit_partition

    g0, cams, gts, cfg, grid = _tiny_fit_setup()
    kw = dict(steps=6, extent=1.0, densify_every=2, densify_from=0,
              grid=grid, ckpt_every=3)

    # uninterrupted reference run (saves at steps 3 and 6)
    s_full = cfg.tier_schedule()
    _, _, losses_full = fit_partition(
        g0, cams, gts, None, cfg, key=jax.random.PRNGKey(0),
        schedule=s_full, ckpt=CheckpointManager(str(tmp_path / "full")),
        **kw)
    assert len(losses_full) == 6

    # interrupted run: stop at step 3...
    mgr = CheckpointManager(str(tmp_path / "ab"))
    s_a = cfg.tier_schedule()
    fit_partition(g0, cams, gts, None, cfg, key=jax.random.PRNGKey(0),
                  schedule=s_a, ckpt=mgr, **{**kw, "steps": 3})
    assert mgr.latest_step() == 3

    # ...the saved schedule state round-trips exactly...
    from repro.core.tiling import TierSchedule
    from repro.core.train import init_opt
    _, extra = mgr.restore(3, (g0, init_opt(g0)))
    s_saved = TierSchedule.from_state(extra["schedule"])
    assert s_saved.k_tiers == s_a.k_tiers
    assert s_saved.tier_caps == s_a.tier_caps

    # ...and the resumed run probes ONLY after densify events (the initial
    # probe is skipped because the restored schedule already has caps)
    probes = {"n": 0}
    real_probe = train_mod.occupancy_probe_jit

    def counting_probe(*a, **k):
        probes["n"] += 1
        return real_probe(*a, **k)

    monkeypatch.setattr(train_mod, "occupancy_probe_jit", counting_probe)
    s_b = cfg.tier_schedule()
    _, _, losses_resumed = fit_partition(
        g0, cams, gts, None, cfg, key=jax.random.PRNGKey(0),
        schedule=s_b, ckpt=mgr, **kw)
    assert s_b.tier_caps is not None
    # resume covers steps 3..6: densify events at i=3 and i=5 -> exactly 2
    # re-probes, zero init probes
    assert probes["n"] == 2, probes
    assert len(losses_resumed) == 3
    np.testing.assert_allclose(losses_resumed, losses_full[3:],
                               rtol=1e-6, atol=1e-7)


ELASTIC_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import sys
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
sys.path.insert(0, "{src}")
from repro.runtime import CheckpointManager

mode, root = sys.argv[1], sys.argv[2]
mesh = jax.make_mesh(({d}, 2), ("data", "model"))
sh = NamedSharding(mesh, P("data", "model"))
mgr = CheckpointManager(root)
if mode == "save":
    x = jax.device_put(jnp.arange(64, dtype=jnp.float32).reshape(8, 8), sh)
    mgr.save(3, {{"x": x}})
else:
    like = {{"x": jnp.zeros((8, 8), jnp.float32)}}
    got, _ = mgr.restore(3, like, shardings={{"x": sh}})
    assert got["x"].sharding.num_devices == {n}, got["x"].sharding
    np.testing.assert_array_equal(
        np.asarray(got["x"]), np.arange(64, dtype=np.float32).reshape(8, 8))
print("OK", mode)
"""


@pytest.mark.slow
def test_elastic_restore_across_meshes(tmp_path):
    """Save on an 8-device (4,2) mesh, restore onto 4-device (2,2) — the
    'lost a pod' path.  Subprocesses force different CPU device counts."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    root = str(tmp_path / "ck")

    def run(n, d, mode):
        code = ELASTIC_SCRIPT.format(n=n, d=d, src=os.path.abspath(src))
        out = subprocess.run([sys.executable, "-c", code, mode, root],
                             capture_output=True, text=True, timeout=300)
        assert out.returncode == 0, out.stderr[-2000:]
        assert f"OK {mode}" in out.stdout

    run(8, 4, "save")
    run(4, 2, "restore")


def test_unshaped_restore(tmp_path):
    """Shape-free templates (UNSHAPED sentinels) restore whatever the
    checkpoint holds — the serve-side loading idiom, where the merged
    model's capacity is a training outcome the server cannot predict."""
    from repro.core.gaussians import Gaussians
    from repro.runtime import UNSHAPED, unshaped_like

    tree = make_tree()
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, tree)
    got, _ = mgr.restore(1, unshaped_like(tree))
    assert tree_eq(got, tree)

    # NamedTuple-CLASS form: one sentinel per field, no instance needed
    tmpl = unshaped_like(Gaussians)
    assert isinstance(tmpl, Gaussians)
    assert all(leaf is UNSHAPED for leaf in jax.tree.leaves(tmpl))

    # structure (leaf count) is still asserted — only shapes float
    with pytest.raises(AssertionError):
        mgr.restore(1, unshaped_like({"one_leaf": 0}))


@pytest.mark.dtype
def test_quantized_cold_checkpoint_roundtrip(tmp_path):
    """int8 cold-attribute checkpointing (runtime.checkpoint.quantize_cold):
    SH color + opacity logit stored int8 with per-tensor scales riding
    extra["quant"], restored shape-free and dequantized; per-element error
    bounded by scale/2 = max|x|/254, geometry bit-identical, the checkpoint
    on disk actually smaller, and the rendered image error bounded."""
    from repro.core.cameras import orbital_rig
    from repro.core.gaussians import Gaussians, from_points
    from repro.core.pipeline import render_views
    from repro.core.tiling import TileGrid
    from repro.data.isosurface import point_cloud_for
    from repro.runtime import unshaped_like
    from repro.runtime.checkpoint import (COLD_QUANT_FIELDS, dequantize_cold,
                                          quantize_cold)

    N, res = 128, 32
    pts, cols = point_cloud_for("sphere_shell", N)
    g = from_points(jnp.asarray(pts[:N]), jnp.asarray(cols[:N]), opacity=0.7)

    q, meta = quantize_cold(g)
    assert meta["mode"] == "int8"
    assert set(meta["fields"]) == set(COLD_QUANT_FIELDS)
    for name in COLD_QUANT_FIELDS:
        assert np.asarray(getattr(q, name)).dtype == np.int8

    # save both variants; the quantized tree must be smaller ON DISK
    # (3 bytes/element saved on every quantized leaf)
    m32 = CheckpointManager(str(tmp_path / "f32"))
    mq = CheckpointManager(str(tmp_path / "q"))
    d32 = m32.save(1, g)
    dq = mq.save(1, q, extra={"quant": meta})

    def nbytes(d):
        return sum(os.path.getsize(os.path.join(d, f))
                   for f in os.listdir(d) if f.endswith(".npy"))

    assert nbytes(dq) < 0.9 * nbytes(d32), (nbytes(dq), nbytes(d32))

    # shape-free restore + dequantize (the serving path)
    got, extra = mq.restore(1, unshaped_like(Gaussians))
    got = dequantize_cold(got, extra["quant"])
    for name in COLD_QUANT_FIELDS:
        x = np.asarray(getattr(g, name), np.float32)
        y = np.asarray(getattr(got, name))
        assert y.dtype == np.float32
        # symmetric per-tensor scale: error <= scale/2 = max|x|/254
        bound = np.abs(x).max() / 254.0 + 1e-7
        assert np.abs(y - x).max() <= bound, name
    # geometry untouched, bit-for-bit
    for name in ("means", "log_scales", "quats", "active"):
        np.testing.assert_array_equal(np.asarray(getattr(got, name)),
                                      np.asarray(getattr(g, name)), name)

    # rendered-image error: color/opacity quantization error <= max|x|/254
    # per attribute propagates through compositing (convex in color, smooth
    # in alpha) to the same order in pixel space; asserted at 0.02 worst
    # pixel / 0.005 mean with margin (measured ~4e-3 / ~1e-4)
    grid = TileGrid(res, res, 8, 16)
    cams = orbital_rig(2, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
    rgb32, _ = render_views(g, cams, grid, K=8)
    rgbq, _ = render_views(got, cams, grid, K=8)
    err = np.abs(np.asarray(rgbq) - np.asarray(rgb32))
    assert err.max() <= 0.02, err.max()
    assert err.mean() <= 0.005, err.mean()

    # unknown quant modes refuse loudly
    with pytest.raises(ValueError):
        dequantize_cold(got, {"mode": "int4", "fields": {}})


@pytest.mark.dtype
def test_quantized_midrun_resume_bounded_divergence(tmp_path):
    """Resume from a mid-run checkpoint whose cold attributes went through
    the int8 quantize->dequantize round trip: the resumed loss curve stays
    within a bounded band of the uninterrupted f32 run (the injected
    perturbation is <= max|x|/254 per element, and training re-absorbs it)
    rather than matching at 1e-6 — quantization is lossy and the test says
    so."""
    from repro.core.train import fit_partition, init_opt
    from repro.runtime.checkpoint import dequantize_cold, quantize_cold

    g0, cams, gts, cfg, grid = _tiny_fit_setup()
    kw = dict(steps=6, extent=1.0, grid=grid, ckpt_every=3)

    s_full = cfg.tier_schedule()
    _, _, losses_full = fit_partition(
        g0, cams, gts, None, cfg, key=jax.random.PRNGKey(0),
        schedule=s_full, ckpt=CheckpointManager(str(tmp_path / "full")),
        **kw)

    mgr = CheckpointManager(str(tmp_path / "q"))
    s_a = cfg.tier_schedule()
    fit_partition(g0, cams, gts, None, cfg, key=jax.random.PRNGKey(0),
                  schedule=s_a, ckpt=mgr, **{**kw, "steps": 3})

    # quantize-round-trip the saved params in place (opt state untouched)
    (g3, opt3), extra = mgr.restore(3, (g0, init_opt(g0)))
    g3q = dequantize_cold(*quantize_cold(g3))
    mgr.save(3, (g3q, opt3), extra=extra)

    s_b = cfg.tier_schedule()
    _, _, losses_resumed = fit_partition(
        g0, cams, gts, None, cfg, key=jax.random.PRNGKey(0),
        schedule=s_b, ckpt=mgr, **kw)
    assert len(losses_resumed) == 3
    # bounded divergence: per-step loss within 5% relative + 1e-3 absolute
    # of the f32 curve (measured gap ~1e-4; NOT the exact-resume 1e-6 pin)
    np.testing.assert_allclose(losses_resumed, losses_full[3:],
                               rtol=5e-2, atol=1e-3)


@pytest.mark.slow
def test_train_serve_roundtrip(tmp_path):
    """launch/train.py --gs --smoke writes a merged checkpoint + final
    render; a fresh process restores it shape-free and reproduces the
    trainer's merged render to 1e-6, and the serving loader builds a
    working server from the same tree."""
    from repro.core.cameras import orbital_rig
    from repro.core.gaussians import Gaussians
    from repro.core.pipeline import render_views
    from repro.core.serving import GSRenderServer
    from repro.core.tiling import TileGrid
    from repro.runtime import unshaped_like

    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    ckpt = str(tmp_path / "gs")
    env = dict(os.environ, PYTHONPATH=src)
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--gs", "--smoke",
         "--host-devices", "4", "--steps", "3", "--ckpt-dir", ckpt],
        capture_output=True, text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stderr[-2000:]

    mgr = CheckpointManager(os.path.join(ckpt, "merged"))
    g, extra, step = mgr.restore_latest(unshaped_like(Gaussians))
    assert step is not None
    meta = extra["scene"]
    res = int(meta["resolution"])
    grid = TileGrid(res, res, int(meta["tile_h"]), int(meta["tile_w"]))
    cams = orbital_rig(int(meta["n_views"]), np.asarray(meta["center"]),
                       float(meta["radius"]), width=res, height=res)
    rgb, _ = render_views(g, cams, grid, K=int(meta["K"]))
    want = np.load(os.path.join(ckpt, "render_final.npy"))
    assert rgb.shape == want.shape
    np.testing.assert_allclose(rgb, want, rtol=1e-6, atol=1e-6)

    # serving restore path: same checkpoint -> a working batched server
    server, extra2 = GSRenderServer.from_checkpoint(ckpt)
    assert extra2["scene"] == meta
    results = server.serve(orbital_rig(
        2, np.asarray(meta["center"]), float(meta["radius"]),
        width=res, height=res))
    assert len(results) == 2
    assert all(np.isfinite(r.rgb).all() for r in results)
    assert server.telemetry()["misses"] == 2


@pytest.mark.slow
@pytest.mark.dtype
def test_train_serve_roundtrip_bf16_quantized(tmp_path):
    """The full mixed-precision handoff: launch/train.py --gs with
    --dtype-policy bf16 --ckpt-quantize int8 trains and writes an int8
    cold-attribute merged checkpoint; serving restores it (dequantizing)
    under a bf16 ServeCfg and renders finite images; the dequantized model
    reproduces the trainer's f32 eval render within the int8 quantization
    band; and a resume under the DEFAULT f32 policy fails loudly with the
    documented mismatch error instead of silently forking the loss curve."""
    from repro.core.cameras import orbital_rig
    from repro.core.gaussians import Gaussians
    from repro.core.pipeline import render_views
    from repro.core.serving import GSRenderServer
    from repro.core.tiling import TileGrid
    from repro.runtime import unshaped_like
    from repro.runtime.checkpoint import dequantize_cold

    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    ckpt = str(tmp_path / "gs")
    env = dict(os.environ, PYTHONPATH=src)
    base = [sys.executable, "-m", "repro.launch.train", "--gs", "--smoke",
            "--host-devices", "4", "--ckpt-dir", ckpt]
    out = subprocess.run(
        base + ["--steps", "3", "--dtype-policy", "bf16",
                "--ckpt-quantize", "int8"],
        capture_output=True, text=True, timeout=900, env=env)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "dtype=bf16" in out.stdout

    # the merged checkpoint really stores int8 cold attributes
    # (Gaussians leaf order: colors is leaf 4)
    mgr = CheckpointManager(os.path.join(ckpt, "merged"))
    step = mgr.latest_restorable_step()
    with open(os.path.join(mgr._step_dir(step), "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["leaves"][4]["dtype"] == "int8", manifest["leaves"]
    assert "quant" in manifest["extra"]

    # dequantized restore reproduces the trainer's f32 eval render within
    # the int8 band (same 0.02/0.005 envelope as the unit round-trip test;
    # the trainer rendered render_final.npy from the UNQUANTIZED merge)
    g, extra, _ = mgr.restore_latest(unshaped_like(Gaussians))
    g = dequantize_cold(g, extra["quant"])
    meta = extra["scene"]
    res = int(meta["resolution"])
    grid = TileGrid(res, res, int(meta["tile_h"]), int(meta["tile_w"]))
    cams = orbital_rig(int(meta["n_views"]), np.asarray(meta["center"]),
                       float(meta["radius"]), width=res, height=res)
    rgb, _ = render_views(g, cams, grid, K=int(meta["K"]))
    want = np.load(os.path.join(ckpt, "render_final.npy"))
    err = np.abs(np.asarray(rgb) - want)
    assert err.max() <= 0.02 and err.mean() <= 0.005, (err.max(), err.mean())

    # serving restore dequantizes on its own and serves under a bf16 policy
    server, _ = GSRenderServer.from_checkpoint(ckpt, dtype_policy="bf16")
    assert server.cfg.dtype_policy == "bf16"
    results = server.serve(orbital_rig(
        2, np.asarray(meta["center"]), float(meta["radius"]),
        width=res, height=res))
    assert len(results) == 2
    assert all(np.isfinite(r.rgb).all() for r in results)

    # resume across the policy boundary: loud, documented, non-zero exit
    out2 = subprocess.run(base + ["--steps", "4"], capture_output=True,
                          text=True, timeout=900, env=env)
    assert out2.returncode != 0
    assert "dtype_policy" in out2.stderr and "bf16" in out2.stderr
