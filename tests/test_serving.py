"""Serving contract suite (PR 7 tentpole): core/serving.GSRenderServer.

Pins the four serving contracts end-to-end:

  * batched queue service == sequential single-view renders (ref AND
    interpret impls) at float-associativity tolerance;
  * a pose-bucket cache HIT is BIT-identical to the cold MISS that
    populated it — indices, scores and the final image;
  * LRU eviction and zero-budget overflow are counted, never silent, and
    degraded configs still produce finite well-formed images;
  * LOD rung selection is deterministic + monotone in camera distance,
    and load shedding serves (never drops) at the lower serving K.

Plus the two table lemmas the cache leans on: quantize_pose bucket
stability and the slice_table prefix property.
"""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cameras import Camera, orbital_rig, select
from repro.core.gaussians import from_points
from repro.core.render import assign_tables_jit, render
from repro.core.serving import (GSRenderServer, QueueFullError, ServeCfg,
                                build_lod_ladder, camera_distance,
                                camera_eye, lod_keep_mask, select_rung,
                                splat_impact)
from repro.core.tiling import TileGrid, quantize_pose, slice_table
from repro.data.isosurface import point_cloud_for

RES = 32
CENTER = (0.5, 0.5, 0.5)


def scene(n=400, seed=0):
    pts, cols = point_cloud_for("sphere_shell", n, seed=seed)
    g = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.9)
    grid = TileGrid(RES, RES, 8, 16)
    return g, grid


def mixed_rig(n_near=3, n_far=3, far_r=8.0):
    """Near orbit (rung 0) + far orbit (beyond the auto LOD threshold)."""
    near = orbital_rig(n_near, CENTER, 1.5, width=RES, height=RES)
    far = orbital_rig(n_far, CENTER, far_r, width=RES, height=RES)
    return Camera(view=jnp.concatenate([near.view, far.view]),
                  fx=jnp.concatenate([near.fx, far.fx]),
                  fy=jnp.concatenate([near.fy, far.fy]),
                  width=RES, height=RES)


def canonical(cam: Camera, bins=ServeCfg.pose_bins) -> Camera:
    """The bucket-snapped camera the server actually renders."""
    _, (v, fx, fy) = quantize_pose(cam.view, cam.fx, cam.fy, bins=bins)
    return Camera(jnp.asarray(v), jnp.float32(fx), jnp.float32(fy),
                  cam.width, cam.height)


# ---------------------------------------------------------------------------
# table lemmas
# ---------------------------------------------------------------------------


def test_quantize_pose_buckets():
    g, grid = scene()
    cam = select(orbital_rig(3, CENTER, 1.5, width=RES, height=RES), 0)
    key, (v, fx, fy) = quantize_pose(cam.view, cam.fx, cam.fy)
    # sub-half-bucket noise off the canonical (lattice) pose lands in the
    # SAME bucket (a raw pose can sit arbitrarily close to a boundary, so
    # the guarantee is per-bucket, not per-pose)
    eps = 0.4 / ServeCfg.pose_bins
    key2, _ = quantize_pose(np.asarray(v, np.float64) + eps, fx, fy)
    assert key2 == key
    # a clearly different pose lands elsewhere
    key3, _ = quantize_pose(np.asarray(v, np.float64) + 0.1, fx, fy)
    assert key3 != key
    # canonicalization is idempotent: the canonical pose is its own bucket
    key4, (v4, fx4, fy4) = quantize_pose(v, fx, fy)
    assert key4 == key
    np.testing.assert_array_equal(v4, v)
    assert (fx4, fy4) == (fx, fy)


def test_slice_table_prefix_property():
    """A depth-K table's first k columns ARE the depth-k assignment —
    bit-for-bit (total order: score desc, index asc) — so shed renders can
    slice the cached Kmax table instead of re-assigning."""
    g, grid = scene()
    cams = orbital_rig(2, CENTER, 1.5, width=RES, height=RES)
    idx16, s16, _ = assign_tables_jit(grid, 16, None, "dense", None)(g, cams)
    idx8, s8, _ = assign_tables_jit(grid, 8, None, "dense", None)(g, cams)
    sl_idx, sl_s = slice_table(np.asarray(idx16), np.asarray(s16), 8)
    np.testing.assert_array_equal(sl_idx, np.asarray(idx8))
    np.testing.assert_array_equal(sl_s, np.asarray(s8))
    with pytest.raises(ValueError):
        slice_table(np.asarray(idx16), np.asarray(s16), 32)


# ---------------------------------------------------------------------------
# batched queue service == sequential renders
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_serve_matches_sequential_render(impl):
    g, grid = scene()
    cfg = ServeCfg(K=16, impl=impl, max_batch=4, lod_dists=(4.0,))
    server = GSRenderServer(g, grid, cfg, center=CENTER)
    rig = mixed_rig()
    results = server.serve(rig)
    assert [r.request_id for r in results] == list(range(6))
    assert {r.rung for r in results} == {0, 1}       # mixed rig spans LOD
    for v, r in enumerate(results):
        cam = canonical(select(rig, v))
        ref = render(server.ladder[r.rung], cam, grid, K=16, impl=impl)
        np.testing.assert_allclose(r.rgb, np.asarray(ref.rgb),
                                   rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(r.coverage, np.asarray(ref.coverage),
                                   rtol=1e-6, atol=1e-6)
    tel = server.telemetry()
    assert tel["requests"] == 6 and tel["shed"] == 0 == tel["rejected"]
    assert tel["tiles"] == 0 == tel["assign"]        # nothing dropped


# ---------------------------------------------------------------------------
# cache: hit == miss, bit-identical; LRU honesty
# ---------------------------------------------------------------------------


def test_cache_hit_bit_identical_to_miss():
    g, grid = scene()
    server = GSRenderServer(g, grid,
                            ServeCfg(K=16, max_batch=4, lod_dists=(4.0,)),
                            center=CENTER)
    rig = mixed_rig()
    cold = server.serve(rig)
    warm = server.serve(rig)
    assert not any(r.cache_hit for r in cold)
    assert all(r.cache_hit for r in warm)
    for c, w in zip(cold, warm):
        np.testing.assert_array_equal(c.rgb, w.rgb)          # BIT-identical
        np.testing.assert_array_equal(c.coverage, w.coverage)
        assert (c.rung, c.K) == (w.rung, w.K)
    tel = server.telemetry()
    assert tel["hits"] == 6 and tel["misses"] == 6
    assert tel["evictions"] == 0 == tel["cache_overflow"]


def test_cached_table_matches_fresh_assignment():
    """The cached (T, K) table is bit-identical to a fresh assignment of
    the canonical pose — the cache stores exact tables, not approximations."""
    g, grid = scene()
    server = GSRenderServer(g, grid,
                            ServeCfg(K=16, max_batch=4, lod_dists=(4.0,)),
                            center=CENTER)
    rig = orbital_rig(2, CENTER, 1.5, width=RES, height=RES)
    server.serve(rig)
    for v in range(2):
        cam = canonical(select(rig, v))
        entry = server.cached_table(select(rig, v), rung=0)
        assert entry is not None
        cams1 = Camera(cam.view[None], cam.fx[None], cam.fy[None], RES, RES)
        idx, score, _ = assign_tables_jit(grid, 16, None, "dense",
                                          None)(server.ladder[0], cams1)
        np.testing.assert_array_equal(entry[0], np.asarray(idx)[0])
        np.testing.assert_array_equal(entry[1], np.asarray(score)[0])


def test_lru_eviction_counted_and_outputs_finite():
    g, grid = scene()
    server = GSRenderServer(g, grid,
                            ServeCfg(K=16, max_batch=4, cache_entries=1),
                            center=CENTER)
    rig = mixed_rig()
    for _ in range(2):
        results = server.serve(rig)
        assert len(results) == 6
        for r in results:
            assert r.rgb.shape == (RES, RES, 3)
            assert np.isfinite(r.rgb).all() and np.isfinite(r.coverage).all()
    tel = server.telemetry()
    assert tel["evictions"] > 0                   # starved budget: counted
    assert tel["hits"] + tel["misses"] == tel["requests"]


def test_zero_cache_budget_counts_overflow():
    g, grid = scene()
    server = GSRenderServer(g, grid,
                            ServeCfg(K=16, max_batch=4, cache_entries=0),
                            center=CENTER)
    rig = orbital_rig(3, CENTER, 1.5, width=RES, height=RES)
    for _ in range(2):
        results = server.serve(rig)
        assert all(np.isfinite(r.rgb).all() for r in results)
    tel = server.telemetry()
    assert tel["cache_overflow"] > 0              # inserts dropped: counted
    assert tel["hits"] == 0                       # nothing can ever hit
    assert tel["evictions"] == 0


# ---------------------------------------------------------------------------
# LOD ladder
# ---------------------------------------------------------------------------


def test_select_rung_monotone_deterministic():
    thresholds = (2.0, 4.0, 8.0)
    dists = np.linspace(0.0, 10.0, 101)
    rungs = [select_rung(float(d), thresholds) for d in dists]
    assert rungs == sorted(rungs)                          # monotone
    assert rungs[0] == 0 and rungs[-1] == len(thresholds)  # full range
    assert rungs == [select_rung(float(d), thresholds) for d in dists]


def test_lod_keep_mask_sizes_and_cap():
    g, _ = scene()
    n_live = int(np.asarray(g.active).sum())
    full = lod_keep_mask(g, 1.0)
    assert int(full.sum()) == n_live
    half = lod_keep_mask(g, 0.5)
    assert int(half.sum()) == int(np.ceil(0.5 * n_live))
    assert not (half & ~full).any()               # keep sets nest by impact
    capped = lod_keep_mask(g, 1.0, cap=32)
    assert int(capped.sum()) == 32
    # top-impact rows survive: the kept set's min impact >= dropped max
    imp = splat_impact(g)
    assert imp[capped].min() >= imp[full & ~capped].max()


def test_build_lod_ladder_shrinks_and_compacts():
    g, _ = scene()
    ladder = build_lod_ladder(g, (1.0, 0.4), cap=64, round_to=64)
    lives = [int(np.asarray(r.active).sum()) for r in ladder]
    assert lives[0] == int(np.asarray(g.active).sum())
    assert lives[1] == min(64, int(np.ceil(0.4 * lives[0])))
    for r in ladder:
        assert r.means.shape[0] % 64 == 0          # padded capacity
        n = int(np.asarray(r.active).sum())
        assert not np.asarray(r.active)[n:].any()  # live rows compacted front


def test_server_rung_tracks_distance():
    g, grid = scene()
    server = GSRenderServer(g, grid,
                            ServeCfg(K=16, max_batch=4, lod_dists=(4.0,)),
                            center=CENTER)
    rig = mixed_rig(n_near=2, n_far=2)
    results = server.serve(rig)
    assert [r.rung for r in results] == [0, 0, 1, 1]
    # rung selection is a pure function of distance vs the ladder
    for v, r in enumerate(results):
        d = camera_distance(select(rig, v).view, server.center)
        assert r.rung == select_rung(d, server.lod_dists)


def test_camera_eye_roundtrip():
    rig = orbital_rig(4, CENTER, 1.5, width=RES, height=RES)
    for v in range(4):
        eye = camera_eye(select(rig, v).view)
        np.testing.assert_allclose(np.linalg.norm(eye - np.asarray(CENTER)),
                                   1.5, rtol=1e-5)


# ---------------------------------------------------------------------------
# load shedding + bounded queue
# ---------------------------------------------------------------------------


def test_load_shed_serves_lower_k():
    g, grid = scene()
    cfg = ServeCfg(K=16, max_batch=4, shed_at=2, shed_rung=0)
    server = GSRenderServer(g, grid, cfg, center=CENTER)
    shed_k = int(server.schedule.k_tiers[cfg.shed_rung])
    kmax = int(server.schedule.kmax)
    assert shed_k < kmax
    rig = orbital_rig(6, CENTER, 1.5, width=RES, height=RES)
    for v in range(6):
        server.submit(select(rig, v))
    results = server.flush()
    assert len(results) == 6                       # shed, never dropped
    assert [r.shed for r in results] == [False, False, True, True, True,
                                         True]
    assert [r.K for r in results] == [kmax, kmax] + [shed_k] * 4
    tel = server.telemetry()
    assert tel["shed"] == 4 and tel["rejected"] == 0
    # a shed render is exactly the low-K render of the same canonical pose
    r = results[-1]
    cam = canonical(select(rig, 5))
    ref = render(server.ladder[r.rung], cam, grid, K=shed_k)
    np.testing.assert_allclose(r.rgb, np.asarray(ref.rgb),
                               rtol=1e-6, atol=1e-6)


def test_queue_cap_rejects_and_counts():
    g, grid = scene()
    server = GSRenderServer(g, grid,
                            ServeCfg(K=16, max_batch=4, queue_cap=2),
                            center=CENTER)
    rig = orbital_rig(3, CENTER, 1.5, width=RES, height=RES)
    server.submit(select(rig, 0))
    server.submit(select(rig, 1))
    with pytest.raises(QueueFullError):
        server.submit(select(rig, 2))
    assert server.telemetry()["rejected"] == 1
    assert len(server.flush()) == 2                # accepted work survives
    # serve() flushes before the cap: same rig, no rejection
    assert len(server.serve(rig)) == 3
    assert server.telemetry()["rejected"] == 1


def test_submit_validates_camera():
    g, grid = scene()
    server = GSRenderServer(g, grid, ServeCfg(K=16), center=CENTER)
    rig = orbital_rig(2, CENTER, 1.5, width=RES, height=RES)
    with pytest.raises(ValueError):
        server.submit(rig)                         # batched rig: use serve()
    bad = orbital_rig(1, CENTER, 1.5, width=64, height=64)
    with pytest.raises(ValueError):
        server.submit(select(bad, 0))              # grid mismatch


def test_serve_cfg_validation():
    g, grid = scene()
    with pytest.raises(ValueError):
        ServeCfg(K=16, k_ladder=(8, 4, 16)).resolved_ladder()
    with pytest.raises(ValueError):
        ServeCfg(K=16, k_ladder=(4, 8)).resolved_ladder()
    with pytest.raises(ValueError):
        GSRenderServer(g, grid, ServeCfg(K=16, shed_rung=7), center=CENTER)
    with pytest.raises(ValueError):
        GSRenderServer(g, grid,
                       ServeCfg(K=16, lod_fracs=(1.0, 0.5),
                                lod_dists=(1.0, 2.0)), center=CENTER)


def test_serve_cfg_is_hashable():
    # jit cache keys derive from cfg fields; frozen dataclass must hash
    assert hash(ServeCfg()) == hash(dataclasses.replace(ServeCfg()))
