"""Per-architecture smoke tests: reduced config, one forward/train/decode step
on CPU, asserting output shapes and absence of NaNs (assignment deliverable f).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALIASES, get_smoke
from repro.models import (
    TrainCfg,
    init_opt_state,
    init_params,
    make_decode_step,
    make_prefill_step,
    make_train_step,
)
from repro.models.steps import cache_specs

ARCHS = list(ALIASES.keys())
B, S = 2, 64


def make_batch(spec, rng):
    r1, r2 = jax.random.split(jax.random.PRNGKey(rng))
    tokens = jax.random.randint(r1, (B, S), 0, spec.vocab, jnp.int32)
    labels = jax.random.randint(r2, (B, S), 0, spec.vocab, jnp.int32)
    batch = {"tokens": tokens, "labels": labels}
    if spec.family == "encdec":
        batch["frames"] = jax.random.normal(r1, (B, S, spec.frontend_dim),
                                            jnp.bfloat16)
    if spec.family == "vlm":
        npre = spec.n_prefix_tokens
        batch = {
            "patches": jax.random.normal(r1, (B, npre, spec.frontend_dim),
                                         jnp.bfloat16),
            "tokens": tokens[:, : S - npre],
            "labels": labels[:, : S - npre],
        }
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    spec = get_smoke(arch)
    params = init_params(spec, jax.random.PRNGKey(0))
    cfg = TrainCfg(total_steps=10, kv_chunk=32)
    step = jax.jit(make_train_step(spec, cfg))
    opt = init_opt_state(spec, params, cfg)
    batch = make_batch(spec, 1)
    new_params, new_opt, metrics = step(params, opt, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss), f"{arch}: loss={loss}"
    assert loss > 0
    # params actually changed
    l0 = jax.tree.leaves(params)[0]
    l1 = jax.tree.leaves(new_params)[0]
    assert l0.shape == l1.shape
    gn = float(metrics["grad_norm"])
    assert np.isfinite(gn) and gn > 0, f"{arch}: grad_norm={gn}"


@pytest.mark.parametrize("arch", ARCHS)
def test_train_two_steps_loss_finite(arch):
    spec = get_smoke(arch)
    params = init_params(spec, jax.random.PRNGKey(0))
    cfg = TrainCfg(total_steps=10, kv_chunk=32)
    step = jax.jit(make_train_step(spec, cfg))
    opt = init_opt_state(spec, params, cfg)
    for i in range(2):
        params, opt, metrics = step(params, opt, make_batch(spec, i))
        assert np.isfinite(float(metrics["loss"]))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_and_decode(arch):
    spec = get_smoke(arch)
    params = init_params(spec, jax.random.PRNGKey(0))
    prefill = jax.jit(make_prefill_step(spec, kv_chunk=32))
    batch = make_batch(spec, 2)
    batch.pop("labels", None)
    logits, caches = prefill(params, batch)
    assert logits.shape[0] == B and logits.shape[-1] == spec.padded_vocab
    assert np.isfinite(np.asarray(logits, np.float32)).all(), arch

    # decode continues from a fresh fixed-size cache (dry-run style)
    Lc = 32
    cspecs = cache_specs(spec, B, Lc)
    caches0 = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), cspecs)
    if spec.family == "encdec":
        # reuse prefill cross-kv shapes: re-zero is fine for smoke
        pass
    decode = jax.jit(make_decode_step(spec))
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.int32(0)
    for i in range(3):
        tok, caches0 = decode(params, caches0, tok, pos + i)
        assert tok.shape == (B, 1)
        assert int(tok.max()) < spec.vocab, f"{arch}: sampled padded-vocab token"


@pytest.mark.parametrize("arch", ARCHS)
def test_microbatched_train_matches_shapes(arch):
    spec = get_smoke(arch)
    params = init_params(spec, jax.random.PRNGKey(0))
    cfg = TrainCfg(total_steps=10, n_microbatches=2, kv_chunk=32)
    step = jax.jit(make_train_step(spec, cfg))
    opt = init_opt_state(spec, params, cfg)
    _, _, metrics = step(params, opt, make_batch(spec, 3))
    assert np.isfinite(float(metrics["loss"]))
