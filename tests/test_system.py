"""System-level checks: public API surface, config registry integrity,
dry-run machinery on a reduced mesh (subprocess), spec invariants."""

import os
import subprocess
import sys

import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # degraded fallback (see tests/_hyp.py)
    from _hyp import given, settings, st

from repro.configs import ALIASES, all_arch_ids, get_smoke, get_spec
from repro.models.spec import logical_to_pspec

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def test_public_api_imports():
    import repro.core as core
    import repro.data as data
    import repro.kernels as kernels
    import repro.runtime as runtime
    for name in ("render", "Gaussians", "TileGrid", "run_pipeline",
                 "GSTrainCfg", "orbital_rig"):
        assert hasattr(core, name), name
    assert hasattr(kernels, "rasterize_tiles")
    assert hasattr(runtime, "CheckpointManager")
    assert hasattr(data, "extract_isosurface")


def test_registry_covers_all_assigned_archs():
    assigned = {
        "minicpm-2b", "h2o-danube-1.8b", "qwen1.5-4b", "codeqwen1.5-7b",
        "llama4-maverick-400b-a17b", "mixtral-8x22b", "mamba2-780m",
        "jamba-v0.1-52b", "whisper-tiny", "paligemma-3b",
    }
    assert set(ALIASES) == assigned


@pytest.mark.parametrize("arch", all_arch_ids())
def test_spec_invariants(arch):
    spec = get_spec(arch)
    smoke = get_smoke(arch)
    assert spec.family == smoke.family
    assert spec.n_layers % spec.period == 0
    if spec.n_q:
        assert spec.padded_n_q % 16 == 0          # model-axis divisible
        assert spec.padded_n_q % spec.padded_n_kv == 0
    assert spec.padded_vocab % (128 * 16) == 0
    assert spec.param_count() > 0
    # MoE active params < total
    if spec.moe is not None:
        assert spec.param_count(active_only=True) < spec.param_count()


PUBLISHED_PARAMS = {
    # name -> (published count, tolerance) — sanity that configs track the
    # models they claim (embedding-heavy small models drift most)
    "minicpm-2b": (2.7e9, 0.35),
    "qwen1.5-4b": (4e9, 0.35),
    "codeqwen1.5-7b": (7e9, 0.35),
    "mixtral-8x22b": (141e9, 0.25),
    "mamba2-780m": (780e6, 0.35),
}


@pytest.mark.parametrize("arch", list(PUBLISHED_PARAMS))
def test_param_counts_near_published(arch):
    want, tol = PUBLISHED_PARAMS[arch]
    got = get_spec(arch).param_count()
    assert abs(got - want) / want < tol, (arch, got, want)


@settings(max_examples=30, deadline=None)
@given(st.sampled_from(all_arch_ids()),
       st.sampled_from([("data", "model"), ("pod", "data", "model")]))
def test_param_pspecs_valid_on_any_mesh(arch, mesh_axes):
    """Every REAL parameter's PartitionSpec is well-formed on any mesh: no
    mesh axis appears twice within one leaf's spec, and every referenced
    axis exists on the mesh."""
    from repro.models.params import PDef, param_defs

    spec = get_spec(arch)
    leaves = []

    def collect(tree):
        if isinstance(tree, PDef):
            leaves.append(tree)
        else:
            for v in tree.values():
                collect(v)

    collect(param_defs(spec))
    assert leaves
    for d in leaves:
        ps = logical_to_pspec(d.logical, spec.sharding_policy, mesh_axes,
                              spec.kv_shardable)
        assert len(ps) == len(d.shape)
        used = []
        for entry in ps:
            if entry is None:
                continue
            for ax in (entry if isinstance(entry, tuple) else (entry,)):
                assert ax in mesh_axes
                used.append(ax)
        assert len(used) == len(set(used)), (d.logical, ps)


DRYRUN_SMOKE = r"""
import os, sys, json, subprocess
env = dict(os.environ, REPRO_DRYRUN_DEVICES="8",
           PYTHONPATH=r"%(src)s")
out = subprocess.run(
    [sys.executable, "-m", "repro.launch.dryrun", "--arch", "whisper-tiny",
     "--shape", "train_4k", "--mesh", "both", "--out", sys.argv[1]],
    env=env, capture_output=True, text=True, timeout=1200)
print(out.stdout[-1500:], out.stderr[-500:])
assert out.returncode == 0
rec = json.load(open(sys.argv[1] + "/single/whisper-tiny__train_4k.json"))
assert rec["status"] == "ok", rec.get("traceback", "")[-500:]
assert rec["hlo"]["flops"] > 0
assert rec["roofline"]["compute_s"] > 0
rec2 = json.load(open(sys.argv[1] + "/multi/whisper-tiny__train_4k.json"))
assert rec2["status"] == "ok"
print("DRYRUN-SMOKE-OK")
"""


@pytest.mark.slow
def test_dryrun_machinery_reduced_mesh(tmp_path):
    code = DRYRUN_SMOKE % {"src": SRC}
    out = subprocess.run([sys.executable, "-c", code, str(tmp_path)],
                         capture_output=True, text=True, timeout=1500)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-2000:])
    assert "DRYRUN-SMOKE-OK" in out.stdout


def test_mesh_module_is_lazy():
    """Importing launch.mesh must not initialise jax devices."""
    code = ("import sys; sys.path.insert(0, r'%s');"
            "import jax; import repro.launch.mesh as m;"
            "assert not jax._src.xla_bridge._backends;"
            "print('LAZY-OK')" % SRC)
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=120)
    assert out.returncode == 0, out.stderr[-1000:]
    assert "LAZY-OK" in out.stdout
