"""Variable-K occupancy-binned rasterization (the tentpole).

Pins the tier contract:
  * binning is a partition: every non-empty tile lands in exactly one tier
    (its smallest covering K) when caps suffice, empty tiles in none;
  * tiered rendering is EXACT vs the dense path at K = k_tiers[-1] whenever
    caps cover the occupancy histogram — forward (ref + interpret impls,
    single and view-batched) and gradients through the tier scatter;
  * capacity pressure promotes tiles upward (still exact) and only the top
    tier drops, surfaced via the overflow counter;
  * edge cases: every tile in one tier, empty tiers, all-background scenes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.cameras import orbital_rig, select
from repro.core.gaussians import from_points
from repro.core.pipeline import render_views
from repro.core.render import render, render_batch
from repro.core.tiling import (TierSchedule, TileGrid, auto_tier_caps,
                               bin_tiles_by_occupancy, tile_occupancy,
                               tile_tiers)
from repro.data.isosurface import point_cloud_for


def scene(n=600, res=48, n_views=3, seed=0, opacity=0.9):
    pts, cols = point_cloud_for("sphere_shell", n, seed=seed)
    g = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=opacity)
    cams = orbital_rig(n_views, (0.5, 0.5, 0.5), 1.5, width=res, height=res)
    return g, cams, TileGrid(res, res, 8, 16)


# ---------------------------------------------------------------------------
# binning unit tests
# ---------------------------------------------------------------------------


def test_binning_is_a_partition_when_caps_cover():
    rng = np.random.default_rng(0)
    occ = jnp.asarray(rng.integers(0, 65, 200), jnp.int32)
    kt = (8, 32, 64)
    caps = auto_tier_caps(occ, kt)
    plan = bin_tiles_by_occupancy(occ, kt, caps)
    assert int(plan.overflow) == 0
    placed = np.concatenate([np.asarray(t) for t in plan.tile_ids])
    placed = placed[placed < 200]
    # exactly the non-empty tiles, each exactly once
    np.testing.assert_array_equal(np.sort(placed),
                                  np.nonzero(np.asarray(occ) > 0)[0])
    # every placed tile's tier K covers its occupancy
    tiers = np.asarray(tile_tiers(occ, kt))
    for i, (k, ids) in enumerate(zip(kt, plan.tile_ids)):
        ids = np.asarray(ids)
        live = ids[ids < 200]
        assert (np.asarray(occ)[live] <= k).all()
        assert (tiers[live] == i).all()
        assert int(plan.counts[i]) == len(live)


def test_binning_promotes_on_capacity_pressure_and_counts_overflow():
    occ = jnp.asarray([4, 4, 4, 40, 70, 70, 70], jnp.int32)
    kt = (8, 32, 64)
    # tier0 cap 1: two tier0 tiles promote; tier1 takes one + its own; the
    # top tier (cap 2) holds two of {promoted, 70s} and drops the rest
    plan = bin_tiles_by_occupancy(occ, kt, (1, 2, 2))
    assert int(plan.counts.sum()) + int(plan.overflow) == 7
    assert int(plan.overflow) == 2
    # promotion keeps ids sorted within each tier and never demotes
    tiers = np.asarray(tile_tiers(occ, kt))
    for i, ids in enumerate(plan.tile_ids):
        live = np.asarray(ids)[np.asarray(ids) < 7]
        assert (tiers[live] <= i).all()
        assert (np.diff(live) > 0).all()


def test_binning_rejects_bad_schedules():
    occ = jnp.zeros((4,), jnp.int32)
    with pytest.raises(ValueError):
        bin_tiles_by_occupancy(occ, (16, 16), (4, 4))
    with pytest.raises(ValueError):
        bin_tiles_by_occupancy(occ, (16, 64), (4,))


def test_auto_tier_caps_under_jit_raises_with_guidance():
    """Cap sizing under tracing must fail LOUDLY with the fix recipe (caps
    are static shapes), naming both the single-device probe idiom and the
    distributed probe_counts path — not a bare TypeError."""
    with pytest.raises(TypeError) as e:
        jax.jit(lambda o: auto_tier_caps(o, (8, 16)))(
            jnp.zeros((4,), jnp.int32))
    msg = str(e.value)
    assert "auto_tier_caps" in msg
    assert "STATIC" in msg and "outside the traced computation" in msg
    assert "occupancy_probe_jit" in msg and "probe_counts" in msg


# ---------------------------------------------------------------------------
# forward parity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_tiered_render_exact_vs_dense_maxk(impl):
    g, cams, grid = scene()
    cam = select(cams, 0)
    kt = (4, 16, 64)
    dense = render(g, cam, grid, K=kt[-1], impl=impl)
    tiered = render(g, cam, grid, k_tiers=kt, impl=impl)
    assert int(tiered.overflow) == 0
    np.testing.assert_allclose(np.asarray(tiered.rgb), np.asarray(dense.rgb),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(tiered.coverage),
                               np.asarray(dense.coverage),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_tiered_render_batch_exact_vs_dense(impl):
    g, cams, grid = scene(n_views=3)
    kt = (4, 16, 64)
    dense = render_batch(g, cams, grid, K=kt[-1], impl=impl)
    tiered = render_batch(g, cams, grid, k_tiers=kt, impl=impl)
    assert tiered.overflow.shape == (3,)
    assert int(tiered.overflow.sum()) == 0
    np.testing.assert_allclose(np.asarray(tiered.rgb), np.asarray(dense.rgb),
                               rtol=1e-6, atol=1e-6)


def test_tiered_render_views_matches_dense_and_caches():
    from repro.core import pipeline as pl
    g, cams, grid = scene(n_views=5)
    r0, c0 = render_views(g, cams, grid, K=64, impl="ref", batch=2)
    before = pl._render_batch_jit.cache_info().misses
    r1, c1 = render_views(g, cams, grid, K=64, impl="ref", batch=2,
                          k_tiers=(4, 16, 64))
    r2, _ = render_views(g, cams, grid, K=64, impl="ref", batch=2,
                         k_tiers=(4, 16, 64))
    np.testing.assert_allclose(r0, r1, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(c0, c1, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(r1, r2)
    # the second tiered call reuses the cached jit (same auto caps)
    assert pl._render_batch_jit.cache_info().misses == before + 1


def test_tiered_with_static_caps_under_jit():
    g, cams, grid = scene()
    cam = select(cams, 0)
    kt = (4, 16, 64)
    caps = auto_tier_caps(
        tile_occupancy(_score(g, cam, grid, kt[-1])), kt)
    f = jax.jit(lambda gg: render(gg, cam, grid, k_tiers=kt,
                                  tier_caps=caps, impl="ref").rgb)
    dense = render(g, cam, grid, K=kt[-1], impl="ref").rgb
    np.testing.assert_allclose(np.asarray(f(g)), np.asarray(dense),
                               rtol=1e-6, atol=1e-6)


def _score(g, cam, grid, K):
    from repro.core.projection import project
    from repro.core.tiling import assign_tiles
    return assign_tiles(project(g, cam), grid, K=K)[1]


# ---------------------------------------------------------------------------
# gradients through the tier scatter
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("impl", ["ref", "interpret"])
def test_tiered_gradient_parity(impl):
    g, cams, grid = scene(n=300, res=32)
    cam = select(cams, 0)
    kt = (4, 16, 64)
    target = jnp.zeros((32, 32, 3))

    def loss(colors, k_tiers):
        out = render(g._replace(colors=colors), cam, grid,
                     K=kt[-1], impl=impl, k_tiers=k_tiers)
        return jnp.mean((out.rgb - target) ** 2)

    gd = jax.grad(lambda c: loss(c, None))(g.colors)
    gt = jax.grad(lambda c: loss(c, kt))(g.colors)
    np.testing.assert_allclose(np.asarray(gt), np.asarray(gd),
                               rtol=1e-5, atol=1e-6)
    assert float(jnp.abs(gd).max()) > 0  # non-trivial gradient


def test_tiered_gradient_parity_batched():
    g, cams, grid = scene(n=300, res=32, n_views=2)
    kt = (4, 16, 64)

    def loss(means, k_tiers):
        out = render_batch(g._replace(means=means), cams, grid,
                           K=kt[-1], impl="ref", k_tiers=k_tiers)
        return jnp.mean(out.rgb ** 2)

    gd = jax.grad(lambda m: loss(m, None))(g.means)
    gt = jax.grad(lambda m: loss(m, kt))(g.means)
    np.testing.assert_allclose(np.asarray(gt), np.asarray(gd),
                               rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# TierSchedule: telemetry-driven (k_tiers, tier_caps) lifecycle
# ---------------------------------------------------------------------------


def test_tier_schedule_probe_covers_and_keeps_telemetry_live():
    rng = np.random.default_rng(1)
    occ = jnp.asarray(rng.integers(0, 13, 200), jnp.int32)   # max occ <= 12
    sched = TierSchedule((4, 16, 64))
    kt, caps = sched.probe(occ)
    # default keeps the FULL ladder with cap-0 (no-launch) upper tiers, so
    # the step still assigns at Kmax and occupancy growth stays measurable
    assert kt == (4, 16, 64)
    assert caps[-1] == 0
    assert sched.kmax == 64
    plan = bin_tiles_by_occupancy(occ, kt, caps)
    assert int(plan.overflow) == 0        # probed caps cover the histogram
    # growth into an unoccupied tier FIRES the overflow counter (the signal
    # note_overflow consumes) instead of truncating silently
    occ_grown = occ.at[:8].set(60)
    assert int(bin_tiles_by_occupancy(occ_grown, kt, caps).overflow) > 0


def test_tier_schedule_opt_in_trim():
    """trim=True (for re-probing runs) drops unoccupied top tiers so sparse
    phases stop paying large-K assignment."""
    occ = jnp.asarray([0, 3, 12, 12], jnp.int32)
    sched = TierSchedule((4, 16, 64), trim=True)
    kt, caps = sched.probe(occ)
    assert kt == (4, 16)                  # 64-tier dropped: nothing needs it
    assert sched.kmax == 64               # probes still assign at ladder max
    assert int(bin_tiles_by_occupancy(occ, kt, caps).overflow) == 0
    # a probe that saturates Kmax keeps the full ladder (occupancy is only
    # a lower bound there)
    kt2, _ = sched.probe(jnp.asarray([64, 64], jnp.int32))
    assert kt2 == (4, 16, 64)


def test_tier_schedule_reprobe_grows_caps_after_densify_overflow():
    """The re-probe contract: a densify that pushes tiles past the current
    top-tier cap must (a) be visible as overflow under the OLD caps and
    (b) disappear after a re-probe, whose caps grew."""
    rng = np.random.default_rng(2)
    sched = TierSchedule((4, 16, 64), slack=1.0)
    occ_before = jnp.asarray(
        np.concatenate([rng.integers(1, 17, 90),    # tiers 0/1
                        rng.integers(17, 65, 10)]), jnp.int32)  # few heavy
    kt0, caps0 = sched.probe(occ_before)
    assert int(bin_tiles_by_occupancy(occ_before, kt0, caps0).overflow) == 0
    # "densify": many more tiles land in the top tier than caps0 allows
    occ_after = jnp.asarray(
        np.concatenate([rng.integers(1, 17, 40),
                        rng.integers(17, 65, 60)]), jnp.int32)
    assert int(bin_tiles_by_occupancy(occ_after, kt0, caps0).overflow) > 0
    kt1, caps1 = sched.probe(occ_after)
    assert caps1[-1] > caps0[-1]          # top-tier cap grew
    assert int(bin_tiles_by_occupancy(occ_after, kt1, caps1).overflow) == 0


def test_tier_schedule_note_overflow_grows_and_clamps():
    sched = TierSchedule((4, 16), round_to=8, growth=2.0)
    assert not sched.note_overflow(5, 100)      # no probe yet: no-op
    sched.probe(jnp.asarray([3, 3, 10, 10, 10], jnp.int32))
    caps0 = sched.tier_caps
    assert not sched.note_overflow(0, 100)      # zero counter: no-op
    assert sched.note_overflow(jnp.int32(2), 100)
    assert all(c1 >= c0 for c1, c0 in zip(sched.tier_caps, caps0))
    for _ in range(10):                          # growth is clamped at M...
        sched.note_overflow(1, 100)
    assert all(c <= 100 for c in sched.tier_caps)
    assert not sched.note_overflow(1, 100)       # ...where it's a no-op


def test_tier_schedule_rejects_bad_ladder_and_tracers():
    with pytest.raises(ValueError):
        TierSchedule((16, 16))
    with pytest.raises(ValueError):
        TierSchedule(())
    # the probe under tracing is the classic foot-gun (e.g. calling it
    # inside a jitted train loop): the error must name the caller and ship
    # the documented recipe, under jit AND under vmap/grad alike
    with pytest.raises(TypeError) as e:
        jax.jit(lambda o: TierSchedule((4, 16)).probe(o))(
            jnp.zeros((4,), jnp.int32))
    msg = str(e.value)
    assert "TierSchedule.probe" in msg
    assert "outside the traced computation" in msg
    assert "probe_counts" in msg          # the distributed-mesh recipe
    with pytest.raises(TypeError, match="TierSchedule.probe"):
        jax.vmap(lambda o: jnp.float32(
            TierSchedule((4, 16)).probe(o)[1][0]))(
            jnp.zeros((2, 4), jnp.int32))
    with pytest.raises(TypeError, match="probe_counts"):
        jax.jit(lambda c: TierSchedule((4, 16)).probe_counts(
            c, 3, n_tiles=8))(jnp.zeros((2,), jnp.int32))


def test_tier_schedule_probe_counts_matches_probe():
    """probe_counts is the reduced-telemetry twin of probe: feeding it the
    per-tier worst-slice counts + max occupancy (what the distributed
    pmax reduction produces) must land on the same (k_tiers, tier_caps)."""
    from repro.core.tiling import _tier_counts
    occ = jnp.asarray([[0, 3, 10, 70, 3], [5, 5, 5, 5, 9]], jnp.int32)
    for trim in (False, True):
        a = TierSchedule((4, 16, 64), trim=trim)
        b = TierSchedule((4, 16, 64), trim=trim)
        a.probe(occ)
        counts, mx = _tier_counts(occ, b.ladder)
        b.probe_counts(counts, mx, n_tiles=occ.shape[-1])
        assert a.k_tiers == b.k_tiers
        assert a.tier_caps == b.tier_caps
    with pytest.raises(ValueError, match="FULL ladder"):
        TierSchedule((4, 16, 64)).probe_counts([1, 2], 3, n_tiles=8)


def test_tier_schedule_state_roundtrip():
    """state_dict/load_state/from_state: the checkpointed schedule resumes
    with identical ladder/knobs/active tiers/caps — including through a
    JSON round-trip (CheckpointManager stores it in the manifest)."""
    import json
    sched = TierSchedule((4, 16, 64), slack=1.5, round_to=4, growth=3.0)
    sched.probe(jnp.asarray([[0, 3, 10, 70], [5, 5, 5, 5]], jnp.int32))
    sched.note_overflow(2, 100)
    state = json.loads(json.dumps(sched.state_dict()))
    back = TierSchedule.from_state(state)
    assert back.ladder == sched.ladder
    assert back.k_tiers == sched.k_tiers
    assert back.tier_caps == sched.tier_caps
    assert (back.slack, back.round_to, back.growth, back.trim) \
        == (sched.slack, sched.round_to, sched.growth, sched.trim)
    # un-probed schedules round-trip too (caps None)
    fresh = TierSchedule.from_state(TierSchedule((8, 32)).state_dict())
    assert fresh.tier_caps is None and fresh.k_tiers == (8, 32)
    # load_state into an existing (differently-constructed) schedule: the
    # checkpoint wins
    other = TierSchedule((2, 4), slack=9.9)
    other.load_state(state)
    assert other.ladder == sched.ladder and other.tier_caps == sched.tier_caps
    with pytest.raises(ValueError, match="ladder"):
        TierSchedule.from_state({**state, "ladder": [16, 16]})


def test_trainer_tiered_default_matches_dense_escape_hatch():
    """GSTrainCfg now trains tiered by default; the dense_k= escape hatch
    must reproduce the exact same training trajectory (caps cover -> tiered
    is exact, so the default flip is a pure execution-strategy change)."""
    from repro.core.train import GSTrainCfg, fit_partition
    g, cams, grid = scene(n=300, res=32, n_views=3)
    gts = jnp.full((3, 32, 32, 3), 0.5)
    cfg_t = GSTrainCfg(K=32, view_batch=2, impl="ref")
    cfg_d = GSTrainCfg(K=32, view_batch=2, impl="ref", dense_k=32)
    assert cfg_t.resolved_k_tiers() == (4, 16, 32)
    assert cfg_d.resolved_k_tiers() is None
    g_t, _, l_t = fit_partition(g, cams, gts, None, cfg_t, steps=3,
                                extent=1.0, grid=grid)
    g_d, _, l_d = fit_partition(g, cams, gts, None, cfg_d, steps=3,
                                extent=1.0, grid=grid)
    np.testing.assert_allclose(l_t, l_d, rtol=1e-6, atol=1e-6)
    for k, v in g_t.trainable().items():
        np.testing.assert_allclose(np.asarray(v),
                                   np.asarray(getattr(g_d, k)),
                                   rtol=1e-6, atol=1e-6, err_msg=k)


def test_fit_partition_reprobes_schedule_across_densify():
    """End-to-end lifecycle: fit_partition probes the supplied schedule and
    re-probes after densify events (schedule state is observable because
    schedule= is caller-owned)."""
    from repro.core.train import GSTrainCfg, fit_partition
    g, cams, grid = scene(n=200, res=32, n_views=2)
    gts = jnp.full((2, 32, 32, 3), 0.2)
    cfg = GSTrainCfg(K=16, densify_grad_thresh=0.0, max_new=64, impl="ref")
    sched = cfg.tier_schedule()
    assert sched.tier_caps is None
    g1, _, losses = fit_partition(g, cams, gts, None, cfg, steps=4,
                                  extent=1.0, grid=grid, densify_every=2,
                                  densify_from=0, schedule=sched)
    assert sched.tier_caps is not None          # probed (and re-probed)
    assert all(np.isfinite(losses))
    assert int(g1.active.sum()) >= int(g.active.sum())  # densify ran


# ---------------------------------------------------------------------------
# edge cases
# ---------------------------------------------------------------------------


def test_all_tiles_in_one_tier():
    g, cams, grid = scene()
    cam = select(cams, 0)
    occ = tile_occupancy(_score(g, cam, grid, 600))   # 600 splats: exact
    m = int(occ.max())
    kt = (m, 2 * m)                        # tier 0 swallows every live tile
    caps = auto_tier_caps(occ, kt)
    assert caps[1] == 0                    # top tier is empty -> no launch
    out = render(g, cam, grid, k_tiers=kt, impl="ref")
    dense = render(g, cam, grid, K=2 * m, impl="ref")
    assert int(out.overflow) == 0
    np.testing.assert_allclose(np.asarray(out.rgb), np.asarray(dense.rgb),
                               rtol=1e-6, atol=1e-6)


def test_all_background_scene_renders_bg():
    """A fully inactive gaussian set: every tile is empty, zero launches."""
    g, cams, grid = scene()
    g = g._replace(active=jnp.zeros_like(g.active))
    out = render(g, select(cams, 0), grid, k_tiers=(4, 16), bg=1.0,
                 impl="ref")
    assert int(out.overflow) == 0
    np.testing.assert_allclose(np.asarray(out.rgb), 1.0)
    np.testing.assert_allclose(np.asarray(out.coverage), 0.0)


def test_top_tier_overflow_is_counted_not_silent():
    g, cams, grid = scene()
    cam = select(cams, 0)
    out = render(g, cam, grid, k_tiers=(4, 16, 64), tier_caps=(1, 1, 1),
                 impl="ref")
    assert int(out.overflow) > 0


def test_render_views_explicit_undersized_caps_warn():
    """Explicit caps are the user's contract: never altered, but dropping
    tiles must be LOUD (RuntimeWarning), not silent background."""
    g, cams, grid = scene()
    with pytest.warns(RuntimeWarning, match="overflowed"):
        render_views(g, cams, grid, K=64, impl="ref", k_tiers=(4, 16, 64),
                     tier_caps=(1, 1, 1))


def test_render_views_auto_caps_grow_on_later_chunks():
    """Auto caps are sized from the FIRST chunk; a later chunk with much
    higher occupancy must trigger the overflow-driven cap growth and still
    come back exact (not silently cropped to the first chunk's caps)."""
    g, _, grid = scene()
    far = orbital_rig(1, (0.5, 0.5, 0.5), 4.0, width=48, height=48)
    near = orbital_rig(1, (0.5, 0.5, 0.5), 1.2, width=48, height=48)
    cams = far._replace(   # width/height are scalar (shared) fields
        view=jnp.concatenate([far.view, near.view]),
        fx=jnp.concatenate([far.fx, near.fx]),
        fy=jnp.concatenate([far.fy, near.fy]))
    kt = (4, 16, 64)
    r_tier, c_tier = render_views(g, cams, grid, K=64, impl="ref",
                                  k_tiers=kt, batch=1)
    r_dense, c_dense = render_views(g, cams, grid, K=64, impl="ref",
                                    batch=1)
    np.testing.assert_allclose(r_tier, r_dense, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(c_tier, c_dense, rtol=1e-6, atol=1e-6)
