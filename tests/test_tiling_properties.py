"""Property-style tests for tile assignment (core/tiling.py).

Pins the contract the rasterizer relies on:
  * with K >= the true per-tile overlap depth, assign_tiles is EXACT — it
    matches a brute-force per-tile circle/rect test + depth sort;
  * live entries come out front-to-back (scores non-increasing = depth
    non-decreasing);
  * the coarse superblock pre-cull returns identical (idx, score) to the
    dense path on live slots whenever its candidate budget covers the true
    per-superblock occupancy (empty-slot idx values are unspecified).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.projection import Splats2D
from repro.core.tiling import NEG, TileGrid, assign_tiles, tile_bounds


def random_splats(seed, n, w, h, *, rmax=9.0, invalid_frac=0.1):
    r = np.random.default_rng(seed)
    return Splats2D(
        mean2d=jnp.asarray(r.uniform([-12, -12], [w + 12, h + 12], (n, 2)),
                           jnp.float32),
        cov2d=jnp.ones((n, 3), jnp.float32),
        depth=jnp.asarray(r.uniform(0.1, 10.0, n), jnp.float32),
        rgb=jnp.asarray(r.uniform(0, 1, (n, 3)), jnp.float32),
        alpha=jnp.asarray(r.uniform(0.1, 0.9, n), jnp.float32),
        radius=jnp.asarray(r.uniform(0.5, rmax, n), jnp.float32),
        valid=jnp.asarray(r.uniform(size=n) > invalid_frac),
    )


def brute_force(splats, grid, K):
    """O(T*N) numpy oracle: exact overlap set per tile, depth-sorted, top-K."""
    lo, hi = (np.asarray(x) for x in tile_bounds(grid))
    mean = np.asarray(splats.mean2d)
    rad = np.asarray(splats.radius)
    depth = np.asarray(splats.depth)
    valid = np.asarray(splats.valid)
    out = []
    for t in range(grid.n_tiles):
        cx = np.clip(mean[:, 0], lo[t, 0], hi[t, 0])
        cy = np.clip(mean[:, 1], lo[t, 1], hi[t, 1])
        hit = ((mean[:, 0] - cx) ** 2 + (mean[:, 1] - cy) ** 2
               <= rad ** 2) & valid
        ids = np.nonzero(hit)[0]
        # front-to-back; ties broken by index (matches stable top_k on -depth)
        ids = ids[np.argsort(depth[ids], kind="stable")]
        out.append(ids[:K])
    return out


@pytest.mark.parametrize("seed,n,res,K", [
    (0, 150, 32, 64),
    (1, 300, 48, 96),
    (2, 60, 64, 64),
])
def test_assign_tiles_matches_brute_force_when_k_sufficient(seed, n, res, K):
    grid = TileGrid(res, res, 8, 16)
    splats = random_splats(seed, n, res, res)
    idx, score = assign_tiles(splats, grid, K=K)
    idx, score = np.asarray(idx), np.asarray(score)
    depth = np.asarray(splats.depth)
    want = brute_force(splats, grid, K)
    # K must really cover the worst tile for this to be an exactness test
    assert max(len(w) for w in want) <= K
    for t in range(grid.n_tiles):
        live = score[t] > NEG / 2
        got = idx[t][live]
        assert len(got) == len(want[t])
        # same SET of splats; order may differ only within equal depths
        np.testing.assert_array_equal(np.sort(got), np.sort(want[t]))
        np.testing.assert_allclose(depth[got], depth[want[t]])


@pytest.mark.parametrize("seed", [3, 4])
def test_assign_tiles_front_to_back(seed):
    grid = TileGrid(64, 64, 8, 16)
    splats = random_splats(seed, 400, 64, 64)
    idx, score = assign_tiles(splats, grid, K=32)
    score = np.asarray(score)
    # scores (=-depth) non-increasing along K: front-to-back compositing order
    assert (np.diff(score, axis=1) <= 1e-6).all()
    depth = np.asarray(splats.depth)[np.asarray(idx)]
    live = score > NEG / 2
    d = np.where(live, depth, 1e30)   # finite sentinel: diff stays NaN-free
    assert (np.diff(d, axis=1) >= -1e-6).all()


@pytest.mark.parametrize("seed,n,res,sb", [
    (5, 200, 64, 2),
    (6, 500, 64, 2),
    (7, 350, 128, 4),
])
def test_coarse_cull_matches_dense(seed, n, res, sb):
    grid = TileGrid(res, res, 8, 16)
    splats = random_splats(seed, n, res, res, rmax=6.0)
    i0, s0 = assign_tiles(splats, grid, K=24)
    # full budget: provably no overflow -> exact (and the counter agrees)
    i1, s1, ov1 = assign_tiles(splats, grid, K=24, coarse=sb,
                               coarse_budget=n, return_overflow=True)
    assert int(ov1) == 0
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    live = np.asarray(s0) > NEG / 2
    np.testing.assert_array_equal(np.asarray(i0)[live], np.asarray(i1)[live])
    # auto budget on these scenes also covers the occupancy
    i2, s2, ov2 = assign_tiles(splats, grid, K=24, coarse=sb,
                               return_overflow=True)
    assert int(ov2) == 0
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i0)[live], np.asarray(i2)[live])


def test_coarse_overflow_counter_fires_on_saturated_budget():
    """A starved budget must be SURFACED, not silently wrong: the counter
    reports exactly the dropped (superblock, splat) candidate pairs."""
    grid = TileGrid(64, 64, 8, 16)
    splats = random_splats(8, 400, 64, 64, rmax=6.0, invalid_frac=0.0)
    from repro.core.tiling import coarse_candidates
    cand_full, ov_full = coarse_candidates(
        splats.mean2d, splats.radius, splats.valid, grid, sb=2, budget=400)
    assert int(ov_full) == 0
    occ = (np.asarray(cand_full) < 400).sum(axis=1)       # true occupancy
    budget = max(int(occ.max()) // 2, 1)
    _, ov = coarse_candidates(
        splats.mean2d, splats.radius, splats.valid, grid, sb=2,
        budget=budget)
    want = np.maximum(occ - budget, 0).sum()
    assert int(ov) == want and want > 0
    # the dense path never drops -> overflow is identically 0
    _, _, ov_dense = assign_tiles(splats, grid, K=24, return_overflow=True)
    assert int(ov_dense) == 0


def test_topk_tiebreak_is_merge_order_invariant():
    """Duplicate depths at the K boundary: the secondary splat-index key
    must make assignment independent of the block/merge order (the ROADMAP
    tie-break divergence item).  With many equal-depth splats per tile and
    K smaller than the overlap, different block sizes change the merge
    order — idx must not change."""
    res = 32
    grid = TileGrid(res, res, 8, 16)
    r = np.random.default_rng(42)
    n = 300
    depths = np.repeat(r.uniform(0.5, 5.0, n // 4), 4)[:n]  # 4-way ties
    splats = random_splats(9, n, res, res, rmax=12.0, invalid_frac=0.0)
    splats = splats._replace(depth=jnp.asarray(depths, jnp.float32))
    idx_ref, score_ref = assign_tiles(splats, grid, K=8, block=n)
    for block in (7, 32, 128):
        idx_b, score_b = assign_tiles(splats, grid, K=8, block=block)
        np.testing.assert_array_equal(np.asarray(idx_ref), np.asarray(idx_b))
        np.testing.assert_array_equal(np.asarray(score_ref),
                                      np.asarray(score_b))
    # and the coarse path agrees bit-for-bit on live slots too
    idx_c, score_c = assign_tiles(splats, grid, K=8, coarse=2,
                                  coarse_budget=n)
    live = np.asarray(score_ref) > NEG / 2
    np.testing.assert_array_equal(np.asarray(score_ref), np.asarray(score_c))
    np.testing.assert_array_equal(np.asarray(idx_ref)[live],
                                  np.asarray(idx_c)[live])
    # within equal scores the indices come out ascending (front-to-back
    # order with a deterministic tie order)
    sc, ix = np.asarray(score_ref), np.asarray(idx_ref)
    same = (np.diff(sc, axis=1) == 0) & (sc[:, :-1] > NEG / 2)
    assert (np.diff(ix, axis=1)[same] > 0).all()


def test_coarse_cull_under_vmap():
    """The batched render path vmaps assign_tiles over views."""
    grid = TileGrid(48, 48, 8, 16)
    sp = [random_splats(10 + v, 250, 48, 48) for v in range(3)]
    batched = jax.tree.map(lambda *xs: jnp.stack(xs), *sp)
    f = lambda s: assign_tiles(s, grid, K=16, coarse=2)[1]
    scores_b = jax.vmap(f)(batched)
    for v in range(3):
        np.testing.assert_array_equal(
            np.asarray(scores_b[v]), np.asarray(assign_tiles(sp[v], grid, K=16)[1]))
