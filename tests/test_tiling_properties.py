"""Property-style tests for tile assignment (core/tiling.py).

Pins the contract the rasterizer relies on:
  * with K >= the true per-tile overlap depth, assign_tiles is EXACT — it
    matches a brute-force per-tile circle/rect test + depth sort;
  * live entries come out front-to-back (scores non-increasing = depth
    non-decreasing);
  * the coarse superblock pre-cull returns identical (idx, score) to the
    dense path on live slots whenever its candidate budget covers the true
    per-superblock occupancy (empty-slot idx values are unspecified);
  * the sort-based path (assign_tiles_sorted) is BIT-IDENTICAL to the
    dense sweep — indices, scores, empty slots, overflow counters —
    whenever its per-splat tile budget covers the scene, including
    duplicate scores, saturated K, empty tiles and under vmap; a starved
    budget fires the overflow counter with the exact dropped-slot count.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.projection import Splats2D
from repro.core.tiling import (NEG, SORTED_MIN_TILES, TileGrid, assign_tiles,
                               assign_tiles_sorted, resolve_assign_impl,
                               tile_bounds)


def random_splats(seed, n, w, h, *, rmax=9.0, invalid_frac=0.1):
    r = np.random.default_rng(seed)
    return Splats2D(
        mean2d=jnp.asarray(r.uniform([-12, -12], [w + 12, h + 12], (n, 2)),
                           jnp.float32),
        cov2d=jnp.ones((n, 3), jnp.float32),
        depth=jnp.asarray(r.uniform(0.1, 10.0, n), jnp.float32),
        rgb=jnp.asarray(r.uniform(0, 1, (n, 3)), jnp.float32),
        alpha=jnp.asarray(r.uniform(0.1, 0.9, n), jnp.float32),
        radius=jnp.asarray(r.uniform(0.5, rmax, n), jnp.float32),
        valid=jnp.asarray(r.uniform(size=n) > invalid_frac),
    )


def brute_force(splats, grid, K):
    """O(T*N) numpy oracle: exact overlap set per tile, depth-sorted, top-K."""
    lo, hi = (np.asarray(x) for x in tile_bounds(grid))
    mean = np.asarray(splats.mean2d)
    rad = np.asarray(splats.radius)
    depth = np.asarray(splats.depth)
    valid = np.asarray(splats.valid)
    out = []
    for t in range(grid.n_tiles):
        cx = np.clip(mean[:, 0], lo[t, 0], hi[t, 0])
        cy = np.clip(mean[:, 1], lo[t, 1], hi[t, 1])
        hit = ((mean[:, 0] - cx) ** 2 + (mean[:, 1] - cy) ** 2
               <= rad ** 2) & valid
        ids = np.nonzero(hit)[0]
        # front-to-back; ties broken by index (matches stable top_k on -depth)
        ids = ids[np.argsort(depth[ids], kind="stable")]
        out.append(ids[:K])
    return out


@pytest.mark.parametrize("seed,n,res,K", [
    (0, 150, 32, 64),
    (1, 300, 48, 96),
    (2, 60, 64, 64),
])
def test_assign_tiles_matches_brute_force_when_k_sufficient(seed, n, res, K):
    grid = TileGrid(res, res, 8, 16)
    splats = random_splats(seed, n, res, res)
    idx, score = assign_tiles(splats, grid, K=K)
    idx, score = np.asarray(idx), np.asarray(score)
    depth = np.asarray(splats.depth)
    want = brute_force(splats, grid, K)
    # K must really cover the worst tile for this to be an exactness test
    assert max(len(w) for w in want) <= K
    for t in range(grid.n_tiles):
        live = score[t] > NEG / 2
        got = idx[t][live]
        assert len(got) == len(want[t])
        # same SET of splats; order may differ only within equal depths
        np.testing.assert_array_equal(np.sort(got), np.sort(want[t]))
        np.testing.assert_allclose(depth[got], depth[want[t]])


@pytest.mark.parametrize("seed", [3, 4])
def test_assign_tiles_front_to_back(seed):
    grid = TileGrid(64, 64, 8, 16)
    splats = random_splats(seed, 400, 64, 64)
    idx, score = assign_tiles(splats, grid, K=32)
    score = np.asarray(score)
    # scores (=-depth) non-increasing along K: front-to-back compositing order
    assert (np.diff(score, axis=1) <= 1e-6).all()
    depth = np.asarray(splats.depth)[np.asarray(idx)]
    live = score > NEG / 2
    d = np.where(live, depth, 1e30)   # finite sentinel: diff stays NaN-free
    assert (np.diff(d, axis=1) >= -1e-6).all()


@pytest.mark.parametrize("seed,n,res,sb", [
    (5, 200, 64, 2),
    (6, 500, 64, 2),
    (7, 350, 128, 4),
])
def test_coarse_cull_matches_dense(seed, n, res, sb):
    grid = TileGrid(res, res, 8, 16)
    splats = random_splats(seed, n, res, res, rmax=6.0)
    i0, s0 = assign_tiles(splats, grid, K=24)
    # full budget: provably no overflow -> exact (and the counter agrees)
    i1, s1, ov1 = assign_tiles(splats, grid, K=24, coarse=sb,
                               coarse_budget=n, return_overflow=True)
    assert int(ov1) == 0
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s1))
    live = np.asarray(s0) > NEG / 2
    np.testing.assert_array_equal(np.asarray(i0)[live], np.asarray(i1)[live])
    # auto budget on these scenes also covers the occupancy
    i2, s2, ov2 = assign_tiles(splats, grid, K=24, coarse=sb,
                               return_overflow=True)
    assert int(ov2) == 0
    np.testing.assert_array_equal(np.asarray(s0), np.asarray(s2))
    np.testing.assert_array_equal(np.asarray(i0)[live], np.asarray(i2)[live])


def test_coarse_overflow_counter_fires_on_saturated_budget():
    """A starved budget must be SURFACED, not silently wrong: the counter
    reports exactly the dropped (superblock, splat) candidate pairs."""
    grid = TileGrid(64, 64, 8, 16)
    splats = random_splats(8, 400, 64, 64, rmax=6.0, invalid_frac=0.0)
    from repro.core.tiling import coarse_candidates
    cand_full, ov_full = coarse_candidates(
        splats.mean2d, splats.radius, splats.valid, grid, sb=2, budget=400)
    assert int(ov_full) == 0
    occ = (np.asarray(cand_full) < 400).sum(axis=1)       # true occupancy
    budget = max(int(occ.max()) // 2, 1)
    _, ov = coarse_candidates(
        splats.mean2d, splats.radius, splats.valid, grid, sb=2,
        budget=budget)
    want = np.maximum(occ - budget, 0).sum()
    assert int(ov) == want and want > 0
    # the dense path never drops -> overflow is identically 0
    _, _, ov_dense = assign_tiles(splats, grid, K=24, return_overflow=True)
    assert int(ov_dense) == 0


def test_topk_tiebreak_is_merge_order_invariant():
    """Duplicate depths at the K boundary: the secondary splat-index key
    must make assignment independent of the block/merge order (the ROADMAP
    tie-break divergence item).  With many equal-depth splats per tile and
    K smaller than the overlap, different block sizes change the merge
    order — idx must not change."""
    res = 32
    grid = TileGrid(res, res, 8, 16)
    r = np.random.default_rng(42)
    n = 300
    depths = np.repeat(r.uniform(0.5, 5.0, n // 4), 4)[:n]  # 4-way ties
    splats = random_splats(9, n, res, res, rmax=12.0, invalid_frac=0.0)
    splats = splats._replace(depth=jnp.asarray(depths, jnp.float32))
    idx_ref, score_ref = assign_tiles(splats, grid, K=8, block=n)
    for block in (7, 32, 128):
        idx_b, score_b = assign_tiles(splats, grid, K=8, block=block)
        np.testing.assert_array_equal(np.asarray(idx_ref), np.asarray(idx_b))
        np.testing.assert_array_equal(np.asarray(score_ref),
                                      np.asarray(score_b))
    # and the coarse path agrees bit-for-bit on live slots too
    idx_c, score_c = assign_tiles(splats, grid, K=8, coarse=2,
                                  coarse_budget=n)
    live = np.asarray(score_ref) > NEG / 2
    np.testing.assert_array_equal(np.asarray(score_ref), np.asarray(score_c))
    np.testing.assert_array_equal(np.asarray(idx_ref)[live],
                                  np.asarray(idx_c)[live])
    # within equal scores the indices come out ascending (front-to-back
    # order with a deterministic tie order)
    sc, ix = np.asarray(score_ref), np.asarray(idx_ref)
    same = (np.diff(sc, axis=1) == 0) & (sc[:, :-1] > NEG / 2)
    assert (np.diff(ix, axis=1)[same] > 0).all()


# ---------------------------------------------------------------------------
# Sort-based assignment (assign_tiles_sorted) vs the dense oracle
# ---------------------------------------------------------------------------


def _bbox_tile_counts(splats, grid):
    """Numpy oracle of the sorted path's per-splat bbox candidate count
    (the quantity its budget bounds and its overflow counter reports)."""
    mean = np.asarray(splats.mean2d)
    rad = np.asarray(splats.radius)
    valid = np.asarray(splats.valid)
    x0 = np.clip(np.ceil((mean[:, 0] - rad) / grid.tile_w) - 1,
                 0, grid.nx - 1)
    x1 = np.clip(np.floor((mean[:, 0] + rad) / grid.tile_w), 0, grid.nx - 1)
    y0 = np.clip(np.ceil((mean[:, 1] - rad) / grid.tile_h) - 1,
                 0, grid.ny - 1)
    y1 = np.clip(np.floor((mean[:, 1] + rad) / grid.tile_h), 0, grid.ny - 1)
    return np.where(valid, (x1 - x0 + 1) * (y1 - y0 + 1), 0).astype(np.int64)


@pytest.mark.parametrize("seed,n,res,K,kwargs", [
    (0, 150, 32, 64, {}),                        # K covers every tile
    (1, 300, 48, 96, {}),
    (11, 400, 64, 8, {}),                        # saturated K (K < overlap)
    (12, 500, 64, 4, dict(rmax=14.0, invalid_frac=0.0)),   # heavy ties at K
    (13, 40, 128, 16, dict(rmax=2.0)),           # mostly EMPTY tiles
    (14, 200, 64, 16, dict(invalid_frac=0.6)),   # many dead splats
])
def test_sorted_assignment_bit_identical_to_dense(seed, n, res, K, kwargs):
    """Full-budget sorted == dense on EVERYTHING: indices (live and empty
    slots), scores, and the overflow counter — the contract that lets the
    sorted path replace the sweep with zero downstream change."""
    grid = TileGrid(res, res, 8, 16)
    splats = random_splats(seed, n, res, res, **kwargs)
    i_d, s_d, ov_d = assign_tiles(splats, grid, K=K, return_overflow=True)
    i_s, s_s, ov_s = assign_tiles_sorted(splats, grid, K=K,
                                         tile_budget=grid.n_tiles,
                                         return_overflow=True)
    assert int(ov_d) == 0 and int(ov_s) == 0
    np.testing.assert_array_equal(np.asarray(s_d), np.asarray(s_s))
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_s))
    # the dispatcher routes impl="sorted" to the same result
    i_2, s_2 = assign_tiles(splats, grid, K=K, impl="sorted",
                            tile_budget=grid.n_tiles)
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_2))
    np.testing.assert_array_equal(np.asarray(s_d), np.asarray(s_2))


def test_sorted_assignment_tie_break_bit_identical():
    """Duplicate depths at the K boundary: the sorted path's stable
    (depth, splat index) ranking must reproduce the dense sweep's two-key
    tie-break exactly (the same invariant the merge-order test pins for
    the dense path)."""
    res = 32
    grid = TileGrid(res, res, 8, 16)
    r = np.random.default_rng(7)
    n = 300
    depths = np.repeat(r.uniform(0.5, 5.0, n // 4), 4)[:n]   # 4-way ties
    splats = random_splats(15, n, res, res, rmax=12.0, invalid_frac=0.0)
    splats = splats._replace(depth=jnp.asarray(depths, jnp.float32))
    i_d, s_d = assign_tiles(splats, grid, K=8, block=n)
    i_s, s_s = assign_tiles_sorted(splats, grid, K=8,
                                   tile_budget=grid.n_tiles)
    np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_s))
    np.testing.assert_array_equal(np.asarray(s_d), np.asarray(s_s))


def test_sorted_auto_budget_exact_on_small_scenes():
    """The auto budget (min(T, DEFAULT_TILE_BUDGET)) covers these scenes:
    overflow 0 and full bit-identity without an explicit tile_budget."""
    for seed, n, res in [(2, 60, 64), (16, 250, 48)]:
        grid = TileGrid(res, res, 8, 16)
        splats = random_splats(seed, n, res, res, rmax=6.0)
        i_d, s_d = assign_tiles(splats, grid, K=24)
        i_s, s_s, ov = assign_tiles_sorted(splats, grid, K=24,
                                           return_overflow=True)
        assert int(ov) == 0
        np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_s))
        np.testing.assert_array_equal(np.asarray(s_d), np.asarray(s_s))


def test_sorted_budget_overflow_counter_fires():
    """A starved per-splat budget must be SURFACED, not silently wrong:
    the counter reports exactly the bbox candidate slots dropped past the
    budget (conservative superset of true hits — 0 proves exactness), and
    the truncated output stays well-formed: front-to-back scores and live
    entries that are a subset of the exact assignment's."""
    grid = TileGrid(64, 64, 8, 16)
    splats = random_splats(17, 400, 64, 64, rmax=9.0, invalid_frac=0.0)
    cnt = _bbox_tile_counts(splats, grid)
    budget = max(1, int(cnt.max()) // 2)
    i_b, s_b, ov = assign_tiles_sorted(splats, grid, K=24,
                                       tile_budget=budget,
                                       return_overflow=True)
    want = int(np.maximum(cnt - budget, 0).sum())
    assert int(ov) == want and want > 0
    s_b = np.asarray(s_b)
    assert (np.diff(s_b, axis=1) <= 1e-6).all()      # still front-to-back
    # every live (tile, splat) pair the truncated run kept is a true pair
    # of the exact run (K = N: nothing truncated on the oracle side)
    i_x, s_x = assign_tiles(splats, grid, K=400)
    exact = {(t, int(i)) for t in range(grid.n_tiles)
             for i, sc in zip(np.asarray(i_x)[t], np.asarray(s_x)[t])
             if sc > NEG / 2}
    live = s_b > NEG / 2
    got = {(t, int(i)) for t in range(grid.n_tiles)
           for i in np.asarray(i_b)[t][live[t]]}
    assert got <= exact


def test_sorted_assignment_under_vmap():
    """render_batch vmaps the assignment over views — the sorted path must
    match its own unbatched result and the dense oracle per view."""
    grid = TileGrid(48, 48, 8, 16)
    sp = [random_splats(20 + v, 250, 48, 48) for v in range(3)]
    batched = jax.tree.map(lambda *xs: jnp.stack(xs), *sp)
    f = lambda s: assign_tiles_sorted(s, grid, K=16,
                                      tile_budget=grid.n_tiles)
    idx_b, score_b = jax.vmap(f)(batched)
    for v in range(3):
        i_d, s_d = assign_tiles(sp[v], grid, K=16)
        np.testing.assert_array_equal(np.asarray(score_b[v]), np.asarray(s_d))
        np.testing.assert_array_equal(np.asarray(idx_b[v]), np.asarray(i_d))


def test_assign_impl_auto_resolution():
    """"auto" picks sorted only when it can prove it should: enough tiles
    AND a known (probed/explicit) per-splat budget lean enough to win.
    No budget in hand -> the always-exact dense sweep (a directly jitted
    building block must not silently truncate); a fat budget demotes too
    (big-splat scenes are where duplicate-and-sort loses).  Unknown impls
    fail loudly."""
    from repro.core.tiling import SORTED_BUDGET_RATIO
    T = 4 * SORTED_MIN_TILES
    ok_budget = T // SORTED_BUDGET_RATIO
    assert resolve_assign_impl("auto", SORTED_MIN_TILES - 1, 8) == "dense"
    assert resolve_assign_impl("auto", SORTED_MIN_TILES) == "dense"  # no B
    assert resolve_assign_impl("auto", T, ok_budget) == "sorted"
    assert resolve_assign_impl("auto", T, ok_budget + 1) == "dense"
    # explicit impls are never overridden by the budget
    assert resolve_assign_impl("sorted", T, T) == "sorted"
    assert resolve_assign_impl("dense", 10 ** 6) == "dense"
    assert resolve_assign_impl("sorted", 1) == "sorted"
    with pytest.raises(ValueError):
        resolve_assign_impl("radix", 64)
    with pytest.raises(ValueError):
        grid = TileGrid(32, 32, 8, 16)
        assign_tiles(random_splats(0, 10, 32, 32), grid, K=4, impl="nope")


def test_resolve_assignment_probes_and_demotes():
    """render.resolve_assignment — the shared host-loop policy: probes a
    budget over the whole rig for small-splat scenes (sorted wins), and
    demotes "auto" to dense on big-splat scenes; pinned impls keep their
    choice, explicit budgets are honored verbatim."""
    from repro.core.cameras import orbital_rig
    from repro.core.gaussians import from_points
    from repro.core.render import resolve_assignment

    r = np.random.default_rng(6)
    grid = TileGrid(256, 256, 8, 16)          # T = 512 >= SORTED_MIN_TILES
    cams = orbital_rig(3, (0.5, 0.5, 0.5), 2.6, width=256, height=256)

    def scene(n, scale):
        pts = r.uniform(0, 1, (n, 3))
        return from_points(jnp.asarray(pts, jnp.float32),
                           jnp.asarray(r.uniform(0, 1, (n, 3))),
                           init_scale=scale / n ** (1 / 3), opacity=0.8)

    small = scene(20000, 0.4)                 # tiny splats: sorted wins
    impl, budget = resolve_assignment(small, cams, grid)
    assert impl == "sorted" and budget is not None
    assert budget * 20 <= grid.n_tiles        # probed lean budget
    big = scene(300, 0.6)                     # huge splats: dense wins
    impl_b, budget_b = resolve_assignment(big, cams, grid)
    assert impl_b == "dense" and budget_b is None
    # pinned sorted keeps sorted but still gets a probed budget
    impl_s, budget_s = resolve_assignment(big, cams, grid,
                                          assign_impl="sorted")
    assert impl_s == "sorted" and budget_s is not None
    # explicit budgets pass through untouched
    impl_e, budget_e = resolve_assignment(small, cams, grid,
                                          assign_impl="sorted",
                                          assign_budget=24)
    assert (impl_e, budget_e) == ("sorted", 24)


def test_render_views_probed_budget_stays_exact_on_big_splats():
    """The app-level honesty gate: on a big-splat scene at a grid past the
    auto crossover, render_views must probe the per-splat budget from
    concrete bbox counts — demoting "auto" to the dense sweep (sorted
    cannot win there) and, when sorted is pinned, sizing the budget so the
    render stays bit-identical to the dense oracle."""
    from repro.core.cameras import orbital_rig
    from repro.core.gaussians import from_points
    from repro.core.pipeline import render_views

    r = np.random.default_rng(5)
    pts = r.uniform(0, 1, (400, 3))
    g = from_points(jnp.asarray(pts, jnp.float32),
                    jnp.asarray(r.uniform(0, 1, (400, 3))),
                    init_scale=0.5 / 400 ** (1 / 3), opacity=0.8)
    grid = TileGrid(256, 256, 8, 16)
    assert grid.n_tiles >= SORTED_MIN_TILES
    cams = orbital_rig(2, (0.5, 0.5, 0.5), 2.2, width=256, height=256)
    rgb_d, _ = render_views(g, cams, grid, K=16, assign_impl="dense")
    rgb_a, _ = render_views(g, cams, grid, K=16)                # auto
    rgb_s, _ = render_views(g, cams, grid, K=16, assign_impl="sorted")
    np.testing.assert_array_equal(rgb_a, rgb_d)
    np.testing.assert_array_equal(rgb_s, rgb_d)


def test_sorted_assignment_through_render_ref_and_interpret():
    """End-to-end: swapping assign_impl never changes the rendered tiles,
    on both the jnp oracle and the interpreted Pallas kernel."""
    from repro.core.cameras import orbital_rig, select
    from repro.core.gaussians import from_points
    from repro.core.render import render_tiles

    r = np.random.default_rng(3)
    pts = r.uniform(0, 1, (300, 3))
    g = from_points(jnp.asarray(pts, jnp.float32),
                    jnp.asarray(r.uniform(0, 1, (300, 3))), opacity=0.8)
    cams = orbital_rig(1, (0.5, 0.5, 0.5), 1.8, width=48, height=48)
    grid = TileGrid(48, 48, 8, 16)
    for impl in ("ref", "interpret"):
        t_d, _, _ = render_tiles(g, select(cams, 0), grid, K=16, impl=impl,
                                 assign_impl="dense")
        t_s, _, _ = render_tiles(g, select(cams, 0), grid, K=16, impl=impl,
                                 assign_impl="sorted",
                                 assign_budget=grid.n_tiles)
        np.testing.assert_array_equal(np.asarray(t_d), np.asarray(t_s))


def test_coarse_cull_under_vmap():
    """The batched render path vmaps assign_tiles over views."""
    grid = TileGrid(48, 48, 8, 16)
    sp = [random_splats(10 + v, 250, 48, 48) for v in range(3)]
    batched = jax.tree.map(lambda *xs: jnp.stack(xs), *sp)
    f = lambda s: assign_tiles(s, grid, K=16, coarse=2)[1]
    scores_b = jax.vmap(f)(batched)
    for v in range(3):
        np.testing.assert_array_equal(
            np.asarray(scores_b[v]), np.asarray(assign_tiles(sp[v], grid, K=16)[1]))


# ---------------------------------------------------------------------------
# >32-bit packed-key fallback (_segment_topk_sort3)
# ---------------------------------------------------------------------------


def _sparse_assign_oracle(splats, grid, K):
    """Numpy oracle over HIT tiles only: per valid splat enumerate its bbox
    tiles, apply the exact circle/rect test, then per tile sort stably by
    (depth, splat idx) and keep the first K.  Same semantics as the dense
    sweep but O(hits) instead of O(T * N), so it reaches the 65k-tile grid
    that genuinely exceeds the 32 packed key bits (where the dense sweep's
    T*N cost is prohibitive)."""
    mean = np.asarray(splats.mean2d)
    rad = np.asarray(splats.radius)
    depth = np.asarray(splats.depth)
    valid = np.asarray(splats.valid)
    per_tile = {}
    for i in np.nonzero(valid)[0]:
        x0 = int(np.clip(np.ceil((mean[i, 0] - rad[i]) / grid.tile_w) - 1,
                         0, grid.nx - 1))
        x1 = int(np.clip(np.floor((mean[i, 0] + rad[i]) / grid.tile_w),
                         0, grid.nx - 1))
        y0 = int(np.clip(np.ceil((mean[i, 1] - rad[i]) / grid.tile_h) - 1,
                         0, grid.ny - 1))
        y1 = int(np.clip(np.floor((mean[i, 1] + rad[i]) / grid.tile_h),
                         0, grid.ny - 1))
        for ty in range(y0, y1 + 1):
            for tx in range(x0, x1 + 1):
                lox, loy = tx * grid.tile_w, ty * grid.tile_h
                cx = np.clip(mean[i, 0], lox, lox + grid.tile_w)
                cy = np.clip(mean[i, 1], loy, loy + grid.tile_h)
                if ((mean[i, 0] - cx) ** 2 + (mean[i, 1] - cy) ** 2
                        <= rad[i] ** 2):
                    per_tile.setdefault(ty * grid.nx + tx, []).append(i)
    # enumeration order is splat-index ascending, so a stable depth sort
    # realizes exactly the (score desc, idx asc) two-key order
    return {t: np.array(ids)[np.argsort(depth[ids], kind="stable")][:K]
            for t, ids in per_tile.items()}


def test_sort3_fallback_exact_on_genuinely_exceeding_grid():
    """A grid/N combo whose (tile, rank) key genuinely does NOT fit 32
    bits must route to _segment_topk_sort3 and still match the exact
    assignment semantics on every hit tile (and leave the rest empty)."""
    from repro.core import tiling

    grid = TileGrid(2048, 2048, 8, 8)                 # T = 65536 -> 17 bits
    n = (1 << 15) + 1                                 # rank_bits = 16
    rank_bits = max(1, (n - 1).bit_length())
    assert grid.n_tiles.bit_length() + rank_bits > 32  # genuinely exceeding
    splats = random_splats(21, n, 2048, 2048, rmax=3.0, invalid_frac=0.05)

    # prove the dispatch really takes the fallback for THIS call
    seen = []
    orig = tiling._segment_topk_sort3

    def spy(tile, depth, *, n_tiles, K):
        seen.append(n_tiles)
        return orig(tile, depth, n_tiles=n_tiles, K=K)

    try:
        tiling._segment_topk_sort3 = spy
        budget = int(_bbox_tile_counts(splats, grid).max())
        i_s, s_s, ov = assign_tiles_sorted(splats, grid, K=8,
                                           tile_budget=budget,
                                           return_overflow=True)
    finally:
        tiling._segment_topk_sort3 = orig
    assert seen == [grid.n_tiles]
    assert int(ov) == 0
    i_s, s_s = np.asarray(i_s), np.asarray(s_s)
    depth = np.asarray(splats.depth)

    want = _sparse_assign_oracle(splats, grid, K=8)
    live = s_s > NEG / 2
    hit_tiles = np.nonzero(live.any(axis=1))[0]
    assert set(hit_tiles) == set(want)                # no phantom tiles
    assert len(want) > 100                            # scene is non-trivial
    for t, ids in want.items():
        np.testing.assert_array_equal(i_s[t][live[t]], ids)
        np.testing.assert_array_equal(s_s[t][live[t]], -depth[ids])
    # front-to-back everywhere, empty slots all NEG
    assert (np.diff(np.asarray(s_s), axis=1) <= 1e-6).all()


def test_sort3_forced_parity_sweep(monkeypatch):
    """Force EVERY packed-path call through the sort3 fallback and re-run
    the bit-identity sweep vs the dense oracle: the two top-k kernels are
    interchangeable, so fallback activation can never change results."""
    from repro.core import tiling

    calls = []

    def forced(tile, rank_of, perm, depth, *, n_tiles, K, rank_bits):
        calls.append(n_tiles)
        return tiling._segment_topk_sort3(tile, depth, n_tiles=n_tiles, K=K)

    monkeypatch.setattr(tiling, "_segment_topk_packed", forced)
    sweep = [
        (0, 150, 32, 64, {}),
        (11, 400, 64, 8, {}),                        # saturated K
        (12, 500, 64, 4, dict(rmax=14.0, invalid_frac=0.0)),  # ties at K
        (14, 200, 64, 16, dict(invalid_frac=0.6)),   # many dead splats
    ]
    for seed, n, res, K, kwargs in sweep:
        grid = TileGrid(res, res, 8, 16)
        splats = random_splats(seed, n, res, res, **kwargs)
        i_d, s_d, ov_d = assign_tiles(splats, grid, K=K, return_overflow=True)
        i_s, s_s, ov_s = assign_tiles_sorted(splats, grid, K=K,
                                             tile_budget=grid.n_tiles,
                                             return_overflow=True)
        assert int(ov_d) == 0 and int(ov_s) == 0
        np.testing.assert_array_equal(np.asarray(s_d), np.asarray(s_s))
        np.testing.assert_array_equal(np.asarray(i_d), np.asarray(i_s))
    assert len(calls) == len(sweep)                  # the forcing took
