"""Time-series warm-start training (PR 9): the ``--timeseries`` contract.

Three surfaces, each pinned at the tolerance ISSUE 9 names:

  * warm-start parity: handing ``fit_partitions`` a previous timestep's
    merged state via ``warm_start=`` lands EXACTLY on the disk-resume
    trajectory (losses bit-equal, trainables at 1e-6) — restored
    TierSchedule caps, no init re-probe (probe calls counted), densify
    key stream fast-forwarded.  Runs as a subprocess on 4 forced host
    devices (the tests/test_distributed.py driver idiom).
  * densify_cap: a property test (hypothesis, with the tests/_hyp.py
    degraded fallback) that one densify event never grows the live count
    past ``max(cap, live_before)`` — the GeoGaussian-style ``num_max``
    bound that keeps timeseries memory flat.
  * delta checkpoints: ``save_delta``/``restore_delta`` round-trip
    exactly through a >=3-deep chain — f32, int32 and cold-quantized
    int8 leaves, schedule/exchange extras riding along — and fail LOUDLY
    when the base is missing, replaced, or structurally different;
    plain ``restore`` refuses a delta step.

The end-to-end ``--timeseries`` CLI (2 timesteps, warm-start provenance
print, committed delta manifest, restart skip-to-merge) is the slow
subprocess smoke at the bottom — the pytest twin of the CI leg.
"""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:                      # degraded fallback (see tests/_hyp.py)
    from _hyp import given, settings, st

from repro.core.gaussians import from_points
from repro.core.train import GSTrainCfg, densify_and_prune, init_opt
from repro.runtime import CheckpointManager
from repro.runtime.checkpoint import dequantize_cold, quantize_cold

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


# ---------------------------------------------------------------------------
# densify_cap: live count never exceeds max(cap, live_before)
# ---------------------------------------------------------------------------


def _hot_partition(n_live, capacity, seed=0):
    """A partition where EVERY live splat is a densify candidate: uniform
    points, grad stats forced over any positive threshold."""
    rng = np.random.default_rng(seed)
    pts = jnp.asarray(rng.uniform(0.2, 0.8, (n_live, 3)), jnp.float32)
    g = from_points(pts, capacity=capacity, opacity=0.7)
    opt = init_opt(g)
    opt = opt._replace(grad_accum=jnp.ones_like(opt.grad_accum),
                       grad_count=jnp.ones_like(opt.grad_count))
    return g, opt


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 48), st.integers(0, 64), st.integers(1, 64),
       st.integers(0, 80))
def test_densify_cap_bounds_live_count(n_live, free, max_new, cap):
    """Property: after one densify event with ``densify_cap=cap`` the live
    count is <= max(cap, live_before) (a cap below the current count only
    stops GROWTH — it never force-prunes) and never exceeds capacity;
    the uncapped twin on the same state grows at least as much."""
    capacity = n_live + free
    g, opt = _hot_partition(n_live, capacity)
    cfg = GSTrainCfg(K=16, max_new=max_new, densify_grad_thresh=1e-9,
                     prune_opacity=0.0, densify_cap=cap)
    g1, _ = densify_and_prune(g, opt, jax.random.PRNGKey(0), cfg, extent=1.0)
    live1 = int(np.asarray(g1.active).sum())
    assert live1 <= max(cap, n_live)
    assert live1 <= capacity
    # never below the uncapped floor semantics: cap=None grows freely
    cfg_free = GSTrainCfg(K=16, max_new=max_new, densify_grad_thresh=1e-9,
                          prune_opacity=0.0)
    g2, _ = densify_and_prune(g, opt, jax.random.PRNGKey(0), cfg_free,
                              extent=1.0)
    assert live1 <= int(np.asarray(g2.active).sum())


def test_densify_cap_admits_exact_headroom():
    """With headroom h = cap - live and >= h free slots + hot sources, the
    capped event admits EXACTLY h children (the prefix mask neither
    over- nor under-fills)."""
    g, opt = _hot_partition(16, 64)
    cfg = GSTrainCfg(K=16, max_new=32, densify_grad_thresh=1e-9,
                     prune_opacity=0.0, densify_cap=21)
    g1, _ = densify_and_prune(g, opt, jax.random.PRNGKey(0), cfg, extent=1.0)
    assert int(np.asarray(g1.active).sum()) == 21


# ---------------------------------------------------------------------------
# Delta checkpoints: exact chained round-trip + loud failure modes
# ---------------------------------------------------------------------------


def _tree(seed, n=32):
    rng = np.random.default_rng(seed)
    return {
        "f32": jnp.asarray(rng.normal(size=(n, 4)), jnp.float32),
        "i32": jnp.asarray(rng.integers(0, 9, (n,)), jnp.int32),
        "q8": jnp.asarray(rng.integers(-127, 128, (n, 3)), jnp.int8),
    }


def _perturb_rows(tree, rows, seed):
    """Touch only ``rows`` of each leaf — the timeseries shape of change."""
    rng = np.random.default_rng(seed)
    out = {}
    for k, v in tree.items():
        arr = np.array(v)
        arr[rows] = rng.normal(size=arr[rows].shape).astype(arr.dtype) \
            if arr.dtype != np.int8 else \
            rng.integers(-127, 128, arr[rows].shape).astype(np.int8)
        out[k] = jnp.asarray(arr)
    return out


def test_delta_chain_round_trips_exactly(tmp_path):
    """full @ t0 -> delta @ t1 -> delta @ t2 -> delta @ t3: every step
    restores BIT-identically (int8 leaves included), extras ride each
    manifest, and the sparse 'rows' encoding actually engaged."""
    mgr = CheckpointManager(str(tmp_path), keep=0)
    S = 4
    trees = [_tree(0)]
    for t in range(1, 4):
        trees.append(_perturb_rows(trees[-1], [1, 7, t], seed=t))

    mgr.save(S, trees[0], extra={"timestep": 0, "schedule": {"caps": [8, 4]}})
    for t in range(1, 4):
        mgr.save_delta((t + 1) * S, trees[t], base_step=t * S,
                       extra={"timestep": t,
                              "schedule": {"caps": [8, 4]},
                              "exchange": {"budget": 128 + t}})

    like = jax.tree.map(lambda x: x, trees[0])
    for t in range(4):
        got, extra = mgr.restore_delta((t + 1) * S, like)
        assert extra["timestep"] == t
        if t:
            assert extra["exchange"]["budget"] == 128 + t
        for k in trees[t]:
            a, b = np.asarray(got[k]), np.asarray(trees[t][k])
            assert a.dtype == b.dtype, k
            np.testing.assert_array_equal(a, b, err_msg=f"t={t} leaf={k}")

    # the chain really is sparse: the f32 leaf of every delta stored rows
    for t in range(1, 4):
        with open(tmp_path / f"step_{(t + 1) * S:09d}" / "manifest.json") as f:
            m = json.load(f)
        assert m["delta"]["base_step"] == t * S
        modes = [leaf["delta"] for leaf in m["leaves"]]
        assert "rows" in modes, (t, modes)


def test_delta_composes_with_cold_quantized_checkpoints(tmp_path):
    """--ckpt-quantize int8 composability: a quantize_cold'd Gaussians tree
    (int8 colors/opacity_logit) delta-chains and round-trips exactly,
    and dequantizes to the same values either side of the round trip."""
    rng = np.random.default_rng(3)
    pts = jnp.asarray(rng.uniform(0.1, 0.9, (24, 3)), jnp.float32)
    g0 = from_points(pts, capacity=32, opacity=0.7)
    q0, meta0 = quantize_cold(g0)
    g1 = g0._replace(means=g0.means.at[2].add(0.05))
    q1, meta1 = quantize_cold(g1)

    mgr = CheckpointManager(str(tmp_path), keep=0)
    mgr.save(2, q0, extra={"quant": meta0})
    mgr.save_delta(4, q1, base_step=2, extra={"quant": meta1})
    got, extra = mgr.restore_delta(4, jax.tree.map(lambda x: x, q1))
    for name in q1._fields:
        a, b = np.asarray(getattr(got, name)), np.asarray(getattr(q1, name))
        assert a.dtype == b.dtype, name
        np.testing.assert_array_equal(a, b, err_msg=name)
    assert np.asarray(got.colors).dtype == np.int8
    np.testing.assert_array_equal(
        np.asarray(dequantize_cold(got, extra["quant"]).colors),
        np.asarray(dequantize_cold(q1, meta1).colors))


def test_delta_failure_modes_are_loud(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=0)
    t0, t1 = _tree(0), _perturb_rows(_tree(0), [0], 1)

    # save_delta without a committed base
    with pytest.raises(ValueError, match="base checkpoint step 4 is missing"):
        mgr.save_delta(8, t1, base_step=4)

    mgr.save(4, t0)
    # structure mismatch vs the base
    with pytest.raises(ValueError, match="does not match"):
        mgr.save_delta(8, {"only": t1["f32"]}, base_step=4)

    mgr.save_delta(8, t1, base_step=4)
    like = jax.tree.map(lambda x: x, t0)

    # plain restore() must refuse the delta step (restore_delta's job)
    with pytest.raises(ValueError, match="DELTA checkpoint"):
        mgr.restore(8, like)

    # base replaced after the delta was written -> digest mismatch
    mgr.save(4, _perturb_rows(t0, [2], 9))
    with pytest.raises(ValueError, match="DIFFERENT base"):
        mgr.restore_delta(8, like)

    # base gone entirely -> chain refusal names the missing step
    import shutil
    shutil.rmtree(tmp_path / "step_000000004")
    with pytest.raises(ValueError, match="needs base step 4"):
        mgr.restore_delta(8, like)


# ---------------------------------------------------------------------------
# Warm-start parity vs the disk-resume oracle (4 forced host devices)
# ---------------------------------------------------------------------------

WARM_PARITY_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import sys
sys.path.insert(0, r"%(src)s")
import tempfile
import jax, jax.numpy as jnp
import numpy as np

from repro.core.cameras import orbital_rig
import repro.core.distributed as D
from repro.core.gaussians import from_points
from repro.core.pipeline import render_views
from repro.core.tiling import TileGrid
from repro.core.train import GSTrainCfg, init_opt
from repro.data.isosurface import point_cloud_for
from repro.runtime import CheckpointManager

# count schedule probes per driver run: warm start must NOT re-probe init
probes = {"n": 0}
_real_probe = D.probe_gs_schedule
def counting_probe(*a, **kw):
    probes["n"] += 1
    return _real_probe(*a, **kw)
D.probe_gs_schedule = counting_probe

N, res, V = 256, 32, 4
pts, cols = point_cloud_for("sphere_shell", N)
pts, cols = pts[:N], cols[:N]
cams = orbital_rig(V, (0.5, 0.5, 0.5), 1.6, width=res, height=res)
mesh = jax.make_mesh((2, 2), ("part", "view"))
grid = TileGrid(res, res, 8, 16)

g_gt = from_points(jnp.asarray(pts), jnp.asarray(cols), opacity=0.95)
gts = jnp.asarray(render_views(g_gt, cams, grid, K=16, bg=0.0)[0])
masks = jnp.ones((V, res, res), bool)
g0 = from_points(jnp.asarray(pts), jnp.asarray(cols), capacity=N + 128,
                 opacity=0.7)
g_b = jax.tree.map(lambda x: x[None], g0)

cfg = GSTrainCfg(K=16, lambda_dssim=0.0, bg=0.0, view_batch=2,
                 lr_colors=5e-2, max_new=64, densify_grad_thresh=1e-9)
kw = dict(mesh=mesh, extent=1.0, densify_every=3, densify_from=0, grid=grid)

def run(**over):
    probes["n"] = 0
    out = D.fit_partitions(g_b, cams, gts[None], masks[None], cfg,
                           key=jax.random.PRNGKey(1), **kw, **over)
    return out, probes["n"]

# oracle: 0..3 with a checkpoint at 3, then disk-resume 3..6
ck = CheckpointManager(tempfile.mkdtemp(), keep=0)
(_, p_cold) = run(steps=3, ckpt=ck, ckpt_every=3,
                  schedule=cfg.tier_schedule())
sched_b = cfg.tier_schedule()
((g_r, _, l_r), p_resume) = run(steps=6, ckpt=ck, schedule=sched_b)

# warm-start: the SAME saved state handed in memory, no disk manager
tree, extra = ck.restore(3, (g_b, init_opt(g_b)))
sched_c = cfg.tier_schedule()
((g_w, _, l_w), p_warm) = run(steps=6, warm_start=(tree, extra, 3),
                              schedule=sched_c)

np.testing.assert_allclose(l_r, l_w, rtol=0, atol=0)
for k, v in g_r.trainable().items():
    np.testing.assert_allclose(np.asarray(v), np.asarray(getattr(g_w, k)),
                               rtol=0, atol=1e-6, err_msg=k)
assert sched_c.tier_caps is not None       # caps came from the warm extra
# cold run pays the init probe the resumed runs skip; warm == disk resume
assert p_cold > p_resume, (p_cold, p_resume)
assert p_warm == p_resume, (p_warm, p_resume)
print("WS-PARITY", [round(l, 5) for l in l_w])
print("WS-PROBES cold=%%d resume=%%d warm=%%d" %% (p_cold, p_resume, p_warm))

# policy guard fires on the warm path exactly like a disk resume
try:
    run(steps=6, warm_start=(tree, {"grad_compress": "int8"}, 3),
        schedule=cfg.tier_schedule())
except ValueError as e:
    assert "grad_compress" in str(e)
    print("WS-POLICY-GUARD")

# densify_cap through the driver: cap at the current live count freezes it
tree2, extra2 = ck.restore(3, (g_b, init_opt(g_b)))
live0 = int(np.asarray(tree2[0].active).sum())
((g_c, _, _), _) = run(steps=6, warm_start=(tree2, extra2, 3),
                       densify_cap=live0, schedule=cfg.tier_schedule())
live_c = int(np.asarray(g_c.active).sum())
live_w = int(np.asarray(g_w.active).sum())
assert live_c == live0 and live_w > live0, (live0, live_c, live_w)
print("WS-DENSIFY-CAP %%d -> %%d (uncapped %%d)" %% (live0, live_c, live_w))
"""


@pytest.mark.slow
def test_warm_start_matches_disk_resume(tmp_path):
    """``warm_start=`` is an in-memory resume: bit-equal losses and 1e-6
    trainables vs the disk-resume oracle, restored caps (no init probe —
    probe calls counted), resume-policy guard, and a driver-level
    densify_cap that freezes the live count where the uncapped run
    grows."""
    code = WARM_PARITY_SCRIPT % {"src": SRC}
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "WS-PARITY" in out.stdout
    assert "WS-POLICY-GUARD" in out.stdout
    assert "WS-DENSIFY-CAP" in out.stdout


# ---------------------------------------------------------------------------
# --timeseries CLI: 2 timesteps, warm provenance, committed delta, restart
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_timeseries_cli_smoke_and_restart(tmp_path):
    """`--gs --timeseries --smoke` on 4 forced host devices: t=0 cold,
    t=1 warm-started (provenance print: schedule+exchange restored, no
    init probe), t=1 committed as a DELTA against t=0's full checkpoint;
    a rerun restarts past the complete chain straight to merge."""
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    base = [sys.executable, "-m", "repro.launch.train", "--gs",
            "--timeseries", "--smoke", "--host-devices", "4",
            "--steps", "4", "--timesteps", "2",
            "--ckpt-dir", str(tmp_path)]
    out = subprocess.run(base, env=env, capture_output=True, text=True,
                         timeout=900)
    assert out.returncode == 0, (out.stdout[-2000:], out.stderr[-3000:])
    assert "timestep 0: cold start" in out.stdout
    assert "warm-start from timestep 0" in out.stdout
    assert "no init probe" in out.stdout

    man = tmp_path / "timeseries" / "step_000000008" / "manifest.json"
    with open(man) as f:
        m = json.load(f)
    assert m["delta"]["base_step"] == 4
    assert m["delta"]["base_digest"]
    assert m["extra"]["timestep"] == 1

    out2 = subprocess.run(base, env=env, capture_output=True, text=True,
                          timeout=900)
    assert out2.returncode == 0, (out2.stdout[-2000:], out2.stderr[-3000:])
    assert "chain already complete at timestep 1" in out2.stdout
    assert "warm-start from timestep" not in out2.stdout
