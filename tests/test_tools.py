"""CI gate tools behave like gates: tools/check_bench.py fails on
regressions AND on unbaselined benchmarks (with --allow-new as the
explicit escape hatch), and tools/check_cov.py enforces the core/ line
coverage floor from a coverage.xml report.  Run as subprocesses — the
tools are argv -> exit-code programs and that interface is the contract.
"""

import json
import os
import subprocess
import sys

ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _summary(entries, mode="smoke"):
    return {"schema": 1, "mode": mode,
            "entries": [{"name": n, "config": {}, "wall_clock_s": w,
                         "result": {}} for n, w in entries]}


def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return str(p)


def _check_bench(*args):
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_bench.py"),
         *args], capture_output=True, text=True, timeout=60)


def test_check_bench_passes_within_ratio(tmp_path):
    bench = _write(tmp_path, "bench.json", _summary([("a", 1.0), ("b", 2.0)]))
    base = _write(tmp_path, "base.json", _summary([("a", 1.1), ("b", 1.9)]))
    out = _check_bench("--bench", bench, "--baseline", base)
    assert out.returncode == 0, out.stdout
    assert "PASS" in out.stdout


def test_check_bench_fails_on_regression(tmp_path):
    bench = _write(tmp_path, "bench.json", _summary([("a", 10.0)]))
    base = _write(tmp_path, "base.json", _summary([("a", 1.0)]))
    out = _check_bench("--bench", bench, "--baseline", base)
    assert out.returncode == 1
    assert "REGRESSED" in out.stdout and "FAIL" in out.stdout


def test_check_bench_missing_baseline_entry_fails(tmp_path):
    """A benchmark with no baseline is an ungated benchmark — it can
    regress forever without tripping CI, so its presence must FAIL."""
    bench = _write(tmp_path, "bench.json",
                   _summary([("a", 1.0), ("new_bench", 3.0)]))
    base = _write(tmp_path, "base.json", _summary([("a", 1.0)]))
    out = _check_bench("--bench", bench, "--baseline", base)
    assert out.returncode == 1, out.stdout
    assert "no baseline for 'new_bench'" in out.stdout
    assert "FAIL" in out.stdout


def test_check_bench_allow_new_demotes_to_warning(tmp_path):
    """--allow-new is the explicit escape hatch for the PR that introduces
    a benchmark: the gate stays green, the message stays loud."""
    bench = _write(tmp_path, "bench.json",
                   _summary([("a", 1.0), ("new_bench", 3.0)]))
    base = _write(tmp_path, "base.json", _summary([("a", 1.0)]))
    out = _check_bench("--bench", bench, "--baseline", base, "--allow-new")
    assert out.returncode == 0, out.stdout
    assert "WARNING: no baseline for 'new_bench'" in out.stdout
    assert "PASS" in out.stdout
    # ...but --allow-new does NOT mask a real regression elsewhere
    bench2 = _write(tmp_path, "bench2.json",
                    _summary([("a", 9.0), ("new_bench", 3.0)]))
    out2 = _check_bench("--bench", bench2, "--baseline", base, "--allow-new")
    assert out2.returncode == 1


def test_check_bench_update_writes_baseline(tmp_path):
    bench = _write(tmp_path, "bench.json", _summary([("a", 1.0)]))
    base = str(tmp_path / "base.json")
    out = _check_bench("--bench", bench, "--baseline", base, "--update")
    assert out.returncode == 0
    assert json.load(open(base))["entries"][0]["name"] == "a"
    # the freshly updated baseline gates its own run green
    out2 = _check_bench("--bench", bench, "--baseline", base)
    assert out2.returncode == 0


COV_XML = """<?xml version="1.0" ?>
<coverage line-rate="{total}">
 <packages>
  <package name="repro.core">
   <classes>
    <class filename="src/repro/core/tiling.py" line-rate="{core}">
     <lines>{core_lines}</lines>
    </class>
    <class filename="src/repro/launch/train.py" line-rate="0.10">
     <lines><line number="1" hits="1"/><line number="2" hits="0"/></lines>
    </class>
   </classes>
  </package>
 </packages>
</coverage>
"""


def _cov_xml(tmp_path, core_hit, core_total):
    lines = "".join(
        f'<line number="{i + 1}" hits="{1 if i < core_hit else 0}"/>'
        for i in range(core_total))
    p = tmp_path / "coverage.xml"
    p.write_text(COV_XML.format(total=0.5, core=core_hit / core_total,
                                core_lines=lines))
    return str(p)


def _check_cov(*args):
    return subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_cov.py"),
         *args], capture_output=True, text=True, timeout=60)


def test_check_cov_passes_above_floor(tmp_path):
    xml = _cov_xml(tmp_path, core_hit=9, core_total=10)
    out = _check_cov("--xml", xml, "--floor", "0.5")
    assert out.returncode == 0, (out.stdout, out.stderr)
    assert "PASS" in out.stdout and "90.0%" in out.stdout


def test_check_cov_fails_below_floor(tmp_path):
    xml = _cov_xml(tmp_path, core_hit=2, core_total=10)
    out = _check_cov("--xml", xml, "--floor", "0.5")
    assert out.returncode == 1, out.stdout
    assert "FAIL" in out.stdout
    # the launch/ file's 10%% line-rate must NOT have dragged the core
    # number: scoping is by filename prefix
    assert "20.0%" in out.stdout


def test_check_cov_fails_when_scope_has_no_files(tmp_path):
    xml = _cov_xml(tmp_path, core_hit=9, core_total=10)
    out = _check_cov("--xml", xml, "--floor", "0.1",
                     "--scope", "src/repro/nonexistent/")
    assert out.returncode == 1
    assert "no files" in out.stdout.lower()
